"""Mamba2 (SSD — state-space duality) mixer, chunked-scan formulation.

Implements the Mamba2 block of zamba2: input projection to (x, z, B, C, dt),
short causal conv on x, selective state-space recurrence with scalar-per-head
decay A, gated output.  Training/prefill uses the chunked ("block-diagonal +
low-rank") algorithm: within a chunk the quadratic form, across chunks a
``lax.scan`` carrying the (H, hd, N) state — O(S·c) work, sub-quadratic in S,
which is what qualifies the hybrid arch for the 500k-token shape.

Decode keeps a conv ring (B, d_conv-1, d_in) and the SSM state
(B, H, hd, N); one token is O(1).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense, init_dense

__all__ = ["init_mamba2", "mamba2", "init_ssm_state"]

Params = Dict[str, Any]


def init_mamba2(
    key,
    d_model: int,
    *,
    d_state: int,
    d_conv: int,
    expand: int,
    head_dim: int,
    dtype=jnp.bfloat16,
) -> Params:
    d_in = expand * d_model
    nheads = d_in // head_dim
    k1, k2, k3 = jax.random.split(key, 3)
    # in_proj packs [x, z, B, C, dt] like the reference implementation.
    d_proj = 2 * d_in + 2 * d_state + nheads
    return {
        "in_proj": init_dense(k1, d_model, d_proj, dtype=dtype),
        "conv_w": (
            jax.random.normal(k2, (d_conv, d_in), jnp.float32) / math.sqrt(d_conv)
        ).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "A_log": jnp.zeros((nheads,), jnp.float32),  # A = -exp(A_log) in (-inf,0)
        "dt_bias": jnp.full((nheads,), math.log(math.e - 1), jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "norm_z": jnp.ones((d_in,), dtype),
        "out_proj": init_dense(k3, d_in, d_model, dtype=dtype),
    }


def init_ssm_state(
    batch: int, d_model: int, *, d_state: int, d_conv: int, expand: int,
    head_dim: int, dtype=jnp.float32,
) -> Dict[str, jax.Array]:
    d_in = expand * d_model
    nheads = d_in // head_dim
    return {
        "conv": jnp.zeros((batch, d_conv - 1, d_in), dtype=jnp.bfloat16),
        "ssm": jnp.zeros((batch, nheads, head_dim, d_state), dtype),
    }


def _split_proj(p: Params, x: jax.Array, d_in: int, d_state: int, nheads: int):
    proj = dense(p["in_proj"], x)
    xz, rest = proj[..., : 2 * d_in], proj[..., 2 * d_in :]
    xs, z = xz[..., :d_in], xz[..., d_in:]
    B = rest[..., :d_state]
    C = rest[..., d_state : 2 * d_state]
    dt = rest[..., 2 * d_state :]
    return xs, z, B, C, dt


def _conv1d(p: Params, xs: jax.Array, conv_state: Optional[jax.Array]):
    """Short causal depthwise conv.  xs (B,S,d_in)."""
    d_conv = p["conv_w"].shape[0]
    if conv_state is None:
        pad = jnp.zeros((xs.shape[0], d_conv - 1, xs.shape[-1]), xs.dtype)
    else:
        pad = conv_state.astype(xs.dtype)
    xp = jnp.concatenate([pad, xs], axis=1)  # (B, S+dc-1, d_in)
    out = sum(
        xp[:, i : i + xs.shape[1], :] * p["conv_w"][i][None, None, :]
        for i in range(d_conv)
    )
    new_state = xp[:, -(d_conv - 1) :, :] if d_conv > 1 else pad[:, :0]
    return jax.nn.silu(out + p["conv_b"]), new_state


def _ssd_chunked(
    xh: jax.Array,   # (B, S, H, hd)
    dt: jax.Array,   # (B, S, H) softplus'd, fp32
    A: jax.Array,    # (H,) negative, fp32
    B_: jax.Array,   # (B, S, N)
    C_: jax.Array,   # (B, S, N)
    state0: jax.Array,  # (B, H, hd, N) fp32
    chunk: int,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.  Returns (y (B,S,H,hd), final state)."""
    b, s, h, hd = xh.shape
    n = B_.shape[-1]
    nc = s // chunk
    # reshape into chunks
    xc = xh.reshape(b, nc, chunk, h, hd).astype(jnp.float32)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B_.reshape(b, nc, chunk, n).astype(jnp.float32)
    Cc = C_.reshape(b, nc, chunk, n).astype(jnp.float32)

    logd = dtc * A[None, None, None, :]          # (b,nc,c,h) log decay per step
    cum = jnp.cumsum(logd, axis=2)               # inclusive
    # intra-chunk quadratic term: y_i += C_i . sum_{j<=i} exp(cum_i-cum_j) dt_j B_j x_j
    li = cum[:, :, :, None, :]                   # (b,nc,c,1,h)
    lj = cum[:, :, None, :, :]                   # (b,nc,1,c,h)
    gate = jnp.exp(li - lj)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    gate = jnp.where(causal, gate, 0.0)
    cb = jnp.einsum("bzin,bzjn->bzij", Cc, Bc)   # (b,nc,c,c)
    w = cb[..., None] * gate                     # (b,nc,c,c,h)
    xdt = xc * dtc[..., None]                    # (b,nc,c,h,hd)
    y_intra = jnp.einsum("bzijh,bzjhd->bzihd", w, xdt)

    # per-chunk state contribution: S_z = sum_j exp(cum_end - cum_j) dt_j x_j B_j^T
    g_end = jnp.exp(cum[:, :, -1:, :] - cum)     # (b,nc,c,h)
    dS = jnp.einsum("bzch,bzchd,bzcn->bzhdn", g_end, xc * dtc[..., None], Bc)
    decay_chunk = jnp.exp(cum[:, :, -1, :])      # (b,nc,h) total chunk decay

    def step(st, inp):
        dS_z, dec_z, C_z, gin_z = inp
        # inter-chunk output for this chunk uses the INCOMING state
        y = jnp.einsum("bcn,bhdn,bch->bchd", C_z, st, gin_z)
        st = st * dec_z[:, :, None, None] + dS_z
        return st, y

    g_in = jnp.exp(cum)                          # decay from chunk start to i
    xs_scan = (
        jnp.moveaxis(dS, 1, 0),
        jnp.moveaxis(decay_chunk, 1, 0),
        jnp.moveaxis(Cc, 1, 0),
        jnp.moveaxis(g_in, 1, 0),
    )
    stateF, y_inter = jax.lax.scan(step, state0, xs_scan)
    y_inter = jnp.moveaxis(y_inter, 0, 1)        # (b,nc,c,h,hd)
    y = (y_intra + y_inter).reshape(b, s, h, hd)
    return y, stateF


def mamba2(
    p: Params,
    x: jax.Array,  # (B, S, D)
    *,
    d_state: int,
    expand: int,
    head_dim: int,
    chunk: int = 128,
    state: Optional[Dict[str, jax.Array]] = None,
    update_state: bool = False,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    b, s, d_model = x.shape
    d_in = expand * d_model
    nheads = d_in // head_dim
    xs, z, B_, C_, dt = _split_proj(p, x, d_in, d_state, nheads)
    conv_state = state["conv"] if state is not None else None
    xs, new_conv = _conv1d(p, xs, conv_state)

    A = -jnp.exp(p["A_log"])
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    xh = xs.reshape(b, s, nheads, head_dim)
    state0 = (
        state["ssm"] if state is not None
        else jnp.zeros((b, nheads, head_dim, d_state), jnp.float32)
    )

    if s == 1 and state is not None:
        # decode: one recurrence step, closed form
        dA = jnp.exp(dtp[:, 0, :] * A[None, :])            # (B,H)
        dBx = jnp.einsum(
            "bh,bhd,bn->bhdn", dtp[:, 0], xh[:, 0].astype(jnp.float32),
            B_[:, 0].astype(jnp.float32),
        )
        st = state0 * dA[:, :, None, None] + dBx
        y = jnp.einsum("bhdn,bn->bhd", st, C_[:, 0].astype(jnp.float32))
        y = y[:, None]  # (B,1,H,hd)
        stateF = st
    else:
        cs = min(chunk, s)
        if s % cs:
            raise ValueError(f"seq {s} not divisible by chunk {cs}")
        y, stateF = _ssd_chunked(xh, dtp, A, B_, C_, state0, cs)

    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, s, d_in).astype(x.dtype)
    # gated RMSNorm (Mamba2's norm-before-out)
    zf = jax.nn.silu(z.astype(jnp.float32))
    yf = y.astype(jnp.float32) * zf
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-5) * p["norm_z"].astype(jnp.float32)
    out = dense(p["out_proj"], yf.astype(x.dtype))

    if not update_state:
        return out, None
    return out, {"conv": new_conv.astype(jnp.bfloat16), "ssm": stateF}
