"""Grouped-query attention with KV cache, RoPE, and sliding-window support.

Pure-functional: ``init_attention`` builds a param pytree, ``attention``
applies it.  Three entry modes, all jit/pjit-friendly:

  * training / prefill: full (B, S) sequence, causal mask, returns the new
    KV cache when ``cache`` is a fresh one (prefill) or None (training);
  * decode: S == 1 with a ring-buffer or linear KV cache written at
    ``cache["pos"]``;
  * sliding window (``window > 0``): the causal mask is additionally banded;
    the decode cache is a ring buffer of ``window`` slots (used by the
    hybrid arch for the 500k-token long-context shape).

Sharding notes (the TP contract, see launch/shardings.py): wq/wk/wv are
column-sharded over the ``model`` axis (head dim), wo row-sharded; the cache
is sharded over batch (dp) and kv-heads (model) when divisible, else over
sequence.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import DP, dense, init_dense, rope, shard_hint
from repro.models.policy import current_policy

__all__ = ["init_attention", "attention", "init_cache", "AttnCache"]

Params = Dict[str, Any]
AttnCache = Dict[str, Any]


def init_attention(
    key,
    d_model: int,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    *,
    bias: bool = False,
    dtype=jnp.bfloat16,
) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": init_dense(kq, d_model, num_heads * head_dim, bias=bias, dtype=dtype),
        "wk": init_dense(kk, d_model, num_kv_heads * head_dim, bias=bias, dtype=dtype),
        "wv": init_dense(kv, d_model, num_kv_heads * head_dim, bias=bias, dtype=dtype),
        "wo": init_dense(ko, num_heads * head_dim, d_model, dtype=dtype),
    }


def init_cache(
    batch: int,
    seq: int,
    num_kv_heads: int,
    head_dim: int,
    *,
    window: int = 0,
    dtype=jnp.bfloat16,
) -> AttnCache:
    """Decode cache.  ``seq`` is the maximum context; with a window the
    buffer is a ring of ``min(window, seq)`` slots."""
    slots = min(window, seq) if window else seq
    return {
        "k": jnp.zeros((batch, slots, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, slots, num_kv_heads, head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def _sdpa(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,  # (B, T, KVH, hd)
    v: jax.Array,  # (B, T, KVH, hd)
    mask: Optional[jax.Array],  # broadcastable to (B, H, S, T) or None
) -> jax.Array:
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    group = h // kvh
    qg = q.reshape(b, s, kvh, group, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores * (1.0 / math.sqrt(hd))
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(b, s, h * hd)


def _expand_kv(k: jax.Array, group: int) -> jax.Array:
    """(B,T,KVH,hd) -> (B,T,KVH*group,hd) — a broadcast, so per-device only
    the local head shard materializes under the flash head sharding."""
    if group == 1:
        return k
    b, t, kvh, hd = k.shape
    return jnp.broadcast_to(
        k[:, :, :, None, :], (b, t, kvh, group, hd)
    ).reshape(b, t, kvh * group, hd)


def _sdpa_flash(
    q: jax.Array,        # (B, S, H, hd)
    k: jax.Array,        # (B, T, KVH, hd)
    v: jax.Array,        # (B, T, KVH, hd)
    q_offset,            # scalar: absolute position of query row 0
    window: int,
    block: int,
) -> jax.Array:
    """KV-chunked online-softmax attention (flash style, §Perf).

    Never materializes the (S, T) score matrix: a ``lax.scan`` over KV
    chunks carries the running (max, denominator, accumulator).  Explicit
    head sharding over the ``model`` axis keeps every chunk einsum local to
    a device (GSPMD pads when H doesn't divide TP), and GQA KV heads are
    broadcast to full heads so q/k/v shard congruently — the whole-layer
    collective cost of attention drops to the (tiny) KV all-gather.
    """
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    k = _expand_kv(k, h // kvh)
    v = _expand_kv(v, h // kvh)
    q = shard_hint(q, DP, None, "model", None)
    k = shard_hint(k, DP, None, "model", None)
    v = shard_hint(v, DP, None, "model", None)

    t = k.shape[1]
    nb = -(-t // block)
    pad = nb * block - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, nb, block, h, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nb, block, h, hd).transpose(1, 0, 2, 3, 4)

    scale = 1.0 / math.sqrt(hd)
    qi = jnp.arange(s)[:, None] + q_offset            # absolute query pos
    qf = q.astype(jnp.float32) * scale

    def body(carry, chunk):
        m, l, acc, t0 = carry
        kb, vb = chunk                                 # (B, block, H, hd)
        sc = jnp.einsum("bshd,bthd->bhst", qf, kb.astype(jnp.float32))
        kj = t0 + jnp.arange(block)[None, :]           # (1, block)
        valid = kj <= qi                               # causal
        if window:
            valid = valid & (kj > qi - window)
        valid = valid & (kj[0] < t)[None, :]           # kv padding
        sc = jnp.where(valid[None, None], sc, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
        # fully-masked-so-far rows: keep exp() finite
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(sc - m_safe[..., None])
        p = jnp.where(valid[None, None], p, 0.0)
        corr = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhst,bthd->bhsd", p, vb.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new, t0 + block), None

    m0 = jnp.full((b, h, s), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, s), jnp.float32)
    a0 = jnp.zeros((b, h, s, hd), jnp.float32)
    a0 = shard_hint(a0, DP, "model", None, None)
    (m, l, acc, _), _ = jax.lax.scan(body, (m0, l0, a0, jnp.int32(0)), (kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 2, 1, 3).astype(q.dtype)    # (B, S, H, hd)
    return out.reshape(b, s, h * hd)


def _causal_mask(s: int, t: int, offset, window: int) -> jax.Array:
    """(1, 1, s, t) boolean mask; query i attends key j iff
    j <= i + offset and (no window or j > i + offset - window)."""
    qi = jnp.arange(s)[:, None] + offset
    kj = jnp.arange(t)[None, :]
    m = kj <= qi
    if window:
        m = m & (kj > qi - window)
    return m[None, None]


def attention(
    p: Params,
    x: jax.Array,  # (B, S, D)
    positions: jax.Array,  # (B, S)
    *,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    rope_theta: float,
    window: int = 0,
    cache: Optional[AttnCache] = None,
    update_cache: bool = False,
) -> Tuple[jax.Array, Optional[AttnCache]]:
    """Apply attention.

    training:       cache=None, update_cache=False
    prefill:        cache=fresh, update_cache=True  (writes positions 0..S)
    decode (S==1):  cache=live,  update_cache=True  (writes at cache['pos'])
    """
    b, s, _ = x.shape
    q = dense(p["wq"], x).reshape(b, s, num_heads, head_dim)
    k = dense(p["wk"], x).reshape(b, s, num_kv_heads, head_dim)
    v = dense(p["wv"], x).reshape(b, s, num_kv_heads, head_dim)
    q = rope(q, positions, rope_theta)
    k = rope(k, positions, rope_theta)

    fb = current_policy().flash_block
    use_flash = fb > 0 and s > 1 and s >= fb

    if cache is None:
        if use_flash:
            out = _sdpa_flash(q, k, v, 0, window, fb)
        else:
            mask = _causal_mask(s, s, 0, window)
            out = _sdpa(q, k, v, mask)
        return dense(p["wo"], out), None

    slots = cache["k"].shape[1]
    pos = cache["pos"]
    if s == 1:
        # Decode: write one entry (ring-buffer slot when windowed).
        slot = jnp.where(jnp.int32(window) > 0, pos % slots, jnp.minimum(pos, slots - 1))
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        if current_policy().flash_decode and not window:
            # Pallas fused decode (§Perf): one VMEM pass over the cache.
            from repro.kernels.flash_decode import flash_decode

            group = num_heads // num_kv_heads
            kx = _expand_kv(ck, group).transpose(0, 2, 1, 3)  # (B,H,T,hd)
            vx = _expand_kv(cv, group).transpose(0, 2, 1, 3)
            qx = q.transpose(0, 2, 1, 3)                      # (B,H,1,hd)
            length = jnp.broadcast_to(pos + 1, (b,))
            interp = jax.default_backend() != "tpu"
            o = flash_decode(qx, kx, vx, length, interpret=interp)
            out = o.transpose(0, 2, 1, 3).reshape(b, 1, num_heads * head_dim)
            new_cache = {"k": ck, "v": cv, "pos": pos + 1}
            return dense(p["wo"], out), new_cache
        # Valid keys: absolute index of ring slot j is recoverable because we
        # only need "is it within the causal window", not its exact position
        # for RoPE (keys were rotated at write time).
        j = jnp.arange(slots)
        if window:
            age = (slot - j) % slots  # 0 = just written
            valid = (age <= jnp.minimum(pos, window - 1))
        else:
            valid = j <= pos
        mask = valid[None, None, None, :]
        out = _sdpa(q, ck, cv, mask)
        new_cache = {"k": ck, "v": cv, "pos": pos + 1}
        return dense(p["wo"], out), new_cache

    # Prefill: write the whole (possibly window-truncated) sequence.
    if use_flash:
        out = _sdpa_flash(q, k, v, 0, window, fb)
    else:
        mask = _causal_mask(s, s, 0, window)
        out = _sdpa(q, k, v, mask)
    if window and slots < s:
        # Keep the last ``slots`` keys, aligned so that ring slot
        # (i % slots) holds absolute position i for i in [s-slots, s).
        tail_k, tail_v = k[:, -slots:], v[:, -slots:]
        roll = (-(s - slots)) % slots
        ck = jnp.roll(tail_k, shift=-roll, axis=1)
        cv = jnp.roll(tail_v, shift=-roll, axis=1)
    else:
        pad = slots - s
        ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k
        cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v
    new_cache = {"k": ck, "v": cv, "pos": jnp.asarray(s, jnp.int32)}
    return dense(p["wo"], out), new_cache
