"""Trace-time compute policy: the §Perf hillclimb knobs.

The policy is ambient (a module-level stack, captured at trace time inside
``jax.jit``), so the launcher / dry-run can flip optimization regimes
without threading arguments through every model signature:

  * ``flash_block``: 0 = eager full-score SDPA (the baseline; materializes
    (B,H,S,T) scores); >0 = KV-chunked online-softmax attention (flash
    style) with explicit head sharding — never materializes the score
    matrix, removes the head_dim-contraction all-reduce GSPMD picks when
    heads don't divide the TP axis.
  * ``explicit_ep``: False = scatter/gather MoE dispatch into a globally
    sharded (E, cap, d) buffer (baseline; GSPMD lowers the scatter to
    all-reduces of the whole buffer); True = shard_map expert parallelism:
    every model-axis column selects tokens for its local experts from the
    (TP-replicated) activations, computes, and the per-token combine rides
    the existing Megatron psum.

Used with::

    with compute_policy(flash_block=1024, explicit_ep=True):
        lowered = step.lower(...)
"""
from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass, replace
from typing import Iterator, List

__all__ = ["ComputePolicy", "compute_policy", "current_policy"]


@dataclass(frozen=True)
class ComputePolicy:
    flash_block: int = 0
    explicit_ep: bool = False
    flash_decode: bool = False   # Pallas fused decode kernel (linear cache)


_STACK: List[ComputePolicy] = [ComputePolicy()]


def current_policy() -> ComputePolicy:
    return _STACK[-1]


@contextmanager
def compute_policy(**kw) -> Iterator[ComputePolicy]:
    pol = replace(_STACK[-1], **kw)
    _STACK.append(pol)
    try:
        yield pol
    finally:
        _STACK.pop()
