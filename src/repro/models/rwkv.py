"""RWKV-6 "Finch" mixer: time-mix with data-dependent decay + channel-mix.

Attention-free: the per-head state is a (hd, hd) outer-product accumulator
with a *data-dependent* per-channel decay w_t (the Finch contribution over
RWKV-5's static decay).  Training/prefill runs a chunked ``lax.scan`` over
the sequence (O(S) time, O(1) state — sub-quadratic, so rwkv6 runs the
500k-token shape); decode is a single recurrence step.

Simplifications vs. the reference CUDA implementation, noted per DESIGN.md:
the low-rank "token-shift lerp" LoRA uses one shared rank per projection and
the decay LoRA feeds ``exp(-exp(.))`` exactly as upstream.  Shapes and
parameter counts match rwkv6-1.6b at the assigned config.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense, init_dense

__all__ = ["init_rwkv6", "rwkv6_timemix", "rwkv6_channelmix", "init_rwkv_state"]

Params = Dict[str, Any]


def init_rwkv6(
    key, d_model: int, *, head_dim: int, d_ff: int, lora: int = 64,
    dtype=jnp.bfloat16,
) -> Params:
    h = d_model // head_dim
    ks = jax.random.split(key, 12)
    tm = {
        "mu": jnp.full((5, d_model), 0.5, dtype),  # shift-lerp for r,k,v,w,g
        "wr": init_dense(ks[0], d_model, d_model, dtype=dtype),
        "wk": init_dense(ks[1], d_model, d_model, dtype=dtype),
        "wv": init_dense(ks[2], d_model, d_model, dtype=dtype),
        "wg": init_dense(ks[3], d_model, d_model, dtype=dtype),
        "wo": init_dense(ks[4], d_model, d_model, dtype=dtype),
        "w_lora_a": init_dense(ks[5], d_model, lora, dtype=dtype),
        "w_lora_b": init_dense(ks[6], lora, d_model, dtype=dtype),
        "w_bias": jnp.full((d_model,), -2.0, jnp.float32),
        "bonus": (jax.random.normal(ks[7], (h, head_dim), jnp.float32) * 0.1),
        "ln_x": jnp.ones((d_model,), jnp.float32),
    }
    cm = {
        "mu": jnp.full((2, d_model), 0.5, dtype),  # shift-lerp for k,r
        "wk": init_dense(ks[8], d_model, d_ff, dtype=dtype),
        "wv": init_dense(ks[9], d_ff, d_model, dtype=dtype),
        "wr": init_dense(ks[10], d_model, d_model, dtype=dtype),
    }
    return {"tm": tm, "cm": cm}


def init_rwkv_state(batch: int, d_model: int, *, head_dim: int, dtype=jnp.float32):
    h = d_model // head_dim
    return {
        "tm_shift": jnp.zeros((batch, d_model), jnp.bfloat16),
        "cm_shift": jnp.zeros((batch, d_model), jnp.bfloat16),
        "wkv": jnp.zeros((batch, h, head_dim, head_dim), dtype),
    }


def _shift(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """x_{t-1} along seq; position 0 gets ``prev`` (or zeros)."""
    b, s, d = x.shape
    first = prev[:, None, :].astype(x.dtype) if prev is not None else jnp.zeros(
        (b, 1, d), x.dtype
    )
    return jnp.concatenate([first, x[:, :-1, :]], axis=1)


def rwkv6_timemix(
    p: Params,
    x: jax.Array,  # (B,S,D)
    *,
    head_dim: int,
    state: Optional[Dict[str, jax.Array]] = None,
    update_state: bool = False,
):
    tm = p["tm"]
    b, s, d = x.shape
    h = d // head_dim
    prev = state["tm_shift"] if state is not None else None
    xp = _shift(x, prev)
    mu = tm["mu"].astype(x.dtype)
    lerp = lambda i: x + (xp - x) * mu[i][None, None, :]
    r = dense(tm["wr"], lerp(0)).reshape(b, s, h, head_dim)
    k = dense(tm["wk"], lerp(1)).reshape(b, s, h, head_dim)
    v = dense(tm["wv"], lerp(2)).reshape(b, s, h, head_dim)
    # data-dependent decay (Finch): w = exp(-exp(bias + lora(x_lerped)))
    wlog = dense(tm["w_lora_b"], jnp.tanh(dense(tm["w_lora_a"], lerp(3))))
    wlog = tm["w_bias"][None, None, :] + wlog.astype(jnp.float32)
    w = jnp.exp(-jnp.exp(wlog)).reshape(b, s, h, head_dim)  # in (0,1)
    g = jax.nn.silu(dense(tm["wg"], lerp(4)))

    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    u = tm["bonus"][None, :, :]  # (1,h,hd)

    st0 = (
        state["wkv"] if state is not None
        else jnp.zeros((b, h, head_dim, head_dim), jnp.float32)
    )

    if s == 1 and state is not None:
        kt, vt, rt, wt = kf[:, 0], vf[:, 0], rf[:, 0], w[:, 0]
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
        y = jnp.einsum("bhi,bhij->bhj", rt, st0 + u[..., None] * kv)
        stF = st0 * wt[..., None] + kv
        out = y[:, None]  # (B,1,h,hd)
    else:
        def step(st, inp):
            kt, vt, rt, wt = inp  # (b,h,hd) each
            kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
            y = jnp.einsum("bhi,bhij->bhj", rt, st + u[..., None] * kv)
            st = st * wt[..., None] + kv
            return st, y

        seq_first = lambda a: jnp.moveaxis(a, 1, 0)
        stF, ys = jax.lax.scan(step, st0, (seq_first(kf), seq_first(vf),
                                           seq_first(rf), seq_first(w)))
        out = jnp.moveaxis(ys, 0, 1)  # (B,S,h,hd)

    # group-norm per head then output gate/proj
    of = out.reshape(b, s, h, head_dim)
    mean = jnp.mean(of, axis=-1, keepdims=True)
    var = jnp.var(of, axis=-1, keepdims=True)
    of = (of - mean) * jax.lax.rsqrt(var + 64e-5)
    of = of.reshape(b, s, d) * p["tm"]["ln_x"][None, None, :]
    y = dense(tm["wo"], (of.astype(x.dtype) * g))

    if not update_state:
        return y, None
    new_state = {"tm_shift": x[:, -1, :].astype(jnp.bfloat16), "wkv": stF}
    return y, new_state


def rwkv6_channelmix(
    p: Params,
    x: jax.Array,
    *,
    state: Optional[Dict[str, jax.Array]] = None,
    update_state: bool = False,
):
    cm = p["cm"]
    prev = state["cm_shift"] if state is not None else None
    xp = _shift(x, prev)
    mu = cm["mu"].astype(x.dtype)
    xk = x + (xp - x) * mu[0][None, None, :]
    xr = x + (xp - x) * mu[1][None, None, :]
    k = jnp.square(jax.nn.relu(dense(cm["wk"], xk)))
    kv = dense(cm["wv"], k)
    y = jax.nn.sigmoid(dense(cm["wr"], xr)) * kv
    if not update_state:
        return y, None
    return y, {"cm_shift": x[:, -1, :].astype(jnp.bfloat16)}
