"""Mixture-of-Experts layer with IPS4o-style sort-based token dispatch.

This is the paper's technique as a first-class framework feature (DESIGN.md
§3): routing n tokens to E experts *is* the paper's distribution problem —
the "classifier" is the router's expert id instead of a splitter-tree
descent, and the rest of the machinery is identical:

  local classification -> per-tile expert histograms  (core.partition)
  prefix sum           -> per-expert write offsets
  block permutation    -> the stable partition permutation groups tokens
                          into contiguous per-expert runs
  cleanup / overflow   -> capacity clamping: tokens ranked beyond an
                          expert's capacity land in a *drop bucket* — the
                          equality-bucket/overflow-block analogue.

The grouped tokens feed a dense batched expert matmul (E-contiguous runs =
the MXU-friendly layout), then the inverse permutation + top-k combine
weights scatter results back.  Under EP the expert dimension is sharded over
the ``model`` mesh axis; XLA turns the gather/scatter into the
all-to-all pair, matching the paper's "data distribution in distributed
memory algorithms" use.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.partition import partition_permutation
from repro.models.layers import dense, init_dense, shard_hint
from repro.models.policy import current_policy

__all__ = ["init_moe", "moe_ffn", "sort_dispatch", "expert_capacity"]

Params = Dict[str, Any]


def expert_capacity(num_tokens: int, num_experts: int, top_k: int,
                    capacity_factor: float) -> int:
    cap = int(math.ceil(num_tokens * top_k / num_experts * capacity_factor))
    return max(8, -(-cap // 8) * 8)


def init_moe(
    key,
    d_model: int,
    *,
    num_experts: int,
    d_ff_expert: int,
    top_k: int,
    num_shared: int = 0,
    d_ff_shared: int = 0,
    dtype=jnp.bfloat16,
) -> Params:
    kr, ke, ks = jax.random.split(key, 3)
    scale = 1.0 / math.sqrt(d_model)
    kg, ku, kd = jax.random.split(ke, 3)
    p: Params = {
        "router": init_dense(kr, d_model, num_experts, dtype=jnp.float32),
        "experts": {
            "gate": (jax.random.normal(kg, (num_experts, d_model, d_ff_expert),
                                       jnp.float32) * scale).astype(dtype),
            "up": (jax.random.normal(ku, (num_experts, d_model, d_ff_expert),
                                     jnp.float32) * scale).astype(dtype),
            "down": (jax.random.normal(kd, (num_experts, d_ff_expert, d_model),
                                       jnp.float32) / math.sqrt(d_ff_expert)
                     ).astype(dtype),
        },
    }
    if num_shared:
        kg2, ku2, kd2 = jax.random.split(ks, 3)
        dff = d_ff_shared or d_ff_expert * num_shared
        p["shared"] = {
            "gate": init_dense(kg2, d_model, dff, dtype=dtype),
            "up": init_dense(ku2, d_model, dff, dtype=dtype),
            "down": init_dense(kd2, dff, d_model, dtype=dtype),
        }
    return p


def sort_dispatch(
    expert_id: jax.Array,   # (n*k,) or (L, n*k) int32 expert assignment
    num_experts: int,
    capacity: int,
    *,
    tile: int = 2048,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """The paper's partition machinery applied to MoE routing.

    Returns (slot, kept, counts):
      slot (n*k,) int32: destination slot in the (E*capacity,) grouped
        buffer; dropped (over-capacity) entries point at slot E*capacity
        (a trash slot — the overflow block).
      kept (n*k,) bool; counts (E,) tokens per expert pre-clamp.

    A 2-D ``expert_id`` (L, n*k) dispatches L independent routing problems
    (e.g. every MoE layer of a step) in ONE call and one trace — the
    batch-axis-native form (DESIGN.md §6): per-row stable partitions,
    outputs gain the leading L dimension.  The 1-D path is the L=1 case
    of the same implementation, so per-layer parity is structural.
    """
    if expert_id.ndim == 2:
        return _sort_dispatch_batched(expert_id, num_experts, capacity, tile)
    slot, kept, counts = _sort_dispatch_batched(
        expert_id[None, :], num_experts, capacity, tile
    )
    return slot[0], kept[0], counts[0]


def _sort_dispatch_batched(
    expert_id: jax.Array, num_experts: int, capacity: int, tile: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-layer routing in one call: L stable partitions, one trace."""
    L, m = expert_id.shape
    t = min(tile, m)
    if m % t:
        t = m
    perm, offsets = jax.vmap(
        lambda e: partition_permutation(e, num_experts, t)
    )(expert_id)  # (L, m), (L, E+1)
    inv = jax.vmap(
        lambda p: jnp.zeros((m,), jnp.int32).at[p].set(
            jnp.arange(m, dtype=jnp.int32), mode="promise_in_bounds"
        )
    )(perm)
    rank = inv - jnp.take_along_axis(offsets[:, :-1], expert_id, axis=1)
    kept = rank < capacity
    slot = jnp.where(kept, expert_id * capacity + rank, num_experts * capacity)
    counts = jnp.diff(offsets, axis=1)
    return slot, kept, counts


def _expert_mlp(experts: Params, xg: jax.Array) -> jax.Array:
    """xg: (E, cap, D) -> (E, cap, D); dense grouped SwiGLU."""
    g = jnp.einsum("ecd,edf->ecf", xg, experts["gate"])
    u = jnp.einsum("ecd,edf->ecf", xg, experts["up"])
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, experts["down"])


def _ambient_mesh():
    try:
        from jax._src.mesh import thread_resources

        mesh = thread_resources.env.physical_mesh
        return mesh if mesh.axis_names else None
    except Exception:  # pragma: no cover
        return None


def _moe_ep_shard_map(p, xf, gate_vals, eids, *, num_experts, top_k,
                      capacity_factor, mesh, ep_axis="model"):
    """Explicit expert parallelism (§Perf, ``ComputePolicy.explicit_ep``).

    The Megatron-TP contract makes activations entering the FFN replicated
    over the ``model`` axis, so every model-column already HOLDS every
    token of its dp shard: no dispatch all-to-all is needed at all.  Each
    column selects the (token, k) entries routed to its E/TP local experts
    with the IPS4o partition machinery, computes the grouped MLP, combines
    locally, and a single psum over ``model`` (the same reduce a dense
    MLP's row-parallel matmul needs) sums the per-column partials.

    This replaces the baseline's GSPMD-lowered scatter into a globally
    sharded (E, cap, d) buffer — which XLA implements as all-reduces of
    the WHOLE buffer per layer (the dominant collective term of both MoE
    archs' baseline roofline).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_total = 1
    for a in dp or ():
        dp_total *= mesh.shape[a]
    dp = dp if dp else None
    e_loc = num_experts // mesh.shape[ep_axis]
    n, d = xf.shape
    # per-dp-shard capacity: each column only ever sees n/dp tokens, so the
    # buffer (and the grouped matmul) must be sized for THAT — the paper's
    # per-thread buffer blocks, not one global buffer (fixes the 2.4x
    # compute regression of the first explicit-EP cut, §Perf iteration 2b)
    cap = expert_capacity(n // dp_total, num_experts, top_k, capacity_factor)

    def column(xf, gates, eids, experts):
        nl = xf.shape[0]
        j = jax.lax.axis_index(ep_axis)
        lo = j * e_loc
        flat_e = eids.reshape(nl * top_k).astype(jnp.int32)
        local_e = flat_e - lo
        mine = (local_e >= 0) & (local_e < e_loc)
        # foreign entries land in pseudo-bucket e_loc; its slots are never
        # fed to an expert (the trash region of the buffer)
        bucket = jnp.where(mine, local_e, e_loc)
        slot, kept, counts = sort_dispatch(bucket, e_loc + 1, cap)
        kept = kept & mine
        buf = jnp.zeros(((e_loc + 1) * cap + 1, d), xf.dtype)
        tok_idx = jnp.repeat(jnp.arange(nl, dtype=jnp.int32), top_k)
        buf = buf.at[slot].set(jnp.take(xf, tok_idx, axis=0),
                               mode="promise_in_bounds")
        xg = buf[: e_loc * cap].reshape(e_loc, cap, d)
        yg = _expert_mlp(experts, xg).reshape(e_loc * cap, d)
        pad = jnp.zeros((cap + 1, d), yg.dtype)        # trash region reads 0
        yg = jnp.concatenate([yg, pad], axis=0)
        y_tok = jnp.take(yg, slot, axis=0)
        wts = (gates.reshape(nl * top_k) * kept).astype(jnp.float32)
        y = jnp.zeros((nl, d), jnp.float32).at[tok_idx].add(
            y_tok.astype(jnp.float32) * wts[:, None],
            mode="promise_in_bounds",
        )
        # the Megatron row-parallel reduce — the ONLY collective of the
        # routed path (replaces the baseline's whole-buffer all-reduces)
        y = jax.lax.psum(y, ep_axis)
        dropped = jnp.sum(mine & ~kept)
        counts = counts[:e_loc]
        if dp:  # per-dp-shard partials -> global stats
            dropped = jax.lax.psum(dropped, dp)
            counts = jax.lax.psum(counts, dp)
        return y, dropped, counts

    espec = jax.tree.map(lambda _: P(ep_axis, None, None), p["experts"])
    f = shard_map(
        column,
        mesh=mesh,
        in_specs=(P(dp, None), P(dp, None), P(dp, None), espec),
        out_specs=(P(dp, None), P(), P(ep_axis)),
        check_rep=False,
    )
    return f(xf, gate_vals, eids, p["experts"])


def moe_ffn(
    p: Params,
    x: jax.Array,   # (B, S, D)
    *,
    num_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    router_softmax_after: bool = True,
    ep_axis: Optional[str] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Returns (output, aux) where aux carries load-balancing stats."""
    b, s, d = x.shape
    n = b * s
    xf = x.reshape(n, d)
    logits = dense(p["router"], xf.astype(jnp.float32))  # (n, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eids = jax.lax.top_k(probs, top_k)         # (n, k)
    if router_softmax_after:
        gate_vals = gate_vals / (jnp.sum(gate_vals, axis=-1, keepdims=True) + 1e-9)

    cap = expert_capacity(n, num_experts, top_k, capacity_factor)

    mesh = _ambient_mesh()
    if (current_policy().explicit_ep and mesh is not None
            and "model" in mesh.axis_names
            and num_experts % mesh.shape["model"] == 0):
        y, dropped, counts = _moe_ep_shard_map(
            p, xf, gate_vals, eids, num_experts=num_experts, top_k=top_k,
            capacity_factor=capacity_factor, mesh=mesh)
        if "shared" in p:
            sh = p["shared"]
            g = dense(sh["gate"], xf)
            u = dense(sh["up"], xf)
            y = y + dense(sh["down"], jax.nn.silu(g) * u).astype(jnp.float32)
        me = jnp.mean(probs, axis=0)
        ce = counts.astype(jnp.float32) / (n * top_k)
        aux = {
            "lb_loss": num_experts * jnp.sum(me * ce),
            "dropped": dropped.astype(jnp.int32),
            "max_load": jnp.max(counts),
        }
        return y.reshape(b, s, d).astype(x.dtype), aux

    flat_e = eids.reshape(n * top_k).astype(jnp.int32)
    slot, kept, counts = sort_dispatch(flat_e, num_experts, cap)

    # scatter tokens into the grouped (E, cap) buffer (trash slot at the end)
    buf = jnp.zeros((num_experts * cap + 1, d), x.dtype)
    tok_idx = jnp.repeat(jnp.arange(n, dtype=jnp.int32), top_k)
    buf = buf.at[slot].set(jnp.take(xf, tok_idx, axis=0),
                           mode="promise_in_bounds")
    # EP: grouped buffer sharded expert-major over the model axis — the
    # scatter above + gather below become the dispatch/return all-to-alls
    xg = shard_hint(buf[:-1].reshape(num_experts, cap, d), "model", None, None)
    yg = _expert_mlp(p["experts"], xg).reshape(num_experts * cap, d)
    yg = jnp.concatenate([yg, jnp.zeros((1, d), yg.dtype)], axis=0)

    # combine: gather back + weight; dropped entries read the zero trash slot
    y_tok = jnp.take(yg, slot, axis=0)  # (n*k, d)
    wts = (gate_vals.reshape(n * top_k) * kept).astype(jnp.float32)
    y = jnp.zeros((n, d), jnp.float32).at[tok_idx].add(
        y_tok.astype(jnp.float32) * wts[:, None], mode="promise_in_bounds"
    )

    if "shared" in p:
        sh = p["shared"]
        g = dense(sh["gate"], xf)
        u = dense(sh["up"], xf)
        y = y + dense(sh["down"], jax.nn.silu(g) * u).astype(jnp.float32)

    # load-balance aux loss terms (Switch-style)
    me = jnp.mean(probs, axis=0)                       # (E,)
    ce = counts.astype(jnp.float32) / (n * top_k)
    aux = {
        "lb_loss": num_experts * jnp.sum(me * ce),
        "dropped": jnp.sum(~kept).astype(jnp.int32),
        "max_load": jnp.max(counts),
    }
    return y.reshape(b, s, d).astype(x.dtype), aux
