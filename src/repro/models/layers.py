"""Shared neural building blocks (pure-functional JAX, no framework deps)."""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm",
    "dense",
    "swiglu",
    "gelu_mlp",
    "rope",
    "init_dense",
    "init_norm",
    "cross_entropy",
    "shard_hint",
]


def shard_hint(x: jax.Array, *axes) -> jax.Array:
    """Best-effort ``with_sharding_constraint`` against the ambient mesh.

    ``axes`` give per-dimension mesh axis names (str, tuple of str, or
    None); names absent from the ambient mesh are silently dropped, and
    with no ambient mesh (plain CPU tests) this is the identity — so model
    code can carry its sharding contract without depending on the launcher.
    Critical use: the logits constraint keeps the (B, S, vocab) tensor
    vocab-sharded instead of letting GSPMD replicate it (49 GB/dev -> fits).
    """
    try:
        from jax._src.mesh import thread_resources

        mesh_axes = set(thread_resources.env.physical_mesh.axis_names)
    except Exception:  # pragma: no cover - private API fallback
        return x
    if not mesh_axes:
        return x

    def filt(a):
        if a is None:
            return None
        if isinstance(a, str):
            return a if a in mesh_axes else None
        t = tuple(n for n in a if n in mesh_axes)
        return t if t else None

    from jax.sharding import PartitionSpec

    spec = PartitionSpec(*[filt(a) for a in axes])
    return jax.lax.with_sharding_constraint(x, spec)


DP = ("pod", "data")  # data-parallel axes (filtered by shard_hint)

Params = Dict[str, Any]


def init_norm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_dense(key, d_in: int, d_out: int, bias: bool = False, dtype=jnp.bfloat16) -> Params:
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * (1.0 / math.sqrt(d_in))
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def swiglu(p: Params, x: jax.Array) -> jax.Array:
    g = dense(p["gate"], x)
    u = dense(p["up"], x)
    return dense(p["down"], jax.nn.silu(g) * u)


def gelu_mlp(p: Params, x: jax.Array) -> jax.Array:
    return dense(p["down"], jax.nn.gelu(dense(p["up"], x)))


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (..., seq, heads, hd); positions: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., s, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy, fp32 accumulation.  logits (..., V)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)
