"""The decoder-LM skeleton shared by all ten assigned architectures.

One parameter pytree + three entry points:

  ``forward(params, cfg, inputs, ...)``   logits (+ updated cache/state)
  ``train_loss(params, cfg, batch)``      scalar loss + metrics
  ``init_model(key, cfg)``                parameters
  ``init_decode_cache(cfg, batch, max_seq)``  per-family cache pytree

Layer stacking uses ``lax.scan`` over a *stacked* layer pytree (leading dim
L), so the HLO is compact (one layer body) for the 126-layer archs; remat is
``jax.checkpoint`` on the scanned body.  Three block families:

  * ``attn``   — [dense | moe | vlm | audio]: RMSNorm -> GQA -> RMSNorm ->
                 (SwiGLU | GELU-MLP | MoE-FFN with sort-based dispatch);
  * ``rwkv``   — RWKV-6 time-mix + channel-mix (LayerNorm pairs);
  * ``hybrid`` — zamba2: groups of ``attn_every`` Mamba2 layers followed by
                 one SHARED attention block (single param set reused by all
                 groups, scan over groups).

Caches are stacked along the layer dim and scanned together with the
layer params, so decode is a single fused scan as well.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    DP,
    cross_entropy,
    dense,
    gelu_mlp,
    init_dense,
    init_norm,
    rms_norm,
    shard_hint,
    swiglu,
)

__all__ = ["init_model", "forward", "train_loss", "init_decode_cache"]

Params = Dict[str, Any]

# Sliding window used by the hybrid arch's shared attention for the 500k
# shape (what makes zamba2 sub-quadratic end to end; DESIGN.md §5).
HYBRID_ATTN_WINDOW = 4096


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _init_mlp(key, cfg: ModelConfig, dtype):
    if cfg.family == "moe":
        m = cfg.moe
        return moe_mod.init_moe(
            key, cfg.d_model, num_experts=m.num_experts, d_ff_expert=m.d_ff_expert,
            top_k=m.top_k, num_shared=m.num_shared, d_ff_shared=m.d_ff_shared,
            dtype=dtype,
        )
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.family == "audio":  # GELU MLP
        return {"up": init_dense(k1, cfg.d_model, cfg.d_ff, dtype=dtype),
                "down": init_dense(k2, cfg.d_ff, cfg.d_model, dtype=dtype)}
    return {"gate": init_dense(k1, cfg.d_model, cfg.d_ff, dtype=dtype),
            "up": init_dense(k2, cfg.d_model, cfg.d_ff, dtype=dtype),
            "down": init_dense(k3, cfg.d_ff, cfg.d_model, dtype=dtype)}


def _init_attn_layer(key, cfg: ModelConfig, dtype):
    ka, km = jax.random.split(key)
    return {
        "ln1": init_norm(cfg.d_model),
        "attn": attn_mod.init_attention(
            ka, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd,
            bias=cfg.attn_bias, dtype=dtype,
        ),
        "ln2": init_norm(cfg.d_model),
        "mlp": _init_mlp(km, cfg, dtype),
    }


def _init_rwkv_layer(key, cfg: ModelConfig, dtype):
    return {
        "ln1": init_norm(cfg.d_model),
        "mix": rwkv_mod.init_rwkv6(
            key, cfg.d_model, head_dim=cfg.ssm.head_dim, d_ff=cfg.d_ff, dtype=dtype
        ),
        "ln2": init_norm(cfg.d_model),
    }


def _init_mamba_layer(key, cfg: ModelConfig, dtype):
    s = cfg.ssm
    return {
        "ln1": init_norm(cfg.d_model),
        "mamba": ssm_mod.init_mamba2(
            key, cfg.d_model, d_state=s.d_state, d_conv=s.d_conv,
            expand=s.expand, head_dim=s.head_dim, dtype=dtype,
        ),
        "ln2": init_norm(cfg.d_model),
        "mlp": {"gate": None, "up": None, "down": None},  # filled below
    }


def _stack(layers):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


def init_model(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    keys = jax.random.split(key, cfg.num_layers + 4)
    p: Params = {}
    if not cfg.takes_embeds:
        p["embed"] = (
            jax.random.normal(keys[-1], (cfg.vocab_size, cfg.d_model), jnp.float32)
            * 0.02
        ).astype(dtype)
    p["final_norm"] = init_norm(cfg.d_model)
    if not cfg.tie_embeddings:
        p["lm_head"] = init_dense(keys[-2], cfg.d_model, cfg.vocab_size, dtype=dtype)

    fam = _block_family(cfg)
    if fam == "rwkv":
        p["layers"] = _stack(
            [_init_rwkv_layer(keys[i], cfg, dtype) for i in range(cfg.num_layers)]
        )
    elif fam == "hybrid":
        g = cfg.ssm.attn_every
        assert cfg.num_layers % g == 0, "hybrid: layers must divide into groups"
        layers = []
        for i in range(cfg.num_layers):
            km, kf = jax.random.split(keys[i])
            lyr = _init_mamba_layer(km, cfg, dtype)
            k1, k2, k3 = jax.random.split(kf, 3)
            lyr["mlp"] = {
                "gate": init_dense(k1, cfg.d_model, cfg.d_ff, dtype=dtype),
                "up": init_dense(k2, cfg.d_model, cfg.d_ff, dtype=dtype),
                "down": init_dense(k3, cfg.d_ff, cfg.d_model, dtype=dtype),
            }
            layers.append(lyr)
        p["layers"] = _stack(layers)
        p["shared_attn"] = _init_attn_layer(keys[-3], cfg, dtype)  # ONE set
    else:
        p["layers"] = _stack(
            [_init_attn_layer(keys[i], cfg, dtype) for i in range(cfg.num_layers)]
        )
    return p


def _block_family(cfg: ModelConfig) -> str:
    if cfg.family == "ssm":
        return "rwkv"
    if cfg.family == "hybrid":
        return "hybrid"
    return "attn"


# --------------------------------------------------------------------------
# decode cache
# --------------------------------------------------------------------------

def init_decode_cache(cfg: ModelConfig, batch: int, max_seq: int,
                      dtype=jnp.bfloat16) -> Params:
    """Stacked (leading dim = num scanned layers) cache pytree."""
    fam = _block_family(cfg)
    L = cfg.num_layers

    def rep(tree, n):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), tree)

    if fam == "attn":
        c = attn_mod.init_cache(batch, max_seq, cfg.num_kv_heads, cfg.hd, dtype=dtype)
        return {"layers": rep(c, L)}
    if fam == "rwkv":
        s = rwkv_mod.init_rwkv_state(batch, cfg.d_model, head_dim=cfg.ssm.head_dim)
        return {"layers": rep(s, L)}
    # hybrid: mamba states per layer + one shared-attn cache per group
    s = cfg.ssm
    ms = ssm_mod.init_ssm_state(
        batch, cfg.d_model, d_state=s.d_state, d_conv=s.d_conv,
        expand=s.expand, head_dim=s.head_dim,
    )
    groups = cfg.num_layers // s.attn_every
    window = HYBRID_ATTN_WINDOW if max_seq > HYBRID_ATTN_WINDOW else 0
    ac = attn_mod.init_cache(batch, max_seq, cfg.num_kv_heads, cfg.hd,
                             window=window, dtype=dtype)
    return {"layers": rep(ms, L), "attn": rep(ac, groups)}


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------

def _attn_block(lyr, cfg: ModelConfig, x, positions, cache, update_cache,
                window: int = 0):
    h, new_cache = attn_mod.attention(
        lyr["attn"], rms_norm(lyr["ln1"], x, cfg.norm_eps), positions,
        num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads, head_dim=cfg.hd,
        rope_theta=cfg.rope_theta, window=window, cache=cache,
        update_cache=update_cache,
    )
    x = x + h
    y = rms_norm(lyr["ln2"], x, cfg.norm_eps)
    aux = None
    if cfg.family == "moe":
        m = cfg.moe
        y, aux = moe_mod.moe_ffn(
            lyr["mlp"], y, num_experts=m.num_experts, top_k=m.top_k,
            capacity_factor=m.capacity_factor,
        )
    elif cfg.family == "audio":
        y = gelu_mlp(lyr["mlp"], y)
    else:
        y = swiglu(lyr["mlp"], y)
    return x + y, new_cache, aux


def _rwkv_block(lyr, cfg: ModelConfig, x, state, update_state):
    h, st_tm = rwkv_mod.rwkv6_timemix(
        lyr["mix"], rms_norm(lyr["ln1"], x, cfg.norm_eps),
        head_dim=cfg.ssm.head_dim, state=state, update_state=update_state,
    )
    x = x + h
    h, st_cm = rwkv_mod.rwkv6_channelmix(
        lyr["mix"], rms_norm(lyr["ln2"], x, cfg.norm_eps),
        state=state, update_state=update_state,
    )
    new_state = None
    if update_state:
        new_state = {**st_tm, **st_cm}
    return x + h, new_state


def _mamba_block(lyr, cfg: ModelConfig, x, state, update_state):
    s = cfg.ssm
    h, new_state = ssm_mod.mamba2(
        lyr["mamba"], rms_norm(lyr["ln1"], x, cfg.norm_eps),
        d_state=s.d_state, expand=s.expand, head_dim=s.head_dim,
        state=state, update_state=update_state,
    )
    x = x + h
    x = x + swiglu(lyr["mlp"], rms_norm(lyr["ln2"], x, cfg.norm_eps))
    return x, new_state


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _maybe_remat(f, cfg: ModelConfig):
    return jax.checkpoint(f) if cfg.remat else f


def _scan_attn(params, cfg, x, positions, cache, update_cache):
    """Uniform attention stack; cache (if any) scanned along layers."""
    aux0 = None
    if cfg.family == "moe":
        aux0 = {"lb_loss": jnp.zeros((), jnp.float32),
                "dropped": jnp.zeros((), jnp.int32),
                "max_load": jnp.zeros((), jnp.int32)}

    def body(carry, xs):
        x, aux = carry
        lyr, c = xs
        x, nc, a = _attn_block(lyr, cfg, x, positions, c, update_cache)
        if aux is not None:
            aux = {"lb_loss": aux["lb_loss"] + a["lb_loss"],
                   "dropped": aux["dropped"] + a["dropped"],
                   "max_load": jnp.maximum(aux["max_load"], a["max_load"])}
        return (x, aux), nc

    body = _maybe_remat(body, cfg)
    (x, aux), new_cache = jax.lax.scan(
        body, (x, aux0), (params["layers"], cache)
    )
    return x, new_cache, aux


def _scan_rwkv(params, cfg, x, cache, update_cache):
    def body(x, xs):
        lyr, st = xs
        x, ns = _rwkv_block(lyr, cfg, x, st, update_cache)
        return x, ns

    body = _maybe_remat(body, cfg)
    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    return x, new_cache


def _scan_hybrid(params, cfg, x, positions, cache, update_cache):
    """Groups of attn_every mamba layers + one shared attention block."""
    g = cfg.ssm.attn_every
    L = cfg.num_layers
    groups = L // g
    shared = params["shared_attn"]
    window = 0
    if cache is not None and "attn" in cache:
        slots = cache["attn"]["k"].shape[2]
        # ring buffer iff smaller than what positions can reach; static here
        window = HYBRID_ATTN_WINDOW if slots == HYBRID_ATTN_WINDOW else 0
    regroup = lambda t: jax.tree.map(
        lambda a: a.reshape((groups, g) + a.shape[1:]), t
    )
    layers_g = regroup(params["layers"])
    mstates_g = regroup(cache["layers"]) if cache is not None else None
    acaches = cache["attn"] if cache is not None else None

    def inner(x, xs):
        lyr, st = xs
        x, ns = _mamba_block(lyr, cfg, x, st, update_cache)
        return x, ns

    inner = _maybe_remat(inner, cfg)

    def group_body(x, xs):
        lyrs, msts, ac = xs
        x, new_msts = jax.lax.scan(inner, x, (lyrs, msts))
        x, new_ac, _ = _attn_block(shared, cfg, x, positions, ac, update_cache,
                                   window=window)
        return x, (new_msts, new_ac)

    x, (new_m, new_a) = jax.lax.scan(
        group_body, x, (layers_g, mstates_g, acaches)
    )
    if not update_cache:
        return x, None
    unroll = jax.tree.map(lambda a: a.reshape((L,) + a.shape[2:]), new_m)
    return x, {"layers": unroll, "attn": new_a}


def forward(
    params: Params,
    cfg: ModelConfig,
    inputs: jax.Array,       # (B,S) int tokens  or (B,S,D) embeds
    positions: Optional[jax.Array] = None,
    cache: Optional[Params] = None,
    update_cache: bool = False,
) -> Tuple[jax.Array, Optional[Params], Optional[Dict[str, jax.Array]]]:
    """Returns (logits (B,S,V), new_cache | None, moe_aux | None)."""
    if cfg.takes_embeds:
        x = inputs.astype(jnp.bfloat16)
        b, s = x.shape[:2]
    else:
        b, s = inputs.shape
        x = jnp.take(params["embed"], inputs, axis=0)
    x = shard_hint(x, DP, None, None)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    fam = _block_family(cfg)
    aux = None
    if fam == "attn":
        lcache = cache["layers"] if cache is not None else None
        x, nc, aux = _scan_attn(params, cfg, x, positions, lcache, update_cache)
        new_cache = {"layers": nc} if update_cache else None
    elif fam == "rwkv":
        lcache = cache["layers"] if cache is not None else None
        x, nc = _scan_rwkv(params, cfg, x, lcache, update_cache)
        new_cache = {"layers": nc} if update_cache else None
    else:
        x, new_cache = _scan_hybrid(params, cfg, x, positions, cache, update_cache)

    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    x = shard_hint(x, DP, None, None)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = dense(params["lm_head"], x)
    # vocab-sharded logits: GSPMD must NOT replicate (B,S,V) per device
    logits = shard_hint(logits, DP, None, "model")
    return logits, new_cache, aux


def train_loss(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
               lb_coef: float = 0.01):
    """batch: {"inputs": (B,S)[int] | (B,S,D), "labels": (B,S) int}."""
    logits, _, aux = forward(params, cfg, batch["inputs"])
    loss = cross_entropy(logits, batch["labels"])
    metrics = {"ce": loss}
    if aux is not None:
        loss = loss + lb_coef * aux["lb_loss"] / cfg.num_layers
        metrics["lb_loss"] = aux["lb_loss"] / cfg.num_layers
        metrics["dropped"] = aux["dropped"].astype(jnp.float32)
    metrics["loss"] = loss
    return loss, metrics
