"""Compatibility shim: the comparison-tree classifier moved to
``repro.classify.tree`` when the classifier seam became a subsystem
(DESIGN.md §9).  Import from ``repro.classify`` in new code; this module
keeps the original import path working.
"""
from repro.classify.tree import (  # noqa: F401
    classify,
    classify_batched,
    classify_segmented,
    num_local_buckets,
)

__all__ = ["classify", "classify_batched", "classify_segmented", "num_local_buckets"]
