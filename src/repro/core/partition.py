"""Stable block-structured distribution (paper §4.1–§4.3), TPU formulation.

The paper's three partition phases map to:

  local classification  -> per-tile grouping: each tile (= the VMEM-resident
                           analogue of a thread's stripe-walk with k buffer
                           blocks) groups its elements by bucket id.
  prefix sum            -> per-tile histograms + exclusive scans over tiles
                           (the paper's "prefix sum over stripes"), giving
                           every tile's write offset inside every bucket.
  block permutation +   -> a single gather by the precomputed permutation;
  cleanup                  under jit the input buffer is donated, so XLA
                           reuses it (the in-place property).  The faithful
                           cycle-following variant lives in
                           ``repro.kernels.permute_inplace``.

The resulting permutation is *stable* (tiles in order, stable grouping within
a tile), which the higher levels rely on.

This module is also the engine of MoE token dispatch (``repro.models.moe``):
there the "classifier" output is the router's expert id.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["tile_histogram", "stable_partition", "partition_permutation"]

Pytree = Any


def tile_histogram(bucket_tiles: jax.Array, nb: int) -> jax.Array:
    """(T, tile) int bucket ids -> (T, nb) histogram."""
    return jax.vmap(lambda row: jnp.bincount(row, length=nb))(bucket_tiles)


def partition_permutation(
    bucket: jax.Array, nb: int, tile: int
) -> Tuple[jax.Array, jax.Array]:
    """Compute the stable partition permutation.

    Args:
      bucket: (n,) int32 bucket ids in [0, nb); n must be a multiple of tile.
      nb: number of buckets (static).
      tile: tile size (static) — the VMEM block granularity.

    Returns:
      (perm, offsets): ``sorted_x = x[perm]`` groups any payload by bucket,
      stably; ``offsets`` (nb+1,) int32 bucket boundaries.
    """
    n = bucket.shape[0]
    if n % tile:
        raise ValueError(f"n={n} not a multiple of tile={tile}")
    num_tiles = n // tile
    bt = bucket.reshape(num_tiles, tile)

    # Local classification: stable grouping within each tile.
    order = jnp.argsort(bt, axis=1, stable=True)  # (T, tile)
    bt_g = jnp.take_along_axis(bt, order, axis=1)

    # Prefix sums (paper: over stripes).
    hist = tile_histogram(bt, nb)  # (T, nb)
    totals = hist.sum(axis=0)  # (nb,)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(totals).astype(jnp.int32)]
    )
    tile_off = (jnp.cumsum(hist, axis=0) - hist).astype(jnp.int32)  # excl, (T, nb)
    run_start = (jnp.cumsum(hist, axis=1) - hist).astype(jnp.int32)  # excl, (T, nb)

    # Block permutation: destination of each grouped element.
    pos = jnp.arange(tile, dtype=jnp.int32)[None, :]
    dest = (
        jnp.take(offsets[:-1], bt_g, axis=0)
        + jnp.take_along_axis(tile_off, bt_g, axis=1)
        + (pos - jnp.take_along_axis(run_start, bt_g, axis=1))
    )  # (T, tile)

    src = (order + (jnp.arange(num_tiles, dtype=jnp.int32) * tile)[:, None]).reshape(-1)
    perm = (
        jnp.zeros((n,), jnp.int32).at[dest.reshape(-1)].set(src, mode="promise_in_bounds")
    )
    return perm, offsets


def stable_partition(
    bucket: jax.Array, arrays: Pytree, nb: int, tile: int
) -> Tuple[Pytree, jax.Array]:
    """Stably reorder every leaf of ``arrays`` so buckets are contiguous.

    Returns (reordered pytree, offsets (nb+1,)).
    """
    perm, offsets = partition_permutation(bucket, nb, tile)
    out = jax.tree.map(lambda a: jnp.take(a, perm, axis=0), arrays)
    return out, offsets
