"""Stable block-structured distribution (paper §4.1–§4.3), TPU formulation.

The paper's three partition phases map to:

  local classification  -> per-tile grouping: each tile (= the VMEM-resident
                           analogue of a thread's stripe-walk with k buffer
                           blocks) groups its elements by bucket id.
  prefix sum            -> per-tile histograms + exclusive scans over tiles
                           (the paper's "prefix sum over stripes"), giving
                           every tile's write offset inside every bucket.
  block permutation +   -> a single gather by the precomputed permutation;
  cleanup                  under jit the input buffer is donated, so XLA
                           reuses it (the in-place property).  The faithful
                           cycle-following variant lives in
                           ``repro.kernels.permute_inplace``.

The resulting permutation is *stable* (tiles in order, stable grouping within
a tile), which the higher levels rely on.

Two interchangeable engines produce that same permutation (DESIGN.md §2):

  "xla"     per-tile stable ``argsort`` grouping + prefix sums + one gather
            (O(tile·log tile) comparison sort inside the distribution pass);
  "pallas"  the fused rank+histogram kernel
            (``kernels.level_fused.rank_hist``): one non-sequential grid
            pass emits tile-local ranks and the per-tile histogram, and a
            tiny prefix epilogue closes dest[i] = offsets[b_i] +
            tile_off[t_i, b_i] + rank[i] — branchless, no comparison sort,
            no bincount glue, and no running counters to serialize the
            grid (DESIGN.md §10).  The sequential counting-rank kernel
            (``kernels.dispatch_rank``) remains as the MoE dispatch engine
            and a tested oracle.  The payload move is a scatter by dest;
            when the caller can guarantee block-homogeneous buckets
            (``partition_blocks``) the faithful in-place block-permutation
            kernel carries the move instead.

Both engines emit the *identical* stable permutation, so they are
bit-exact interchangeable — the plan cache picks per (n, dtype, hardware).

This module is also the engine of MoE token dispatch (``repro.models.moe``):
there the "classifier" output is the router's expert id.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "tile_histogram",
    "stable_partition",
    "batched_stable_partition",
    "partition_permutation",
    "partition_ranks_pallas",
    "partition_blocks",
    "ENGINES",
]

Pytree = Any

ENGINES = ("xla", "pallas")


def _default_interpret() -> bool:
    """Pallas kernels lower natively on TPU; everywhere else interpret.

    Delegates to the one shared policy (``kernels.resolve_interpret``) so
    every kernel call site in the repo resolves identically.
    """
    from repro.kernels import resolve_interpret

    return resolve_interpret()


def tile_histogram(bucket_tiles: jax.Array, nb: int) -> jax.Array:
    """(T, tile) int bucket ids -> (T, nb) histogram."""
    return jax.vmap(lambda row: jnp.bincount(row, length=nb))(bucket_tiles)


def partition_permutation(
    bucket: jax.Array, nb: int, tile: int
) -> Tuple[jax.Array, jax.Array]:
    """Compute the stable partition permutation.

    Args:
      bucket: (n,) int32 bucket ids in [0, nb); n must be a multiple of tile.
      nb: number of buckets (static).
      tile: tile size (static) — the VMEM block granularity.

    Returns:
      (perm, offsets): ``sorted_x = x[perm]`` groups any payload by bucket,
      stably; ``offsets`` (nb+1,) int32 bucket boundaries.
    """
    n = bucket.shape[0]
    if n % tile:
        raise ValueError(f"n={n} not a multiple of tile={tile}")
    num_tiles = n // tile
    bt = bucket.reshape(num_tiles, tile)

    # Local classification: stable grouping within each tile.
    # int32 keeps the scatter below typed against its int32 zeros operand
    # when x64 is enabled (argsort then returns int64 indices)
    order = jnp.argsort(bt, axis=1, stable=True).astype(jnp.int32)  # (T, tile)
    bt_g = jnp.take_along_axis(bt, order, axis=1)

    # Prefix sums (paper: over stripes).
    hist = tile_histogram(bt, nb)  # (T, nb)
    totals = hist.sum(axis=0)  # (nb,)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(totals).astype(jnp.int32)]
    )
    tile_off = (jnp.cumsum(hist, axis=0) - hist).astype(jnp.int32)  # excl, (T, nb)
    run_start = (jnp.cumsum(hist, axis=1) - hist).astype(jnp.int32)  # excl, (T, nb)

    # Block permutation: destination of each grouped element.
    pos = jnp.arange(tile, dtype=jnp.int32)[None, :]
    dest = (
        jnp.take(offsets[:-1], bt_g, axis=0)
        + jnp.take_along_axis(tile_off, bt_g, axis=1)
        + (pos - jnp.take_along_axis(run_start, bt_g, axis=1))
    )  # (T, tile)

    src = (order + (jnp.arange(num_tiles, dtype=jnp.int32) * tile)[:, None]).reshape(-1)
    perm = (
        jnp.zeros((n,), jnp.int32).at[dest.reshape(-1)].set(src, mode="promise_in_bounds")
    )
    return perm, offsets


def partition_ranks_pallas(
    bucket: jax.Array,
    offsets: jax.Array,
    nb: int,
    *,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Per-element stable counting destination via the Pallas rank kernel.

    ``offsets`` is the (nb+1,) bucket-boundary array (only the exclusive
    prefix ``offsets[:-1]`` is consumed).  Returns dest (n,) int32 such that
    scattering ``a[i] -> dest[i]`` reproduces the stable partition.
    """
    from repro.kernels.dispatch_rank import partition_ranks

    if interpret is None:
        interpret = _default_interpret()
    return partition_ranks(
        bucket.astype(jnp.int32), offsets[:-1], nb=nb, interpret=interpret
    )


def stable_partition(
    bucket: jax.Array,
    arrays: Pytree,
    nb: int,
    tile: int,
    engine: str = "xla",
    *,
    offsets: Optional[jax.Array] = None,
    interpret: Optional[bool] = None,
) -> Tuple[Pytree, jax.Array]:
    """Stably reorder every leaf of ``arrays`` so buckets are contiguous.

    ``engine`` selects how the stable placement is computed:

      "xla"     per-tile stable argsort + prefix sums + gather (default);
      "pallas"  the fused rank+histogram kernel + scatter — no comparison
                sort inside the distribution pass, no bincount glue (the
                kernel's histogram yields the boundaries as a by-product).
                ``offsets`` is accepted for API compatibility but ignored
                on this path: the fused kernel recomputes identical
                boundaries for free.

    Both engines produce bit-identical results.  Returns
    (reordered pytree, offsets (nb+1,)).
    """
    if engine == "pallas":
        from repro.kernels.level_fused import rank_hist

        dest, offsets = rank_hist(
            bucket.astype(jnp.int32), nb=nb, interpret=interpret
        )
        out = jax.tree.map(
            lambda a: jnp.zeros_like(a).at[dest].set(a, mode="promise_in_bounds"),
            arrays,
        )
        return out, offsets
    if engine != "xla":
        raise ValueError(f"unknown partition engine {engine!r}; expected {ENGINES}")
    perm, offsets = partition_permutation(bucket, nb, tile)
    out = jax.tree.map(lambda a: jnp.take(a, perm, axis=0), arrays)
    return out, offsets


def batched_stable_partition(
    bucket: jax.Array,
    arrays: Pytree,
    nb: int,
    tile: int,
    engine: str = "xla",
    *,
    offsets: Optional[jax.Array] = None,
    interpret: Optional[bool] = None,
) -> Tuple[Pytree, jax.Array]:
    """Per-row stable partition over a leading batch dimension (DESIGN.md §6).

    ``bucket`` is (B, n); every leaf of ``arrays`` is (B, n, ...).  Each row
    is partitioned independently — elements never cross rows — producing
    per-row bucket boundaries ``offsets`` (B, nb+1).

    Engines mirror :func:`stable_partition`:

      "xla"     the per-tile-argsort permutation, vmapped over rows (dense
                jnp ops batch natively);
      "pallas"  ONE launch of the batch-grid fused rank+histogram kernel
                (``kernels.level_fused.rank_hist_batched``) — rows are
                fully independent, no counter resets exist — followed by
                a flat scatter.  ``offsets`` is ignored on this path (the
                kernel recomputes identical boundaries for free).

    Both produce the bit-identical per-row stable permutation.
    """
    B, n = bucket.shape
    if engine == "pallas":
        from repro.kernels.level_fused import rank_hist_batched

        dest, offsets = rank_hist_batched(
            bucket.astype(jnp.int32), nb=nb, interpret=interpret
        )
        # flatten the per-row destinations into one scatter over (B*n, ...)
        flat_dest = (dest + n * jnp.arange(B, dtype=jnp.int32)[:, None]).reshape(-1)

        def move(a):
            fa = a.reshape((B * n,) + a.shape[2:])
            out = jnp.zeros_like(fa).at[flat_dest].set(fa, mode="promise_in_bounds")
            return out.reshape(a.shape)

        return jax.tree.map(move, arrays), offsets
    if engine != "xla":
        raise ValueError(f"unknown partition engine {engine!r}; expected {ENGINES}")
    perm, offsets = jax.vmap(lambda b: partition_permutation(b, nb, tile))(bucket)
    out = jax.tree.map(
        lambda a: jax.vmap(lambda row, p: jnp.take(row, p, axis=0))(a, perm), arrays
    )
    return out, offsets


def partition_blocks(
    arrays: Pytree,
    block_bucket: jax.Array,
    nb: int,
    block_elems: int,
    *,
    interpret: Optional[bool] = None,
) -> Tuple[Pytree, jax.Array]:
    """Group *block-homogeneous* data with the in-place Pallas kernel.

    The faithful payload move (paper §4.2): when the caller guarantees each
    consecutive run of ``block_elems`` elements shares one bucket (the
    block_bucket (N,) array gives that bucket per block — e.g. MoE capacity
    blocks, distributed chunk exchange), whole blocks move HBM-in-place via
    the stable swap-cycle kernel (``kernels.block_permute``): the *stable*
    block destinations are computed up front (``stable_block_dest``) and
    the kernel chases the permutation cycles over aliased input/output
    refs — no second n-sized buffer.  The kernel path requires every leaf
    to be 1-D with ``block_elems`` a multiple of 128; if any leaf is
    ineligible the whole pytree falls back to a gather by the stable block
    order.  Both paths realize the SAME stable permutation, so they are
    interchangeable per call (the legacy bucket-pointer kernel in
    ``kernels.permute_inplace``, which is not stable, remains as the
    faithful-§4.2 reference).

    Returns (grouped pytree, (nb+1,) *block*-boundary offsets).
    """
    from repro.kernels.block_permute import permute_blocks_by_dest, stable_block_dest

    if interpret is None:
        interpret = _default_interpret()
    hist = jnp.bincount(block_bucket, length=nb)
    d = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(hist).astype(jnp.int32)]
    )

    leaves = jax.tree.leaves(arrays)
    kernel_ok = block_elems % 128 == 0 and all(
        a.ndim == 1 and a.shape[0] % block_elems == 0 for a in leaves
    )

    if kernel_ok:
        dst = stable_block_dest(block_bucket)
        move = lambda a: permute_blocks_by_dest(
            a, dst, block_elems=block_elems, interpret=interpret
        )
    else:
        block_order = jnp.argsort(block_bucket, stable=True)
        nblocks = block_bucket.shape[0]

        def move(a):
            blocks = a.reshape((nblocks, block_elems) + a.shape[1:])
            return jnp.take(blocks, block_order, axis=0).reshape(a.shape)

    return jax.tree.map(move, arrays), d
