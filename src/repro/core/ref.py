"""Pure-jnp oracles for the sorting library.

``ref_sort`` is the ground truth every other implementation (jnp IPS4o,
Pallas kernels, distributed sort) is validated against.  It is a *stable*
sort so payload association is deterministic.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["ref_sort", "ref_partition"]


def ref_sort(keys: jax.Array, values: Any = None):
    """Stable oracle sort. Returns keys or (keys, values)."""
    if values is None:
        return jnp.sort(keys, stable=True)
    order = jnp.argsort(keys, stable=True)
    return jnp.take(keys, order, axis=0), jax.tree.map(
        lambda v: jnp.take(v, order, axis=0), values
    )


def ref_partition(
    bucket: jax.Array, arrays: Any, nb: int
) -> Tuple[Any, jax.Array]:
    """Stable bucket-grouping oracle (counting sort via stable argsort)."""
    order = jnp.argsort(bucket, stable=True)
    out = jax.tree.map(lambda a: jnp.take(a, order, axis=0), arrays)
    hist = jnp.bincount(bucket, length=nb)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(hist).astype(jnp.int32)]
    )
    return out, offsets
