"""s3-sort: non-in-place Super Scalar Samplesort [Sanders & Winkel 2004].

The paper's closest non-in-place competitor and its own starting point.  We
implement it as a baseline with the *same* classifier but the out-of-place
distribution structure the paper criticizes in §4.5 / Appendix B:

  * an explicit **oracle array** of bucket ids is materialized (s3-sort's
    trademark: classify once, store the oracle, then distribute);
  * elements are scattered into a **freshly allocated** output array (no
    buffer donation -> 2n live HBM, the "OOM column" in Table 1);
  * the result is copied back (modelled by not donating).

Used by benchmarks/io_volume.py to reproduce the paper's 48n-vs-86n I/O
volume comparison, with bytes measured from XLA's cost analysis instead of
hardware counters.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import sampling
from repro.classify import classify
from repro.core.ips4o import SortConfig, plan_levels
from repro.core.ref import ref_partition

__all__ = ["s3_sort"]


def s3_sort(keys: jax.Array, values: Any = None, cfg: SortConfig = SortConfig()):
    """Out-of-place samplesort baseline (one distribution level + small sort).

    Deliberately keeps the oracle array and out-of-place scatter alive so the
    memory/IO comparison against IPS4o is faithful to Appendix B.
    """
    n = keys.shape[0]
    if n <= 1:
        return keys if values is None else (keys, values)
    levels = plan_levels(n, cfg)
    arrays = {"k": keys}
    if values is not None:
        arrays["v"] = values
    if not levels:
        order = jnp.argsort(keys, stable=True)
        out = jax.tree.map(lambda a: jnp.take(a, order, axis=0), arrays)
        return out["k"] if values is None else (out["k"], out.get("v"))

    k = levels[0]
    m = min(max(sampling.oversampling_factor(n) * k, k), cfg.max_sample, n)
    pos = jax.random.randint(jax.random.PRNGKey(cfg.seed), (m,), 0, n)
    spl = sampling.select_splitters(jnp.sort(jnp.take(keys, pos)), k)
    oracle = classify(keys, spl, k)  # the materialized oracle array
    # Out-of-place distribution into fresh arrays.
    out, offsets = ref_partition(oracle, arrays, 2 * k)
    # Segment-local small sorts (oracle-free, vendor sorter as base case).
    seg = (
        jnp.searchsorted(
            offsets, jnp.arange(n, dtype=jnp.int32), side="right"
        ).astype(jnp.int32)
        - 1
    )
    o1 = jnp.argsort(out["k"], stable=True)
    o2 = jnp.argsort(jnp.take(seg, o1), stable=True)
    order = jnp.take(o1, o2)
    final = jax.tree.map(lambda a: jnp.take(a, order, axis=0), out)
    if values is None:
        return final["k"]
    return final["k"], final["v"]
