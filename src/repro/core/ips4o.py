"""IPS4o: In-place Parallel Super Scalar Samplesort, TPU/JAX formulation.

Structure (see DESIGN.md §4 for the full mapping from the paper):

  * recursion is flattened into at most two *level passes* (the paper's
    "adaptive number of buckets on the last two levels", §4.7, combined with
    the strictly-in-place recursion elimination, §4.6);
  * each level pass = sample -> branchless classification -> stable
    block-structured partition (``core.partition``);
  * equality buckets (§4.4) are always on: odd local bucket ids hold runs of
    identical keys and are skipped by deeper levels and the base case;
  * base case = segmented overlapped-window sort: two passes of
    per-window (bucket, key) lexicographic sorts at window offsets 0 and W/2.
    Every non-trivial bucket has size <= W/2 (checked!), so it is interior to
    a window of one of the two passes and ends up fully sorted;
  * a *robustness fallback* (data-dependent, via ``lax.cond``) runs a plain
    stable sort in the (w.h.p. impossible) event a bucket exceeds W/2 — the
    static-shape analogue of the paper's recursion-until-small guarantee;
  * padding to a multiple of W uses the key-type sentinel and a dedicated
    final bucket — the analogue of the paper's overflow block.

The returned permutation is value-exact vs. ``ref_sort`` (stable) for keys;
payload association is exact per element.  The permutation is **stable**:
every stage preserves the relative order of equal keys — the block
partition is stable by construction, equality buckets keep their input
order, the base-case ``_window_perm`` is a stable lexicographic
(bucket, key) sort and the overlapped windows never exchange equal
elements, and the robustness fallback is ``jnp.argsort(stable=True)``.
``tiebreak_passes`` (multi-word keys, DESIGN.md §11) and the differential
fuzz harness (``tests/test_fuzz_differential.py``) rely on this and pin it
against the numpy stable-argsort oracle.

Keys must form a total order under ``>`` / ``==`` at this level (raw NaNs
are rejected by that contract); the ``repro.ops`` entry points remove the
limitation by bijecting keys into the ordered uint keyspace
(``ops/keyspace.py``) before calling in, so NaN / -0.0 handling is their
concern, not this module's.

The classify+partition hot loops run on one of two engines
(``SortConfig.engine``): "xla" (dense jnp classification + per-tile-argsort
partition) or "pallas" (the fused single-pass level kernel
``kernels.level_fused`` — classify + histogram + rank in ONE grid sweep,
the paper's §4.1/§4.2 loops as one real kernel); "auto" lets the plan
cache / backend pick.  Both engines are bit-exact interchangeable
(DESIGN.md §4.8, §10).

Orthogonally, ``SortConfig.classifier`` picks the bucket-id function each
level pass uses (``repro.classify``, DESIGN.md §9): "tree" (the paper's
sampled comparison tree), "radix" (IPS2Ra bit extraction — no sampling
pass; level 2 shifts past the level-1 bits), "learned" (piecewise-linear
CDF model with an imbalance fallback to the tree), or "auto" (the plan
cache races them).  All engines honour the same contract — monotone local
ids in [0, 2k) with odd ids as equality buckets — so the partition, the
base case, and the robustness fallback are untouched by the choice.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.classify import (
    classify,
    classify_batched,
    classify_segmented,
    learned_bucket_ids,
    learned_bucket_ids_batched,
    radix_bucket_ids,
    resolve_classifier,
)
from repro.core import sampling
from repro.core.partition import ENGINES, batched_stable_partition, stable_partition
from repro.kernels import resolve_interpret

__all__ = [
    "SortConfig",
    "ips4o_sort",
    "is4o_sort",
    "plan_levels",
    "make_sorter",
    "resolve_engine",
    # level-pass internals, consumed by ``repro.ops`` (DESIGN.md §5)
    "pad_with_sentinel",
    "level_pass",
    "segmented_level_pass",
    "partition_passes",
    "base_case",
    "bucket_violations",
    "segment_ids",
    "stable_full_sort",
    "tiebreak_passes",
    # batch-axis-native pipeline, consumed by ``repro.ops.batched`` (§6)
    "ips4o_sort_batched",
    "batched_pad_with_sentinel",
    "batched_level_pass",
    "batched_segmented_level_pass",
    "batched_partition_passes",
    "batched_base_case",
    "batched_bucket_violations",
    "batched_segment_ids",
    "batched_stable_full_sort",
]


@dataclass(frozen=True)
class SortConfig:
    """Tuning parameters (paper §4.7 defaults, adapted to VMEM sizes)."""

    base_case: int = 8192          # W: base-case window (VMEM-resident)
    kmax: int = 128                # max buckets per level (paper: 256)
    tile: int = 4096               # distribution tile (the paper's stripe walk)
    slack: int = 8                 # target expected bucket size = W / slack
    max_sample: int = 8192         # cap on per-level sample size
    seed: int = 0xC0FFEE
    fallback: bool = True          # robustness fallback via lax.cond
    engine: str = "xla"            # partition engine: "xla" | "pallas" | "auto"
    classifier: str = "tree"       # "tree" | "radix" | "learned" | "auto" (§9)
    classify_rows: int = 0         # fused-kernel tile rows; 0 = roofline-derived


def plan_levels(n: int, cfg: SortConfig) -> List[int]:
    """Choose the k for each of (at most two) level passes."""
    if n <= cfg.base_case:
        return []
    target = -(-cfg.slack * n // cfg.base_case)  # ceil
    k1 = max(2, 1 << math.ceil(math.log2(target)))
    if k1 <= cfg.kmax:
        return [k1]
    k1 = cfg.kmax
    k2 = max(2, 1 << math.ceil(math.log2(-(-target // k1))))
    if k2 > cfg.kmax:
        raise ValueError(
            f"n={n} too large for 2 levels with kmax={cfg.kmax}, "
            f"base_case={cfg.base_case}"
        )
    return [k1, k2]


def _auto_tile(n: int, nb: int, cfg: SortConfig) -> int:
    """Grow the tile so the (T, nb) histogram stays bounded (<= 2^26 ints)."""
    tile = cfg.tile
    while (n // tile) * nb > (1 << 26) and tile < cfg.base_case:
        tile *= 2
    return tile


def _obs_level_stats(offsets, nb: int, pad_bucket: Optional[int], level: str) -> None:
    """Bucket-balance stats for one completed level pass, as pure
    functions of the partition offsets, delivered through the obs side
    channel (unordered debug callback — ``repro.obs``, DESIGN.md §12).
    Stages nothing — zero added jaxpr equations — unless obs is enabled
    at trace time.  Accepts (nb+1,) and batched (B, nb+1) offsets."""
    if not obs.enabled():
        return
    sizes = jnp.diff(offsets, axis=-1)
    ids = np.arange(nb)
    mask = ids % 2 == 0  # odd ids = equality buckets, sized by the data
    if pad_bucket is not None:
        mask &= ids != pad_bucket
    k_eff = int(mask.sum())
    if k_eff == 0:
        return
    rows = int(np.prod(sizes.shape[:-1], dtype=np.int64)) if sizes.ndim > 1 else 1
    szs = jnp.where(jnp.asarray(mask), sizes, 0)
    largest = jnp.max(szs)
    mean = jnp.maximum(jnp.sum(szs) / (k_eff * max(rows, 1)), 1.0)
    obs.jit_observe(
        "sort.bucket_imbalance", largest.astype(jnp.float32) / mean, level=level
    )
    obs.jit_observe("sort.largest_bucket", largest, level=level)


def _obs_base_stats(violated: jax.Array) -> None:
    """Base-case vs robustness-fallback counters (pure in-jit stats;
    staged only when obs is enabled at trace time — emitted *before* the
    ``lax.cond`` so the callback never sits inside a branch)."""
    if not obs.enabled():
        return
    v = violated.astype(jnp.int32)
    obs.jit_count("sort.fallback_engaged", v)
    obs.jit_count("sort.base_case", 1 - v)


# Largest bucket count the fused rank kernel takes on: its per-tile
# one-hot is (rows*128, nb) in VMEM, so the segmented pass (nb = seg*2k)
# must drop back to the XLA engine past this.
_PALLAS_NB_MAX = 1024


def resolve_engine(cfg: SortConfig, n: int, dtype=None, batch: Optional[int] = None) -> str:
    """Concrete engine for this (cfg, n): "auto" consults the plan cache's
    persisted choice for a same-shape sort — the (batch, n) shape when
    ``batch`` is given — else picks by backend (the kernels lower natively
    only on TPU)."""
    if cfg.engine in ENGINES:
        return cfg.engine
    if cfg.engine != "auto":
        raise ValueError(
            f"unknown engine {cfg.engine!r}; expected one of {ENGINES + ('auto',)}"
        )
    if dtype is not None:
        from repro.ops.plan import default_cache  # lazy: ops layers on core

        hint = default_cache.engine_hint(n, dtype, batch=batch)
        if hint is not None:
            return hint
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _classify_rows(n: int, cfg: SortConfig, dtype, k: int) -> int:
    """Fused level-kernel tile rows for this level, or 0 if no candidate
    tile divides n (the caller then stays on the XLA classifier).
    ``cfg.classify_rows`` pins a swept value (the plan-cache autotune
    dimension); 0 derives the largest ``KernelLaunchSpec`` candidate for
    the ``"level_fused"`` kernel kind (``launch.roofline.launch_spec``)."""
    from repro.kernels.level_fused import fused_rows

    if cfg.classify_rows:
        return cfg.classify_rows if n % (cfg.classify_rows * 128) == 0 else 0
    return fused_rows(n, jnp.dtype(dtype).itemsize, k)


def segment_ids(offsets: jax.Array, n: int) -> jax.Array:
    """Per-position bucket/segment id from (nb+1,) boundary offsets."""
    return (
        jnp.searchsorted(offsets, jnp.arange(n, dtype=jnp.int32), side="right").astype(
            jnp.int32
        )
        - 1
    )


def _window_perm(keys_w: jax.Array, fb_w: jax.Array) -> jax.Array:
    """Stable lexicographic (bucket, key) sort permutation per window."""
    o1 = jnp.argsort(keys_w, axis=1, stable=True)
    o2 = jnp.argsort(jnp.take_along_axis(fb_w, o1, axis=1), axis=1, stable=True)
    return jnp.take_along_axis(o1, o2, axis=1)


def _apply_window_perm(perm: jax.Array, a: jax.Array) -> jax.Array:
    return jax.vmap(lambda row, p: jnp.take(row, p, axis=0))(a, perm)


def base_case(arrays: Any, fb: jax.Array, W: int, limit: Optional[int] = None) -> Any:
    """Two overlapped segmented window-sort passes (DESIGN.md §4.3).

    ``limit`` (static, multiple of W) restricts both passes to the index
    range [0, limit) — used by the partial sorts in ``repro.ops.topk``,
    which only need the buckets covering the first ``k`` ranks sorted.
    """
    n = fb.shape[0] if limit is None else limit

    def one_pass(arrays, fb, lo, hi):
        keys = arrays["k"][lo:hi]
        m = hi - lo
        kw = keys.reshape(m // W, W)
        fw = fb[lo:hi].reshape(m // W, W)
        perm = _window_perm(kw, fw)

        def fix(a):
            aw = a[lo:hi].reshape((m // W, W) + a.shape[1:])
            sw = _apply_window_perm(perm, aw).reshape((m,) + a.shape[1:])
            return a.at[lo:hi].set(sw)

        arrays = jax.tree.map(fix, arrays)
        fb = fb.at[lo:hi].set(
            _apply_window_perm(perm, fw).reshape(m)
        )
        return arrays, fb

    arrays, fb = one_pass(arrays, fb, 0, n)
    if n > W:  # offset pass: windows at W/2 (ends need no second pass)
        arrays, fb = one_pass(arrays, fb, W // 2, n - W // 2)
    return arrays


def stable_full_sort(arrays: Any) -> Any:
    """Plain stable sort of the arrays dict by key — the robustness fallback."""
    order = jnp.argsort(arrays["k"], stable=True)
    return jax.tree.map(lambda a: jnp.take(a, order, axis=0), arrays)


def pad_with_sentinel(arrays: Any, unit: int) -> Any:
    """Pad every leaf of the arrays dict to a multiple of ``unit``; pad keys
    get the dtype sentinel so they sort to the tail (the overflow-block
    analogue).  Non-key leaves are zero-padded."""
    n = arrays["k"].shape[0]
    n_pad = -(-n // unit) * unit
    if n_pad == n:
        return arrays
    pad_n = n_pad - n
    sent = sampling.sentinel_for(arrays["k"].dtype)

    def pad(a):
        padding = [(0, pad_n)] + [(0, 0)] * (a.ndim - 1)
        return jnp.pad(a, padding)

    arrays = jax.tree.map(pad, arrays)
    arrays["k"] = arrays["k"].at[n:].set(sent)
    return arrays


def level_pass(
    arrays: Any,
    n_real: int,
    k: int,
    cfg: SortConfig,
    rng: jax.Array,
    consumed_bits: int = 0,
) -> Tuple[Any, jax.Array, int, int]:
    """One *global* level pass: sample -> branchless classify -> stable
    block partition.  Pads (positions >= n_real) go to a dedicated final
    bucket.  Returns (arrays, offsets, nb, pad_bucket) with nb = 2k + 1.

    The classifier engine comes from ``cfg.classifier`` (DESIGN.md §9):
    "tree" samples splitters, "radix" extracts the next log2(k) key bits
    (skipping ``consumed_bits`` fixed by earlier radix levels — no sample
    at all), "learned" fits a CDF on the sample with a measured-imbalance
    ``lax.cond`` fallback to the tree; "auto" at this depth means "tree"
    (the plan-cache routing happens at the ``repro.ops`` boundary).

    On the "pallas" engine the whole level runs as ONE fused kernel pass
    (``kernels.level_fused``): classify + per-tile histogram + in-tile
    rank in a single grid sweep — one HBM read of the keys instead of the
    former three (classify kernel, histogram glue, counting-rank kernel)
    — with pads routed to the dedicated bucket in-kernel and a prefix
    epilogue closing the destinations.  Offsets and the permutation are
    bit-identical to the "xla" engine (DESIGN.md §10).
    """
    keys = arrays["k"]
    n = keys.shape[0]
    clf = resolve_classifier(cfg.classifier)

    nb = 2 * k + 1  # +1: dedicated pad bucket (the overflow-block analogue)
    pad_n = n - n_real
    engine = resolve_engine(cfg, n, keys.dtype)
    # the fused classify kernels need a 128-aligned n (tree and radix have
    # fused forms; learned classifies on XLA); the counting-rank partition
    # self-pads, so a pallas engine keeps its partition either way
    rows = (
        _classify_rows(n, cfg, keys.dtype, k)
        if engine == "pallas" and clf in ("tree", "radix")
        else 0
    )
    interpret = resolve_interpret()

    if clf != "radix":
        with obs.trace("sample", k=k, n=n_real):
            m1 = min(
                max(sampling.oversampling_factor(n_real) * k, k), cfg.max_sample, n_real
            )
            sample_pos = jax.random.randint(rng, (m1,), 0, n_real)
            sample = jnp.sort(jnp.take(keys, sample_pos, axis=0))
            spl = sampling.select_splitters(sample, k)

    if rows:
        # the fused single-pass level kernel: classify + histogram + rank
        # in one grid sweep; pads route to bucket 2k in-kernel; the prefix
        # epilogue yields the stable destinations and bucket boundaries
        from repro.kernels.level_fused import level_fused

        with obs.trace("classify", engine="pallas", fused=True, classifier=clf, k=k):
            dest, off = level_fused(
                keys, None if clf == "radix" else spl, k=k, n_real=n_real,
                classifier=clf, consumed_bits=consumed_bits, rows=rows,
                interpret=interpret,
            )
        with obs.trace("partition", engine="pallas", fused=True, nb=nb):
            arrays = jax.tree.map(
                lambda a: jnp.zeros_like(a).at[dest].set(a, mode="promise_in_bounds"),
                arrays,
            )
        return arrays, off, nb, 2 * k
    with obs.trace("classify", engine=engine, classifier=clf, k=k):
        if clf == "radix":
            b = radix_bucket_ids(keys, k, consumed_bits)
        elif clf == "learned":
            b, _ = learned_bucket_ids(keys, sample, spl, k)
        else:
            b = classify(keys, spl, k)
        if pad_n:
            is_pad = jnp.arange(n, dtype=jnp.int32) >= n_real
            b = jnp.where(is_pad, 2 * k, b)
    with obs.trace("partition", engine=engine, nb=nb):
        arrays, off = stable_partition(
            b, arrays, nb, _auto_tile(n, nb, cfg), engine=engine,
            interpret=interpret,
        )
    return arrays, off, nb, 2 * k


def segmented_level_pass(
    arrays: Any,
    seg_offsets: jax.Array,
    num_seg: int,
    n_real: int,
    k: int,
    cfg: SortConfig,
    rng: jax.Array,
    sample_cap: int = 2048,
    classifier: str = "tree",
    consumed_bits: int = 0,
) -> Tuple[Any, jax.Array, int]:
    """One *segmented* level pass: per-segment splitters, flattened
    classification, composite-bucket partition.  This is recursion level 2
    of the full sort and the whole of ``repro.ops.segmented_sort``.

    ``seg_offsets`` (num_seg+1,) bounds each segment; segments keep their
    index ranges (the composite id is monotone in segment and the partition
    is stable).  Returns (arrays, offsets, nb) with nb = num_seg * 2k.

    ``classifier`` accepts "tree" (per-segment sampled splitters) or
    "radix" (the shared per-level shift extractor — valid ONLY when the
    segments are radix-aligned key ranges, i.e. when level 1 was a radix
    level too, which is why ``partition_passes`` is the only caller that
    passes it; the "learned" engine has no per-segment form and maps to
    "tree" one layer up).

    Classification stays on the XLA path (the composite-bucket classifier
    has no fused kernel; the radix extractor is one shift + mask, already
    as cheap as a kernel); the *partition* honours ``cfg.engine`` as long
    as nb fits the fused rank kernel's VMEM one-hot (past
    ``_PALLAS_NB_MAX`` composite buckets it drops back to "xla").
    """
    keys = arrays["k"]
    n = keys.shape[0]
    seg = segment_ids(seg_offsets, n)
    if classifier == "radix":
        # no sampling pass: within a radix-aligned segment the next
        # log2(k) bits are monotone, and the shift is segment-independent
        with obs.trace("classify", segmented=True, classifier="radix", k=k):
            local = radix_bucket_ids(keys, k, consumed_bits)
    else:
        with obs.trace("sample", segmented=True, k=k, segments=num_seg):
            m = min(max(sampling.oversampling_factor(n_real) * k, k), sample_cap)
            seg_rngs = jax.random.split(rng, num_seg)
            pos = jax.vmap(lambda r, lo, hi: sampling.sample_indices(r, m, lo, hi))(
                seg_rngs, seg_offsets[:-1], seg_offsets[1:]
            )
            svals = jnp.sort(
                jnp.take(keys, pos.reshape(-1), axis=0).reshape(num_seg, m), axis=-1
            )
            spl = sampling.select_splitters(svals, k)  # (num_seg, k-1)
        with obs.trace("classify", segmented=True, classifier="tree", k=k):
            local = classify_segmented(keys, seg, spl, k)
    comp = seg * (2 * k) + local
    nb = num_seg * 2 * k
    engine = resolve_engine(cfg, n, keys.dtype)
    if engine == "pallas" and nb > _PALLAS_NB_MAX:
        engine = "xla"
    with obs.trace("partition", segmented=True, nb=nb, engine=engine):
        arrays, offsets = stable_partition(
            comp, arrays, nb, _auto_tile(n, nb, cfg), engine=engine
        )
    return arrays, offsets, nb


def partition_passes(
    arrays: Any, n_real: int, cfg: SortConfig, levels: Sequence[int]
) -> Tuple[Any, jax.Array, int, Optional[int]]:
    """Run the (at most two) level passes of the flattened recursion.

    Returns (arrays, offsets, nb, pad_bucket); after this every bucket is
    contiguous, buckets are in key order, odd ids are equality buckets, and
    pads are at the tail (in ``pad_bucket`` after one level, in an odd
    sentinel-equality bucket after two).

    Classifier threading: level 1 takes ``cfg.classifier`` as resolved by
    ``level_pass``; level 2 reuses "radix" only when level 1 was radix (the
    segments are then bit-aligned key ranges and the next log2(k2) bits
    stay monotone per segment, with ``consumed_bits = log2(k1)``) and maps
    "learned" back to "tree" (the CDF model is global; per-segment refits
    would cost more than the per-segment tree they'd replace).
    """
    clf = resolve_classifier(cfg.classifier)
    rng = jax.random.PRNGKey(cfg.seed)
    r1, r2 = jax.random.split(rng)
    with obs.trace("level_pass", level=1, k=levels[0]):
        arrays, off1, nb1, pad_bucket = level_pass(arrays, n_real, levels[0], cfg, r1)
    _obs_level_stats(off1, nb1, pad_bucket, level="1")
    if len(levels) == 1:
        return arrays, off1, nb1, pad_bucket
    with obs.trace("level_pass", level=2, k=levels[1], segmented=True):
        arrays, offsets, nb = segmented_level_pass(
            arrays, off1, nb1, n_real, levels[1], cfg, r2,
            classifier="radix" if clf == "radix" else "tree",
            consumed_bits=int(math.log2(levels[0])),
        )
    _obs_level_stats(offsets, nb, None, level="2")
    return arrays, offsets, nb, None  # pads now sit in an odd equality bucket


def bucket_violations(
    offsets: jax.Array,
    nb: int,
    W: int,
    pad_bucket: Optional[int] = None,
    limit: Optional[jax.Array] = None,
) -> jax.Array:
    """True iff some non-trivial bucket exceeds W/2 (base-case precondition).

    Equality buckets (odd ids) hold identical keys and never need sorting,
    so their size is unbounded.  ``limit`` restricts the check to buckets
    that intersect [0, limit) — partial sorts only care about those.
    """
    sizes = jnp.diff(offsets)
    ids = jnp.arange(nb, dtype=jnp.int32)
    nontrivial = (ids % 2) == 0  # odd ids = equality buckets (all-equal)
    if pad_bucket is not None:
        nontrivial = nontrivial & (ids != pad_bucket)
    if limit is not None:
        nontrivial = nontrivial & (offsets[:-1] < limit)
    return jnp.any(jnp.where(nontrivial, sizes, 0) > W // 2)


def _sort_padded(arrays: Any, n_real: int, cfg: SortConfig, levels: Sequence[int]) -> Any:
    """Sort padded arrays dict (pads = sentinel keys at the tail)."""
    n = arrays["k"].shape[0]
    W = cfg.base_case

    if not levels:
        # Single window: plain stable base case (the paper's smallSort).
        return stable_full_sort(arrays)

    arrays, offsets, nb, pad_bucket = partition_passes(arrays, n_real, cfg, levels)

    # ---- Base case + robustness fallback ---------------------------------
    fb = segment_ids(offsets, n)
    violated = bucket_violations(offsets, nb, W, pad_bucket)
    _obs_base_stats(violated)

    with obs.trace("base_case", W=W, fallback=cfg.fallback):
        if cfg.fallback:
            return jax.lax.cond(
                violated,
                stable_full_sort,
                lambda a: base_case(a, fb, W),
                arrays,
            )
        return base_case(arrays, fb, W)


# --------------------------------------------------------------------------
# Batch-axis-native pipeline (DESIGN.md §6): every stage of the 1-D sort
# lifted over a leading batch dimension (B, n) in ONE trace.  Rows never
# exchange elements; each row gets its own splitter set, its own bucket
# offsets, and its own stable partition.  The Pallas engine runs the
# batch-grid kernels (grid = (B, tiles)); the XLA engine vmaps its dense
# formulation, which batches natively.


def batched_segment_ids(offsets: jax.Array, n: int) -> jax.Array:
    """Per-position bucket id per row from (B, nb+1) boundary offsets."""
    return jax.vmap(lambda off: segment_ids(off, n))(offsets)


def batched_stable_full_sort(arrays: Any) -> Any:
    """Per-row stable sort by key — the batched robustness fallback."""
    order = jnp.argsort(arrays["k"], axis=1, stable=True)
    take = jax.vmap(lambda a, p: jnp.take(a, p, axis=0))
    return jax.tree.map(lambda a: take(a, order), arrays)


def batched_pad_with_sentinel(arrays: Any, unit: int) -> Any:
    """Pad axis 1 of every (B, n, ...) leaf to a multiple of ``unit``; pad
    keys get the dtype sentinel (each row's overflow-block analogue)."""
    n = arrays["k"].shape[1]
    n_pad = -(-n // unit) * unit
    if n_pad == n:
        return arrays
    pad_n = n_pad - n
    sent = sampling.sentinel_for(arrays["k"].dtype)

    def pad(a):
        padding = [(0, 0), (0, pad_n)] + [(0, 0)] * (a.ndim - 2)
        return jnp.pad(a, padding)

    arrays = jax.tree.map(pad, arrays)
    arrays["k"] = arrays["k"].at[:, n:].set(sent)
    return arrays


def batched_base_case(
    arrays: Any, fb: jax.Array, W: int, limit: Optional[int] = None
) -> Any:
    """The two overlapped window-sort passes (§4.3) over (B, n, ...) leaves.

    Rows share no window: the per-row index range [lo, hi) reshapes to
    B * (hi-lo)/W independent windows, so the same ``_window_perm``
    machinery sorts every row's windows in one pass.  ``limit`` (static,
    multiple of W) restricts both passes to [0, limit) *per row*.
    """
    B = fb.shape[0]
    n = fb.shape[1] if limit is None else limit

    def one_pass(arrays, fb, lo, hi):
        m = hi - lo
        nw = B * (m // W)
        kw = arrays["k"][:, lo:hi].reshape(nw, W)
        fw = fb[:, lo:hi].reshape(nw, W)
        perm = _window_perm(kw, fw)

        def fix(a):
            aw = a[:, lo:hi].reshape((nw, W) + a.shape[2:])
            sw = _apply_window_perm(perm, aw).reshape((B, m) + a.shape[2:])
            return a.at[:, lo:hi].set(sw)

        arrays = jax.tree.map(fix, arrays)
        fb = fb.at[:, lo:hi].set(_apply_window_perm(perm, fw).reshape(B, m))
        return arrays, fb

    arrays, fb = one_pass(arrays, fb, 0, n)
    if n > W:  # offset pass: per-row windows at W/2
        arrays, fb = one_pass(arrays, fb, W // 2, n - W // 2)
    return arrays


def batched_bucket_violations(
    offsets: jax.Array,
    nb: int,
    W: int,
    pad_bucket: Optional[int] = None,
    limit: Optional[jax.Array] = None,
) -> jax.Array:
    """True iff ANY row has a non-trivial bucket exceeding W/2.  The
    fallback is batch-wide (one ``lax.cond`` for the whole trace), so a
    single violating row reroutes every row through the stable sort."""
    sizes = jnp.diff(offsets, axis=1)  # (B, nb)
    ids = jnp.arange(nb, dtype=jnp.int32)
    nontrivial = (ids % 2) == 0
    if pad_bucket is not None:
        nontrivial = nontrivial & (ids != pad_bucket)
    nontrivial = jnp.broadcast_to(nontrivial[None, :], sizes.shape)
    if limit is not None:
        nontrivial = nontrivial & (offsets[:, :-1] < limit)
    return jnp.any(jnp.where(nontrivial, sizes, 0) > W // 2)


def batched_level_pass(
    arrays: Any, n_real: int, k: int, cfg: SortConfig, rng: jax.Array
) -> Tuple[Any, jax.Array, int, int]:
    """One global level pass per row: per-row sample -> per-row splitters ->
    batched branchless classify -> per-row stable partition.

    Returns (arrays, offsets (B, nb+1), nb, pad_bucket) with nb = 2k + 1.
    On the "pallas" engine the whole level runs as ONE batch-grid launch
    of the fused level kernel (``kernels.level_fused``) for all B rows.

    Classifier dispatch mirrors ``level_pass``: "radix" skips the per-row
    sampling entirely (the shift mask is row-independent), "learned" fits
    one CDF model per row and falls back batch-wide to the per-row trees
    when any row's measured imbalance trips the threshold, "auto" at this
    depth means "tree" (the data-aware router is eager-side).
    """
    keys = arrays["k"]
    B, n = keys.shape
    clf = resolve_classifier(cfg.classifier)
    nb = 2 * k + 1  # +1: dedicated pad bucket per row
    pad_n = n - n_real
    engine = resolve_engine(cfg, n, keys.dtype)
    rows = (
        _classify_rows(n, cfg, keys.dtype, k)
        if engine == "pallas" and clf in ("tree", "radix")
        else 0
    )
    interpret = resolve_interpret()

    if clf != "radix":
        with obs.trace("sample", batched=True, k=k, n=n_real):
            m1 = min(
                max(sampling.oversampling_factor(n_real) * k, k), cfg.max_sample, n_real
            )
            row_rngs = jax.random.split(rng, B)
            sample_pos = jax.vmap(lambda r: jax.random.randint(r, (m1,), 0, n_real))(
                row_rngs
            )
            sample = jnp.sort(jnp.take_along_axis(keys, sample_pos, axis=1), axis=1)
            spl = sampling.select_splitters(sample, k)  # (B, k-1) per-row splitters

    if rows:
        # one batch-grid launch of the fused level kernel for all B rows
        from repro.kernels.level_fused import level_fused_batched

        with obs.trace("classify", batched=True, engine="pallas", fused=True, k=k):
            dest, off = level_fused_batched(
                keys, None if clf == "radix" else spl, k=k, n_real=n_real,
                classifier=clf, rows=rows, interpret=interpret,
            )
        with obs.trace("partition", batched=True, engine="pallas", fused=True, nb=nb):
            flat_dest = (
                dest + n * jnp.arange(B, dtype=jnp.int32)[:, None]
            ).reshape(-1)

            def move(a):
                fa = a.reshape((B * n,) + a.shape[2:])
                out = jnp.zeros_like(fa).at[flat_dest].set(
                    fa, mode="promise_in_bounds"
                )
                return out.reshape(a.shape)

            return jax.tree.map(move, arrays), off, nb, 2 * k
    with obs.trace("classify", batched=True, engine=engine, classifier=clf, k=k):
        if clf == "radix":
            b = radix_bucket_ids(keys, k)
        elif clf == "learned":
            b, _ = learned_bucket_ids_batched(keys, sample, spl, k)
        else:
            b = classify_batched(keys, spl, k)
        if pad_n:
            is_pad = jnp.arange(n, dtype=jnp.int32)[None, :] >= n_real
            b = jnp.where(is_pad, 2 * k, b)
    with obs.trace("partition", batched=True, engine=engine, nb=nb):
        arrays, off = batched_stable_partition(
            b, arrays, nb, _auto_tile(n, nb, cfg), engine=engine,
            interpret=interpret,
        )
    return arrays, off, nb, 2 * k


def batched_segmented_level_pass(
    arrays: Any,
    seg_offsets: jax.Array,
    num_seg: int,
    n_real: int,
    k: int,
    cfg: SortConfig,
    rng: jax.Array,
    sample_cap: int = 2048,
    classifier: str = "tree",
    consumed_bits: int = 0,
) -> Tuple[Any, jax.Array, int]:
    """Recursion level 2 per row: per-(row, segment) splitters, flattened
    classification, per-row composite-bucket partition.

    ``seg_offsets`` (B, num_seg+1) bounds each row's segments.  The
    composite id ``seg * 2k + local`` stays row-local, so the partition is
    the per-row one (nb = num_seg * 2k buckets per row) — rows still never
    exchange elements.

    ``classifier`` accepts "tree" or "radix" under the same contract as the
    1-D ``segmented_level_pass``: radix is only valid when level 1 was
    radix (bit-aligned segments), and it skips the per-(row, segment)
    sampling entirely.
    """
    keys = arrays["k"]
    B, n = keys.shape
    seg = batched_segment_ids(seg_offsets, n)  # (B, n)
    if classifier == "radix":
        local = radix_bucket_ids(keys, k, consumed_bits)
    else:
        m = min(max(sampling.oversampling_factor(n_real) * k, k), sample_cap)
        seg_rngs = jax.random.split(rng, B * num_seg).reshape(B, num_seg, -1)
        pos = jax.vmap(
            jax.vmap(lambda r, lo, hi: sampling.sample_indices(r, m, lo, hi))
        )(seg_rngs, seg_offsets[:, :-1], seg_offsets[:, 1:])  # (B, num_seg, m)
        svals = jnp.sort(
            jnp.take_along_axis(keys, pos.reshape(B, num_seg * m), axis=1).reshape(
                B, num_seg, m
            ),
            axis=-1,
        )
        spl = sampling.select_splitters(svals, k)  # (B, num_seg, k-1)
        # flatten (row, segment) -> global segment for the shared classifier
        gseg = (seg + num_seg * jnp.arange(B, dtype=jnp.int32)[:, None]).reshape(B * n)
        local = classify_segmented(
            keys.reshape(B * n), gseg, spl.reshape(B * num_seg, k - 1), k
        ).reshape(B, n)
    comp = seg * (2 * k) + local  # row-local composite bucket
    nb = num_seg * 2 * k
    engine = resolve_engine(cfg, n, keys.dtype)
    if engine == "pallas" and nb > _PALLAS_NB_MAX:
        engine = "xla"
    arrays, offsets = batched_stable_partition(
        comp, arrays, nb, _auto_tile(n, nb, cfg), engine=engine
    )
    return arrays, offsets, nb


def batched_partition_passes(
    arrays: Any, n_real: int, cfg: SortConfig, levels: Sequence[int]
) -> Tuple[Any, jax.Array, int, Optional[int]]:
    """The (at most two) batched level passes of the flattened recursion.

    Returns (arrays, offsets (B, nb+1), nb, pad_bucket); per row, buckets
    are contiguous and in key order, odd local ids are equality buckets,
    pads sit at the row tail.  Classifier threading matches the 1-D
    ``partition_passes``: radix carries to level 2 with the consumed-bit
    shift, learned maps back to tree there.
    """
    clf = resolve_classifier(cfg.classifier)
    rng = jax.random.PRNGKey(cfg.seed)
    r1, r2 = jax.random.split(rng)
    with obs.trace("level_pass", level=1, k=levels[0], batched=True):
        arrays, off1, nb1, pad_bucket = batched_level_pass(
            arrays, n_real, levels[0], cfg, r1
        )
    _obs_level_stats(off1, nb1, pad_bucket, level="1")
    if len(levels) == 1:
        return arrays, off1, nb1, pad_bucket
    with obs.trace("level_pass", level=2, k=levels[1], batched=True, segmented=True):
        arrays, offsets, nb = batched_segmented_level_pass(
            arrays, off1, nb1, n_real, levels[1], cfg, r2,
            classifier="radix" if clf == "radix" else "tree",
            consumed_bits=int(math.log2(levels[0])),
        )
    _obs_level_stats(offsets, nb, None, level="2")
    return arrays, offsets, nb, None  # pads now sit in odd equality buckets


def _sort_padded_batched(
    arrays: Any, n_real: int, cfg: SortConfig, levels: Sequence[int]
) -> Any:
    """Sort padded (B, n_pad, ...) arrays dict, all rows in one trace."""
    n = arrays["k"].shape[1]
    W = cfg.base_case

    if not levels:
        return batched_stable_full_sort(arrays)

    arrays, offsets, nb, pad_bucket = batched_partition_passes(
        arrays, n_real, cfg, levels
    )

    fb = batched_segment_ids(offsets, n)
    violated = batched_bucket_violations(offsets, nb, W, pad_bucket)
    _obs_base_stats(violated)

    with obs.trace("base_case", W=W, fallback=cfg.fallback, batched=True):
        if cfg.fallback:
            return jax.lax.cond(
                violated,
                batched_stable_full_sort,
                lambda a: batched_base_case(a, fb, W),
                arrays,
            )
        return batched_base_case(arrays, fb, W)


def ips4o_sort_batched(
    keys: jax.Array,
    values: Any = None,
    cfg: SortConfig = SortConfig(),
):
    """Sort every row of ``keys`` (B, n) independently, ascending, in ONE
    trace (DESIGN.md §6) — no vmap over the 1-D sort, no python loop.

    Optionally permutes a ``values`` pytree (leaves with leading dims
    (B, n)) alongside, row by row.  Same key contract as
    :func:`ips4o_sort`: keys must form a total order under ``>`` / ``==``
    (the ``repro.ops.batched`` entry points keyspace-encode first and are
    NaN-safe).  Jit-compatible; static shapes.
    """
    if keys.ndim != 2:
        raise ValueError("keys must be 2-D (B, n)")
    B, n = keys.shape
    if n <= 1 or B == 0:
        return keys if values is None else (keys, values)

    arrays = {"k": keys}
    if values is not None:
        arrays["v"] = values

    unit = max(cfg.base_case, cfg.tile)
    with obs.trace("ips4o_sort_batched", B=B, n=n, engine=cfg.engine):
        arrays = batched_pad_with_sentinel(arrays, unit)
        levels = plan_levels(arrays["k"].shape[1], cfg)
        arrays = _sort_padded_batched(arrays, n, cfg, levels)

    out_k = arrays["k"][:, :n]
    if values is None:
        return out_k
    return out_k, jax.tree.map(lambda a: a[:, :n], arrays["v"])


def ips4o_sort(
    keys: jax.Array,
    values: Any = None,
    cfg: SortConfig = SortConfig(),
):
    """Sort ``keys`` (n,) ascending; optionally permute a ``values`` pytree
    (leaves with leading dim n) alongside.  Jit-compatible; static shapes.

    Keys must form a total order under ``>`` / ``==``, which raw float NaNs
    do not — use the ``repro.ops`` entry points (``ops.sort`` etc.), which
    biject keys through ``ops/keyspace.py`` first and are NaN-safe (NaNs
    sort last, -0.0 before +0.0), or canonicalize NaNs yourself before
    calling this low-level engine directly.
    """
    n = keys.shape[0]
    if keys.ndim != 1:
        raise ValueError("keys must be 1-D")
    if n <= 1:
        return keys if values is None else (keys, values)

    arrays = {"k": keys}
    if values is not None:
        arrays["v"] = values

    unit = max(cfg.base_case, cfg.tile)
    with obs.trace(
        "ips4o_sort", n=n, engine=cfg.engine, classifier=cfg.classifier
    ):
        arrays = pad_with_sentinel(arrays, unit)
        levels = plan_levels(arrays["k"].shape[0], cfg)
        arrays = _sort_padded(arrays, n, cfg, levels)

    out_k = arrays["k"][:n]
    if values is None:
        return out_k
    return out_k, jax.tree.map(lambda a: a[:n], arrays["v"])


def tiebreak_passes(
    cols: Sequence[jax.Array],
    values: Any = None,
    cfg: SortConfig = SortConfig(),
) -> Tuple[List[jax.Array], Any]:
    """MSD tie-break level schedule over multi-word keys (DESIGN.md §11).

    ``cols`` is the word decomposition of each row's key, most significant
    first (word 0): W arrays of shape (n,) whose dtypes form a total order
    under ``>`` / ``==`` (the ``repro.ops`` callers pass keyspace-encoded
    uint words).  Rows end up in **stable lexicographic order** — the
    permutation is bit-identical to ``np.lexsort`` over the columns —
    relying on the stability of :func:`ips4o_sort` (module docstring).

    Schedule: level 0 sorts word 0 outright.  Level l re-sorts only the
    runs that still tie on words 0..l-1: tie runs are the
    ``group_by``-style boundary runs of the already-sorted prefix, and the
    segmented re-sort is two stable passes (sort by word l, then by run
    id — the run id is nondecreasing before the pass, so the second sort
    restores every run's index range with word l ordered inside it).
    Words 0..l-1 are *not* threaded through the re-sort: they are constant
    within a tie run by definition, and the composed permutation never
    moves an element across runs.  A level with no surviving ties is
    skipped at runtime via ``lax.cond``.

    Returns ``(sorted cols, values)``; ``values`` leaves (leading dim n)
    are permuted alongside through every pass.
    """
    cols = [c for c in cols]
    if not cols:
        raise ValueError("tiebreak_passes: need at least one word column")
    n = cols[0].shape[0]
    if any(c.shape != (n,) for c in cols):
        raise ValueError("tiebreak_passes: word columns must share shape (n,)")
    if n <= 1:
        return cols, values

    # level 0: plain sort on the most significant word
    key, state = ips4o_sort(cols[0], {"rest": cols[1:], "v": values}, cfg=cfg)
    out: List[jax.Array] = [key]
    boundary = jnp.concatenate(
        [jnp.ones((1,), jnp.bool_), key[1:] != key[:-1]]
    )

    for lvl in range(1, len(cols)):
        rest = state["rest"]
        col, rest = rest[0], rest[1:]
        # tie-run ids of the sorted prefix (words 0..lvl-1): nondecreasing,
        # one id per maximal equal-prefix run (the group_by boundary scan)
        seg = (jnp.cumsum(boundary.astype(jnp.int32)) - 1).astype(jnp.uint32)
        has_ties = jnp.any(~boundary)

        def _resort(args):
            col, rest, v, seg = args
            # stable segmented sort by (run, word lvl) as two stable passes
            col_a, st_a = ips4o_sort(col, {"seg": seg, "rest": rest, "v": v}, cfg=cfg)
            seg_b, st_b = ips4o_sort(
                st_a["seg"], {"col": col_a, "rest": st_a["rest"], "v": st_a["v"]},
                cfg=cfg,
            )
            return st_b["col"], st_b["rest"], st_b["v"], seg_b

        col, rest, v, seg = jax.lax.cond(
            has_ties, _resort, lambda args: args, (col, rest, state["v"], seg)
        )
        state = {"rest": rest, "v": v}
        out.append(col)
        boundary = boundary | jnp.concatenate(
            [jnp.ones((1,), jnp.bool_), col[1:] != col[:-1]]
        )

    return out, state["v"]


def is4o_sort(keys: jax.Array, values: Any = None, cfg: SortConfig = SortConfig()):
    """IS4o — the sequential (single-core) instantiation; on TPU a single
    core runs the same pass pipeline, so this is an alias with one stripe."""
    return ips4o_sort(keys, values, cfg)


def make_sorter(n: int, dtype, cfg: SortConfig = SortConfig(), donate: bool = True):
    """Build a jitted sorter for shape (n,); ``donate=True`` gives the
    in-place property (XLA reuses the input HBM buffer)."""
    f = partial(ips4o_sort, cfg=cfg)
    return jax.jit(f, donate_argnums=(0,) if donate else ())
