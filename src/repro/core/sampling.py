"""Splitter sampling and branchless search-tree construction (paper §3, §4).

The paper samples alpha*k - 1 elements, sorts them, picks k-1 equidistant
splitters, and stores them in an implicit binary search tree (breadth-first
layout) so that classification is a branch-free descent
``i <- 2i + (e > tree[i])``.

On TPU the descent is vectorized: one VPU lane per element, log2(k) identical
steps, zero divergence — the architectural analogue of "no branch
mispredictions".
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "tree_permutation",
    "build_tree",
    "sentinel_for",
    "oversampling_factor",
    "select_splitters",
    "splitters_from_histogram",
    "sample_indices",
]


@functools.lru_cache(maxsize=None)
def tree_permutation(k: int) -> np.ndarray:
    """Static permutation mapping BFS tree slots -> sorted-splitter indices.

    ``tree[node] = splitters[perm[node]]`` for node in 1..k-1 reproduces the
    s3-sort layout: the root holds the median splitter, etc.  Slot 0 is
    unused (descent starts at index 1).
    """
    if k & (k - 1):
        raise ValueError(f"k must be a power of two, got {k}")
    perm = np.zeros(k, np.int64)

    def rec(node: int, lo: int, hi: int) -> None:
        if lo >= hi:
            return
        mid = (lo + hi) // 2
        perm[node] = mid
        rec(2 * node, lo, mid)
        rec(2 * node + 1, mid + 1, hi)

    rec(1, 0, k - 1)
    return perm


def build_tree(splitters: jax.Array, k: int) -> jax.Array:
    """Lay out sorted splitters (..., k-1) into BFS tree slots (..., k)."""
    perm = jnp.asarray(tree_permutation(k))
    return jnp.take(splitters, perm, axis=-1)


def sentinel_for(dtype) -> jax.Array:
    """Largest representable value of ``dtype`` — used for padding and as the
    upper splitter of the last bucket (the paper's ``s_k = +inf``)."""
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.asarray(jnp.finfo(dtype).max, dtype)
    return jnp.asarray(jnp.iinfo(dtype).max, dtype)


def oversampling_factor(n: int) -> int:
    """Paper §4.7: alpha = 0.2 * log2(n), at least 1."""
    return max(1, int(0.2 * math.log2(max(n, 2))))


def sample_indices(rng: jax.Array, num: int, lo, hi) -> jax.Array:
    """Uniform sample positions in [lo, hi); lo/hi may be traced scalars.

    ``hi - lo`` may be zero (empty segment) — indices clamp to ``lo`` which is
    harmless because no element classifies into an empty segment.
    """
    u = jax.random.uniform(rng, (num,))
    size = jnp.maximum(hi - lo, 1)
    idx = lo + jnp.floor(u * size).astype(jnp.int32)
    return jnp.clip(idx, lo, jnp.maximum(hi - 1, lo))


def select_splitters(sorted_sample: jax.Array, k: int) -> jax.Array:
    """Pick k-1 equidistant splitters from a sorted sample (..., m)."""
    m = sorted_sample.shape[-1]
    idx = np.clip(((np.arange(1, k) * m) // k), 0, m - 1)
    return jnp.take(sorted_sample, jnp.asarray(idx), axis=-1)


def splitters_from_histogram(
    candidates: jax.Array, cum_counts: jax.Array, k: int, total: jax.Array
) -> jax.Array:
    """Re-split rule (DESIGN.md §8): k-1 splitters from observed key ranks.

    ``candidates`` is a sorted (m,) set of candidate splitter values and
    ``cum_counts[j]`` the *observed* number of keys strictly below
    ``candidates[j]`` (a global histogram, not a sample estimate).  The
    returned splitters are the candidates whose observed ranks best match
    the equidistant target ranks ``i * total / k`` — exact load balance up
    to the mass between adjacent candidates, which is what a failed
    sample-based split retries with.  ``total`` may be a traced scalar;
    the target arithmetic avoids the ``total * (k-1)`` int32 overflow.
    """
    i = jnp.arange(1, k, dtype=jnp.int32)
    total = total.astype(jnp.int32)
    target = (total // k) * i + ((total % k) * i) // k
    j = jnp.searchsorted(cum_counts, target, side="left")
    j = jnp.clip(j, 0, candidates.shape[0] - 1)
    return jnp.take(candidates, j)
