"""Multi-chip distributed sort: IPS4o as the data-distribution engine.

The paper's conclusion: "The algorithm can also be used for data
distribution and local sorting in distributed memory parallel algorithms
[2] (AMS-sort)".  This module is that instantiation on a TPU mesh:

  1. every core samples its stripe; samples are all-gathered and a shared
     splitter set (one splitter per core boundary, oversampled) is chosen —
     the distributed analogue of the sampling phase;
  2. each core runs *local classification* (branchless, same classifier) to
     one bucket per destination core, then the *stable block partition* so
     its stripe is destination-contiguous — exactly the paper's local
     classification phase with cores as buckets;
  3. one capacity-padded ``all_to_all`` moves whole contiguous chunks — the
     paper's block permutation phase, with ICI links instead of shared
     memory (pointer atomics -> a single collective; see DESIGN.md §2);
  4. every core sorts what it received with local IS4o (sequential IPS4o).

Result: globally sorted in core order, each shard padded to capacity with
sentinels and a valid-count per shard (the static-shape price of SPMD; the
overflow flag reports capacity violations instead of UB).

Works on any 1-D logical axis (or tuple of axes, e.g. ("pod", "data")).
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import sampling
from repro.core.ips4o import SortConfig, ips4o_sort, resolve_engine
from repro.core.partition import stable_partition

__all__ = ["distributed_sort", "make_distributed_sorter"]

AxisNames = Union[str, Tuple[str, ...]]


def _local_shard_sort(
    keys: jax.Array,
    values: Optional[jax.Array],   # (n_local, w) payload rows or None
    d: int,
    axis: AxisNames,
    capacity: int,
    oversample: int,
    cfg: SortConfig,
):
    """Body run per shard under shard_map."""
    n_local = keys.shape[0]
    sent = sampling.sentinel_for(keys.dtype)

    if d == 1:
        # Degenerate mesh: the whole exchange is the identity (and an
        # all_to_all over a size-1 axis trips this jax version).  Pad (or,
        # for undersized capacity, truncate + flag overflow, matching the
        # d > 1 contract) and sort locally.
        m_valid = min(n_local, capacity)
        pad = jnp.full((capacity - m_valid,), sent, keys.dtype)
        flat = jnp.concatenate([keys[:m_valid], pad])
        m = jnp.asarray(m_valid, jnp.int32)
        overflow = jnp.asarray(n_local > capacity)
        if values is None:
            return ips4o_sort(flat, cfg=cfg), m[None], overflow[None]
        vpad = jnp.zeros((capacity - m_valid, values.shape[1]), values.dtype)
        sorted_local, sorted_v = ips4o_sort(
            flat, jnp.concatenate([values[:m_valid], vpad], axis=0), cfg=cfg
        )
        return sorted_local, sorted_v, m[None], overflow[None]

    # --- 0. balanced pre-exchange ------------------------------------------
    # A skew-placed input (e.g. already sorted) makes the value-based
    # exchange diagonal-heavy: one (sender, dest) pair can carry a whole
    # stripe, so per-pair capacity would need to be n_local.  One round-robin
    # all_to_all first gives every core a representative slice of every
    # stripe, bounding per-pair counts at ~n_local/d w.h.p. for ANY placement
    # (the distributed cousin of the paper's beta overpartitioning).
    chunk = n_local // d
    keys = jax.lax.all_to_all(
        keys.reshape(d, chunk), axis, split_axis=0, concat_axis=0, tiled=True
    ).reshape(n_local)
    if values is not None:
        w = values.shape[1]
        values = jax.lax.all_to_all(
            values.reshape(d, chunk, w), axis, split_axis=0, concat_axis=0,
            tiled=True,
        ).reshape(n_local, w)

    # --- 1. sampling: local sample, global gather, shared splitters -------
    my = jax.lax.axis_index(axis)
    rng = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), my)
    pos = jax.random.randint(rng, (oversample,), 0, n_local)
    local_sample = jnp.take(keys, pos, axis=0)
    all_samples = jax.lax.all_gather(local_sample, axis, tiled=True)  # (d*s,)
    ssorted = jnp.sort(all_samples)
    spl = sampling.select_splitters(ssorted, d)  # d-1 splitters

    # --- 2. local classification + stable partition -----------------------
    # Equality buckets, distributed form (paper §4.4): an element equal to a
    # (possibly duplicated) splitter may legally live on ANY core in the
    # span [lo, hi] covering that splitter run — stripe such elements across
    # the span so heavy duplicates are "not a load balancing problem".
    lo = jnp.searchsorted(spl, keys, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(spl, keys, side="right").astype(jnp.int32)
    span = hi - lo + 1
    stripe = jnp.arange(n_local, dtype=jnp.int32) % jnp.maximum(span, 1)
    dest = jnp.minimum(lo + stripe, d - 1).astype(jnp.int32)  # [0, d)
    tile = min(cfg.tile, n_local)
    to_part = {"k": keys}
    if values is not None:
        to_part["v"] = values
    # cfg.engine rides into the stripe partition too: with d buckets the
    # counting-rank kernel is far under its VMEM one-hot cap
    arrays, offsets = stable_partition(
        dest, to_part, d, tile, engine=resolve_engine(cfg, n_local, keys.dtype)
    )
    part = arrays["k"]
    counts = jnp.diff(offsets)  # (d,)

    # --- 3. capacity-padded all_to_all (the block permutation) ------------
    overflow = jnp.any(counts > capacity)
    idx = offsets[:-1, None] + jnp.arange(capacity, dtype=jnp.int32)[None, :]
    valid = jnp.arange(capacity, dtype=jnp.int32)[None, :] < counts[:, None]
    gidx = jnp.minimum(idx, n_local - 1)
    send = jnp.where(valid, jnp.take(part, gidx, axis=0), sent)  # (d, capacity)
    recv = jax.lax.all_to_all(send, axis, split_axis=0, concat_axis=0, tiled=True)
    recv_counts = jax.lax.all_to_all(
        jnp.minimum(counts, capacity), axis, split_axis=0, concat_axis=0, tiled=True
    )

    # --- 4. local sort (IS4o); sentinels sort to the tail ------------------
    flat = recv.reshape(d * capacity)
    m = jnp.sum(recv_counts).astype(jnp.int32)
    if values is None:
        sorted_local = ips4o_sort(flat, cfg=cfg)
        return sorted_local, m[None], overflow[None]

    send_v = jnp.where(valid[..., None],
                       jnp.take(arrays["v"], gidx, axis=0), 0)  # (d, cap, w)
    recv_v = jax.lax.all_to_all(send_v, axis, split_axis=0, concat_axis=0,
                                tiled=True).reshape(d * capacity, w)
    sorted_local, sorted_v = ips4o_sort(flat, recv_v, cfg=cfg)
    return sorted_local, sorted_v, m[None], overflow[None]


def distributed_sort(
    keys: jax.Array,
    mesh: Mesh,
    axis: AxisNames = "data",
    *,
    values: Optional[jax.Array] = None,
    slack: float = 2.0,
    cfg: SortConfig = SortConfig(),
):
    """Sort a globally-sharded key array (optionally with payload rows).

    Args:
      keys: (n,) array sharded over ``axis`` of ``mesh`` (n divisible by the
        axis size).
      values: optional (n, w) payload rows, same sharding — the paper's
        Pair/Quartet/100Bytes case; rows travel with their keys through the
        pre-exchange, partition, and block-permutation all_to_alls.
      slack: capacity factor for the all_to_all buffers (paper's beta-like
        overpartitioning safety).

    Returns (sorted, counts, overflow) — or, with values,
    (sorted, sorted_values, counts, overflow):
      sorted: (d * capacity_total,) — shard i holds its sorted range with
        sentinel padding at the tail;
      counts: (d,) valid element count per shard;
      overflow: (d,) bool, True if any send bucket exceeded capacity (result
        then dropped elements — caller should re-run with higher slack).
    """
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    d = 1
    for a in axes:
        d *= mesh.shape[a]
    n = keys.shape[0]
    n_local = n // d
    if n_local * d != n:
        raise ValueError(f"n={n} not divisible by axis size {d}")
    if n_local % d:
        raise ValueError(
            f"shard size {n_local} must be divisible by d={d} (pre-exchange)"
        )
    capacity = int(n_local // d * slack)
    capacity = max(128, -(-capacity // 128) * 128)
    oversample = max(32, sampling.oversampling_factor(n) * 16)

    spec = P(axes if len(axes) > 1 else axes[0])
    body = functools.partial(
        _local_shard_sort,
        d=d,
        axis=axes if len(axes) > 1 else axes[0],
        capacity=capacity,
        oversample=oversample,
        cfg=cfg,
    )
    if values is None:
        f = shard_map(
            lambda k: body(k, None),
            mesh=mesh,
            in_specs=(spec,),
            out_specs=(spec, spec, spec),
        )
        return f(keys)
    vspec = P(axes if len(axes) > 1 else axes[0], None)
    f = shard_map(
        body,
        mesh=mesh,
        in_specs=(spec, vspec),
        out_specs=(spec, vspec, spec, spec),
    )
    return f(keys, values)


def make_distributed_sorter(mesh: Mesh, axis: AxisNames = "data", **kw):
    return jax.jit(functools.partial(distributed_sort, mesh=mesh, axis=axis, **kw))
