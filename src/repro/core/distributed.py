"""Multi-chip distributed sort — compatibility shim over ``repro.dist``.

The paper's conclusion: "The algorithm can also be used for data
distribution and local sorting in distributed memory parallel algorithms
[2] (AMS-sort)".  The full instantiation now lives in ``repro.dist``
(DESIGN.md §8): a multi-level, recursion-free AMS-style sort that runs
sample → branchless-classify → stable-block-partition → all_to_all per
mesh axis, with an observed-histogram re-split retry instead of
truncate-on-overflow and a ``dist:`` plan family learning capacity factor
× oversampling × engine per (n_local, d, dtype).

This module keeps the original single-entry-point surface alive for
existing callers (quickstart §5, ``benchmarks/sort_scaling.py``, the
subprocess test suite): same signature, same
(sorted, [values,] counts, overflow) contract, same capacity-padded
per-shard layout.  ``slack`` maps onto the capacity factor; a tuple
``axis`` now genuinely runs one exchange level per axis instead of one
global exchange.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
from jax.sharding import Mesh

from repro.core.ips4o import SortConfig
from repro.dist.levels import AxisNames

__all__ = ["distributed_sort", "make_distributed_sorter"]


def distributed_sort(
    keys: jax.Array,
    mesh: Mesh,
    axis: AxisNames = "data",
    *,
    values: Optional[Any] = None,
    slack: float = 2.0,
    cfg: SortConfig = SortConfig(),
):
    """Sort a globally-sharded key array (optionally with payload rows).

    Thin wrapper over :func:`repro.dist.sort` — see that docstring for the
    full contract.  Returns (sorted, counts, overflow) — or, with values,
    (sorted, sorted_values, counts, overflow): shard i holds its sorted
    range with sentinel padding at the tail; ``overflow`` is raised only
    after the per-level re-split retries are exhausted (the result is then
    deterministically truncated, never UB-shaped).
    """
    from repro import dist

    return dist.sort(keys, mesh, axis, values=values, slack=slack, cfg=cfg)


def make_distributed_sorter(mesh: Mesh, axis: AxisNames = "data", **kw):
    return jax.jit(functools.partial(distributed_sort, mesh=mesh, axis=axis, **kw))
