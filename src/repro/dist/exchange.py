"""Per-level exchange: sample → classify → stable partition → all_to_all.

This is the body of one :class:`repro.dist.levels.Level`, run per shard
under ``shard_map``.  It is the paper's single-node pipeline with the mesh
axis as the bucket dimension (DESIGN.md §8):

  1. **sampling** — every shard samples its *valid prefix*; samples are
     all-gathered over the level's domain and ``groups - 1`` shared
     splitters selected (per-axis-sized, never global);
  2. **classification** — branchless two-searchsorted descent with the
     distributed equality-bucket rule (paper §4.4): an element equal to a
     duplicated splitter stripes across the whole span of groups covering
     that splitter run, so heavy duplicates are not a balance problem;
  3. **stable block partition** — ``core.partition.stable_partition``
     with ``groups + 1`` buckets (the extra bucket collects sentinel pads,
     which must never travel) on the caller's engine ("xla" | "pallas");
  4. **exchange** — one capacity-padded ``all_to_all`` over this level's
     axis only, plus the count vector; arrivals are re-compacted to a
     valid prefix by a 2-bucket stable partition (the same engine again),
     so the next level sees the same invariant it started from.

**Re-split retry** instead of truncate-on-overflow: if any (sender, group)
chunk would exceed its capacity anywhere in the domain (one ``pmax``),
the next round *recomputes the splitters from the observed histogram* —
every shard counts its keys below each candidate point of a fresh sample
draw, a ``psum`` makes the counts global, and
``sampling.splitters_from_histogram`` picks candidates at the exact
balanced ranks.  Rounds are a statically unrolled, bounded loop (the
recursion-free discipline of ``core/ips4o.py``); only if every round
overflows does the exchange truncate deterministically and raise the
overflow flag — the last resort, no longer the first response.  With
``repro.obs`` enabled, truncation is no longer silent either: the
exchange records a ``dist.exchange_overflow`` event carrying the
observed per-round fill (max chunk / capacity, one entry per round) and
logs a one-line warning; converged exchanges record the active re-split
round count (``dist.resplit_rounds``) and per-shard collective volume
(``dist.collective_bytes``) per level (DESIGN.md §12).

**Overlap-scheduled exchange** (``overlap=True``, DESIGN.md §13): the
sampling/classify/re-split rounds are a *global* barrier by construction
(the overflow verdict needs every shard's full-shard counts before any
element may travel), but everything after the destinations are fixed is
not.  The overlap path splits the shard into two position-halves and
staggers partition/pack against the wire: half A is partitioned, packed,
and its ``all_to_all`` *issued* before half B's partition even starts, so
XLA's latency-hiding scheduler can run half B's local partition while half
A's collective is in flight.  Arrivals are reassembled sender-major with
A-slots before B-slots — exactly the stable order of the synchronous
exchange — and the truncation budget is shared across the halves
(``send_B = min(counts_B, cap - send_A)``), so the overlapped exchange is
**bit-identical** to the synchronous one, overflow flag, truncation and
payloads included.  The cost is a larger padded frame (each half carries
the full per-chunk capacity, since either half could in principle hold a
whole chunk); real payload bytes on the wire are unchanged, and
``repro.obs`` records the overlappable fraction per level
(``dist.overlap_efficiency``).

**Radix destinations** (``classifier="radix"``, DESIGN.md §9): when the
level's group count is a power of two and the keys are keyspace-encoded
(unsigned), round 0 can skip the sampling collective entirely and send
each element to group ``key >> (bits - log2 g)`` — the distributed form
of the IPS2Ra level-0 bucket.  Skewed keyspaces that overflow a bit-range
land in the existing re-split rounds, which are always splitter-based
(observed-histogram splitters are what fixes skew; re-deriving bit ranges
could not), so the radix path costs nothing in robustness.  Callers only
pass it for level 0: deeper levels' domains hold splitter-delimited (not
bit-aligned) ranges whenever any earlier round re-split.
"""
from __future__ import annotations

import math
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import sampling
from repro.core.partition import stable_partition
from repro.dist.levels import Level

__all__ = ["exchange_level", "compact_valid", "tile_for"]

Pytree = Any


def tile_for(n: int, pref: int) -> int:
    """A partition tile that divides ``n`` (static), at most ``pref``.

    >>> tile_for(48, 32)
    16
    >>> tile_for(7, 4)
    1
    """
    return max(1, math.gcd(n, pref))


def compact_valid(
    arrays: Pytree, valid: jax.Array, tile: int, engine: str
) -> Pytree:
    """Stably move valid elements to the front (2-bucket partition).

    Key order among valid elements is preserved because the block
    partition is stable (DESIGN.md §2).

    >>> import jax.numpy as jnp
    >>> out = compact_valid({"k": jnp.asarray([9, 7, 8, 6])},
    ...                     jnp.asarray([False, True, False, True]), 2, "xla")
    >>> out["k"].tolist()
    [7, 6, 9, 8]
    """
    dest = jnp.where(valid, 0, 1).astype(jnp.int32)
    out, _ = stable_partition(dest, arrays, 2, tile, engine=engine)
    return out


def _classify(
    keys: jax.Array, spl: jax.Array, valid: jax.Array, groups: int
) -> Tuple[jax.Array, jax.Array]:
    """Destination group per element (pads -> trash bucket ``groups``) and
    per-group counts, with equality-bucket striping across splitter runs."""
    n = keys.shape[0]
    lo = jnp.searchsorted(spl, keys, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(spl, keys, side="right").astype(jnp.int32)
    span = hi - lo + 1
    # stripe by a multiplicative hash of the position, NOT the raw
    # position: structured inputs (EightDup's i^8 lattice) place every
    # copy of a heavy value at one residue class, so ``pos % span`` sends
    # the whole run to a single group; the Fibonacci-hash high bits
    # decorrelate the stripe from any input lattice
    pos = jnp.arange(n, dtype=jnp.uint32) * jnp.uint32(2654435761)
    stripe = (pos >> jnp.uint32(16)).astype(jnp.int32) % jnp.maximum(span, 1)
    dest = jnp.minimum(lo + stripe, groups - 1)
    dest = jnp.where(valid, dest, groups)
    counts = jnp.bincount(dest, length=groups + 1)[:groups]
    return dest, counts


def _radix_dest(
    keys: jax.Array, valid: jax.Array, groups: int
) -> Tuple[jax.Array, jax.Array]:
    """Destination group from the top log2(groups) bits of the encoded key
    (pads -> trash bucket ``groups``) and per-group counts.  Requires an
    unsigned (keyspace-encoded) dtype and a power-of-two ``groups`` —
    monotone in the key, so the level's range invariant holds."""
    shift = keys.dtype.itemsize * 8 - int(math.log2(groups))
    dest = jnp.right_shift(keys, jnp.asarray(shift, keys.dtype)).astype(jnp.int32)
    dest = jnp.where(valid, dest, groups)
    counts = jnp.bincount(dest, length=groups + 1)[:groups]
    return dest, counts


def _observed_cumulative(
    keys: jax.Array, valid: jax.Array, cands: jax.Array, domain
) -> jax.Array:
    """Global #keys strictly below each candidate point (one ``psum``)."""
    m = cands.shape[0]
    below = jnp.searchsorted(cands, keys, side="right").astype(jnp.int32)
    below = jnp.where(valid, below, m + 1)  # pads count nowhere
    hist = jnp.bincount(below, length=m + 2)
    cum = jnp.cumsum(hist)[:m].astype(jnp.int32)  # cum[j] = #{key < cands[j]}
    return jax.lax.psum(cum, domain)


def _split_kv(arrays: Pytree):
    vals = {k: v for k, v in arrays.items() if k != "k"}
    return arrays["k"], vals


def exchange_level(
    arrays: Pytree,
    m: jax.Array,
    level: Level,
    *,
    engine: str,
    tile: int,
    seed: int,
    level_idx: int,
    retries: int = 2,
    classifier: str = "tree",
    overlap: bool = False,
) -> Tuple[Pytree, jax.Array, jax.Array]:
    """Run one level's exchange on this shard's ``arrays`` dict.

    ``arrays`` is a dict whose ``"k"`` leaf holds (n_in,) keyspace-encoded
    keys with the valid prefix [0, m) (sentinel pads beyond); every other
    entry is a values pytree riding the same partitions.  Returns
    (arrays (n_out,), m', overflowed) — ``overflowed`` is True only when
    every re-split round still exceeded capacity somewhere in the domain
    (the exchange then truncated deterministically).

    ``classifier="radix"`` takes the bit-range destination at round 0 (no
    sampling collective — see the module docstring); it silently degrades
    to the sampled-splitter path when the group count is not a power of
    two or the keys are not unsigned.  Re-split rounds are always
    splitter-based.

    ``overlap=True`` takes the half-shard staggered exchange (module
    docstring): bit-identical results, with half B's partition/pack
    overlappable against half A's in-flight collective.  It silently
    stays synchronous on a degenerate axis or an odd shard size.

    The degenerate (groups == 1) level needs no collective and therefore
    no ``shard_map`` context — the d = 1 contract in one call:

    >>> import jax.numpy as jnp
    >>> from repro.dist.levels import plan_schedule
    >>> (lv,) = plan_schedule({"data": 1}, "data", 256)
    >>> out, m, ovf = exchange_level(
    ...     {"k": jnp.arange(256, dtype=jnp.uint32)}, jnp.int32(256), lv,
    ...     engine="xla", tile=64, seed=0, level_idx=0)
    >>> (out["k"].shape[0], int(m), bool(ovf))   # padded to n_out, no loss
    (512, 256, False)
    """
    n = arrays["k"].shape[0]
    g, cap = level.groups, level.capacity
    sent = sampling.sentinel_for(arrays["k"].dtype)

    if g == 1:
        # degenerate axis: no collective — pad (or truncate + flag, the
        # same last-resort contract as the d > 1 exchange) to n_out.
        # A truncated buffer keeps the FIRST n_out slots: if they were all
        # valid (m > n_out) every kept slot stays valid; otherwise the kept
        # tail is already sentinel pads — no rewriting either way.
        n_out = level.n_out
        m_new = jnp.minimum(m, jnp.asarray(n_out, jnp.int32))
        overflow = m > n_out
        if obs.enabled():
            obs.jit_event(
                "dist.exchange_overflow",
                {"m": m},
                gate=overflow,
                warn=(
                    f"repro.dist: degenerate level {level_idx} buffer "
                    f"(n_out={n_out}) overflowed; truncating"
                ),
                level=str(level_idx), groups=1, capacity=n_out,
            )
        if n_out >= n:
            pad = n_out - n

            def grow(a, fill):
                padding = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
                return jnp.pad(a, padding, constant_values=fill)

            key, vals = _split_kv(arrays)
            out = {
                "k": grow(key, sent),
                **jax.tree.map(lambda a: grow(a, 0), vals),
            }
            return out, m_new, overflow
        return jax.tree.map(lambda a: a[:n_out], arrays), m_new, overflow

    valid = jnp.arange(n, dtype=jnp.int32) < m
    my = jax.lax.axis_index(level.domain)
    spl = None
    dest_keep = jnp.zeros((n,), jnp.int32)
    done = jnp.asarray(False)
    # obs (DESIGN.md §12): per-round worst global fill (max chunk / cap)
    # and the number of *active* re-split rounds, staged only when obs is
    # enabled at trace time — zero added ops otherwise
    track = obs.enabled()
    round_fill = []
    rounds_used = jnp.asarray(0, jnp.int32)
    use_radix = (
        classifier == "radix"
        and g & (g - 1) == 0
        and jnp.dtype(arrays["k"].dtype).kind == "u"
    )

    for r in range(max(0, retries) + 1):
        if r == 0 and use_radix:
            # bit-range destinations, no sampling collective this round;
            # spl is initialised to the implied bit boundaries so the
            # re-split rounds' where(done, spl, new_spl) select is typed
            # (its value is never used when round 0 succeeded)
            kd = arrays["k"].dtype
            shift = kd.itemsize * 8 - int(math.log2(g))
            spl = jnp.left_shift(
                jnp.arange(1, g, dtype=kd), jnp.asarray(shift, kd)
            )
            dest, counts = _radix_dest(arrays["k"], valid, g)
            over_here = jnp.any(counts > cap)
            over_r = jax.lax.pmax(over_here.astype(jnp.int32), level.domain) > 0
            if track:
                round_fill.append(
                    jax.lax.pmax(
                        jnp.max(counts).astype(jnp.float32), level.domain
                    ) / cap
                )
                rounds_used = rounds_used + 1
            dest_keep = dest
            done = ~over_r
            continue
        rng = jax.random.fold_in(
            jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(seed), level_idx), r
            ),
            my,
        )
        pos = sampling.sample_indices(rng, level.oversample, 0, m)
        local_sample = jnp.take(arrays["k"], pos, axis=0)
        gathered = jax.lax.all_gather(local_sample, level.domain, tiled=True)
        cands = jnp.sort(gathered)
        if r == 0:
            spl = sampling.select_splitters(cands, g)
        else:
            # observed-histogram re-split: exact global ranks at the fresh
            # candidate points replace the failed sample estimate
            cum = _observed_cumulative(arrays["k"], valid, cands, level.domain)
            total = jax.lax.psum(m, level.domain)
            new_spl = sampling.splitters_from_histogram(cands, cum, g, total)
            spl = jnp.where(done, spl, new_spl)
        dest, counts = _classify(arrays["k"], spl, valid, g)
        over_here = jnp.any(counts > cap)
        over_r = jax.lax.pmax(over_here.astype(jnp.int32), level.domain) > 0
        if track:
            # ``done`` still holds the PREVIOUS round's verdict here, so a
            # round is "active" iff the exchange had not yet converged
            active = jnp.asarray(True) if r == 0 else ~done
            round_fill.append(
                jax.lax.pmax(
                    jnp.max(counts).astype(jnp.float32), level.domain
                ) / cap
            )
            rounds_used = rounds_used + active.astype(jnp.int32)
        if r == 0:
            dest_keep = dest
            done = ~over_r
        else:
            dest_keep = jnp.where(done, dest_keep, dest)
            done = jnp.logical_or(done, ~over_r)
    overflowed = ~done
    if track:
        # fill/rounds are pmax-replicated: record once per domain group
        # (lead shard) instead of once per shard
        is_lead = jax.lax.axis_index(level.domain) == 0
        obs.jit_observe(
            "dist.resplit_rounds", rounds_used, gate=is_lead,
            level=str(level_idx), axis=str(level.axis),
        )
        obs.jit_event(
            "dist.exchange_overflow",
            {"round_fill": jnp.stack(round_fill), "rounds_used": rounds_used},
            gate=overflowed & is_lead,
            warn=(
                f"repro.dist: capacity exhausted after "
                f"{max(0, retries) + 1} round(s) at level {level_idx} "
                f"(axis {level.axis!r}, capacity {cap}); truncating "
                f"overflowing chunks"
            ),
            level=str(level_idx), groups=g, capacity=cap,
        )

    if overlap and n % 2 == 0:
        return _exchange_halves(
            arrays, dest_keep, overflowed, level,
            engine=engine, tile=tile, level_idx=level_idx, track=track,
        )

    # stable block partition with a trash bucket for pads (never sent)
    parts, offsets = stable_partition(
        dest_keep, arrays, g + 1, tile_for(n, tile), engine=engine
    )
    counts = jnp.diff(offsets)[:g]
    send_counts = jnp.minimum(counts, cap)  # truncation only past the last retry
    if track:
        # this shard's real payload on the wire this level (the padded
        # frame is the static g * cap * itemsize upper bound)
        per_elem = sum(
            jnp.dtype(leaf.dtype).itemsize for leaf in jax.tree.leaves(parts)
        )
        obs.jit_observe(
            "dist.collective_bytes",
            jnp.sum(send_counts).astype(jnp.float32) * per_elem,
            level=str(level_idx), axis=str(level.axis),
            padded_bytes=g * cap * per_elem,
        )

    idx = offsets[:g, None] + jnp.arange(cap, dtype=jnp.int32)[None, :]
    in_cap = jnp.arange(cap, dtype=jnp.int32)[None, :] < send_counts[:, None]
    gidx = jnp.minimum(idx, n - 1).reshape(-1)

    def pack(a, fill):
        chunk = jnp.take(a, gidx, axis=0).reshape((g, cap) + a.shape[1:])
        mask = in_cap.reshape((g, cap) + (1,) * (a.ndim - 1))
        return jnp.where(mask, chunk, fill)

    def a2a(x):
        return jax.lax.all_to_all(
            x, level.axis, split_axis=0, concat_axis=0, tiled=True
        )

    key_part, val_part = _split_kv(parts)
    recv_k = a2a(pack(key_part, sent))
    recv_v = jax.tree.map(lambda a: a2a(pack(a, jnp.zeros((), a.dtype))), val_part)
    recv_counts = a2a(send_counts)
    m_next = jnp.sum(recv_counts).astype(jnp.int32)

    flat = {
        "k": recv_k.reshape(g * cap),
        **jax.tree.map(lambda a: a.reshape((g * cap,) + a.shape[2:]), recv_v),
    }
    arrived = (
        jnp.arange(cap, dtype=jnp.int32)[None, :] < recv_counts[:, None]
    ).reshape(-1)
    out = compact_valid(flat, arrived, tile_for(g * cap, tile), engine)
    return out, m_next, overflowed


def _exchange_halves(
    arrays: Pytree,
    dest_keep: jax.Array,
    overflowed: jax.Array,
    level: Level,
    *,
    engine: str,
    tile: int,
    level_idx: int,
    track: bool,
) -> Tuple[Pytree, jax.Array, jax.Array]:
    """The staggered tail of an overlapped exchange (module docstring).

    Destinations and the overflow verdict are already fixed over the full
    shard; this routine partitions/packs each position-half separately and
    issues half A's ``all_to_all`` before half B's partition, opening the
    exchange/compute overlap window.  Bit-identity with the synchronous
    tail holds because (a) the stable partition of a position-prefix is a
    prefix of the stable partition of the whole, so per (sender, group)
    the A-chunk's elements all precede the B-chunk's in the synchronous
    chunk order; (b) the shared truncation budget keeps exactly the first
    ``min(counts, cap)`` elements of that concatenated order; and (c)
    arrivals concatenate per sender as [A-slots | B-slots], which the
    stable compaction flattens back into the synchronous arrival order.
    """
    n = arrays["k"].shape[0]
    g, cap = level.groups, level.capacity
    sent = sampling.sentinel_for(arrays["k"].dtype)
    h = n // 2
    slot = jnp.arange(cap, dtype=jnp.int32)[None, :]
    budget = jnp.full((g,), cap, jnp.int32)
    recv, recv_counts, sent_counts = [], [], []
    for lo in (0, h):
        sub = jax.tree.map(lambda a: a[lo:lo + h], arrays)
        parts, offsets = stable_partition(
            dest_keep[lo:lo + h], sub, g + 1, tile_for(h, tile), engine=engine
        )
        counts = jnp.diff(offsets)[:g]
        send = jnp.minimum(counts, budget)  # B spends what A left over
        budget = budget - send
        idx = offsets[:g, None] + jnp.arange(cap, dtype=jnp.int32)[None, :]
        in_cap = slot < send[:, None]
        gidx = jnp.minimum(idx, h - 1).reshape(-1)

        def pack(a, fill):
            chunk = jnp.take(a, gidx, axis=0).reshape((g, cap) + a.shape[1:])
            mask = in_cap.reshape((g, cap) + (1,) * (a.ndim - 1))
            return jnp.where(mask, chunk, fill)

        def a2a(x):
            return jax.lax.all_to_all(
                x, level.axis, split_axis=0, concat_axis=0, tiled=True
            )

        key_part, val_part = _split_kv(parts)
        # the collective is ISSUED here, before the next loop iteration
        # touches half B — nothing after this point depends on it until
        # reassembly, which is the data-dependence gap XLA's latency-hiding
        # scheduler fills with half B's partition/pack
        recv.append({
            "k": a2a(pack(key_part, sent)),
            **jax.tree.map(
                lambda a: a2a(pack(a, jnp.zeros((), a.dtype))), val_part
            ),
        })
        recv_counts.append(a2a(send))
        sent_counts.append(send)

    if track:
        per_elem = sum(
            jnp.dtype(leaf.dtype).itemsize for leaf in jax.tree.leaves(arrays)
        )
        bytes_a = jnp.sum(sent_counts[0]).astype(jnp.float32) * per_elem
        bytes_b = jnp.sum(sent_counts[1]).astype(jnp.float32) * per_elem
        obs.jit_observe(
            "dist.collective_bytes", bytes_a + bytes_b,
            level=str(level_idx), axis=str(level.axis),
            padded_bytes=2 * g * cap * per_elem, overlap="on",
        )
        # the fraction of this level's payload whose transfer can hide
        # behind local partition work (half A's bytes overlap half B's
        # partition; by symmetry of the halves either ratio is reported)
        obs.jit_observe(
            "dist.overlap_efficiency",
            bytes_a / jnp.maximum(bytes_a + bytes_b, 1.0),
            level=str(level_idx), axis=str(level.axis),
        )

    # per sender: [A-slots | B-slots] — the synchronous stable chunk order
    flat = {}
    for name in recv[0]:
        both = jnp.concatenate([recv[0][name], recv[1][name]], axis=1)
        flat[name] = both.reshape((2 * g * cap,) + both.shape[2:])
    arrived = jnp.concatenate(
        [slot < recv_counts[0][:, None], slot < recv_counts[1][:, None]], axis=1
    ).reshape(-1)
    m_next = jnp.sum(recv_counts[0] + recv_counts[1]).astype(jnp.int32)
    out = compact_valid(flat, arrived, tile_for(2 * g * cap, tile), engine)
    # every slot past n_out is invalid (m_next <= g * cap by the shared
    # budget), so the slice drops only pads the compaction pushed behind
    return jax.tree.map(lambda a: a[:g * cap], out), m_next, overflowed
