"""repro.dist — the multi-level distributed sort subsystem (DESIGN.md §8).

The paper's conclusion positions IPS4o as "the data distribution and local
sorting" engine for distributed-memory sorting (AMS-sort); this package is
that instantiation on a device mesh, one exchange level per mesh axis:

  levels.py    the explicit (recursion-free) level schedule and capacities
  exchange.py  per-level sample -> classify -> stable partition ->
               all_to_all, with the observed-histogram re-split retry
  api.py       sharded ops: sort / argsort / topk / bottomk / group_by
               behind the same engine seam and keyspace encoding as
               ``repro.ops``
  elastic.py   the same sort as a checkpointed level-boundary state
               machine: restorable after shard loss (DESIGN.md §13)

Every exchange also takes ``overlap=True`` (half-shard staggering of the
collective against local partition work) and ``order="auto"`` (topology-
aware level ordering) — see DESIGN.md §13.
"""
from repro.dist.api import argsort, bottomk, group_by, sort, topk
from repro.dist.elastic import sort_elastic
from repro.dist.levels import (
    Level, axis_bandwidths, order_axes, plan_schedule, schedule_cost,
)

__all__ = [
    "sort", "argsort", "topk", "bottomk", "group_by", "sort_elastic",
    "Level", "plan_schedule", "order_axes", "schedule_cost",
    "axis_bandwidths",
]
