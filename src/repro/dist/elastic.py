"""Elastic distributed sort: level boundaries as restore points.

``repro.dist.sort`` runs its whole pipeline — pre-exchange, every level's
exchange, the local finish — inside one jitted ``shard_map``: fast, but a
shard loss anywhere loses everything.  This module re-expresses the same
computation as a *host-driven state machine* whose per-shard state
materialises at every level boundary and is checkpointed through
``repro.checkpoint.CheckpointManager`` (DESIGN.md §13.3):

    INIT ──save(0)──> LEVEL 0 ──save(1)──> LEVEL 1 ── ... ──save(L)──> FINISH

  * **state** at boundary s: the per-shard key (and payload) arrays, the
    per-shard validity counts, the accumulated overflow flags, the
    observed per-shard fill histogram (valid counts at every boundary so
    far), the consumed-level index, and a parameter fingerprint;
  * **restore**: ``latest_step()`` finds the last completed boundary,
    ``read_leaf`` recovers the consumed-level index (state shapes depend
    on it), and ``restore`` re-lays the arrays out on the CURRENT mesh —
    the manager's elastic path, so resumption tolerates a re-formed mesh
    of the same shape and axis names;
  * **determinism**: every level's splitter RNG folds (seed, level_idx,
    round, shard-index) — history-independent — so a resumed sort draws
    exactly the samples the uninterrupted sort would have drawn, and the
    final output is bit-identical, re-split retries and truncation
    included.

Each step is one jitted ``shard_map`` over the exact per-shard bodies of
``dist.api`` (``_pre_exchange`` / ``exchange_level`` / ``_finish_local``),
so the elastic path cannot drift from the monolithic one.  The price of
restorability is one host round-trip and checkpoint write per level;
``save(..., blocking=False)`` overlaps the write with the next level's
compute, the same compute/IO overlap the checkpoint manager gives
training loops.

A directory identifies ONE sort job: calling :func:`sort_elastic` with a
directory holding a finished job's checkpoints just replays its finish.
Point different sorts at different directories (or clean up between).
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import replace
from typing import Any, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import obs
from repro.checkpoint.manager import CheckpointManager
from repro.classify import resolve_classifier
from repro.core.ips4o import SortConfig
from repro.dist.api import (
    _axis_arg, _finish_local, _plan_params, _pre_exchange, _prepare,
    _resolve_dist_engine,
)
from repro.dist.exchange import exchange_level
from repro.dist.levels import AxisNames, plan_schedule
from repro.ops import keyspace

__all__ = ["sort_elastic"]


def _fingerprint(meta: dict) -> np.ndarray:
    """sha256 of the sort parameters as a (32,) uint8 leaf — a checkpoint
    from a *different* sort configuration must never silently resume."""
    digest = hashlib.sha256(
        json.dumps(meta, sort_keys=True).encode()
    ).digest()
    return np.frombuffer(digest, dtype=np.uint8).copy()


def _leaf_specs(arrays, ax):
    return jax.tree.map(lambda a: P(ax, *([None] * (a.ndim - 1))), arrays)


def _state_shardings(like, mesh, ax):
    """NamedShardings for the checkpoint state on the CURRENT mesh: array
    leaves and per-shard scalars shard over ``ax``; host metadata (fills
    history, level index, fingerprint) replicates."""
    shard = jax.tree.map(
        lambda a: NamedSharding(mesh, P(ax, *([None] * (len(a.shape) - 1)))),
        like["arrays"],
    )
    row = NamedSharding(mesh, P(ax))
    rep = NamedSharding(mesh, P())
    return {
        "arrays": shard, "m": row, "ovf": row,
        "fills": rep, "level": rep, "fingerprint": rep,
    }


def sort_elastic(
    keys: jax.Array,
    mesh: Mesh,
    axes: AxisNames = "data",
    *,
    manager: CheckpointManager,
    values: Any = None,
    slack: Optional[float] = None,
    oversample: Optional[int] = None,
    retries: int = 2,
    cfg: SortConfig = SortConfig(),
    engine: Optional[str] = None,
    classifier: Optional[str] = None,
    overlap: bool = False,
    blocking_saves: bool = True,
    _fail_at_step: Optional[int] = None,
):
    """Restorable multi-level distributed sort (module docstring).

    Same contract as :func:`repro.dist.sort` — returns (sorted, counts,
    overflow), or (sorted, sorted_values, counts, overflow) with
    ``values`` — and bit-identical output, but the sort checkpoints its
    per-shard state into ``manager`` at every level boundary and, when
    the manager's directory already holds a matching checkpoint, resumes
    from the last completed level instead of restarting.  On resume the
    *data* comes from the checkpoint; ``keys`` / ``values`` supply only
    shapes, dtypes and sharding.  A checkpoint whose parameter
    fingerprint disagrees (different seed, schedule, dtype, ...) raises
    ``ValueError`` rather than resuming into a different sort.

    ``blocking_saves=False`` uses the manager's async path: the write of
    boundary s overlaps level s's compute.  ``_fail_at_step`` is the
    fault-injection hook for the elastic-restore test suite: it raises
    ``RuntimeError`` (simulating shard loss) right after the named
    boundary's checkpoint commits.

    >>> import tempfile
    >>> import jax, jax.numpy as jnp
    >>> from repro.checkpoint import CheckpointManager
    >>> mesh = jax.make_mesh((1,), ("data",))
    >>> ck = CheckpointManager(tempfile.mkdtemp())
    >>> out, counts, ovf = sort_elastic(
    ...     jnp.asarray([3.0, 1.0, 2.0, 0.0]), mesh, manager=ck)
    >>> out[: int(counts[0])].tolist()
    [0.0, 1.0, 2.0, 3.0]
    >>> ck.latest_step()  # boundaries 0 (pre-exchange) and 1 (one level)
    1
    """
    names, d, n_local = _prepare(keys, mesh, axes)
    slack, oversample, plan_engine, _ = _plan_params(
        n_local, d, keys.dtype, slack, oversample, False
    )
    eng = _resolve_dist_engine(engine, cfg, plan_engine, n_local, keys.dtype)
    clf = resolve_classifier(classifier or cfg.classifier, n_local, keys.dtype)
    cfg_run = replace(cfg, engine=eng, classifier=clf)
    schedule = plan_schedule(
        dict(mesh.shape), names, n_local, slack=slack, oversample=oversample
    )
    levels = len(schedule)
    ax = _axis_arg(names)
    enc = keyspace.encode(keys)
    arrays = {"k": enc} if values is None else {"k": enc, "v": values}
    val_meta = [
        (str(path), str(leaf.dtype), list(leaf.shape[1:]))
        for path, leaf in jax.tree_util.tree_flatten_with_path(
            {} if values is None else values
        )[0]
    ]
    fp = _fingerprint({
        "axes": list(names), "d": d, "n_local": n_local,
        "slack": float(slack), "oversample": int(oversample),
        "retries": int(retries), "seed": int(cfg.seed),
        "dtype": str(keys.dtype), "engine": eng, "classifier": clf,
        "overlap": bool(overlap), "values": val_meta,
    })

    def _arrays_like(n_shard: int):
        def sds(a):
            return jax.ShapeDtypeStruct((d * n_shard,) + a.shape[1:], a.dtype)

        return jax.tree.map(sds, arrays)

    # ---------------------------------------------------------- resume
    start = 0
    fills = np.zeros((levels + 1, d), np.int32)
    last = manager.latest_step()
    resumed = last is not None
    if resumed:
        saved_fp = manager.read_leaf(last, "fingerprint")
        if not np.array_equal(saved_fp, fp):
            raise ValueError(
                "checkpoint directory holds a different sort "
                "(parameter fingerprint mismatch); use a fresh directory"
            )
        start = int(manager.read_leaf(last, "level"))
        n_shard = n_local if start == 0 else schedule[start - 1].n_out
        like = {
            "arrays": _arrays_like(n_shard),
            "m": jax.ShapeDtypeStruct((d,), jnp.int32),
            "ovf": jax.ShapeDtypeStruct((d,), jnp.bool_),
            "fills": jax.ShapeDtypeStruct((levels + 1, d), jnp.int32),
            "level": jax.ShapeDtypeStruct((), jnp.int32),
            "fingerprint": jax.ShapeDtypeStruct((32,), jnp.uint8),
        }
        st = manager.restore(last, like, _state_shardings(like, mesh, ax))
        arrays, m, ovf = st["arrays"], st["m"], st["ovf"]
        fills = np.array(st["fills"])  # np.asarray of a jax array is read-only

    def _save(step: int):
        state = {
            "arrays": arrays, "m": m, "ovf": ovf,
            "fills": fills.copy(), "level": np.int32(step),
            "fingerprint": fp,
        }
        manager.save(step, state, blocking=blocking_saves)
        if _fail_at_step is not None and step == _fail_at_step:
            manager.wait()
            raise RuntimeError(
                f"injected shard loss after level boundary {step}"
            )

    with obs.trace(
        "dist.sort_elastic", axes=",".join(names), levels=levels, d=d,
        resumed="yes" if resumed else "no", start_level=start,
        overlap="on" if overlap else "off",
    ):
        if not resumed:
            aspec = _leaf_specs(arrays, ax)
            init = shard_map(
                lambda t: _pre_exchange(t, n_local, ax, d) if d > 1 else t,
                mesh=mesh, in_specs=(aspec,), out_specs=aspec,
                check_rep=False,
            )
            arrays = jax.jit(init)(arrays)
            m = jnp.full((d,), n_local, jnp.int32)
            ovf = jnp.zeros((d,), jnp.bool_)
            fills[0] = n_local
            _save(0)

        for i in range(start, levels):
            level = schedule[i]

            def step(tree, mm, _i=i, _lv=level):
                out, m1, o1 = exchange_level(
                    tree, mm[0], _lv,
                    engine=eng, tile=cfg.tile, seed=cfg.seed,
                    level_idx=_i, retries=retries,
                    classifier=clf if _i == 0 else "tree",
                    overlap=overlap,
                )
                return out, m1[None], o1[None]

            in_a = _leaf_specs(arrays, ax)
            out_like = _arrays_like(level.n_out)
            f = shard_map(
                step, mesh=mesh, in_specs=(in_a, P(ax)),
                out_specs=(_leaf_specs(out_like, ax), P(ax), P(ax)),
                check_rep=False,
            )
            arrays, m, ovf_i = jax.jit(f)(arrays, m)
            ovf = jnp.logical_or(ovf, ovf_i)
            fills[i + 1] = np.asarray(m)
            _save(i + 1)

        aspec = _leaf_specs(arrays, ax)
        fin = shard_map(
            lambda t, mm: _finish_local(t, mm[0], cfg_run, eng),
            mesh=mesh, in_specs=(aspec, P(ax)), out_specs=aspec,
            check_rep=False,
        )
        out = jax.jit(fin)(arrays, m)
    manager.wait()

    decoded = keyspace.decode(out["k"], keys.dtype)
    if values is None:
        return decoded, m, ovf
    return decoded, out["v"], m, ovf
