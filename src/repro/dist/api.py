"""repro.dist — sharded sort-derived ops on the multi-level engine.

Public entry points mirror ``repro.ops`` (DESIGN.md §5) lifted onto a
device mesh (DESIGN.md §8): keys biject through ``ops.keyspace`` at the
boundary (NaN-safe, -0.0 < +0.0, identical total order to ``ops.sort``),
the partition engine threads through the same ``engine="xla"|"pallas"|
"auto"`` seam, and "auto" resolves against the ``dist:`` plan family of
the plan cache (capacity factor × oversampling × engine learned per
(n_local, d, dtype)).

  sort / argsort   multi-level AMS-style sort over one or more mesh axes
                   (e.g. ``("pod", "data")``): per-axis splitter sets and
                   per-axis collective fan-in, re-split retry on overflow
  topk / bottomk   distributed rank-k: splitter-based local partial sort
                   (the filter), gather of the per-shard candidates, and a
                   single-shard finish — replicated (k,) results
  group_by         multi-level sort + per-shard run boundaries

Sharded results follow the original distributed-sort contract: each shard
holds its sorted range padded to capacity with sentinels, plus a valid
count per shard and an overflow flag (raised only after every re-split
retry failed — the last resort, not the first response).
"""
from __future__ import annotations

import functools
from dataclasses import replace
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro import obs
from repro.classify import resolve_classifier
from repro.core.ips4o import SortConfig, ips4o_sort, resolve_engine
from repro.dist.exchange import compact_valid, exchange_level, tile_for
from repro.dist.levels import (
    AxisNames, normalize_axes, order_axes, plan_schedule,
)
from repro.ops import keyspace
from repro.ops.topk import smallest_encoded

__all__ = ["sort", "argsort", "topk", "bottomk", "group_by"]


def _mesh_arity(mesh: Mesh, names: Tuple[str, ...]) -> int:
    d = 1
    for a in names:
        d *= mesh.shape[a]
    return d


def _axis_arg(names: Tuple[str, ...]):
    return names if len(names) > 1 else names[0]


def _resolve_dist_engine(
    engine: Optional[str], cfg: SortConfig, plan_engine: Optional[str],
    n_local: int, dtype,
) -> str:
    """Same seam as ``ops.sort.with_engine``: explicit argument > config >
    persisted ``dist:`` plan > backend heuristic — resolved at the API
    boundary against the caller's (n_local, dtype)."""
    eng = engine or cfg.engine
    if eng != "auto":
        return resolve_engine(replace(cfg, engine=eng), n_local, dtype)
    if plan_engine in ("xla", "pallas"):
        return plan_engine
    return resolve_engine(replace(cfg, engine="auto"), n_local, dtype)


def _plan_params(
    n_local: int, d: int, dtype, slack: Optional[float],
    oversample: Optional[int], tune: bool,
):
    from repro.ops.plan import default_cache  # lazy: keep dist importable alone

    plan = default_cache.dist_plan(n_local, d, dtype, tune=tune)
    return (
        plan.slack if slack is None else float(slack),
        plan.oversample if oversample is None else int(oversample),
        plan.engine,
        plan.axis_order,
    )


def _resolve_order(
    order: Optional[str], names: Tuple[str, ...], mesh: Mesh, n_local: int,
    d: int, dtype, planned: Tuple[str, ...], slack: float, oversample: int,
) -> Tuple[str, ...]:
    """``order="auto"``: topology-aware axis ordering (DESIGN.md §13.4).

    A persisted ``axis_order`` from the ``dist:`` plan wins when it names
    exactly this call's axes; otherwise the static cost model picks the
    order and records it as a plan dimension for the next call.  The
    default (None / "given") keeps the caller's order — bit-compatible
    with every pre-existing call site.
    """
    if order not in (None, "given", "auto"):
        raise ValueError(f"order must be None, 'given' or 'auto', got {order!r}")
    if order in (None, "given") or len(names) < 2:
        return names
    if tuple(sorted(planned)) == tuple(sorted(names)):
        return tuple(planned)
    chosen = order_axes(
        dict(mesh.shape), names, n_local, slack=slack, oversample=oversample
    )
    from repro.ops.plan import default_cache

    default_cache.record_dist_axis_order(n_local, d, dtype, chosen)
    return chosen


def _finish_local(arrays, m, cfg: SortConfig, engine: str):
    """Final per-shard IS4o sort.  Pads share the sentinel key with real
    dtype-max / NaN-class keys, so when payload identity matters a validity
    bit rides the sort and one stable 2-bucket partition pushes pads behind
    every real element without disturbing key order."""
    n = arrays["k"].shape[0]
    vals = {k: v for k, v in arrays.items() if k != "k"}
    if not vals:
        return {"k": ips4o_sort(arrays["k"], cfg=cfg)}
    validity = (jnp.arange(n, dtype=jnp.int32) < m).astype(jnp.int32)
    k_sorted, out_v = ips4o_sort(
        arrays["k"], {**vals, "_valid": validity}, cfg=cfg
    )
    valid_sorted = out_v.pop("_valid")
    return compact_valid(
        {"k": k_sorted, **out_v}, valid_sorted > 0, tile_for(n, cfg.tile), engine
    )


def _pre_exchange(arrays, n_local: int, ax, d: int):
    """Balanced pre-exchange over the FULL mesh domain: one round-robin
    all_to_all gives every shard a representative slice of every stripe,
    bounding per-pair counts for ANY input placement (the distributed
    cousin of the paper's beta overpartitioning).  Runs under shard_map."""
    chunk = n_local // d

    def pre(a):
        t = jax.lax.all_to_all(
            a.reshape((d, chunk) + a.shape[1:]),
            ax, split_axis=0, concat_axis=0, tiled=True,
        )
        return t.reshape((n_local,) + a.shape[1:])

    return jax.tree.map(pre, arrays)


def _sort_body(
    arrays, n_local: int, names: Tuple[str, ...], schedule, cfg: SortConfig,
    engine: str, retries: int, d: int, classifier: str = "tree",
    overlap: bool = False,
):
    """Per-shard body: balanced pre-exchange, the explicit level loop, and
    the local finish.  Runs under ``shard_map``."""
    ax = _axis_arg(names)
    if d > 1:
        arrays = _pre_exchange(arrays, n_local, ax, d)

    m = jnp.asarray(n_local, jnp.int32)
    overflow = jnp.asarray(False)
    for i, level in enumerate(schedule):
        # radix destinations only at level 0: deeper domains hold
        # splitter-delimited ranges once any round re-split
        arrays, m, ovf = exchange_level(
            arrays, m, level,
            engine=engine, tile=cfg.tile, seed=cfg.seed,
            level_idx=i, retries=retries,
            classifier=classifier if i == 0 else "tree",
            overlap=overlap,
        )
        overflow = jnp.logical_or(overflow, ovf)
    out = _finish_local(arrays, m, cfg, engine)
    return out, m[None], overflow[None]


def _prepare(
    keys: jax.Array, mesh: Mesh, axes: AxisNames, pre_exchange: bool = True
):
    names = normalize_axes(axes)
    d = _mesh_arity(mesh, names)
    n = keys.shape[0]
    if keys.ndim != 1:
        raise ValueError("keys must be 1-D (sharded over the mesh axes)")
    n_local = n // d
    if n_local * d != n:
        raise ValueError(f"n={n} not divisible by axis size {d}")
    # the balanced pre-exchange reshapes each shard into d chunks; rank-k
    # queries never run it and accept any shard size
    if pre_exchange and d > 1 and n_local % d:
        raise ValueError(
            f"shard size {n_local} must be divisible by d={d} (pre-exchange)"
        )
    return names, d, n_local


def sort(
    keys: jax.Array,
    mesh: Mesh,
    axes: AxisNames = "data",
    *,
    values: Any = None,
    slack: Optional[float] = None,
    oversample: Optional[int] = None,
    retries: int = 2,
    cfg: SortConfig = SortConfig(),
    engine: Optional[str] = None,
    classifier: Optional[str] = None,
    tune: bool = False,
    overlap: bool = False,
    order: Optional[str] = None,
):
    """Multi-level distributed sort of a globally sharded key array.

    Args:
      keys: (n,) array sharded over ``axes`` of ``mesh`` (n divisible by
        the total axis size d; shard size divisible by d for d > 1).
      axes: one mesh axis or an outermost-first tuple (e.g.
        ``("pod", "data")``) — one exchange level per axis.
      values: optional payload pytree (leaves with leading dim n), same
        sharding; rows ride every partition and exchange.
      slack / oversample: capacity factor and per-shard sample size; None
        reads the ``dist:`` plan for (n_local, d, dtype) (``tune=True``
        runs the capacity simulation and persists the winner).
      retries: bounded re-split rounds per level before the overflow flag.
      engine: "xla" | "pallas" | "auto" partition engine override.
      classifier: "tree" | "radix" | "learned" | "auto" classifier-engine
        override (DESIGN.md §9), resolved here against (n_local, dtype).
        "radix" additionally takes bit-range destinations at round 0 of
        level 0, skipping that round's sampling collective; exchange
        levels past the first (and every re-split round) stay
        splitter-based.
      overlap: stagger each level's exchange against local partition work
        via the half-shard protocol (DESIGN.md §13) — bit-identical
        results, collectives issued early enough to hide behind compute.
      order: None/"given" keeps the caller's axis order; "auto" reorders
        the level schedule by the topology cost model (DESIGN.md §13.4),
        consulting/recording the ``dist:`` plan's ``axis_order``.  The
        output contract follows the *chosen* order: shard ranges
        concatenate in the reordered spec's block order.

    Returns (sorted, counts, overflow) — with values,
    (sorted, sorted_values, counts, overflow): shard i of ``sorted`` holds
    its globally-ordered range with sentinel padding at the tail,
    ``counts`` (d,) the valid prefix per shard, ``overflow`` (d,) True only
    if some exchange truncated after exhausting its re-split retries.

    >>> import jax, jax.numpy as jnp
    >>> mesh = jax.make_mesh((1,), ("data",))
    >>> out, counts, ovf = sort(jnp.asarray([3.0, 1.0, 2.0, 0.0]), mesh)
    >>> out[: int(counts[0])].tolist()
    [0.0, 1.0, 2.0, 3.0]
    >>> bool(ovf.any())
    False
    """
    names, d, n_local = _prepare(keys, mesh, axes)
    slack, oversample, plan_engine, planned_order = _plan_params(
        n_local, d, keys.dtype, slack, oversample, tune
    )
    names = _resolve_order(
        order, names, mesh, n_local, d, keys.dtype, planned_order,
        slack, oversample,
    )
    eng = _resolve_dist_engine(engine, cfg, plan_engine, n_local, keys.dtype)
    clf = resolve_classifier(classifier or cfg.classifier, n_local, keys.dtype)
    cfg_run = replace(cfg, engine=eng, classifier=clf)
    schedule = plan_schedule(
        dict(mesh.shape), names, n_local, slack=slack, oversample=oversample
    )
    body = functools.partial(
        _sort_body, n_local=n_local, names=names, schedule=schedule,
        cfg=cfg_run, engine=eng, retries=retries, d=d, classifier=clf,
        overlap=overlap,
    )
    ax = _axis_arg(names)
    spec = P(ax)
    enc = keyspace.encode(keys)
    span = obs.trace(
        "dist.sort", axes=",".join(names), levels=len(schedule), d=d,
        overlap="on" if overlap else "off", engine=eng,
    )

    if values is None:
        def run(k):
            out, m, o = body({"k": k})
            return out["k"], m, o

        f = shard_map(run, mesh=mesh, in_specs=(spec,),
                      out_specs=(spec, spec, spec), check_rep=False)
        with span:
            out_k, counts, ovf = f(enc)
        return keyspace.decode(out_k, keys.dtype), counts, ovf

    vspecs = jax.tree.map(lambda a: P(ax, *([None] * (a.ndim - 1))), values)

    def run(k, v):
        out, m, o = body({"k": k, "v": v})
        return out["k"], out["v"], m, o

    # check_rep=False throughout: the replication checker cannot see
    # through the engine's scan-shaped internals (jax's own recommendation
    # for this false positive); no output here claims replication anyway
    f = shard_map(run, mesh=mesh, in_specs=(spec, vspecs),
                  out_specs=(spec, vspecs, spec, spec), check_rep=False)
    with span:
        out_k, out_v, counts, ovf = f(enc, values)
    return keyspace.decode(out_k, keys.dtype), out_v, counts, ovf


def argsort(
    keys: jax.Array,
    mesh: Mesh,
    axes: AxisNames = "data",
    *,
    slack: Optional[float] = None,
    oversample: Optional[int] = None,
    retries: int = 2,
    cfg: SortConfig = SortConfig(),
    engine: Optional[str] = None,
    classifier: Optional[str] = None,
    tune: bool = False,
    overlap: bool = False,
    order: Optional[str] = None,
):
    """Distributed argsort: global input positions ride as the payload.

    ``overlap`` / ``order`` behave exactly as in :func:`sort` (the global
    indices ride the same half-shard frames).

    Returns (order, counts, overflow): shard i's valid prefix of ``order``
    holds the global indices of its sorted range — concatenating the valid
    prefixes yields a permutation sorting the global array.

    >>> import jax, jax.numpy as jnp
    >>> mesh = jax.make_mesh((1,), ("data",))
    >>> idx, counts, ovf = argsort(jnp.asarray([30, 10, 20, 0]), mesh)
    >>> idx[: int(counts[0])].tolist()
    [3, 1, 2, 0]
    """
    names, d, n_local = _prepare(keys, mesh, axes)
    slack, oversample, plan_engine, planned_order = _plan_params(
        n_local, d, keys.dtype, slack, oversample, tune
    )
    names = _resolve_order(
        order, names, mesh, n_local, d, keys.dtype, planned_order,
        slack, oversample,
    )
    eng = _resolve_dist_engine(engine, cfg, plan_engine, n_local, keys.dtype)
    clf = resolve_classifier(classifier or cfg.classifier, n_local, keys.dtype)
    cfg_run = replace(cfg, engine=eng, classifier=clf)
    schedule = plan_schedule(
        dict(mesh.shape), names, n_local, slack=slack, oversample=oversample
    )
    body = functools.partial(
        _sort_body, n_local=n_local, names=names, schedule=schedule,
        cfg=cfg_run, engine=eng, retries=retries, d=d, classifier=clf,
        overlap=overlap,
    )
    ax = _axis_arg(names)
    spec = P(ax)

    def run(k):
        my = jax.lax.axis_index(ax).astype(jnp.int32)
        gidx = my * n_local + jnp.arange(n_local, dtype=jnp.int32)
        out, m, o = body({"k": k, "v": gidx})
        return out["v"], m, o

    f = shard_map(run, mesh=mesh, in_specs=(spec,),
                  out_specs=(spec, spec, spec), check_rep=False)
    return f(keyspace.encode(keys))


def bottomk(
    keys: jax.Array,
    k: int,
    mesh: Mesh,
    axes: AxisNames = "data",
    *,
    cfg: SortConfig = SortConfig(),
    engine: Optional[str] = None,
    classifier: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """The k globally smallest keys (ascending) with their global indices.

    Splitter-filter then single-shard finish: every shard runs the
    splitter-based *partial* sort (``ops`` §5.2 — only the rank-covering
    bucket prefix is base-case-sorted) as its local filter, the per-shard
    candidates are gathered, and one shard-local partial sort finishes.
    Results are replicated (same on every shard), NaN-safe like
    ``ops.bottomk``.

    >>> import jax, jax.numpy as jnp
    >>> mesh = jax.make_mesh((1,), ("data",))
    >>> v, i = bottomk(jnp.asarray([4.0, 1.0, 3.0, 2.0]), 2, mesh)
    >>> (v.tolist(), i.tolist())
    ([1.0, 2.0], [1, 3])
    """
    return _rank_k(
        keys, k, mesh, axes, cfg=cfg, engine=engine, classifier=classifier,
        largest=False,
    )


def topk(
    keys: jax.Array,
    k: int,
    mesh: Mesh,
    axes: AxisNames = "data",
    *,
    cfg: SortConfig = SortConfig(),
    engine: Optional[str] = None,
    classifier: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """The k globally largest keys (descending) with their global indices;
    ``bottomk`` of the complemented keyspace codes (``~u`` reverses the
    total order), like ``ops.topk``.

    >>> import jax, jax.numpy as jnp
    >>> mesh = jax.make_mesh((1,), ("data",))
    >>> v, i = topk(jnp.asarray([4.0, 1.0, 3.0, 2.0]), 2, mesh)
    >>> (v.tolist(), i.tolist())
    ([4.0, 3.0], [0, 2])
    """
    return _rank_k(
        keys, k, mesh, axes, cfg=cfg, engine=engine, classifier=classifier,
        largest=True,
    )


def _rank_k(
    keys: jax.Array, k: int, mesh: Mesh, axes: AxisNames,
    *, cfg: SortConfig, engine: Optional[str], largest: bool,
    classifier: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    names, d, n_local = _prepare(keys, mesh, axes, pre_exchange=False)
    n = keys.shape[0]
    kk = max(0, min(int(k), n))
    if kk == 0:
        return keys[:0], jnp.zeros((0,), jnp.int32)
    if d == 1:
        from repro.ops.topk import bottomk as _bk, topk as _tk

        return (_tk if largest else _bk)(
            keys, kk, cfg=cfg, engine=engine, classifier=classifier
        )

    eng = _resolve_dist_engine(engine, cfg, None, n_local, keys.dtype)
    clf = resolve_classifier(classifier or cfg.classifier, n_local, keys.dtype)
    cfg_run = replace(cfg, engine=eng, classifier=clf)
    ax = _axis_arg(names)
    k_local = min(kk, n_local)
    enc = keyspace.encode(keys)
    if largest:
        enc = ~enc

    def run(e):
        vals, idx = smallest_encoded(e, k_local, cfg_run)   # the local filter
        my = jax.lax.axis_index(ax).astype(jnp.int32)
        gidx = my * n_local + idx
        cand_v = jax.lax.all_gather(vals, ax, tiled=True)   # (d * k_local,)
        cand_i = jax.lax.all_gather(gidx, ax, tiled=True)
        fin_v, fin_i = smallest_encoded(cand_v, kk, cfg_run)  # single-shard finish
        return fin_v, jnp.take(cand_i, fin_i, axis=0)

    # outputs are replicated: every shard computes the same finish over the
    # same gathered candidates (check_rep can't see through the partial
    # sort's internals, so it is disabled rather than trusted to infer)
    f = shard_map(run, mesh=mesh, in_specs=(P(ax),), out_specs=(P(), P()),
                  check_rep=False)
    out_v, out_i = f(enc)
    if largest:
        out_v = ~out_v
    return keyspace.decode(out_v, keys.dtype), out_i


def group_by(
    keys: jax.Array,
    mesh: Mesh,
    axes: AxisNames = "data",
    *,
    values: Any = None,
    slack: Optional[float] = None,
    retries: int = 2,
    cfg: SortConfig = SortConfig(),
    engine: Optional[str] = None,
    classifier: Optional[str] = None,
    overlap: bool = False,
):
    """Sharded grouping: multi-level sort by key, then per-shard run starts.

    Returns (sorted_keys, [sorted_values,] starts, counts, overflow) where
    ``starts`` marks the first element of each key run *within its shard*
    (a run crossing a shard boundary re-starts on the next shard — merging
    boundary runs is one host-side concat of adjacent shard edges; the
    global sort guarantees a key spans only adjacent shards).

    >>> import jax, jax.numpy as jnp
    >>> mesh = jax.make_mesh((1,), ("data",))
    >>> ks, starts, counts, ovf = group_by(jnp.asarray([2, 1, 2, 1]), mesh)
    >>> m = int(counts[0])
    >>> (ks[:m].tolist(), starts[:m].tolist())
    ([1, 1, 2, 2], [True, False, True, False])
    """
    res = sort(
        keys, mesh, axes, values=values, slack=slack, retries=retries,
        cfg=cfg, engine=engine, classifier=classifier, overlap=overlap,
    )
    if values is None:
        out_k, counts, ovf = res
        out_v = None
    else:
        out_k, out_v, counts, ovf = res
    names, d, _ = _prepare(keys, mesh, axes)
    cap = out_k.shape[0] // d
    ax = _axis_arg(names)

    def run(kk, m):
        ek = keyspace.encode(kk)  # NaN-safe equality: one NaN class, -0 != +0
        pos = jnp.arange(cap, dtype=jnp.int32)
        valid = pos < m[0]
        prev = jnp.concatenate([ek[:1], ek[:-1]])
        starts = valid & ((pos == 0) | (ek != prev))
        return starts

    f = shard_map(run, mesh=mesh, in_specs=(P(ax), P(ax)), out_specs=P(ax))
    starts = f(out_k, counts)
    if values is None:
        return out_k, starts, counts, ovf
    return out_k, out_v, starts, counts, ovf
