"""Level schedule for the multi-level distributed sort (DESIGN.md §8).

AMS-sort runs the paper's sample → classify → partition → exchange
recursion once per *level of the machine hierarchy*; the journal follow-up
(*Engineering In-place (Shared-memory) Sorting Algorithms*) shows the same
recursion scales across memory levels, and the Fugaku evaluation confirms
multi-level splitter exchange is what keeps collective volume per-axis-
sized at scale.  Exactly as ``core/ips4o.py`` flattens the paper's bucket
recursion into at most two static level passes, this module flattens the
*mesh* recursion into an explicit, statically planned schedule:

  axes = ("pod", "data")   ->   [ Level(axis="pod",  groups=p0, ...),
                                  Level(axis="data", groups=p1, ...) ]

Level l collapses mesh axis ``axes[l]``: shards sharing the leading axis
indices ``axes[:l]`` form a *group* that owns one contiguous key range and
is itself distributed over ``domain = axes[l:]``.  The exchange at level l
is an ``all_to_all`` over ``axes[l]`` only (fan-in = that axis size, not
the global device count), against a splitter set of ``groups - 1`` values
(per-axis-sized, not global).  After the last level every shard owns a
contiguous global range and sorts locally.

Capacities are *expectation-based*: the balanced data volume entering any
level is ~``n_local`` per shard (the total is conserved), so each
per-(sender, group) chunk gets ``ceil(n_local / groups) * slack`` slots —
``slack`` is headroom over the balanced expectation, the paper's beta-like
overpartitioning safety, learned per (n_local, d, dtype) by the ``dist:``
plan family (``ops/plan.py``).  Padded shard size after level l is
therefore ~``slack * n_local`` at every level, not ``slack**l``.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Tuple, Union

from repro.core import sampling

__all__ = ["Level", "plan_schedule", "normalize_axes", "default_oversample"]

AxisNames = Union[str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class Level:
    """One flattened step of the mesh recursion (one exchanged axis)."""

    axis: str                  # mesh axis collapsed by this level's all_to_all
    domain: Tuple[str, ...]    # axes[l:]: the group this level's splitters span
    groups: int                # size of ``axis`` = buckets = collective fan-in
    n_in: int                  # padded per-shard element count entering the level
    capacity: int              # per-(sender, group) chunk slots in the exchange
    oversample: int            # per-shard sample size for this level's splitters

    @property
    def n_out(self) -> int:
        """Padded per-shard element count after this level's exchange."""
        return self.groups * self.capacity


def normalize_axes(axes: AxisNames) -> Tuple[str, ...]:
    return (axes,) if isinstance(axes, str) else tuple(axes)


def default_oversample(n_total: int) -> int:
    """Per-shard sample size: the paper's alpha scaled for the distributed
    setting (splitters must be good enough that no retry is the common
    case)."""
    return max(32, sampling.oversampling_factor(n_total) * 16)


def _round_up(x: int, unit: int = 128) -> int:
    return -(-x // unit) * unit


def plan_schedule(
    axis_sizes: Mapping[str, int],
    axes: AxisNames,
    n_local: int,
    *,
    slack: float = 2.0,
    oversample: int = 0,
) -> Tuple[Level, ...]:
    """The explicit level loop for ``axes`` (outermost first).

    ``axis_sizes`` maps mesh axis name -> size (``dict(mesh.shape)``).
    ``oversample=0`` uses :func:`default_oversample`.  Capacities round up
    to 128 lanes and never drop below one lane register, mirroring the
    single-level seed formula so the compat shim is shape-identical.
    """
    names = normalize_axes(axes)
    if not names:
        raise ValueError("at least one mesh axis is required")
    sizes = [int(axis_sizes[a]) for a in names]
    d_total = 1
    for s in sizes:
        d_total *= s
    if oversample <= 0:
        oversample = default_oversample(n_local * d_total)
    levels = []
    n = n_local
    for lvl, (name, g) in enumerate(zip(names, sizes)):
        # headroom over the *balanced* per-pair expectation n_local / g;
        # the padded size entering deeper levels stays ~slack * n_local
        cap = _round_up(max(128, int(-(-n_local * slack // g))))
        levels.append(
            Level(
                axis=name,
                domain=tuple(names[lvl:]),
                groups=g,
                n_in=n,
                capacity=cap,
                oversample=oversample,
            )
        )
        n = g * cap
    return tuple(levels)
