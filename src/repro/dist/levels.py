"""Level schedule for the multi-level distributed sort (DESIGN.md §8).

AMS-sort runs the paper's sample → classify → partition → exchange
recursion once per *level of the machine hierarchy*; the journal follow-up
(*Engineering In-place (Shared-memory) Sorting Algorithms*) shows the same
recursion scales across memory levels, and the Fugaku evaluation confirms
multi-level splitter exchange is what keeps collective volume per-axis-
sized at scale.  Exactly as ``core/ips4o.py`` flattens the paper's bucket
recursion into at most two static level passes, this module flattens the
*mesh* recursion into an explicit, statically planned schedule:

  axes = ("pod", "data")   ->   [ Level(axis="pod",  groups=p0, ...),
                                  Level(axis="data", groups=p1, ...) ]

Level l collapses mesh axis ``axes[l]``: shards sharing the leading axis
indices ``axes[:l]`` form a *group* that owns one contiguous key range and
is itself distributed over ``domain = axes[l:]``.  The exchange at level l
is an ``all_to_all`` over ``axes[l]`` only (fan-in = that axis size, not
the global device count), against a splitter set of ``groups - 1`` values
(per-axis-sized, not global).  After the last level every shard owns a
contiguous global range and sorts locally.

Capacities are *expectation-based*: the balanced data volume entering any
level is ~``n_local`` per shard (the total is conserved), so each
per-(sender, group) chunk gets ``ceil(n_local / groups) * slack`` slots —
``slack`` is headroom over the balanced expectation, the paper's beta-like
overpartitioning safety, learned per (n_local, d, dtype) by the ``dist:``
plan family (``ops/plan.py``).  Padded shard size after level l is
therefore ~``slack * n_local`` at every level, not ``slack**l``.

**Topology-aware ordering** (DESIGN.md §13.4): the Fugaku evaluation
(2305.05245) attributes parallel samplesort's scaling wall to per-level
collective cost, which differs per mesh axis (intra-node vs inter-node
interconnect).  :func:`order_axes` reorders the level schedule to minimise
a static cost model (:func:`schedule_cost`) with two terms per level:

  * the ``all_to_all`` wire term — ``(groups - 1)/groups`` of the padded
    frame actually crosses the axis, divided by that axis's bandwidth.
    Under expectation-based capacities this term is order-*invariant*
    (capacity depends only on the level's own fan-in), so it anchors the
    model but does not drive the ordering;
  * the splitter/control term — level l's sample ``all_gather`` (and the
    re-split ``psum``/``pmax``) span the whole remaining domain
    ``axes[l:]`` and are bottlenecked by the *slowest* axis in it.  This
    term is what ordering moves: an axis placed early drops out of every
    deeper domain, so slow (low-bandwidth) axes schedule first and the
    highest-fan-in exchange runs late, over a domain containing only the
    cheapest collectives.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Mapping, Optional, Tuple, Union

from repro.core import sampling

__all__ = [
    "Level",
    "plan_schedule",
    "normalize_axes",
    "default_oversample",
    "axis_bandwidths",
    "schedule_cost",
    "order_axes",
]

AxisNames = Union[str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class Level:
    """One flattened step of the mesh recursion (one exchanged axis)."""

    axis: str                  # mesh axis collapsed by this level's all_to_all
    domain: Tuple[str, ...]    # axes[l:]: the group this level's splitters span
    groups: int                # size of ``axis`` = buckets = collective fan-in
    n_in: int                  # padded per-shard element count entering the level
    capacity: int              # per-(sender, group) chunk slots in the exchange
    oversample: int            # per-shard sample size for this level's splitters

    @property
    def n_out(self) -> int:
        """Padded per-shard element count after this level's exchange."""
        return self.groups * self.capacity


def normalize_axes(axes: AxisNames) -> Tuple[str, ...]:
    return (axes,) if isinstance(axes, str) else tuple(axes)


def default_oversample(n_total: int) -> int:
    """Per-shard sample size: the paper's alpha scaled for the distributed
    setting (splitters must be good enough that no retry is the common
    case)."""
    return max(32, sampling.oversampling_factor(n_total) * 16)


def _round_up(x: int, unit: int = 128) -> int:
    return -(-x // unit) * unit


def plan_schedule(
    axis_sizes: Mapping[str, int],
    axes: AxisNames,
    n_local: int,
    *,
    slack: float = 2.0,
    oversample: int = 0,
) -> Tuple[Level, ...]:
    """The explicit level loop for ``axes`` (outermost first).

    ``axis_sizes`` maps mesh axis name -> size (``dict(mesh.shape)``).
    ``oversample=0`` uses :func:`default_oversample`.  Capacities round up
    to 128 lanes and never drop below one lane register, mirroring the
    single-level seed formula so the compat shim is shape-identical.
    """
    names = normalize_axes(axes)
    if not names:
        raise ValueError("at least one mesh axis is required")
    sizes = [int(axis_sizes[a]) for a in names]
    d_total = 1
    for s in sizes:
        d_total *= s
    if oversample <= 0:
        oversample = default_oversample(n_local * d_total)
    levels = []
    n = n_local
    for lvl, (name, g) in enumerate(zip(names, sizes)):
        # headroom over the *balanced* per-pair expectation n_local / g;
        # the padded size entering deeper levels stays ~slack * n_local
        cap = _round_up(max(128, int(-(-n_local * slack // g))))
        levels.append(
            Level(
                axis=name,
                domain=tuple(names[lvl:]),
                groups=g,
                n_in=n,
                capacity=cap,
                oversample=oversample,
            )
        )
        n = g * cap
    return tuple(levels)


def axis_bandwidths(axis_sizes: Mapping[str, int]) -> dict:
    """Default relative collective bandwidth per mesh axis.

    Mesh axes are conventionally declared outermost-first — the slowest
    interconnect (inter-pod DCN) outermost, the fastest (intra-pod ICI)
    innermost — so the default assigns each axis ``4**position`` in
    declaration order.  Pass an explicit mapping to :func:`order_axes` /
    :func:`schedule_cost` when the machine differs; only ratios matter.

    >>> axis_bandwidths({"pod": 2, "data": 4})
    {'pod': 1.0, 'data': 4.0}
    """
    return {a: 4.0 ** i for i, a in enumerate(axis_sizes)}


def schedule_cost(
    schedule: Tuple[Level, ...],
    bandwidths: Mapping[str, float],
    itemsize: int = 4,
) -> float:
    """Static per-level collective cost of a schedule (relative units).

    Extends ``benchmarks/sort_distributed.py``'s volume accounting with
    bandwidth weights: per level, the ``all_to_all`` moves
    ``(groups - 1) * capacity * itemsize`` bytes off-shard over the
    level's axis, and the splitter/control collectives gather
    ``oversample * itemsize`` bytes from every *other* shard of the
    remaining domain, bottlenecked by the slowest axis still in it.

    >>> sched = plan_schedule({"pod": 2, "data": 4}, ("pod", "data"), 8192)
    >>> swapped = plan_schedule({"pod": 2, "data": 4}, ("data", "pod"), 8192)
    >>> bw = axis_bandwidths({"pod": 2, "data": 4})
    >>> schedule_cost(sched, bw) < schedule_cost(swapped, bw)  # slow axis first
    True
    """
    total = 0.0
    domain_size = {}
    acc = 1
    for lv in reversed(schedule):
        acc *= lv.groups
        domain_size[lv.axis] = acc
    for lv in schedule:
        wire = (lv.groups - 1) * lv.capacity * itemsize
        total += wire / bandwidths.get(lv.axis, 1.0)
        dsz = domain_size[lv.axis]
        min_bw = min(bandwidths.get(a, 1.0) for a in lv.domain)
        total += lv.oversample * itemsize * (dsz - 1) / min_bw
    return total


def order_axes(
    axis_sizes: Mapping[str, int],
    axes: AxisNames,
    n_local: int,
    *,
    bandwidths: Optional[Mapping[str, float]] = None,
    slack: float = 2.0,
    oversample: int = 0,
) -> Tuple[str, ...]:
    """The axis order minimising :func:`schedule_cost` (ties keep the
    caller's order).  Axis counts are tiny, so plain permutation
    enumeration; the result feeds :func:`plan_schedule` and is persisted
    as the ``dist:`` plan's ``axis_order`` dimension (``ops/plan.py``).

    >>> order_axes({"pod": 2, "data": 4}, ("data", "pod"), 8192)
    ('pod', 'data')
    >>> order_axes({"pod": 2, "data": 4}, ("data", "pod"), 8192,
    ...            bandwidths={"pod": 4.0, "data": 1.0})
    ('data', 'pod')
    """
    names = normalize_axes(axes)
    if len(names) < 2:
        return names
    bw = dict(bandwidths) if bandwidths is not None else axis_bandwidths(axis_sizes)
    best, best_cost = names, None
    # permutations() emits the caller's order first, and only a strictly
    # cheaper permutation displaces it — ties keep the given order
    for perm in itertools.permutations(names):
        sched = plan_schedule(
            axis_sizes, perm, n_local, slack=slack, oversample=oversample
        )
        cost = schedule_cost(sched, bw)
        if best_cost is None or cost < best_cost - 1e-9:
            best, best_cost = perm, cost
    return tuple(best)
