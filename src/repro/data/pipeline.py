"""Deterministic, resumable synthetic-token data pipeline.

A real deployment swaps ``SyntheticLM`` for a file-backed source; the
contract the trainer relies on is:

  * deterministic as a function of (seed, step) — restart at step N
    reproduces the same batch (resume == bitwise-identical training);
  * sharded host feeding: ``global_batch`` rows are produced, each host
    materializes only its slice (here: one host = all rows);
  * **length bucketing via the paper's machinery**: documents are sorted by
    length through ``repro.ops`` before packing, minimizing pad waste — the
    data-pipeline instantiation of the sorting engine (DESIGN.md §3).  The
    argsort comes from the plan cache (``ops.get_sorter``), so repeated
    packing calls at a fixed corpus size reuse one cached jitted sorter
    (and pick up persisted tuned plans when present); shard sets larger
    than device memory pack out-of-core via ``repro.stream``
    (``pack_by_length(..., chunk_size=...)``, DESIGN.md §7).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["SyntheticLM", "pack_by_length"]


@dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    embed_dim: int = 0  # >0: emit embeddings (vlm/audio stub frontends)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        b, s = self.global_batch, self.seq_len
        if self.embed_dim:
            inputs = rng.standard_normal((b, s, self.embed_dim), np.float32)
        else:
            inputs = rng.integers(0, self.vocab_size, (b, s), dtype=np.int32)
        labels = rng.integers(0, self.vocab_size, (b, s), dtype=np.int32)
        return {"inputs": inputs, "labels": labels}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def _greedy_pack(lengths_np: np.ndarray, idx: np.ndarray, seq_len: int):
    """Greedy first-fit over length-sorted docs; see :func:`pack_by_length`."""
    n = len(lengths_np)
    keys = lengths_np[idx]
    row_id = np.zeros(n, np.int32)
    offset = np.zeros(n, np.int32)
    # pack longest-first so fragmentation stays bounded
    rows: list[int] = []  # remaining space per row
    for j in range(n - 1, -1, -1):
        doc, ln = idx[j], keys[j]
        ln = min(int(ln), seq_len)
        placed = False
        for r, space in enumerate(rows):
            if space >= ln:
                row_id[doc] = r
                offset[doc] = seq_len - space
                rows[r] = space - ln
                placed = True
                break
        if not placed:
            rows.append(seq_len - ln)
            row_id[doc] = len(rows) - 1
            offset[doc] = 0
    return row_id, offset, len(rows)


def _dist_length_order(lengths_np: np.ndarray, mesh, axes) -> Optional[np.ndarray]:
    """Global length-sorted document order via ``repro.dist.argsort``.

    Lengths pad with the int32 sentinel to a shape divisible by d² (the
    multi-level pre-exchange requirement); pads sort last and drop out of
    the returned order.  Returns None on a degenerate (d == 1) mesh or in
    the last-resort overflow case — callers then use the single-device
    plan-cached path, which is semantically identical.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import dist
    from repro.dist.levels import normalize_axes

    names = normalize_axes(axes)
    d = 1
    for a in names:
        d *= mesh.shape[a]
    if d <= 1:
        return None
    n = len(lengths_np)
    unit = d * d
    n_pad = max(unit, -(-n // unit) * unit)
    padded = np.full(n_pad, np.iinfo(np.int32).max, np.int32)
    padded[:n] = lengths_np
    spec = P(names if len(names) > 1 else names[0])
    xs = jax.device_put(jnp.asarray(padded), NamedSharding(mesh, spec))
    order, counts, overflow = dist.argsort(xs, mesh, axes)
    if bool(np.asarray(overflow).any()):
        return None  # last resort: retries exhausted — single-device path
    order, counts = np.asarray(order), np.asarray(counts)
    cap = order.shape[0] // d
    idx = np.concatenate([order[i * cap : i * cap + counts[i]] for i in range(d)])
    return idx[idx < n]  # sentinel pads sort last; drop them


def pack_by_length(
    lengths: np.ndarray,
    seq_len: int,
    *,
    chunk_size: Optional[int] = None,
    mesh=None,
    axes="data",
):
    """Greedy packing of documents into rows after an IPS4o length sort.

    Returns (row_id, offset, num_rows) per document.  Sorting by length
    first (the paper's engine, used as a library) makes greedy packing
    near-optimal and deterministic.

    2-D ``lengths`` (S, n) packs S shards (hosts, corpus slices) at once:
    ONE plan-cached batched argsort (``ops.batched_argsort`` via
    ``get_sorter(..., batch=S)``, DESIGN.md §6) sorts every shard's
    lengths in a single trace, then each shard packs greedily from its own
    row.  Returns a list of S (row_id, offset, num_rows) tuples.

    **Out-of-core** (DESIGN.md §7): 1-D shard sets larger than one device
    allocation pass ``chunk_size`` — the length argsort then runs through
    ``repro.stream.external_argsort`` (chunked run formation + stable
    merge), so only ``chunk_size`` lengths ever sit on device while the
    pack itself stays host-side and identical.  The packing is unchanged
    up to tie order within a chunk (both paths sort by length; greedy
    packing consumes lengths, not indices, so row counts agree).

    **Sharded** (DESIGN.md §8): with ``mesh`` (a ``jax.sharding.Mesh``)
    the 1-D length argsort runs through the multi-level distributed
    engine (``repro.dist.argsort`` over ``axes``) — lengths shard across
    the mesh, only a per-shard slice sits on any one device, and the
    globally sorted order comes back as concatenated valid prefixes; the
    greedy pack itself stays host-side and identical.
    """
    import jax.numpy as jnp

    from repro.ops import get_sorter

    lengths_np = np.asarray(lengths, np.int32)
    if mesh is not None and lengths_np.ndim == 1:
        idx = _dist_length_order(lengths_np, mesh, axes)
        if idx is not None:
            return _greedy_pack(lengths_np, idx, seq_len)
    if lengths_np.ndim == 2:
        s, n = lengths_np.shape
        idx = np.asarray(
            get_sorter(n, jnp.int32, op="argsort", batch=s)(jnp.asarray(lengths_np))
        )
        return [_greedy_pack(lengths_np[i], idx[i], seq_len) for i in range(s)]
    n = len(lengths_np)
    if chunk_size is not None and n > chunk_size:
        from repro.stream import external_argsort

        idx = external_argsort(lengths_np, chunk_size=chunk_size)
        return _greedy_pack(lengths_np, idx, seq_len)
    idx = np.asarray(get_sorter(n, jnp.int32, op="argsort")(jnp.asarray(lengths_np)))
    return _greedy_pack(lengths_np, idx, seq_len)
