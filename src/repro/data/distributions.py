"""The paper's nine benchmark input distributions (§5) + element types.

Uniform, Exponential, AlmostSorted (Shun et al.), RootDup, TwoDup, EightDup
(Edelkamp et al.), Sorted, ReverseSorted, Ones — generated deterministically
from a seed, as numpy arrays (host-side data pipeline).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

__all__ = ["DISTRIBUTIONS", "make_input", "make_payload", "ELEMENT_TYPES"]


def _uniform(rng, n, dtype):
    if np.issubdtype(dtype, np.floating):
        return rng.random(n).astype(dtype)
    return rng.integers(0, np.iinfo(dtype).max, n, dtype=dtype)


def _exponential(rng, n, dtype):
    x = rng.exponential(size=n)
    if np.issubdtype(dtype, np.floating):
        return x.astype(dtype)
    return np.minimum(x * (1 << 20), np.iinfo(dtype).max).astype(dtype)


def _almost_sorted(rng, n, dtype):
    x = np.sort(_uniform(rng, n, dtype))
    num_swaps = max(1, int(np.sqrt(n)))
    i = rng.integers(0, n, num_swaps)
    j = rng.integers(0, n, num_swaps)
    x[i], x[j] = x[j].copy(), x[i].copy()
    return x


def _root_dup(rng, n, dtype):
    return (np.arange(n) % max(1, int(np.floor(np.sqrt(n))))).astype(dtype)


def _two_dup(rng, n, dtype):
    i = np.arange(n, dtype=np.uint64)
    return ((i * i + n // 2) % n).astype(dtype)


def _eight_dup(rng, n, dtype):
    i = np.arange(n, dtype=np.uint64)
    return (((i**8) + n // 2) % n).astype(dtype)


def _sorted(rng, n, dtype):
    return np.sort(_uniform(rng, n, dtype))


def _reverse_sorted(rng, n, dtype):
    return np.sort(_uniform(rng, n, dtype))[::-1].copy()


def _ones(rng, n, dtype):
    return np.ones(n, dtype)


DISTRIBUTIONS = {
    "Uniform": _uniform,
    "Exponential": _exponential,
    "AlmostSorted": _almost_sorted,
    "RootDup": _root_dup,
    "TwoDup": _two_dup,
    "EightDup": _eight_dup,
    "Sorted": _sorted,
    "ReverseSorted": _reverse_sorted,
    "Ones": _ones,
}

# Paper §5 element types: double / Pair / Quartet / 100Bytes.  Payload is a
# (n, payload_words) uint64 block permuted alongside the key.
ELEMENT_TYPES: Dict[str, Tuple[np.dtype, int]] = {
    "double": (np.dtype(np.float64), 0),
    "Pair": (np.dtype(np.float64), 1),
    "Quartet": (np.dtype(np.float64), 3),
    "100Bytes": (np.dtype(np.uint64), 12),  # 10B key -> u64 key + 90B payload
}


def make_input(name: str, n: int, dtype=np.float32, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return DISTRIBUTIONS[name](rng, n, np.dtype(dtype))


def make_payload(n: int, words: int, seed: int = 1) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, 1 << 62, (n, words), dtype=np.uint64)
