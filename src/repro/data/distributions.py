"""The paper's nine benchmark input distributions (§5) + element types.

Uniform, Exponential, AlmostSorted (Shun et al.), RootDup, TwoDup, EightDup
(Edelkamp et al.), Sorted, ReverseSorted, Ones — generated deterministically
from a seed, as numpy arrays (host-side data pipeline).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

__all__ = ["DISTRIBUTIONS", "make_input", "make_payload", "ELEMENT_TYPES"]


def _clamp_to_int(x: np.ndarray, dtype) -> np.ndarray:
    """Clamp a float array into an integer dtype's range, in integer space.

    ``np.minimum(x, iinfo(int64).max)`` is wrong for 64-bit targets: the
    bound is not exactly representable in float64, rounds *up* to 2^63, and
    the later cast wraps negative.  Compare against the rounded-up float
    bound instead and substitute the exact integer max for everything at or
    above it; values strictly below 2^63 cast safely.
    """
    info = np.iinfo(dtype)
    fmax = np.float64(info.max)  # may round up (int64: 2^63 exactly)
    over = x >= fmax
    under = x <= np.float64(info.min)
    safe = np.where(over | under, 0.0, x).astype(dtype)
    return np.where(over, info.max, np.where(under, info.min, safe)).astype(dtype)


def _fit_int(vals: np.ndarray, n: int, dtype) -> np.ndarray:
    """Cast values in [0, n) to ``dtype``, folding into the dtype's range
    first when n exceeds it (instead of silently wrapping, e.g. negative
    for int16 keys with n = 10^6)."""
    if np.issubdtype(dtype, np.floating):
        return vals.astype(dtype)
    info = np.iinfo(dtype)
    if n - 1 > int(info.max):
        vals = vals % np.uint64(int(info.max) + 1)
    return vals.astype(dtype)


def _uniform(rng, n, dtype):
    if np.issubdtype(dtype, np.floating):
        return rng.random(n).astype(dtype)
    return rng.integers(0, np.iinfo(dtype).max, n, dtype=dtype)


def _exponential(rng, n, dtype):
    x = rng.exponential(size=n)
    if np.issubdtype(dtype, np.floating):
        return x.astype(dtype)
    # a fixed 2^20 scale saturates narrow dtypes — for int8 nearly every
    # draw clamps to info.max, degenerating the "Exponential" input to a
    # constant array; scale so the bulk of the mass (x < 8 covers all but
    # ~3e-4 of it) stays in range, leaving int32/int64 behavior unchanged
    info = np.iinfo(dtype)
    scale = min(1 << 20, max(1, int(info.max) // 8))
    return _clamp_to_int(x * scale, dtype)


def _almost_sorted(rng, n, dtype):
    x = np.sort(_uniform(rng, n, dtype))
    if n < 2:  # nothing to perturb (rng.integers rejects high=0)
        return x
    num_swaps = max(1, int(np.sqrt(n)))
    i = rng.integers(0, n, num_swaps)
    j = rng.integers(0, n, num_swaps)
    x[i], x[j] = x[j].copy(), x[i].copy()
    return x


def _root_dup(rng, n, dtype):
    vals = np.arange(n, dtype=np.uint64) % max(1, int(np.floor(np.sqrt(n))))
    return _fit_int(vals, n, dtype)


def _two_dup(rng, n, dtype):
    i = np.arange(n, dtype=np.uint64)
    return _fit_int((i * i + n // 2) % n, n, dtype)


def _eight_dup(rng, n, dtype):
    i = np.arange(n, dtype=np.uint64)
    return _fit_int(((i**8) + n // 2) % n, n, dtype)


def _sorted(rng, n, dtype):
    return np.sort(_uniform(rng, n, dtype))


def _reverse_sorted(rng, n, dtype):
    return np.sort(_uniform(rng, n, dtype))[::-1].copy()


def _ones(rng, n, dtype):
    return np.ones(n, dtype)


DISTRIBUTIONS = {
    "Uniform": _uniform,
    "Exponential": _exponential,
    "AlmostSorted": _almost_sorted,
    "RootDup": _root_dup,
    "TwoDup": _two_dup,
    "EightDup": _eight_dup,
    "Sorted": _sorted,
    "ReverseSorted": _reverse_sorted,
    "Ones": _ones,
}

# Paper §5 element types: double / Pair / Quartet / 100Bytes.  Payload is a
# (n, payload_words) uint64 block permuted alongside the key.
ELEMENT_TYPES: Dict[str, Tuple[np.dtype, int]] = {
    "double": (np.dtype(np.float64), 0),
    "Pair": (np.dtype(np.float64), 1),
    "Quartet": (np.dtype(np.float64), 3),
    "100Bytes": (np.dtype(np.uint64), 12),  # 10B key -> u64 key + 90B payload
}


def make_input(name: str, n: int, dtype=np.float32, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return DISTRIBUTIONS[name](rng, n, np.dtype(dtype))


def make_payload(n: int, words: int, seed: int = 1) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, 1 << 62, (n, words), dtype=np.uint64)
