"""Realistic record workloads: the journal paper's real-dataset evaluation.

The IPS⁴o journal follow-up ("Engineering In-place Sorting Algorithms",
2009.13569) evaluates on real datasets — sky-survey records and genomic
strings — not only the nine adversarial scalar distributions of
``data/distributions.py``.  This module is that workload zoo for the
multi-word path (DESIGN.md §11): four generator families producing
structured records, each with the fixed-width word decomposition
(``ops.keyspace.encode_words``) attached and an *independent* numpy sort
oracle (``oracle_argsort`` — ``np.lexsort`` / byte-string argsort, no
keyspace machinery for the comparison itself).

Families
  SkySurvey     SDSS-like (ra, dec, mag) float32 columns; ra quantized to
                0.1° bins so word 0 is tie-heavy and the tie-break
                schedule engages on (dec, mag).
  RnaSequences  RNAcentral-like variable-length sequences over ACGU —
                4-letter alphabet, so every 4-byte word has ≤ 256 values
                and ties persist for several words.
  UrlPaths      URL/path strings from a small host/segment vocabulary:
                massive shared prefixes, exact duplicates, and proper
                prefix-of records ("…/users" vs "…/users/42").
  TenantTuples  zipf-weighted (tenant, priority, arrival) composite
                tuples — the multi-tenant scheduler key shape; arrival is
                unique so full records never tie.

Everything is deterministic from ``seed`` and host-side numpy (like
``distributions.make_input``); ``Dataset.words`` is what goes to the
device (``ops.sort_records``).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import numpy as np

from repro.ops import keyspace

__all__ = ["DATASETS", "Dataset", "make_dataset", "oracle_argsort"]


class Dataset(NamedTuple):
    """A generated record workload plus its device-ready word matrix."""

    name: str
    records: Any          # list[bytes] (strings) or tuple of column arrays
    words: np.ndarray     # (n, W) uint32, word 0 most significant
    spec: keyspace.WordSpec
    payload: np.ndarray   # (n,) int32 row ids — the permutation carrier


def _sky(rng: np.random.Generator, n: int) -> Tuple[np.ndarray, ...]:
    # SDSS-like photometric records: right ascension binned to 0.1 degree
    # (tie-heavy word 0), declination and magnitude at full precision
    ra = np.round(rng.uniform(0.0, 360.0, n), 1).astype(np.float32)
    dec = rng.uniform(-90.0, 90.0, n).astype(np.float32)
    mag = np.clip(rng.normal(20.0, 2.0, n), 10.0, 30.0).astype(np.float32)
    return (ra, dec, mag)


_RNA_LETTERS = np.frombuffer(b"ACGU", dtype=np.uint8)


def _rna(rng: np.random.Generator, n: int) -> List[bytes]:
    lens = rng.integers(8, 33, n) if n else np.zeros(0, np.int64)
    offs = np.concatenate([[0], np.cumsum(lens)])
    flat = _RNA_LETTERS[rng.integers(0, 4, int(offs[-1]))]
    return [flat[offs[i] : offs[i + 1]].tobytes() for i in range(n)]


_HOSTS = [
    "example.com", "cdn.example.com", "api.example.com", "img.example.com",
    "shop.example.com", "docs.example.com", "m.example.com", "eu.example.com",
]
_SEGMENTS = [
    "v1", "v2", "users", "items", "assets", "img", "static", "data",
    "search", "docs", "a", "b", "42", "7",
]


def _zipf_p(k: int, a: float = 1.2) -> np.ndarray:
    p = 1.0 / np.arange(1, k + 1) ** a
    return p / p.sum()


def _urls(rng: np.random.Generator, n: int) -> List[bytes]:
    hosts = rng.choice(len(_HOSTS), n, p=_zipf_p(len(_HOSTS)))
    depths = rng.integers(0, 4, n)
    segs = rng.choice(len(_SEGMENTS), (n, 3), p=_zipf_p(len(_SEGMENTS)))
    out = []
    for i in range(n):
        path = "".join("/" + _SEGMENTS[s] for s in segs[i, : depths[i]]) or "/"
        out.append(f"https://{_HOSTS[hosts[i]]}{path}".encode())
    return out


def _tenants(rng: np.random.Generator, n: int) -> Tuple[np.ndarray, ...]:
    tenant = rng.choice(1024, n, p=_zipf_p(1024)).astype(np.uint32)
    priority = rng.integers(0, 8, n).astype(np.uint8)
    arrival = rng.permutation(n).astype(np.uint32)  # unique: no full-row ties
    return (tenant, priority, arrival)


DATASETS: Dict[str, Callable[[np.random.Generator, int], Any]] = {
    "SkySurvey": _sky,
    "RnaSequences": _rna,
    "UrlPaths": _urls,
    "TenantTuples": _tenants,
}


def make_dataset(
    name: str, n: int, seed: int = 0, width: Optional[int] = None
) -> Dataset:
    """Generate dataset ``name`` with ``n`` records, deterministically from
    ``seed``.  ``width`` clips string records to a byte budget (fewer
    words => fewer tie-break passes *and* heavier ties — tests use it to
    bound compile cost while stressing the tie schedule); it is ignored
    for composite-column families, whose width is fixed by the dtypes.
    """
    records = DATASETS[name](np.random.default_rng(seed), n)
    if isinstance(records, list) and width is not None:
        records = [r[:width] for r in records]
        words, spec = keyspace.encode_words(records, width=width)
    else:
        words, spec = keyspace.encode_words(records)
    return Dataset(
        name=name,
        records=records,
        words=words,
        spec=spec,
        payload=np.arange(n, dtype=np.int32),
    )


def oracle_argsort(ds: Dataset) -> np.ndarray:
    """The canonical stable sort order of the dataset's records, computed
    *independently* of the word encoding: byte-string argsort for string
    families, ``np.lexsort`` over the raw columns for composite families
    (generators never emit NaN or -0.0, where IEEE and keyspace order
    would diverge).  ``ops.argsort_records(ds.words)`` must bit-match.
    """
    if ds.spec.kind == "bytes":
        maxlen = max((len(r) for r in ds.records), default=0)
        arr = np.array(ds.records, dtype=f"S{max(1, maxlen)}")
        return np.argsort(arr, kind="stable")
    return np.lexsort(tuple(reversed(ds.records)))
