"""Distributed training loop: step function factory + fault-tolerant driver.

``make_train_step`` builds the jitted (donated, sharded) step:

  * microbatched gradient accumulation via ``lax.scan`` (bounds live
    activation memory: the 126-layer archs at 4k seq do not fit without it);
  * per-layer remat inside the model (cfg.remat);
  * optional int8 error-feedback gradient compression applied right before
    the (implicit, GSPMD-inserted) DP reduction;
  * AdamW with memory-tiered moments; LR schedule baked in.

``Trainer`` is the production driver: checkpoint/restart (atomic + async),
straggler detection via a per-step wall-time ledger (p95-based deadline), a
step-skip path for lost batches, preemption-signal save.  Elastic rescale
happens at restore time (checkpoint stores logical specs; see
checkpoint/manager.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.checkpoint.manager import CheckpointManager
from repro.launch.shardings import (
    ShardingStrategy, batch_specs, named, param_specs,
)
from repro.models.transformer import init_model, train_loss
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import (
    compress_grads, decompress_grads, init_error_feedback,
)
from repro.optim.schedule import linear_warmup_cosine

__all__ = ["TrainConfig", "make_train_step", "Trainer"]


@dataclass(frozen=True)
class TrainConfig:
    microbatch: int = 0            # 0 = no accumulation (single shot)
    warmup_steps: int = 100
    total_steps: int = 1000
    compress_grads: bool = False   # int8 error-feedback DP all-reduce
    adamw: AdamWConfig = field(default_factory=AdamWConfig)
    lb_coef: float = 0.01          # MoE load-balance coefficient


def _accumulate_grads(cfg: ModelConfig, tcfg: TrainConfig, params, batch):
    """Microbatched loss+grad; returns (loss, metrics, grads)."""
    gb = batch["labels"].shape[0]
    mb = tcfg.microbatch or gb
    assert gb % mb == 0, f"global batch {gb} % microbatch {mb}"
    steps = gb // mb

    def loss_fn(p, b):
        return train_loss(p, cfg, b, lb_coef=tcfg.lb_coef)

    if steps == 1:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        return loss, metrics, grads

    resh = jax.tree.map(lambda a: a.reshape((steps, mb) + a.shape[1:]), batch)

    def body(carry, mbatch):
        acc, loss_acc = carry
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, mbatch
        )
        acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
        return (acc, loss_acc + loss), metrics

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (gsum, loss_sum), metrics = jax.lax.scan(body, (zeros, 0.0), resh)
    grads = jax.tree.map(lambda g: g / steps, gsum)
    loss = loss_sum / steps
    metrics = jax.tree.map(lambda m: m[-1], metrics)
    return loss, metrics, grads


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh,
                    strat: ShardingStrategy = ShardingStrategy(),
                    params_like: Any = None, batch_like: Any = None):
    """Returns (jitted step, state_shardings).  step(state, batch) -> (state,
    metrics).  state = {params, opt, eff?}.

    Pass ``batch_like`` (ShapeDtypeStructs) to pin the batch in_shardings at
    jit time — REQUIRED for embed-input archs (vlm/audio): without it GSPMD
    may replicate the (B, S, D) embed batch per device (17 GB/dev for
    internvl2 train_4k) instead of dp-sharding it."""

    def step(state, batch):
        params = state["params"]
        loss, metrics, grads = _accumulate_grads(cfg, tcfg, params, batch)
        if tcfg.compress_grads:
            comp, new_eff = compress_grads(grads, state["eff"])
            grads = decompress_grads(comp, grads)
        lr_scale = linear_warmup_cosine(
            state["opt"]["step"], tcfg.warmup_steps, tcfg.total_steps
        )
        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, state["opt"], tcfg.adamw, lr_scale
        )
        metrics = {**metrics, **opt_metrics, "loss": loss}
        new_state = {"params": new_params, "opt": new_opt}
        if tcfg.compress_grads:
            new_state["eff"] = new_eff
        return new_state, metrics

    if params_like is None:
        params_like = jax.eval_shape(
            lambda: init_model(jax.random.PRNGKey(0), cfg)
        )
    pspecs = param_specs(params_like, cfg, mesh, strat)
    opt_like = jax.eval_shape(lambda p: adamw_init(p, tcfg.adamw), params_like)
    ospecs = _opt_specs(opt_like, pspecs)
    state_specs = {"params": pspecs, "opt": ospecs}
    if tcfg.compress_grads:
        state_specs["eff"] = pspecs
    state_sh = named(mesh, state_specs)

    def in_batch_sh(bl):
        return named(mesh, batch_specs(cfg, mesh, bl))

    batch_sh = in_batch_sh(batch_like) if batch_like is not None else None
    stepf = jax.jit(
        step,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
        donate_argnums=(0,),
    )
    return stepf, state_sh, in_batch_sh


def _opt_specs(opt_like, pspecs):
    """Moments are congruent to params except int8 {q, scale} leaves."""
    from jax.sharding import PartitionSpec as P

    def per_moment(mtree):
        def f(spec, leaf):
            if isinstance(leaf, dict) and "q" in leaf:
                return {"q": spec, "scale": P()}
            return spec
        return jax.tree.map(
            f, pspecs, mtree,
            is_leaf=lambda x: isinstance(x, dict) and "q" in x,
        )

    return {
        "m": per_moment(opt_like["m"]),
        "v": per_moment(opt_like["v"]),
        "step": P(),
    }


class Trainer:
    """Fault-tolerant driver around the jitted step."""

    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, mesh,
                 ckpt_dir: Optional[str] = None, seed: int = 0,
                 strat: ShardingStrategy = ShardingStrategy()):
        self.cfg, self.tcfg, self.mesh = cfg, tcfg, mesh
        self.step_fn, self.state_sh, self._batch_sh = make_train_step(
            cfg, tcfg, mesh, strat
        )
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.seed = seed
        self.step_times: list = []  # straggler ledger
        self.state: Any = None
        self.step_num = 0

    def init_state(self):
        params = jax.jit(
            lambda k: init_model(k, self.cfg),
            out_shardings=self.state_sh["params"],
        )(jax.random.PRNGKey(self.seed))
        opt = jax.jit(
            lambda p: adamw_init(p, self.tcfg.adamw),
            out_shardings=self.state_sh["opt"],
        )(params)
        self.state = {"params": params, "opt": opt}
        if self.tcfg.compress_grads:
            self.state["eff"] = jax.jit(
                init_error_feedback, out_shardings=self.state_sh["params"]
            )(params)
        return self.state

    def maybe_restore(self) -> bool:
        """Resume from the newest complete checkpoint (elastic re-layout onto
        the current mesh).  Returns True if restored."""
        if self.ckpt is None:
            return False
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self.state
        ) if self.state is not None else None
        if like is None:
            self.init_state()
            like = self.state
        self.state = self.ckpt.restore(latest, like, self.state_sh)
        self.step_num = latest
        return True

    def straggler_deadline(self) -> Optional[float]:
        """p95 * 3 of recent step times — steps exceeding it are flagged."""
        if len(self.step_times) < 5:
            return None
        return float(np.percentile(self.step_times[-50:], 95)) * 3.0

    def run(self, data_iter, num_steps: int, ckpt_every: int = 100,
            log_every: int = 10, log=print) -> Dict[str, float]:
        last_metrics: Dict[str, float] = {}
        deadline = None
        for _ in range(num_steps):
            batch = next(data_iter)
            batch = jax.device_put(batch, self._batch_sh(batch))
            t0 = time.perf_counter()
            self.state, metrics = self.step_fn(self.state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.step_times.append(dt)
            if deadline and dt > deadline:
                log(f"[straggler] step {self.step_num} took {dt:.2f}s "
                    f"(deadline {deadline:.2f}s) — flagged")
            deadline = self.straggler_deadline()
            self.step_num += 1
            if self.step_num % log_every == 0:
                last_metrics = {k: float(v) for k, v in metrics.items()}
                log(f"step {self.step_num}: " + " ".join(
                    f"{k}={v:.4g}" for k, v in last_metrics.items()))
            if self.ckpt and self.step_num % ckpt_every == 0:
                self.ckpt.save(self.step_num, self.state, blocking=False)
        if self.ckpt:
            self.ckpt.save(self.step_num, self.state, blocking=True)
        if not last_metrics:
            last_metrics = {k: float(v) for k, v in metrics.items()}
        return last_metrics
