from repro.train.trainer import TrainConfig, Trainer, make_train_step
