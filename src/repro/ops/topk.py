"""Splitter-based partial sort: top-k / bottom-k cheaper than a full sort.

The full sort is (level passes) + (base case over *every* window).  For
rank-k queries only the buckets covering ranks [0, k) need their base case:
after the level passes buckets are contiguous and in key order, so the k
smallest elements all live inside the prefix that ends with the bucket
containing rank k-1.  We therefore run the same classify/partition passes
and then base-case-sort only a static, W-aligned prefix

    P = ceil((k + W) / W) * W        (W = cfg.base_case)

which is guaranteed to cover that bucket whenever the base-case
precondition holds (every non-trivial bucket <= W/2: a bucket starting
before rank k ends before k + W/2 <= P - W/2; equality buckets may cross P
but hold identical keys and need no sorting).  The data-dependent
robustness fallback (``lax.cond`` -> full stable sort) guards the
precondition exactly as in the full sort, restricted to buckets that
intersect the prefix.  Work saved: all base-case windows beyond P — the
dominant term for k << n (see ``benchmarks/sort_ops.py``).

``topk`` (largest-k) reuses the ascending machinery through the keyspace
complement: ``~encode(x)`` reverses the total order, so the bottom-k of
the complemented keys are the top-k of the originals.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.ips4o import (
    SortConfig,
    base_case,
    bucket_violations,
    pad_with_sentinel,
    partition_passes,
    plan_levels,
    segment_ids,
    stable_full_sort,
)
from repro.ops import keyspace

__all__ = ["topk", "bottomk", "smallest_encoded"]


def _prefix_limit(k: int, W: int, n_pad: int) -> int:
    """Static W-aligned prefix length covering the bucket of rank k-1."""
    return min(n_pad, -(-(k + W) // W) * W)


def smallest_encoded(
    enc: jax.Array, kk: int, cfg: SortConfig
) -> Tuple[jax.Array, jax.Array]:
    """(sorted k smallest encoded keys, their original indices).

    ``enc`` must be in the ordered-uint keyspace; ``0 < kk <= n`` static.
    This is the splitter-filter primitive ``repro.dist`` reuses as the
    per-shard candidate filter of the distributed rank-k query.
    """
    n = enc.shape[0]
    arrays = {"k": enc, "v": jnp.arange(n, dtype=jnp.int32)}
    unit = max(cfg.base_case, cfg.tile)
    arrays = pad_with_sentinel(arrays, unit)
    n_pad = arrays["k"].shape[0]
    W = cfg.base_case
    levels = plan_levels(n_pad, cfg)

    if not levels:
        arrays = stable_full_sort(arrays)
        return arrays["k"][:kk], arrays["v"][:kk]

    arrays, offsets, nb, pad_bucket = partition_passes(arrays, n, cfg, levels)
    P = _prefix_limit(kk, W, n_pad)
    fb = segment_ids(offsets, n_pad)
    violated = bucket_violations(offsets, nb, W, pad_bucket, limit=P)

    run = lambda a: base_case(a, fb, W, limit=P)
    if cfg.fallback:
        arrays = jax.lax.cond(violated, stable_full_sort, run, arrays)
    else:
        arrays = run(arrays)
    return arrays["k"][:kk], arrays["v"][:kk]


def bottomk(
    keys: jax.Array,
    k: int,
    *,
    cfg: SortConfig = SortConfig(),
    engine: Optional[str] = None,
    classifier: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """The ``k`` smallest keys in ascending order, with their indices.

    Returns (values, indices), each of length ``min(k, n)`` (k >= n degrades
    to a full sort).  NaN-safe via the keyspace encoding: NaN is the
    *maximum* of the total order, so ``bottomk`` only yields NaNs once
    every non-NaN key is taken (and, symmetrically, ``topk`` yields them
    first — the ``lax.top_k`` convention).

    >>> import jax.numpy as jnp
    >>> vals, idx = bottomk(jnp.asarray([4.0, 1.0, 3.0]), 2)
    >>> vals.tolist()
    [1.0, 3.0]
    >>> idx.tolist()
    [1, 2]
    """
    n = keys.shape[0]
    if keys.ndim != 1:
        raise ValueError("keys must be 1-D")
    from repro.ops.sort import with_engine

    kk = max(0, min(int(k), n))
    if kk == 0:
        return keys[:0], jnp.zeros((0,), jnp.int32)
    out, idx = smallest_encoded(
        keyspace.encode(keys), kk, with_engine(cfg, engine, keys, classifier)
    )
    return keyspace.decode(out, keys.dtype), idx


def topk(
    keys: jax.Array,
    k: int,
    *,
    cfg: SortConfig = SortConfig(),
    engine: Optional[str] = None,
    classifier: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """The ``k`` largest keys in descending order, with their indices.

    Same contract as ``jax.lax.top_k`` (modulo tie order); implemented as
    bottom-k of the complemented encoded keys — ``~u`` reverses the
    keyspace total order, so no descending variant of the engine is needed.

    >>> import jax.numpy as jnp
    >>> vals, idx = topk(jnp.asarray([1.0, 9.0, 3.0, 7.0]), 2)
    >>> vals.tolist()
    [9.0, 7.0]
    >>> idx.tolist()
    [1, 3]
    """
    n = keys.shape[0]
    if keys.ndim != 1:
        raise ValueError("keys must be 1-D")
    from repro.ops.sort import with_engine

    kk = max(0, min(int(k), n))
    if kk == 0:
        return keys[:0], jnp.zeros((0,), jnp.int32)
    out, idx = smallest_encoded(
        ~keyspace.encode(keys), kk, with_engine(cfg, engine, keys, classifier)
    )
    return keyspace.decode(~out, keys.dtype), idx
