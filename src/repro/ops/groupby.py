"""Sort-derived grouping ops: unique / run_length / group_by.

All three are "sort plus boundary extraction" (DESIGN.md §5.3).  The §4.4
equality buckets make the sort side cheap on duplicate-heavy inputs — a
run of identical keys lands in one equality bucket and is never base-case
sorted — which is exactly the regime grouping ops live in.

Static shapes: JAX cannot return data-dependent lengths, so the per-group
outputs (``unique`` values, counts, run lengths) come back padded to n
with a scalar count of the valid prefix, mirroring the static-shape
conventions used elsewhere in the repo (e.g. ``repro.dist``).

``group_by`` has three interchangeable engines:
  * ``"partition"`` — keys are small ints in [0, num_groups): one stable
    block partition (``core.partition``), no sampling, exact buckets.
    This is the MoE-dispatch path (``models.moe.sort_dispatch``).
  * ``"pallas"``    — same contract, ranks computed by the fused
    ``kernels.dispatch_rank`` kernel (one pass, SMEM running counters).
  * ``"sort"``      — arbitrary keys: full IPS4o sort + boundary scan.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.ips4o import SortConfig, ips4o_sort
from repro.core.partition import partition_permutation
from repro.ops import keyspace

__all__ = ["Groups", "group_by", "unique", "run_length"]


class Groups(NamedTuple):
    """Result of :func:`group_by`; positions are grouped key-ascending."""

    keys: jax.Array            # (n,) grouped keys
    values: Any                # grouped payload pytree (None if not given)
    group_ids: jax.Array       # (n,) group index of each grouped position
    counts: jax.Array          # (num_groups,) exact, or (n,) padded for "sort"
    num_groups: Union[int, jax.Array]  # static int, or traced scalar for "sort"
    perm: jax.Array            # (n,) source index of each grouped position


def _boundaries(enc_sorted: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(group id per position, num groups) from sorted encoded keys."""
    n = enc_sorted.shape[0]
    mask = jnp.concatenate(
        [jnp.ones((1,), jnp.bool_), enc_sorted[1:] != enc_sorted[:-1]]
    )
    gid = jnp.cumsum(mask).astype(jnp.int32) - 1
    return gid, gid[-1] + 1


def _int_group_perm(
    keys: jax.Array, num_groups: int, method: str, tile: int
) -> Tuple[jax.Array, jax.Array]:
    """(perm, offsets) grouping small-int keys; both engines are stable."""
    n = keys.shape[0]
    b = keys.astype(jnp.int32)
    if method == "pallas":
        from repro.kernels.dispatch_rank import LANES, dispatch_ranks

        unit = 8 * LANES
        n_pad = -(-n // unit) * unit
        # pad ids into an extra trash group so the kernel sees a full grid
        ids = jnp.full((n_pad,), num_groups, jnp.int32).at[:n].set(b)
        counts = jnp.bincount(b, length=num_groups)
        offsets = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts).astype(jnp.int32)]
        )
        start = jnp.concatenate([offsets[:-1], jnp.full((1,), n, jnp.int32)])
        dest = dispatch_ranks(ids, start, num_experts=num_groups + 1)
        perm = (
            jnp.zeros((n_pad,), jnp.int32)
            .at[dest]
            .set(jnp.arange(n_pad, dtype=jnp.int32), mode="promise_in_bounds")
        )
        return perm[:n], offsets
    t = min(tile, n)
    if n % t:
        t = n  # single tile fallback for odd sizes (as in models.moe)
    return partition_permutation(b, num_groups, t)


def group_by(
    keys: jax.Array,
    values: Any = None,
    *,
    num_groups: Optional[int] = None,
    method: str = "auto",
    tile: int = 2048,
    cfg: SortConfig = SortConfig(),
) -> Groups:
    """Group elements by key, key-ascending, stably within a group.

    With ``num_groups`` (keys are ints in [0, num_groups)) the grouping is
    a single stable block partition — or the fused Pallas ranking kernel
    with ``method="pallas"`` — and ``counts``/``num_groups`` are exact and
    static.  Without it, keys are arbitrary (``method="sort"``): a full
    NaN-safe sort groups equal keys, ``counts`` comes back (n,)-padded and
    ``num_groups`` is a traced scalar.

    >>> import jax.numpy as jnp
    >>> g = group_by(jnp.asarray([2, 0, 2, 1]), num_groups=3)
    >>> g.keys.tolist()
    [0, 1, 2, 2]
    >>> g.counts.tolist()
    [1, 1, 2]
    >>> g.perm.tolist()  # stable within a group
    [1, 3, 0, 2]
    """
    n = keys.shape[0]
    if keys.ndim != 1:
        raise ValueError("keys must be 1-D")
    if method == "auto":
        method = "partition" if num_groups is not None else "sort"
    if method in ("partition", "pallas"):
        if num_groups is None:
            raise ValueError(f"method={method!r} requires num_groups")
        if n == 0:
            return Groups(
                keys, values, jnp.zeros((0,), jnp.int32),
                jnp.zeros((num_groups,), jnp.int32), num_groups,
                jnp.zeros((0,), jnp.int32),
            )
        perm, offsets = _int_group_perm(keys, num_groups, method, tile)
        gk = jnp.take(keys, perm, axis=0)
        gv = (
            None
            if values is None
            else jax.tree.map(lambda a: jnp.take(a, perm, axis=0), values)
        )
        return Groups(
            keys=gk,
            values=gv,
            group_ids=gk.astype(jnp.int32),
            counts=jnp.diff(offsets),
            num_groups=num_groups,
            perm=perm,
        )
    if method != "sort":
        raise ValueError(f"unknown group_by method {method!r}")
    if n == 0:
        return Groups(
            keys, values, jnp.zeros((0,), jnp.int32),
            jnp.zeros((0,), jnp.int32), jnp.zeros((), jnp.int32),
            jnp.zeros((0,), jnp.int32),
        )
    enc = keyspace.encode(keys)
    payload = {"i": jnp.arange(n, dtype=jnp.int32)}
    if values is not None:
        payload["v"] = values
    enc_sorted, out = ips4o_sort(enc, payload, cfg=cfg)
    perm = out["i"]
    gid, num = _boundaries(enc_sorted)
    counts = jnp.zeros((n,), jnp.int32).at[gid].add(1, mode="promise_in_bounds")
    return Groups(
        keys=keyspace.decode(enc_sorted, keys.dtype),
        values=out.get("v"),
        group_ids=gid,
        counts=counts,
        num_groups=num,
        perm=perm,
    )


def unique(
    keys: jax.Array, *, cfg: SortConfig = SortConfig()
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Distinct keys, ascending.  Returns (values, counts, num_unique):
    ``values``/``counts`` are (n,)-padded, valid for the first
    ``num_unique`` entries (entries beyond that are unspecified).

    >>> import jax.numpy as jnp
    >>> vals, counts, num = unique(jnp.asarray([3, 1, 3, 1, 1]))
    >>> int(num)
    2
    >>> (vals[:2].tolist(), counts[:2].tolist())
    ([1, 3], [3, 2])
    """
    n = keys.shape[0]
    if n == 0:
        return keys, jnp.zeros((0,), jnp.int32), jnp.zeros((), jnp.int32)
    enc = keyspace.encode(keys)
    enc_sorted = ips4o_sort(enc, cfg=cfg)
    gid, num = _boundaries(enc_sorted)
    vals = (
        jnp.zeros((n,), enc_sorted.dtype)
        .at[gid]
        .set(enc_sorted, mode="promise_in_bounds")
    )
    counts = jnp.zeros((n,), jnp.int32).at[gid].add(1, mode="promise_in_bounds")
    return keyspace.decode(vals, keys.dtype), counts, num


def run_length(
    keys: jax.Array, *, cfg: SortConfig = SortConfig()
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Run-length encoding of *consecutive* equal keys (no sorting).

    Returns (values, lengths, num_runs), (n,)-padded like :func:`unique`
    (entries beyond num_runs are unspecified).
    Equality is keyspace equality, so NaN runs and -0.0/+0.0 behave
    deterministically (NaN == NaN, -0.0 != +0.0).

    >>> import jax.numpy as jnp
    >>> vals, lens, num = run_length(jnp.asarray([5, 5, 2, 2, 2, 5]))
    >>> int(num)
    3
    >>> (vals[:3].tolist(), lens[:3].tolist())
    ([5, 2, 5], [2, 3, 1])
    """
    n = keys.shape[0]
    if n == 0:
        return keys, jnp.zeros((0,), jnp.int32), jnp.zeros((), jnp.int32)
    enc = keyspace.encode(keys)
    rid, num = _boundaries(enc)  # runs are "groups" of the unsorted stream
    vals = jnp.zeros((n,), enc.dtype).at[rid].set(enc, mode="promise_in_bounds")
    lengths = jnp.zeros((n,), jnp.int32).at[rid].add(1, mode="promise_in_bounds")
    return keyspace.decode(vals, keys.dtype), lengths, num
