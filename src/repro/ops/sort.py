"""NaN-safe full sort and argsort on top of the IPS4o engine.

These are thin compositions: biject keys into the ordered uint space
(``ops.keyspace``), run ``ips4o_sort`` there (where ``>`` / ``==`` are a
total order, so the documented NaN limitation disappears), and decode.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.ips4o import SortConfig, ips4o_sort
from repro.ops import keyspace

__all__ = ["sort", "argsort"]


def sort(keys: jax.Array, values: Any = None, *, cfg: SortConfig = SortConfig()):
    """Sort ``keys`` ascending (NaNs last, -0.0 before +0.0), optionally
    permuting a ``values`` pytree alongside.  Jit-compatible."""
    enc = keyspace.encode(keys)
    if values is None:
        out = ips4o_sort(enc, cfg=cfg)
        return keyspace.decode(out, keys.dtype)
    out, vs = ips4o_sort(enc, values, cfg=cfg)
    return keyspace.decode(out, keys.dtype), vs


def argsort(keys: jax.Array, *, cfg: SortConfig = SortConfig()) -> jax.Array:
    """Indices that sort ``keys`` ascending: ``keys[argsort(keys)]`` is
    sorted.  The index payload rides the existing values-pytree threading;
    ties are in arbitrary (but deterministic) order."""
    n = keys.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    if n <= 1:
        return idx
    _, order = ips4o_sort(keyspace.encode(keys), idx, cfg=cfg)
    return order
