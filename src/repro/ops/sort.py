"""NaN-safe full sort and argsort on top of the IPS4o engine.

These are thin compositions: biject keys into the ordered uint space
(``ops.keyspace``), run ``ips4o_sort`` there (where ``>`` / ``==`` are a
total order, so the documented NaN limitation disappears), and decode.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.classify import resolve_classifier
from repro.core.ips4o import SortConfig, ips4o_sort, resolve_engine
from repro.ops import keyspace

__all__ = ["sort", "argsort", "with_engine"]


def with_engine(
    cfg: SortConfig,
    engine: Optional[str],
    keys: Optional[jax.Array] = None,
    classifier: Optional[str] = None,
) -> SortConfig:
    """Override the partition engine and/or classifier on a config (None
    keeps the cfg's value).

    When ``keys`` is given, "auto" (for either knob) is resolved HERE —
    against the caller's original (n, dtype), which is what the plan cache
    keys tuned plans under.  Deeper layers see the keyspace-encoded dtype
    and the padded n, so resolving any later would never match a persisted
    plan.

    >>> with_engine(SortConfig(), "pallas").engine
    'pallas'
    >>> with_engine(SortConfig(engine="pallas"), None).engine
    'pallas'
    >>> with_engine(SortConfig(), None, classifier="radix").classifier
    'radix'
    """
    cfg = cfg if engine is None else replace(cfg, engine=engine)
    if classifier is not None:
        cfg = replace(cfg, classifier=classifier)
    if keys is not None:
        if cfg.engine == "auto":
            cfg = replace(
                cfg, engine=resolve_engine(cfg, keys.shape[0], keys.dtype)
            )
        if cfg.classifier == "auto":
            cfg = replace(
                cfg,
                classifier=resolve_classifier(
                    "auto", keys.shape[0], keys.dtype
                ),
            )
    return cfg


def sort(
    keys: jax.Array,
    values: Any = None,
    *,
    cfg: SortConfig = SortConfig(),
    engine: Optional[str] = None,
    classifier: Optional[str] = None,
):
    """Sort ``keys`` ascending (NaNs last, -0.0 before +0.0), optionally
    permuting a ``values`` pytree alongside.  Jit-compatible.  ``engine``
    ("xla" | "pallas" | "auto") overrides ``cfg.engine`` for this call;
    ``classifier`` ("tree" | "radix" | "learned" | "auto") overrides
    ``cfg.classifier`` the same way (DESIGN.md §9).

    >>> import jax.numpy as jnp
    >>> sort(jnp.asarray([3.0, 1.0, 2.0])).tolist()
    [1.0, 2.0, 3.0]
    >>> k, v = sort(jnp.asarray([2, 1]), {"tag": jnp.asarray([20, 10])})
    >>> (k.tolist(), v["tag"].tolist())  # payload rows follow their keys
    ([1, 2], [10, 20])
    """
    cfg = with_engine(cfg, engine, keys, classifier)
    enc = keyspace.encode(keys)
    if values is None:
        out = ips4o_sort(enc, cfg=cfg)
        return keyspace.decode(out, keys.dtype)
    out, vs = ips4o_sort(enc, values, cfg=cfg)
    return keyspace.decode(out, keys.dtype), vs


def argsort(
    keys: jax.Array,
    *,
    cfg: SortConfig = SortConfig(),
    engine: Optional[str] = None,
    classifier: Optional[str] = None,
) -> jax.Array:
    """Indices that sort ``keys`` ascending: ``keys[argsort(keys)]`` is
    sorted.  The index payload rides the existing values-pytree threading;
    ties are in arbitrary (but deterministic) order.

    >>> import jax.numpy as jnp
    >>> argsort(jnp.asarray([30.0, 10.0, 20.0])).tolist()
    [1, 2, 0]
    """
    n = keys.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    if n <= 1:
        return idx
    _, order = ips4o_sort(
        keyspace.encode(keys), idx, cfg=with_engine(cfg, engine, keys, classifier)
    )
    return order
