"""NaN-safe full sort and argsort on top of the IPS4o engine.

These are thin compositions: biject keys into the ordered uint space
(``ops.keyspace``), run ``ips4o_sort`` there (where ``>`` / ``==`` are a
total order, so the documented NaN limitation disappears), and decode.

``sort_records`` / ``argsort_records`` extend the same composition to
multi-word keys (strings and composite records decomposed by
``keyspace.encode_words``, DESIGN.md §11): word 0 is sorted outright and
the runs that tie are re-sorted word by word through the MSD tie-break
schedule (``core.ips4o.tiebreak_passes``), with the engine and classifier
seams threaded through every pass — the radix classifier is the natural
winner on prefix words (the high bits of a pass's composite run structure
are exactly what it buckets on), and ``classifier="auto"`` routes through
the racing plan-cache router like every other op.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.classify import resolve_classifier
from repro.core.ips4o import SortConfig, ips4o_sort, resolve_engine, tiebreak_passes
from repro.ops import keyspace

__all__ = ["sort", "argsort", "sort_records", "argsort_records", "with_engine"]


def with_engine(
    cfg: SortConfig,
    engine: Optional[str],
    keys: Optional[jax.Array] = None,
    classifier: Optional[str] = None,
) -> SortConfig:
    """Override the partition engine and/or classifier on a config (None
    keeps the cfg's value).

    When ``keys`` is given, "auto" (for either knob) is resolved HERE —
    against the caller's original (n, dtype), which is what the plan cache
    keys tuned plans under.  Deeper layers see the keyspace-encoded dtype
    and the padded n, so resolving any later would never match a persisted
    plan.

    >>> with_engine(SortConfig(), "pallas").engine
    'pallas'
    >>> with_engine(SortConfig(engine="pallas"), None).engine
    'pallas'
    >>> with_engine(SortConfig(), None, classifier="radix").classifier
    'radix'
    """
    cfg = cfg if engine is None else replace(cfg, engine=engine)
    if classifier is not None:
        cfg = replace(cfg, classifier=classifier)
    if keys is not None:
        if cfg.engine == "auto":
            cfg = replace(
                cfg, engine=resolve_engine(cfg, keys.shape[0], keys.dtype)
            )
        if cfg.classifier == "auto":
            cfg = replace(
                cfg,
                classifier=resolve_classifier(
                    "auto", keys.shape[0], keys.dtype
                ),
            )
    return cfg


def sort(
    keys: jax.Array,
    values: Any = None,
    *,
    cfg: SortConfig = SortConfig(),
    engine: Optional[str] = None,
    classifier: Optional[str] = None,
):
    """Sort ``keys`` ascending (NaNs last, -0.0 before +0.0), optionally
    permuting a ``values`` pytree alongside.  Jit-compatible.  ``engine``
    ("xla" | "pallas" | "auto") overrides ``cfg.engine`` for this call;
    ``classifier`` ("tree" | "radix" | "learned" | "auto") overrides
    ``cfg.classifier`` the same way (DESIGN.md §9).

    >>> import jax.numpy as jnp
    >>> sort(jnp.asarray([3.0, 1.0, 2.0])).tolist()
    [1.0, 2.0, 3.0]
    >>> k, v = sort(jnp.asarray([2, 1]), {"tag": jnp.asarray([20, 10])})
    >>> (k.tolist(), v["tag"].tolist())  # payload rows follow their keys
    ([1, 2], [10, 20])
    """
    cfg = with_engine(cfg, engine, keys, classifier)
    with obs.trace(
        "ops.sort", n=keys.shape[0], dtype=str(keys.dtype), engine=cfg.engine
    ):
        enc = keyspace.encode(keys)
        if values is None:
            out = keyspace.decode(ips4o_sort(enc, cfg=cfg), keys.dtype)
        else:
            k, vs = ips4o_sort(enc, values, cfg=cfg)
            out = (keyspace.decode(k, keys.dtype), vs)
        obs.block(out)  # eager path: the span measures real execution
    return out


def argsort(
    keys: jax.Array,
    *,
    cfg: SortConfig = SortConfig(),
    engine: Optional[str] = None,
    classifier: Optional[str] = None,
) -> jax.Array:
    """Indices that sort ``keys`` ascending: ``keys[argsort(keys)]`` is
    sorted.  The index payload rides the existing values-pytree threading;
    ties are in arbitrary (but deterministic) order.

    >>> import jax.numpy as jnp
    >>> argsort(jnp.asarray([30.0, 10.0, 20.0])).tolist()
    [1, 2, 0]
    """
    n = keys.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    if n <= 1:
        return idx
    cfg = with_engine(cfg, engine, keys, classifier)
    with obs.trace("ops.argsort", n=n, dtype=str(keys.dtype), engine=cfg.engine):
        _, order = ips4o_sort(keyspace.encode(keys), idx, cfg=cfg)
        obs.block(order)
    return order


def _check_words(words: jax.Array) -> jax.Array:
    words = jnp.asarray(words)
    if words.ndim != 2:
        raise ValueError("words must be 2-D (n, W)")
    if words.shape[1] == 0:
        raise ValueError("words must have at least one word column")
    return words


def _record_cols(words: jax.Array) -> Tuple[jax.Array, ...]:
    return tuple(keyspace.encode(words[:, j]) for j in range(words.shape[1]))


def sort_records(
    words: jax.Array,
    values: Any = None,
    *,
    cfg: SortConfig = SortConfig(),
    engine: Optional[str] = None,
    classifier: Optional[str] = None,
):
    """Sort multi-word records (n, W) into row-lexicographic order.

    ``words`` is the fixed-width word decomposition of each record —
    usually ``keyspace.encode_words`` output (uint32, word 0 most
    significant), but any supported dtype works (each word column is
    keyspace-encoded, so float/signed words order naturally, NaNs last).
    The sort is **stable**: equal records keep their input order, and the
    implied permutation is bit-identical to ``np.lexsort`` over the
    columns.  A ``values`` pytree (leaves with leading dim n) is permuted
    alongside.  Jit-compatible; ``engine`` / ``classifier`` thread through
    every tie-break pass (DESIGN.md §11).

    >>> import jax.numpy as jnp
    >>> w = jnp.asarray([[1, 9], [0, 5], [1, 2]], jnp.uint32)
    >>> sort_records(w).tolist()  # row-lexicographic
    [[0, 5], [1, 2], [1, 9]]
    """
    words = _check_words(words)
    n = words.shape[0]
    if n <= 1:
        return words if values is None else (words, values)
    cfg = with_engine(cfg, engine, words[:, 0], classifier)
    cols, vals = tiebreak_passes(_record_cols(words), values, cfg=cfg)
    out = jnp.stack(
        [keyspace.decode(c, words.dtype) for c in cols], axis=1
    )
    return out if values is None else (out, vals)


def argsort_records(
    words: jax.Array,
    *,
    cfg: SortConfig = SortConfig(),
    engine: Optional[str] = None,
    classifier: Optional[str] = None,
) -> jax.Array:
    """Stable lexicographic argsort of multi-word records (n, W):
    ``words[argsort_records(words)]`` is row-sorted, and the permutation
    is bit-identical to ``np.lexsort`` over the word columns (ties keep
    input order).

    >>> import jax.numpy as jnp
    >>> w = jnp.asarray([[1, 9], [0, 5], [1, 2]], jnp.uint32)
    >>> argsort_records(w).tolist()
    [1, 2, 0]
    """
    words = _check_words(words)
    n = words.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    if n <= 1:
        return idx
    cfg = with_engine(cfg, engine, words[:, 0], classifier)
    _, order = tiebreak_passes(_record_cols(words), idx, cfg=cfg)
    return order
