"""repro.ops — sort-derived operations on the IPS4o engine (DESIGN.md §5).

The paper positions IPS4o as a reusable engine ("the algorithm can also be
used for data distribution and local sorting"); this package is that
engine exposed as a library of jit-compatible operations instead of a
single monolithic sort entry point:

  sort / argsort      NaN-safe total-order sort (keyspace-encoded)
  sort_records /      multi-word keys (strings, composite records) via the
  argsort_records     MSD tie-break level schedule (DESIGN.md §11)
  topk / bottomk      splitter-based partial sort: classify + partition
                      once, base-case-sort only the rank-covering prefix
  segmented_sort      batched independent segments in one composite pass
  unique / run_length sort + equality-bucket boundary extraction
  group_by            grouping via partition / Pallas kernel / full sort
  batched_*           batch-axis-native (B, n) sort / argsort / topk /
                      bottomk — all rows in one trace (DESIGN.md §6)
  keyspace            total-order uint bijection for float/int keys
  PlanCache           (op, [B,] n, dtype) -> tuned, jitted, persisted callable

Production call sites: ``serve.scheduler`` (bottomk, batched across
admission queues), ``data.pipeline`` (plan-cached argsort, batched across
shards), ``models.moe`` / ``examples/moe_routing.py`` (group_by; batched
sort_dispatch across layers).
"""
from repro.core.ips4o import SortConfig
from repro.ops import keyspace
from repro.ops.batched import (
    batched_argsort,
    batched_bottomk,
    batched_sort,
    batched_topk,
)
from repro.ops.groupby import Groups, group_by, run_length, unique
from repro.ops.plan import PlanCache, default_cache, get_sorter
from repro.ops.segmented import segmented_sort
from repro.ops.sort import argsort, argsort_records, sort, sort_records
from repro.ops.topk import bottomk, topk

__all__ = [
    "SortConfig",
    "keyspace",
    "sort",
    "argsort",
    "sort_records",
    "argsort_records",
    "topk",
    "bottomk",
    "batched_sort",
    "batched_argsort",
    "batched_topk",
    "batched_bottomk",
    "segmented_sort",
    "unique",
    "run_length",
    "group_by",
    "Groups",
    "PlanCache",
    "default_cache",
    "get_sorter",
]
