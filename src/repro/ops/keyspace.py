"""Order-preserving bijections from sortable dtypes into unsigned-int keys.

The IPS4o classifier compares keys against splitters with ``>`` / ``==``;
that is a total order for ints and for floats *without* NaN, which is why
``ips4o_sort`` documents a NaN limitation.  This module removes it for the
``repro.ops`` layer (DESIGN.md §5.1): every supported dtype is bijected
into the same-width unsigned integer space where ``<`` on the encoded keys
equals the desired order on the originals:

  * unsigned ints: identity;
  * signed ints:   flip the sign bit (two's complement -> offset binary);
  * floats:        the classic radix trick — negative values are bitwise
    complemented, non-negative values get the sign bit set.  This orders
    -inf < ... < -0.0 < +0.0 < ... < +inf, and (unlike IEEE ``<``) gives
    -0.0 and +0.0 distinct, adjacent code points;
  * NaNs (any sign, any payload) are canonicalized to the maximum code so
    they sort to the tail as a single equivalence class (the equality
    bucket of §4.4 then makes all-NaN runs free).  ``decode`` returns the
    canonical quiet NaN for that class — NaN payloads do not round-trip,
    everything else is bit-exact.

The complement of an encoded key reverses the order (``~u`` sorts
descending), which is how ``topk`` reuses the ascending partial sort.

Multi-word keys (DESIGN.md §11): strings and composite records do not fit
one machine word, so :func:`encode_words` decomposes them into a fixed
width ``(n, W)`` uint32 matrix — each record's bytes laid out big-endian
across the words — such that **row-lexicographic order on the words equals
the record order** (bytes order for strings, tuple order for composite
columns, with every numeric column bijected through the same single-word
encoding above).  ``ops.sort_records`` then sorts word 0 and tie-breaks
the runs that collide word by word.  :func:`decode_words` inverts the
layout.  These two run host-side (numpy): strings are inherently ragged
host data; the resulting word matrix is what goes to the device.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "encode",
    "decode",
    "ordered_uint_dtype",
    "supported",
    "encode_np",
    "decode_np",
    "WordSpec",
    "encode_words",
    "decode_words",
]

_UINT_FOR_BITS = {8: jnp.uint8, 16: jnp.uint16, 32: jnp.uint32, 64: jnp.uint64}


def ordered_uint_dtype(dtype):
    """The unsigned dtype that ``encode`` maps ``dtype`` into.

    >>> import jax.numpy as jnp
    >>> ordered_uint_dtype(jnp.float32)
    dtype('uint32')
    >>> ordered_uint_dtype(jnp.int16)
    dtype('uint16')
    """
    dtype = jnp.dtype(dtype)
    bits = dtype.itemsize * 8
    if bits not in _UINT_FOR_BITS:
        raise TypeError(f"keyspace: unsupported key dtype {dtype}")
    return jnp.dtype(_UINT_FOR_BITS[bits])


def supported(dtype) -> bool:
    """Whether :func:`encode` accepts keys of ``dtype``.

    >>> import jax.numpy as jnp
    >>> (supported(jnp.int16), supported(jnp.complex64))
    (True, False)
    """
    dtype = jnp.dtype(dtype)
    return (
        jnp.issubdtype(dtype, jnp.integer) or jnp.issubdtype(dtype, jnp.floating)
    ) and dtype.itemsize * 8 in _UINT_FOR_BITS


def _sign_bit(udtype) -> jax.Array:
    bits = jnp.dtype(udtype).itemsize * 8
    return jnp.asarray(1 << (bits - 1), udtype)


def encode(keys: jax.Array) -> jax.Array:
    """Biject ``keys`` into unsigned ints such that uint ``<`` == key order.

    >>> import jax.numpy as jnp
    >>> u = encode(jnp.asarray([-1.0, 0.0, 1.0]))
    >>> bool(jnp.all(u[:-1] < u[1:]))  # codes preserve the key order
    True
    """
    dtype = jnp.dtype(keys.dtype)
    udtype = ordered_uint_dtype(dtype)
    if jnp.issubdtype(dtype, jnp.unsignedinteger):
        return keys
    if jnp.issubdtype(dtype, jnp.signedinteger):
        u = jax.lax.bitcast_convert_type(keys, udtype)
        return u ^ _sign_bit(udtype)
    # floating
    bits = jax.lax.bitcast_convert_type(keys, udtype)
    sign = _sign_bit(udtype)
    neg = (bits & sign) != 0
    u = jnp.where(neg, ~bits, bits | sign)
    umax = jnp.asarray(jnp.iinfo(udtype).max, udtype)
    return jnp.where(jnp.isnan(keys), umax, u)


def decode(u: jax.Array, dtype) -> jax.Array:
    """Inverse of :func:`encode` (NaNs come back as the canonical NaN).

    >>> import jax.numpy as jnp
    >>> x = jnp.asarray([-2.5, -0.0, 0.0, 3.0])
    >>> decode(encode(x), jnp.float32).tolist()  # bit-exact round-trip
    [-2.5, -0.0, 0.0, 3.0]
    """
    dtype = jnp.dtype(dtype)
    udtype = ordered_uint_dtype(dtype)
    if u.dtype != udtype:
        raise TypeError(f"keyspace: encoded dtype {u.dtype} != expected {udtype}")
    if jnp.issubdtype(dtype, jnp.unsignedinteger):
        return u
    if jnp.issubdtype(dtype, jnp.signedinteger):
        return jax.lax.bitcast_convert_type(u ^ _sign_bit(udtype), dtype)
    sign = _sign_bit(udtype)
    was_neg = (u & sign) == 0  # encoded negatives have the top bit clear
    bits = jnp.where(was_neg, ~u, u ^ sign)
    return jax.lax.bitcast_convert_type(bits, dtype)


# ---------------------------------------------------------------------------
# Host-side (numpy) mirror of encode/decode — the data layer's generators and
# the multi-word codec below are host-side, and tests use these as the
# independent oracle encoding.


def encode_np(x: np.ndarray) -> np.ndarray:
    """Numpy mirror of :func:`encode` (bit-identical).

    >>> import numpy as np
    >>> encode_np(np.asarray([-1.0, 0.0], np.float32)).dtype
    dtype('uint32')
    """
    x = np.asarray(x)
    dtype = x.dtype
    udtype = np.dtype(_UINT_FOR_BITS[dtype.itemsize * 8].__name__)
    sign = udtype.type(1) << udtype.type(dtype.itemsize * 8 - 1)
    if np.issubdtype(dtype, np.unsignedinteger):
        return x
    if np.issubdtype(dtype, np.signedinteger):
        return x.view(udtype) ^ sign
    bits = x.view(udtype)
    neg = (bits & sign) != 0
    u = np.where(neg, ~bits, bits | sign)
    return np.where(np.isnan(x), np.iinfo(udtype).max, u).astype(udtype)


def decode_np(u: np.ndarray, dtype) -> np.ndarray:
    """Numpy mirror of :func:`decode` (NaNs come back canonical).

    >>> import numpy as np
    >>> x = np.asarray([-2.5, -0.0, 3.0], np.float32)
    >>> decode_np(encode_np(x), np.float32).tolist()
    [-2.5, -0.0, 3.0]
    """
    u = np.asarray(u)
    dtype = np.dtype(dtype)
    udtype = u.dtype
    sign = udtype.type(1) << udtype.type(dtype.itemsize * 8 - 1)
    if np.issubdtype(dtype, np.unsignedinteger):
        return u
    if np.issubdtype(dtype, np.signedinteger):
        return (u ^ sign).view(dtype)
    was_neg = (u & sign) == 0
    bits = np.where(was_neg, ~u, u ^ sign).astype(udtype)
    return bits.view(dtype)


# ---------------------------------------------------------------------------
# Multi-word keys (DESIGN.md §11): fixed-width big-endian word decomposition.

_WORD_BYTES = 4  # uint32 words: wide enough to amortize passes, and every
#                  backend sorts them without x64 mode


@dataclass(frozen=True)
class WordSpec:
    """Layout metadata produced by :func:`encode_words`, consumed by
    :func:`decode_words`.

    ``kind`` is "bytes" (records were strings / byte strings, padded with
    0x00 to ``row_bytes``) or "columns" (records were a tuple of numeric
    columns whose per-column dtypes are ``dtypes``, laid out big-endian in
    order).  ``words`` is W, the number of uint32 words per row.
    """

    kind: str
    row_bytes: int
    words: int
    dtypes: Tuple[str, ...] = ()


def _pack_rows(b: np.ndarray) -> np.ndarray:
    """(n, L) uint8 byte rows -> (n, ceil(L/4)) big-endian uint32 words."""
    n, L = b.shape
    W = max(1, -(-L // _WORD_BYTES))
    padded = np.zeros((n, W * _WORD_BYTES), np.uint8)
    padded[:, :L] = b
    q = padded.reshape(n, W, _WORD_BYTES).astype(np.uint32)
    return (q[..., 0] << 24) | (q[..., 1] << 16) | (q[..., 2] << 8) | q[..., 3]


def _unpack_rows(words: np.ndarray, row_bytes: int) -> np.ndarray:
    """(n, W) uint32 words -> (n, row_bytes) uint8 byte rows."""
    w = np.asarray(words, np.uint32)
    n, W = w.shape
    b = np.empty((n, W, _WORD_BYTES), np.uint8)
    b[..., 0] = w >> 24
    b[..., 1] = (w >> 16) & 0xFF
    b[..., 2] = (w >> 8) & 0xFF
    b[..., 3] = w & 0xFF
    return b.reshape(n, W * _WORD_BYTES)[:, :row_bytes]


def _is_strings(records: Any) -> bool:
    if isinstance(records, np.ndarray):
        return records.dtype.kind in "SU"
    if isinstance(records, (list, tuple)):
        return len(records) == 0 or isinstance(records[0], (bytes, bytearray, str))
    return False


def encode_words(
    records: Union[Sequence[Union[bytes, str]], Sequence[np.ndarray]],
    *,
    width: int = None,
) -> Tuple[np.ndarray, "WordSpec"]:
    """Fixed-width big-endian word decomposition of records (host-side).

    ``records`` is either a sequence of strings / byte strings, or a tuple
    of equal-length numeric column arrays (a composite record per row).
    Returns ``(words, spec)``: ``words`` is ``(n, W)`` uint32 with word 0
    most significant, and **row-lexicographic order on the words equals
    the record order** — bytes order for strings (shorter strings sort as
    their 0x00-padded extension, i.e. a proper prefix sorts first), tuple
    order for columns (each column in its keyspace order: NaNs last,
    -0.0 < +0.0, signed ints by value).

    Strings must not contain NUL bytes (0x00 is the padding code point);
    ``width`` pads/validates strings to a fixed byte length (default: the
    longest record).

    >>> w, spec = encode_words([b"ab", b"abc", b""])
    >>> w.shape, spec.words
    ((3, 1), 1)
    >>> import numpy as np
    >>> bool(w[2, 0] < w[0, 0] < w[1, 0])  # "" < "ab" < "abc"
    True
    """
    if _is_strings(records):
        if isinstance(records, np.ndarray):
            records = records.tolist()
        bs: List[bytes] = [
            r.encode("utf-8") if isinstance(r, str) else bytes(r) for r in records
        ]
        n = len(bs)
        maxlen = max((len(b) for b in bs), default=0)
        if width is None:
            width = maxlen
        elif maxlen > width:
            raise ValueError(
                f"encode_words: record of {maxlen} bytes exceeds width={width}"
            )
        mat = np.zeros((n, max(1, width)), np.uint8)
        for i, b in enumerate(bs):
            if b"\x00" in b:
                raise ValueError(
                    "encode_words: NUL byte in record (0x00 is the pad code)"
                )
            mat[i, : len(b)] = np.frombuffer(b, np.uint8)
        return _pack_rows(mat), WordSpec(
            kind="bytes", row_bytes=width, words=max(1, -(-width // _WORD_BYTES))
        )
    cols = [np.asarray(c) for c in records]
    if not cols:
        raise ValueError("encode_words: no columns")
    n = cols[0].shape[0]
    parts = []
    for c in cols:
        if c.shape != (n,):
            raise ValueError("encode_words: columns must be equal-length 1-D")
        if not supported(c.dtype):
            raise TypeError(f"encode_words: unsupported column dtype {c.dtype}")
        u = encode_np(c)
        be = np.ascontiguousarray(u.astype(u.dtype.newbyteorder(">")))
        parts.append(be.view(np.uint8).reshape(n, c.dtype.itemsize))
    rows = np.concatenate(parts, axis=1) if n else np.zeros(
        (0, sum(c.dtype.itemsize for c in cols)), np.uint8
    )
    row_bytes = sum(c.dtype.itemsize for c in cols)
    return _pack_rows(rows), WordSpec(
        kind="columns",
        row_bytes=row_bytes,
        words=max(1, -(-row_bytes // _WORD_BYTES)),
        dtypes=tuple(str(c.dtype) for c in cols),
    )


def decode_words(
    words: np.ndarray, spec: "WordSpec"
) -> Union[List[bytes], Tuple[np.ndarray, ...]]:
    """Inverse of :func:`encode_words` (host-side).

    Strings come back as a list of byte strings with the 0x00 padding
    stripped; columns come back as a tuple of arrays in the original
    dtypes (bit-exact except NaN payloads, as with :func:`decode`).

    >>> w, spec = encode_words([b"hi", b"there"])
    >>> decode_words(w, spec)
    [b'hi', b'there']
    """
    b = _unpack_rows(np.asarray(words), spec.row_bytes)
    if spec.kind == "bytes":
        return [bytes(row).rstrip(b"\x00") for row in b]
    if spec.kind != "columns":
        raise ValueError(f"decode_words: unknown spec kind {spec.kind!r}")
    out = []
    off = 0
    for name in spec.dtypes:
        dtype = np.dtype(name)
        sz = dtype.itemsize
        u = (
            np.ascontiguousarray(b[:, off : off + sz])
            .view(np.dtype(f">u{sz}"))
            .reshape(-1)
            .astype(np.dtype(f"u{sz}"))
        )
        out.append(decode_np(u, dtype))
        off += sz
    return tuple(out)
