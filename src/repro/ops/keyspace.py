"""Order-preserving bijections from sortable dtypes into unsigned-int keys.

The IPS4o classifier compares keys against splitters with ``>`` / ``==``;
that is a total order for ints and for floats *without* NaN, which is why
``ips4o_sort`` documents a NaN limitation.  This module removes it for the
``repro.ops`` layer (DESIGN.md §5.1): every supported dtype is bijected
into the same-width unsigned integer space where ``<`` on the encoded keys
equals the desired order on the originals:

  * unsigned ints: identity;
  * signed ints:   flip the sign bit (two's complement -> offset binary);
  * floats:        the classic radix trick — negative values are bitwise
    complemented, non-negative values get the sign bit set.  This orders
    -inf < ... < -0.0 < +0.0 < ... < +inf, and (unlike IEEE ``<``) gives
    -0.0 and +0.0 distinct, adjacent code points;
  * NaNs (any sign, any payload) are canonicalized to the maximum code so
    they sort to the tail as a single equivalence class (the equality
    bucket of §4.4 then makes all-NaN runs free).  ``decode`` returns the
    canonical quiet NaN for that class — NaN payloads do not round-trip,
    everything else is bit-exact.

The complement of an encoded key reverses the order (``~u`` sorts
descending), which is how ``topk`` reuses the ascending partial sort.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["encode", "decode", "ordered_uint_dtype", "supported"]

_UINT_FOR_BITS = {8: jnp.uint8, 16: jnp.uint16, 32: jnp.uint32, 64: jnp.uint64}


def ordered_uint_dtype(dtype):
    """The unsigned dtype that ``encode`` maps ``dtype`` into.

    >>> import jax.numpy as jnp
    >>> ordered_uint_dtype(jnp.float32)
    dtype('uint32')
    >>> ordered_uint_dtype(jnp.int16)
    dtype('uint16')
    """
    dtype = jnp.dtype(dtype)
    bits = dtype.itemsize * 8
    if bits not in _UINT_FOR_BITS:
        raise TypeError(f"keyspace: unsupported key dtype {dtype}")
    return jnp.dtype(_UINT_FOR_BITS[bits])


def supported(dtype) -> bool:
    """Whether :func:`encode` accepts keys of ``dtype``.

    >>> import jax.numpy as jnp
    >>> (supported(jnp.int16), supported(jnp.complex64))
    (True, False)
    """
    dtype = jnp.dtype(dtype)
    return (
        jnp.issubdtype(dtype, jnp.integer) or jnp.issubdtype(dtype, jnp.floating)
    ) and dtype.itemsize * 8 in _UINT_FOR_BITS


def _sign_bit(udtype) -> jax.Array:
    bits = jnp.dtype(udtype).itemsize * 8
    return jnp.asarray(1 << (bits - 1), udtype)


def encode(keys: jax.Array) -> jax.Array:
    """Biject ``keys`` into unsigned ints such that uint ``<`` == key order.

    >>> import jax.numpy as jnp
    >>> u = encode(jnp.asarray([-1.0, 0.0, 1.0]))
    >>> bool(jnp.all(u[:-1] < u[1:]))  # codes preserve the key order
    True
    """
    dtype = jnp.dtype(keys.dtype)
    udtype = ordered_uint_dtype(dtype)
    if jnp.issubdtype(dtype, jnp.unsignedinteger):
        return keys
    if jnp.issubdtype(dtype, jnp.signedinteger):
        u = jax.lax.bitcast_convert_type(keys, udtype)
        return u ^ _sign_bit(udtype)
    # floating
    bits = jax.lax.bitcast_convert_type(keys, udtype)
    sign = _sign_bit(udtype)
    neg = (bits & sign) != 0
    u = jnp.where(neg, ~bits, bits | sign)
    umax = jnp.asarray(jnp.iinfo(udtype).max, udtype)
    return jnp.where(jnp.isnan(keys), umax, u)


def decode(u: jax.Array, dtype) -> jax.Array:
    """Inverse of :func:`encode` (NaNs come back as the canonical NaN).

    >>> import jax.numpy as jnp
    >>> x = jnp.asarray([-2.5, -0.0, 0.0, 3.0])
    >>> decode(encode(x), jnp.float32).tolist()  # bit-exact round-trip
    [-2.5, -0.0, 0.0, 3.0]
    """
    dtype = jnp.dtype(dtype)
    udtype = ordered_uint_dtype(dtype)
    if u.dtype != udtype:
        raise TypeError(f"keyspace: encoded dtype {u.dtype} != expected {udtype}")
    if jnp.issubdtype(dtype, jnp.unsignedinteger):
        return u
    if jnp.issubdtype(dtype, jnp.signedinteger):
        return jax.lax.bitcast_convert_type(u ^ _sign_bit(udtype), dtype)
    sign = _sign_bit(udtype)
    was_neg = (u & sign) == 0  # encoded negatives have the top bit clear
    bits = jnp.where(was_neg, ~u, u ^ sign)
    return jax.lax.bitcast_convert_type(bits, dtype)
