"""Batch-axis-native sort ops: (B, n) rows sorted in one trace (DESIGN.md §6).

Every hot caller with real traffic has a batch dimension — MoE routing ids
per layer, the serve scheduler's admission queues, per-shard document
lengths — and looping the 1-D sort over rows leaves the accelerator idle
across exactly that dimension.  These entry points run the whole pipeline
(per-row sample -> batched branchless classify -> per-row stable partition
-> shared base case) over all B rows at once: the Pallas engine launches
the batch-grid kernels (grid = (B, tiles)), the XLA engine vmaps its dense
formulation, and the base-case window sorts of all rows fuse into one
reshape.  Each row's result is bit-identical to the unbatched op on that
row (``tests/test_batched.py``).

Like ``ops.sort``, keys are bijected through ``ops.keyspace`` first, so
NaN / -0.0 handling matches the unbatched ops exactly.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.classify import resolve_classifier
from repro.core.ips4o import (
    SortConfig,
    resolve_engine,
    batched_base_case,
    batched_bucket_violations,
    batched_pad_with_sentinel,
    batched_partition_passes,
    batched_segment_ids,
    batched_stable_full_sort,
    ips4o_sort_batched,
    plan_levels,
)
from repro.ops import keyspace
from repro.ops.topk import _prefix_limit

__all__ = [
    "batched_sort",
    "batched_argsort",
    "batched_topk",
    "batched_bottomk",
    "with_engine_batched",
]


def with_engine_batched(
    cfg: SortConfig,
    engine: Optional[str],
    keys: Optional[jax.Array] = None,
    classifier: Optional[str] = None,
) -> SortConfig:
    """Override the partition engine and/or classifier for a batched call.

    The batched analogue of ``ops.sort.with_engine``: "auto" (for either
    knob) resolves here, against the caller's original (B, n, dtype) — the
    plan cache keys batched plans under exactly that triple, so resolving
    deeper (against the encoded dtype / padded n) would never match a
    persisted plan.

    >>> from repro.ops import SortConfig
    >>> import jax.numpy as jnp
    >>> cfg = with_engine_batched(SortConfig(), "pallas")
    >>> cfg.engine
    'pallas'
    >>> with_engine_batched(cfg, None).engine  # None keeps cfg.engine
    'pallas'
    >>> with_engine_batched(SortConfig(), None, classifier="radix").classifier
    'radix'
    """
    cfg = cfg if engine is None else replace(cfg, engine=engine)
    if classifier is not None:
        cfg = replace(cfg, classifier=classifier)
    if keys is not None:
        B, n = keys.shape
        if cfg.engine == "auto":
            cfg = replace(
                cfg, engine=resolve_engine(cfg, n, keys.dtype, batch=B)
            )
        if cfg.classifier == "auto":
            cfg = replace(
                cfg,
                classifier=resolve_classifier("auto", n, keys.dtype, batch=B),
            )
    return cfg


def batched_sort(
    keys: jax.Array,
    values: Any = None,
    *,
    cfg: SortConfig = SortConfig(),
    engine: Optional[str] = None,
    classifier: Optional[str] = None,
):
    """Sort each row of ``keys`` (B, n) ascending, NaN-safe, in one trace.

    Per row this is exactly ``ops.sort`` (NaNs last, -0.0 before +0.0);
    across rows it is one compiled program instead of B dispatches.  An
    optional ``values`` pytree (leaves with leading dims (B, n)) is
    permuted alongside, row by row.  ``engine`` ("xla" | "pallas" |
    "auto") overrides ``cfg.engine`` for this call; ``classifier``
    ("tree" | "radix" | "learned" | "auto") overrides ``cfg.classifier``
    (DESIGN.md §9).

    >>> import jax.numpy as jnp
    >>> x = jnp.asarray([[3.0, 1.0, 2.0], [0.0, 5.0, -1.0]])
    >>> batched_sort(x).tolist()
    [[1.0, 2.0, 3.0], [-1.0, 0.0, 5.0]]
    >>> k, v = batched_sort(x, jnp.asarray([[10, 11, 12], [20, 21, 22]]))
    >>> v.tolist()  # payload rows follow their keys
    [[11, 12, 10], [22, 20, 21]]
    """
    if keys.ndim != 2:
        raise ValueError("keys must be 2-D (B, n)")
    cfg = with_engine_batched(cfg, engine, keys, classifier)
    enc = keyspace.encode(keys)
    if values is None:
        out = ips4o_sort_batched(enc, cfg=cfg)
        return keyspace.decode(out, keys.dtype)
    out, vs = ips4o_sort_batched(enc, values, cfg=cfg)
    return keyspace.decode(out, keys.dtype), vs


def batched_argsort(
    keys: jax.Array,
    *,
    cfg: SortConfig = SortConfig(),
    engine: Optional[str] = None,
    classifier: Optional[str] = None,
) -> jax.Array:
    """Per-row indices that sort ``keys`` (B, n) ascending.

    ``jnp.take_along_axis(keys, batched_argsort(keys), axis=1)`` is sorted
    per row; ties are in arbitrary (but deterministic) order.

    >>> import jax.numpy as jnp
    >>> batched_argsort(jnp.asarray([[30, 10, 20]])).tolist()
    [[1, 2, 0]]
    """
    if keys.ndim != 2:
        raise ValueError("keys must be 2-D (B, n)")
    B, n = keys.shape
    idx = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :], (B, n))
    if n <= 1:
        return idx
    _, order = ips4o_sort_batched(
        keyspace.encode(keys), idx,
        cfg=with_engine_batched(cfg, engine, keys, classifier),
    )
    return order


def _batched_smallest(
    enc: jax.Array, kk: int, cfg: SortConfig
) -> Tuple[jax.Array, jax.Array]:
    """Per-row (sorted kk smallest encoded keys, their original indices).

    The batched form of ``ops.topk.smallest_encoded``: same static W-aligned
    prefix P covers the rank-(kk-1) bucket of *every* row, so the base
    case runs over [0, P) of each row only.
    """
    B, n = enc.shape
    idx = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :], (B, n))
    arrays = {"k": enc, "v": idx}
    unit = max(cfg.base_case, cfg.tile)
    arrays = batched_pad_with_sentinel(arrays, unit)
    n_pad = arrays["k"].shape[1]
    W = cfg.base_case
    levels = plan_levels(n_pad, cfg)

    if not levels:
        arrays = batched_stable_full_sort(arrays)
        return arrays["k"][:, :kk], arrays["v"][:, :kk]

    arrays, offsets, nb, pad_bucket = batched_partition_passes(
        arrays, n, cfg, levels
    )
    P = _prefix_limit(kk, W, n_pad)
    fb = batched_segment_ids(offsets, n_pad)
    violated = batched_bucket_violations(offsets, nb, W, pad_bucket, limit=P)

    run = lambda a: batched_base_case(a, fb, W, limit=P)
    if cfg.fallback:
        arrays = jax.lax.cond(violated, batched_stable_full_sort, run, arrays)
    else:
        arrays = run(arrays)
    return arrays["k"][:, :kk], arrays["v"][:, :kk]


def batched_bottomk(
    keys: jax.Array,
    k: int,
    *,
    cfg: SortConfig = SortConfig(),
    engine: Optional[str] = None,
    classifier: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Per row: the ``k`` smallest keys ascending, with their indices.

    Returns (values, indices), each (B, min(k, n)) — the batched form of
    ``ops.bottomk`` with one partial sort covering every row (the base
    case touches only the shared rank-covering prefix of each row).

    >>> import jax.numpy as jnp
    >>> v, i = batched_bottomk(jnp.asarray([[4.0, 1.0, 3.0], [9.0, 8.0, 7.0]]), 2)
    >>> v.tolist()
    [[1.0, 3.0], [7.0, 8.0]]
    >>> i.tolist()
    [[1, 2], [2, 1]]
    """
    if keys.ndim != 2:
        raise ValueError("keys must be 2-D (B, n)")
    n = keys.shape[1]
    kk = max(0, min(int(k), n))
    if kk == 0:
        return keys[:, :0], jnp.zeros((keys.shape[0], 0), jnp.int32)
    out, idx = _batched_smallest(
        keyspace.encode(keys), kk, with_engine_batched(cfg, engine, keys, classifier)
    )
    return keyspace.decode(out, keys.dtype), idx


def batched_topk(
    keys: jax.Array,
    k: int,
    *,
    cfg: SortConfig = SortConfig(),
    engine: Optional[str] = None,
    classifier: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Per row: the ``k`` largest keys descending, with their indices.

    The batched ``ops.topk`` — same ``jax.lax.top_k`` contract per row
    (modulo tie order), implemented as batched bottom-k of the
    complemented encoded keys.

    >>> import jax.numpy as jnp
    >>> v, i = batched_topk(jnp.asarray([[1.0, 9.0, 3.0], [7.0, 2.0, 5.0]]), 2)
    >>> v.tolist()
    [[9.0, 3.0], [7.0, 5.0]]
    >>> i.tolist()
    [[1, 2], [0, 2]]
    """
    if keys.ndim != 2:
        raise ValueError("keys must be 2-D (B, n)")
    n = keys.shape[1]
    kk = max(0, min(int(k), n))
    if kk == 0:
        return keys[:, :0], jnp.zeros((keys.shape[0], 0), jnp.int32)
    out, idx = _batched_smallest(
        ~keyspace.encode(keys), kk, with_engine_batched(cfg, engine, keys, classifier)
    )
    return keyspace.decode(~out, keys.dtype), idx
