"""Batched independent-segment sort via the segmented level pass.

``segmented_sort`` sorts each ``keys[offsets[i]:offsets[i+1]]`` range
independently, in place of the per-window ``jnp.argsort`` fallback that
batched consumers (windowed attention, per-request serving state, bucketed
data pipelines) would otherwise use.  It is exactly recursion level 2 of
the full sort (``core.ips4o.segmented_level_pass``) promoted to a public
op: per-segment splitters -> flattened ``classify_segmented`` -> composite
bucket ids (seg * 2k + local, monotone in segment) -> one stable block
partition -> one shared base case over all segments' windows.

Segment boundaries may be traced (data-dependent); only the segment
*count* is static.  Pads go into an extra trailing segment; the robustness
fallback is a stable lexicographic (segment, key) sort.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.ips4o import (
    SortConfig,
    base_case,
    bucket_violations,
    pad_with_sentinel,
    segment_ids,
    segmented_level_pass,
)
from repro.ops import keyspace

__all__ = ["segmented_sort"]


def _pow2_clamp(x: int, lo: int, hi: int) -> int:
    p = 1
    while p < x:
        p *= 2
    return max(lo, min(p, hi))


def _stable_segmented_sort(arrays: Any, seg: jax.Array) -> Any:
    """Fallback: stable lexicographic (segment, key) sort via two passes."""
    o1 = jnp.argsort(arrays["k"], stable=True)
    o2 = jnp.argsort(jnp.take(seg, o1, axis=0), stable=True)
    order = jnp.take(o1, o2, axis=0)
    return jax.tree.map(lambda a: jnp.take(a, order, axis=0), arrays)


def segmented_sort(
    keys: jax.Array,
    offsets: jax.Array,
    num_segments: int,
    values: Any = None,
    *,
    k: Optional[int] = None,
    cfg: SortConfig = SortConfig(),
    engine: Optional[str] = None,
    classifier: Optional[str] = None,
):
    """Sort each segment of ``keys`` independently, ascending, NaN-safe.

    Args:
      keys: (n,) key array.
      offsets: (num_segments + 1,) nondecreasing int32 segment boundaries
        with offsets[0] == 0 and offsets[-1] == n; may be traced.
      num_segments: static segment count.
      values: optional payload pytree (leaves with leading dim n) permuted
        alongside, per segment.
      k: buckets per segment (power of two); default sizes buckets to the
        average segment like ``plan_levels`` does globally.
      engine: partition-engine override ("xla" | "pallas" | "auto").
      classifier: accepted for API symmetry with ``sort``, but "radix" and
        "learned" are mapped to "tree" here: user segments are arbitrary
        key ranges, not the bit-aligned ranges a radix level 1 produces,
        so the shared bit extractor is not monotone within them, and the
        global CDF model has no per-segment form.  The per-segment
        sampled tree is the only engine whose contract covers this op.

    Returns sorted keys, or (keys, values) when a payload is given.

    >>> import jax.numpy as jnp
    >>> keys = jnp.asarray([3.0, 1.0, 2.0, 2.0, 0.0])
    >>> offsets = jnp.asarray([0, 3, 5], jnp.int32)
    >>> segmented_sort(keys, offsets, 2).tolist()  # segments stay apart
    [1.0, 2.0, 3.0, 0.0, 2.0]
    """
    from repro.ops.sort import with_engine

    cfg = with_engine(cfg, engine, keys, classifier)
    if cfg.classifier != "tree":
        # see the ``classifier`` arg note: only the per-segment tree is
        # valid over arbitrary user segments
        from dataclasses import replace

        cfg = replace(cfg, classifier="tree")
    n = keys.shape[0]
    if keys.ndim != 1:
        raise ValueError("keys must be 1-D")
    if n <= 1:
        return keys if values is None else (keys, values)

    enc = keyspace.encode(keys)
    arrays = {"k": enc}
    if values is not None:
        arrays["v"] = values
    W = cfg.base_case
    unit = max(W, cfg.tile)
    arrays = pad_with_sentinel(arrays, unit)
    n_pad = arrays["k"].shape[0]

    # Pads form one extra trailing segment; sentinel keys make its buckets
    # equality buckets, so it is skipped by the base case for free.
    off_ext = jnp.concatenate(
        [
            jnp.asarray(offsets, jnp.int32),
            jnp.full((1,), n_pad, jnp.int32),
        ]
    )
    num_seg_ext = num_segments + 1
    if k is None:
        avg = max(1, n // max(num_segments, 1))
        k = _pow2_clamp(-(-cfg.slack * avg // W), 2, cfg.kmax)

    rng = jax.random.PRNGKey(cfg.seed)
    arrays, boffs, nb = segmented_level_pass(
        arrays, off_ext, num_seg_ext, n_pad, k, cfg, rng
    )

    fb = segment_ids(boffs, n_pad)
    violated = bucket_violations(boffs, nb, W)
    # the composite partition is stable and monotone in segment, so each
    # segment keeps its input index range — fallback can recompute seg ids
    seg = segment_ids(off_ext, n_pad)

    run = lambda a: base_case(a, fb, W)
    if cfg.fallback:
        arrays = jax.lax.cond(
            violated, lambda a: _stable_segmented_sort(a, seg), run, arrays
        )
    else:
        arrays = run(arrays)

    out = keyspace.decode(arrays["k"][:n], keys.dtype)
    if values is None:
        return out
    return out, jax.tree.map(lambda a: a[:n], arrays["v"])
