"""Tuned plan cache: (op, [B,] n, dtype) -> jitted callable with a tuned config.

Per-(n, dtype, distribution) tuning is where the remaining constant
factors of the engine live (cf. *Towards Parallel Learned Sorting*): the
best base-case window W and tile size depend on the problem size relative
to fast-memory capacity, not just on the algorithm.  ``PlanCache`` owns
that decision:

  * ``get_sorter(n, dtype, op)`` returns a cached, jitted callable for the
    op ("sort" | "argsort" | "topk" | "bottomk");
  * the ``SortConfig`` it bakes in comes from a persisted plan when one
    exists, from a small autotune sweep when ``tune=True`` (a handful of
    candidate configs, median-of-3 wall clocks on a synthetic uniform
    input — the same stable-timing discipline as ``benchmarks/common``),
    and from the paper-default heuristic otherwise;
  * tuned plans are persisted to JSON (``REPRO_OPS_PLAN_CACHE`` or
    ``~/.cache/repro_ops_plans.json``) so the sweep is paid once per
    machine, and the measured wall clock is recorded alongside the chosen
    config the way ``benchmarks/common.py`` records benchmark rows;
  * plans carry the *partition engine* ("xla" | "pallas") as a tuned
    dimension: the sweep times both engines (the Pallas candidates are
    skipped off-TPU above ``_PALLAS_TUNE_MAX`` elements, where interpret
    mode would dominate the sweep) and ``engine_hint`` feeds the winner
    back to ``SortConfig(engine="auto")`` callers.  Plans persisted before
    the engine dimension existed load unchanged (the field defaults);
  * **batched shapes are a key dimension** (DESIGN.md §6): ``batch=B``
    keys a plan under (op, B, n, dtype) and builds/sweeps the
    ``repro.ops.batched`` entry point over a (B, n) synthetic draw —
    batched and unbatched plans for the same row length never collide.
    Schema tolerance cuts the other way too: plan entries written by
    *pre-batch* schemas (extra/unknown config fields) are migrated — the
    known fields load, the foreign ones are dropped and the entry is
    rewritten on the next save — instead of being discarded to defaults;
  * the **stream: key family** (DESIGN.md §7) plans the out-of-core merge
    geometry: ``stream:chunk=65536:fanin=8:dtype=float32`` records the
    merge engine + merge-path tile for an external sort at that chunk
    size x fan-in (``stream_plan``), tuned by timing a synthetic pairwise
    merge at the chunk shape — the first-round merge every tournament
    pass in ``repro.stream`` actually runs;
  * the **dist: key family** (DESIGN.md §8) plans the multi-level
    distributed sort: ``dist:n_local=8192:d=8:dtype=float32`` records the
    capacity factor (slack), per-shard oversampling, and engine
    (``dist_plan``), tuned by a host-side *capacity simulation* — replay
    the level-0 splitter selection on adversarial synthetic draws and keep
    the cheapest candidate whose worst per-pair fill leaves headroom —
    because collective volume scales linearly with the capacity factor;
  * the **clf: key family** (DESIGN.md §9) plans the *classifier engine*:
    ``clf:n=65536:dtype=uint32:dist=uniform`` records which of
    tree / radix / learned won a wall-clock race of full sorts on a
    synthetic draw matching that distribution label
    (``classifier_plan``), and ``classifier_hint`` feeds the winner back
    to ``SortConfig(classifier="auto")`` callers — by exact label when the
    caller measured one (``classify.router.classifier_for``), by consensus
    across labels from the shape-only resolution path.  Plans persisted
    before the classifier dimension existed load with
    ``classifier="tree"`` defaulted (the pre-classifier behaviour), not
    discarded.

Every lookup is observable through ``repro.obs`` (off by default): plan
lookups emit ``plan_cache.hit`` / ``plan_cache.miss`` counters labelled
by key family (``family="sort" | "clf" | "stream" | "dist"``), autotune
sweeps emit ``plan_cache.autotune_sweep`` plus a ``plan.autotune`` span,
compiled-callable memoization emits ``plan_cache.compiled_hit`` /
``plan_cache.compiled_miss``, and classifier races emit a
``classifier.race`` span and a ``classifier.race_winner`` counter — all
visible in ``obs.summary()`` and the exporters (DESIGN.md §12), so a
multi-second autotune stall is attributable instead of silent.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from dataclasses import asdict
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.ips4o import SortConfig, plan_levels

__all__ = ["PlanCache", "StreamPlan", "DistPlan", "get_sorter", "default_cache"]

_OPS = ("sort", "argsort", "topk", "bottomk")

_CFG_FIELDS = frozenset(f.name for f in dataclasses.fields(SortConfig))

# classifier engines the clf: races time against each other ("auto" is the
# output of a race, never a contestant) and the distribution labels raced —
# the label vocabulary of ``classify.router.distribution_moments``
_CLASSIFIER_RACERS = ("tree", "radix", "learned")
_CLF_DISTS = ("uniform", "dup", "sorted", "skew")


def _synthetic_draw(dist: str, count: int, dtype) -> "np.ndarray":
    """Numpy draw with the shape of one ``distribution_moments`` label, in
    a numpy dtype safe to ``.astype()`` into ``dtype``."""
    rng = np.random.default_rng(0)
    if jnp.issubdtype(jnp.dtype(dtype), jnp.floating):
        if dist == "uniform":
            return rng.random(count, dtype=np.float32)
        if dist == "dup":
            return rng.choice(np.linspace(0.0, 1.0, 97, dtype=np.float32), count)
        if dist == "sorted":
            return np.sort(rng.random(count, dtype=np.float32))
        if dist == "skew":
            return rng.exponential(size=count).astype(np.float32)
    else:
        info = jnp.iinfo(dtype)
        nd = np.dtype(jnp.dtype(dtype).name)
        if dist == "uniform":
            return rng.integers(info.min, info.max, count, endpoint=False, dtype=nd)
        if dist == "dup":
            return rng.integers(0, 97, count, dtype=nd)
        if dist == "sorted":
            return np.sort(
                rng.integers(info.min, info.max, count, endpoint=False, dtype=nd)
            )
        if dist == "skew":
            hi = min(int(info.max), 1 << 20)
            return np.minimum(
                rng.exponential(scale=hi / 64, size=count), hi
            ).astype(nd)
    raise ValueError(
        f"unknown distribution label {dist!r}; expected one of {_CLF_DISTS}"
    )


def _default_path() -> str:
    return os.environ.get(
        "REPRO_OPS_PLAN_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro_ops_plans.json"),
    )


# Off-TPU the Pallas kernels run in interpret mode; past this size their
# sweep candidates cost more than any plan could save, so they are skipped
# (the plan then records the XLA winner, which is also the honest answer).
_PALLAS_TUNE_MAX = 1 << 16


def _engines_for(n: int) -> tuple:
    if jax.default_backend() == "tpu" or n <= _PALLAS_TUNE_MAX:
        return ("xla", "pallas")
    return ("xla",)


def _candidates(n: int, engines: tuple = ("xla",), itemsize: int = 4) -> list:
    """Small sweep around the paper defaults; invalid plans are skipped.

    The full W/tile/slack grid runs on the "xla" engine; the "pallas"
    engine adds the default-geometry points only (its constant factors sit
    in the kernels, not the window geometry), keeping the sweep short.
    The classifier dimension adds one "radix" point per engine (the tree
    is already every grid point's classifier; learned is raced separately
    by ``classifier_plan``, where the draw's distribution is controlled),
    and the "pallas" engine adds one off-default ``classify_rows`` point
    from the unified launch-spec candidate list
    (``launch.roofline.spec_candidates`` for the ``"level_fused"`` kernel
    kind at this ``itemsize``) so the fused level kernel's tile shape is
    swept, not assumed.
    """
    out = []
    for base_case, tile in [(8192, 4096), (8192, 2048), (4096, 2048), (16384, 4096)]:
        for slack in (8, 4):
            cfg = SortConfig(base_case=base_case, tile=tile, slack=slack)
            try:
                plan_levels(max(n, 1), cfg)
            except ValueError:
                continue
            out.append(cfg)
    trial = [SortConfig(classifier="radix")]
    if "pallas" in engines:
        for slack in (8, 4):
            trial.append(SortConfig(slack=slack, engine="pallas"))
        trial.append(SortConfig(engine="pallas", classifier="radix"))
        from repro.launch.roofline import spec_candidates

        rows = spec_candidates("level_fused", itemsize, SortConfig().kmax)
        if len(rows) > 1:
            trial.append(SortConfig(engine="pallas", classify_rows=rows[1]))
    for cfg in trial:
        try:
            plan_levels(max(n, 1), cfg)
        except ValueError:
            continue
        out.append(cfg)
    return out


def _build(op: str, cfg: SortConfig, k: Optional[int], batch: Optional[int] = None) -> Callable:
    # local imports: plan is imported by repro.ops.__init__ alongside these
    from repro.ops.sort import argsort, sort
    from repro.ops.topk import bottomk, topk

    if batch is not None:
        from repro.ops.batched import (
            batched_argsort,
            batched_bottomk,
            batched_sort,
            batched_topk,
        )

        fns = {"sort": batched_sort, "argsort": batched_argsort,
               "topk": batched_topk, "bottomk": batched_bottomk}
    else:
        fns = {"sort": sort, "argsort": argsort, "topk": topk, "bottomk": bottomk}
    if op not in fns:
        raise ValueError(f"unknown op {op!r}; expected one of {_OPS}")
    base = fns[op]
    if op in ("topk", "bottomk"):
        f = lambda keys: base(keys, k, cfg=cfg)
    else:
        f = lambda keys: base(keys, cfg=cfg)
    return jax.jit(f)


@dataclasses.dataclass(frozen=True)
class StreamPlan:
    """Tuned geometry for one out-of-core merge family (DESIGN.md §7):
    the merge engine and merge-path tile ``repro.stream`` uses for every
    pairwise pass of an external sort at this chunk size x fan-in."""

    chunk: int
    fanin: int
    merge_tile: int = 256
    engine: str = "xla"


def _stream_tiles() -> tuple:
    """Merge-path tiles the stream autotune sweeps: the unified launch
    spec's candidate rows for the ``"merge"`` kernel kind (x128 lanes)."""
    from repro.launch.roofline import spec_candidates

    return tuple(r * 128 for r in spec_candidates("merge", 4))


@dataclasses.dataclass(frozen=True)
class DistPlan:
    """Tuned knobs for one distributed-sort family (DESIGN.md §8): the
    capacity factor (slack over the balanced per-pair expectation), the
    per-shard oversampling, the partition engine ``repro.dist`` uses for
    every level of a sort at this (n_local, d, dtype), and — when
    ``dist.sort(order="auto")`` has run — the topology-chosen level order
    (DESIGN.md §13.4; empty means "no recorded preference")."""

    n_local: int
    d: int
    slack: float = 2.0
    oversample: int = 32
    engine: str = "xla"
    axis_order: Tuple[str, ...] = ()


# capacity factors and oversample multipliers the dist autotune sweeps —
# ascending, so the first passing candidate is the cheapest (collective
# volume scales linearly with slack)
_DIST_SLACKS = (1.5, 2.0, 2.5, 3.0)
_DIST_OVERSAMPLE_MULS = (1, 2, 4)
# a candidate passes when the simulated worst per-pair fill stays under
# this fraction of capacity (headroom against draws the sweep didn't see)
_DIST_FILL_MARGIN = 0.9


def _bench(f: Callable, x: jax.Array, iters: int = 3) -> float:
    jax.block_until_ready(f(x))  # compile + warm
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(f(x))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


class PlanCache:
    """Process-level cache of tuned sorter plans; JSON-persisted.

    >>> import os, tempfile
    >>> import jax.numpy as jnp
    >>> pc = PlanCache(path=os.path.join(tempfile.mkdtemp(), "plans.json"))
    >>> f = pc.get_sorter(4, jnp.float32)
    >>> f(jnp.asarray([3.0, 1.0, 2.0, 0.0])).tolist()
    [0.0, 1.0, 2.0, 3.0]
    >>> pc.config_for("sort", 4, jnp.float32).engine  # no tuned plan: defaults
    'xla'
    >>> fb = pc.get_sorter(4, jnp.int32, "argsort", batch=2)  # batched plans
    >>> fb(jnp.asarray([[30, 10, 20, 0], [1, 2, 3, 4]])).tolist()
    [[3, 1, 2, 0], [0, 1, 2, 3]]
    """

    def __init__(self, path: Optional[str] = None):
        self.path = _default_path() if path is None else path
        self._plans: Dict[str, Dict[str, Any]] = {}
        self._compiled: Dict[str, Callable] = {}
        if os.path.exists(self.path):
            try:
                with open(self.path) as fh:
                    self._plans = json.load(fh)
            except (OSError, json.JSONDecodeError):
                self._plans = {}

    # -- keys ---------------------------------------------------------------
    @staticmethod
    def _key(op: str, n: int, dtype, k: Optional[int], batch: Optional[int] = None) -> str:
        """Plan key.  Unbatched keys keep the original (pre-batch) format so
        plans persisted before the batch dimension existed still match;
        batched keys insert ``B=``: ``sort:B=32:n=4096:dtype=float32``."""
        b = f"B={batch}:" if batch is not None else ""
        key = f"{op}:{b}n={n}:dtype={jnp.dtype(dtype).name}"
        return key + (f":k={k}" if k is not None else "")

    # -- persistence --------------------------------------------------------
    def _save(self) -> None:
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as fh:
            json.dump(self._plans, fh, indent=1, sort_keys=True)
        os.replace(tmp, self.path)

    # -- plan selection -----------------------------------------------------
    def _coerce_config(self, key: str) -> Optional[SortConfig]:
        """Load a persisted plan's config, tolerating foreign schemas.

        Pre-batch schemas stored fields ``SortConfig`` no longer knows
        (e.g. a ``batch`` recorded inside the config); instead of
        discarding the whole tuned plan, the known fields load and the
        entry is migrated in place (rewritten at the next ``_save``).  A
        config with *no* known fields — or an entry that is not even a
        dict — is genuinely foreign -> None (defaults, never a crash).
        """
        entry = self._plans.get(key)
        if not isinstance(entry, dict):
            return None
        raw = entry.get("config")
        if not isinstance(raw, dict):
            return None
        # keep only known fields whose JSON value kind matches the default's
        # (dataclasses don't validate, so a {"tile": "big"} would otherwise
        # construct fine and crash later inside plan_levels / jit)
        defaults = SortConfig()
        known = {
            f: v
            for f, v in raw.items()
            if f in _CFG_FIELDS and isinstance(v, type(getattr(defaults, f)))
        }
        if not known:
            return None
        cfg = SortConfig(**known)
        if known != raw:
            self._plans[key]["config"] = known  # migrate the pre-batch entry
        return cfg

    def config_for(
        self,
        op: str,
        n: int,
        dtype,
        k: Optional[int] = None,
        tune: bool = False,
        batch: Optional[int] = None,
    ) -> SortConfig:
        """The SortConfig a sorter for this key would use (tuning if asked)."""
        key = self._key(op, n, dtype, k, batch)
        if key in self._plans:
            cfg = self._coerce_config(key)
            if cfg is not None:
                obs.count("plan_cache.hit", family="sort", op=op)
                return cfg
        obs.count("plan_cache.miss", family="sort", op=op)
        if tune:
            return self._autotune(op, n, dtype, k, batch)
        return SortConfig()

    def _autotune(
        self, op: str, n: int, dtype, k: Optional[int], batch: Optional[int] = None
    ) -> SortConfig:
        key = self._key(op, n, dtype, k, batch)
        dtype = jnp.dtype(dtype)
        rng = np.random.default_rng(0)
        shape = (batch, n) if batch is not None else (n,)
        count = n if batch is None else batch * n
        if jnp.issubdtype(dtype, jnp.floating):
            x = jnp.asarray(
                rng.standard_normal(count).astype(np.float32).reshape(shape)
            ).astype(dtype)
        else:
            info = jnp.iinfo(dtype)
            # draw in the target dtype: uint64's max overflows numpy's
            # default int64 draw bounds
            x = jnp.asarray(
                rng.integers(info.min, info.max, count, endpoint=False,
                             dtype=np.dtype(dtype.name)).reshape(shape)
            )
        cands = _candidates(n, _engines_for(n), dtype.itemsize)
        obs.count("plan_cache.autotune_sweep", family="sort", op=op)
        best_cfg, best_t = SortConfig(), float("inf")
        with obs.trace("plan.autotune", key=key, candidates=len(cands)):
            for cfg in cands:
                t = _bench(_build(op, cfg, k, batch), x)
                if t < best_t:
                    best_cfg, best_t = cfg, t
        self._plans[key] = {
            "config": asdict(best_cfg),
            "engine": best_cfg.engine,
            "us": round(best_t * 1e6, 1),
            "tuned_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        }
        self._save()
        return best_cfg

    def engine_hint(self, n: int, dtype, batch: Optional[int] = None) -> Optional[str]:
        """Persisted engine choice for a same-shape "sort" plan, or None.

        This is what ``SortConfig(engine="auto")`` resolves through
        (``core.ips4o.resolve_engine`` unbatched,
        ``ops.batched.with_engine_batched`` batched): a tuned plan's engine
        wins; a batched caller with no batched plan inherits the unbatched
        row-shape plan's engine (same kernels, same row geometry); with no
        plan at all the caller falls back to the backend heuristic.
        """
        plan = self._plans.get(self._key("sort", n, dtype, None, batch))
        if not isinstance(plan, dict) and batch is not None:
            plan = self._plans.get(self._key("sort", n, dtype, None))
        if not isinstance(plan, dict):
            return None
        engine = plan.get("engine")
        if engine is None:
            cfg = plan.get("config")
            engine = cfg.get("engine") if isinstance(cfg, dict) else None
        return engine if engine in ("xla", "pallas") else None

    # -- clf: key family (classifier-engine races) --------------------------
    @staticmethod
    def _clf_key(n: int, dtype, dist: str, batch: Optional[int] = None) -> str:
        b = f"B={batch}:" if batch is not None else ""
        return f"clf:{b}n={n}:dtype={jnp.dtype(dtype).name}:dist={dist}"

    def classifier_plan(
        self,
        n: int,
        dtype,
        *,
        dist: str = "uniform",
        batch: Optional[int] = None,
        tune: bool = False,
        x: Optional[jax.Array] = None,
    ) -> Optional[str]:
        """Winning classifier engine for (n, dtype, ``dist`` label), or None.

        ``dist`` is a distribution label from
        ``classify.router.distribution_moments`` ("uniform" | "dup" |
        "sorted" | "skew").  A persisted ``clf:`` race wins; ``tune=True``
        runs the race (full-sort wall clocks for tree vs radix vs learned
        — the tentpole's per-moments racing) and persists the winner;
        otherwise None, and the caller falls back to "tree".  The race
        input is a synthetic draw matching the label, unless the caller
        passes the actual array ``x`` (``classifier_for``'s eager path
        does: the label only keys the persisted entry then — the measured
        input is the real workload, which a four-way label can't fully
        stand in for).

        >>> import os, tempfile
        >>> import jax.numpy as jnp
        >>> pc = PlanCache(path=os.path.join(tempfile.mkdtemp(), "p.json"))
        >>> pc.classifier_plan(4096, jnp.uint32) is None  # no race yet
        True
        """
        key = self._clf_key(n, dtype, dist, batch)
        entry = self._plans.get(key)
        if isinstance(entry, dict) and entry.get("winner") in _CLASSIFIER_RACERS:
            obs.count("plan_cache.hit", family="clf", dist=dist)
            return entry["winner"]
        obs.count("plan_cache.miss", family="clf", dist=dist)
        if tune:
            return self._race_classifiers(n, dtype, dist, batch, x)
        return None

    def _race_classifiers(
        self,
        n: int,
        dtype,
        dist: str,
        batch: Optional[int] = None,
        x: Optional[jax.Array] = None,
    ) -> str:
        """Time a full sort per classifier engine — on the caller's actual
        array when given, else on a synthetic draw with the asked-for
        distribution shape; persist and return the winner."""
        key = self._clf_key(n, dtype, dist, batch)
        dtype = jnp.dtype(dtype)
        if x is None:
            shape = (batch, n) if batch is not None else (n,)
            count = n if batch is None else batch * n
            x = jnp.asarray(
                _synthetic_draw(dist, count, dtype).reshape(shape)
            ).astype(dtype)
        times = {}
        with obs.trace("classifier.race", key=key, dist=dist):
            for clf in _CLASSIFIER_RACERS:
                f = _build("sort", SortConfig(classifier=clf), None, batch)
                times[clf] = _bench(f, x)
        winner = min(times, key=times.get)
        obs.count("classifier.race_winner", winner=winner, dist=dist)
        self._plans[key] = {
            "winner": winner,
            "us_per_classifier": {
                c: round(t * 1e6, 1) for c, t in times.items()
            },
            "tuned_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        }
        self._save()
        return winner

    def classifier_hint(
        self,
        n: int,
        dtype,
        batch: Optional[int] = None,
        dist: Optional[str] = None,
    ) -> Optional[str]:
        """Persisted classifier choice for this shape, or None.

        This is what ``SortConfig(classifier="auto")`` resolves through
        (``classify.router.resolve_classifier``).  With a ``dist`` label
        (the eager, data-aware path) the exact ``clf:`` race wins.
        Without one — resolution from shape alone, e.g. under jit — a
        winner is returned only when every raced label for this (n,
        dtype[, B]) agrees (consensus: data-independent by construction);
        failing that, the classifier a tuned same-shape "sort" plan baked
        in.  None means "no evidence": callers default to "tree".
        """
        if dist is not None:
            got = self.classifier_plan(n, dtype, dist=dist, batch=batch)
            if got is not None:
                return got
        prefix = self._clf_key(n, dtype, "", batch)[: -len("dist=")]
        winners = {
            e.get("winner")
            for k, e in self._plans.items()
            if k.startswith(prefix) and isinstance(e, dict)
        } & set(_CLASSIFIER_RACERS)
        if len(winners) == 1:
            return next(iter(winners))
        plan = self._plans.get(self._key("sort", n, dtype, None, batch))
        if isinstance(plan, dict):
            cfg = plan.get("config")
            clf = cfg.get("classifier") if isinstance(cfg, dict) else None
            if clf in _CLASSIFIER_RACERS:
                return clf
        return None

    # -- stream: key family (out-of-core merge geometry) --------------------
    @staticmethod
    def _stream_key(chunk: int, fanin: int, dtype) -> str:
        return f"stream:chunk={chunk}:fanin={fanin}:dtype={jnp.dtype(dtype).name}"

    def stream_plan(
        self,
        chunk: int,
        fanin: int,
        dtype,
        *,
        tune: bool = False,
        engine: Optional[str] = None,
    ) -> StreamPlan:
        """The merge geometry an external sort at (chunk, fanin, dtype)
        should use.  A persisted ``stream:`` plan wins; ``tune=True``
        sweeps (engine x merge tile) on a synthetic pairwise merge at the
        chunk shape and persists the winner; otherwise the backend
        heuristic picks the engine.  An explicit ``engine`` (not
        None/"auto") overrides the engine while keeping the planned tile.

        >>> import os, tempfile
        >>> import jax.numpy as jnp
        >>> pc = PlanCache(path=os.path.join(tempfile.mkdtemp(), "p.json"))
        >>> pc.stream_plan(1024, 4, jnp.float32).engine  # no plan: heuristic
        'xla'
        >>> pc.stream_plan(1024, 4, jnp.float32, engine="pallas").engine
        'pallas'
        """
        if engine == "auto":
            engine = None
        key = self._stream_key(chunk, fanin, dtype)
        entry = self._plans.get(key)
        cfg = entry.get("config") if isinstance(entry, dict) else None
        if isinstance(cfg, dict):
            tile = cfg.get("merge_tile")
            eng = cfg.get("engine")
            if isinstance(tile, int) and eng in ("xla", "pallas"):
                obs.count("plan_cache.hit", family="stream")
                return StreamPlan(chunk, fanin, tile, engine or eng)
        obs.count("plan_cache.miss", family="stream")
        if tune:
            plan = self._autotune_stream(chunk, fanin, dtype)
            if engine is not None:
                plan = dataclasses.replace(plan, engine=engine)
            return plan
        default = engine or (
            "pallas" if jax.default_backend() == "tpu" else "xla"
        )
        return StreamPlan(chunk, fanin, engine=default)

    def _autotune_stream(self, chunk: int, fanin: int, dtype) -> StreamPlan:
        from repro.stream.merge import merge as _merge  # lazy: stream layers on ops

        key = self._stream_key(chunk, fanin, dtype)
        dtype = jnp.dtype(dtype)
        rng = np.random.default_rng(0)
        if jnp.issubdtype(dtype, jnp.floating):
            draw = rng.standard_normal(2 * chunk).astype(np.float32)
            a, b = np.sort(draw[:chunk]), np.sort(draw[chunk:])
            a, b = jnp.asarray(a).astype(dtype), jnp.asarray(b).astype(dtype)
        else:
            info = jnp.iinfo(dtype)
            draw = rng.integers(info.min, info.max, 2 * chunk, endpoint=False,
                                dtype=np.dtype(dtype.name))
            a = jnp.asarray(np.sort(draw[:chunk]))
            b = jnp.asarray(np.sort(draw[chunk:]))
        best, best_t = StreamPlan(chunk, fanin), float("inf")
        for eng in _engines_for(chunk):
            for tile in _stream_tiles():
                f = jax.jit(
                    lambda x, e=eng, t=tile: _merge([x, b], engine=e, tile=t)
                )
                t = _bench(f, a)
                if t < best_t:
                    best, best_t = StreamPlan(chunk, fanin, tile, eng), t
        self._plans[key] = {
            "config": {"merge_tile": best.merge_tile, "engine": best.engine},
            "engine": best.engine,
            "us": round(best_t * 1e6, 1),
            "tuned_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        }
        self._save()
        return best

    # -- dist: key family (multi-level exchange geometry) -------------------
    @staticmethod
    def _dist_key(n_local: int, d: int, dtype) -> str:
        return f"dist:n_local={n_local}:d={d}:dtype={jnp.dtype(dtype).name}"

    def dist_plan(
        self,
        n_local: int,
        d: int,
        dtype,
        *,
        tune: bool = False,
        engine: Optional[str] = None,
    ) -> DistPlan:
        """Capacity factor × oversampling × engine for a distributed sort
        at (n_local, d, dtype) — DESIGN.md §8.  A persisted ``dist:`` plan
        wins; ``tune=True`` runs the host-side capacity simulation and
        persists the winner; otherwise the seed defaults.  An explicit
        ``engine`` (not None/"auto") overrides while keeping the planned
        capacity knobs.

        >>> import os, tempfile
        >>> import jax.numpy as jnp
        >>> pc = PlanCache(path=os.path.join(tempfile.mkdtemp(), "p.json"))
        >>> pc.dist_plan(8192, 8, jnp.float32).slack  # no plan: defaults
        2.0
        >>> pc.dist_plan(8192, 8, jnp.float32, engine="pallas").engine
        'pallas'
        """
        if engine == "auto":
            engine = None
        key = self._dist_key(n_local, d, dtype)
        entry = self._plans.get(key)
        cfg = entry.get("config") if isinstance(entry, dict) else None
        axis_order = self._dist_axis_order(cfg)
        if isinstance(cfg, dict):
            slack = cfg.get("slack")
            ovs = cfg.get("oversample")
            eng = cfg.get("engine")
            if (
                isinstance(slack, (int, float))
                and isinstance(ovs, int)
                and eng in ("xla", "pallas")
            ):
                obs.count("plan_cache.hit", family="dist")
                return DistPlan(
                    n_local, d, float(slack), ovs, engine or eng, axis_order
                )
        obs.count("plan_cache.miss", family="dist")
        if tune:
            plan = self._autotune_dist(n_local, d, dtype)
            if engine is not None:
                plan = dataclasses.replace(plan, engine=engine)
            return dataclasses.replace(plan, axis_order=axis_order)
        from repro.dist.levels import default_oversample  # lazy: dist layers on ops

        default_eng = engine or self.engine_hint(n_local, dtype) or (
            "pallas" if jax.default_backend() == "tpu" else "xla"
        )
        return DistPlan(
            n_local, d, oversample=default_oversample(n_local * d),
            engine=default_eng, axis_order=axis_order,
        )

    @staticmethod
    def _dist_axis_order(cfg: Any) -> Tuple[str, ...]:
        if isinstance(cfg, dict):
            ao = cfg.get("axis_order")
            if isinstance(ao, list) and all(isinstance(a, str) for a in ao):
                return tuple(ao)
        return ()

    def record_dist_axis_order(
        self, n_local: int, d: int, dtype, order: Tuple[str, ...]
    ) -> None:
        """Persist the topology-chosen level order as a dimension of the
        ``dist:`` plan entry (DESIGN.md §13.4) — consulted by later
        ``dist.sort(order="auto")`` calls at the same (n_local, d, dtype),
        and carried through a later capacity autotune of the same entry.

        >>> import os, tempfile
        >>> import jax.numpy as jnp
        >>> pc = PlanCache(path=os.path.join(tempfile.mkdtemp(), "p.json"))
        >>> pc.record_dist_axis_order(8192, 8, jnp.float32, ("pod", "data"))
        >>> pc.dist_plan(8192, 8, jnp.float32).axis_order
        ('pod', 'data')
        """
        key = self._dist_key(n_local, d, dtype)
        entry = self._plans.setdefault(key, {})
        entry.setdefault("config", {})["axis_order"] = [str(a) for a in order]
        self._save()

    def _autotune_dist(self, n_local: int, d: int, dtype) -> DistPlan:
        """Host-side capacity simulation: for ascending (slack, oversample)
        candidates, replay the level-0 splitter selection + equality-bucket
        striping on adversarial synthetic draws (uniform / heavy-duplicate
        / exponential, the skew families of ``data.distributions``) and
        keep the cheapest candidate whose worst per-pair fill stays under
        ``_DIST_FILL_MARGIN`` of capacity.  No devices needed — the
        simulation is numpy — so the sweep is paid once per machine like
        every other plan family."""
        from repro.dist.levels import default_oversample, plan_schedule

        key = self._dist_key(n_local, d, dtype)
        dtype = jnp.dtype(dtype)
        n = n_local * d
        base_ovs = default_oversample(n)

        def draws(rng):
            if jnp.issubdtype(dtype, jnp.floating):
                yield rng.standard_normal(n_local).astype(np.float32)
                yield rng.exponential(size=n_local).astype(np.float32)
                yield rng.choice(97, size=n_local).astype(np.float32)  # dup-heavy
            else:
                yield rng.integers(0, 1 << 30, n_local, dtype=np.int64)
                yield rng.integers(0, 97, n_local, dtype=np.int64)  # dup-heavy
                yield (rng.exponential(size=n_local) * (1 << 20)).astype(np.int64)

        def worst_fill(slack: float, oversample: int) -> float:
            cap = plan_schedule(
                {"x": d}, "x", n_local, slack=slack, oversample=oversample
            )[0].capacity
            worst = 0.0
            for seed in range(3):
                rng = np.random.default_rng(seed)
                for x in draws(rng):
                    # one shard's post-pre-exchange stripe: representative
                    # of the global distribution by construction
                    sample = rng.choice(x, size=min(oversample * d, n_local))
                    spl = np.sort(sample)[
                        np.clip((np.arange(1, d) * len(sample)) // d,
                                0, len(sample) - 1)
                    ]
                    lo = np.searchsorted(spl, x, side="left")
                    hi = np.searchsorted(spl, x, side="right")
                    span = np.maximum(hi - lo + 1, 1)
                    # the same hashed equality striping the device classifier
                    # uses (exchange._classify) — a raw pos % span would
                    # validate the slack against a different pipeline
                    pos = (
                        np.arange(n_local, dtype=np.uint64) * 2654435761
                    ) & 0xFFFFFFFF
                    stripe = (pos >> 16).astype(np.int64) % span
                    dest = np.minimum(lo + stripe, d - 1)
                    counts = np.bincount(dest, minlength=d)
                    worst = max(worst, counts.max() / cap)
            return worst

        best = None
        for slack in _DIST_SLACKS:
            for mul in _DIST_OVERSAMPLE_MULS:
                ovs = base_ovs * mul
                fill = worst_fill(slack, ovs)
                if fill <= _DIST_FILL_MARGIN:
                    best = DistPlan(n_local, d, slack, ovs)
                    break
            if best is not None:
                break
        if best is None:  # every candidate overflowed: largest headroom
            best = DistPlan(
                n_local, d, _DIST_SLACKS[-1],
                base_ovs * _DIST_OVERSAMPLE_MULS[-1],
            )
            fill = worst_fill(best.slack, best.oversample)
        eng = self.engine_hint(n_local, dtype) or (
            "pallas" if jax.default_backend() == "tpu" else "xla"
        )
        best = dataclasses.replace(best, engine=eng)
        prev = self._plans.get(key)
        prev_order = self._dist_axis_order(
            prev.get("config") if isinstance(prev, dict) else None
        )
        self._plans[key] = {
            "config": {
                "slack": best.slack,
                "oversample": best.oversample,
                "engine": best.engine,
                # a recorded topology order survives a capacity re-tune
                **({"axis_order": list(prev_order)} if prev_order else {}),
            },
            "engine": best.engine,
            "sim_max_fill": round(float(fill), 3),
            "tuned_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        }
        self._save()
        return best

    # -- public entry -------------------------------------------------------
    def get_sorter(
        self,
        n: int,
        dtype,
        op: str = "sort",
        *,
        k: Optional[int] = None,
        tune: bool = False,
        batch: Optional[int] = None,
    ) -> Callable:
        """Cached jitted callable for ``op`` over (n,)-shaped ``dtype`` keys
        — or, with ``batch=B``, over (B, n)-shaped keys via the
        ``repro.ops.batched`` entry points (plans keyed per (op, B, n,
        dtype), so ragged batch shapes each get their own plan).

        ``k`` is required (and static) for "topk"/"bottomk".  With
        ``tune=True`` a missing plan triggers the autotune sweep; the
        result is persisted so later processes skip it.
        """
        if op not in _OPS:
            raise ValueError(f"unknown op {op!r}; expected one of {_OPS}")
        if op in ("topk", "bottomk") and k is None:
            raise ValueError(f"op={op!r} requires k")
        key = self._key(op, n, dtype, k, batch)
        f = self._compiled.get(key)
        # tune=True with no persisted plan must not be satisfied by an
        # untuned memoized callable — run the sweep and rebuild
        if f is None or (tune and key not in self._plans):
            obs.count("plan_cache.compiled_miss", op=op)
            f = _build(op, self.config_for(op, n, dtype, k, tune=tune, batch=batch), k, batch)
            self._compiled[key] = f
        else:
            obs.count("plan_cache.compiled_hit", op=op)
        return f


default_cache = PlanCache()


def get_sorter(n: int, dtype, op: str = "sort", **kw) -> Callable:
    """Module-level convenience over the default :class:`PlanCache`.

    >>> import jax.numpy as jnp
    >>> f = get_sorter(4, jnp.int32, op="argsort")
    >>> f(jnp.asarray([30, 10, 20, 0])).tolist()
    [3, 1, 2, 0]
    """
    return default_cache.get_sorter(n, dtype, op, **kw)
