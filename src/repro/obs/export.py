"""Exporters: JSONL event log, Chrome trace-event file, human summary.

* :func:`export_jsonl` — one JSON object per line, ``type`` in
  ``{span, event, counter, gauge, histogram}``.  The machine-readable
  archive; ``benchmarks/report.py --trace`` builds its per-phase
  attribution table from the span lines.
* :func:`export_chrome_trace` — the Chrome trace-event format
  (``{"traceEvents": [...]}``, complete ``ph:"X"`` events in µs).  Open
  in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.
* :func:`summary` — a plain-text table of span stats, counters, gauges
  and histogram summaries for terminals and CI logs.
* :func:`timed_min` — min-of-k measurement through the tracer: each
  iteration is a recorded span around ``block_until_ready(fn())``, so
  benches get jitter-resistant numbers *and* the spans land in exports.
"""
from __future__ import annotations

import json
import time
from typing import Any, Callable, Dict, List, Optional

from repro.obs import metrics, tracer

__all__ = [
    "export_chrome_trace",
    "export_jsonl",
    "span_stats",
    "summary",
    "timed_min",
]


def _snapshot(rec: tracer.Recorder) -> Dict[str, Any]:
    with rec._lock:
        return {"spans": list(rec.spans), "events": list(rec.events)}


def span_stats(rec: Optional[tracer.Recorder] = None) -> Dict[str, Dict[str, float]]:
    """Per-name aggregates over recorded spans: count, total/min/max ns."""
    rec = rec or tracer.recorder()
    out: Dict[str, Dict[str, float]] = {}
    for s in _snapshot(rec)["spans"]:
        a = out.setdefault(
            s["name"], {"count": 0, "total_ns": 0, "min_ns": None, "max_ns": 0}
        )
        d = s["dur_ns"]
        a["count"] += 1
        a["total_ns"] += d
        a["min_ns"] = d if a["min_ns"] is None else min(a["min_ns"], d)
        a["max_ns"] = max(a["max_ns"], d)
    return out


def export_jsonl(path: str, rec: Optional[tracer.Recorder] = None) -> None:
    """Write every span, event and metric series as one JSON line each."""
    rec = rec or tracer.recorder()
    snap = _snapshot(rec)
    mets = metrics.metrics_snapshot(rec)
    with open(path, "w") as fh:
        for s in snap["spans"]:
            fh.write(json.dumps({
                "type": "span", "name": s["name"], "id": s["id"],
                "parent": s["parent"], "depth": s["depth"],
                "ts_us": s["t0_ns"] / 1e3, "dur_us": s["dur_ns"] / 1e3,
                "tid": s["tid"], "attrs": s["attrs"],
            }) + "\n")
        for e in snap["events"]:
            fh.write(json.dumps({
                "type": "event", "name": e["name"],
                "ts_us": e["t_ns"] / 1e3, "attrs": e["attrs"],
            }) + "\n")
        for kind in ("counter", "gauge"):
            for m in mets[kind + "s"]:
                fh.write(json.dumps(dict(m, type=kind)) + "\n")
        for m in mets["histograms"]:
            fh.write(json.dumps(dict(m, type="histogram")) + "\n")


def export_chrome_trace(path: str, rec: Optional[tracer.Recorder] = None) -> None:
    """Write a Chrome trace-event JSON viewable in Perfetto.

    Spans become complete (``ph:"X"``) events with µs timestamps;
    point events become instants (``ph:"i"``); final counter values
    become ``ph:"C"`` samples at the trace end.
    """
    rec = rec or tracer.recorder()
    snap = _snapshot(rec)
    mets = metrics.metrics_snapshot(rec)
    tids = {}
    evs: List[Dict[str, Any]] = [{
        "ph": "M", "name": "process_name", "pid": 0,
        "args": {"name": "repro.obs"},
    }]
    end_us = 0.0
    for s in snap["spans"]:
        tid = tids.setdefault(s["tid"], len(tids))
        ts = s["t0_ns"] / 1e3
        dur = s["dur_ns"] / 1e3
        end_us = max(end_us, ts + dur)
        evs.append({
            "name": s["name"], "cat": "span", "ph": "X",
            "ts": ts, "dur": dur, "pid": 0, "tid": tid,
            "args": s["attrs"],
        })
    for e in snap["events"]:
        ts = e["t_ns"] / 1e3
        end_us = max(end_us, ts)
        evs.append({
            "name": e["name"], "cat": "event", "ph": "i", "s": "p",
            "ts": ts, "pid": 0, "tid": 0, "args": e["attrs"],
        })
    for m in mets["counters"]:
        label = ",".join(f"{k}={v}" for k, v in sorted(m["labels"].items()))
        name = m["name"] + (f"{{{label}}}" if label else "")
        evs.append({
            "name": name, "cat": "metric", "ph": "C",
            "ts": end_us, "pid": 0, "tid": 0,
            "args": {"value": m["value"]},
        })
    with open(path, "w") as fh:
        json.dump({"traceEvents": evs, "displayTimeUnit": "ms"}, fh)


def _fmt_labels(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f"{k}={v}" for k, v in sorted(labels.items())) + "}"


def summary(rec: Optional[tracer.Recorder] = None) -> str:
    """Human-readable table of spans, counters, gauges, histograms."""
    rec = rec or tracer.recorder()
    stats = span_stats(rec)
    mets = metrics.metrics_snapshot(rec)
    n_events = len(_snapshot(rec)["events"])
    lines = ["== repro.obs summary =="]
    if stats:
        lines.append(f"-- spans ({sum(a['count'] for a in stats.values())}) --")
        w = max(len(n) for n in stats)
        for name in sorted(stats):
            a = stats[name]
            lines.append(
                f"  {name:<{w}}  count={a['count']:<5d} "
                f"min={a['min_ns'] / 1e3:>10.1f}us "
                f"total={a['total_ns'] / 1e6:>10.2f}ms"
            )
    if mets["counters"]:
        lines.append(f"-- counters ({len(mets['counters'])}) --")
        for m in mets["counters"]:
            lines.append(
                f"  {m['name']}{_fmt_labels(m['labels'])} = {m['value']:g}"
            )
    if mets["gauges"]:
        lines.append(f"-- gauges ({len(mets['gauges'])}) --")
        for m in mets["gauges"]:
            lines.append(
                f"  {m['name']}{_fmt_labels(m['labels'])} = {m['value']:g}"
            )
    if mets["histograms"]:
        lines.append(f"-- histograms ({len(mets['histograms'])}) --")
        for m in mets["histograms"]:
            mean = m["sum"] / max(m["count"], 1)
            lines.append(
                f"  {m['name']}{_fmt_labels(m['labels'])} "
                f"count={m['count']} mean={mean:g} "
                f"min={m['min']:g} max={m['max']:g}"
            )
    if n_events:
        lines.append(f"-- events ({n_events}) --")
        for e in _snapshot(rec)["events"]:
            lines.append(f"  {e['name']} {e['attrs']}")
    if len(lines) == 1:
        lines.append("  (empty)")
    return "\n".join(lines)


def timed_min(
    name: str,
    fn: Callable[[], Any],
    *,
    iters: int = 9,
    warmup: int = 2,
    recorder: Optional[tracer.Recorder] = None,
    **attrs: Any,
) -> float:
    """Min-of-``iters`` wall time (seconds) of ``block_until_ready(fn())``.

    Each iteration is recorded as a span named ``name`` (attrs carry the
    iteration index), into ``recorder`` or the global recorder — the
    explicit-span path records even while obs is globally disabled, so
    benches always leave a trace of how a number was produced.
    """
    import jax

    rec = tracer.recorder() if recorder is None else recorder
    for _ in range(max(0, warmup)):
        jax.block_until_ready(fn())
    best = float("inf")
    for i in range(max(1, iters)):
        with tracer._Span(rec, name, dict(attrs, iter=i)):
            t0 = time.perf_counter_ns()
            jax.block_until_ready(fn())
            dt = time.perf_counter_ns() - t0
        best = min(best, dt)
    return best / 1e9
