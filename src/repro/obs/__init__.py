"""repro.obs — structured tracing, metrics, and profiling hooks.

Low-overhead, **off-by-default** observability for the whole sort
engine (DESIGN.md §12).  Enable with ``REPRO_OBS=1`` in the environment
or ``obs.enabled(True)`` at runtime; while disabled every hook is a
no-op that adds **zero traced ops and no host syncs** (verified by the
jaxpr-identity test in ``tests/test_obs.py``).

Quickstart::

    from repro import obs, ops

    obs.enabled(True)
    out = ops.sort(x)                      # spans + metrics recorded
    print(obs.summary())                   # human table
    obs.export_jsonl("sort.jsonl")         # machine archive
    obs.export_chrome_trace("sort.trace.json")  # open in Perfetto

Three layers:

* **Tracer** — ``obs.trace(name, **attrs)`` span context managers with
  host-side timing (callers hold ``block_until_ready`` discipline; see
  ``obs.block``/``obs.timed_min``) plus ``jax.profiler.TraceAnnotation``
  and ``jax.named_scope`` pass-through, so spans also land in XLA
  profiles.
* **Metrics** — counters/gauges/histograms, host-side (``count`` /
  ``gauge`` / ``observe``) and in-jit (``jit_count`` / ``jit_observe`` /
  ``jit_event``, staged as unordered ``jax.debug.callback`` only when
  obs is enabled at trace time).
* **Exporters** — ``export_jsonl`` (JSONL event log),
  ``export_chrome_trace`` (Perfetto-viewable Chrome trace-event file),
  ``summary()`` (human table).

Instrumented call sites: ``core/ips4o.py`` (per-level spans,
bucket-imbalance / base-case / fallback stats), ``ops/plan.py``
(plan-cache hit/miss/autotune, classifier races), ``classify/router.py``
(routing decisions), ``dist/exchange.py`` (re-split rounds, collective
volume, overflow events), ``stream/api.py`` (spill bytes, tournament
rounds), ``serve/scheduler.py`` (admission), ``launch/roofline.py``
(chosen ``KernelLaunchSpec`` per launch).
"""
from repro.obs.export import (
    export_chrome_trace,
    export_jsonl,
    span_stats,
    summary,
    timed_min,
)
from repro.obs.metrics import (
    count,
    counter_value,
    gauge,
    hist_values,
    jit_count,
    jit_event,
    jit_observe,
    metrics_snapshot,
    observe,
)
from repro.obs.tracer import (
    Recorder,
    block,
    enabled,
    events,
    recorder,
    reset,
    trace,
)

__all__ = [
    "Recorder",
    "block",
    "count",
    "counter_value",
    "enabled",
    "events",
    "export_chrome_trace",
    "export_jsonl",
    "gauge",
    "hist_values",
    "jit_count",
    "jit_event",
    "jit_observe",
    "metrics_snapshot",
    "observe",
    "recorder",
    "reset",
    "span_stats",
    "summary",
    "timed_min",
    "trace",
]
