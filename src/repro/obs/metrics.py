"""Metrics registry: counters, gauges, histograms — host-side and in-jit.

Two families of hooks (DESIGN.md §12 purity rules):

* **Host-side** (``count`` / ``gauge`` / ``observe``): called from plain
  Python — plan-cache lookups, router decisions, stream spills, launch
  specs.  With obs disabled each is a single dict-lookup-and-return.

* **In-jit** (``jit_count`` / ``jit_observe`` / ``jit_event``): called
  from inside traced code with traced values.  The in-jit stats are pure
  functions of traced arrays; delivery to the host registry rides an
  *unordered* ``jax.debug.callback`` (ordered effects are disallowed
  under ``lax.cond``, which the robustness fallback and the tie-break
  schedule both use).  When obs is disabled **at trace time** these
  stage nothing at all — zero added jaxpr equations, verified by the
  jaxpr-identity test in ``tests/test_obs.py``.

``gate=`` on the jit hooks takes a traced boolean: the callback still
runs host-side on every shard/invocation, but records only when the
gate is true — used to deduplicate pmax-replicated values under
``shard_map`` by gating on ``axis_index(...) == 0``.
"""
from __future__ import annotations

import logging
from typing import Any, Dict, List, Optional

from repro.obs import tracer

__all__ = [
    "count",
    "counter_value",
    "gauge",
    "hist_values",
    "jit_count",
    "jit_event",
    "jit_observe",
    "metrics_snapshot",
    "observe",
]

_LOG = logging.getLogger("repro.obs")


def _labels_key(labels: Dict[str, Any]) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


# -- host-side hooks ------------------------------------------------------

def count(name: str, value: float = 1, **labels: Any) -> None:
    """Increment counter ``name`` (one series per distinct label set)."""
    if not tracer._STATE["enabled"]:
        return
    tracer._RECORDER.add_count(name, float(value), _labels_key(labels))


def gauge(name: str, value: float, **labels: Any) -> None:
    """Set gauge ``name`` to its latest value."""
    if not tracer._STATE["enabled"]:
        return
    tracer._RECORDER.set_gauge(name, float(value), _labels_key(labels))


def observe(name: str, value: float, **labels: Any) -> None:
    """Record one observation into histogram ``name``."""
    if not tracer._STATE["enabled"]:
        return
    tracer._RECORDER.add_observation(name, float(value), _labels_key(labels))


# -- in-jit hooks (staged only when obs is enabled at trace time) ---------

def jit_count(name: str, value: Any, **labels: Any) -> None:
    """Counter increment by a traced value, delivered via an unordered
    debug callback at execution time.  No-op (zero added eqns) when obs
    is disabled at trace time."""
    if not tracer._STATE["enabled"]:
        return
    import jax
    import numpy as np

    key = _labels_key(labels)

    def _cb(v: Any, _n: str = name, _k: tuple = key) -> None:
        tracer._RECORDER.add_count(_n, float(np.asarray(v).sum()), _k)

    jax.debug.callback(_cb, value)


def jit_observe(
    name: str, value: Any, *, gate: Any = None, **labels: Any
) -> None:
    """Histogram observation(s) from a traced array; ``gate`` (traced
    bool) suppresses recording at runtime — e.g. lead-shard gating of
    pmax-replicated values under ``shard_map``."""
    if not tracer._STATE["enabled"]:
        return
    import jax
    import numpy as np

    key = _labels_key(labels)

    def _cb(g: Any, v: Any, _n: str = name, _k: tuple = key) -> None:
        if not bool(np.all(np.asarray(g))):
            return
        for x in np.asarray(v, dtype=np.float64).reshape(-1).tolist():
            tracer._RECORDER.add_observation(_n, x, _k)

    jax.debug.callback(_cb, True if gate is None else gate, value)


def jit_event(
    name: str,
    payload: Dict[str, Any],
    *,
    gate: Any = None,
    warn: Optional[str] = None,
    **labels: Any,
) -> None:
    """Point event from inside jit.  ``payload`` maps attr names to
    traced arrays (delivered host-side as the event's attrs, next to the
    static ``labels``); ``warn`` additionally logs one line on the
    ``repro.obs`` logger when the gated event fires."""
    if not tracer._STATE["enabled"]:
        return
    import jax
    import numpy as np

    names = tuple(payload)
    static = {str(k): v for k, v in labels.items()}

    def _cb(g: Any, *vals: Any, _n: str = name, _w: Optional[str] = warn) -> None:
        if not bool(np.all(np.asarray(g))):
            return
        attrs: Dict[str, Any] = dict(static)
        for k, v in zip(names, vals):
            a = np.asarray(v)
            attrs[k] = a.item() if a.size == 1 else a.tolist()
        tracer._RECORDER.add_event(_n, attrs)
        if _w:
            _LOG.warning(
                "%s (%s)", _w,
                ", ".join(f"{k}={attrs[k]}" for k in names),
            )

    jax.debug.callback(_cb, True if gate is None else gate, *payload.values())


# -- read side ------------------------------------------------------------

def _match(key: tuple, name: str, labels: Dict[str, Any]) -> bool:
    if key[0] != name:
        return False
    have = dict(key[1])
    return all(have.get(str(k)) == str(v) for k, v in labels.items())


def counter_value(name: str, **labels: Any) -> float:
    """Sum of all counter series matching ``name`` and the given label
    subset (no labels ⇒ all series of that name)."""
    rec = tracer._RECORDER
    with rec._lock:
        items = list(rec.counters.items())
    return sum(v for k, v in items if _match(k, name, labels))


def hist_values(name: str, **labels: Any) -> List[float]:
    """Concatenated retained observations of matching histogram series."""
    rec = tracer._RECORDER
    with rec._lock:
        items = [(k, list(h["values"])) for k, h in rec.hists.items()]
    out: List[float] = []
    for k, vals in items:
        if _match(k, name, labels):
            out.extend(vals)
    return out


def metrics_snapshot(rec: Optional[tracer.Recorder] = None) -> Dict[str, Any]:
    """JSON-ready snapshot of every metric series."""
    rec = rec or tracer._RECORDER
    with rec._lock:
        return {
            "counters": [
                {"name": k[0], "labels": dict(k[1]), "value": v}
                for k, v in sorted(rec.counters.items())
            ],
            "gauges": [
                {"name": k[0], "labels": dict(k[1]), "value": v}
                for k, v in sorted(rec.gauges.items())
            ],
            "histograms": [
                {"name": k[0], "labels": dict(k[1]),
                 "count": h["count"], "sum": h["sum"],
                 "min": h["min"], "max": h["max"],
                 "values": list(h["values"])}
                for k, h in sorted(rec.hists.items())
            ],
        }
