"""Span tracer: host-side structured timing with XLA-profile pass-through.

The tracer is the *structural* half of ``repro.obs`` (DESIGN.md §12).
``trace(name, **attrs)`` returns a context manager that records a span —
name, wall-clock duration, parent span, static attributes — into the
process-global :class:`Recorder`.  Two regimes, one API:

* around **eager or already-jitted executions**, a span measures real
  wall time (callers follow ``block_until_ready`` discipline, or use
  :func:`repro.obs.timed_min` which enforces it);
* inside **traced code**, a span measures trace time and contributes
  structure (the nesting of sample/classify/partition under a level
  pass).  Runtime signals from inside jit travel separately, through the
  ``jit_*`` metric hooks in :mod:`repro.obs.metrics`.

Every span also best-effort enters ``jax.profiler.TraceAnnotation`` and
``jax.named_scope``, so the same names land in XLA profiles and HLO
metadata when a device profiler is attached.

Disabled (the default — enable with ``REPRO_OBS=1`` or
``obs.enabled(True)``), ``trace`` returns a shared allocation-free null
span: no lock, no clock read, no jax import side effects, zero added
traced ops.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Any, Dict, List, Optional

__all__ = [
    "Recorder",
    "block",
    "enabled",
    "events",
    "recorder",
    "reset",
    "trace",
]

_TRUTHY = ("1", "true", "True", "yes", "on")
_STATE = {"enabled": os.environ.get("REPRO_OBS", "") in _TRUTHY}


def enabled(value: Optional[bool] = None) -> bool:
    """Get (no args) or set the global obs enable flag.

    Note the jit-cache caveat: programs compiled while obs was disabled
    stay uninstrumented (and vice versa) until retraced — toggling does
    NOT call ``jax.clear_caches()``.  Tests and the bench exporter clear
    explicitly when they need a re-trace.
    """
    if value is not None:
        _STATE["enabled"] = bool(value)
    return _STATE["enabled"]


class Recorder:
    """Accumulates spans, point events, and metric aggregates.

    One process-global instance backs the module-level API; explicit
    instances can be passed to ``trace(..., recorder=...)`` /
    ``timed_min(..., recorder=...)`` for isolated measurement.

    Metric keys are ``(name, ((label, value), ...))`` with labels sorted,
    so the same name with different labels forms distinct series.
    """

    #: cap on raw values retained per histogram series (count/sum/min/max
    #: keep aggregating past it)
    HIST_CAP = 4096

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._local = threading.local()
        self._next_id = 0
        self.origin_ns = time.perf_counter_ns()
        self.spans: List[Dict[str, Any]] = []
        self.events: List[Dict[str, Any]] = []
        self.counters: Dict[tuple, float] = {}
        self.gauges: Dict[tuple, float] = {}
        self.hists: Dict[tuple, Dict[str, Any]] = {}

    def clear(self) -> None:
        with self._lock:
            self._next_id = 0
            self.origin_ns = time.perf_counter_ns()
            self.spans.clear()
            self.events.clear()
            self.counters.clear()
            self.gauges.clear()
            self.hists.clear()

    # -- span bookkeeping -------------------------------------------------
    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _new_id(self) -> int:
        with self._lock:
            i = self._next_id
            self._next_id += 1
        return i

    def add_span(self, span: Dict[str, Any]) -> None:
        with self._lock:
            self.spans.append(span)

    # -- metrics (called from metrics.py and from debug callbacks) --------
    def add_event(self, name: str, attrs: Dict[str, Any]) -> None:
        ev = {
            "name": name,
            "t_ns": time.perf_counter_ns() - self.origin_ns,
            "attrs": attrs,
        }
        with self._lock:
            self.events.append(ev)

    def add_count(self, name: str, value: float, labels: tuple) -> None:
        key = (name, labels)
        with self._lock:
            self.counters[key] = self.counters.get(key, 0.0) + value

    def set_gauge(self, name: str, value: float, labels: tuple) -> None:
        with self._lock:
            self.gauges[(name, labels)] = value

    def add_observation(self, name: str, value: float, labels: tuple) -> None:
        key = (name, labels)
        with self._lock:
            h = self.hists.get(key)
            if h is None:
                h = self.hists[key] = {
                    "count": 0, "sum": 0.0, "min": value, "max": value,
                    "values": [],
                }
            h["count"] += 1
            h["sum"] += value
            h["min"] = min(h["min"], value)
            h["max"] = max(h["max"], value)
            if len(h["values"]) < self.HIST_CAP:
                h["values"].append(value)


_RECORDER = Recorder()


def recorder() -> Recorder:
    """The process-global recorder (stable identity across ``reset``)."""
    return _RECORDER


def reset() -> None:
    """Clear the global recorder in place (identity preserved, so staged
    debug callbacks keep pointing at the live recorder)."""
    _RECORDER.clear()


def events(name: Optional[str] = None) -> List[Dict[str, Any]]:
    """Recorded point events, optionally filtered by name."""
    with _RECORDER._lock:
        evs = list(_RECORDER.events)
    return evs if name is None else [e for e in evs if e["name"] == name]


class _NullSpan:
    """Shared no-op span returned while obs is disabled."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False

    def set(self, **attrs: Any) -> "_NullSpan":
        return self


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("rec", "name", "attrs", "id", "parent", "depth", "t0",
                 "_ann", "_scope")

    def __init__(self, rec: Recorder, name: str, attrs: Dict[str, Any]):
        self.rec = rec
        self.name = name
        self.attrs = attrs

    def set(self, **attrs: Any) -> "_Span":
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "_Span":
        rec = self.rec
        stack = rec._stack()
        self.parent = stack[-1].id if stack else None
        self.depth = len(stack)
        self.id = rec._new_id()
        stack.append(self)
        self._ann = self._scope = None
        try:  # profiler pass-through is best-effort: never fail the workload
            import jax

            self._ann = jax.profiler.TraceAnnotation(self.name)
            self._ann.__enter__()
            self._scope = jax.named_scope(self.name)
            self._scope.__enter__()
        except Exception:
            pass
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type: Any, exc: Any, tb: Any) -> bool:
        dur = time.perf_counter_ns() - self.t0
        for cm in (self._scope, self._ann):
            if cm is not None:
                try:
                    cm.__exit__(exc_type, exc, tb)
                except Exception:
                    pass
        rec = self.rec
        stack = rec._stack()
        if stack and stack[-1] is self:
            stack.pop()
        rec.add_span({
            "id": self.id,
            "parent": self.parent,
            "name": self.name,
            "t0_ns": self.t0 - rec.origin_ns,
            "dur_ns": dur,
            "depth": self.depth,
            "tid": threading.get_ident(),
            "attrs": dict(self.attrs),
        })
        return False


def trace(name: str, *, recorder: Optional[Recorder] = None, **attrs: Any):
    """Span context manager: ``with obs.trace("level_pass", level=1): ...``.

    With obs disabled and no explicit ``recorder``, returns a shared
    no-op span (allocation-free fast path).  An explicit ``recorder``
    records regardless of the global flag — that is how
    :func:`repro.obs.timed_min` measures with obs off.
    """
    rec = recorder
    if rec is None:
        if not _STATE["enabled"]:
            return _NULL_SPAN
        rec = _RECORDER
    return _Span(rec, name, attrs)


def block(x: Any) -> Any:
    """``jax.block_until_ready(x)`` when obs is enabled and ``x`` is
    concrete; identity otherwise.

    Used at op boundaries so an enclosing span measures real execution
    time on the eager path without adding a host sync when obs is off,
    and without breaking tracing (Tracers pass through untouched).
    """
    if not _STATE["enabled"]:
        return x
    try:
        import jax

        return jax.block_until_ready(x)
    except Exception:
        return x
