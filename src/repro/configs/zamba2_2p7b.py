"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242].  54 Mamba2 layers; ONE shared attention block (single
param set) applied after every 6 SSM layers.  Sub-quadratic (the shared
attention runs a 4k sliding window for long contexts) -> long_500k RUNS.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    head_dim=80,
    ssm=SSMConfig(kind="mamba2", d_state=64, d_conv=4, expand=2,
                  head_dim=64, attn_every=6),
    sub_quadratic=True,
)
