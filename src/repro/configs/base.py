"""Model configuration schema for all assigned architectures."""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

__all__ = ["MoEConfig", "SSMConfig", "ModelConfig", "reduced"]


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0           # shared (always-on) experts
    d_ff_shared: int = 0          # hidden dim of the shared expert(s)
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba2"          # "mamba2" | "rwkv6"
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2               # d_inner = expand * d_model
    head_dim: int = 64            # rwkv6 time-mix head dim
    attn_every: int = 0           # hybrid: shared attn block after every N
                                  # ssm layers (0 = never)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rope_theta: float = 5e5
    norm_eps: float = 1e-5
    attn_bias: bool = False       # qwen1.5-style qkv bias
    tie_embeddings: bool = False
    frontend: Optional[str] = None  # "vit_stub" | "encodec_stub" (embeds in)
    sub_quadratic: bool = False   # long_500k applicability
    remat: bool = True            # activation checkpointing per layer

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def takes_embeds(self) -> bool:
        return self.frontend is not None


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Shrink a config for CPU smoke tests, preserving the family shape."""
    small = dict(
        num_layers=min(cfg.num_layers, 4 if cfg.ssm and cfg.ssm.attn_every else 2),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 4) if cfg.num_kv_heads < cfg.num_heads else 4,
        d_ff=256,
        vocab_size=512,
        head_dim=32,
    )
    if cfg.moe:
        small["moe"] = replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, 8),
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=64,
            d_ff_shared=min(cfg.moe.d_ff_shared, 128) if cfg.moe.d_ff_shared else 0,
            # lossless capacity (cap >= n*top_k): smoke tests need routing to
            # be drop-free so prefill/decode exactly match the full forward
            capacity_factor=float(min(cfg.moe.num_experts, 8)),
        )
    if cfg.ssm:
        small["ssm"] = replace(
            cfg.ssm,
            d_state=16,
            head_dim=16,
            attn_every=2 if cfg.ssm.attn_every else 0,
        )
        if cfg.ssm.attn_every:
            small["num_layers"] = 4
    small.update(overrides)
    return replace(cfg, **small)
