"""rwkv6-1.6b [ssm] — "Finch", attention-free, data-dependent decay
[arXiv:2404.05892].  Sub-quadratic -> long_500k RUNS."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,        # derived: d_model / head_dim (time-mix heads)
    num_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    head_dim=64,
    ssm=SSMConfig(kind="rwkv6", head_dim=64),
    sub_quadratic=True,
)
