"""deepseek-moe-16b [moe] — fine-grained: 2 shared + 64 routed top-6,
d_ff_expert=1408 [arXiv:2401.06066].  MoE dispatch = the paper's sort-based
distribution machinery (DESIGN.md §3)."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    head_dim=128,
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                  num_shared=2, d_ff_shared=2816),
    sub_quadratic=False,
)
