"""The paper's own tuning parameters (§4.7) and our TPU-adapted defaults.

Paper (x86 multicore, C++):
    k = 256 buckets, alpha = 0.2 log n oversampling, beta = 1
    overpartitioning, base case n0 = 16 (insertion sort), block size
    b = max(1, 2^(11 - log2 s)) elements (~2 KiB).

TPU adaptation (DESIGN.md §2): the base case is a VMEM-resident window
(n0 = 8192 elements, not 16 — VMEM plays the role of L1/L2 and a
*vectorized* bitonic pass replaces insertion sort), k is capped at 128 per
level to bound the splitter-compare broadcast, and the distribution tile
(4096) plays the role of the 2 KiB buffer block.  ``alpha`` is the paper's
0.2 log n (see core/sampling.oversampling_factor).
"""
from __future__ import annotations

from repro.core.ips4o import SortConfig

__all__ = ["PAPER_CPU", "TPU_DEFAULT", "TPU_BIG_PAYLOAD"]

# The paper's values, recorded for reference (running them verbatim on TPU
# is pessimal: n0 = 16 would mean ~n/16 window sorts of 16 elements).
PAPER_CPU = {
    "k": 256,
    "alpha": "0.2 * log2(n)",
    "beta": 1,
    "n0": 16,
    "block_bytes": 2048,
}

# Our defaults (= SortConfig defaults; benchmarks use these).
TPU_DEFAULT = SortConfig()

# Large payloads move twice per pass (the paper's own §6 caveat for
# Quartet/100Bytes): fewer, larger buckets per level cut pass count.
TPU_BIG_PAYLOAD = SortConfig(base_case=16384, kmax=64, tile=8192)
