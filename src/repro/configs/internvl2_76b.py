"""internvl2-76b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821].

The ViT frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch/token embeddings (B, S, d_model); only the 80-layer
InternLM2 transformer backbone is modelled.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    frontend="vit_stub",
    sub_quadratic=False,
)
