"""musicgen-medium [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284].  The EnCodec frontend is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    head_dim=64,
    frontend="encodec_stub",
    sub_quadratic=False,
)
