"""Architecture registry + assigned input shapes + ShapeDtypeStruct specs.

The 10 assigned architectures x 4 LM shapes = 40 dry-run cells.  ``decode_*``
and ``long_*`` lower ``serve_step`` (one token + cache); ``train_4k`` lowers
``train_step``; ``prefill_32k`` lowers the prefill step.  ``long_500k`` is
only applicable to sub-quadratic archs (zamba2, rwkv6) — the eight
full-attention archs skip it (recorded in DESIGN.md §5).
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, reduced

__all__ = [
    "ARCHS", "SHAPES", "get_config", "get_reduced", "cells",
    "input_specs", "Shape",
]

_MODULES = {
    "internvl2-76b": "internvl2_76b",
    "llama3-405b": "llama3_405b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "yi-9b": "yi_9b",
    "zamba2-2.7b": "zamba2_2p7b",
    "rwkv6-1.6b": "rwkv6_1p6b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "musicgen-medium": "musicgen_medium",
}
ARCHS: List[str] = list(_MODULES)


@dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_reduced(arch: str, **overrides) -> ModelConfig:
    return reduced(get_config(arch), **overrides)


def shape_applicable(cfg: ModelConfig, shape: Shape) -> bool:
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True


def cells(include_inapplicable: bool = False):
    """All (arch, shape) dry-run cells (40 assigned; 38 applicable)."""
    out = []
    for a in ARCHS:
        cfg = get_config(a)
        for s in SHAPES.values():
            if include_inapplicable or shape_applicable(cfg, s):
                out.append((a, s.name))
    return out


def input_specs(cfg: ModelConfig, shape: Shape) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell —
    weak-type-correct, shardable, no device allocation."""
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        if cfg.takes_embeds:
            inputs = sds((b, s, cfg.d_model), jnp.bfloat16)
        else:
            inputs = sds((b, s), jnp.int32)
        return {"inputs": inputs, "labels": sds((b, s), jnp.int32)}
    if shape.kind == "prefill":
        if cfg.takes_embeds:
            return {"inputs": sds((b, s, cfg.d_model), jnp.bfloat16)}
        return {"inputs": sds((b, s), jnp.int32)}
    # decode: one new token against a cache of seq_len
    if cfg.takes_embeds:
        tok = sds((b, 1, cfg.d_model), jnp.bfloat16)
    else:
        tok = sds((b, 1), jnp.int32)
    from repro.models.transformer import init_decode_cache  # lazy: avoids cycle

    cache = jax.eval_shape(
        lambda: init_decode_cache(cfg, b, s)
    )
    return {"inputs": tok, "cache": cache}
