"""Gradient compression for the DP all-reduce: int8 with error feedback.

Before the data-parallel gradient reduction, each leaf is quantized to int8
with a per-leaf fp32 scale; the quantization residual is carried to the next
step (error feedback), so the compression is unbiased over time.  This
shrinks DP all-reduce bytes 2x (bf16->int8) / 4x (fp32->int8) — the
"gradient compression" distributed-optimization trick.  Used by the trainer
when ``TrainConfig.compress_grads`` is set; the dry-run's collective-bytes
roofline term shows the reduction (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["init_error_feedback", "compress_grads", "decompress_grads"]


def init_error_feedback(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_grads(grads: Any, err: Any) -> Tuple[Any, Any]:
    """Returns (compressed {q,scale} tree, new error feedback)."""

    def comp(g, e):
        x = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, 1e-12)
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        new_e = x - q.astype(jnp.float32) * scale
        return {"q": q, "scale": scale}, new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err)
    out = [comp(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), tdef.unflatten([o[1] for o in out])


def decompress_grads(comp: Any, like: Any) -> Any:
    flat_l, tdef = jax.tree.flatten(like)
    flat_c = tdef.flatten_up_to(comp)
    return tdef.unflatten(
        [c["q"].astype(jnp.float32) * c["scale"] for c in flat_c]
    )
