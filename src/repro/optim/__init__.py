from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule, linear_warmup_cosine
from repro.optim.compression import compress_grads, decompress_grads
