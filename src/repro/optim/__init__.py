from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import compress_grads, decompress_grads
from repro.optim.schedule import cosine_schedule, linear_warmup_cosine

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "linear_warmup_cosine",
    "compress_grads",
    "decompress_grads",
]
