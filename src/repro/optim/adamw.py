"""AdamW with configurable moment dtypes (memory-tiered optimizer states).

At 405B params on 16 GiB/chip v5e, fp32 (m, v) does not fit next to bf16
weights + grads even at 256-way sharding (4x405e9/256 bytes/moment-pair).
We support ``m_dtype=bfloat16`` (sign+magnitude coarse is fine for the
first moment) while keeping ``v`` in fp32 by default, and fully-quantized
int8 moments with per-tensor scales as the aggressive tier — the
distributed-optimization "gradient/state compression" knob, selectable per
config (see launch/shardings.py for which archs need it).

Pure-functional: state is a pytree congruent to params; updates are
elementwise, so the state inherits the params' sharding (ZeRO by
construction: params sharded over (data, model) => moments too).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4              # peak; schedule multiplies
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    m_dtype: str = "float32"      # float32 | bfloat16 | int8
    v_dtype: str = "float32"      # float32 | bfloat16 | int8


def _q_init(p: jax.Array, dtype: str):
    if dtype == "int8":
        return {"q": jnp.zeros(p.shape, jnp.int8),
                "scale": jnp.zeros((), jnp.float32)}
    return jnp.zeros(p.shape, jnp.dtype(dtype))


def _q_read(s, dtype: str) -> jax.Array:
    if dtype == "int8":
        return s["q"].astype(jnp.float32) * s["scale"]
    return s.astype(jnp.float32)


def _q_write(x: jax.Array, dtype: str):
    if dtype == "int8":
        scale = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, 1e-12)
        return {"q": jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8),
                "scale": scale}
    return x.astype(jnp.dtype(dtype))


def adamw_init(params: Any, cfg: AdamWConfig) -> Dict[str, Any]:
    return {
        "m": jax.tree.map(lambda p: _q_init(p, cfg.m_dtype), params),
        "v": jax.tree.map(lambda p: _q_init(p, cfg.v_dtype), params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(grads: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )


def adamw_update(
    params: Any,
    grads: Any,
    state: Dict[str, Any],
    cfg: AdamWConfig,
    lr_scale: jax.Array | float = 1.0,
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step.  Returns (params, state, metrics)."""
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    is_q = lambda s: isinstance(s, dict) and "q" in s

    def upd(p, g, m_s, v_s):
        g = g.astype(jnp.float32) * clip
        m = _q_read(m_s, cfg.m_dtype) * cfg.b1 + (1 - cfg.b1) * g
        v = _q_read(v_s, cfg.v_dtype) * cfg.b2 + (1 - cfg.b2) * g * g
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        p2 = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        return p2, _q_write(m, cfg.m_dtype), _q_write(v, cfg.v_dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = [m for m in _iter_moments(state["m"], tdef)]
    flat_v = [v for v in _iter_moments(state["v"], tdef)]
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics


def _iter_moments(tree: Any, tdef) -> list:
    """Flatten a moment tree to match the params treedef (int8 moments are
    {q, scale} dicts which must be treated as leaves)."""
    return tdef.flatten_up_to(tree)
