"""Fault-tolerant sharded checkpointing.

Design (what "runs on 1000 nodes" requires):

  * **atomic**: writes go to ``step_N.tmp/`` and are renamed to ``step_N/``
    only after the manifest + all shards are fsync'd — a preempted writer
    never corrupts the latest valid checkpoint;
  * **sharded**: each host writes only the addressable shards of its local
    devices (``.addressable_shards``), one file per (param, shard) with the
    index in the filename — no cross-host traffic at save;
  * **elastic restore**: the manifest stores the *logical* PartitionSpec per
    leaf, not device ids; restore reassembles the full logical array from
    shard files and re-lays it out on the CURRENT mesh, so a job can restart
    on a different pod count / mesh shape (elastic re-scaling);
  * **resumable**: ``latest_step()`` scans for complete checkpoints only;
    crash-during-save leaves a ``.tmp`` dir that is ignored and GC'd;
  * **async**: ``save(..., blocking=False)`` snapshots to host memory and
    writes on a background thread — training overlaps the next step with
    checkpoint I/O (compute/IO overlap);
  * retention: ``keep`` newest checkpoints are retained.

On this single-process container every shard is addressable, which is the
degenerate (but fully exercised) case of the same code path.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten_with_names(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((name, leaf))
    return out, treedef


class CheckpointManager:
    """Atomic, sharded, elastically restorable checkpoints (module
    docstring).  The full cycle, on the degenerate single-process mesh:

    >>> import tempfile
    >>> import jax.numpy as jnp
    >>> ck = CheckpointManager(tempfile.mkdtemp())
    >>> ck.save(1, {"w": jnp.arange(4)})
    >>> ck.latest_step()
    1
    >>> ck.restore(1, {"w": jnp.zeros((4,), jnp.int32)})["w"].tolist()
    [0, 1, 2, 3]
    """

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._gc_tmp()

    # ---------------------------------------------------------- paths
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def _gc_tmp(self) -> None:
        for d in os.listdir(self.dir):
            if d.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    def latest_step(self) -> Optional[int]:
        """Newest *complete* checkpoint step, or None.

        >>> import tempfile
        >>> CheckpointManager(tempfile.mkdtemp()).latest_step() is None
        True
        """
        steps = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, d, "MANIFEST.json")):
                    steps.append(int(d[5:]))
        return max(steps) if steps else None

    def read_leaf(self, step: int, name: str) -> np.ndarray:
        """One leaf of a checkpoint by its flattened path name, as host
        numpy, without materialising the rest.  This is how a restorer
        whose state *shapes* depend on saved metadata (e.g. the elastic
        distributed sort's consumed-level index, ``repro.dist.elastic``)
        bootstraps: read the scalar, build ``like``, then ``restore``.

        >>> import tempfile
        >>> import jax.numpy as jnp
        >>> ck = CheckpointManager(tempfile.mkdtemp())
        >>> ck.save(3, {"level": jnp.asarray(2), "k": jnp.arange(8)})
        >>> int(ck.read_leaf(3, "level"))
        2
        """
        d = self._step_dir(step)
        with open(os.path.join(d, "MANIFEST.json")) as f:
            meta = json.load(f)["leaves"][name]
        arr = np.load(os.path.join(d, meta["file"]))
        if meta["dtype"] == "bfloat16":
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)
        return arr

    # ---------------------------------------------------------- save
    def save(self, step: int, state: Any, blocking: bool = True) -> None:
        """Checkpoint ``state`` (pytree of jax/np arrays) at ``step``."""
        self.wait()  # one async save in flight at a time
        named, _ = _flatten_with_names(state)
        # snapshot to host (this is the only sync part of an async save)
        host: Dict[str, Tuple[np.ndarray, Optional[str]]] = {}
        for name, leaf in named:
            spec = None
            if isinstance(leaf, jax.Array) and hasattr(leaf, "sharding"):
                try:
                    spec = str(leaf.sharding.spec)  # logical axes, mesh-free
                except Exception:
                    spec = None
            host[name] = (np.asarray(jax.device_get(leaf)), spec)

        def write():
            tmp = self._step_dir(step) + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            manifest = {"step": step, "leaves": {}}
            for name, (arr, spec) in host.items():
                fn = name.replace("/", "__") + ".npy"
                dtype = str(arr.dtype)
                if dtype == "bfloat16":
                    # numpy serializes ml_dtypes.bfloat16 as raw void ('V2')
                    # which cannot round-trip; store the bit pattern instead
                    arr = arr.view(np.uint16)
                np.save(os.path.join(tmp, fn), arr)
                manifest["leaves"][name] = {
                    "file": fn,
                    "shape": list(arr.shape),
                    "dtype": dtype,
                    "spec": spec,
                }
            with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            final = self._step_dir(step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic commit
            self._retain()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _retain(self) -> None:
        steps = sorted(
            int(d[5:]) for d in os.listdir(self.dir)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ---------------------------------------------------------- restore
    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: optional pytree of NamedSharding
        for the CURRENT mesh — this is the elastic-rescale path: data saved
        from any mesh is re-laid-out onto the new one."""
        d = self._step_dir(step)
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)
        named, treedef = _flatten_with_names(like)
        shard_list = (
            treedef.flatten_up_to(shardings) if shardings is not None
            else [None] * len(named)
        )
        leaves = []
        for (name, leaf), sh in zip(named, shard_list):
            meta = manifest["leaves"][name]
            arr = np.load(os.path.join(d, meta["file"]))
            if meta["dtype"] == "bfloat16":
                import ml_dtypes

                arr = arr.view(ml_dtypes.bfloat16)
            want = tuple(getattr(leaf, "shape", arr.shape))
            if tuple(arr.shape) != want:
                raise ValueError(f"{name}: checkpoint {arr.shape} vs model {want}")
            if sh is not None:
                leaves.append(jax.device_put(arr, sh))
            else:
                leaves.append(jax.device_put(arr))
        return jax.tree.unflatten(treedef, leaves)
