"""Batch scheduler: orders admitted requests by remaining length with the
paper's engine (IPS4o as a library — DESIGN.md §3), so continuous batches
retire together and padding waste is minimized.

Admission is a rank-k query, not a full sort: only ``batch_size`` requests
leave the queue per call, so the scheduler uses ``repro.ops.bottomk`` —
the splitter-based partial sort that base-case-sorts just the buckets
covering the admitted prefix (DESIGN.md §5.2)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.ops import bottomk

__all__ = ["Request", "Scheduler"]


@dataclass
class Request:
    uid: int
    prompt_len: int
    max_new: int
    done: int = 0

    @property
    def remaining(self) -> int:
        return self.max_new - self.done


@dataclass
class Scheduler:
    batch_size: int
    queue: List[Request] = field(default_factory=list)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def next_batch(self) -> List[Request]:
        """Admit up to batch_size requests, shortest-remaining-first.

        Rank-k selection on remaining length via ``ops.bottomk`` — requests
        that retire together sit together, so slot churn (and therefore
        prefill restarts) is minimized, and only the admitted prefix is
        ever fully sorted.
        """
        if not self.queue:
            return []
        keys = jnp.asarray([r.remaining for r in self.queue], jnp.int32)
        _, order = bottomk(keys, min(self.batch_size, len(self.queue)))
        order = np.asarray(order)
        batch = [self.queue[i] for i in order]
        picked = set(int(i) for i in order)
        self.queue = [r for i, r in enumerate(self.queue) if i not in picked]
        return batch
