"""Batch scheduler: orders admitted requests by remaining length with the
paper's engine (IPS4o as a library — DESIGN.md §3), so continuous batches
retire together and padding waste is minimized.

Admission is a rank-k query, not a full sort: only ``batch_size`` requests
leave the queue per call, so the scheduler uses ``repro.ops.bottomk`` —
the splitter-based partial sort that base-case-sorts just the buckets
covering the admitted prefix (DESIGN.md §5.2).

Two serving-correctness details:

  * selection runs on a composite (remaining, arrival-index) key, so ties
    on ``remaining`` admit in FIFO order deterministically — the base-case
    window sort is not stable across equal keys, and nondeterministic tie
    order is a starvation risk;
  * the queue is padded to the next power of two with sentinel keys and the
    sorter comes from the plan cache, so a queue that grows by one request
    per tick compiles O(log n) distinct shapes instead of one per length.

Serving real traffic runs S continuous-batching groups (replicas, LoRA
adapters, priority classes) side by side; :func:`admit_many` admits one
step for ALL of them with a single batched rank-k call (DESIGN.md §6):
queues pad to a shared (S_pad, n_pad) key matrix (both pow2, so ragged
queue counts compile O(log S · log n) shapes) and one plan-cached
``ops.batched_bottomk`` selects every group's batch at once.

A restarted server also carries a **persisted backlog** — requests
spilled at the previous shutdown, re-attached sorted
(:meth:`Scheduler.attach_backlog`).  Admission then works on a *merged
view* of persisted + live queues (DESIGN.md §7): the backlog is already a
sorted run, the live candidates come out of ``bottomk`` sorted, and one
stable 2-way ``repro.stream.merge`` interleaves them — backlog winning
ties (it is strictly older, so FIFO is preserved across the restart).

Queues too large for one device admit **across a mesh axis**
(``next_batch(mesh=...)``, DESIGN.md §8): the composite keys shard over
the axis, every shard runs the splitter-based partial sort as its local
filter, and a single-shard finish over the gathered per-shard candidates
selects the batch — ``repro.dist.bottomk``, with semantics identical to
the single-device path (shortest remaining first, FIFO ties).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.ops import plan

__all__ = ["Request", "Scheduler", "admit_many"]


@dataclass
class Request:
    uid: int
    prompt_len: int
    max_new: int
    done: int = 0

    @property
    def remaining(self) -> int:
        return self.max_new - self.done


@dataclass
class Scheduler:
    batch_size: int
    queue: List[Request] = field(default_factory=list)
    backlog: List[Request] = field(default_factory=list)  # persisted, sorted

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def attach_backlog(self, reqs: Sequence[Request]) -> None:
        """Attach a persisted queue (requests spilled by a previous server
        session) as a sorted run: one plan-cached argsort on the same
        composite (remaining, position) key as live admission, so the
        backlog is ordered exactly the way :meth:`next_batch` consumes it.
        Backlog requests are strictly older than anything live and win
        admission ties (FIFO across the restart).

        Repeated attaches stay sorted: the new run stable-merges into the
        existing backlog (host-side — a stable argsort of the concatenation
        of two sorted runs IS their stable merge), earlier attaches winning
        ties.
        """
        reqs = list(reqs)
        q = len(reqs)
        if not q:
            return
        n_pad = 1 << (q - 1).bit_length() if q > 1 else 1
        comp = _composite_of(reqs, n_pad)
        if comp is None:  # int32 overflow: host-side stable order
            rem = np.asarray([r.remaining for r in reqs], np.int64)
            order = np.lexsort((np.arange(q), rem))
        else:
            keys = np.full(n_pad, _SENTINEL, np.int32)
            keys[:q] = comp
            order = np.asarray(
                plan.get_sorter(n_pad, jnp.int32, "argsort")(jnp.asarray(keys))
            )
            order = order[order < q]
        combined = self.backlog + [reqs[i] for i in order]
        rem = np.asarray([r.remaining for r in combined], np.int64)
        self.backlog = [combined[i] for i in np.argsort(rem, kind="stable")]
        obs.count("serve.backlog_attached", q)

    def next_batch(self, *, mesh=None, axes="data") -> List[Request]:
        """Admit up to batch_size requests, shortest-remaining-first,
        FIFO among equal ``remaining``.

        With ``mesh`` (a ``jax.sharding.Mesh``), live selection runs the
        *distributed* bottom-k over ``axes`` (``repro.dist.bottomk``,
        DESIGN.md §8): each shard splitter-filters its slice of the
        composite keys and a single-shard finish selects the batch — same
        admission order, queue sizes beyond one device.

        Rank-k selection on a composite (remaining, arrival-index) key via
        the plan-cached ``ops.bottomk`` — requests that retire together sit
        together, so slot churn (and therefore prefill restarts) is
        minimized, and only the admitted prefix is ever fully sorted.  The
        queue position *is* the arrival index (the queue is append-only
        between calls and removal preserves relative order).

        With a persisted backlog attached, admission runs on the *merged
        view*: the backlog prefix (already a sorted run) and the live
        ``bottomk`` candidates (sorted by construction) interleave through
        one stable 2-way ``stream.merge`` on the ``remaining`` key — the
        stable tie rule admits backlog (older) requests first, and because
        both inputs are sorted runs, the admitted set is a prefix of each.
        """
        kk = min(self.batch_size, len(self.queue) + len(self.backlog))
        if not kk:
            return []
        with obs.trace("serve.next_batch", queue=len(self.queue),
                       backlog=len(self.backlog)):
            order = self._select_live(
                min(self.batch_size, len(self.queue)), mesh=mesh, axes=axes
            )
            if not self.backlog:
                batch = self._take(order)
                obs.count("serve.admitted", len(batch))
                return batch
            bk = np.asarray(
                [r.remaining for r in self.backlog[: self.batch_size]], np.int64
            )
            lk = np.asarray([self.queue[i].remaining for i in order], np.int64)
            if max(bk.max(initial=0), lk.max(initial=0)) < _SENTINEL:
                from repro.stream import merge  # lazy: stream layers above serve

                _, src = merge(
                    [jnp.asarray(bk.astype(np.int32)), jnp.asarray(lk.astype(np.int32))],
                    values=[
                        jnp.arange(len(bk), dtype=jnp.int32),
                        len(bk) + jnp.arange(len(lk), dtype=jnp.int32),
                    ],
                )
                src = np.asarray(src)
            else:
                # remaining overflows int32 (same hazard the composite path
                # guards): host-side stable merge — the stable argsort of the
                # concatenation of two sorted runs is exactly their merge
                src = np.argsort(np.concatenate([bk, lk]), kind="stable")
            src = src[:kk]
            n_back = int(np.sum(src < len(bk)))  # a prefix of the backlog run
            batch: List[Request] = []
            live_iter = iter(self._take(order[: kk - n_back]))
            back_iter = iter(self.backlog[:n_back])
            self.backlog = self.backlog[n_back:]
            for s in src:
                batch.append(next(back_iter) if s < len(bk) else next(live_iter))
            obs.count("serve.admitted", len(batch))
            return batch

    def _select_live(self, kk: int, mesh=None, axes="data") -> np.ndarray:
        """Selection order (queue positions) of the live admission
        candidates — the bottomk path shared by both admission views."""
        q = len(self.queue)
        if not q or not kk:
            return np.zeros((0,), np.int64)
        if mesh is not None:
            d = 1
            for a in (axes,) if isinstance(axes, str) else tuple(axes):
                d *= mesh.shape[a]
            if d > 1:
                return self._select_live_dist(kk, mesh, axes, d)
        n_pad = 1 << (q - 1).bit_length() if q > 1 else 1
        comp = self._composite_keys(n_pad)
        if comp is None:
            # composite would overflow int32 (gigantic remaining x queue):
            # host-side stable selection keeps the same (remaining, arrival)
            # order at O(n log n) — vanishingly rare in practice
            rem = np.asarray([r.remaining for r in self.queue], np.int64)
            return np.lexsort((np.arange(q), rem))[:kk]
        keys = np.full(n_pad, _SENTINEL, np.int32)
        keys[:q] = comp
        f = plan.get_sorter(
            n_pad, jnp.int32, "bottomk", k=min(self.batch_size, n_pad)
        )
        _, order = f(jnp.asarray(keys))
        order = np.asarray(order)
        return order[order < q][:kk]  # drop sentinel pad slots

    def _select_live_dist(self, kk: int, mesh, axes, d: int) -> np.ndarray:
        """Distributed live selection (DESIGN.md §8): shard the composite
        keys over the mesh axis and admit via ``repro.dist.bottomk`` —
        splitter-filter per shard, single-shard finish.  Same composite
        (remaining, arrival) order, the same int32-overflow host fallback."""
        import jax
        import jax.numpy as jnp_
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro import dist

        q = len(self.queue)
        # pad to a pow2 shape divisible by d so shards are equal-sized
        # (plan-style O(log n) compile shapes survive the sharding)
        n_pad = 1 << (max(q, d) - 1).bit_length() if max(q, d) > 1 else 1
        if n_pad % d:
            n_pad = -(-n_pad // d) * d
        comp = self._composite_keys(n_pad)
        if comp is None:
            rem = np.asarray([r.remaining for r in self.queue], np.int64)
            return np.lexsort((np.arange(q), rem))[:kk]
        keys = np.full(n_pad, _SENTINEL, np.int32)
        keys[:q] = comp
        names = (axes,) if isinstance(axes, str) else tuple(axes)
        spec = P(names if len(names) > 1 else names[0])
        xs = jax.device_put(jnp_.asarray(keys), NamedSharding(mesh, spec))
        _, order = dist.bottomk(xs, min(self.batch_size, n_pad), mesh, axes)
        order = np.asarray(order)
        return order[order < q][:kk]  # drop sentinel pad slots

    # -- shared selection plumbing (used by admit_many too) -----------------
    def _composite_keys(self, n_pad: int) -> Optional[np.ndarray]:
        """(remaining, arrival) composite int32 keys for the current queue,
        or None when the composite would overflow int32."""
        return _composite_of(self.queue, n_pad)

    def _take(self, order: np.ndarray) -> List[Request]:
        """Pop the requests at queue positions ``order`` (selection order),
        preserving the relative order of everything left behind."""
        batch = [self.queue[i] for i in order]
        picked = set(int(i) for i in order)
        self.queue = [r for i, r in enumerate(self.queue) if i not in picked]
        return batch


_SENTINEL = np.iinfo(np.int32).max


def _composite_of(reqs: Sequence[Request], n_pad: int) -> Optional[np.ndarray]:
    """(remaining, position) composite int32 keys for a request list, or
    None when the composite would overflow int32."""
    q = len(reqs)
    rem = np.asarray([r.remaining for r in reqs], np.int64)
    comp = rem * n_pad + np.arange(q, dtype=np.int64)
    if q and comp.max() >= _SENTINEL:
        return None
    return comp.astype(np.int32)


def admit_many(schedulers: Sequence[Scheduler]) -> List[List[Request]]:
    """Admit one step for every scheduler with ONE batched rank-k call.

    The batched form of :meth:`Scheduler.next_batch` (DESIGN.md §6): all S
    admission queues become rows of one (S_pad, n_pad) composite-key
    matrix — queues shorter than n_pad (and the pad rows beyond S) fill
    with the int32 sentinel, both dims pad to powers of two so ragged
    fleets compile O(log S · log n) shapes — and a single plan-cached
    ``ops.batched_bottomk`` selects every group's admitted prefix.  Each
    queue keeps the exact semantics of the unbatched path: shortest
    remaining first, FIFO ties, the same int32-overflow host fallback per
    queue.
    """
    results: List[List[Request]] = [[] for _ in schedulers]
    lens = [len(s.queue) for s in schedulers]
    n_max = max(lens, default=0)
    if n_max == 0 and not any(s.backlog for s in schedulers):
        return results
    with obs.trace("serve.admit_many", schedulers=len(schedulers)):
        return _admit_many(schedulers, results, lens, n_max)


def _admit_many(schedulers, results, lens, n_max):
    n_pad = 1 << (n_max - 1).bit_length() if n_max > 1 else 1

    rows: List[np.ndarray] = []
    row_ids: List[int] = []
    for i, s in enumerate(schedulers):
        q = lens[i]
        if s.backlog:
            # merged persisted + live view: per-scheduler path (the merge
            # against the backlog run is scheduler-local by construction)
            results[i] = s.next_batch()
            continue
        if q == 0:
            continue
        comp = s._composite_keys(n_pad)
        if comp is None:  # per-queue overflow fallback, as in next_batch
            rem = np.asarray([r.remaining for r in s.queue], np.int64)
            order = np.lexsort((np.arange(q), rem))[: min(s.batch_size, q)]
            results[i] = s._take(order)
            obs.count("serve.admitted", len(results[i]))
            continue
        keys = np.full(n_pad, _SENTINEL, np.int32)
        keys[:q] = comp
        rows.append(keys)
        row_ids.append(i)
    if not rows:
        return results

    S = len(rows)
    s_pad = 1 << (S - 1).bit_length() if S > 1 else 1
    mat = np.full((s_pad, n_pad), _SENTINEL, np.int32)
    mat[:S] = np.stack(rows)
    kk = min(max(schedulers[i].batch_size for i in row_ids), n_pad)
    f = plan.get_sorter(n_pad, jnp.int32, "bottomk", k=kk, batch=s_pad)
    _, order = f(jnp.asarray(mat))
    order = np.asarray(order)
    for j, i in enumerate(row_ids):
        s, q = schedulers[i], lens[i]
        o = order[j]
        o = o[o < q][: min(s.batch_size, q)]  # drop sentinel pad slots
        results[i] = s._take(o)
        obs.count("serve.admitted", len(results[i]))
    return results
