"""Batch scheduler: orders admitted requests by remaining length with the
paper's sorter (IPS4o as a library — DESIGN.md §3), so continuous batches
retire together and padding waste is minimized."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.ips4o import ips4o_sort

__all__ = ["Request", "Scheduler"]


@dataclass
class Request:
    uid: int
    prompt_len: int
    max_new: int
    done: int = 0

    @property
    def remaining(self) -> int:
        return self.max_new - self.done


@dataclass
class Scheduler:
    batch_size: int
    queue: List[Request] = field(default_factory=list)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def next_batch(self) -> List[Request]:
        """Admit up to batch_size requests, shortest-remaining-first.

        Sort keyed on remaining length via ips4o_sort — requests that retire
        together sit together, so slot churn (and therefore prefill restarts)
        is minimized.
        """
        if not self.queue:
            return []
        keys = jnp.asarray([r.remaining for r in self.queue], jnp.int32)
        idx = jnp.arange(len(self.queue), dtype=jnp.int32)
        _, order = ips4o_sort(keys, idx)
        order = np.asarray(order)
        batch = [self.queue[i] for i in order[: self.batch_size]]
        picked = set(int(order[i]) for i in range(min(self.batch_size, len(order))))
        self.queue = [r for i, r in enumerate(self.queue) if i not in picked]
        return batch
