"""Batch scheduler: orders admitted requests by remaining length with the
paper's engine (IPS4o as a library — DESIGN.md §3), so continuous batches
retire together and padding waste is minimized.

Admission is a rank-k query, not a full sort: only ``batch_size`` requests
leave the queue per call, so the scheduler uses ``repro.ops.bottomk`` —
the splitter-based partial sort that base-case-sorts just the buckets
covering the admitted prefix (DESIGN.md §5.2).

Two serving-correctness details:

  * selection runs on a composite (remaining, arrival-index) key, so ties
    on ``remaining`` admit in FIFO order deterministically — the base-case
    window sort is not stable across equal keys, and nondeterministic tie
    order is a starvation risk;
  * the queue is padded to the next power of two with sentinel keys and the
    sorter comes from the plan cache, so a queue that grows by one request
    per tick compiles O(log n) distinct shapes instead of one per length.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.ops import plan

__all__ = ["Request", "Scheduler"]


@dataclass
class Request:
    uid: int
    prompt_len: int
    max_new: int
    done: int = 0

    @property
    def remaining(self) -> int:
        return self.max_new - self.done


@dataclass
class Scheduler:
    batch_size: int
    queue: List[Request] = field(default_factory=list)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def next_batch(self) -> List[Request]:
        """Admit up to batch_size requests, shortest-remaining-first,
        FIFO among equal ``remaining``.

        Rank-k selection on a composite (remaining, arrival-index) key via
        the plan-cached ``ops.bottomk`` — requests that retire together sit
        together, so slot churn (and therefore prefill restarts) is
        minimized, and only the admitted prefix is ever fully sorted.  The
        queue position *is* the arrival index (the queue is append-only
        between calls and removal preserves relative order).
        """
        if not self.queue:
            return []
        q = len(self.queue)
        kk = min(self.batch_size, q)
        rem = np.asarray([r.remaining for r in self.queue], np.int64)
        n_pad = 1 << (q - 1).bit_length() if q > 1 else 1
        comp = rem * n_pad + np.arange(q, dtype=np.int64)
        sentinel = np.iinfo(np.int32).max
        if comp.max() >= sentinel:
            # composite would overflow int32 (gigantic remaining x queue):
            # host-side stable selection keeps the same (remaining, arrival)
            # order at O(n log n) — vanishingly rare in practice
            order = np.lexsort((np.arange(q), rem))[:kk]
        else:
            keys = np.full(n_pad, sentinel, np.int32)
            keys[:q] = comp.astype(np.int32)
            f = plan.get_sorter(
                n_pad, jnp.int32, "bottomk", k=min(self.batch_size, n_pad)
            )
            _, order = f(jnp.asarray(keys))
            order = np.asarray(order)
            order = order[order < q][:kk]  # drop sentinel pad slots
        batch = [self.queue[i] for i in order]
        picked = set(int(i) for i in order)
        self.queue = [r for i, r in enumerate(self.queue) if i not in picked]
        return batch
