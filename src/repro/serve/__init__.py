from repro.serve.engine import ServeConfig, Engine, make_prefill_step, make_decode_step
