from repro.serve.engine import Engine, ServeConfig, make_decode_step, make_prefill_step

__all__ = ["ServeConfig", "Engine", "make_prefill_step", "make_decode_step"]
