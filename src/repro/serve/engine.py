"""Batched serving engine: prefill + decode step factories and a driver.

``make_prefill_step`` / ``make_decode_step`` produce the jitted, sharded
callables that the dry-run lowers for the ``prefill_32k`` / ``decode_32k`` /
``long_500k`` cells; ``Engine`` drives them for real generation (greedy or
temperature sampling) with continuous batching via serve/scheduler.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.shardings import (
    ShardingStrategy, cache_specs, named, param_specs,
)
from repro.models.transformer import forward, init_decode_cache, init_model

__all__ = ["ServeConfig", "Engine", "make_prefill_step", "make_decode_step"]


@dataclass(frozen=True)
class ServeConfig:
    max_seq: int
    batch_size: int
    temperature: float = 0.0  # 0 = greedy


def make_prefill_step(cfg: ModelConfig, mesh,
                      strat: ShardingStrategy = ShardingStrategy(),
                      params_like: Any = None,
                      donate_cache: bool = True):
    """prefill(params, inputs, cache) -> (last_logits, cache)."""
    if params_like is None:
        params_like = jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))
    psh = named(mesh, param_specs(params_like, cfg, mesh, strat))

    def prefill(params, inputs, cache):
        logits, new_cache, _ = forward(params, cfg, inputs, cache=cache,
                                       update_cache=True)
        return logits[:, -1], new_cache

    return jax.jit(
        prefill,
        in_shardings=(psh, None, None),
        donate_argnums=(2,) if donate_cache else (),
    ), psh


def make_decode_step(cfg: ModelConfig, mesh,
                     strat: ShardingStrategy = ShardingStrategy(),
                     params_like: Any = None):
    """decode(params, tok, pos, cache) -> (logits (B,V), cache). Donates
    the cache (in-place KV update — the framework-level analogue of the
    paper's buffer reuse)."""
    if params_like is None:
        params_like = jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))
    psh = named(mesh, param_specs(params_like, cfg, mesh, strat))

    def decode(params, tok, pos, cache):
        logits, new_cache, _ = forward(params, cfg, tok, positions=pos,
                                       cache=cache, update_cache=True)
        return logits[:, 0], new_cache

    return jax.jit(
        decode,
        in_shardings=(psh, None, None, None),
        donate_argnums=(3,),
    ), psh


class Engine:
    def __init__(self, cfg: ModelConfig, scfg: ServeConfig, mesh, params,
                 strat: ShardingStrategy = ShardingStrategy()):
        self.cfg, self.scfg, self.mesh = cfg, scfg, mesh
        self.params = params
        like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
        self.prefill_fn, _ = make_prefill_step(cfg, mesh, strat, like)
        self.decode_fn, _ = make_decode_step(cfg, mesh, strat, like)
        csh = named(mesh, cache_specs(
            cfg, mesh, jax.eval_shape(
                lambda: init_decode_cache(cfg, scfg.batch_size, scfg.max_seq)
            ), strat))
        self._init_cache = jax.jit(
            lambda: init_decode_cache(cfg, scfg.batch_size, scfg.max_seq),
            out_shardings=csh,
        )
        # materialized lazily: generate() starts every call from a fresh
        # cache (the steps donate the buffer), so an eager init here would
        # only be thrown away
        self.cache = None

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits.astype(jnp.float32) / self.scfg.temperature, axis=-1
        ).astype(jnp.int32)

    def generate(self, prompts: jax.Array, max_new: int, seed: int = 0):
        """prompts: (B, P) int32.  Returns (B, max_new) generated tokens."""
        b, plen = prompts.shape
        assert b == self.scfg.batch_size
        # Fresh KV per call: prefill/decode donate the cache buffer, so after
        # a previous generate() it holds that call's keys/values past the new
        # prompt length — a shorter prompt would attend over stale KV.
        self.cache = self._init_cache()
        logits, self.cache = self.prefill_fn(self.params, prompts, self.cache)
        key = jax.random.PRNGKey(seed)
        toks = []
        # split before the first sample too — sampling with the parent key
        # and then splitting it correlates token 0 with the whole stream
        key, sub = jax.random.split(key)
        tok = self._sample(logits, sub)
        for i in range(max_new):
            toks.append(tok)
            pos = jnp.full((b, 1), plen + i, jnp.int32)
            logits, self.cache = self.decode_fn(
                self.params, tok[:, None], pos, self.cache
            )
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub)
        return jnp.stack(toks, axis=1)
