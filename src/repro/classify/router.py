"""The racing router: which classifier engine should "auto" run?

The honest answer is empirical — the radix extractor wins on uniform-ish
keyspaces, the tree wins under heavy duplication (its equality buckets
absorb what would overflow a radix bucket), the learned CDF wins on
smoothly skewed continuous inputs — so the router *measures* instead of
guessing, the same learn-and-route pattern an inference stack uses to
pick kernels per shape:

  * ``distribution_moments`` reduces a host-visible key array to a coarse
    distribution label ("uniform" | "dup" | "sorted" | "skew") from three
    cheap sample moments: duplicate fraction, sortedness, and top-bits
    histogram imbalance (the radix engine's own view of the keys);
  * the plan cache races tree vs radix vs learned on a synthetic draw
    matching that label and persists the winner under a ``clf:`` key
    (``PlanCache.classifier_plan`` — DESIGN.md §9);
  * ``resolve_classifier`` is the jit-boundary half: it maps "auto" to a
    persisted winner for this (n, dtype[, batch]) — or "tree", the always-
    correct default — *without* looking at the data, because the entry
    points are jit-compatible and data moments are host-only.  The
    moments-aware path is the eager ``classifier_for(x)`` convenience.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

__all__ = [
    "CLASSIFIERS",
    "resolve_classifier",
    "distribution_moments",
    "classifier_for",
]

CLASSIFIERS = ("tree", "radix", "learned")

# moments thresholds for the coarse label (see distribution_moments)
_DUP_FRACTION = 0.5      # > half the sample is a repeat -> "dup"
_SORTEDNESS = 0.95       # >= 95% nondecreasing adjacent pairs -> "sorted"
_TOPBITS_IMBALANCE = 4.0  # heaviest of 16 top-bit bins vs uniform -> "skew"


def resolve_classifier(
    classifier: str,
    n: Optional[int] = None,
    dtype=None,
    batch: Optional[int] = None,
) -> str:
    """Concrete engine for ``SortConfig.classifier``.

    A named engine passes through; "auto" consults the plan cache's raced
    ``clf:`` winners for this shape (``PlanCache.classifier_hint``) and
    defaults to "tree" — the only engine that is never the wrong answer —
    when nothing has been raced yet.

    >>> resolve_classifier("radix")
    'radix'
    >>> resolve_classifier("auto")  # nothing raced: the safe default
    'tree'
    """
    if classifier in CLASSIFIERS:
        return classifier
    if classifier != "auto":
        raise ValueError(
            f"unknown classifier {classifier!r}; expected one of "
            f"{CLASSIFIERS + ('auto',)}"
        )
    if dtype is not None and n is not None:
        from repro.ops.plan import default_cache  # lazy: ops layers on classify

        hint = default_cache.classifier_hint(n, dtype, batch=batch)
        if hint is not None:
            obs.count("classifier.route", source="hint", winner=hint)
            return hint
    obs.count("classifier.route", source="default", winner="tree")
    return "tree"


def distribution_moments(x, sample: int = 4096, seed: int = 0) -> str:
    """Coarse distribution label of a host-visible key array.

    Three moments on a bounded sample (host-side numpy — this is NOT
    jit-compatible, by design):

      * duplicate fraction -> "dup": the tree's equality buckets are the
        only engine feature that absorbs heavy repeats;
      * adjacent sortedness -> "sorted": near-sorted inputs make sampled
        splitters near-perfect and radix gains nothing;
      * top-4-bits histogram imbalance -> "skew": exactly the load the
        radix extractor would see at its first level, so a lopsided
        histogram predicts radix bucket overflow.

    Anything unremarkable is "uniform" — radix territory.
    """
    flat = np.asarray(jax.device_get(x)).reshape(-1)
    if flat.size == 0:
        return "uniform"
    # sortedness wants *adjacent* pairs: measure it on a contiguous prefix
    # (a random subsample would shuffle away exactly the signal)
    prefix = flat[:sample]
    xs = (
        np.random.default_rng(seed).choice(flat, size=sample, replace=False)
        if flat.size > sample
        else flat
    )
    dup = 1.0 - np.unique(xs).size / xs.size
    if dup > _DUP_FRACTION:
        return "dup"
    sortedness = (
        float(np.mean(prefix[1:] >= prefix[:-1])) if prefix.size > 1 else 1.0
    )
    if sortedness >= _SORTEDNESS:
        return "sorted"
    # top-bits view: rank-normalise into 16 equal-width value bins between
    # the sample extremes (rank spacing of the extremes approximates the
    # encoded top-bit histogram without needing the encode here)
    lo, hi = np.min(xs), np.max(xs)
    if hi > lo:
        bins = np.clip(
            ((xs.astype(np.float64) - np.float64(lo))
             / (np.float64(hi) - np.float64(lo)) * 16).astype(np.int64),
            0, 15,
        )
        counts = np.bincount(bins, minlength=16)
        if counts.max() * 16 / xs.size > _TOPBITS_IMBALANCE:
            return "skew"
    return "uniform"


def classifier_for(
    x,
    *,
    batch: Optional[int] = None,
    tune: bool = True,
    cache=None,
) -> str:
    """Eager, data-aware routing: label ``x``'s distribution, race (or look
    up) the engines for (n, dtype, label), return the winner.

    This is the host-side companion to ``SortConfig(classifier="auto")``:
    call it once per recurring workload shape, then pass the returned
    engine (or just keep using "auto" — the race it triggers is persisted
    and feeds ``resolve_classifier`` from then on).  A fresh race here
    times the engines on ``x`` itself (not the label's synthetic draw) —
    the one path that holds real data is the one place the measurement
    can be exact.
    """
    if cache is None:
        from repro.ops.plan import default_cache as cache  # lazy
    arr = jnp.asarray(x)
    n = arr.shape[-1]
    b = arr.shape[0] if arr.ndim == 2 else batch
    with obs.trace("classifier.route_for", n=n, batch=b):
        label = distribution_moments(arr)
        winner = cache.classifier_plan(
            n, arr.dtype, dist=label, batch=b, tune=tune, x=arr
        )
    winner = winner or "tree"
    obs.count("classifier.route", source="race", winner=winner, dist=label)
    return winner
