"""The sampled comparison-tree classifier (paper §3 + equality buckets §4.4).

Classification of element ``e`` against k-1 sorted splitters is a descent of
the implicit BFS tree: ``i <- 2i + (e > tree[i])`` repeated log2(k) times.
Afterwards ``j = i - k`` is the bucket index: bucket j holds (s_{j-1}, s_j].

Equality buckets (paper §4.4): one extra branch-free comparison against the
*upper* splitter of the landing bucket.  Final local bucket id = ``2j + (e ==
s_j)`` — even ids are regular range-buckets, odd ids are equality buckets
(all elements identical), which are skipped by deeper levels and by the base
case.  We keep equality buckets enabled unconditionally: the paper enables
them at runtime when duplicate splitters are detected, but a jitted program
cannot branch on data, so we pay the one extra comparison statically (noted
in DESIGN.md as a changed assumption).

This module is the "tree" engine of the ``repro.classify`` seam (DESIGN.md
§9); it is the only engine that *needs* the sampling pass — its splitters
come from a sorted sample — which is also what makes it distribution-
adaptive.  The sibling engines trade that adaptivity away ("radix", no
sample at all) or replace it with a model ("learned", CDF fit on the same
sample).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.sampling import build_tree, sentinel_for

__all__ = ["classify", "classify_batched", "classify_segmented", "num_local_buckets"]


def num_local_buckets(k: int) -> int:
    """2j + eq with j in [0,k) -> ids in [0, 2k)."""
    return 2 * k


def classify(keys: jax.Array, splitters: jax.Array, k: int) -> jax.Array:
    """Classify ``keys`` (n,) against sorted ``splitters`` (k-1,).

    Returns int32 local bucket ids in [0, 2k): ``2j + (key == upper_j)``.
    """
    tree = build_tree(splitters, k)
    upper = jnp.concatenate(
        [splitters, jnp.full((1,), sentinel_for(keys.dtype), keys.dtype)]
    )
    idx = jnp.ones(keys.shape, jnp.int32)
    for _ in range(int(math.log2(k))):
        node = jnp.take(tree, idx, axis=0)
        idx = 2 * idx + (keys > node).astype(jnp.int32)
    j = idx - k
    eq = (keys == jnp.take(upper, j, axis=0)).astype(jnp.int32)
    return 2 * j + eq


def classify_batched(keys: jax.Array, splitters: jax.Array, k: int) -> jax.Array:
    """Per-row classification over a leading batch dimension (DESIGN.md §6).

    ``keys`` (B, n) rows classify against their own sorted splitter set
    ``splitters`` (B, k-1): the same branch-free descent as :func:`classify`
    with the tree/upper lookups row-local (``take_along_axis``).  Returns
    int32 local bucket ids (B, n) in [0, 2k).
    """
    tree = build_tree(splitters, k)  # (B, k)
    upper = jnp.concatenate(
        [
            splitters,
            jnp.full((splitters.shape[0], 1), sentinel_for(keys.dtype), keys.dtype),
        ],
        axis=1,
    )  # (B, k)
    idx = jnp.ones(keys.shape, jnp.int32)
    for _ in range(int(math.log2(k))):
        node = jnp.take_along_axis(tree, idx, axis=1)
        idx = 2 * idx + (keys > node).astype(jnp.int32)
    j = idx - k
    eq = (keys == jnp.take_along_axis(upper, j, axis=1)).astype(jnp.int32)
    return 2 * j + eq


def classify_segmented(
    keys: jax.Array, seg: jax.Array, splitters: jax.Array, k: int
) -> jax.Array:
    """Per-segment classification (recursion level 2, flattened).

    ``seg`` (n,) int32 assigns each element its segment; ``splitters``
    (num_seg, k-1) holds each segment's sorted splitters.  Returns local
    bucket ids in [0, 2k) — the caller forms the composite bucket
    ``seg * 2k + local``.
    """
    num_seg = splitters.shape[0]
    tree = build_tree(splitters, k).reshape(num_seg * k)
    upper = jnp.concatenate(
        [
            splitters,
            jnp.full((num_seg, 1), sentinel_for(keys.dtype), keys.dtype),
        ],
        axis=-1,
    ).reshape(num_seg * k)
    base = seg.astype(jnp.int32) * k
    idx = jnp.ones(keys.shape, jnp.int32)
    for _ in range(int(math.log2(k))):
        node = jnp.take(tree, base + idx, axis=0)
        idx = 2 * idx + (keys > node).astype(jnp.int32)
    j = idx - k
    eq = (keys == jnp.take(upper, base + j, axis=0)).astype(jnp.int32)
    return 2 * j + eq
