"""The learned-CDF classifier (arXiv 2208.06902, *Towards Parallel Learned
Sorting*), fitted per level pass on the same sample the tree engine uses.

Instead of equidistant sample order statistics becoming *splitters*, the
whole sorted sample becomes a model: a monotone piecewise-linear CDF with
``P`` equal-probability segments whose knots are the sample quantiles

    knots[i] = sample[round(i * (m-1) / P)],   CDF(knots[i]) = i / P.

Classification is model evaluation instead of a tree descent or a
searchsorted against k-1 splitters — one searchsorted against P-1 interior
knots (P << k) plus a fused multiply:

    seg  = |{interior knots <= key}|
    frac = clip((key - knots[seg]) / (knots[seg+1] - knots[seg]), 0, 1)
    j    = clip(floor((seg + frac) / P * k), 0, k-1)

``j`` is monotone nondecreasing in the key (each term is: ``seg`` is a
rank, ``frac`` interpolates within a segment, duplicate knots collapse to
frac = 0 or 1, and the uint -> f32 cast rounds monotonically), so the
stable-partition + (bucket, key) base-case contract holds exactly as for
sampled splitters.  Equality buckets degrade to the sentinel-only rule of
the radix engine (odd bucket iff key == sentinel) — the model has no
per-bucket upper splitter to compare against.

**Fallback rule** (the paper's guard against model mispredictions, made
jit-compatible): the fit is scored on its own training sample — the
largest predicted bucket load, normalised so a perfect fit scores 1.0:

    imbalance = max_j |{model(sample) = j}| * k / m

When it exceeds ``IMBALANCE_THRESHOLD`` the level classifies with the
comparison tree instead, via one ``lax.cond`` (the splitters come from the
same sample, so the fallback costs nothing extra when not taken).  The
threshold sits well below ``slack / 2`` — the load factor at which a
bucket would overflow W/2 and trip the full-sort robustness fallback — so
a bad fit reroutes to the tree *before* it can cost a stable full sort.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.classify.tree import classify, classify_batched
from repro.core.sampling import sentinel_for

__all__ = [
    "NUM_KNOTS",
    "IMBALANCE_THRESHOLD",
    "fit_cdf_knots",
    "eval_cdf_buckets",
    "sample_imbalance",
    "learned_bucket_ids",
    "learned_bucket_ids_batched",
]

# P: piecewise-linear segments of the CDF.  Few segments keep the fit and
# the per-element searchsorted cheap (P is independent of k); 64 matches
# the paper's observation that splitter *precision* matters less than
# splitter *balance* once buckets are oversampled.
NUM_KNOTS = 64

# Sample-measured load factor above which the level falls back to the
# comparison tree.  A perfectly balanced fit scores 1.0; the full-sort
# robustness fallback only trips near slack/2 (= 4.0 at the default
# slack=8), so 3.0 reroutes bad fits one stage earlier.
IMBALANCE_THRESHOLD = 3.0


def _to_float(x: jax.Array) -> jax.Array:
    """Monotone cast into the model's evaluation space (f32 is enough:
    rounding is monotone nondecreasing, and both keys and knots round
    through the same map, so bucket boundaries stay consistent)."""
    return x.astype(jnp.float32)


def fit_cdf_knots(sorted_sample: jax.Array, num_knots: int = NUM_KNOTS) -> jax.Array:
    """(..., m) sorted sample -> (..., P+1) f32 knots at sample quantiles."""
    m = sorted_sample.shape[-1]
    idx = np.clip(
        np.round(np.arange(num_knots + 1) * (m - 1) / max(num_knots, 1)), 0, m - 1
    ).astype(np.int32)
    return _to_float(jnp.take(sorted_sample, jnp.asarray(idx), axis=-1))


def eval_cdf_buckets(keys: jax.Array, knots: jax.Array, k: int) -> jax.Array:
    """Bucket index j in [0, k) per key — the model evaluation.

    ``keys`` (n,) with knots (P+1,), or (B, n) with per-row knots (B, P+1).
    """
    P = knots.shape[-1] - 1
    kf = _to_float(keys)
    inner = knots[..., 1:-1]  # (.., P-1) interior knots
    if keys.ndim == 2:
        seg = jax.vmap(lambda kn, kv: jnp.searchsorted(kn, kv, side="right"))(
            inner, kf
        ).astype(jnp.int32)
        lo = jnp.take_along_axis(knots, seg, axis=-1)
        hi = jnp.take_along_axis(knots, seg + 1, axis=-1)
    else:
        seg = jnp.searchsorted(inner, kf, side="right").astype(jnp.int32)
        lo = jnp.take(knots, seg, axis=0)
        hi = jnp.take(knots, seg + 1, axis=0)
    # duplicate knots (heavy sample duplicates) give hi == lo: the segment
    # carries zero probability mass, frac pins to 0 — still monotone
    span = hi - lo
    frac = jnp.clip(
        jnp.where(span > 0, (kf - lo) / jnp.where(span > 0, span, 1.0), 0.0),
        0.0,
        1.0,
    )
    cdf = (seg.astype(jnp.float32) + frac) / max(P, 1)
    return jnp.clip((cdf * k).astype(jnp.int32), 0, k - 1)


def sample_imbalance(sorted_sample: jax.Array, knots: jax.Array, k: int) -> jax.Array:
    """Largest predicted bucket load on the training sample, normalised so
    a perfect fit scores 1.0 (scalar per row; (...,) for batched input).

    The model is monotone and the sample sorted, so the predicted bucket
    ids are sorted too and per-bucket counts are rank differences — no
    scatter, just k+1 searchsorteds against the (tiny) sample.
    """
    m = sorted_sample.shape[-1]
    jb = eval_cdf_buckets(sorted_sample, knots, k)
    edges = jnp.arange(k + 1, dtype=jnp.int32)
    if sorted_sample.ndim == 2:
        pos = jax.vmap(lambda r: jnp.searchsorted(r, edges, side="left"))(jb)
    else:
        pos = jnp.searchsorted(jb, edges, side="left")
    counts = jnp.diff(pos)
    return jnp.max(counts, axis=-1).astype(jnp.float32) * k / m


def _with_eq(keys: jax.Array, j: jax.Array) -> jax.Array:
    eq = (keys == sentinel_for(keys.dtype)).astype(jnp.int32)
    return 2 * j + eq


def learned_bucket_ids(
    keys: jax.Array,
    sorted_sample: jax.Array,
    splitters: jax.Array,
    k: int,
    threshold: float = IMBALANCE_THRESHOLD,
) -> Tuple[jax.Array, jax.Array]:
    """Local bucket ids in [0, 2k) for ``keys`` (n,), with the tree fallback.

    ``sorted_sample`` (m,) trains the CDF; ``splitters`` (k-1,) are the
    tree's equidistant order statistics of the *same* sample, so the
    ``lax.cond`` fallback branch needs no extra sampling pass.  Returns
    (bucket ids, fell_back flag).
    """
    knots = fit_cdf_knots(sorted_sample)
    fell_back = sample_imbalance(sorted_sample, knots, k) > threshold
    b = jax.lax.cond(
        fell_back,
        lambda: classify(keys, splitters, k),
        lambda: _with_eq(keys, eval_cdf_buckets(keys, knots, k)),
    )
    return b, fell_back


def learned_bucket_ids_batched(
    keys: jax.Array,
    sorted_sample: jax.Array,
    splitters: jax.Array,
    k: int,
    threshold: float = IMBALANCE_THRESHOLD,
) -> Tuple[jax.Array, jax.Array]:
    """Per-row ids for ``keys`` (B, n) with per-row samples (B, m) and
    splitters (B, k-1).  The fallback is batch-wide (one ``lax.cond`` for
    the whole trace, like the batched robustness fallback — DESIGN.md §6):
    a single badly-fit row reroutes every row through the tree.
    """
    knots = fit_cdf_knots(sorted_sample)
    fell_back = jnp.any(sample_imbalance(sorted_sample, knots, k) > threshold)
    b = jax.lax.cond(
        fell_back,
        lambda: classify_batched(keys, splitters, k),
        lambda: _with_eq(keys, eval_cdf_buckets(keys, knots, k)),
    )
    return b, fell_back
