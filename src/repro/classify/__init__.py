"""repro.classify — pluggable classifier engines behind one seam (DESIGN.md §9).

IPS4o's partition pipeline is classifier-agnostic: every level pass needs
one function ``keys -> local bucket ids in [0, 2k)`` that is monotone
nondecreasing in the key, with odd ids reserved for equality buckets
(runs of identical keys, skipped by deeper levels and the base case).
This package is that seam, with three interchangeable engines:

  tree     the paper's sampled comparison tree (§3 + §4.4): splitters
           from a sorted sample, branchless BFS descent, per-bucket
           equality test.  Distribution-adaptive; the always-correct
           default.
  radix    IPS2Ra (arXiv 2009.13569): bucket on the next log2(k) bits of
           the keyspace-encoded key — no sampling pass, one shift + mask
           per element, a per-level shift for level 2.  Fastest on
           uniform-ish keyspaces; overflows (and falls back) on heavy
           duplicates.
  learned  arXiv 2208.06902: a monotone piecewise-linear CDF fitted on
           the sample, classification by model evaluation, with a
           measured-imbalance fallback to the tree inside one
           ``lax.cond``.
  auto     (``SortConfig.classifier``) the racing router: the plan cache
           races the engines per (n, dtype, distribution label) and
           routes to the persisted winner (``router.resolve_classifier``,
           ``PlanCache.classifier_plan``).

The fused Pallas forms of the tree and radix classifiers live in
``kernels/classify.py``; the engines here are their XLA formulations and
the single source of truth for the bucket-id contract.
"""
from repro.classify.learned import (
    IMBALANCE_THRESHOLD,
    NUM_KNOTS,
    eval_cdf_buckets,
    fit_cdf_knots,
    learned_bucket_ids,
    learned_bucket_ids_batched,
    sample_imbalance,
)
from repro.classify.radix import radix_bucket_ids, radix_shift
from repro.classify.router import (
    CLASSIFIERS,
    classifier_for,
    distribution_moments,
    resolve_classifier,
)
from repro.classify.tree import (
    classify,
    classify_batched,
    classify_segmented,
    num_local_buckets,
)

__all__ = [
    "CLASSIFIERS",
    # tree
    "classify",
    "classify_batched",
    "classify_segmented",
    "num_local_buckets",
    # radix
    "radix_bucket_ids",
    "radix_shift",
    # learned
    "NUM_KNOTS",
    "IMBALANCE_THRESHOLD",
    "fit_cdf_knots",
    "eval_cdf_buckets",
    "sample_imbalance",
    "learned_bucket_ids",
    "learned_bucket_ids_batched",
    # router
    "resolve_classifier",
    "distribution_moments",
    "classifier_for",
]
