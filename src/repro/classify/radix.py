"""The radix bit-extractor classifier (IPS2Ra — arXiv 2009.13569 §5).

*Engineering In-place (Shared-memory) Sorting Algorithms* shows the IPS4o
partition pipeline wins substantially more when the branchless comparison
tree is replaced by a radix extractor: bucket = the next ``log2(k)`` bits
of the key.  No sampling pass, no splitter tree, one shift + one mask per
element — the cheapest classifier a total-order uint keyspace admits.

Our keyspace encoding (``ops/keyspace.py``) maps every supported dtype to
a same-width unsigned integer whose *bit-pattern order equals the key
order*, so the extractor drops in for free at the ``repro.ops`` boundary:

    j     = (key >> shift) & (k - 1),   shift = bits - consumed - log2(k)
    local = 2j + (key == sentinel)

``consumed`` is the number of bits already fixed by earlier radix levels:
level 1 consumes the top ``log2(k1)`` bits, so level 2's shift moves down
by exactly that much — the "per-level shift" of the paper's recursive
MSB radix.  The shift clamps at 0 for narrow keys; within a radix-aligned
segment the bits above the clamped mask are constant, so bucket ids stay
monotone in the key and the partition/base-case contract is unharmed.

The equality rule mirrors the tree classifier's last bucket: ``eq`` fires
only for keys equal to the dtype sentinel (all-ones — the encoding of the
pad key and of the NaN class), so pads and NaN runs land in an *odd*
(equality) bucket that deeper levels and the base case skip, exactly as
with sampled splitters.  Other duplicates get no equality buckets — the
trade of this engine: a value with more than ``slack * W / (2k)`` copies
overflows its bucket and triggers the robustness fallback, which is why
the "auto" router sends duplicate-heavy inputs elsewhere (DESIGN.md §9).

Monotonicity (required by the stable-partition + (bucket, key) base-case
contract): ``j`` is a nondecreasing step function of the key within the
level's domain whenever the domain agrees on the bits above the mask —
true globally at level 1 and true per segment at level 2 *because* level 1
was also a radix level.  ``repro.ops.segmented_sort`` therefore does NOT
accept this engine for user-supplied (arbitrary-range) segments.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.sampling import sentinel_for

__all__ = ["radix_shift", "radix_bucket_ids"]


def radix_shift(dtype, k: int, consumed_bits: int = 0) -> int:
    """Static right-shift placing the next log2(k) key bits at the bottom."""
    dtype = jnp.dtype(dtype)
    if dtype.kind != "u":
        raise ValueError(
            f"radix classifier needs keyspace-encoded (unsigned) keys, got {dtype}"
        )
    bits = dtype.itemsize * 8
    return max(bits - consumed_bits - int(math.log2(k)), 0)


def radix_bucket_ids(keys: jax.Array, k: int, consumed_bits: int = 0) -> jax.Array:
    """Local bucket ids in [0, 2k) for ``keys`` (any shape) — elementwise.

    ``2 * ((key >> shift) & (k-1)) + (key == sentinel)``; batched and
    segmented callers use the same function (the shift is data-independent,
    so there is no per-row or per-segment state to thread).
    """
    shift = radix_shift(keys.dtype, k, consumed_bits)
    j = jnp.bitwise_and(
        jnp.right_shift(keys, jnp.asarray(shift, keys.dtype)),
        jnp.asarray(k - 1, keys.dtype),
    ).astype(jnp.int32)
    eq = (keys == sentinel_for(keys.dtype)).astype(jnp.int32)
    return 2 * j + eq
