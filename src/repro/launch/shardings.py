"""PartitionSpec rules: the TP/FSDP/EP contract for every architecture.

One rule table maps parameter tree paths to logical shardings on the
(pod, data, model) production mesh:

  * **TP** (``model`` axis): attention heads / FFN hidden / vocab are
    column-sharded on their "parallel" matrices (wq/wk/wv, gate/up,
    lm_head, embed) and row-sharded on the reducing ones (wo, down) — the
    Megatron pairing, one reduce per block;
  * **FSDP/ZeRO** (``data`` (+``pod``) axes): the non-TP dim of every large
    matrix is additionally sharded over the dp axes; optimizer moments are
    elementwise so they inherit it (ZeRO-3 by construction);
  * **EP**: expert tensors (E, ..) shard E over ``model`` — dispatch becomes
    the all-to-all pair, the distributed instantiation of the paper's block
    permutation;
  * small vectors/scalars are replicated.

``strategy`` switches let the §Perf hillclimb swap regimes per cell (e.g.
pure-TP params for decode, sequence-sharded KV for long contexts) without
touching model code.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import dp_axes

__all__ = ["ShardingStrategy", "param_specs", "batch_specs", "cache_specs",
           "named", "logits_spec"]


@dataclass(frozen=True)
class ShardingStrategy:
    """Tunable regime knobs (hillclimbed in EXPERIMENTS.md §Perf)."""
    fsdp_params: bool = True       # shard params over dp axes (ZeRO-3)
    seq_shard_cache: Optional[bool] = None  # None: auto by kv-head divisibility
    shard_moe_router: bool = False
    embed_vocab_axis: str = "model"  # "model" | "none"


def _dp(mesh: Mesh) -> Tuple[str, ...]:
    return dp_axes(mesh)


def _tp_size(mesh: Mesh) -> int:
    return mesh.shape["model"]


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _rule(pstr: str, shape, cfg: ModelConfig, mesh: Mesh,
          strat: ShardingStrategy) -> P:
    dp = _dp(mesh) if strat.fsdp_params else None
    tp = "model"
    nd = len(shape)
    stacked = pstr.startswith("layers/")
    lead = (None,) if stacked else ()
    core = shape[1:] if stacked else shape

    def spec(*axes):
        return P(*(lead + axes))

    leaf = pstr.split("/")[-1]
    parent = pstr.split("/")[-2] if "/" in pstr else ""

    # ---- embeddings / head -------------------------------------------------
    if pstr == "embed":
        va = tp if strat.embed_vocab_axis == "model" else None
        return P(va, dp)
    if parent == "lm_head" and leaf in ("w",):
        return P(dp, tp)

    # ---- MoE expert banks (E, din, dout) -----------------------------------
    if "experts" in pstr and len(core) == 3:
        if leaf in ("gate", "up"):
            return spec(tp, dp, None)
        return spec(tp, None, dp)  # down
    if "router" in pstr:
        return spec(dp, None) if strat.shard_moe_router else spec(None, None)

    # ---- attention ----------------------------------------------------------
    if parent in ("wq", "wk", "wv") and leaf == "w":
        # column-parallel; kv projections with few heads still shard evenly
        # because the column dim is kv_heads*head_dim (GSPMD pads if uneven)
        return spec(dp, tp)
    if parent in ("wq", "wk", "wv") and leaf == "b":
        return spec(tp)
    if parent == "wo" and leaf == "w":
        return spec(tp, dp)

    # ---- dense / shared-expert MLPs ----------------------------------------
    if parent in ("gate", "up") and leaf == "w":
        return spec(dp, tp)
    if parent == "down" and leaf == "w":
        return spec(tp, dp)
    if leaf == "b":
        return spec(None)

    # ---- mamba2 -------------------------------------------------------------
    if parent == "in_proj" and leaf == "w":
        return spec(dp, tp)
    if parent == "out_proj" and leaf == "w":
        return spec(tp, dp)
    if leaf == "conv_w":
        return spec(None, tp)
    if leaf in ("conv_b", "norm_z"):
        return spec(tp)

    # ---- rwkv6 --------------------------------------------------------------
    if parent in ("wr", "wk", "wv", "wg") and leaf == "w":
        return spec(dp, tp)
    if parent == "wo" and leaf == "w":
        return spec(tp, dp)
    if parent in ("w_lora_a",) and leaf == "w":
        return spec(dp, None)
    if parent in ("w_lora_b",) and leaf == "w":
        return spec(None, tp)
    if leaf == "mu":
        return spec(None, tp)

    # ---- everything else (norm scales, per-head vectors, scalars) ----------
    return spec(*([None] * len(core)))


def param_specs(params: Any, cfg: ModelConfig, mesh: Mesh,
                strat: ShardingStrategy = ShardingStrategy()) -> Any:
    """Pytree of PartitionSpec congruent to ``params`` (works on
    ShapeDtypeStructs too)."""

    def f(path, leaf):
        return _rule(_path_str(path), leaf.shape, cfg, mesh, strat)

    return jax.tree_util.tree_map_with_path(f, params)


def _dp_for(mesh: Mesh, size: int):
    """dp axes if they divide ``size`` evenly, else the largest prefix that
    does (a batch of 1 — long_500k — simply replicates)."""
    axes = []
    prod = 1
    for a in _dp(mesh):
        if size % (prod * mesh.shape[a]) == 0:
            axes.append(a)
            prod *= mesh.shape[a]
    return tuple(axes) if axes else None


def batch_specs(cfg: ModelConfig, mesh: Mesh, batch: Any) -> Any:
    def f(path, leaf):
        nd = len(leaf.shape)
        dp = _dp_for(mesh, leaf.shape[0])
        if nd >= 3:  # embeds (B,S,D)
            return P(dp, None, None)
        return P(*( (dp,) + (None,) * (nd - 1) ))

    return jax.tree_util.tree_map_with_path(f, batch)


def cache_specs(cfg: ModelConfig, mesh: Mesh, cache: Any,
                strat: ShardingStrategy = ShardingStrategy()) -> Any:
    """Decode-cache shardings.  Leaves are stacked: leading dim = layers.

    KV tensors (L,B,T,KVH,hd): kv-heads over ``model`` when divisible,
    else the cache SEQUENCE dim is sharded over ``model`` (flash-decoding
    style) — that is what lets a 32k x 128-request cache of an 8-kv-head
    model fit.
    """
    tp_n = _tp_size(mesh)

    def f(path, leaf):
        pstr = _path_str(path)
        shape = leaf.shape
        leafname = pstr.split("/")[-1]
        dp = _dp_for(mesh, shape[1]) if len(shape) >= 2 else None
        if leafname in ("k", "v") and len(shape) == 5:
            kvh = shape[3]
            seq_shard = strat.seq_shard_cache
            if seq_shard is None:
                seq_shard = kvh % tp_n != 0
            if seq_shard:
                return P(None, dp, "model", None, None)
            return P(None, dp, None, "model", None)
        if leafname == "pos":
            return P(*([None] * len(shape)))
        if leafname == "wkv" and len(shape) == 5:  # (L,B,h,hd,hd)
            h = shape[2]
            if h % tp_n == 0:
                return P(None, dp, "model", None, None)
            return P(None, dp, None, None, None)
        if leafname == "ssm" and len(shape) == 5:  # (L,B,nh,hd,N)
            return P(None, dp, None, None, None)
        if leafname == "conv" and len(shape) == 4:  # (L,B,dc-1,d_in)
            return P(None, dp, None, "model")
        if len(shape) >= 2:  # shifts (L,B,D) etc.
            return P(*((None, dp) + (None,) * (len(shape) - 2)))
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(f, cache)


def logits_spec(mesh: Mesh) -> P:
    return P(_dp(mesh), None, "model")


def named(mesh: Mesh, specs: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
