"""Aggregate dry-run JSON rows into the EXPERIMENTS.md §Roofline table.

  PYTHONPATH=src python -m repro.launch.report results/dryrun [--md]
"""
from __future__ import annotations

import glob
import json
import os
import sys


def load(dirname: str):
    rows = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        d = json.load(open(f))
        d["_file"] = os.path.basename(f)
        rows.append(d)
    return rows


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    return f"{x * 1e3:.1f}ms"


def table(rows, md=False):
    hdr = ["arch", "shape", "mesh", "t_comp", "t_mem", "t_mem_min", "t_coll",
           "bott(min)", "useful", "peakGB", "frac"]
    out = []
    for d in rows:
        if d.get("status") == "skipped":
            out.append([d["arch"], d["shape"], d.get("mesh", ""), "skip:full-attn",
                        "", "", "", "", "", "", ""])
            continue
        if d.get("status") != "ok":
            out.append([d["arch"], d["shape"], d.get("mesh", ""),
                        "ERROR", "", "", "", "", "", "", ""])
            continue
        r = d["roofline"]
        tc, tm, tmm, tx = (r["t_compute"], r["t_memory"],
                           r.get("t_memory_min", 0.0), r["t_collective"])
        peak = (d.get("memory", {}).get("peak_memory_in_bytes")
                or d.get("memory", {}).get("argument_size_in_bytes", 0))
        # roofline fraction: useful-compute time over the modelled step time
        # (optimistic memory model; the honest "how close to roofline" score)
        model_t = r["model_flops"] / r["chips"] / 197e12
        frac = model_t / max(tc, tmm, tx) if max(tc, tmm, tx) else 0.0
        out.append([
            d["arch"], d["shape"], d["mesh"], fmt_s(tc), fmt_s(tm), fmt_s(tmm),
            fmt_s(tx), r.get("bottleneck_min", r["bottleneck"]),
            f"{r['useful_ratio']:.2f}", f"{peak / 2**30:.1f}",
            f"{frac:.3f}",
        ])
    w = [max(len(str(r[i])) for r in [hdr] + out) for i in range(len(hdr))]
    sep = " | " if md else "  "
    lines = [sep.join(str(h).ljust(w[i]) for i, h in enumerate(hdr))]
    if md:
        lines.insert(0, "| " + lines[0] + " |")
        lines[0] = lines.pop(0)
        lines.append("|" + "|".join("-" * (x + 2) for x in w) + "|")
        lines[0], lines[1] = lines[0], lines[1]
    for r in out:
        line = sep.join(str(c).ljust(w[i]) for i, c in enumerate(r))
        lines.append(("| " + line + " |") if md else line)
    if md:
        lines[0] = "| " + sep.join(str(h).ljust(w[i]) for i, h in enumerate(hdr)) + " |"
    return "\n".join(lines)


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    md = "--md" in sys.argv
    rows = load(d)
    pods = {}
    for r in rows:
        pods.setdefault("2pod" if "2pod" in r["_file"] else "1pod", []).append(r)
    for pod, rs in sorted(pods.items()):
        print(f"\n=== {pod} ===")
        print(table(rs, md=md))


if __name__ == "__main__":
    main()
