"""Roofline-term extraction from a compiled (unexecuted) XLA artifact.

Three terms per (arch x shape x mesh) cell, TPU v5e constants:

  compute    = HLO_FLOPs_global    / (chips * 197e12 FLOP/s bf16)
  memory     = HLO_bytes_global    / (chips * 819e9 B/s HBM)
  collective = collective_bytes    / (chips * 4 * 50e9 B/s ICI links)

``cost_analysis()`` reports the PER-DEVICE partitioned module (SPMD = one
program per device), so globals are per-device * chips and the chip count
cancels; we keep both forms for the table.  Collective bytes are NOT in
cost_analysis — we parse the optimized HLO and sum, for every
all-gather/all-reduce/reduce-scatter/all-to-all/collective-permute, the
bytes that cross the wire per device (receive-volume convention: result
bytes for gather-like ops, operand bytes for reduce-scatter; all-reduce
counts 2x operand (reduce-scatter + all-gather of a ring)).
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

__all__ = ["HW", "collective_bytes", "roofline_terms", "RooflineReport",
           "model_flops", "classify_tile_rows", "KernelLaunchSpec",
           "launch_spec", "spec_candidates"]

# TPU v5e per chip
HW = {
    "peak_flops": 197e12,       # bf16
    "hbm_bw": 819e9,            # B/s
    "ici_bw": 50e9,             # B/s per link
    "ici_links": 4,             # links/chip on a 2-D torus (16x16 pod)
    "hbm_bytes": 16 * 2**30,    # capacity
    "vmem_bytes": 16 * 2**20,   # VMEM per core — the Pallas tile budget
}

# unified kernel-launch model: lanes per VPU row, the VMEM fraction a
# double-buffered kernel may claim for one grid step, and the largest row
# count worth scheduling (past it the grid has too few steps to pipeline).
_CLASSIFY_LANES = 128
_CLASSIFY_VMEM_FRACTION = 3   # 1/3: input double-buffer + in-flight outputs
_CLASSIFY_MAX_ROWS = 128


@dataclass(frozen=True)
class KernelLaunchSpec:
    """One launch contract shared by every sort kernel (DESIGN.md §10).

    Each Pallas sort kernel used to pick its own tile shape with its own
    ad-hoc constant (classify: roofline rows, dispatch_rank: ``rows=8``,
    merge_path: ``tile=256``).  A :class:`KernelLaunchSpec` replaces the
    three code paths with one derivation: ``kind`` names the kernel's
    per-row working-set model, ``rows`` x ``lanes`` is the grid-step tile,
    ``vmem_budget`` is the bytes one grid step may claim (the VMEM budget
    already divided by ``double_buffer`` in-flight copies), and
    ``interpret`` is the shared off-TPU policy (``None`` resolves through
    ``kernels.resolve_interpret``).  ``rows == 0`` means no candidate tile
    divides the requested ``n`` — callers then stay on their XLA path.
    """

    kind: str
    rows: int
    lanes: int = _CLASSIFY_LANES
    vmem_budget: int = HW["vmem_bytes"] // _CLASSIFY_VMEM_FRACTION
    double_buffer: int = 2
    interpret: Optional[bool] = None

    @property
    def tile(self) -> int:
        """Elements per grid step."""
        return self.rows * self.lanes

    def resolve_interpret(self) -> bool:
        from repro.kernels import resolve_interpret

        return resolve_interpret(self.interpret)


def _bytes_per_row(kind: str, key_bytes: int, k: Optional[int]) -> int:
    """VMEM bytes one tile row of 128 lanes costs in kernel ``kind``.

    The models count the resident operands plus the dominant broadcast
    intermediate of each kernel body:

      classify     keys + (lanes, 2k) int32 compare/one-hot + bucket out
      rank         int32 bucket ids + (lanes, nb) one-hot + rank/dest out
      level_fused  classify AND rank in one body: keys + one-hot against
                   nb = 2k+1 + bucket/rank outputs
      merge        two (key, int32 src) sequences of the double window
      permute      two swap buffers of block rows
    """
    L = _CLASSIFY_LANES
    if kind == "classify":
        return L * (key_bytes + 4 * (2 * k) + 4)
    if kind == "rank":
        return L * (4 + 4 * k + 4)          # k is nb here
    if kind == "level_fused":
        return L * (key_bytes + 4 * (2 * k + 1) + 8)
    if kind == "merge":
        return L * 4 * (key_bytes + 4)       # (key, src) x in/out staging
    if kind == "permute":
        return L * 2 * key_bytes             # the two swap buffers
    raise ValueError(f"unknown kernel kind {kind!r}")


_MAX_ROWS = {
    "classify": _CLASSIFY_MAX_ROWS,
    "rank": _CLASSIFY_MAX_ROWS,
    "level_fused": _CLASSIFY_MAX_ROWS,
    "merge": 8,       # merge-path T = rows*128; diagonals grow linearly in T
    "permute": 64,    # block_elems = rows*128
}


def spec_candidates(
    kind: str,
    key_bytes: int,
    k: Optional[int] = None,
    *,
    vmem_bytes: Optional[int] = None,
    max_rows: Optional[int] = None,
) -> tuple:
    """Descending power-of-two row-count candidates for kernel ``kind``.

    The largest candidate is the biggest power of two whose working set
    (``_bytes_per_row`` x rows) fits the per-step VMEM budget (one
    ``_CLASSIFY_VMEM_FRACTION``-th of VMEM: input double-buffer plus
    in-flight outputs); the tail enumerates down to one row so callers can
    pick the largest candidate dividing their n and the plan cache can
    sweep the leading entries.
    """
    budget = (HW["vmem_bytes"] if vmem_bytes is None else vmem_bytes)
    budget //= _CLASSIFY_VMEM_FRACTION
    per_row = _bytes_per_row(kind, key_bytes, k)
    cap = _MAX_ROWS[kind] if max_rows is None else max_rows
    rows = 1
    while rows * 2 <= cap and (rows * 2) * per_row <= budget:
        rows *= 2
    out = []
    while rows >= 1:
        out.append(rows)
        rows //= 2
    return tuple(out)


def launch_spec(
    kind: str,
    key_bytes: int,
    k: Optional[int] = None,
    *,
    n: Optional[int] = None,
    rows: Optional[int] = None,
    vmem_bytes: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> KernelLaunchSpec:
    """The one tile-shape derivation every sort kernel launches through.

    ``rows`` pins a swept value (the plan-cache autotune dimension);
    otherwise the largest :func:`spec_candidates` entry wins, filtered to
    tiles dividing ``n`` when given (``rows == 0`` in the returned spec
    when none divides — n not 128-aligned — and the caller stays on XLA).

    >>> launch_spec("classify", 4, 128).rows
    32
    >>> launch_spec("merge", 4).tile
    1024
    >>> launch_spec("classify", 4, 128, n=1000).rows
    0
    """
    budget = (HW["vmem_bytes"] if vmem_bytes is None else vmem_bytes)
    budget //= _CLASSIFY_VMEM_FRACTION
    cands = spec_candidates(kind, key_bytes, k, vmem_bytes=vmem_bytes)
    if rows is None:
        rows = 0
        for cand in cands:
            if n is None or n % (cand * _CLASSIFY_LANES) == 0:
                rows = cand
                break
    elif n is not None and n % (rows * _CLASSIFY_LANES):
        rows = 0
    from repro import obs  # lazy: keep the roofline importable without jax

    obs.count("launch.spec", kind=kind, rows=rows)  # rows=0 = XLA fallback
    return KernelLaunchSpec(
        kind=kind, rows=rows, vmem_budget=budget, interpret=interpret
    )


def classify_tile_rows(
    key_bytes: int,
    k: int,
    *,
    vmem_bytes: Optional[int] = None,
    max_rows: int = _CLASSIFY_MAX_ROWS,
) -> tuple:
    """Row-count candidates for the fused classify kernels, from the VMEM
    roofline instead of a hard-coded constant.

    One grid step of ``kernels/classify.py`` holds, per tile row of 128
    lanes: the keys (``key_bytes`` each), the int32 one-hot / compare
    broadcast against nb = 2k buckets, and the int32 bucket output — so

        bytes_per_row = 128 * (key_bytes + 4 * 2k + 4)

    and the largest power-of-two row count fitting a third of VMEM
    (input double-buffer + in-flight outputs) leads a descending
    candidate tuple; the plan cache sweeps the leading entries and the
    level pass picks the largest candidate dividing n.  At the defaults
    (f32/u32 keys, k = 128, 16 MiB VMEM) this reproduces the previously
    hard-coded 32 rows.  This is the ``kind="classify"`` projection of
    :func:`spec_candidates`, kept as the stable entry point.

    >>> classify_tile_rows(4, 128)[0]
    32
    >>> classify_tile_rows(4, 32)[0] > classify_tile_rows(8, 256)[0]
    True
    """
    return spec_candidates(
        "classify", key_bytes, k, vmem_bytes=vmem_bytes, max_rows=max_rows
    )

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape string, incl. tuples '(f32[..], bf16[..])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device wire bytes by collective kind, from optimized HLO text."""
    out: Dict[str, int] = {}
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        result_shape, kind = m.group(1), m.group(2)
        # async pairs: count the -start, skip the -done
        if "-done(" in line:
            continue
        rb = _shape_bytes(result_shape)
        # operand bytes: everything inside the call parens
        inner = line[line.index("(") + 1 :]
        ob = _shape_bytes(inner)
        if kind == "all-reduce":
            wire = 2 * ob          # ring RS+AG
        elif kind == "reduce-scatter":
            wire = ob
        elif kind == "all-gather":
            wire = rb
        elif kind == "all-to-all":
            wire = max(rb, ob)
        else:  # collective-permute
            wire = rb
        out[kind] = out.get(kind, 0) + wire
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    coll_breakdown: Dict[str, int]
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float
    useful_ratio: float          # MODEL_FLOPS / global HLO flops
    peak_mem_per_dev: Optional[float] = None
    note: str = ""
    raw_flops_per_dev: float = 0.0   # cost_analysis() as reported (loops x1)
    raw_bytes_per_dev: float = 0.0
    n_while: int = 0
    loop_trips: Dict[str, int] = field(default_factory=dict)
    bytes_min_per_dev: float = 0.0   # fusion-optimistic HBM traffic
    t_memory_min: float = 0.0
    bottleneck_min: str = ""         # bottleneck under optimistic memory

    def to_json(self) -> str:
        return json.dumps(asdict(self))


def roofline_terms(
    *, arch: str, shape: str, mesh_name: str, chips: int,
    flops_per_dev: float, bytes_per_dev: float, hlo_text: str,
    model_fl: float, peak_mem: Optional[float] = None, note: str = "",
) -> RooflineReport:
    """``flops_per_dev``/``bytes_per_dev`` are the RAW cost_analysis numbers
    (loop bodies counted once — see launch/hlo_cost.py).  We re-derive
    trip-count-corrected values from the HLO text and use THOSE for the
    three terms; the raws are kept in the report for comparison."""
    from repro.launch.hlo_cost import analyze_hlo

    hc = analyze_hlo(hlo_text)
    raw_flops, raw_bytes = flops_per_dev, bytes_per_dev
    # corrected flops: never less than what XLA itself counted
    flops_per_dev = max(hc.flops, raw_flops)
    bytes_per_dev = max(hc.bytes, raw_bytes)
    bytes_min = hc.bytes_min
    coll = {k: int(v) for k, v in hc.coll.items()}
    cb = float(sum(coll.values()))
    t_c = flops_per_dev / HW["peak_flops"]
    t_m = bytes_per_dev / HW["hbm_bw"]          # conservative (XLA convention)
    t_m_min = bytes_min / HW["hbm_bw"]          # fusion-optimistic (TPU real)
    t_x = cb / (HW["ici_links"] * HW["ici_bw"])
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bott = max(terms, key=terms.get)
    # bottleneck under the TPU-realistic memory model (used by §Perf)
    terms_min = {"compute": t_c, "memory": t_m_min, "collective": t_x}
    bott_min = max(terms_min, key=terms_min.get)
    global_flops = flops_per_dev * chips
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_dev=flops_per_dev, bytes_per_dev=bytes_per_dev,
        coll_bytes_per_dev=cb, coll_breakdown=coll,
        t_compute=t_c, t_memory=t_m, t_collective=t_x, bottleneck=bott,
        model_flops=model_fl,
        useful_ratio=(model_fl / global_flops) if global_flops else 0.0,
        peak_mem_per_dev=peak_mem, note=note,
        raw_flops_per_dev=raw_flops, raw_bytes_per_dev=raw_bytes,
        n_while=hc.n_while, loop_trips=dict(hc.trips),
        bytes_min_per_dev=bytes_min, t_memory_min=t_m_min,
        bottleneck_min=bott_min,
    )


def _param_count(cfg) -> float:
    """Total parameter count N (all experts counted; N_active separately)."""
    d, L, V = cfg.d_model, cfg.num_layers, cfg.vocab_size
    hd = cfg.hd
    emb = V * d * (1 if cfg.tie_embeddings else 2)
    if cfg.family == "ssm":  # rwkv6
        tm = 5 * d * d + 2 * d * 64 + d  # r,k,v,g,o + lora
        cm = d * cfg.d_ff * 2 + d * d
        return L * (tm + cm) + emb
    attn = d * (cfg.num_heads * hd) * 2 + d * (cfg.num_kv_heads * hd) * 2
    if cfg.family == "moe":
        m = cfg.moe
        routed = m.num_experts * 3 * d * m.d_ff_expert
        shared = (3 * d * m.d_ff_shared) if m.num_shared else 0
        ffn = routed + shared + d * m.num_experts
    else:
        ffn = 3 * d * cfg.d_ff
    if cfg.family == "hybrid":
        s = cfg.ssm
        d_in = s.expand * d
        mamba = d * (2 * d_in + 2 * s.d_state + d_in // s.head_dim) + d_in * d
        per = mamba + 3 * d * cfg.d_ff
        groups = L // s.attn_every
        return L * per + attn + emb  # ONE shared attn block
    return L * (attn + ffn) + emb


def _active_param_count(cfg) -> float:
    if cfg.family != "moe":
        return _param_count(cfg)
    d, L = cfg.d_model, cfg.num_layers
    m = cfg.moe
    attn = d * (cfg.num_heads * cfg.hd) * 2 + d * (cfg.num_kv_heads * cfg.hd) * 2
    act = m.top_k * 3 * d * m.d_ff_expert + (3 * d * m.d_ff_shared if m.num_shared else 0)
    emb = cfg.vocab_size * d * 2
    return L * (attn + act + d * m.num_experts) + emb


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); D = tokens processed.
    For decode shapes D = global_batch (one token per request);
    train counts fwd+bwd (6ND), prefill/decode fwd only (2ND)."""
    n_act = _active_param_count(cfg)
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n_act * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n_act * toks
    return 2.0 * n_act * shape.global_batch
