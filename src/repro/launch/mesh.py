"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run sets
``xla_force_host_platform_device_count=512`` before first jax init, while
smoke tests must see the 1 real CPU device.
"""
from __future__ import annotations

from typing import Tuple

import jax

__all__ = ["make_production_mesh", "dp_axes", "tp_axis"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> Tuple[str, ...]:
    """Data-parallel axes: batch (and FSDP/ZeRO param+state sharding)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_axis(mesh) -> str:
    return "model"
