"""End-to-end training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On this CPU container use ``--reduced`` (the ~100M-class smoke config); the
same launcher drives the full configs on a real mesh (the multi-pod path is
exercised by launch/dryrun.py).  Demonstrates: data pipeline, sharded init,
jitted step with accumulation, checkpoint/restart (kill it mid-run and
re-launch: it resumes from the newest complete checkpoint), straggler
ledger logging.
"""
import argparse
import sys

import jax


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs.registry import get_config, get_reduced
    from repro.data.pipeline import SyntheticLM
    from repro.optim.adamw import AdamWConfig
    from repro.train.trainer import TrainConfig, Trainer

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    tcfg = TrainConfig(
        microbatch=args.microbatch,
        warmup_steps=max(args.steps // 10, 1),
        total_steps=args.steps,
        compress_grads=args.compress_grads,
        adamw=AdamWConfig(lr=args.lr),
    )
    ndev = len(jax.devices())
    mesh = jax.make_mesh((ndev, 1), ("data", "model"))
    data = SyntheticLM(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed, embed_dim=cfg.d_model if cfg.takes_embeds else 0,
    )

    trainer = Trainer(cfg, tcfg, mesh, ckpt_dir=args.ckpt_dir, seed=args.seed)
    trainer.init_state()
    if trainer.maybe_restore():
        print(f"resumed from step {trainer.step_num}")
    it = iter(data)
    # fast-forward the data stream for bitwise-identical resume
    for _ in range(trainer.step_num):
        next(it)
    metrics = trainer.run(it, args.steps - trainer.step_num,
                          ckpt_every=args.ckpt_every)
    print("final:", metrics)
    return 0


if __name__ == "__main__":
    sys.exit(main())
