"""Trip-count-aware cost model over optimized HLO text.

Why this exists: ``compiled.cost_analysis()`` counts the body of every
``while`` loop (= every ``lax.scan``: the layer stack, the microbatch
accumulation loop, the decode loop) exactly ONCE — verified on this jax
build with a 10-step scan reporting 1/10th of the unrolled flops.  Our
dry-run models are 90%+ scan-shaped, so the raw numbers undercount
flops/bytes/collective-bytes by 1-2 orders of magnitude and would make the
roofline table fiction.

This module re-derives the three roofline inputs from the optimized HLO
text itself, multiplying loop bodies by their trip counts, which XLA
helpfully serializes on each while op::

    backend_config={"known_trip_count":{"n":"126"}, ...}

Cost conventions (mirroring xla::HloCostAnalysis):
  * dot: 2 * prod(result_dims) * prod(lhs contracting dim sizes)
  * elementwise / reduce: prod(result dims) (reduce: prod(operand dims))
  * bytes: per *top-level* op in sequential computations (entry, while
    bodies, call/conditional targets): operand bytes + result bytes.
    Fusion ops count their operands+result only (the fused body is
    VMEM-resident by construction — that is the fusion contract), but
    contribute their internal dot/elementwise flops.
  * collectives: wire bytes per device — all-gather: result; reduce-scatter:
    operand; all-reduce: 2x operand (ring RS+AG); all-to-all / permute:
    max(result, operand) / result.  Multiplied by enclosing trip counts,
    which the naive line-scan in roofline.collective_bytes could not do.

Pure text processing — no jax import, works on any backend's HLO.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

# opcodes that move no data / are bookkeeping
_FREE = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "opt-barrier", "domain",
    "get-dimension-size", "add-dependency",
}
# ~1 flop per output element
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "and", "or", "xor", "not", "negate", "abs", "sign", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "exponential",
    "exponential-minus-one", "log", "log-plus-one", "tanh", "rsqrt", "sqrt",
    "cbrt", "sine", "cosine", "tan", "logistic", "atan2", "compare",
    "select", "clamp", "remainder", "shift-left", "shift-right-arithmetic",
    "shift-right-logical", "popcnt", "clz", "erf", "is-finite",
    "stochastic-convert",
}
_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
}

_TRIP_RE = re.compile(r'known_trip_count[="{\\]+n[\\":]+(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LCD_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPNAME_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(shape_str: str) -> int:
    """Elements of the FIRST array shape in the string."""
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 0
    n = 1
    if m.group(2):
        for d in m.group(2).split(","):
            n *= int(d)
    return n


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclass
class _Op:
    name: str
    shape: str           # result shape string
    opcode: str
    args: str            # raw text inside the call parens
    attrs: str           # raw text after the call parens
    is_root: bool = False


@dataclass
class _Computation:
    name: str
    ops: List[_Op] = field(default_factory=list)
    defs: Dict[str, str] = field(default_factory=dict)  # op name -> shape str


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0       # conservative: trip-corrected XLA bytes-accessed
    bytes_min: float = 0.0   # fusion-optimistic: TPU-fusable elementwise free
    coll: Dict[str, float] = field(default_factory=dict)
    n_while: int = 0
    trips: Dict[str, int] = field(default_factory=dict)
    bytes_by_op: Dict[str, float] = field(default_factory=dict)

    @property
    def coll_bytes(self) -> float:
        return float(sum(self.coll.values()))

    def _add(self, other: "HloCost", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.bytes_min += mult * other.bytes_min
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + mult * v
        for k, v in other.bytes_by_op.items():
            self.bytes_by_op[k] = self.bytes_by_op.get(k, 0.0) + mult * v
        self.n_while += other.n_while
        self.trips.update(other.trips)

    def _addb(self, op_kind: str, nbytes: float, hard: bool = False) -> None:
        """hard=True: traffic a TPU cannot fuse away (dot operands, copies,
        stack writes, collectives) — contributes to bytes_min as well."""
        self.bytes += nbytes
        if hard:
            self.bytes_min += nbytes
        self.bytes_by_op[op_kind] = self.bytes_by_op.get(op_kind, 0.0) + nbytes


def _matching_paren(s: str, start: int) -> int:
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i
    return len(s) - 1


def _parse_op(line: str) -> Optional[_Op]:
    m = _DEF_RE.match(line)
    if m is None:
        return None
    name, rest = m.group(1), m.group(2)
    # result shape: tuple '(...)' (balance parens) or single token
    if rest.startswith("("):
        end = _matching_paren(rest, 0)
        shape = rest[: end + 1]
        rest2 = rest[end + 1 :].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape = rest[:sp]
        rest2 = rest[sp + 1 :]
    pi = rest2.find("(")
    if pi < 0:
        return None
    opcode = rest2[:pi].strip()
    close = _matching_paren(rest2, pi)
    args = rest2[pi + 1 : close]
    attrs = rest2[close + 1 :]
    return _Op(name=name, shape=shape, opcode=opcode, args=args, attrs=attrs,
               is_root=line.lstrip().startswith("ROOT"))


def _parse_module(hlo_text: str) -> Tuple[Dict[str, _Computation], Optional[str]]:
    comps: Dict[str, _Computation] = {}
    entry: Optional[str] = None
    cur: Optional[_Computation] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if cur is None:
            s = line.strip()
            is_entry = s.startswith("ENTRY ")
            if is_entry:
                s = s[len("ENTRY "):].strip()
            if s.startswith("%") and s.endswith("{") and "(" in s:
                cname = s[1 : s.index(" ")] if " " in s else s[1:-1]
                cname = cname.split("(")[0].rstrip()
                cur = _Computation(name=cname)
                if is_entry:
                    entry = cname
                # parameters are declared in the header but re-declared as
                # 'parameter(i)' lines in the body, so no extra handling
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        op = _parse_op(line)
        if op is not None:
            cur.ops.append(op)
            cur.defs[op.name] = op.shape
    if cur is not None:  # unterminated (defensive)
        comps[cur.name] = cur
    return comps, entry


def _dot_flops(op: _Op, comp: _Computation) -> float:
    out_elems = _shape_elems(op.shape)
    lcd = _LCD_RE.search(op.attrs)
    contract = 1
    names = _OPNAME_RE.findall(op.args)
    if lcd and names:
        lhs_shape = comp.defs.get(names[0], "")
        dims = _shape_dims(lhs_shape)
        if lcd.group(1):
            for d in lcd.group(1).split(","):
                di = int(d)
                if di < len(dims):
                    contract *= dims[di]
    return 2.0 * out_elems * contract


def _operand_bytes(op: _Op, comp: _Computation) -> int:
    total = 0
    for nm in _OPNAME_RE.findall(op.args):
        total += _shape_bytes(comp.defs.get(nm, ""))
    return total


def _wire_bytes(op: _Op, comp: _Computation) -> float:
    rb = _shape_bytes(op.shape)
    ob = _operand_bytes(op, comp)
    kind = op.opcode
    for suffix in ("-start", "-done"):
        if kind.endswith(suffix):
            kind = kind[: -len(suffix)]
    if kind == "all-reduce":
        return 2.0 * ob
    if kind == "reduce-scatter":
        return float(ob)
    if kind == "all-gather":
        return float(rb)
    if kind == "all-to-all":
        return float(max(rb, ob))
    return float(rb)  # permute / broadcast


_SLICING = {"dynamic-slice", "slice", "gather"}


def _fusion_bytes(fop: _Op, fc: _Computation) -> "Tuple[float, float]":
    """HBM bytes of one fusion call, use-aware:

    * a fused-computation parameter consumed ONLY through
      slice/dynamic-slice/gather contributes the sliced bytes, not the full
      operand (the classic case: picking one layer's slab out of a stacked
      [L, ...] scan carry — charging the full stack would overcount x L);
    * a parameter used as the BASE of a dynamic-update-slice is aliased
      in-place and contributes nothing;
    * a root that is a dynamic-update-slice writes only the update slice.

    Mirrors xla::HloCostAnalysis's fusion handling closely enough for
    roofline purposes.

    Returns ``(conservative, hard)``: the conservative figure charges all
    surviving operands+results; the hard figure keeps only traffic that even
    a perfectly-fusing TPU backend must perform — sliced reads out of big
    loop-carried stacks and dynamic-update-slice writes into them.
    """
    total = 0.0
    hard = 0.0
    roots = set()
    root_op = None
    for o in fc.ops:
        if o.is_root:
            root_op = o
    # --- operand side ---
    for o in fc.ops:
        if o.opcode != "parameter":
            continue
        full = _shape_bytes(o.shape)
        uses = [u for u in fc.ops
                if u.opcode != "parameter"
                and o.name in _OPNAME_RE.findall(u.args)]
        if not uses:
            continue
        b = 0.0
        direct_full = False
        for u in uses:
            if u.opcode in _SLICING:
                b += _shape_bytes(u.shape)
            elif u.opcode == "dynamic-update-slice":
                unames = _OPNAME_RE.findall(u.args)
                if unames and unames[0] == o.name:
                    continue  # in-place base: aliased, no traffic
                direct_full = True
                break
            else:
                direct_full = True
                break
        if direct_full:
            total += full
        else:
            total += min(b, full)
            hard += min(b, full)
    # --- result side ---
    if root_op is not None and root_op.opcode == "dynamic-update-slice":
        unames = _OPNAME_RE.findall(root_op.args)
        upd = fc.defs.get(unames[1], "") if len(unames) > 1 else ""
        w = _shape_bytes(upd) if upd else _shape_bytes(root_op.shape)
        total += w
        hard += w
    elif root_op is not None and root_op.opcode == "tuple":
        for nm in _OPNAME_RE.findall(root_op.args):
            elt = None
            for o in fc.ops:
                if o.name == nm:
                    elt = o
                    break
            if elt is not None and elt.opcode == "dynamic-update-slice":
                un = _OPNAME_RE.findall(elt.args)
                upd = fc.defs.get(un[1], "") if len(un) > 1 else ""
                w = _shape_bytes(upd) if upd else _shape_bytes(elt.shape)
                total += w
                hard += w
            else:
                total += _shape_bytes(fc.defs.get(nm, ""))
    else:
        total += _shape_bytes(fop.shape)
    return total, hard


def _trip_count(op: _Op, comps: Dict[str, _Computation]) -> int:
    m = _TRIP_RE.search(op.attrs)
    if m:
        return int(m.group(1))
    # fallback: condition computation comparing induction var to constant
    cm = _COND_RE.search(op.attrs)
    if cm and cm.group(1) in comps:
        cond = comps[cm.group(1)]
        const = None
        for o in cond.ops:
            if o.opcode == "constant" and o.shape.startswith(("s32", "s64", "u32", "u64")):
                try:
                    const = int(o.args)
                except ValueError:
                    pass
        if const is not None:
            return max(1, const)
    return 1


class _Analyzer:
    def __init__(self, comps: Dict[str, _Computation]):
        self.comps = comps
        self._memo: Dict[Tuple[str, bool], HloCost] = {}

    def cost(self, cname: str, fused: bool) -> HloCost:
        key = (cname, fused)
        if key in self._memo:
            return self._memo[key]
        # cycle guard: HLO computations form a DAG, but be defensive
        self._memo[key] = HloCost()
        comp = self.comps.get(cname)
        out = HloCost()
        if comp is None:
            self._memo[key] = out
            return out
        for op in comp.ops:
            oc = op.opcode
            base = oc
            for suffix in ("-start", "-done", "-update"):
                if base.endswith(suffix):
                    base = base[: -len(suffix)]
            if base in _FREE:
                continue
            if base in _COLLECTIVES:
                if oc.endswith("-done") or oc.endswith("-update"):
                    continue  # counted at -start
                w = _wire_bytes(op, comp)
                out.coll[base] = out.coll.get(base, 0.0) + w
                if not fused:
                    out._addb(base, _operand_bytes(op, comp) + _shape_bytes(op.shape))
                continue
            if oc == "while":
                bm = _BODY_RE.search(op.attrs)
                trip = _trip_count(op, self.comps)
                out.n_while += 1
                if bm:
                    body = self.cost(bm.group(1), fused=False)
                    out._add(body, mult=trip)
                    out.trips[bm.group(1)] = trip
                continue
            if oc == "conditional":
                names = _BRANCH_RE.search(op.attrs)
                branches = (_OPNAME_RE.findall(names.group(1)) if names else [])
                if not branches:
                    branches = _OPNAME_RE.findall(op.attrs)
                if branches:
                    costs = [self.cost(b, fused=False) for b in branches]
                    # static roofline: charge the most expensive branch
                    out._add(max(costs, key=lambda c: (c.flops, c.bytes)))
                if not fused:
                    out._addb("conditional", _operand_bytes(op, comp) + _shape_bytes(op.shape))
                continue
            if oc == "fusion":
                cm = _CALLS_RE.search(op.attrs)
                fc = self.comps.get(cm.group(1)) if cm else None
                if fc is not None:
                    out._add(self.cost(fc.name, fused=True))
                if not fused:
                    if fc is not None:
                        cons, hard = _fusion_bytes(op, fc)
                        out._addb("fusion", cons)
                        out.bytes_min += hard
                    else:
                        out._addb("fusion", _operand_bytes(op, comp)
                                  + _shape_bytes(op.shape), hard=True)
                continue
            if oc in ("call", "async-start"):
                cm = _CALLS_RE.search(op.attrs) or _APPLY_RE.search(op.attrs)
                if cm:
                    out._add(self.cost(cm.group(1), fused=fused))
                continue
            if oc == "dot":
                out.flops += _dot_flops(op, comp)
                if not fused:
                    out._addb("dot", _operand_bytes(op, comp)
                              + _shape_bytes(op.shape), hard=True)
                continue
            if oc == "convolution":
                # rhs operand = kernel; flops ~ 2 * out_elems * kernel_elems
                names = _OPNAME_RE.findall(op.args)
                kelems = _shape_elems(comp.defs.get(names[1], "")) if len(names) > 1 else 1
                out_batchfeat = _shape_elems(op.shape)
                out.flops += 2.0 * out_batchfeat * max(1, kelems // max(
                    1, _shape_dims(comp.defs.get(names[1], ""))[-1] if names[1:] and _shape_dims(comp.defs.get(names[1], "")) else 1))
                if not fused:
                    out._addb("convolution", _operand_bytes(op, comp)
                              + _shape_bytes(op.shape), hard=True)
                continue
            if base in ("reduce", "reduce-window"):
                out.flops += float(_shape_elems(
                    comp.defs.get(_OPNAME_RE.findall(op.args)[0], "")
                ) if _OPNAME_RE.findall(op.args) else 0)
                if not fused:
                    out._addb("reduce", _operand_bytes(op, comp)
                              + _shape_bytes(op.shape), hard=True)
                continue
            if oc in ("dynamic-slice", "slice"):
                # read + write the slice only, not the sliced-from buffer
                if not fused:
                    out._addb(oc, 2.0 * _shape_bytes(op.shape), hard=True)
                continue
            if oc == "dynamic-update-slice":
                names = _OPNAME_RE.findall(op.args)
                upd = comp.defs.get(names[1], "") if len(names) > 1 else ""
                ub = _shape_bytes(upd) if upd else _shape_bytes(op.shape)
                if not fused:
                    out._addb(oc, 2.0 * ub, hard=True)  # read upd + write slice
                continue
            if base in _ELEMENTWISE or base in ("convert", "map", "iota",
                                                "rng", "rng-bit-generator",
                                                "exponential"):
                out.flops += float(_shape_elems(op.shape))
            # data-movement ops (copy, transpose, reshape, broadcast, slice,
            # dynamic-slice, dynamic-update-slice, gather, scatter, pad,
            # concatenate, sort, ...) and elementwise: bytes at top level
            if not fused:
                out._addb(base, _operand_bytes(op, comp) + _shape_bytes(op.shape))
        self._memo[key] = out
        return out


def analyze_hlo(hlo_text: str) -> HloCost:
    """Trip-count-corrected {flops, bytes, collective wire bytes} of the
    per-device optimized HLO module."""
    comps, entry = _parse_module(hlo_text)
    if entry is None:
        # fall back: largest computation
        entry = max(comps, key=lambda c: len(comps[c].ops)) if comps else None
    if entry is None:
        return HloCost()
    # computations reachable only via fusion 'calls=' must not double-count:
    # cost() is called from the entry, so unreachable comps are ignored.
    return _Analyzer(comps).cost(entry, fused=False)
