import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede any jax import (jax locks the device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh ((16,16) or (2,16,16));
  2. builds ShapeDtypeStruct inputs (no allocation) via configs.registry;
  3. jits the right step (train_step / prefill / decode) with the
     production in/out shardings and ``.lower().compile()``s it;
  4. prints ``memory_analysis()`` (proves the cell fits 16 GiB/chip) and
     ``cost_analysis()`` (FLOPs/bytes for EXPERIMENTS.md §Roofline);
  5. parses the optimized HLO for collective bytes and emits the roofline
     JSON row.

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all --out results/dryrun  (40 cells)
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.registry import (
    SHAPES, Shape, cells, get_config, input_specs, shape_applicable,
)
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.launch.roofline import model_flops, roofline_terms
from repro.launch.shardings import (
    ShardingStrategy, batch_specs, cache_specs, named, param_specs,
)


def default_microbatch(cfg: ModelConfig, shape: Shape, mesh) -> int:
    """Accumulation so that per-dp-shard microbatch keeps live activations
    inside 16 GiB (1 row/shard for the giant archs, 4 otherwise)."""
    dp = 1
    for a in dp_axes(mesh):
        dp *= mesh.shape[a]
    per_shard = 1 if cfg.d_model >= 8192 or cfg.num_layers >= 90 else 4
    mb = min(shape.global_batch, dp * per_shard)
    while shape.global_batch % mb:
        mb -= 1
    return max(1, mb)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               strat: ShardingStrategy = ShardingStrategy(),
               tcfg=None, verbose: bool = True,
               hlo_out: Optional[str] = None,
               flash_block: int = 0,
               explicit_ep: bool = False) -> Dict[str, Any]:
    from repro.models.transformer import (
        forward, init_decode_cache, init_model,
    )
    from repro.train.trainer import TrainConfig, make_train_step
    from repro.optim.adamw import adamw_init

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not shape_applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": "full-attention arch: long_500k needs sub-quadratic"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(mesh.shape[a]) for a in mesh.axis_names)
    chips = 1
    for a in mesh.axis_names:
        chips *= mesh.shape[a]

    specs = input_specs(cfg, shape)
    params_like = jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))
    psh = named(mesh, param_specs(params_like, cfg, mesh, strat))

    from repro.models.policy import compute_policy

    t0 = time.perf_counter()
    with mesh:  # ambient mesh: resolves shard_hint P-constraints at trace
        with compute_policy(flash_block=flash_block, explicit_ep=explicit_ep):
            lowered = _lower(shape, cfg, mesh, specs, params_like, psh,
                             strat, tcfg)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    with mesh:
        compiled = lowered.compile()
    t_compile = time.perf_counter() - t0
    if hlo_out:
        with open(hlo_out, "w") as f:
            f.write(compiled.as_text())
    return _finish(arch, shape_name, cfg, shape, mesh_name, chips, compiled,
                   t_lower, t_compile, verbose)


def _lower(shape, cfg, mesh, specs, params_like, psh, strat, tcfg):
    import jax
    import jax.numpy as jnp
    from repro.models.transformer import forward, init_decode_cache
    from repro.train.trainer import TrainConfig, make_train_step
    from repro.optim.adamw import adamw_init
    from repro.launch.shardings import batch_specs, cache_specs, named

    if shape.kind == "train":
        if tcfg is None:
            tcfg = TrainConfig(microbatch=default_microbatch(cfg, shape, mesh))
        stepf, state_sh, batch_sh_fn = make_train_step(
            cfg, tcfg, mesh, strat, params_like, batch_like=specs
        )
        state_like = {
            "params": params_like,
            "opt": jax.eval_shape(lambda p: adamw_init(p, tcfg.adamw), params_like),
        }
        if tcfg.compress_grads:
            state_like["eff"] = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), params_like
            )
        batch_like = specs
        lowered = stepf.lower(state_like, batch_like)
    elif shape.kind == "prefill":
        cache_like = jax.eval_shape(
            lambda: init_decode_cache(cfg, shape.global_batch, shape.seq_len)
        )
        csh = named(mesh, cache_specs(cfg, mesh, cache_like, strat))
        bsh = named(mesh, batch_specs(cfg, mesh, specs))

        def prefill(params, inputs, cache):
            logits, new_cache, _ = forward(params, cfg, inputs, cache=cache,
                                           update_cache=True)
            return logits[:, -1], new_cache

        fn = jax.jit(prefill, in_shardings=(psh, bsh["inputs"], csh),
                     donate_argnums=(2,))
        lowered = fn.lower(params_like, specs["inputs"], cache_like)
    else:  # decode
        cache_like = specs["cache"]
        csh = named(mesh, cache_specs(cfg, mesh, cache_like, strat))
        tok_like = specs["inputs"]
        bsh = named(mesh, batch_specs(cfg, mesh, {"inputs": tok_like}))
        pos_like = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)

        def decode(params, tok, pos, cache):
            logits, new_cache, _ = forward(params, cfg, tok, positions=pos,
                                           cache=cache, update_cache=True)
            return logits[:, 0], new_cache

        fn = jax.jit(decode, in_shardings=(psh, bsh["inputs"], None, csh),
                     donate_argnums=(3,))
        lowered = fn.lower(params_like, tok_like, pos_like, cache_like)

    return lowered


def _finish(arch, shape_name, cfg, shape, mesh_name, chips, compiled,
            t_lower, t_compile, verbose) -> Dict[str, Any]:
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    peak = None
    mem_repr = {}
    if mem is not None:
        for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "generated_code_size_in_bytes",
                  "peak_memory_in_bytes", "alias_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                mem_repr[k] = int(v)
        peak = mem_repr.get("peak_memory_in_bytes") or (
            mem_repr.get("temp_size_in_bytes", 0)
            + mem_repr.get("argument_size_in_bytes", 0)
        )

    rep = roofline_terms(
        arch=arch, shape=shape_name, mesh_name=mesh_name, chips=chips,
        flops_per_dev=flops, bytes_per_dev=bytes_acc, hlo_text=hlo,
        model_fl=model_flops(cfg, shape), peak_mem=peak,
    )
    row = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
        "status": "ok", "t_lower_s": round(t_lower, 1),
        "t_compile_s": round(t_compile, 1), "memory": mem_repr,
        "roofline": json.loads(rep.to_json()),
    }
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] compiled "
              f"in {t_compile:.1f}s; mem={mem_repr}", flush=True)
        print(f"  flops/dev={flops:.3e} bytes/dev={bytes_acc:.3e} "
              f"coll/dev={rep.coll_bytes_per_dev:.3e} "
              f"bottleneck={rep.bottleneck}", flush=True)
        print(f"  t_comp={rep.t_compute*1e3:.2f}ms t_mem={rep.t_memory*1e3:.2f}ms "
              f"(min {rep.t_memory_min*1e3:.2f}ms) "
              f"t_coll={rep.t_collective*1e3:.2f}ms useful={rep.useful_ratio:.2f} "
              f"bott_min={rep.bottleneck_min}",
              flush=True)
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="write one JSON per cell here")
    ap.add_argument("--seq-shard-cache", action="store_true", default=None)
    ap.add_argument("--save-hlo", default=None,
                    help="write the optimized HLO text of each cell here")
    ap.add_argument("--flash", type=int, default=0,
                    help="flash-attention KV block size (0 = eager baseline)")
    ap.add_argument("--explicit-ep", action="store_true",
                    help="shard_map expert parallelism for MoE archs")
    ap.add_argument("--tag", default=None,
                    help="suffix for --out/--save-hlo filenames")
    ap.add_argument("--microbatch", type=int, default=0,
                    help="override gradient-accumulation microbatch size")
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8 error-feedback gradient compression")
    args = ap.parse_args(argv)

    strat = ShardingStrategy(seq_shard_cache=args.seq_shard_cache)
    todo = (
        cells(include_inapplicable=True) if args.all
        else [(args.arch, args.shape)]
    )
    failures = 0
    for arch, shape in todo:
        try:
            hlo_out = None
            pod = "2pod" if args.multi_pod else "1pod"
            if args.tag:
                pod = f"{pod}__{args.tag}"
            if args.save_hlo:
                os.makedirs(args.save_hlo, exist_ok=True)
                hlo_out = os.path.join(args.save_hlo,
                                       f"{arch}__{shape}__{pod}.hlo")
            tcfg = None
            if args.microbatch or args.compress_grads:
                from repro.train.trainer import TrainConfig
                tcfg = TrainConfig(microbatch=args.microbatch,
                                   compress_grads=args.compress_grads)
            row = lower_cell(arch, shape, multi_pod=args.multi_pod,
                             strat=strat, hlo_out=hlo_out, tcfg=tcfg,
                             flash_block=args.flash,
                             explicit_ep=args.explicit_ep)
        except Exception as e:  # a failure here is a bug in our sharding
            traceback.print_exc()
            row = {"arch": arch, "shape": shape, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "mesh": "2x16x16" if args.multi_pod else "16x16"}
            failures += 1
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            fn = os.path.join(args.out, f"{arch}__{shape}__{pod}.json")
            with open(fn, "w") as f:
                json.dump(row, f, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
