# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

from typing import Optional

__all__ = ["resolve_interpret"]


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Shared off-TPU interpret policy for every Pallas kernel in the repo.

    ``None`` (the default on all kernel entry points) resolves to "interpret
    everywhere except a real TPU backend": on TPU the kernels lower natively,
    anywhere else (this CPU container, GPU hosts) they run under the Pallas
    interpreter for correctness.  An explicit bool always wins — tests use it
    to force interpret-mode on any backend.

    Keep every kernel default routed through here (classify, dispatch_rank,
    bitonic, merge_path, the partition engines) so the policy changes in one
    place, not per kernel.
    """
    if interpret is not None:
        return interpret
    import jax

    return jax.default_backend() != "tpu"
