"""Pallas TPU kernel: segmented in-VMEM bitonic sort (the base case).

The paper's base case is insertion sort run while the bucket is
cache-resident (§4.7: "on the last level, we perform the base case sorting
immediately after the bucket has been completely filled ... more
cache-friendly").  The TPU analogue of "cache-resident small sort" is a
branch-free **bitonic sorting network** executed entirely inside VMEM on one
window of W elements: O(W log^2 W) compare-exchanges, every one a dense
(rows, lanes) VPU select with zero data-dependent control flow — insertion
sort's data-dependent inner loop would be poison on a vector unit.

The sort key is the lexicographic pair (bucket_id, key): this makes the
window sort *segmented* — bucket boundaries inside the window are respected
automatically — which is what lets IPS4o's overlapped-window base case fix
bucket-straddling tiles (DESIGN.md §4.3).  A payload index rides along so
the wrapper can permute arbitrary payload pytrees.

Each compare-exchange round at distance d is expressed as a static reshape
(W,) -> (W/2d, 2, d) so partners (idx XOR d) sit in adjacent sub-rows; the
direction bit (idx AND 2*size) is constant per sub-row.  All shapes static.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret

__all__ = ["bitonic_sort_windows"]


def _cmp_exchange(b, k, v, size: int, d: int, W: int):
    """One bitonic round: partner = idx ^ d, ascending iff (idx & 2*size)==0."""
    shape = (W // (2 * d), 2, d)
    b3, k3, v3 = (x.reshape(shape) for x in (b, k, v))
    lo = (b3[:, 0], k3[:, 0], v3[:, 0])
    hi = (b3[:, 1], k3[:, 1], v3[:, 1])
    # ascending iff (base_idx & (2*size)) == 0; base_idx = row * 2d.
    row = jax.lax.broadcasted_iota(jnp.int32, (W // (2 * d), 1), 0)
    asc = ((row * (2 * d)) & (2 * size)) == 0
    # lexicographic (bucket, key) greater-than
    gt = (lo[0] > hi[0]) | ((lo[0] == hi[0]) & (lo[1] > hi[1]))
    swap = jnp.where(asc, gt, ~gt)
    out = []
    for a, c in zip(lo, hi):
        na = jnp.where(swap, c, a)
        nc = jnp.where(swap, a, c)
        out.append(jnp.stack([na, nc], axis=1).reshape(W))
    (b, k, v) = out
    return b, k, v


def _kernel(b_ref, k_ref, v_ref, bo_ref, ko_ref, vo_ref, *, W: int):
    b = b_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    for s in range(int(math.log2(W))):
        size = 1 << s  # ascending runs of length 2*size after this stage
        for dp in range(s, -1, -1):
            b, k, v = _cmp_exchange(b, k, v, size, 1 << dp, W)
    bo_ref[0] = b
    ko_ref[0] = k
    vo_ref[0] = v


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitonic_sort_windows(
    bucket: jax.Array,
    keys: jax.Array,
    idx: jax.Array,
    *,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Sort each window (row) of (num_w, W) arrays by (bucket, key).

    W must be a power of two.  Returns permuted (bucket, keys, idx).
    VMEM per grid step: 3 arrays * W * 4 B (W=8192 -> 96 KiB).
    ``interpret=None`` resolves through the shared off-TPU policy
    (``kernels.resolve_interpret``).
    """
    interpret = resolve_interpret(interpret)
    num_w, W = keys.shape
    if W & (W - 1):
        raise ValueError(f"W={W} must be a power of two")
    spec = lambda: pl.BlockSpec((1, W), lambda i: (i, 0))
    shapes = [
        jax.ShapeDtypeStruct((num_w, W), bucket.dtype),
        jax.ShapeDtypeStruct((num_w, W), keys.dtype),
        jax.ShapeDtypeStruct((num_w, W), idx.dtype),
    ]
    return pl.pallas_call(
        functools.partial(_kernel, W=W),
        grid=(num_w,),
        in_specs=[spec(), spec(), spec()],
        out_specs=[spec(), spec(), spec()],
        out_shape=shapes,
        interpret=interpret,
    )(bucket, keys, idx)
