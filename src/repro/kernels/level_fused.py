"""Pallas TPU kernel: the fused single-pass level kernel (DESIGN.md §10).

One grid pass over the keys replaces the level pass's former three HBM
round-trips (classify kernel -> XLA histogram glue -> counting-rank
kernel).  Each grid step, on one VMEM-resident tile:

  1. **classifies** the tile — the dense lane-parallel compare against the
     splitters+sentinel block (tree), or the shift+mask extractor (radix),
     with pad positions (>= ``n_real``) routed to the dedicated pad bucket
     *in-kernel* (the host-side positional reroute disappears);
  2. **accumulates the per-tile bucket histogram** via the one-hot
     reduction (the paper's "count per bucket as a side effect");
  3. **ranks every element within its tile-local bucket run** — the
     exclusive one-hot prefix along the tile, i.e. the paper's
     block-local bucket runs expressed as (bucket, rank-in-run) pairs.

The per-tile outputs are all O(tile): bucket ids, in-run ranks, and the
(num_tiles, nb) histogram.  The *global* placement then closes in a tiny
XLA epilogue with no second pass over the data:

    dest[i] = offsets[b_i] + tile_off[t_i, b_i] + rank[i]

where ``offsets``/``tile_off`` are prefix sums of the histogram (O(T*nb)
work, not O(n)).  The composition is bit-identical to the XLA oracle's
stable partition permutation (``core.partition.partition_permutation``):
tiles in order, stable grouping within a tile — tiling-independent.

Unlike the counting-rank kernel (``dispatch_rank``), nothing here carries
running counters across the sequential grid: every grid step is
independent, so the same body serves the batched form (grid (B, tiles))
with zero reset logic, and a future multi-core stripe split needs no
cross-step state at all.

``rank_hist`` is the classify-free mode for callers that already hold
bucket ids (the segmented/composite level pass, ``stable_partition``'s
pallas engine): same fused rank+histogram pass, same epilogue, self-
padding with the out-of-range trash id like ``partition_ranks``.

Tile shapes come from the unified ``launch.roofline.KernelLaunchSpec``
(kind ``"level_fused"``); the plan cache sweeps the candidate rows.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.classify.radix import radix_bucket_ids
from repro.core.sampling import sentinel_for
from repro.kernels import resolve_interpret

__all__ = [
    "level_fused",
    "level_fused_batched",
    "rank_hist",
    "rank_hist_batched",
    "fused_rows",
]

LANES = 128


def fused_rows(n: int, key_bytes: int, k: int) -> int:
    """Largest spec row candidate whose tile divides ``n`` (0 if none —
    callers then stay on the XLA classifier, exactly like
    ``kernels.classify.default_rows``)."""
    from repro.launch.roofline import launch_spec

    return launch_spec("level_fused", key_bytes, k, n=n).rows


def _rank_and_hist(bucket, nb: int, rows: int):
    """Tile-local (rank-in-bucket-run, histogram) via one one-hot pass.

    One inclusive cumsum serves both outputs: its contraction with the
    one-hot is rank+1 (so the exclusive-prefix subtraction folds into a
    scalar -1), and its last row IS the tile histogram (no second
    reduction over the (tile, nb) sheet).  Rows whose id falls outside
    [0, nb) — the self-padding trash id — have an all-zero one-hot and
    get rank -1; their destinations are trimmed by every caller.
    """
    flat = bucket.reshape(rows * LANES, 1)
    ids = jax.lax.broadcasted_iota(jnp.int32, (1, nb), 1)
    onehot = (flat == ids).astype(jnp.int32)  # (tile, nb)
    # dtype= pins the x64-mode accumulators to the int32 output refs
    incl = jnp.cumsum(onehot, axis=0, dtype=jnp.int32)
    rank = jnp.sum(incl * onehot, axis=1, dtype=jnp.int32) - 1  # (tile,)
    hist = incl[-1, :]  # (nb,)
    return rank.reshape(rows, LANES), hist[None, :]


def _classify_tile(keys, spl, *, k: int, classifier: str, consumed: int):
    """Local bucket ids in [0, 2k) for one (rows, LANES) tile."""
    if classifier == "radix":
        return radix_bucket_ids(keys, k, consumed)
    kf = keys[:, :, None]  # (rows, 128, 1)
    sf = spl[0][None, None, :]  # (1, 1, k): k-1 splitters + sentinel upper
    j = jnp.sum((kf > sf[..., : k - 1]).astype(jnp.int32), axis=-1, dtype=jnp.int32)
    eq = jnp.any(kf == sf, axis=-1).astype(jnp.int32)
    return 2 * j + eq


def _fused_kernel(
    *refs, k: int, nb: int, rows: int, tiles_per_row: int, n_real: int,
    classifier: str, consumed: int,
):
    if classifier == "radix":
        keys_ref, bucket_ref, rank_ref, hist_ref = refs
        spl = None
    else:
        keys_ref, spl_ref, bucket_ref, rank_ref, hist_ref = refs
        spl = spl_ref[...]
    tile_id = pl.program_id(1) if tiles_per_row else pl.program_id(0)
    keys = keys_ref[...]  # (rows, 128)
    bucket = _classify_tile(keys, spl, k=k, classifier=classifier, consumed=consumed)
    # in-kernel pad routing: positions >= n_real (within the row, for the
    # batched grid) belong to the dedicated pad bucket 2k
    tile = rows * LANES
    pos = (
        tile_id * tile
        + jax.lax.broadcasted_iota(jnp.int32, (rows, LANES), 0) * LANES
        + jax.lax.broadcasted_iota(jnp.int32, (rows, LANES), 1)
    )
    bucket = jnp.where(pos >= n_real, 2 * k, bucket)
    bucket_ref[...] = bucket
    rank_ref[...], hist_ref[...] = _rank_and_hist(bucket, nb, rows)


def _ids_kernel(bid_ref, rank_ref, hist_ref, *, nb: int, rows: int):
    rank_ref[...], hist_ref[...] = _rank_and_hist(bid_ref[...], nb, rows)


def _close_placement(bucket, rank, hist, nb: int, tile: int):
    """The XLA epilogue: prefix-sum the histogram and place every element.

    O(num_tiles * nb) prefix work plus one fused elementwise gather —
    never a second pass of classify/one-hot over the data.  1-D form;
    callers vmap it for the batched grid (everything batches natively).
    """
    n = bucket.shape[0]
    num_tiles = hist.shape[0]
    totals = hist.sum(axis=0, dtype=jnp.int32)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(totals, dtype=jnp.int32)]
    )
    tile_off = (jnp.cumsum(hist, axis=0, dtype=jnp.int32) - hist)  # (T, nb)
    base = (offsets[:-1][None, :] + tile_off).reshape(num_tiles * nb)
    t_idx = jnp.arange(n, dtype=jnp.int32) // tile
    dest = jnp.take(base, t_idx * nb + bucket, mode="clip") + rank
    return dest, offsets


@functools.partial(
    jax.jit,
    static_argnames=("k", "n_real", "classifier", "consumed_bits", "rows", "interpret"),
)
def level_fused(
    keys: jax.Array,
    splitters: Optional[jax.Array] = None,
    *,
    k: int,
    n_real: Optional[int] = None,
    classifier: str = "tree",
    consumed_bits: int = 0,
    rows: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """One fused level pass over ``keys`` (n,): classify + histogram + rank
    in a single kernel launch, placement closed by the prefix epilogue.

    Args:
      keys: (n,) totally ordered under ``>``/``==``; n a multiple of the
        rows*128 tile.
      splitters: (k-1,) sorted splitters (tree mode); None for radix.
      k: buckets per level; nb = 2k+1 with bucket 2k dedicated to pads.
      n_real: positions >= n_real are pads and route to bucket 2k
        in-kernel (default n: no pads).
      classifier: "tree" (dense compare) or "radix" (shift+mask, with
        ``consumed_bits`` already fixed by earlier levels).
      rows: tile rows; None derives the largest ``KernelLaunchSpec``
        candidate dividing n.

    Returns (dest (n,) int32, offsets (nb+1,) int32): scattering
    ``a[i] -> dest[i]`` reproduces the stable partition, bit-identical to
    the XLA oracle; ``offsets`` are the bucket boundaries (last bucket =
    the pads).
    """
    interpret = resolve_interpret(interpret)
    n = keys.shape[0]
    if n_real is None:
        n_real = n
    if rows is None:
        rows = fused_rows(n, keys.dtype.itemsize, k)
    tile = rows * LANES
    if not rows or n % tile:
        raise ValueError(f"n={n} must be a multiple of a rows*{LANES} tile")
    num_tiles = n // tile
    nb = 2 * k + 1
    keys2 = keys.reshape(num_tiles * rows, LANES)

    kern = functools.partial(
        _fused_kernel, k=k, nb=nb, rows=rows, tiles_per_row=0,
        n_real=n_real, classifier=classifier, consumed=consumed_bits,
    )
    in_specs = [pl.BlockSpec((rows, LANES), lambda i: (i, 0))]
    operands = [keys2]
    if classifier != "radix":
        upper = jnp.concatenate(
            [splitters, jnp.full((1,), sentinel_for(splitters.dtype), splitters.dtype)]
        )
        in_specs.append(pl.BlockSpec((1, k), lambda i: (0, 0)))
        operands.append(upper.reshape(1, k))

    bucket, rank, hist = pl.pallas_call(
        kern,
        grid=(num_tiles,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, nb), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_tiles * rows, LANES), jnp.int32),
            jax.ShapeDtypeStruct((num_tiles * rows, LANES), jnp.int32),
            jax.ShapeDtypeStruct((num_tiles, nb), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
    return _close_placement(bucket.reshape(n), rank.reshape(n), hist, nb, tile)


@functools.partial(
    jax.jit,
    static_argnames=("k", "n_real", "classifier", "consumed_bits", "rows", "interpret"),
)
def level_fused_batched(
    keys: jax.Array,
    splitters: Optional[jax.Array] = None,
    *,
    k: int,
    n_real: Optional[int] = None,
    classifier: str = "tree",
    consumed_bits: int = 0,
    rows: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Per-row fused level pass over ``keys`` (B, n): batch grid dimension
    (B, num_tiles), row ``b`` classifying against its own splitter block
    (tree) or the shared extractor (radix).  No cross-step state exists in
    the fused body, so rows need no counter resets at all.

    Returns (dest (B, n) int32 within each row, offsets (B, nb+1) int32).
    """
    interpret = resolve_interpret(interpret)
    B, n = keys.shape
    if n_real is None:
        n_real = n
    if rows is None:
        rows = fused_rows(n, keys.dtype.itemsize, k)
    tile = rows * LANES
    if not rows or n % tile:
        raise ValueError(f"n={n} must be a multiple of a rows*{LANES} tile")
    num_tiles = n // tile
    nb = 2 * k + 1
    keys2 = keys.reshape(B * num_tiles * rows, LANES)

    kern = functools.partial(
        _fused_kernel, k=k, nb=nb, rows=rows, tiles_per_row=num_tiles,
        n_real=n_real, classifier=classifier, consumed=consumed_bits,
    )
    in_specs = [pl.BlockSpec((rows, LANES), lambda b, i: (b * num_tiles + i, 0))]
    operands = [keys2]
    if classifier != "radix":
        upper = jnp.concatenate(
            [
                splitters,
                jnp.full((B, 1), sentinel_for(splitters.dtype), splitters.dtype),
            ],
            axis=1,
        )
        in_specs.append(pl.BlockSpec((1, k), lambda b, i: (b, 0)))
        operands.append(upper)

    bucket, rank, hist = pl.pallas_call(
        kern,
        grid=(B, num_tiles),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((rows, LANES), lambda b, i: (b * num_tiles + i, 0)),
            pl.BlockSpec((rows, LANES), lambda b, i: (b * num_tiles + i, 0)),
            pl.BlockSpec((1, nb), lambda b, i: (b * num_tiles + i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * num_tiles * rows, LANES), jnp.int32),
            jax.ShapeDtypeStruct((B * num_tiles * rows, LANES), jnp.int32),
            jax.ShapeDtypeStruct((B * num_tiles, nb), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
    close = jax.vmap(functools.partial(_close_placement, nb=nb, tile=tile))
    return close(
        bucket.reshape(B, n), rank.reshape(B, n), hist.reshape(B, num_tiles, nb)
    )


@functools.partial(jax.jit, static_argnames=("nb", "rows", "interpret"))
def rank_hist(
    bucket: jax.Array,
    *,
    nb: int,
    rows: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Fused rank + histogram over precomputed bucket ids (n,) int32.

    The classify-free mode of the fused level kernel, for callers that
    computed ids elsewhere (composite/segmented buckets, MoE expert ids,
    the learned classifier): one kernel pass yields tile ranks and the
    histogram, the prefix epilogue closes placement.  Self-pads to the
    kernel tile with the out-of-range trash id ``nb`` (all-zero one-hot:
    no histogram or counter pollution; trash dests are sliced off).

    Returns (dest (n,) int32, offsets (nb+1,) int32), the stable
    counting placement — bit-identical to ``partition_permutation``.
    """
    interpret = resolve_interpret(interpret)
    n = bucket.shape[0]
    if rows is None:
        from repro.launch.roofline import launch_spec

        rows = launch_spec("rank", 4, nb).rows or 8
    tile = rows * LANES
    n_pad = -(-n // tile) * tile
    if n_pad != n:
        bucket = jnp.concatenate([bucket, jnp.full((n_pad - n,), nb, jnp.int32)])
    num_tiles = n_pad // tile
    bid2 = bucket.reshape(num_tiles * rows, LANES)

    rank, hist = pl.pallas_call(
        functools.partial(_ids_kernel, nb=nb, rows=rows),
        grid=(num_tiles,),
        in_specs=[pl.BlockSpec((rows, LANES), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, nb), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_tiles * rows, LANES), jnp.int32),
            jax.ShapeDtypeStruct((num_tiles, nb), jnp.int32),
        ],
        interpret=interpret,
    )(bid2)
    dest, offsets = _close_placement(
        bucket.reshape(n_pad), rank.reshape(n_pad), hist, nb, tile
    )
    return dest[:n], offsets


@functools.partial(jax.jit, static_argnames=("nb", "rows", "interpret"))
def rank_hist_batched(
    bucket: jax.Array,
    *,
    nb: int,
    rows: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Per-row fused rank + histogram over bucket ids (B, n) int32.

    Rows are fully independent (no cross-step state), so the batched form
    is the unbatched kernel over the flattened rows — tiles never straddle
    rows because each row self-pads to the kernel tile first.

    Returns (dest (B, n) within each row, offsets (B, nb+1)).
    """
    interpret = resolve_interpret(interpret)
    B, n = bucket.shape
    if rows is None:
        from repro.launch.roofline import launch_spec

        rows = launch_spec("rank", 4, nb).rows or 8
    tile = rows * LANES
    n_pad = -(-n // tile) * tile
    if n_pad != n:
        bucket = jnp.concatenate(
            [bucket, jnp.full((B, n_pad - n), nb, jnp.int32)], axis=1
        )
    num_tiles = n_pad // tile
    bid2 = bucket.reshape(B * num_tiles * rows, LANES)

    rank, hist = pl.pallas_call(
        functools.partial(_ids_kernel, nb=nb, rows=rows),
        grid=(B, num_tiles),
        in_specs=[pl.BlockSpec((rows, LANES), lambda b, i: (b * num_tiles + i, 0))],
        out_specs=[
            pl.BlockSpec((rows, LANES), lambda b, i: (b * num_tiles + i, 0)),
            pl.BlockSpec((1, nb), lambda b, i: (b * num_tiles + i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * num_tiles * rows, LANES), jnp.int32),
            jax.ShapeDtypeStruct((B * num_tiles, nb), jnp.int32),
        ],
        interpret=interpret,
    )(bid2)
    close = jax.vmap(functools.partial(_close_placement, nb=nb, tile=tile))
    dest, offsets = close(
        bucket.reshape(B, n_pad), rank.reshape(B, n_pad), hist.reshape(B, num_tiles, nb)
    )
    return dest[:, :n], offsets
