"""Pallas TPU kernel: stable in-place block permutation by explicit dests.

The paper's §4.2 block permutation, upgraded from the bucket-pointer form
(``kernels.permute_inplace``) to *explicit per-block destinations*: the
caller hands every full block its final slot (``dst``, a permutation of
[0, N)), and the kernel chases the permutation cycles HBM-in-place:

  * the data array is input/output aliased (``input_output_aliases``) —
    no second n-sized buffer exists; block moves are explicit HBM<->VMEM
    DMAs through two swap buffers alternating via a parity flag (the
    paper's "two local swap buffers per thread");
  * a VMEM visited bitmap (one int32 lane per block) tracks which slots'
    original content has been consumed; the next cycle head is the first
    unvisited slot (one vectorized ``argmin`` — no sequential scan loop);
  * each grid step performs exactly one block *write* — swapping the held
    block into its destination after DMA-ing the displaced block into the
    other buffer, or dropping it into an already-consumed slot (cycle
    close) — preceded, when no block is held, by the cycle-head scan and
    read.  N writes complete the permutation; grid = N + 1.

Because ``dst`` is explicit, the placement is whatever the caller
computed — ``core.partition.partition_blocks`` passes the *stable* block
order (``argsort(block_bucket, stable=True)`` inverted), so unlike the
bucket-pointer kernel this one realizes the stable grouping, and the
kernel and fallback paths of ``partition_blocks`` now agree exactly.

Cleanup phase (paper §4.3, the overflow block): a trailing *partial*
block (n % block_elems = r > 0) cannot ride the block DMAs.  It is the
analogue of the paper's overflow block: the caller guarantees it already
sits at its final position (its bucket is >= every full block's bucket —
true by construction for the sentinel-pad tail bucket), and the cleanup
re-attaches the r tail elements outside the grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["permute_blocks_by_dest", "stable_block_dest"]

LANES = 128

# scalar state slots
S_FILLED, S_DONE, S_CUR, S_DST = range(4)


def stable_block_dest(block_bucket: jax.Array) -> jax.Array:
    """Destination slot of every block under the *stable* bucket grouping:
    dst[i] = #blocks with a smaller bucket + #earlier blocks of the same
    bucket.  The scatter form of ``argsort(block_bucket, stable=True)``."""
    nblocks = block_bucket.shape[0]
    order = jnp.argsort(block_bucket, stable=True).astype(jnp.int32)
    return (
        jnp.zeros((nblocks,), jnp.int32)
        .at[order]
        .set(jnp.arange(nblocks, dtype=jnp.int32), mode="promise_in_bounds")
    )


def _kernel(dst_ref, a_in, a_out, visited, st_ref, swap0, swap1, sem,
            *, nblocks: int, brows: int):
    pid = pl.program_id(0)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, nblocks), 1)

    @pl.when(pid == 0)
    def _init():
        visited[...] = jnp.zeros((1, nblocks), jnp.int32)
        for s in range(4):
            st_ref[s] = 0

    def copy(src, dst):
        cp = pltpu.make_async_copy(src, dst, sem)
        cp.start()
        cp.wait()

    def block(ref, idx):
        return ref.at[pl.dslice(idx * brows, brows), :]

    def swap_ref(sel):
        return swap0 if sel == 0 else swap1

    @pl.when(st_ref[S_DONE] == 0)
    def _step():
        # ---- cycle-head scan + read when no block is held ----------------
        @pl.when(st_ref[S_FILLED] == 0)
        def _scan():
            vi = visited[...]  # (1, nblocks)
            # first unvisited slot, vectorized (0 < 1 so argmin = first 0)
            head = jnp.argmin(vi, axis=1)[0].astype(jnp.int32)
            found = jnp.min(vi) == 0

            @pl.when(found)
            def _read():
                for sel in (0, 1):
                    @pl.when(st_ref[S_CUR] == sel)
                    def _(sel=sel):
                        copy(block(a_in, head), swap_ref(sel))
                visited[...] = jnp.maximum(vi, (lane == head).astype(jnp.int32))
                st_ref[S_DST] = dst_ref[head]
                st_ref[S_FILLED] = 1

            @pl.when(jnp.logical_not(found))
            def _done():
                st_ref[S_DONE] = 1

        # ---- one block write --------------------------------------------
        @pl.when(st_ref[S_FILLED] == 1)
        def _write():
            d = st_ref[S_DST]
            vi = visited[...]
            # slot d still holds unconsumed content iff its visited lane is 0
            occupied = jnp.sum(jnp.where(lane == d, vi, 0)) == 0

            @pl.when(occupied)
            def _displace():
                for sel in (0, 1):
                    @pl.when(st_ref[S_CUR] == sel)
                    def _(sel=sel):
                        copy(block(a_in, d), swap_ref(1 - sel))

            next_dst = dst_ref[d]

            for sel in (0, 1):
                @pl.when(st_ref[S_CUR] == sel)
                def _(sel=sel):
                    copy(swap_ref(sel), block(a_out, d))

            visited[...] = jnp.maximum(vi, (lane == d).astype(jnp.int32))

            @pl.when(occupied)
            def _rotate():
                st_ref[S_CUR] = 1 - st_ref[S_CUR]
                st_ref[S_DST] = next_dst

            @pl.when(jnp.logical_not(occupied))
            def _emptied():
                st_ref[S_FILLED] = 0


@functools.partial(jax.jit, static_argnames=("block_elems", "interpret"))
def permute_blocks_by_dest(
    a: jax.Array,
    dst: jax.Array,
    *,
    block_elems: int = 1024,
    interpret: bool = True,
) -> jax.Array:
    """Move block i of ``a`` to slot dst[i], HBM-in-place.

    Args:
      a: (n,) data, n >= N * block_elems with N = n // block_elems full
         blocks; a trailing partial block of r = n % block_elems elements
         is the *overflow block* — the caller guarantees it already sits
         at its final (tail) position and the cleanup phase re-attaches it
         untouched.
      dst: (N,) int32, a permutation of [0, N): block i's destination
         slot.  For stable bucket grouping use :func:`stable_block_dest`.
      block_elems: elements per block; must be a multiple of 128.

    Returns the permuted array (same HBM buffer for the aligned prefix:
    input is aliased/donated).
    """
    if block_elems % LANES:
        raise ValueError("block_elems must be a multiple of 128")
    brows = block_elems // LANES
    n = a.shape[0]
    nblocks = n // block_elems
    r = n - nblocks * block_elems
    if nblocks <= 1:
        return a
    body, tail = (a[: n - r], a[n - r :]) if r else (a, None)
    a2 = body.reshape(nblocks * brows, LANES)

    out = pl.pallas_call(
        functools.partial(_kernel, nblocks=nblocks, brows=brows),
        grid=(nblocks + 1,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # dst
            pl.BlockSpec(memory_space=pl.ANY),  # a (HBM)
        ],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        out_shape=jax.ShapeDtypeStruct(a2.shape, a2.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, nblocks), jnp.int32),  # visited bitmap
            pltpu.SMEM((4,), jnp.int32),  # scalar state
            pltpu.VMEM((brows, LANES), a2.dtype),  # swap buffer 0
            pltpu.VMEM((brows, LANES), a2.dtype),  # swap buffer 1
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={1: 0},
        interpret=interpret,
    )(dst.astype(jnp.int32), a2)
    flat = out.reshape(n - r)
    # cleanup phase: re-attach the overflow (partial boundary) block
    return jnp.concatenate([flat, tail]) if r else flat
