"""Pallas TPU kernel: fused flash attention (causal / windowed, GQA).

§Perf motivation (EXPERIMENTS.md): after the JAX-level KV-chunked attention
removed the head-contraction all-reduce, the dominant roofline term of the
32k prefill cells became HBM traffic of the *chunk score matrices* — XLA
materializes the (B, H, S, block) logits between the two chunk einsums, so
every layer still moves ~88 GB/device through HBM.  The fix is the classic
fused kernel: scores live and die in VMEM.

Grid: (B*H, S/bq) — one grid step owns a (bq, hd) query block and loops the
KV blocks with ``jax.lax.fori_loop``, carrying the online-softmax state
(m, l, acc) in VMEM.  Per-step VMEM: q (bq x hd) + k,v (bk x hd each) +
scores (bq x bk) f32 + acc (bq x hd) f32 — for bq = bk = 512, hd = 128:
~2.8 MiB, comfortably inside ~16 MiB VMEM.  MXU alignment: bq, bk, hd all
multiples of 128 (hd 64 also allowed — (8,128) tiling pads).

Causality is exploited at BLOCK granularity: KV blocks strictly above the
diagonal are skipped by clamping the fori_loop bound — this is what the
pure-JAX scan path cannot express with one unchunked q, and it halves the
attention FLOPs of a causal prefill.

HBM traffic per (layer, device): q + k + v + out  (+ nothing else) —
the 16x reduction claimed in §Perf iteration 3.

``ref.py`` holds the jnp oracle; tests sweep shapes/dtypes/windows in
``interpret=True`` (this container is CPU-only; on TPU the same call lowers
to Mosaic natively).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int, seq: int,
            window: int, causal: bool):
    qi = pl.program_id(1)  # query-block index
    q = q_ref[0].astype(jnp.float32)          # (bq, hd)
    hd = q.shape[-1]
    scale = 1.0 / math.sqrt(hd)
    q = q * scale

    q_lo = qi * bq                             # first absolute query row
    nkv = seq // bk
    if causal:
        # skip KV blocks strictly above the diagonal
        hi = jax.lax.div(q_lo + bq - 1, bk) + 1
        hi = jnp.minimum(hi, nkv)
    else:
        hi = nkv
    if causal and window:
        lo = jnp.maximum(jax.lax.div(q_lo - window + 1, bk), 0)
    else:
        lo = 0

    def body(j, carry):
        m, l, acc = carry
        # leading axis via a 1-sized dslice: a bare int index has no
        # interpret-mode load-discharge rule in this jax version
        kb = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(j * bk, bk), slice(None))
                     )[0].astype(jnp.float32)  # (bk, hd)
        vb = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(j * bk, bk), slice(None))
                     )[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())))  # (bq, bk)
        rows = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = jnp.full((bq, bk), True)
        if causal:
            valid = cols <= rows
        if window:
            valid = valid & (cols > rows - window)
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, vb, (((1,), (0,)), ((), ())))
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "bq", "bk", "interpret"),
)
def flash_attention(
    q: jax.Array,          # (B, H, S, hd)
    k: jax.Array,          # (B, H, S, hd)  (GQA pre-expanded: H == q heads)
    v: jax.Array,          # (B, H, S, hd)
    *,
    causal: bool = True,
    window: int = 0,
    bq: int = 512,
    bk: int = 512,
    interpret: bool = True,
) -> jax.Array:
    b, h, s, hd = q.shape
    bq = min(bq, s)
    bk = min(bk, s)
    if s % bq or s % bk:
        raise ValueError(f"seq {s} must be a multiple of bq={bq}, bk={bk}")

    kern = functools.partial(
        _kernel, bq=bq, bk=bk, seq=s, window=window, causal=causal,
    )
    bh = b * h
    qf = q.reshape(bh, s, hd)
    kf = k.reshape(bh, s, hd)
    vf = v.reshape(bh, s, hd)
    out = pl.pallas_call(
        kern,
        grid=(bh, s // bq),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda i, j: (i, j, 0)),   # q block
            pl.BlockSpec((1, s, hd), lambda i, j: (i, 0, 0)),    # full K row
            pl.BlockSpec((1, s, hd), lambda i, j: (i, 0, 0)),    # full V row
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, hd)
