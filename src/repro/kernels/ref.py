"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.classify import classify as _tree_classify

__all__ = [
    "classify_histogram_ref",
    "bitonic_sort_windows_ref",
    "permute_blocks_ref",
    "dispatch_ranks_ref",
    "partition_ranks_ref",
    "merge_path_perm_ref",
]


def classify_histogram_ref(
    keys: jax.Array, splitters: jax.Array, *, k: int, rows: int = 32
) -> Tuple[jax.Array, jax.Array]:
    """Oracle: tree-descent classifier + per-tile bincount."""
    bucket = _tree_classify(keys, splitters, k)
    tile = rows * 128
    bt = bucket.reshape(-1, tile)
    hist = jax.vmap(lambda r: jnp.bincount(r, length=2 * k))(bt)
    return bucket, hist.astype(jnp.int32)


def bitonic_sort_windows_ref(
    bucket: jax.Array, keys: jax.Array, idx: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Oracle: per-window stable lexicographic (bucket, key) sort."""

    def one(b, k, v):
        o1 = jnp.argsort(k, stable=True)
        o2 = jnp.argsort(b[o1], stable=True)
        o = o1[o2]
        return b[o], k[o], v[o]

    return jax.vmap(one)(bucket, keys, idx)


def permute_blocks_ref(
    a: jax.Array, block_bucket: jax.Array, *, k: int, block_elems: int
) -> jax.Array:
    """Oracle: stable block grouping by bucket (canonical representative of
    the permutation's equivalence class; tests compare per-bucket block
    multisets, not exact order)."""
    nblocks = block_bucket.shape[0]
    order = jnp.argsort(block_bucket, stable=True)
    blocks = a.reshape(nblocks, block_elems)
    return jnp.take(blocks, order, axis=0).reshape(-1)


def dispatch_ranks_ref(expert_id: jax.Array, expert_start: jax.Array) -> jax.Array:
    """Oracle: dest = start[e] + stable rank of token within its expert."""
    n = expert_id.shape[0]
    order = jnp.argsort(expert_id, stable=True)  # tokens grouped by expert
    dest = jnp.zeros((n,), jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))
    # `dest` computed this way already equals start[e] + rank when starts are
    # the exclusive histogram prefix (grouped positions are exactly that).
    return dest


def partition_ranks_ref(bucket: jax.Array, start: jax.Array, nb: int) -> jax.Array:
    """Oracle: dest = start[b] + stable rank of the element within its bucket
    (the stable counting placement — same contract as dispatch_ranks_ref but
    with explicit, possibly non-prefix, starts)."""
    onehot = (bucket[:, None] == jnp.arange(nb, dtype=jnp.int32)[None, :]).astype(
        jnp.int32
    )
    rank = jnp.sum((jnp.cumsum(onehot, axis=0) - onehot) * onehot, axis=1)
    base = jnp.sum(onehot * start[None, :], axis=1)
    return (base + rank).astype(jnp.int32)


def merge_path_perm_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """jnp oracle for kernels/merge_path.py — and the "xla" merge engine.

    The stable-merge permutation by rank arithmetic: element a[i] lands at
    i + |{b < a[i]}| (strict: ties keep A first), b[j] at j + |{a <= b[j]}|.
    Those destinations are disjoint and cover [0, nA+nB), so one scatter
    yields the permutation — branchless under XLA (two searchsorteds), no
    comparison sort.
    """
    nA, nB = a.shape[0], b.shape[0]
    n = nA + nB
    if nA == 0 or nB == 0:
        return jnp.arange(n, dtype=jnp.int32)
    ai = jnp.arange(nA, dtype=jnp.int32)
    bi = jnp.arange(nB, dtype=jnp.int32)
    pos_a = ai + jnp.searchsorted(b, a, side="left").astype(jnp.int32)
    pos_b = bi + jnp.searchsorted(a, b, side="right").astype(jnp.int32)
    return (
        jnp.zeros((n,), jnp.int32)
        .at[pos_a]
        .set(ai, mode="promise_in_bounds")
        .at[pos_b]
        .set(nA + bi, mode="promise_in_bounds")
    )


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """jnp oracle for kernels/flash_attention.py: q,k,v (B,H,S,hd)."""
    import math as _math

    b, h, s, hd = q.shape
    sc = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                    k.astype(jnp.float32)) / _math.sqrt(hd)
    qi = jnp.arange(s)[:, None]
    kj = jnp.arange(s)[None, :]
    valid = jnp.full((s, s), True)
    if causal:
        valid = kj <= qi
    if window:
        valid = valid & (kj > qi - window)
    sc = jnp.where(valid[None, None], sc, -jnp.inf)
    w = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", w, v.astype(jnp.float32)).astype(q.dtype)


def flash_decode_ref(q, k, v, length):
    """jnp oracle for kernels/flash_decode.py: q (B,H,1,hd), cache (B,H,T,hd)."""
    import math as _math

    b, h, _, hd = q.shape
    t = k.shape[2]
    sc = jnp.einsum("bhqd,bhtd->bhqt", q.astype(jnp.float32),
                    k.astype(jnp.float32)) / _math.sqrt(hd)
    mask = (jnp.arange(t)[None, :] < length[:, None])[:, None, None, :]
    sc = jnp.where(mask, sc, -jnp.inf)
    w = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bhqt,bhtd->bhqd", w, v.astype(jnp.float32)).astype(q.dtype)
