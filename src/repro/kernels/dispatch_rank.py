"""Pallas TPU kernels: fused counting-rank placement (IPS4o distribution).

Token->expert dispatch is the paper's distribution problem with the router
as classifier (DESIGN.md §3).  These kernels fuse, in ONE pass over the
element stream, what XLA would otherwise do with sort+cumsum+scatter chains:

  dest[i] = start[b_i] + (#elements with bucket b_i before i)

i.e. the *stable* counting placement — rank = prefix count of equal-bucket
lanes, branchless, no comparison sort anywhere in the distribution pass.
The cross-tile running counters persist across the sequential TPU grid —
the same "running bucket pointers on one core" idea as the block
permutation kernel (§4.2), at element granularity.

Two variants:

  * ``dispatch_ranks``: E small (MoE experts) — counters are SMEM scalars,
    the per-bucket base lookup is an unrolled scalar loop.
  * ``partition_ranks``: nb up to hundreds of buckets (the sort hot path's
    2k+1) — counters are a VMEM (1, nb) vector and the base lookup is a
    one-hot contraction, so nothing unrolls over nb.  Formerly the
    "pallas" partition engine of ``core.partition.stable_partition``; the
    fused level kernel (``kernels.level_fused``, DESIGN.md §10) demoted it
    to the MoE dispatch engine and a sequential-counter oracle — its
    running counters serialize the grid, where the fused kernel's
    tile-local ranks + prefix epilogue do not.

``partition_ranks_batched`` (DESIGN.md §6) lifts the second variant over a
leading batch dimension with a *batch grid dimension*: grid =
(B, num_tiles).  The TPU grid iterates sequentially, minor dimension last,
so the running counters simply reset at tile 0 of every row (instead of
only at program 0) and each row's placement stays independent — B stable
per-row partitions in one kernel launch.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import resolve_interpret

__all__ = ["dispatch_ranks", "partition_ranks", "partition_ranks_batched"]

LANES = 128


def _default_rank_rows(nb: int) -> int:
    """Tile rows from the unified launch spec (kind ``"rank"``; the spec's
    ``k`` is nb here), floored at the legacy 8 for degenerate budgets."""
    from repro.launch.roofline import launch_spec

    return launch_spec("rank", 4, nb).rows or 8


def _kernel(start_ref, eid_ref, dest_ref, run_ref, *, num_experts: int, rows: int):
    pid = pl.program_id(0)

    @pl.when(pid == 0)
    def _init():
        for e in range(num_experts):
            run_ref[e] = 0

    eid = eid_ref[...]  # (rows, 128)
    flat = eid.reshape(rows * LANES, 1)
    ids = jax.lax.broadcasted_iota(jnp.int32, (1, num_experts), 1)
    onehot = (flat == ids).astype(jnp.int32)  # (tile, E)
    excl = jnp.cumsum(onehot, axis=0) - onehot  # rank within tile
    rank_in_tile = jnp.sum(excl * onehot, axis=1)  # (tile,)
    tile_hist = jnp.sum(onehot, axis=0)  # (E,)

    base = jnp.zeros((rows * LANES,), jnp.int32)
    for e in range(num_experts):  # SMEM scalar reads, unrolled (E is small)
        sel = flat[:, 0] == e
        base = jnp.where(sel, start_ref[e] + run_ref[e], base)
    dest_ref[...] = (base + rank_in_tile).reshape(rows, LANES)

    for e in range(num_experts):
        run_ref[e] = run_ref[e] + tile_hist[e]


@functools.partial(jax.jit, static_argnames=("num_experts", "rows", "interpret"))
def dispatch_ranks(
    expert_id: jax.Array,
    expert_start: jax.Array,
    *,
    num_experts: int,
    rows: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Destination slot per token for expert-major grouping.

    Args:
      expert_id: (n,) int32 in [0, num_experts); n multiple of rows*128.
      expert_start: (num_experts,) int32 exclusive prefix of expert counts.
      rows: tile rows; None takes the largest unified-launch-spec
        candidate whose tile divides n (legacy 8 when none does).

    Returns (n,) int32 destinations (a permutation when starts come from the
    true histogram).
    """
    interpret = resolve_interpret(interpret)
    n = expert_id.shape[0]
    if rows is None:
        from repro.launch.roofline import launch_spec

        rows = launch_spec("rank", 4, num_experts, n=n).rows or 8
    tile = rows * LANES
    if n % tile:
        raise ValueError(f"n={n} not a multiple of tile={tile}")
    num_tiles = n // tile
    eid2 = expert_id.reshape(num_tiles * rows, LANES)

    dest = pl.pallas_call(
        functools.partial(_kernel, num_experts=num_experts, rows=rows),
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # expert_start
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(eid2.shape, jnp.int32),
        scratch_shapes=[pltpu.SMEM((num_experts,), jnp.int32)],
        interpret=interpret,
    )(expert_start, eid2)
    return dest.reshape(n)


def _rank_kernel(start_ref, bid_ref, dest_ref, run_ref, *, nb: int, rows: int):
    pid = pl.program_id(0)

    @pl.when(pid == 0)
    def _init():
        run_ref[...] = jnp.zeros((1, nb), jnp.int32)

    bid = bid_ref[...]  # (rows, 128)
    flat = bid.reshape(rows * LANES, 1)
    ids = jax.lax.broadcasted_iota(jnp.int32, (1, nb), 1)
    onehot = (flat == ids).astype(jnp.int32)  # (tile, nb)
    # dtype= pins the accumulators: with x64 enabled (u64 keys) the
    # reductions would widen int32 to int64 and mismatch the int32 refs
    excl = jnp.cumsum(onehot, axis=0, dtype=jnp.int32) - onehot
    rank_in_tile = jnp.sum(excl * onehot, axis=1, dtype=jnp.int32)  # (tile,)
    base = jnp.sum(onehot * (start_ref[...] + run_ref[...]), axis=1, dtype=jnp.int32)
    dest_ref[...] = (base + rank_in_tile).reshape(rows, LANES)
    run_ref[...] = run_ref[...] + jnp.sum(onehot, axis=0, dtype=jnp.int32)[None, :]


@functools.partial(jax.jit, static_argnames=("nb", "rows", "interpret"))
def partition_ranks(
    bucket: jax.Array,
    start: jax.Array,
    *,
    nb: int,
    rows: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Stable counting destination per element, vectorized over buckets.

    Args:
      bucket: (n,) int32 bucket ids; ids outside [0, nb) are ignored (their
        dest is unspecified and they never touch the running counters — the
        wrapper layers use id ``nb`` as alignment padding).
      start: (nb,) int32 exclusive prefix of bucket counts.
      nb: number of buckets (static).
      rows: tile rows; None derives the unified launch spec's candidate
        (the kernel self-pads, so any tile fits any n).

    Returns (n,) int32 destinations: ``start[b_i]`` + the number of earlier
    elements with the same bucket — the stable partition permutation's
    scatter index (identical to the XLA per-tile-argsort placement).
    """
    interpret = resolve_interpret(interpret)
    n = bucket.shape[0]
    if rows is None:
        rows = _default_rank_rows(nb)
    tile = rows * LANES
    n_pad = -(-n // tile) * tile
    if n_pad != n:  # align to the kernel tile; pads use the out-of-range id
        bucket = jnp.concatenate(
            [bucket, jnp.full((n_pad - n,), nb, jnp.int32)]
        )
    bid2 = bucket.reshape(n_pad // LANES, LANES)
    num_tiles = n_pad // tile

    dest = pl.pallas_call(
        functools.partial(_rank_kernel, nb=nb, rows=rows),
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((1, nb), lambda i: (0, 0)),  # start
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(bid2.shape, jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, nb), jnp.int32)],  # running counters
        interpret=interpret,
    )(start.reshape(1, nb), bid2)
    return dest.reshape(n_pad)[:n]


def _rank_kernel_batched(start_ref, bid_ref, dest_ref, run_ref, *, nb: int, rows: int):
    tile_id = pl.program_id(1)  # minor grid dim: tiles within the row

    @pl.when(tile_id == 0)
    def _init():  # new row: counters restart (rows are independent)
        run_ref[...] = jnp.zeros((1, nb), jnp.int32)

    bid = bid_ref[...]  # (rows, 128)
    flat = bid.reshape(rows * LANES, 1)
    ids = jax.lax.broadcasted_iota(jnp.int32, (1, nb), 1)
    onehot = (flat == ids).astype(jnp.int32)  # (tile, nb)
    excl = jnp.cumsum(onehot, axis=0) - onehot
    rank_in_tile = jnp.sum(excl * onehot, axis=1)
    base = jnp.sum(onehot * (start_ref[...] + run_ref[...]), axis=1)
    dest_ref[...] = (base + rank_in_tile).reshape(rows, LANES)
    run_ref[...] = run_ref[...] + jnp.sum(onehot, axis=0)[None, :]


@functools.partial(jax.jit, static_argnames=("nb", "rows", "interpret"))
def partition_ranks_batched(
    bucket: jax.Array,
    start: jax.Array,
    *,
    nb: int,
    rows: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Per-row stable counting destinations, batch grid dimension (B, tiles).

    Args:
      bucket: (B, n) int32 bucket ids per row; ids outside [0, nb) are
        ignored (their dest is unspecified; used for alignment padding).
      start: (B, nb) int32 per-row exclusive prefix of bucket counts.
      nb: number of buckets per row (static).

    Returns (B, n) int32 destinations *within each row*: row b's element i
    goes to ``start[b, bucket[b, i]]`` + the number of earlier row-b
    elements in the same bucket — B independent stable partitions computed
    by one kernel, counters resetting at each row's first tile.
    """
    interpret = resolve_interpret(interpret)
    B, n = bucket.shape
    if rows is None:
        rows = _default_rank_rows(nb)
    tile = rows * LANES
    n_pad = -(-n // tile) * tile
    if n_pad != n:  # align rows to the kernel tile; pads use the trash id
        bucket = jnp.concatenate(
            [bucket, jnp.full((B, n_pad - n), nb, jnp.int32)], axis=1
        )
    bid2 = bucket.reshape(B * n_pad // LANES, LANES)
    num_tiles = n_pad // tile

    dest = pl.pallas_call(
        functools.partial(_rank_kernel_batched, nb=nb, rows=rows),
        grid=(B, num_tiles),
        in_specs=[
            pl.BlockSpec((1, nb), lambda b, i: (b, 0)),  # per-row starts
            pl.BlockSpec((rows, LANES), lambda b, i: (b * num_tiles + i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, LANES), lambda b, i: (b * num_tiles + i, 0)),
        out_shape=jax.ShapeDtypeStruct(bid2.shape, jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, nb), jnp.int32)],  # running counters
        interpret=interpret,
    )(start.reshape(B, nb), bid2)
    return dest.reshape(B, n_pad)[:, :n]
