"""Pallas TPU kernel: fused MoE dispatch ranking (IPS4o distribution as EP).

Token->expert dispatch is the paper's distribution problem with the router
as classifier (DESIGN.md §3).  This kernel fuses, in ONE pass over the
token stream, what XLA would otherwise do with sort+cumsum+scatter chains:

  dest[i] = expert_start[e_i] + (#tokens with expert e_i before i)

The cross-tile running counters live in SMEM scratch and persist across the
sequential TPU grid — the same "running bucket pointers on one core" idea as
the block permutation kernel (§4.2), at token granularity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["dispatch_ranks"]

LANES = 128


def _kernel(start_ref, eid_ref, dest_ref, run_ref, *, num_experts: int, rows: int):
    pid = pl.program_id(0)

    @pl.when(pid == 0)
    def _init():
        for e in range(num_experts):
            run_ref[e] = 0

    eid = eid_ref[...]  # (rows, 128)
    flat = eid.reshape(rows * LANES, 1)
    ids = jax.lax.broadcasted_iota(jnp.int32, (1, num_experts), 1)
    onehot = (flat == ids).astype(jnp.int32)  # (tile, E)
    excl = jnp.cumsum(onehot, axis=0) - onehot  # rank within tile
    rank_in_tile = jnp.sum(excl * onehot, axis=1)  # (tile,)
    tile_hist = jnp.sum(onehot, axis=0)  # (E,)

    base = jnp.zeros((rows * LANES,), jnp.int32)
    for e in range(num_experts):  # SMEM scalar reads, unrolled (E is small)
        sel = flat[:, 0] == e
        base = jnp.where(sel, start_ref[e] + run_ref[e], base)
    dest_ref[...] = (base + rank_in_tile).reshape(rows, LANES)

    for e in range(num_experts):
        run_ref[e] = run_ref[e] + tile_hist[e]


@functools.partial(jax.jit, static_argnames=("num_experts", "rows", "interpret"))
def dispatch_ranks(
    expert_id: jax.Array,
    expert_start: jax.Array,
    *,
    num_experts: int,
    rows: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """Destination slot per token for expert-major grouping.

    Args:
      expert_id: (n,) int32 in [0, num_experts); n multiple of rows*128.
      expert_start: (num_experts,) int32 exclusive prefix of expert counts.

    Returns (n,) int32 destinations (a permutation when starts come from the
    true histogram).
    """
    n = expert_id.shape[0]
    tile = rows * LANES
    if n % tile:
        raise ValueError(f"n={n} not a multiple of tile={tile}")
    num_tiles = n // tile
    eid2 = expert_id.reshape(num_tiles * rows, LANES)

    dest = pl.pallas_call(
        functools.partial(_kernel, num_experts=num_experts, rows=rows),
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # expert_start
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(eid2.shape, jnp.int32),
        scratch_shapes=[pltpu.SMEM((num_experts,), jnp.int32)],
        interpret=interpret,
    )(expert_start, eid2)
    return dest.reshape(n)
