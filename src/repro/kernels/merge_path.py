"""Pallas TPU kernel: branchless merge-path stable 2-way merge.

The streaming subsystem (DESIGN.md §7) decomposes an out-of-core sort into
IPS4o-sorted runs plus k-way merging; this kernel is the merge half.  The
classic CPU merge is a data-dependent two-pointer walk — poison on a VPU
for the same reason insertion sort is (every step is a branch on data).
The TPU formulation splits the work in two branch-free stages:

  1. **Diagonal partition** (`merge_path_partition`, plain XLA): for every
     output-tile boundary d = t*T, a binary search on the merge-path
     diagonal finds i(d) = #A-elements among the first d outputs of the
     *stable* merge (ties go to A).  All diagonals search in parallel —
     one fori_loop of ceil(log2 nA)+1 dense gather steps, no kernel needed.
  2. **In-tile merge** (the Pallas kernel): tile t owns output range
     [d_t, d_{t+1}) which merge-path guarantees is exactly
     A[ia:ia+la] ++ B[ja:ja+lb].  The two windows are merged by a
     branchless **bitonic merger**: window A ascending ++ window B
     *reversed* is a bitonic sequence of 2T (key, src) pairs, so
     log2(2T) compare-exchange rounds — each a dense VPU select at
     distance d = T..1, the same static-reshape idiom as
     ``kernels.bitonic`` — sort it ascending.  Ranking is lexicographic
     on (key, src) with every A source index (< nA) below every B source
     index (>= nA), which realizes the stable tie rule *exactly* (ties to
     A, order preserved within runs) with no tie-epsilon.  Lanes beyond
     la/lb mask to (sentinel key, 2^30 src) and sink to the tail.  Versus
     the previous dense (T, T) cross-rank compare + one-hot contraction,
     the merger does O(T log T) work instead of O(T^2) — at T = 256
     that is ~18 dense ops on 2T lanes instead of ~2 on T^2 cells, an
     ~8x compute drop, and the win grows linearly in T.

The kernel emits a *permutation* (int32 source index into ``A ++ B``), not
merged keys: the wrapper layers (``repro.stream.merge``) gather keys and
arbitrary payload pytrees through it, which is also what makes the merge
trivially stable for (key, payload) rows.

Per-tile scalars (window starts/lengths) ride in as a (num_tiles, 4) array
consumed through a per-tile BlockSpec — the same idiom as flash_decode's
``length`` operand — and the windows themselves are dynamic ``pl.ds``
slices of the full (VMEM-resident) runs.  The default T comes from the
unified ``launch.roofline.KernelLaunchSpec`` (kind ``"merge"``); the
stream plan cache sweeps the spec's candidate tiles.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret

__all__ = ["merge_path_partition", "merge_path_perm", "merge_rows"]


def _sentinel_np(dtype):
    """Largest representable value as a *numpy* scalar (static kernel
    parameter — a traced ``sampling.sentinel_for`` would be a captured
    constant, which pallas_call rejects)."""
    dtype = np.dtype(dtype)
    if np.issubdtype(dtype, np.floating):
        return dtype.type(np.finfo(dtype).max)
    return dtype.type(np.iinfo(dtype).max)


def merge_rows(key_bytes: int) -> int:
    """Default merge tile rows from the unified launch spec."""
    from repro.launch.roofline import launch_spec

    return launch_spec("merge", key_bytes).rows


def merge_path_partition(a: jax.Array, b: jax.Array, d: jax.Array) -> jax.Array:
    """#A-elements among the first ``d`` outputs of the stable merge of
    sorted runs ``a`` and ``b`` (ties to A), for every diagonal in ``d``.

    For each d the answer i is the largest value in
    [max(0, d-nB), min(d, nA)] with ``a[i-1] <= b[d-i]`` (the merge-path
    cut condition with the stable tie rule); the predicate is monotone in
    i, so a clamped binary search over all diagonals at once resolves in
    ceil(log2(nA+1))+1 dense steps.  Keys must be totally ordered under
    ``<=`` (the stream layer passes keyspace-encoded uints).
    """
    nA, nB = a.shape[0], b.shape[0]
    d = d.astype(jnp.int32)
    lo = jnp.maximum(0, d - nB)
    hi = jnp.minimum(d, nA)
    steps = int(nA).bit_length() + 1

    def body(_, state):
        lo, hi = state
        active = lo < hi
        mid = (lo + hi + 1) // 2  # candidate i in (lo, hi]
        am = jnp.take(a, jnp.clip(mid - 1, 0, nA - 1))
        bj = jnp.take(b, jnp.clip(d - mid, 0, nB - 1))
        q = am <= bj  # Q(mid): A[mid-1] still precedes the first unchosen B
        lo2 = jnp.where(q, mid, lo)
        hi2 = jnp.where(q, hi, mid - 1)
        return (jnp.where(active, lo2, lo), jnp.where(active, hi2, hi))

    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return lo


# masked lanes sink past every real (key, src) pair: the key is the dtype
# sentinel (>= all keys) and the src outranks any real source index
_PAD_SRC = 1 << 30


def _merge_exchange(k, s, d: int, W: int):
    """One always-ascending merger round at distance ``d``: partner =
    idx ^ d via the static (W/2d, 2, d) reshape; swap on lexicographic
    (key, src) greater-than."""
    shape = (W // (2 * d), 2, d)
    k3, s3 = k.reshape(shape), s.reshape(shape)
    (k_lo, s_lo), (k_hi, s_hi) = (k3[:, 0], s3[:, 0]), (k3[:, 1], s3[:, 1])
    swap = (k_lo > k_hi) | ((k_lo == k_hi) & (s_lo > s_hi))
    k = jnp.stack(
        [jnp.where(swap, k_hi, k_lo), jnp.where(swap, k_lo, k_hi)], axis=1
    ).reshape(W)
    s = jnp.stack(
        [jnp.where(swap, s_hi, s_lo), jnp.where(swap, s_lo, s_hi)], axis=1
    ).reshape(W)
    return k, s


def _merge_kernel(meta_ref, a_ref, b_ref, perm_ref, *, T: int, nA: int, sent):
    ia = meta_ref[0, 0]  # A window start
    ja = meta_ref[0, 1]  # B window start
    la = meta_ref[0, 2]  # A elements owned by this tile
    lb = meta_ref[0, 3]  # B elements owned by this tile
    aw = a_ref[0, pl.ds(ia, T)]  # (T,) — only the first la lanes are real
    bw = b_ref[0, pl.ds(ja, T)]
    p = jax.lax.iota(jnp.int32, T)  # local window index
    # (key, src) pairs; src orders A (< nA) wholly before B (>= nA), and by
    # run position within each — lexicographic sort == the stable merge
    ka = jnp.where(p < la, aw, sent)
    sa = jnp.where(p < la, ia + p, _PAD_SRC)
    kb = jnp.where(p < lb, bw, sent)
    sb = jnp.where(p < lb, nA + ja + p, _PAD_SRC)
    # A ascending ++ B reversed (descending) is bitonic in (key, src):
    # within a run src ascends with key, and A-pads/B-pads sit at the
    # sequence's two ends' tails where monotonicity is preserved
    k = jnp.concatenate([ka, kb[::-1]])
    s = jnp.concatenate([sa, sb[::-1]])
    for dp in range(int(math.log2(2 * T)) - 1, -1, -1):
        k, s = _merge_exchange(k, s, 1 << dp, 2 * T)
    # first T sorted srcs are this tile's outputs (slots >= la+lb — final
    # tile only — hold pad srcs and are sliced off by the wrapper)
    perm_ref[0, :] = s[:T]


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def merge_path_perm(
    a: jax.Array,
    b: jax.Array,
    *,
    tile: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Stable-merge permutation of two sorted runs.

    Args:
      a, b: 1-D sorted arrays of one dtype, totally ordered under ``<=``
        (raw NaNs are the callers' concern — ``repro.stream`` passes
        keyspace-encoded keys, exactly like the sort entry points).
      tile: output elements per grid step (the merge-path T; power of two
        — the in-tile bitonic merger runs log2(2T) rounds).  None derives
        the ``KernelLaunchSpec`` default for this key width.
      interpret: shared off-TPU policy via ``kernels.resolve_interpret``.

    Returns ``perm`` (nA+nB,) int32 with ``concat(a, b)[perm]`` equal to
    the *stable* merge: ties keep all of ``a`` before ``b`` and preserve
    order within each run — bit-identical to
    ``jnp.argsort(concat, stable=True)`` whenever a and b are themselves
    stably sorted prefixes of the concatenation.
    """
    interpret = resolve_interpret(interpret)
    nA, nB = a.shape[0], b.shape[0]
    n = nA + nB
    if tile is None:
        tile = merge_rows(a.dtype.itemsize) * 128
    if tile & (tile - 1):
        raise ValueError(f"tile={tile} must be a power of two")
    if n >= _PAD_SRC:
        raise ValueError("runs too long for the int32 source encoding")
    if nA == 0 or nB == 0:  # nothing to interleave
        return jnp.arange(n, dtype=jnp.int32)
    num_tiles = -(-n // tile)
    d = jnp.minimum(jnp.arange(num_tiles + 1, dtype=jnp.int32) * tile, n)
    part = merge_path_partition(a, b, d).astype(jnp.int32)
    ia = part[:-1]
    la = jnp.diff(part)
    ja = d[:-1] - ia
    lb = jnp.diff(d) - la
    meta = jnp.stack([ia, ja, la, lb], axis=1)  # (num_tiles, 4) int32
    # pad run tails so the T-wide dynamic window loads never read OOB (the
    # pad values are masked by la/lb and never influence a rank)
    La, Lb = nA + tile, nB + tile
    ap = jnp.pad(a, (0, tile)).reshape(1, La)
    bp = jnp.pad(b, (0, tile)).reshape(1, Lb)

    perm = pl.pallas_call(
        functools.partial(_merge_kernel, T=tile, nA=nA, sent=_sentinel_np(a.dtype)),
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((1, 4), lambda t: (t, 0)),  # per-tile scalars
            pl.BlockSpec((1, La), lambda t: (0, 0)),  # run A (whole)
            pl.BlockSpec((1, Lb), lambda t: (0, 0)),  # run B (whole)
        ],
        out_specs=pl.BlockSpec((1, tile), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((num_tiles, tile), jnp.int32),
        interpret=interpret,
    )(meta, ap, bp)
    return perm.reshape(-1)[:n]
