"""Pallas TPU kernel: branchless merge-path stable 2-way merge.

The streaming subsystem (DESIGN.md §7) decomposes an out-of-core sort into
IPS4o-sorted runs plus k-way merging; this kernel is the merge half.  The
classic CPU merge is a data-dependent two-pointer walk — poison on a VPU
for the same reason insertion sort is (every step is a branch on data).
The TPU formulation splits the work in two branch-free stages:

  1. **Diagonal partition** (`merge_path_partition`, plain XLA): for every
     output-tile boundary d = t*T, a binary search on the merge-path
     diagonal finds i(d) = #A-elements among the first d outputs of the
     *stable* merge (ties go to A).  All diagonals search in parallel —
     one fori_loop of ceil(log2 nA)+1 dense gather steps, no kernel needed.
  2. **In-tile merge** (the Pallas kernel): tile t owns output range
     [d_t, d_{t+1}) which merge-path guarantees is exactly
     A[ia:ia+la] ++ B[ja:ja+lb].  Each element's in-tile destination is its
     cross-rank, computed by a dense (T, T) broadcast compare — strict
     ``<`` counting B-before-A and ``<=`` counting A-before-B, the same
     tie discipline as the partition — and the output permutation
     materializes through a one-hot contraction.  Zero gathers, zero
     divergence: the merge analogue of the classify kernel's
     "lane-parallel dense compare instead of pointer chase".

The kernel emits a *permutation* (int32 source index into ``A ++ B``), not
merged keys: the wrapper layers (``repro.stream.merge``) gather keys and
arbitrary payload pytrees through it, which is also what makes the merge
trivially stable for (key, payload) rows.

Per-tile scalars (window starts/lengths) ride in as a (num_tiles, 4) array
consumed through a per-tile BlockSpec — the same idiom as flash_decode's
``length`` operand — and the windows themselves are dynamic ``pl.ds``
slices of the full (VMEM-resident) runs.  VMEM budget: both runs + the
(T, T) compare/one-hot intermediates (T=256: ~0.5 MiB), which bounds a
single kernel launch to runs of a few MiB; the streaming layer's pairwise
passes keep individual merges under that by construction, and interpret
mode (this container) has no such limit.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret

__all__ = ["merge_path_partition", "merge_path_perm"]


def merge_path_partition(a: jax.Array, b: jax.Array, d: jax.Array) -> jax.Array:
    """#A-elements among the first ``d`` outputs of the stable merge of
    sorted runs ``a`` and ``b`` (ties to A), for every diagonal in ``d``.

    For each d the answer i is the largest value in
    [max(0, d-nB), min(d, nA)] with ``a[i-1] <= b[d-i]`` (the merge-path
    cut condition with the stable tie rule); the predicate is monotone in
    i, so a clamped binary search over all diagonals at once resolves in
    ceil(log2(nA+1))+1 dense steps.  Keys must be totally ordered under
    ``<=`` (the stream layer passes keyspace-encoded uints).
    """
    nA, nB = a.shape[0], b.shape[0]
    d = d.astype(jnp.int32)
    lo = jnp.maximum(0, d - nB)
    hi = jnp.minimum(d, nA)
    steps = int(nA).bit_length() + 1

    def body(_, state):
        lo, hi = state
        active = lo < hi
        mid = (lo + hi + 1) // 2  # candidate i in (lo, hi]
        am = jnp.take(a, jnp.clip(mid - 1, 0, nA - 1))
        bj = jnp.take(b, jnp.clip(d - mid, 0, nB - 1))
        q = am <= bj  # Q(mid): A[mid-1] still precedes the first unchosen B
        lo2 = jnp.where(q, mid, lo)
        hi2 = jnp.where(q, hi, mid - 1)
        return (jnp.where(active, lo2, lo), jnp.where(active, hi2, hi))

    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return lo


def _merge_kernel(meta_ref, a_ref, b_ref, perm_ref, *, T: int, nA: int):
    ia = meta_ref[0, 0]  # A window start
    ja = meta_ref[0, 1]  # B window start
    la = meta_ref[0, 2]  # A elements owned by this tile
    lb = meta_ref[0, 3]  # B elements owned by this tile
    aw = a_ref[0, pl.ds(ia, T)]  # (T,) — only the first la lanes are real
    bw = b_ref[0, pl.ds(ja, T)]
    av = aw[:, None]  # (T, 1)
    bv = bw[None, :]  # (1, T)
    p_col = jax.lax.broadcasted_iota(jnp.int32, (T, 1), 0)  # local A index
    q_row = jax.lax.broadcasted_iota(jnp.int32, (1, T), 1)  # local B index
    valid_a = p_col < la
    valid_b = q_row < lb
    # cross-ranks, same tie rule as the diagonal partition: B precedes A
    # only strictly (<), A precedes B on ties (<=)
    b_before_a = jnp.sum(((bv < av) & valid_b).astype(jnp.int32), axis=1)  # (T,)
    a_before_b = jnp.sum(((av <= bv) & valid_a).astype(jnp.int32), axis=0)  # (T,)
    dest_a = p_col[:, 0] + b_before_a  # in-tile output slot of A[ia+p]
    dest_b = q_row[0, :] + a_before_b  # in-tile output slot of B[ja+q]
    # one-hot contraction: perm[r] = global source index of output slot r
    # (slots r >= la+lb — final tile only — stay 0 and are sliced off)
    oh_a = ((dest_a[:, None] == q_row) & valid_a).astype(jnp.int32)  # (T, T)
    oh_b = ((dest_b[:, None] == q_row) & (p_col < lb)).astype(jnp.int32)
    src_a = ia + p_col[:, 0]
    src_b = nA + ja + p_col[:, 0]
    perm_ref[0, :] = jnp.sum(oh_a * src_a[:, None], axis=0) + jnp.sum(
        oh_b * src_b[:, None], axis=0
    )


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def merge_path_perm(
    a: jax.Array,
    b: jax.Array,
    *,
    tile: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Stable-merge permutation of two sorted runs.

    Args:
      a, b: 1-D sorted arrays of one dtype, totally ordered under ``<=``
        (raw NaNs are the callers' concern — ``repro.stream`` passes
        keyspace-encoded keys, exactly like the sort entry points).
      tile: output elements per grid step (the merge-path T).
      interpret: shared off-TPU policy via ``kernels.resolve_interpret``.

    Returns ``perm`` (nA+nB,) int32 with ``concat(a, b)[perm]`` equal to
    the *stable* merge: ties keep all of ``a`` before ``b`` and preserve
    order within each run — bit-identical to
    ``jnp.argsort(concat, stable=True)`` whenever a and b are themselves
    stably sorted prefixes of the concatenation.
    """
    interpret = resolve_interpret(interpret)
    nA, nB = a.shape[0], b.shape[0]
    n = nA + nB
    if nA == 0 or nB == 0:  # nothing to interleave
        return jnp.arange(n, dtype=jnp.int32)
    num_tiles = -(-n // tile)
    d = jnp.minimum(jnp.arange(num_tiles + 1, dtype=jnp.int32) * tile, n)
    part = merge_path_partition(a, b, d).astype(jnp.int32)
    ia = part[:-1]
    la = jnp.diff(part)
    ja = d[:-1] - ia
    lb = jnp.diff(d) - la
    meta = jnp.stack([ia, ja, la, lb], axis=1)  # (num_tiles, 4) int32
    # pad run tails so the T-wide dynamic window loads never read OOB (the
    # pad values are masked by la/lb and never influence a rank)
    La, Lb = nA + tile, nB + tile
    ap = jnp.pad(a, (0, tile)).reshape(1, La)
    bp = jnp.pad(b, (0, tile)).reshape(1, Lb)

    perm = pl.pallas_call(
        functools.partial(_merge_kernel, T=tile, nA=nA),
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((1, 4), lambda t: (t, 0)),  # per-tile scalars
            pl.BlockSpec((1, La), lambda t: (0, 0)),  # run A (whole)
            pl.BlockSpec((1, Lb), lambda t: (0, 0)),  # run B (whole)
        ],
        out_specs=pl.BlockSpec((1, tile), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((num_tiles, tile), jnp.int32),
        interpret=interpret,
    )(meta, ap, bp)
    return perm.reshape(-1)[:n]
