"""Pallas TPU kernel: fused flash-DECODE attention (q_len = 1 vs a cache).

§Roofline identified decode cells running 4–15× above the ideal
params+cache read; after the CPU-artifact (2×) and scan-restack (≈2×)
shares, the remainder is the score/softmax/weighted-sum passes each
re-reading cache-sized tensors through HBM.  This kernel performs the
whole per-head reduction in one VMEM pass over the KV cache: HBM traffic
= K + V read once + (1, hd) out — the floor.

Grid: (B*H, T/bt) with a SEQUENTIAL reduction over the T axis carried in
VMEM scratch (m, l, acc persist across grid steps of the same (b,h) row;
TPU grid iteration is sequential so the carry is race-free — the same
property the in-place block permutation kernel relies on).  The `length`
operand masks the valid cache prefix, so one compiled kernel serves all
ring positions.

Per-step VMEM: k,v blocks (bt × hd) + q (1 × hd) + scratch ≈
2·bt·hd·4 B — bt = 1024, hd = 128: ~1 MiB.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_decode"]

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, bt: int, hd: int):
    t_idx = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(t_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * (1.0 / math.sqrt(hd))  # (1, hd)
    kb = k_ref[0].astype(jnp.float32)                         # (bt, hd)
    vb = v_ref[0].astype(jnp.float32)
    s = jnp.sum(q * kb, axis=-1)[None, :]                     # (1, bt)
    pos = t_idx * bt + jax.lax.broadcasted_iota(jnp.int32, (1, bt), 1)
    valid = pos < len_ref[0, 0]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                                       # (1, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(valid, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + p @ vb               # (1, hd)

    @pl.when(t_idx == nt - 1)
    def _fini():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def flash_decode(
    q: jax.Array,        # (B, H, 1, hd)
    k: jax.Array,        # (B, H, T, hd)  (GQA pre-expanded)
    v: jax.Array,        # (B, H, T, hd)
    length: jax.Array,   # (B,) int32: valid cache prefix per request
    *,
    bt: int = 1024,
    interpret: bool = True,
) -> jax.Array:
    b, h, _, hd = q.shape
    t = k.shape[2]
    bt = min(bt, t)
    if t % bt:
        raise ValueError(f"cache len {t} must be a multiple of bt={bt}")
    bh = b * h
    qf = q.reshape(bh, 1, hd)
    kf = k.reshape(bh, t, hd)
    vf = v.reshape(bh, t, hd)
    lens = jnp.repeat(length.astype(jnp.int32), h).reshape(bh, 1)

    out = pl.pallas_call(
        functools.partial(_kernel, bt=bt, hd=hd),
        grid=(bh, t // bt),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),         # length
            pl.BlockSpec((1, 1, hd), lambda i, j: (i, 0, 0)),  # q
            pl.BlockSpec((1, bt, hd), lambda i, j: (i, j, 0)),  # k block
            pl.BlockSpec((1, bt, hd), lambda i, j: (i, j, 0)),  # v block
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),    # m
            pltpu.VMEM((1, 1), jnp.float32),    # l
            pltpu.VMEM((1, hd), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(lens, qf, kf, vf)
    return out.reshape(b, h, 1, hd)
