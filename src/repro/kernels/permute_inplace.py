"""Pallas TPU kernel: the paper's in-place block permutation (§4.2), faithful.

This kernel realizes IPS4o's central mechanism *literally* on one TPU core:

  * the array is a sequence of N homogeneous blocks of b elements (the
    output of local classification);
  * per-bucket write/read pointers w_i, r_i live in SMEM (the paper keeps
    them in a 128-bit atomic word; on TPU the grid executes sequentially on
    a core, so one core == one paper-thread and no atomics are needed —
    cross-core parallelism happens one level up via shard_map stripes);
  * two VMEM swap buffers (the paper's "each thread maintains two local
    swap buffers") alternate roles via a parity flag;
  * each grid step performs exactly one block *write* (either swapping the
    swap buffer with the unprocessed block at w_dest, or dropping it into an
    empty slot), preceded — when the swap buffer is empty — by a cyclic
    primary-bucket scan and a block *read* that decrements r_p;
  * the data array is input/output aliased: the permutation is genuinely
    in-place in HBM; block moves are explicit HBM<->VMEM DMAs
    (``pltpu.make_async_copy``) — the TPU spelling of the paper's
    cache-block transfers.

Invariant per bucket (Fig. 3): [d_i, w_i) correct | [w_i, r_i) unprocessed |
[r_i, d_{i+1}) empty(read).  Each step preserves it; N writes complete the
permutation; grid = N+1 (the last step detects termination).

Not stable (the paper's permutation isn't either); the oracle checks
per-bucket block multisets.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["permute_blocks_inplace"]

LANES = 128

# scalar state slots
S_FILLED, S_PRIMARY, S_DONE, S_SBB, S_CUR, S_MOVES = range(6)


def _kernel(d_ref, bb_ref, a_in, a_out, w_ref, r_ref, st_ref, swap0, swap1, sem,
            *, k: int, nblocks: int, brows: int):
    pid = pl.program_id(0)

    @pl.when(pid == 0)
    def _init():
        for i in range(k):
            w_ref[i] = d_ref[i]
            r_ref[i] = d_ref[i + 1]
        for s in range(6):
            st_ref[s] = 0

    def copy(src, dst):
        cp = pltpu.make_async_copy(src, dst, sem)
        cp.start()
        cp.wait()

    def block(ref, idx):
        return ref.at[pl.dslice(idx * brows, brows), :]

    def swap_ref(sel):
        # returns a pair (read_fn writing into, ...) — we emit both branches
        # under pl.when since refs can't be selected dynamically.
        return swap0 if sel == 0 else swap1

    @pl.when(st_ref[S_DONE] == 0)
    def _step():
        # ---- refill swap buffer if empty (cyclic primary-bucket scan) ----
        @pl.when(st_ref[S_FILLED] == 0)
        def _fill():
            # hoist the pointer reads out of the while_loop: SMEM scalars to
            # values first (k is small/static), so the loop carries no ref
            # effects — interpret-mode state discharge has no rule for a
            # ref-reading `while`.
            ws = jnp.stack([w_ref[i] for i in range(k)])
            rs = jnp.stack([r_ref[i] for i in range(k)])

            def cond(s):
                p, cnt = s
                return (cnt < k) & (ws[p] >= rs[p])

            def body(s):
                p, cnt = s
                return ((p + 1) % k, cnt + 1)

            p, cnt = jax.lax.while_loop(
                cond, body, (st_ref[S_PRIMARY], jnp.int32(0))
            )
            st_ref[S_PRIMARY] = p
            found = w_ref[p] < r_ref[p]

            @pl.when(found)
            def _read():
                src = r_ref[p] - 1
                r_ref[p] = src
                for sel in (0, 1):
                    @pl.when(st_ref[S_CUR] == sel)
                    def _(sel=sel):
                        copy(block(a_in, src), swap_ref(sel))
                st_ref[S_SBB] = bb_ref[src]
                st_ref[S_FILLED] = 1

            @pl.when(jnp.logical_not(found))
            def _done():
                st_ref[S_DONE] = 1

        # ---- one block write --------------------------------------------
        @pl.when(st_ref[S_FILLED] == 1)
        def _write():
            dest = st_ref[S_SBB]
            wd = w_ref[dest]
            exchange = wd < r_ref[dest]

            # Read the displaced block into the *other* swap buffer first.
            @pl.when(exchange)
            def _displace():
                for sel in (0, 1):
                    @pl.when(st_ref[S_CUR] == sel)
                    def _(sel=sel):
                        copy(block(a_in, wd), swap_ref(1 - sel))

            next_sbb = jnp.where(exchange, bb_ref[wd], 0)

            for sel in (0, 1):
                @pl.when(st_ref[S_CUR] == sel)
                def _(sel=sel):
                    copy(swap_ref(sel), block(a_out, wd))

            w_ref[dest] = wd + 1
            st_ref[S_MOVES] = st_ref[S_MOVES] + 1

            @pl.when(exchange)
            def _rotate():
                st_ref[S_CUR] = 1 - st_ref[S_CUR]
                st_ref[S_SBB] = next_sbb

            @pl.when(jnp.logical_not(exchange))
            def _emptied():
                st_ref[S_FILLED] = 0


@functools.partial(jax.jit, static_argnames=("k", "block_elems", "interpret"))
def permute_blocks_inplace(
    a: jax.Array,
    block_bucket: jax.Array,
    d: jax.Array,
    *,
    k: int,
    block_elems: int = 1024,
    interpret: bool = True,
) -> jax.Array:
    """In-place block permutation.

    Args:
      a: (N * block_elems,) data; block i is homogeneous (single bucket).
      block_bucket: (N,) int32 bucket of each block, in [0, k).
      d: (k+1,) int32 block-index bucket boundaries (from the histogram
         prefix sum); d[k] == N.
      k: number of buckets (static).
      block_elems: elements per block; must be a multiple of 128.

    Returns the permuted array (same buffer: input is aliased/donated).
    """
    if block_elems % LANES:
        raise ValueError("block_elems must be a multiple of 128")
    brows = block_elems // LANES
    n = a.shape[0]
    nblocks = n // block_elems
    if n != nblocks * block_elems:
        raise ValueError("array size must be a multiple of block_elems")
    a2 = a.reshape(nblocks * brows, LANES)

    out = pl.pallas_call(
        functools.partial(_kernel, k=k, nblocks=nblocks, brows=brows),
        grid=(nblocks + 1,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # d
            pl.BlockSpec(memory_space=pltpu.SMEM),  # block_bucket
            pl.BlockSpec(memory_space=pl.ANY),  # a (HBM)
        ],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        out_shape=jax.ShapeDtypeStruct(a2.shape, a2.dtype),
        scratch_shapes=[
            pltpu.SMEM((k,), jnp.int32),  # w
            pltpu.SMEM((k,), jnp.int32),  # r
            pltpu.SMEM((8,), jnp.int32),  # scalar state
            pltpu.VMEM((brows, LANES), a2.dtype),  # swap buffer 0
            pltpu.VMEM((brows, LANES), a2.dtype),  # swap buffer 1
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={2: 0},
        interpret=interpret,
    )(d, block_bucket, a2)
    return out.reshape(n)
