"""Jit'd public wrappers around the Pallas kernels.

These are the entry points the rest of the framework uses; each wrapper
handles padding/reshaping, pytree payloads, and falls back to documented
shapes.  ``interpret=None`` resolves through the shared off-TPU policy
(``kernels.resolve_interpret``): interpret everywhere but TPU, where the
same calls lower natively.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.bitonic import bitonic_sort_windows
from repro.kernels.classify import classify_histogram
from repro.kernels.dispatch_rank import dispatch_ranks, partition_ranks
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode
from repro.kernels.permute_inplace import permute_blocks_inplace

__all__ = [
    "classify_histogram",
    "bitonic_sort_windows",
    "permute_blocks_inplace",
    "dispatch_ranks",
    "partition_ranks",
    "flash_attention",
    "flash_decode",
    "sort_blocks",
    "base_case_windows",
    "moe_group_tokens",
]


def sort_blocks(
    a: jax.Array,
    block_bucket: jax.Array,
    *,
    k: int,
    block_elems: int,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Group homogeneous blocks by bucket with the in-place kernel.

    Thin single-array form of ``core.partition.partition_blocks`` (the
    block-granular move of the "pallas" partition engine).  Returns
    (permuted array, (k+1,) block-boundary offsets).
    """
    from repro.core.partition import partition_blocks

    out, d = partition_blocks(
        {"k": a}, block_bucket, k, block_elems, interpret=interpret
    )
    return out["k"], d


def base_case_windows(
    arrays: Any, fb: jax.Array, W: int, *, interpret: Optional[bool] = None
) -> Any:
    """Pallas version of the overlapped-window base case (both passes).

    ``arrays`` is a pytree whose leaves have leading dim n (multiple of W);
    leaf 'k' is the key array.  Permutes every leaf by the (bucket, key)
    window sort using the bitonic kernel + an index payload.
    """
    n = fb.shape[0]

    def one_pass(arrays, fb, lo, hi):
        m = hi - lo
        kw = arrays["k"][lo:hi].reshape(m // W, W)
        fw = fb[lo:hi].reshape(m // W, W)
        idx = jnp.broadcast_to(
            jnp.arange(W, dtype=jnp.int32)[None, :], (m // W, W)
        )
        fb_s, _, perm = bitonic_sort_windows(fw, kw, idx, interpret=interpret)

        def fix(a):
            aw = a[lo:hi].reshape((m // W, W) + a.shape[1:])
            sw = jax.vmap(lambda row, p: jnp.take(row, p, axis=0))(aw, perm)
            return a.at[lo:hi].set(sw.reshape((m,) + a.shape[1:]))

        arrays = jax.tree.map(fix, arrays)
        fb = fb.at[lo:hi].set(fb_s.reshape(m))
        return arrays, fb

    arrays, fb = one_pass(arrays, fb, 0, n)
    if n > W:
        arrays, fb = one_pass(arrays, fb, W // 2, n - W // 2)
    return arrays


def moe_group_tokens(
    expert_id: jax.Array,
    tokens: jax.Array,
    num_experts: int,
    *,
    rows: int = 8,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Group tokens expert-major using the fused dispatch-rank kernel.

    Returns (grouped tokens, (E+1,) offsets, dest permutation for un-group).
    """
    n = expert_id.shape[0]
    hist = jnp.bincount(expert_id, length=num_experts)
    start = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(hist).astype(jnp.int32)]
    )
    dest = dispatch_ranks(
        expert_id, start[:-1], num_experts=num_experts, rows=rows, interpret=interpret
    )
    grouped = jnp.zeros_like(tokens).at[dest].set(tokens, mode="promise_in_bounds")
    return grouped, start, dest
