"""Pallas TPU kernel: fused branchless classification + per-tile histogram.

This is the hot loop of the paper's *local classification* phase (§4.1).

Hardware adaptation (DESIGN.md §2): the paper's scalar search-tree descent
(`i <- 2i + (e > a_i)`, one conditional-increment per level) exists to avoid
*branch mispredictions* on a superscalar CPU.  A TPU VPU has no branch
predictor and hates serialized gathers; the idiomatic equivalent of
"branch-free" is "lane-parallel dense compare": we classify a whole
(rows, 128) tile against **all** k-1 splitters with broadcast compares,

    j  = sum_i (key > u_i)          (the rank of the key among splitters)
    eq = any_i (key == u_i)         (equality-bucket test, paper §4.4)
    bucket = 2*j + eq

where u = splitters + the dtype sentinel (the paper's s_k = +inf upper
splitter of the last bucket — comparing against it leaves j unchanged but
makes keys equal to the sentinel land in the last *equality* bucket,
exactly like the tree descent's ``e == upper_j`` test),

and which is mathematically identical to the tree descent (j = |{s : s < key}|)
but runs as k dense VPU ops with zero gathers and zero divergence.  The
per-tile histogram (the paper's "count elements per bucket as a side effect
of maintaining buffer blocks") is fused into the same VMEM pass via a
one-hot reduction.

VMEM budget per grid step: tile keys (rows*128*4 B) + splitters (k*4 B) +
one-hot reduction tile.  The row count is not hard-coded: ``rows=None``
derives it from the VMEM roofline model (``launch.roofline.
classify_tile_rows`` — the largest power-of-two tile whose working set
fits the budget, e.g. 32 rows at f32/k=128), and the plan cache sweeps
the leading candidates (``SortConfig.classify_rows``).

The radix form (``radix_histogram`` — the IPS2Ra extractor of DESIGN.md
§9) replaces the dense compare with one shift + mask per element
(``repro.classify.radix`` is the id contract); no splitter operand at
all, same fused per-tile histogram.

The batched variant (``classify_histogram_batched``, DESIGN.md §6) adds a
*batch grid dimension*: grid = (B, num_tiles), each program classifying
tile ``i`` of row ``b`` against row ``b``'s own splitter set.  The kernel
body is unchanged — only the BlockSpec index maps route per-row blocks —
so B independent rows classify in one ``pallas_call`` instead of B
dispatches of the unbatched kernel.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.classify.radix import radix_bucket_ids
from repro.core.sampling import sentinel_for
from repro.kernels import resolve_interpret

__all__ = [
    "classify_histogram",
    "classify_histogram_batched",
    "radix_histogram",
    "radix_histogram_batched",
    "default_rows",
]

LANES = 128


def default_rows(n: int, key_bytes: int, k: int) -> int:
    """Largest launch-spec row candidate whose tile (rows*128) divides
    ``n``, or 0 when no candidate does (callers then stay on the XLA
    path).  One ``KernelLaunchSpec`` resolution, shared with every other
    sort kernel (``launch.roofline.launch_spec``)."""
    from repro.launch.roofline import launch_spec

    return launch_spec("classify", key_bytes, k, n=n).rows


def _kernel(keys_ref, spl_ref, bucket_ref, hist_ref, *, k: int, nb: int):
    keys = keys_ref[...]  # (rows, 128)
    spl = spl_ref[...]  # (1, k): k-1 splitters + the dtype sentinel
    kf = keys[:, :, None]  # (rows, 128, 1)
    sf = spl[0][None, None, :]  # (1, 1, k)
    # j counts only the k-1 real splitters (a key above the sentinel, e.g.
    # +inf, must still land in bucket k-1); eq compares against all k uppers.
    # dtype= pins the accumulator: with x64 enabled (u64 keys) jnp.sum
    # would otherwise widen int32 to int64 and mismatch the output refs
    j = jnp.sum((kf > sf[..., : k - 1]).astype(jnp.int32), axis=-1, dtype=jnp.int32)
    eq = jnp.any(kf == sf, axis=-1).astype(jnp.int32)
    bucket = 2 * j + eq
    bucket_ref[...] = bucket
    # Fused per-tile histogram: one-hot reduce over the tile.
    ids = jax.lax.broadcasted_iota(jnp.int32, (1, 1, nb), 2)
    onehot = (bucket[:, :, None] == ids).astype(jnp.int32)
    hist_ref[...] = jnp.sum(onehot, axis=(0, 1), dtype=jnp.int32)[None, :]


@functools.partial(jax.jit, static_argnames=("k", "rows", "interpret"))
def classify_histogram(
    keys: jax.Array,
    splitters: jax.Array,
    *,
    k: int,
    rows: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Classify ``keys`` (n,) against ``splitters`` (k-1,).

    Returns (bucket ids (n,) int32 in [0, 2k), per-tile histogram
    (num_tiles, 2k) int32).  n must be a multiple of rows*128;
    ``rows=None`` takes the largest roofline candidate dividing n.
    """
    interpret = resolve_interpret(interpret)
    n = keys.shape[0]
    if rows is None:
        rows = default_rows(n, keys.dtype.itemsize, k)
    tile = rows * LANES
    if not rows or n % tile:
        raise ValueError(f"n={n} must be a multiple of a rows*{LANES} tile")
    num_tiles = n // tile
    nb = 2 * k
    keys2 = keys.reshape(num_tiles * rows, LANES)
    # Append the dtype sentinel as the upper splitter of the last bucket: it
    # never changes j (no key is > it) but keys *equal* to it get eq = 1 and
    # land in equality bucket 2(k-1)+1, matching the tree classifier.
    upper = jnp.concatenate(
        [splitters, jnp.full((1,), sentinel_for(splitters.dtype), splitters.dtype)]
    )
    spl2 = upper.reshape(1, k)

    bucket, hist = pl.pallas_call(
        functools.partial(_kernel, k=k, nb=nb),
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, nb), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_tiles * rows, LANES), jnp.int32),
            jax.ShapeDtypeStruct((num_tiles, nb), jnp.int32),
        ],
        interpret=interpret,
    )(keys2, spl2)
    return bucket.reshape(n), hist


@functools.partial(jax.jit, static_argnames=("k", "rows", "interpret"))
def classify_histogram_batched(
    keys: jax.Array,
    splitters: jax.Array,
    *,
    k: int,
    rows: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Classify ``keys`` (B, n) against per-row ``splitters`` (B, k-1).

    The batch-grid form of :func:`classify_histogram`: grid (B, num_tiles),
    row ``b``'s tiles compare against row ``b``'s splitter block.  Returns
    (bucket ids (B, n) int32 in [0, 2k), per-tile histograms
    (B, num_tiles, 2k) int32).  n must be a multiple of rows*128;
    ``rows=None`` takes the largest roofline candidate dividing n.
    """
    interpret = resolve_interpret(interpret)
    B, n = keys.shape
    if rows is None:
        rows = default_rows(n, keys.dtype.itemsize, k)
    tile = rows * LANES
    if not rows or n % tile:
        raise ValueError(f"n={n} must be a multiple of a rows*{LANES} tile")
    num_tiles = n // tile
    nb = 2 * k
    keys2 = keys.reshape(B * num_tiles * rows, LANES)
    upper = jnp.concatenate(
        [
            splitters,
            jnp.full((B, 1), sentinel_for(splitters.dtype), splitters.dtype),
        ],
        axis=1,
    )  # (B, k): per-row splitters + the dtype sentinel upper

    bucket, hist = pl.pallas_call(
        functools.partial(_kernel, k=k, nb=nb),
        grid=(B, num_tiles),
        in_specs=[
            pl.BlockSpec((rows, LANES), lambda b, i: (b * num_tiles + i, 0)),
            pl.BlockSpec((1, k), lambda b, i: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((rows, LANES), lambda b, i: (b * num_tiles + i, 0)),
            pl.BlockSpec((1, nb), lambda b, i: (b * num_tiles + i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * num_tiles * rows, LANES), jnp.int32),
            jax.ShapeDtypeStruct((B * num_tiles, nb), jnp.int32),
        ],
        interpret=interpret,
    )(keys2, upper)
    return bucket.reshape(B, n), hist.reshape(B, num_tiles, nb)


def _radix_kernel(keys_ref, bucket_ref, hist_ref, *, k: int, nb: int, consumed: int):
    # the extractor is elementwise (one shift + one mask — the IPS2Ra
    # classifier), so the id computation is shared verbatim with the XLA
    # engine: repro.classify.radix is the single source of truth
    bucket = radix_bucket_ids(keys_ref[...], k, consumed)  # (rows, 128)
    bucket_ref[...] = bucket
    ids = jax.lax.broadcasted_iota(jnp.int32, (1, 1, nb), 2)
    onehot = (bucket[:, :, None] == ids).astype(jnp.int32)
    # dtype= pins the x64-mode accumulator to the int32 output ref
    hist_ref[...] = jnp.sum(onehot, axis=(0, 1), dtype=jnp.int32)[None, :]


@functools.partial(
    jax.jit, static_argnames=("k", "consumed_bits", "rows", "interpret")
)
def radix_histogram(
    keys: jax.Array,
    *,
    k: int,
    consumed_bits: int = 0,
    rows: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Fused radix extract + per-tile histogram over ``keys`` (n,).

    The radix twin of :func:`classify_histogram` with no splitter operand:
    bucket ``2 * ((key >> shift) & (k-1)) + (key == sentinel)`` where the
    static shift skips ``consumed_bits`` already fixed by earlier levels
    (``repro.classify.radix.radix_shift``).  Keys must be keyspace-encoded
    (unsigned).  Returns (bucket ids (n,) int32 in [0, 2k), per-tile
    histogram (num_tiles, 2k) int32); n must be a multiple of rows*128,
    ``rows=None`` takes the largest roofline candidate dividing n.
    """
    interpret = resolve_interpret(interpret)
    n = keys.shape[0]
    if rows is None:
        rows = default_rows(n, keys.dtype.itemsize, k)
    tile = rows * LANES
    if not rows or n % tile:
        raise ValueError(f"n={n} must be a multiple of a rows*{LANES} tile")
    num_tiles = n // tile
    nb = 2 * k
    keys2 = keys.reshape(num_tiles * rows, LANES)

    bucket, hist = pl.pallas_call(
        functools.partial(_radix_kernel, k=k, nb=nb, consumed=consumed_bits),
        grid=(num_tiles,),
        in_specs=[pl.BlockSpec((rows, LANES), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, nb), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_tiles * rows, LANES), jnp.int32),
            jax.ShapeDtypeStruct((num_tiles, nb), jnp.int32),
        ],
        interpret=interpret,
    )(keys2)
    return bucket.reshape(n), hist


@functools.partial(
    jax.jit, static_argnames=("k", "consumed_bits", "rows", "interpret")
)
def radix_histogram_batched(
    keys: jax.Array,
    *,
    k: int,
    consumed_bits: int = 0,
    rows: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Per-row fused radix extract + histogram over ``keys`` (B, n).

    The extractor has no per-row state (the shift is data-independent, so
    every row uses the same one — nothing like the per-row splitter blocks
    of :func:`classify_histogram_batched` is needed): the rows concatenate
    into one longer unbatched launch and the tile histograms reshape back.
    Returns (bucket ids (B, n), per-tile histograms (B, n/tile, 2k));
    n must be a multiple of rows*128 so tiles never straddle rows.
    """
    B, n = keys.shape
    if rows is None:
        rows = default_rows(n, keys.dtype.itemsize, k)
    if not rows or n % (rows * LANES):
        raise ValueError(f"n={n} must be a multiple of a rows*{LANES} tile")
    bucket, hist = radix_histogram(
        keys.reshape(B * n),
        k=k, consumed_bits=consumed_bits, rows=rows, interpret=interpret,
    )
    return bucket.reshape(B, n), hist.reshape(B, n // (rows * LANES), 2 * k)
