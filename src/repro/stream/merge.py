"""Stable k-way merge of sorted runs (DESIGN.md §7.2).

The merge is the one sort-adjacent primitive the engine's level passes
cannot express: it *combines* already-ordered sequences instead of
partitioning one.  Layering mirrors the sort ops:

  * keys biject through ``ops.keyspace`` first, so the merge is NaN-safe
    (NaNs last, -0.0 before +0.0) with the identical total order as
    ``ops.sort`` — a merge of runs produced by the sort entry points is
    therefore exactly the sort of the concatenation;
  * two bit-identical engines behind the same ``engine="xla"|"pallas"|
    "auto"`` seam as ``stable_partition``: "xla" is the two-searchsorted
    rank merge (``kernels.ref.merge_path_perm_ref``), "pallas" the tiled
    merge-path kernel (``kernels.merge_path``);
  * k runs reduce through a **tournament of pairwise passes** — the
    static-shape analogue of a loser tree: each round merges adjacent run
    pairs, ceil(log2 k) rounds total, and because every pairwise pass is
    stable and rounds preserve run order, ties keep (run index, position)
    order end to end.

Everything here is device-resident and jit-compatible (static run count
and lengths); the host-orchestrated out-of-core pipelines live in
``stream.api``.
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.ips4o import SortConfig, resolve_engine
from repro.kernels.merge_path import merge_path_perm
from repro.kernels.ref import merge_path_perm_ref
from repro.ops import keyspace

__all__ = ["merge", "merge_perm", "merge_runs_encoded"]


def merge_perm(
    a: jax.Array,
    b: jax.Array,
    *,
    engine: str = "xla",
    tile: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Stable-merge permutation of two *totally ordered* sorted runs.

    ``concat(a, b)[perm]`` is the stable merge (ties: all of ``a`` first).
    Callers pass keyspace-encoded keys; raw floats with NaNs violate the
    total-order contract exactly as they do for ``ips4o_sort``.  Both
    engines emit the bit-identical permutation.
    """
    if engine == "pallas":
        return merge_path_perm(a, b, tile=tile, interpret=interpret)
    if engine != "xla":
        raise ValueError(f"unknown merge engine {engine!r}; expected xla|pallas")
    return merge_path_perm_ref(a, b)


def _resolve_merge_engine(engine: Optional[str], n: int, dtype) -> str:
    """Same resolution seam as ``stable_partition``: an explicit engine
    wins; None/"auto" consults the plan cache's persisted choice for this
    shape and then the backend heuristic (``core.ips4o.resolve_engine``)."""
    return resolve_engine(SortConfig(engine=engine or "auto"), n, dtype)


def _merge2(x: Any, y: Any, engine: str, tile: int, interpret: Optional[bool]) -> Any:
    """One tournament round step: stable merge of two arrays-dicts whose
    'k' leaves are encoded sorted runs; every other leaf rides the perm."""
    na, nb = x["k"].shape[0], y["k"].shape[0]
    if na == 0:
        return y
    if nb == 0:
        return x
    perm = merge_perm(x["k"], y["k"], engine=engine, tile=tile, interpret=interpret)
    return jax.tree.map(
        lambda u, v: jnp.take(jnp.concatenate([u, v], axis=0), perm, axis=0), x, y
    )


def merge_runs_encoded(
    items: List[Any],
    *,
    engine: str = "xla",
    tile: int = 256,
    interpret: Optional[bool] = None,
) -> Any:
    """Tournament-reduce k arrays-dicts (encoded sorted 'k' + payload
    leaves) to one.  Adjacent pairs merge each round, so run order — and
    with it global tie order — is preserved; empty runs are absorbed
    free of charge (the pairwise step short-circuits them)."""
    if not items:
        raise ValueError("merge of zero runs")
    while len(items) > 1:
        nxt = [
            _merge2(items[i], items[i + 1], engine, tile, interpret)
            for i in range(0, len(items) - 1, 2)
        ]
        if len(items) % 2:
            nxt.append(items[-1])
        items = nxt
    return items[0]


def merge(
    runs: Sequence[jax.Array],
    values: Optional[Sequence[Any]] = None,
    *,
    engine: Optional[str] = None,
    tile: int = 256,
    interpret: Optional[bool] = None,
) -> Any:
    """Stable k-way merge of sorted runs, NaN-safe.  Jit-compatible.

    Args:
      runs: sorted 1-D key arrays of one dtype, sorted in the keyspace
        total order — as produced by ``ops.sort``: NaNs last, -0.0
        strictly before +0.0.  (``jnp.sort`` output qualifies except that
        it leaves -0.0/+0.0 merely grouped, not ordered.)  Ragged
        lengths, empty runs, and k=1 are all fine.
      values: optional per-run payload pytrees (leaf leading dim = run
        length); merged alongside their keys.
      engine: "xla" | "pallas" | "auto"/None — the ``stable_partition``
        seam; both engines are bit-identical.
      tile: merge-path tile for the "pallas" engine.

    Returns merged keys — with ``values``, ``(keys, values)`` — equal to
    the stable sort of the concatenation: ties keep (run, position) order,
    so payload rows are stable whenever each run was stably formed.

    >>> import jax.numpy as jnp
    >>> merge([jnp.asarray([1.0, 3.0]), jnp.asarray([2.0, 4.0])]).tolist()
    [1.0, 2.0, 3.0, 4.0]
    >>> k, v = merge(
    ...     [jnp.asarray([1, 5]), jnp.asarray([1, 9])],
    ...     values=[jnp.asarray([10, 11]), jnp.asarray([12, 13])],
    ... )
    >>> (k.tolist(), v.tolist())  # tie on 1: run 0's payload first
    ([1, 1, 5, 9], [10, 12, 11, 13])
    """
    runs = list(runs)
    if not runs:
        raise ValueError("merge of zero runs")
    if values is not None and len(values) != len(runs):
        raise ValueError(f"{len(runs)} runs but {len(values)} value pytrees")
    dtype = runs[0].dtype
    for r in runs:
        if r.ndim != 1:
            raise ValueError("runs must be 1-D")
        if r.dtype != dtype:
            raise ValueError(f"mixed run dtypes {dtype} vs {r.dtype}")
    n = sum(r.shape[0] for r in runs)
    engine = _resolve_merge_engine(engine, n, dtype)
    items = []
    for i, r in enumerate(runs):
        d = {"k": keyspace.encode(r)}
        if values is not None:
            d["v"] = values[i]
        items.append(d)
    out = merge_runs_encoded(items, engine=engine, tile=tile, interpret=interpret)
    keys = keyspace.decode(out["k"], dtype)
    if values is None:
        return keys
    return keys, out["v"]
