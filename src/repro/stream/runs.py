"""Run formation: chunked, plan-cached IPS4o sorts with overlapped
host->device transfer (DESIGN.md §7.1).

A host-resident (or generator-fed) keyset is split into device-sized
chunks; each chunk is sorted by the existing plan-cached engines
(``ops.plan.PlanCache.get_sorter``), so a streaming job at a fixed chunk
size compiles exactly two sorter shapes (the full chunk and the ragged
tail) and picks up persisted tuned plans.

**Double-buffer protocol**: JAX dispatch is asynchronous, so overlap
falls out of ordering the enqueues — for every chunk i the transfer of
chunk i+1 (``jax.device_put``) is enqueued *before* the sort of chunk i
is dispatched, and no result is blocked on until the consumer (the merge
layer, or ``np.asarray`` at spill time) actually needs it.  On a real
TPU the H2D DMA of chunk i+1 then runs under the sort of chunk i; on the
CPU backend the same code degrades to sequential execution with no extra
copies.
"""
from __future__ import annotations

from typing import Iterable, Iterator, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.ops import plan

__all__ = ["iter_chunks", "form_runs", "form_argsort_runs"]

Source = Union[np.ndarray, Iterable[np.ndarray]]


def iter_chunks(data: Source, chunk_size: int) -> Iterator[np.ndarray]:
    """Normalize a source into host chunk views.

    A 1-D array yields ``chunk_size`` slices (views, no copies; the tail
    may be ragged); any other iterable is treated as generator-fed and
    passed through (each element must be a 1-D array the caller already
    sized to the device).

    >>> import numpy as np
    >>> [c.tolist() for c in iter_chunks(np.arange(5), 2)]
    [[0, 1], [2, 3], [4]]
    """
    if chunk_size <= 0:
        raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    if isinstance(data, np.ndarray):
        if data.ndim != 1:
            raise ValueError("array source must be 1-D")
        for lo in range(0, data.shape[0], chunk_size):
            yield data[lo : lo + chunk_size]
        return
    for chunk in data:
        chunk = np.asarray(chunk)
        if chunk.ndim != 1:
            raise ValueError("generator-fed chunks must be 1-D")
        yield chunk


def _double_buffered(
    data: Source, chunk_size: int, dispatch
) -> List:
    """Drive ``dispatch(device_chunk, offset)`` over all chunks with the
    transfer of chunk i+1 enqueued before chunk i's sort is dispatched."""
    out: List = []
    pending: Optional[Tuple[jax.Array, int]] = None
    offset = 0
    for chunk in iter_chunks(data, chunk_size):
        dev = jax.device_put(jnp.asarray(chunk))  # H2D of chunk i+1 enqueued
        if pending is not None:
            out.append(dispatch(*pending))  # sort of chunk i dispatched under it
        pending = (dev, offset)
        offset += chunk.shape[0]
    if pending is not None:
        out.append(dispatch(*pending))
    return out


def form_runs(
    data: Source,
    chunk_size: int,
    *,
    cache: Optional[plan.PlanCache] = None,
    tune: bool = False,
) -> List[jax.Array]:
    """Sorted device runs, one per chunk, in stream order.

    Each run comes from the plan-cached NaN-safe sort for its chunk's
    (n, dtype); results are *not* blocked on — they are async device
    arrays the merge layer consumes.

    >>> import numpy as np
    >>> [np.asarray(r).tolist() for r in form_runs(np.asarray([3, 1, 2, 0]), 2)]
    [[1, 3], [0, 2]]
    """
    cache = plan.default_cache if cache is None else cache

    def dispatch(dev: jax.Array, offset: int) -> jax.Array:
        return cache.get_sorter(dev.shape[0], dev.dtype, "sort", tune=tune)(dev)

    return _double_buffered(data, chunk_size, dispatch)


def form_argsort_runs(
    data: Source,
    chunk_size: int,
    *,
    cache: Optional[plan.PlanCache] = None,
    tune: bool = False,
) -> List[Tuple[jax.Array, jax.Array]]:
    """(sorted keys, global source indices) device runs, one per chunk.

    The per-chunk argsort is plan-cached; indices are offset into the
    concatenated stream, so merged runs yield a permutation of the whole
    keyset (``external_argsort``).  Tie order within a chunk is the
    engine's deterministic order; across chunks the stable merge keeps
    chunk order.
    """
    cache = plan.default_cache if cache is None else cache

    def dispatch(dev: jax.Array, offset: int) -> Tuple[jax.Array, jax.Array]:
        n = dev.shape[0]
        idx = cache.get_sorter(n, dev.dtype, "argsort", tune=tune)(dev)
        return jnp.take(dev, idx, axis=0), idx + jnp.int32(offset)

    return _double_buffered(data, chunk_size, dispatch)
