"""repro.stream — out-of-core streaming sort (DESIGN.md §7).

The journal version of the paper ("Engineering In-place (Shared-memory)
Sorting Algorithms") formalizes the decomposition this package implements:
IPS4o as the *run-forming* engine over device-sized chunks, plus a k-way
merge as the recombination primitive.  Three layers:

  runs.py   chunk a host-resident (or generator-fed) keyset, sort each
            chunk with the plan-cached IPS4o engines, double-buffering
            host->device transfers against the previous chunk's sort;
  merge.py  stable k-way merge of sorted runs: a tournament of pairwise
            merges, each a branchless merge-path pass
            (``kernels/merge_path.py`` on the "pallas" engine, a
            two-searchsorted rank merge on "xla" — same engine seam as
            ``stable_partition``);
  api.py    the streaming entry points: ``external_sort``,
            ``external_argsort``, ``streaming_topk``,
            ``streaming_group_by`` — host-orchestrated pipelines whose
            device footprint is bounded by the chunk / pair being
            processed, not the dataset.

Production call sites: ``data.pipeline.pack_by_length`` (out-of-core
length argsort for shard sets larger than device memory) and
``serve.scheduler`` (admission from a merged view of persisted + live
queues).
"""
from repro.stream.api import (
    external_argsort,
    external_sort,
    streaming_group_by,
    streaming_topk,
)
from repro.stream.merge import merge, merge_perm
from repro.stream.runs import form_argsort_runs, form_runs, iter_chunks

__all__ = [
    "external_sort",
    "external_argsort",
    "merge",
    "merge_perm",
    "streaming_topk",
    "streaming_group_by",
    "form_runs",
    "form_argsort_runs",
    "iter_chunks",
]
