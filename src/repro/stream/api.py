"""Streaming entry points: out-of-core sorts over host-resident data.

Host-orchestrated pipelines over the run-formation (``stream.runs``) and
merge (``stream.merge``) layers.  The common shape:

  1. chunks stream host -> device under double buffering and come back as
     sorted runs (device arrays, results not blocked on);
  2. runs reduce through the pairwise merge tournament; between rounds the
     merged results **spill to host** (``np.asarray``), so the device
     footprint at any instant is one pair being merged — never the whole
     dataset plus intermediates;
  3. the merge geometry (engine + merge-path tile) comes from the plan
     cache's ``stream:`` key family (chunk size x fan-in; DESIGN.md §5.4),
     tuned once per machine with ``tune=True``.

``streaming_topk`` and ``streaming_group_by`` never materialize the
stream at all: they carry a bounded candidate / distinct-key buffer and
refine it per chunk with the ops-layer primitives (``bottomk``/``topk``,
``unique``) plus one 2-way merge.

With ``repro.obs`` enabled, the tournament reports itself: per-round
``stream.merge_round`` spans under a ``stream.external_sort`` /
``stream.external_argsort`` root, the host spill volume as a
``stream.spill_bytes`` counter, and round / chunk counts
(``stream.tournament_rounds``, ``stream.chunks``) — DESIGN.md §12.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.ops import keyspace, plan
from repro.stream.merge import merge
from repro.stream.runs import Source, form_argsort_runs, form_runs, iter_chunks

__all__ = [
    "external_sort",
    "external_argsort",
    "streaming_topk",
    "streaming_group_by",
]

# jitted per-shape closures for the host-orchestrated loops (each distinct
# (shapes, static args) signature compiles once per process)
_JIT: Dict[tuple, Callable] = {}


def _jitted(key: tuple, build: Callable[[], Callable]) -> Callable:
    f = _JIT.get(key)
    if f is None:
        f = _JIT[key] = jax.jit(build())
    return f


def _encode_runs(runs):
    """Biject each run into the ordered-uint keyspace ONCE, before the
    tournament: ``keyspace.encode`` is the identity on unsigned ints, so
    every subsequent ``merge`` round is bijection-free — 2 encode/decode
    passes total instead of 2 per round."""
    out = []
    for r in runs:
        f = _jitted(("encode", r.shape, str(r.dtype)), lambda: keyspace.encode)
        out.append(f(jnp.asarray(r)))
    return out


def _decode(u, dtype):
    f = _jitted(("decode", u.shape, str(jnp.dtype(dtype))),
                lambda: lambda enc: keyspace.decode(enc, dtype))
    return np.asarray(f(jnp.asarray(u)))


def _spill(a):
    """Device -> host spill with the byte volume counted (obs off: free)."""
    out = np.asarray(a)
    obs.count("stream.spill_bytes", out.nbytes)
    return out


def _merge_pass(runs, cfg, payloads=None):
    """One tournament round over host-resident runs: merge adjacent pairs
    on device, spill each result back to host."""
    out_k, out_v = [], []
    for i in range(0, len(runs) - 1, 2):
        a, b = jnp.asarray(runs[i]), jnp.asarray(runs[i + 1])
        key = ("merge2", a.shape, b.shape, str(a.dtype),
               cfg.engine, cfg.merge_tile, payloads is not None)
        if payloads is None:
            f = _jitted(key, lambda: lambda x, y: merge(
                [x, y], engine=cfg.engine, tile=cfg.merge_tile))
            out_k.append(_spill(f(a, b)))
        else:
            f = _jitted(key, lambda: lambda x, y, vx, vy: merge(
                [x, y], values=[vx, vy],
                engine=cfg.engine, tile=cfg.merge_tile))
            k, v = f(a, b, jnp.asarray(payloads[i]), jnp.asarray(payloads[i + 1]))
            out_k.append(_spill(k))
            out_v.append(_spill(v))
    if len(runs) % 2:
        # the odd run out rides along untouched: not a spill, no new bytes
        out_k.append(np.asarray(runs[-1]))
        if payloads is not None:
            out_v.append(np.asarray(payloads[-1]))
    return (out_k, out_v) if payloads is not None else (out_k, None)


def external_sort(
    data: Source,
    *,
    chunk_size: int = 1 << 16,
    engine: Optional[str] = None,
    cache: Optional[plan.PlanCache] = None,
    tune: bool = False,
) -> np.ndarray:
    """Sort a host-resident (or generator-fed) keyset larger than one
    device allocation: IPS4o run formation + merge tournament with host
    spill between rounds.

    Value-identical to ``ops.sort`` of the concatenated stream — the
    keyspace total order: NaNs last, -0.0 strictly before +0.0 (equal to
    ``jnp.sort`` under ``==``; ``jnp.sort`` leaves -0.0/+0.0 grouped but
    unordered).  ``engine`` overrides the merge engine; ``tune=True``
    autotunes (and persists) the ``stream:`` plan for this chunk size x
    fan-in.

    >>> import numpy as np
    >>> external_sort(np.asarray([5, 1, 4, 2, 3], np.int32), chunk_size=2).tolist()
    [1, 2, 3, 4, 5]
    """
    cache = plan.default_cache if cache is None else cache
    runs = form_runs(data, chunk_size, cache=cache, tune=tune)
    if not runs:
        return np.zeros((0,), np.asarray(data).dtype if isinstance(data, np.ndarray) else np.float32)
    dtype = runs[0].dtype
    cfg = cache.stream_plan(chunk_size, len(runs), dtype, tune=tune, engine=engine)
    with obs.trace("stream.external_sort", chunks=len(runs),
                   chunk_size=chunk_size, engine=cfg.engine):
        level = _encode_runs(runs)  # device arrays round 0; host after each spill
        rounds = 0
        while len(level) > 1:
            with obs.trace("stream.merge_round", fanin=len(level)):
                level, _ = _merge_pass(level, cfg)
            rounds += 1
        obs.count("stream.tournament_rounds", rounds)
        return _decode(level[0], dtype)


def external_argsort(
    data: Source,
    *,
    chunk_size: int = 1 << 16,
    engine: Optional[str] = None,
    cache: Optional[plan.PlanCache] = None,
    tune: bool = False,
) -> np.ndarray:
    """Indices (int32, into the concatenated stream) that sort it.

    ``keys[idx]`` equals ``external_sort(keys)``; ties across chunk
    boundaries keep chunk order (the merge is stable), ties within a
    chunk are in the engine's deterministic argsort order.

    >>> import numpy as np
    >>> external_argsort(np.asarray([30, 10, 40, 20], np.int32), chunk_size=2).tolist()
    [1, 3, 0, 2]
    """
    cache = plan.default_cache if cache is None else cache
    pairs = form_argsort_runs(data, chunk_size, cache=cache, tune=tune)
    if not pairs:
        return np.zeros((0,), np.int32)
    cfg = cache.stream_plan(chunk_size, len(pairs), pairs[0][0].dtype,
                            tune=tune, engine=engine)
    with obs.trace("stream.external_argsort", chunks=len(pairs),
                   chunk_size=chunk_size, engine=cfg.engine):
        keys = _encode_runs([k for k, _ in pairs])  # only indices come back out
        idxs = [i for _, i in pairs]
        rounds = 0
        while len(keys) > 1:
            with obs.trace("stream.merge_round", fanin=len(keys)):
                keys, idxs = _merge_pass(keys, cfg, idxs)
            rounds += 1
        obs.count("stream.tournament_rounds", rounds)
        return np.asarray(idxs[0])


def streaming_topk(
    data: Source,
    k: int,
    *,
    chunk_size: int = 1 << 16,
    largest: bool = True,
    cache: Optional[plan.PlanCache] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Top-k (or bottom-k) of a stream with a bounded candidate buffer.

    Per chunk: the plan-cached rank-k partial sort (``ops.topk`` /
    ``ops.bottomk`` — only the rank-covering prefix is ever base-case
    sorted) yields that chunk's candidates; one stable 2-way merge against
    the k-entry running buffer refines it.  The buffer lives in the
    *ascending encoded* keyspace (complemented for ``largest=True``), so
    one uint merge serves both directions.  Device footprint: one chunk
    plus 2k candidates, independent of stream length.

    Returns (values, global int32 indices), values in rank order
    (descending for ``largest=True`` — the ``lax.top_k`` convention);
    ties prefer earlier chunks.

    >>> import numpy as np
    >>> v, i = streaming_topk(np.asarray([1.0, 9.0, 3.0, 7.0], np.float32), 2,
    ...                       chunk_size=2)
    >>> (v.tolist(), i.tolist())
    ([9.0, 7.0], [1, 3])
    """
    cache = plan.default_cache if cache is None else cache
    op = "topk" if largest else "bottomk"
    buf_u = buf_i = None  # encoded-ascending candidates + global indices
    key_dtype = None
    offset = 0
    with obs.trace("stream.topk", k=k, chunk_size=chunk_size, largest=largest):
        for chunk in iter_chunks(data, chunk_size):
            n = chunk.shape[0]
            if n == 0:
                continue
            obs.count("stream.chunks", op="topk")
            dev = jax.device_put(jnp.asarray(chunk))
            key_dtype = dev.dtype
            vals, idx = cache.get_sorter(n, dev.dtype, op, k=min(k, n))(dev)
            enc = _jitted(("enc", vals.shape, str(dev.dtype), largest), lambda: (
                (lambda v: ~keyspace.encode(v)) if largest else keyspace.encode))
            u, gi = enc(vals), idx + jnp.int32(offset)
            if buf_u is None:
                buf_u, buf_i = u[:k], gi[:k]
            else:
                mkey = ("topk-merge", buf_u.shape, u.shape, str(u.dtype), k)
                f = _jitted(mkey, lambda: lambda a, b, ia, ib: tuple(
                    x[:k] for x in merge([a, b], values=[ia, ib])))
                buf_u, buf_i = f(buf_u, u, buf_i, gi)
            offset += n
        if buf_u is None:
            raise ValueError("streaming_topk over an empty stream")
        dec = _jitted(("dec", buf_u.shape, str(key_dtype), largest), lambda: (
            (lambda u: keyspace.decode(~u, key_dtype)) if largest
            else (lambda u: keyspace.decode(u, key_dtype))))
        return np.asarray(dec(buf_u)), np.asarray(buf_i)


def streaming_group_by(
    data: Source,
    *,
    chunk_size: int = 1 << 16,
    cache: Optional[plan.PlanCache] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Global (distinct keys ascending, counts) over a stream: per-chunk
    ``ops.unique`` runs merge-joined into a bounded distinct-key buffer.

    Each chunk contributes its sorted (unique values, counts) run; the
    running buffer absorbs it with one stable 2-way merge followed by a
    host-side join of equal adjacent keys (keys are compared in the
    encoded keyspace, so NaN forms a single class and -0.0 / +0.0 stay
    distinct — the ``ops.unique`` semantics, stream-scaled).  The buffer
    is bounded by the number of distinct keys, not the stream length.

    >>> import numpy as np
    >>> vals, counts = streaming_group_by(
    ...     np.asarray([3, 1, 3, 1, 1, 3], np.int32), chunk_size=2)
    >>> (vals.tolist(), counts.tolist())
    ([1, 3], [3, 3])
    """
    from repro.ops import unique  # lazy: ops layers under stream

    cache = plan.default_cache if cache is None else cache
    buf_u = buf_c = None  # np: encoded distinct keys (asc) + int64 counts
    key_dtype = None
    for chunk in iter_chunks(data, chunk_size):
        n = chunk.shape[0]
        if n == 0:
            continue
        obs.count("stream.chunks", op="group_by")
        dev = jax.device_put(jnp.asarray(chunk))
        key_dtype = dev.dtype
        f = _jitted(("unique", dev.shape, str(dev.dtype)), lambda: (
            lambda x: unique(x)))
        vals, counts, num = f(dev)
        nu = int(num)
        cu = np.asarray(keyspace.encode(vals))[:nu]
        cc = np.asarray(counts)[:nu].astype(np.int64)
        if buf_u is None:
            buf_u, buf_c = cu, cc
            continue
        mkey = ("gb-merge", buf_u.shape, cu.shape, str(cu.dtype))
        g = _jitted(mkey, lambda: lambda a, b, ca, cb: merge(
            [a, b], values=[ca, cb]))
        mk, mc = g(jnp.asarray(buf_u), jnp.asarray(cu),
                   jnp.asarray(buf_c), jnp.asarray(cc))
        mk, mc = np.asarray(mk), np.asarray(mc)
        head = np.concatenate([[True], mk[1:] != mk[:-1]])  # run starts
        gid = np.cumsum(head) - 1
        buf_u = mk[head]
        buf_c = np.bincount(gid, weights=mc).astype(np.int64)
    if buf_u is None:
        raise ValueError("streaming_group_by over an empty stream")
    dec = jnp.asarray(buf_u)
    vals = np.asarray(keyspace.decode(dec, key_dtype))
    return vals, buf_c
