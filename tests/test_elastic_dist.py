"""Fault-injection suite for the elastic distributed sort (DESIGN.md §13.3).

Two tiers, like tests/test_dist.py:

  * tier-1 (always runs): the degenerate d == 1 checkpoint/restore cycle,
    kill-and-restore on the 1-device mesh, the parameter-fingerprint
    guard, and the finished-directory replay;
  * the CI ``distributed`` job (8 virtual devices) runs the acceptance
    matrix: kill-and-restore at EVERY level boundary of the 2-axis mesh,
    a restore landing at the boundary before the re-split-retry-engaging
    level (the retry protocol is atomic within one level's jit, so "mid
    retry" means the whole observed-histogram retry runs post-restore),
    and the overlap + payload + async-save combination — every case
    asserting BIT-identical output to the uninterrupted monolithic
    ``dist.sort``.

Bit-identity uses uint32 views throughout: float sentinel tails decode to
NaN, and NaN != NaN under plain array comparison.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import dist
from repro.checkpoint import CheckpointManager
from repro.core.ips4o import SortConfig
from repro.data.distributions import make_input

_CFG = SortConfig(base_case=2048, kmax=32, tile=512, max_sample=2048)
_N = 1 << 15

needs_8 = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 (virtual) devices — CI mesh job"
)


def _put(mesh, axes, x):
    spec = P(axes if isinstance(axes, str) else tuple(axes))
    return jax.device_put(jnp.asarray(x), NamedSharding(mesh, spec))


def _bits(a):
    a = np.asarray(a)
    return a.view(np.uint32) if a.dtype.kind == "f" else a


def _assert_same(got, want):
    assert len(got) == len(want)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(_bits(g), _bits(w))


# -- tier-1: the degenerate mesh --------------------------------------------


def test_d1_restore_cycle(tmp_path):
    mesh = jax.make_mesh((1,), ("data",))
    x = make_input("Uniform", 512, np.float32, seed=13)
    xs = _put(mesh, "data", x)
    ref = dist.sort(xs, mesh, "data", cfg=_CFG)
    ck = CheckpointManager(str(tmp_path / "ck"))
    got = dist.sort_elastic(xs, mesh, "data", manager=ck, cfg=_CFG)
    _assert_same(got, ref)
    assert ck.latest_step() == 1  # boundaries: init + the single level
    # a finished directory replays the finish only — same output again
    again = dist.sort_elastic(xs, mesh, "data", manager=ck, cfg=_CFG)
    _assert_same(again, ref)


def test_d1_kill_and_restore(tmp_path):
    mesh = jax.make_mesh((1,), ("data",))
    x = make_input("Exponential", 512, np.float32, seed=3)
    xs = _put(mesh, "data", x)
    ref = dist.sort(xs, mesh, "data", cfg=_CFG)
    ck = CheckpointManager(str(tmp_path / "ck"))
    with pytest.raises(RuntimeError, match="injected shard loss"):
        dist.sort_elastic(
            xs, mesh, "data", manager=ck, cfg=_CFG, _fail_at_step=0
        )
    assert ck.latest_step() == 0
    got = dist.sort_elastic(xs, mesh, "data", manager=ck, cfg=_CFG)
    _assert_same(got, ref)


def test_fingerprint_guard(tmp_path):
    # a checkpoint from a DIFFERENT sort configuration must refuse to
    # resume rather than silently continue someone else's job
    mesh = jax.make_mesh((1,), ("data",))
    xs = _put(mesh, "data", make_input("Uniform", 512, np.float32, seed=13))
    ck = CheckpointManager(str(tmp_path / "ck"))
    with pytest.raises(RuntimeError):
        dist.sort_elastic(
            xs, mesh, "data", manager=ck, cfg=_CFG, _fail_at_step=0
        )
    with pytest.raises(ValueError, match="fingerprint"):
        dist.sort_elastic(xs, mesh, "data", manager=ck, cfg=_CFG, slack=3.0)


# -- d = 8: the acceptance matrix (CI `distributed` job) --------------------


@needs_8
def test_elastic_matches_monolithic(tmp_path):
    """Uninterrupted elastic == monolithic, keys and payload, both mesh
    shapes — the state-machine decomposition cannot drift from the
    single-jit pipeline it re-expresses."""
    for mesh, axes in [
        (jax.make_mesh((8,), ("data",)), "data"),
        (jax.make_mesh((2, 4), ("pod", "data")), ("pod", "data")),
    ]:
        x = make_input("Exponential", _N, np.float32, seed=42)
        xs = _put(mesh, axes, x)
        ref = dist.sort(xs, mesh, axes, cfg=_CFG)
        ck = CheckpointManager(str(tmp_path / f"ck{len(axes)}"), keep=8)
        got = dist.sort_elastic(xs, mesh, axes, manager=ck, cfg=_CFG)
        _assert_same(got, ref)


@needs_8
@pytest.mark.parametrize("boundary", [0, 1, 2])
def test_kill_and_restore_every_boundary(tmp_path, boundary):
    """Shard loss right after boundary 0 (pre-exchange), 1 (pod level) or
    2 (data level) of the 2-axis mesh: a fresh manager instance over the
    same directory resumes from the last committed boundary and the final
    output is bit-identical to the uninterrupted sort."""
    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    axes = ("pod", "data")
    x = make_input("Exponential", _N, np.float32, seed=42)
    xs = _put(mesh, axes, x)
    ref = dist.sort(xs, mesh, axes, cfg=_CFG)
    ckdir = str(tmp_path / "ck")
    with pytest.raises(RuntimeError, match="injected shard loss"):
        dist.sort_elastic(
            xs, mesh, axes,
            manager=CheckpointManager(ckdir, keep=8), cfg=_CFG,
            _fail_at_step=boundary,
        )
    survivor = CheckpointManager(ckdir, keep=8)  # the restarted process
    assert survivor.latest_step() == boundary
    got = dist.sort_elastic(xs, mesh, axes, manager=survivor, cfg=_CFG)
    _assert_same(got, ref)


@needs_8
def test_restore_lands_mid_resplit_retry(tmp_path):
    """The converging-retry config of test_resplit_retry_converges (round
    0 genuinely overflows; the observed-histogram re-split fixes it):
    killing at boundary 0 makes the ENTIRE retry-engaging level — sample,
    overflow verdict, re-split rounds — run after resume.  The level RNG
    folds (seed, level_idx, round), never wall-clock history, so the
    resumed retry draws the same samples and the output stays
    bit-identical."""
    x = make_input("Exponential", 1 << 16, np.float32, seed=42)
    mesh = jax.make_mesh((8,), ("data",))
    xs = _put(mesh, "data", x)
    kw = dict(cfg=_CFG, slack=1.25, oversample=8, retries=2)
    ref = dist.sort(xs, mesh, "data", **kw)
    assert not np.asarray(ref[2]).any(), "retry must converge uninterrupted"
    ckdir = str(tmp_path / "ck")
    with pytest.raises(RuntimeError, match="injected shard loss"):
        dist.sort_elastic(
            xs, mesh, "data",
            manager=CheckpointManager(ckdir, keep=8), _fail_at_step=0, **kw
        )
    got = dist.sort_elastic(
        xs, mesh, "data", manager=CheckpointManager(ckdir, keep=8), **kw
    )
    assert not np.asarray(got[2]).any(), "resumed retry failed to converge"
    _assert_same(got, ref)


@needs_8
def test_overlap_payload_async_saves_restore(tmp_path):
    """The full composition: overlap-scheduled exchange, integer payload
    riding the half-shard frames, async (non-blocking) checkpoint writes,
    shard loss after the last level boundary — restored output
    bit-identical to the monolithic overlap sort."""
    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    axes = ("pod", "data")
    x = make_input("TwoDup", _N, np.int32, seed=7)
    xs = _put(mesh, axes, x)
    vs = _put(mesh, axes, np.arange(_N, dtype=np.int32))
    ref = dist.sort(xs, mesh, axes, values=vs, cfg=_CFG, overlap=True)
    ckdir = str(tmp_path / "ck")
    with pytest.raises(RuntimeError, match="injected shard loss"):
        dist.sort_elastic(
            xs, mesh, axes, manager=CheckpointManager(ckdir, keep=8),
            values=vs, cfg=_CFG, overlap=True, blocking_saves=False,
            _fail_at_step=2,
        )
    got = dist.sort_elastic(
        xs, mesh, axes, manager=CheckpointManager(ckdir, keep=8),
        values=vs, cfg=_CFG, overlap=True, blocking_saves=False,
    )
    _assert_same(got, ref)
