"""Batched-engine parity suite (DESIGN.md §6).

The contract: ``batched_sort(X)[i]`` is bit-identical to ``sort(X[i])``
for every row, across all nine paper input distributions x {f32, i32} x
both partition engines; B=1 equals unbatched; the batch-grid kernels
match their unbatched counterparts row-for-row; ragged batch shapes
round-trip through the plan cache under distinct (op, B, n, dtype) keys;
and pre-batch plan schemas load (migrated) instead of being discarded.
"""
import json
from dataclasses import replace

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import ops
from repro.core.ips4o import SortConfig, plan_levels
from repro.data.distributions import DISTRIBUTIONS, make_input

# one-level path with per-row pads (n=5000 -> n_pad=6144, k=32)
_cfg = SortConfig(base_case=1024, kmax=32, tile=256, max_sample=256, slack=4)
_N = 5000
_B = 4


def _rows(dist, n, dtype, nrows=_B):
    return np.stack([make_input(dist, n, dtype, seed=s) for s in range(nrows)])


# ---------------------------------------------------------------- tentpole
@pytest.mark.parametrize("dist", sorted(DISTRIBUTIONS))
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
@pytest.mark.parametrize("engine", ["xla", "pallas"])
def test_batched_sort_parity_distributions(dist, dtype, engine):
    """batched_sort(x)[i] == sort(x[i]) bit-identical, every distribution."""
    x = _rows(dist, _N, dtype)
    out = np.asarray(ops.batched_sort(jnp.asarray(x), cfg=_cfg, engine=engine))
    for i in range(_B):
        ref = np.asarray(ops.sort(jnp.asarray(x[i]), cfg=_cfg, engine=engine))
        np.testing.assert_array_equal(out[i], ref)
        np.testing.assert_array_equal(out[i], np.sort(x[i]))


@pytest.mark.parametrize("engine", ["xla", "pallas"])
def test_batched_two_level_parity(engine):
    """Rows long enough for the per-row segmented second level."""
    n = 20000
    assert len(plan_levels(20480, _cfg)) == 2
    x = _rows("TwoDup", n, np.int32, nrows=3)
    out = np.asarray(ops.batched_sort(jnp.asarray(x), cfg=_cfg, engine=engine))
    np.testing.assert_array_equal(out, np.sort(x, axis=1))
    for i in range(3):
        ref = np.asarray(ops.sort(jnp.asarray(x[i]), cfg=_cfg, engine=engine))
        np.testing.assert_array_equal(out[i], ref)


def test_batched_b1_equals_unbatched():
    """The degenerate batch is exactly the unbatched op."""
    x = make_input("Exponential", _N, np.float32, seed=2)
    for engine in ("xla", "pallas"):
        b1 = np.asarray(
            ops.batched_sort(jnp.asarray(x[None, :]), cfg=_cfg, engine=engine)
        )
        ref = np.asarray(ops.sort(jnp.asarray(x), cfg=_cfg, engine=engine))
        np.testing.assert_array_equal(b1[0], ref)


def test_batched_payload_and_argsort():
    x = _rows("TwoDup", _N, np.float32)
    v = jnp.broadcast_to(jnp.arange(_N, dtype=jnp.int32)[None, :], (_B, _N))
    for engine in ("xla", "pallas"):
        k2, v2 = ops.batched_sort(jnp.asarray(x), v, cfg=_cfg, engine=engine)
        np.testing.assert_array_equal(
            np.take_along_axis(x, np.asarray(v2), axis=1), np.asarray(k2)
        )
        order = np.asarray(ops.batched_argsort(jnp.asarray(x), cfg=_cfg, engine=engine))
        np.testing.assert_array_equal(
            np.take_along_axis(x, order, axis=1), np.sort(x, axis=1)
        )


@pytest.mark.parametrize("engine", ["xla", "pallas"])
def test_batched_topk_bottomk(engine):
    x = _rows("Uniform", _N, np.float32)
    v, i = ops.batched_bottomk(jnp.asarray(x), 37, cfg=_cfg, engine=engine)
    np.testing.assert_array_equal(np.asarray(v), np.sort(x, axis=1)[:, :37])
    np.testing.assert_array_equal(
        np.take_along_axis(x, np.asarray(i), axis=1), np.asarray(v)
    )
    v, i = ops.batched_topk(jnp.asarray(x), 12, cfg=_cfg, engine=engine)
    np.testing.assert_array_equal(np.asarray(v), -np.sort(-x, axis=1)[:, :12])
    np.testing.assert_array_equal(
        np.take_along_axis(x, np.asarray(i), axis=1), np.asarray(v)
    )
    # per-row parity with the unbatched partial sort
    vu, iu = ops.bottomk(jnp.asarray(x[0]), 37, cfg=_cfg, engine=engine)
    vb, _ = ops.batched_bottomk(jnp.asarray(x), 37, cfg=_cfg, engine=engine)
    np.testing.assert_array_equal(np.asarray(vb[0]), np.asarray(vu))


def test_batched_nan_and_negzero():
    """Keyspace semantics hold per row: NaNs last, -0.0 before +0.0."""
    x = np.asarray(
        [[np.nan, 1.0, -0.0, 0.0, -1.0], [2.0, np.nan, np.nan, -2.0, 0.0]],
        np.float32,
    )
    out = np.asarray(ops.batched_sort(jnp.asarray(x)))
    np.testing.assert_array_equal(out[0], np.asarray([-1.0, -0.0, 0.0, 1.0, np.nan], np.float32))
    assert np.signbit(out[0][1]) and not np.signbit(out[0][2])
    np.testing.assert_array_equal(out[1][:3], np.asarray([-2.0, 0.0, 2.0], np.float32))
    assert np.all(np.isnan(out[1][3:]))


# ---------------------------------------------------------- batched kernels
def test_batched_classify_kernel_matches_unbatched():
    from repro.kernels.classify import classify_histogram, classify_histogram_batched

    rng = np.random.default_rng(0)
    B, n, k = 3, 2048, 16
    keys = jnp.asarray(rng.standard_normal((B, n)), jnp.float32)
    spl = jnp.sort(jnp.asarray(rng.standard_normal((B, k - 1)), jnp.float32), axis=1)
    b, hist = classify_histogram_batched(keys, spl, k=k, rows=8)
    for i in range(B):
        bi, hi = classify_histogram(keys[i], spl[i], k=k, rows=8)
        np.testing.assert_array_equal(np.asarray(b[i]), np.asarray(bi))
        np.testing.assert_array_equal(np.asarray(hist[i]), np.asarray(hi))


def test_batched_rank_kernel_matches_unbatched():
    from repro.kernels.dispatch_rank import partition_ranks, partition_ranks_batched

    rng = np.random.default_rng(1)
    B, n, nb = 4, 3000, 21  # n not tile-aligned: exercises the pad path
    bkt = jnp.asarray(rng.integers(0, nb, (B, n)), jnp.int32)
    totals = jax.vmap(lambda r: jnp.bincount(r, length=nb))(bkt)
    start = (jnp.cumsum(totals, axis=1) - totals).astype(jnp.int32)
    dest = partition_ranks_batched(bkt, start, nb=nb)
    for i in range(B):
        ref = partition_ranks(bkt[i], start[i], nb=nb)
        np.testing.assert_array_equal(np.asarray(dest[i]), np.asarray(ref))
        assert len(set(np.asarray(dest[i]).tolist())) == n  # per-row permutation


# ------------------------------------------------------------- plan cache
def test_plan_cache_ragged_batch_roundtrip(tmp_path):
    """Ragged batch shapes get distinct plans; persisted plans reload."""
    path = str(tmp_path / "plans.json")
    pc = ops.PlanCache(path=path)
    rng = np.random.default_rng(0)
    x3 = jnp.asarray(rng.standard_normal((3, 4096)), jnp.float32)
    for b in (2, 3):
        f = pc.get_sorter(4096, jnp.float32, "sort", batch=b)
        out = np.asarray(f(x3[:b]))
        np.testing.assert_array_equal(out, np.sort(np.asarray(x3[:b]), axis=1))
    assert pc._key("sort", 4096, jnp.float32, None, 2) != pc._key(
        "sort", 4096, jnp.float32, None, 3
    )
    # tuned batched plan persists under the B= key and reloads
    pc.get_sorter(2048, jnp.float32, "sort", batch=4, tune=True)
    key = pc._key("sort", 2048, jnp.float32, None, 4)
    assert key in pc._plans and key.startswith("sort:B=4:")
    pc2 = ops.PlanCache(path=path)
    assert pc2.config_for("sort", 2048, jnp.float32, batch=4) == SortConfig(
        **pc._plans[key]["config"]
    )
    # batched "auto" falls back to the unbatched row-shape plan's engine
    pc2._plans[pc2._key("sort", 512, jnp.float32, None)] = {
        "engine": "pallas", "config": {}
    }
    assert pc2.engine_hint(512, jnp.float32, batch=7) == "pallas"
    # and an unbatched lookup never sees a batched plan
    assert pc2.engine_hint(2048, jnp.float32) is None


def test_plan_cache_pre_batch_schema_migrates(tmp_path):
    """Plan entries written by a pre-batch schema (unknown config fields)
    load with their tuned geometry — migrated, not discarded — and the
    migrated form is what the next save persists."""
    path = str(tmp_path / "plans.json")
    stale = {
        "sort:n=4096:dtype=float32": {
            "config": {"base_case": 2048, "kmax": 64, "tile": 1024,
                       "max_sample": 4096, "slack": 4, "seed": 1,
                       "fallback": True, "engine": "xla",
                       "batch": 1, "rows_per_block": 8},  # pre-batch extras
            "engine": "xla",
            "us": 2.0,
        },
        "sort:n=2048:dtype=float32": {
            "config": {"window": 9999},  # fully foreign -> defaults still
            "us": 3.0,
        },
        "sort:n=1024:dtype=float32": "xla",  # not even a dict -> defaults
        "sort:n=512:dtype=float32": {
            "config": {"tile": "big", "base_case": 2048},  # wrong value kind
            "us": 1.0,
        },
    }
    with open(path, "w") as fh:
        json.dump(stale, fh)
    pc = ops.PlanCache(path=path)
    cfg = pc.config_for("sort", 4096, jnp.float32)
    assert cfg.base_case == 2048 and cfg.kmax == 64  # tuned geometry kept
    assert "batch" not in pc._plans["sort:n=4096:dtype=float32"]["config"]
    assert pc.config_for("sort", 2048, jnp.float32) == SortConfig()
    assert pc.config_for("sort", 1024, jnp.float32) == SortConfig()
    assert pc.engine_hint(1024, jnp.float32) is None
    assert pc.engine_hint(1024, jnp.float32, batch=2) is None
    # mis-typed field dropped, well-typed sibling still loads
    assert pc.config_for("sort", 512, jnp.float32) == SortConfig(base_case=2048)
    pc._save()
    with open(path) as fh:
        saved = json.load(fh)
    assert "rows_per_block" not in saved["sort:n=4096:dtype=float32"]["config"]


# -------------------------------------------------------------- rewired callers
def test_scheduler_admit_many_matches_unbatched():
    import copy

    from repro.serve.scheduler import Request, Scheduler, admit_many

    rng = np.random.default_rng(3)
    scheds = []
    for s in range(5):
        sc = Scheduler(batch_size=int(rng.integers(1, 5)))
        for u in range(int(rng.integers(0, 20))):
            sc.submit(Request(uid=s * 1000 + u, prompt_len=4,
                              max_new=int(rng.integers(1, 40))))
        scheds.append(sc)
    ref = [copy.deepcopy(s) for s in scheds]
    got = admit_many(scheds)
    for i, s in enumerate(ref):
        exp = s.next_batch()
        assert [r.uid for r in got[i]] == [r.uid for r in exp]
        assert [r.uid for r in scheds[i].queue] == [r.uid for r in s.queue]
    assert admit_many([Scheduler(batch_size=2)]) == [[]]


def test_pack_by_length_batched_matches_per_shard():
    from repro.data.pipeline import pack_by_length

    rng = np.random.default_rng(4)
    lengths = rng.integers(1, 64, (3, 257)).astype(np.int32)
    batched = pack_by_length(lengths, 128)
    assert len(batched) == 3
    for s in range(3):
        r1, o1, nr1 = pack_by_length(lengths[s], 128)
        r2, o2, nr2 = batched[s]
        np.testing.assert_array_equal(r1, r2)
        np.testing.assert_array_equal(o1, o2)
        assert nr1 == nr2


def test_moe_sort_dispatch_batched_matches_per_layer():
    from repro.models.moe import expert_capacity, sort_dispatch

    rng = np.random.default_rng(5)
    E, k, n, L = 8, 2, 1024, 4
    cap = expert_capacity(n, E, k, 1.25)
    fe = jnp.asarray(rng.integers(0, E, (L, n * k)).astype(np.int32))
    slot, kept, counts = sort_dispatch(fe, E, cap)
    assert slot.shape == (L, n * k) and counts.shape == (L, E)
    for l in range(L):
        s1, k1, c1 = sort_dispatch(fe[l], E, cap)
        np.testing.assert_array_equal(np.asarray(slot[l]), np.asarray(s1))
        np.testing.assert_array_equal(np.asarray(kept[l]), np.asarray(k1))
        np.testing.assert_array_equal(np.asarray(counts[l]), np.asarray(c1))


# ------------------------------------------------------------------ shape guards
def test_batched_rejects_1d():
    x = jnp.zeros((8,), jnp.float32)
    for fn in (ops.batched_sort, ops.batched_argsort):
        with pytest.raises(ValueError, match="2-D"):
            fn(x)
    for fn in (ops.batched_topk, ops.batched_bottomk):
        with pytest.raises(ValueError, match="2-D"):
            fn(x, 2)


def test_batched_trivial_shapes():
    x = jnp.asarray([[5.0], [3.0]])
    np.testing.assert_array_equal(np.asarray(ops.batched_sort(x)), np.asarray(x))
    v, i = ops.batched_topk(x, 0)
    assert v.shape == (2, 0) and i.shape == (2, 0)
    # engine threading: explicit cfg engine + per-call override agree
    y = _rows("Ones", 2048, np.float32, nrows=2)
    a = np.asarray(ops.batched_sort(jnp.asarray(y), cfg=replace(_cfg, engine="pallas")))
    b = np.asarray(ops.batched_sort(jnp.asarray(y), cfg=_cfg, engine="pallas"))
    np.testing.assert_array_equal(a, b)
