"""The §Perf optimization paths must match their baselines exactly.

  * JAX KV-chunked flash attention  == eager SDPA           (models/attention)
  * Pallas fused flash kernel       == jnp oracle           (kernels/flash_attention)
  * shard_map explicit-EP MoE       == GSPMD-lowered MoE    (models/moe), fwd + grad
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import flash_attention_ref
from repro.models.attention import _causal_mask, _sdpa, _sdpa_flash
from repro.models.policy import compute_policy, current_policy


@pytest.mark.parametrize("b,s,h,kvh,hd,window,block", [
    (2, 128, 8, 4, 32, 0, 32),
    (1, 96, 6, 2, 16, 40, 32),
    (2, 64, 4, 4, 32, 0, 64),
    (1, 256, 4, 1, 64, 0, 128),
])
def test_flash_jax_matches_eager(b, s, h, kvh, hd, window, block):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kvh, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kvh, hd)), jnp.float32)
    ref = _sdpa(q, k, v, _causal_mask(s, s, 0, window))
    out = _sdpa_flash(q, k, v, 0, window, block)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("b,h,s,hd,window,bq,bk", [
    (2, 4, 512, 64, 0, 128, 128),
    (1, 2, 1024, 128, 0, 256, 256),
    (1, 2, 512, 64, 200, 128, 128),
])
def test_flash_pallas_matches_ref(b, h, s, hd, window, bq, bk, dtype, tol):
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((b, h, s, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((b, h, s, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((b, h, s, hd)), dtype)
    out = flash_attention(q, k, v, causal=True, window=window,
                          bq=bq, bk=bk, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol)


def test_flash_pallas_noncausal():
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    out = flash_attention(q, k, v, causal=False, bq=128, bk=128)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_policy_stack():
    assert current_policy().flash_block == 0
    with compute_policy(flash_block=1024):
        assert current_policy().flash_block == 1024
        with compute_policy(explicit_ep=True):
            assert current_policy().flash_block == 1024
            assert current_policy().explicit_ep
        assert not current_policy().explicit_ep
    assert current_policy().flash_block == 0


def test_explicit_ep_matches_baseline():
    """Single-device mesh: shard_map column == GSPMD path (fwd + grad)."""
    from functools import partial

    from repro.models.moe import init_moe, moe_ffn

    E, k, d, dff = 8, 2, 32, 16
    p = init_moe(jax.random.PRNGKey(0), d, num_experts=E, d_ff_expert=dff,
                 top_k=k, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d), jnp.float32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    f = partial(moe_ffn, num_experts=E, top_k=k, capacity_factor=float(E))

    def run(ep):
        def g(p, x):
            if ep:
                with compute_policy(explicit_ep=True):
                    y, aux = f(p, x)
            else:
                y, aux = f(p, x)
            return y, aux
        with mesh:
            y, aux = jax.jit(g)(p, x)
            grads = jax.jit(jax.grad(lambda p: jnp.sum(g(p, x)[0] ** 2)))(p)
        return y, aux, grads

    y0, a0, g0 = run(False)
    y1, a1, g1 = run(True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               atol=2e-5, rtol=2e-5)
    assert int(a0["dropped"]) == int(a1["dropped"]) == 0
    for l0, l1 in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                                   atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("b,h,t,hd,bt", [
    (2, 4, 2048, 64, 512),
    (1, 2, 1024, 128, 256),
    (3, 2, 512, 64, 512),   # single T block
])
def test_flash_decode_matches_ref(b, h, t, hd, bt, dtype, tol):
    from repro.kernels.flash_decode import flash_decode
    from repro.kernels.ref import flash_decode_ref

    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.standard_normal((b, h, 1, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((b, h, t, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((b, h, t, hd)), dtype)
    length = jnp.asarray(rng.integers(1, t + 1, (b,)), jnp.int32)
    out = flash_decode(q, k, v, length, bt=bt, interpret=True)
    ref = flash_decode_ref(q, k, v, length)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_decode_policy_in_attention():
    """attention() with ComputePolicy.flash_decode must match the eager
    decode path (linear cache)."""
    from repro.models.attention import attention, init_attention, init_cache

    b, hd, h, kvh, T = 2, 32, 4, 2, 128
    d = 64
    p = init_attention(jax.random.PRNGKey(0), d, h, kvh, hd, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, 1, d), jnp.float32)
    cache = init_cache(b, T, kvh, hd, dtype=jnp.float32)
    # pretend 17 tokens were prefilled
    cache = {**cache, "pos": jnp.asarray(17, jnp.int32),
             "k": cache["k"].at[:, :17].set(
                 jax.random.normal(jax.random.PRNGKey(2), (b, 17, kvh, hd))),
             "v": cache["v"].at[:, :17].set(
                 jax.random.normal(jax.random.PRNGKey(3), (b, 17, kvh, hd)))}
    pos = jnp.full((b, 1), 17, jnp.int32)
    kw = dict(num_heads=h, num_kv_heads=kvh, head_dim=hd, rope_theta=1e4,
              cache=cache, update_cache=True)
    out0, c0 = attention(p, x, pos, **kw)
    with compute_policy(flash_decode=True):
        out1, c1 = attention(p, x, pos, **kw)
    np.testing.assert_allclose(np.asarray(out0), np.asarray(out1),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_array_equal(np.asarray(c0["k"]), np.asarray(c1["k"]))
