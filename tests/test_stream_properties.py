"""Property-based tests (hypothesis) for merge stability (ISSUE 4).

Duplicate keys straddling run boundaries, NaN / -0.0 keys, payload rows,
ragged run lengths (empty runs, k=1) — asserting bit-identical output to
``jnp.sort`` / ``jnp.argsort(stable=True)`` of the concatenation across
both merge engines.  A deterministic sweep over the same edge surface
lives in ``tests/test_stream.py`` for environments without hypothesis.
"""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from oracle import stable_oracle as _stable_oracle
from repro import stream
from repro.ops import keyspace

_POOL = [np.nan, -0.0, 0.0, -np.inf, np.inf, 1.0, -1.0, 2.5, 2.5, -2.5]


def _stable_runs(x, bounds):
    # run order and oracle live in the *keyspace* total order (-0.0 strictly
    # before +0.0, which this jax's jnp.sort leaves merely grouped)
    enc = keyspace.encode(x)
    runs, idxs = [], []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        order = jnp.argsort(enc[lo:hi], stable=True)
        runs.append(x[lo:hi][order])
        idxs.append(order.astype(jnp.int32) + lo)
    return runs, idxs


@settings(max_examples=30, deadline=None)
@given(
    st.lists(
        st.lists(
            st.one_of(st.sampled_from(_POOL), st.integers(-3, 3).map(float)),
            min_size=0,
            max_size=25,
        ),
        min_size=1,
        max_size=5,
    ),
    st.sampled_from(("xla", "pallas")),
    st.sampled_from((8, 64)),
)
def test_merge_is_stable_sort_of_concat(run_lists, engine, tile):
    runs_np = [np.asarray(r, np.float32) for r in run_lists]
    lens = [len(r) for r in runs_np]
    if sum(lens) == 0:
        return
    x = jnp.asarray(np.concatenate(runs_np))
    runs, idxs = _stable_runs(x, np.cumsum([0] + lens).tolist())
    keys, src = stream.merge(runs, values=idxs, engine=engine, tile=tile)
    oracle, operm = _stable_oracle(x)
    np.testing.assert_array_equal(np.asarray(keys), np.asarray(oracle))
    np.testing.assert_array_equal(  # -0.0 vs 0.0 must order, not just compare
        np.signbit(np.asarray(keys)), np.signbit(np.asarray(oracle))
    )
    np.testing.assert_array_equal(np.asarray(src), np.asarray(operm))


@settings(max_examples=15, deadline=None)
@given(
    st.integers(1, 120),
    st.integers(1, 120),
    st.integers(0, 8),
    st.sampled_from((16, 128)),
)
def test_merge_path_kernel_matches_ref(na, nb, span, tile):
    rng = np.random.default_rng(na * 1000 + nb)
    a = jnp.asarray(np.sort(rng.integers(0, span + 1, na).astype(np.uint32)))
    b = jnp.asarray(np.sort(rng.integers(0, span + 1, nb).astype(np.uint32)))
    from repro.kernels.merge_path import merge_path_perm
    from repro.kernels.ref import merge_path_perm_ref

    np.testing.assert_array_equal(
        np.asarray(merge_path_perm(a, b, tile=tile, interpret=True)),
        np.asarray(merge_path_perm_ref(a, b)),
    )
