"""Per-kernel validation: interpret=True Pallas vs pure-jnp ref oracles,
swept across shapes and dtypes (the kernel contract from the brief)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels.bitonic import bitonic_sort_windows
from repro.kernels.classify import classify_histogram
from repro.kernels.dispatch_rank import dispatch_ranks
from repro.kernels.permute_inplace import permute_blocks_inplace


# ---------------------------------------------------------------- classify
@pytest.mark.parametrize("k", [2, 4, 32, 128])
@pytest.mark.parametrize("dtype", [np.float32, np.int32, jnp.bfloat16])
@pytest.mark.parametrize("tiles,rows", [(1, 8), (3, 32)])
def test_classify_histogram(k, dtype, tiles, rows):
    n = tiles * rows * 128
    rng = np.random.default_rng(k * 7 + tiles)
    if dtype is np.int32:
        keys = rng.integers(-1000, 1000, n).astype(dtype)
        spl = np.sort(rng.choice(keys, k - 1, replace=False)) if k > 1 else keys[:0]
    else:
        keys = rng.standard_normal(n).astype(np.float32)
        spl = np.sort(rng.choice(keys, k - 1, replace=False))
    keys_j = jnp.asarray(keys).astype(dtype) if dtype is jnp.bfloat16 else jnp.asarray(keys)
    spl_j = jnp.asarray(spl).astype(dtype) if dtype is jnp.bfloat16 else jnp.asarray(spl)
    b, h = classify_histogram(keys_j, spl_j, k=k, rows=rows)
    b_ref, h_ref = ref.classify_histogram_ref(keys_j, spl_j, k=k, rows=rows)
    np.testing.assert_array_equal(np.asarray(b), np.asarray(b_ref))
    np.testing.assert_array_equal(np.asarray(h), np.asarray(h_ref))


# ----------------------------------------------------------------- bitonic
@pytest.mark.parametrize("W", [128, 512, 2048])
@pytest.mark.parametrize("num_w", [1, 4])
@pytest.mark.parametrize("kdtype", [np.float32, np.int32])
def test_bitonic_windows(W, num_w, kdtype):
    rng = np.random.default_rng(W + num_w)
    b = np.sort(rng.integers(0, 9, (num_w, W)).astype(np.int32), axis=1)
    if kdtype is np.float32:
        k = rng.standard_normal((num_w, W)).astype(kdtype)
    else:
        k = rng.integers(-50, 50, (num_w, W)).astype(kdtype)
    idx = np.tile(np.arange(W, dtype=np.int32), (num_w, 1))
    got = bitonic_sort_windows(jnp.asarray(b), jnp.asarray(k), jnp.asarray(idx))
    exp = ref.bitonic_sort_windows_ref(jnp.asarray(b), jnp.asarray(k), jnp.asarray(idx))
    # bucket & key sequences must match exactly; idx may differ within ties,
    # but must be a consistent permutation (payload association).
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(exp[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(exp[1]))
    for w in range(num_w):
        np.testing.assert_array_equal(k[w][np.asarray(got[2][w])], np.asarray(got[1][w]))


# ------------------------------------------------------- permute_inplace
@pytest.mark.parametrize("k,N,be", [(2, 8, 128), (4, 32, 256), (16, 64, 128), (8, 1, 128)])
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_permute_blocks_inplace(k, N, be, dtype):
    rng = np.random.default_rng(k * N)
    bb = rng.integers(0, k, N).astype(np.int32)
    hist = np.bincount(bb, minlength=k)
    d = np.concatenate([[0], np.cumsum(hist)]).astype(np.int32)
    a = (
        (bb[:, None] * 100000 + np.arange(N)[:, None] * be + np.arange(be)[None, :])
        .astype(dtype)
        .reshape(-1)
    )
    out = np.asarray(
        permute_blocks_inplace(
            jnp.asarray(a), jnp.asarray(bb), jnp.asarray(d), k=k, block_elems=be
        )
    )
    exp = np.asarray(ref.permute_blocks_ref(jnp.asarray(a), jnp.asarray(bb), k=k, block_elems=be))
    # per-bucket block multisets must match; blocks must be intact
    outb = out.reshape(N, be)
    expb = exp.reshape(N, be)
    for b in range(k):
        got_set = sorted(outb[j, 0].item() for j in range(d[b], d[b + 1]))
        exp_set = sorted(expb[j, 0].item() for j in range(d[b], d[b + 1]))
        assert got_set == exp_set
    inb = a.reshape(N, be)
    starts = {row[0].item(): i for i, row in enumerate(inb)}
    for j in range(N):
        np.testing.assert_array_equal(outb[j], inb[starts[outb[j, 0].item()]])


def test_sort_blocks_wrapper():
    rng = np.random.default_rng(5)
    k, N, be = 8, 48, 128
    bb = rng.integers(0, k, N).astype(np.int32)
    a = np.repeat(bb.astype(np.float32), be) * 10 + np.tile(np.arange(be) * 0.01, N)
    out, d = ops.sort_blocks(jnp.asarray(a), jnp.asarray(bb), k=k, block_elems=be)
    out, d = np.asarray(out), np.asarray(d)
    seg = np.repeat(np.arange(k), np.diff(d))
    np.testing.assert_array_equal(np.repeat(seg, be), (out // 10).astype(np.int64))


# ------------------------------------------------------------ dispatch
@pytest.mark.parametrize("E", [4, 8, 64])
@pytest.mark.parametrize("tiles", [1, 4])
def test_dispatch_ranks(E, tiles):
    n = tiles * 8 * 128
    rng = np.random.default_rng(E)
    eid = rng.integers(0, E, n).astype(np.int32)
    hist = np.bincount(eid, minlength=E)
    start = np.concatenate([[0], np.cumsum(hist)])[:-1].astype(np.int32)
    got = np.asarray(
        dispatch_ranks(jnp.asarray(eid), jnp.asarray(start), num_experts=E)
    )
    exp = np.asarray(ref.dispatch_ranks_ref(jnp.asarray(eid), jnp.asarray(start)))
    np.testing.assert_array_equal(got, exp)


def test_moe_group_tokens():
    E, n, dm = 8, 2048, 16
    rng = np.random.default_rng(0)
    eid = rng.integers(0, E, n).astype(np.int32)
    tok = rng.standard_normal((n, dm)).astype(np.float32)
    grouped, off, dest = ops.moe_group_tokens(jnp.asarray(eid), jnp.asarray(tok), E)
    grouped, off, dest = map(np.asarray, (grouped, off, dest))
    # each expert segment holds exactly its tokens, in original order (stable)
    for e in range(E):
        seg = grouped[off[e] : off[e + 1]]
        np.testing.assert_array_equal(seg, tok[eid == e])
    # dest is the inverse mapping
    np.testing.assert_array_equal(grouped[dest], tok)


# ------------------------------------------------- pallas base-case window
def test_base_case_windows_matches_jnp():
    n, W = 4096, 512
    rng = np.random.default_rng(1)
    fb = np.sort(rng.integers(0, 40, n)).astype(np.int32)  # contiguous buckets
    keys = rng.standard_normal(n).astype(np.float32)
    # bucket sizes <= W/2 guaranteed? enforce by construction:
    fb = np.repeat(np.arange(n // 128), 128).astype(np.int32)[:n]
    arrays = {"k": jnp.asarray(keys), "v": jnp.arange(n, dtype=jnp.int32)}
    out = ops.base_case_windows(arrays, jnp.asarray(fb), W)
    # every bucket fully sorted afterwards
    ko = np.asarray(out["k"])
    vo = np.asarray(out["v"])
    for b in range(fb.max() + 1):
        m = fb == b
        np.testing.assert_array_equal(np.sort(keys[m]), ko[m])
    np.testing.assert_array_equal(keys[vo], ko)
