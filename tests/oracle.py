"""Shared sort oracles for the test suites.

Every suite that checks a sort against "what numpy would do" needs the
same three ingredients, previously re-implemented per file (test_stream,
test_dist, test_level_fused, ...):

  * the **keyspace total order** — ``jnp.sort`` in this jax version
    leaves -0.0/+0.0 grouped but unordered and has no NaN story, while
    ``ops.keyspace`` orders -0.0 strictly before +0.0 and NaNs last, so
    oracles must sort *encoded* keys and decode back;
  * **stability** — the engine's permutation is stable (core/ips4o.py
    docstring), so oracles use ``kind="stable"`` argsorts;
  * **bit-level assertions** — float comparisons must pin signbits
    (``-0.0 == 0.0`` under ``==``, but they must *order*).

All helpers take anything array-like and return host numpy.
"""
import jax.numpy as jnp
import numpy as np

from repro.ops import keyspace

__all__ = [
    "keyspace_sorted",
    "stable_argsort",
    "stable_oracle",
    "assert_keys_equal",
    "lex_argsort_words",
    "stable_dest",
]


def keyspace_sorted(x) -> np.ndarray:
    """Sorted keys in the keyspace total order (NaNs last, -0.0 before
    +0.0 — the acceptance oracle for every full-sort path)."""
    x = jnp.asarray(x)
    enc = np.asarray(keyspace.encode(x))
    return np.asarray(keyspace.decode(jnp.asarray(np.sort(enc)), x.dtype))


def stable_argsort(x) -> np.ndarray:
    """Stable argsort in the keyspace total order — what a stable engine's
    index payload must reproduce exactly."""
    return np.argsort(np.asarray(keyspace.encode(jnp.asarray(x))), kind="stable")


def stable_oracle(x):
    """(sorted keys, stable argsort) of x in the keyspace total order."""
    x = jnp.asarray(x)
    enc = np.asarray(keyspace.encode(x))
    perm = np.argsort(enc, kind="stable")
    return np.asarray(keyspace.decode(jnp.asarray(enc[perm]), x.dtype)), perm


def assert_keys_equal(got, want) -> None:
    """Bit-level key equality: positional equality (NaNs allowed to match
    NaNs) plus a signbit pin for float dtypes."""
    got, want = np.asarray(got), np.asarray(want)
    np.testing.assert_array_equal(got, want)
    if got.dtype.kind == "f":
        np.testing.assert_array_equal(np.signbit(got), np.signbit(want))


def lex_argsort_words(words) -> np.ndarray:
    """Stable lexicographic argsort of an (n, W) word matrix, word 0 most
    significant, each column compared in the keyspace total order — the
    oracle for ``ops.argsort_records``.  (np.lexsort's *last* key is
    primary, hence the reversal.)"""
    w = np.asarray(words)
    cols = [
        np.asarray(keyspace.encode(jnp.asarray(w[:, j])))
        for j in range(w.shape[1])
    ]
    return np.lexsort(tuple(reversed(cols)))


def stable_dest(ids, nb):
    """Global stable counting placement: dest[i] = offsets[b_i] + #earlier
    same-bucket elements.  The scatter inverse of a stable argsort; the
    partition-kernel oracle."""
    ids = np.asarray(ids)
    order = np.argsort(ids, kind="stable")
    dest = np.empty(ids.size, np.int32)
    dest[order] = np.arange(ids.size, dtype=np.int32)
    hist = np.bincount(ids, minlength=nb)
    off = np.concatenate([[0], np.cumsum(hist)]).astype(np.int32)
    return dest, off
