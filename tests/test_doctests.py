"""Tier-1 wiring for the ``repro.ops`` / ``repro.stream`` / ``repro.dist``
/ ``repro.checkpoint`` doctest suites (ISSUE 3 / ISSUE 4 / ISSUE 10
satellites).

CI also runs ``pytest --doctest-modules`` over the same packages in the
docs job; this file puts the same examples under the tier-1 umbrella
(``pytest -x -q`` from the repo root), so a docstring example that rots
fails the default test run, not just the docs job.  Every public module
of these packages must carry at least one runnable, d=1-safe example.
"""
import doctest
import importlib

import pytest

OPS_MODULES = [
    "repro.ops.sort",
    "repro.ops.topk",
    "repro.ops.batched",
    "repro.ops.segmented",
    "repro.ops.groupby",
    "repro.ops.keyspace",
    "repro.ops.plan",
    "repro.stream.api",
    "repro.stream.merge",
    "repro.stream.runs",
    "repro.dist.api",
    "repro.dist.levels",
    "repro.dist.exchange",
    "repro.dist.elastic",
    "repro.checkpoint.manager",
]


@pytest.mark.parametrize("name", OPS_MODULES)
def test_ops_doctests(name):
    mod = importlib.import_module(name)
    result = doctest.testmod(mod, verbose=False)
    assert result.attempted > 0, f"{name} has no doctest examples"
    assert result.failed == 0, f"{name}: {result.failed} doctest(s) failed"
