"""The multi-pod dry-run deliverable must keep compiling.

Runs ONE cheap cell (rwkv6-1.6b decode_32k — ~3 s compile) through the
real 512-virtual-device path in a subprocess (jax locks the device count
at first init, so it cannot run in-process with the rest of the suite).
"""
import json
import os
import subprocess
import sys

import pytest

_CHILD = r"""
import sys
from repro.launch.dryrun import lower_cell
row = lower_cell("rwkv6-1.6b", "decode_32k", multi_pod=%s, verbose=False)
import json
print("RESULT " + json.dumps({k: row[k] for k in ("status", "mesh", "chips")}))
"""


@pytest.mark.slow
@pytest.mark.parametrize("multi_pod", [False, True])
def test_dryrun_cell_compiles(multi_pod):
    env = {**os.environ, "PYTHONPATH": os.pathsep.join(sys.path)}
    r = subprocess.run(
        [sys.executable, "-c", _CHILD % multi_pod],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    row = json.loads(line[len("RESULT "):])
    assert row["status"] == "ok"
    assert row["chips"] == (512 if multi_pod else 256)
