import os
# Keep default device count = 1 for smoke tests/benches (dry-run overrides in
# its own subprocess; multi-device tests spawn subprocesses too).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
