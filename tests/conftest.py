import os
# Keep default device count = 1 for smoke tests/benches (dry-run overrides in
# its own subprocess; multi-device tests spawn subprocesses too).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="module", autouse=True)
def _clear_jax_caches_between_modules():
    """This jaxlib segfaults inside backend_compile once the in-process
    compile history grows past a few hundred programs (the same fragility
    that forces the x64 suites into subprocesses — see tests/test_classify.py).
    Dropping the jit caches at module boundaries keeps the full tier-1 run
    under that threshold; each module recompiles its own programs anyway, so
    only cross-module cache hits are lost."""
    yield
    import jax

    jax.clear_caches()
