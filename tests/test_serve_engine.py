"""Serving-engine correctness: the donated KV cache must not leak state
across generate() calls, and sampling must be seed-deterministic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_reduced
from repro.models.transformer import init_model
from repro.serve.engine import Engine, ServeConfig


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("yi-9b", num_layers=1)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, mesh, params


def _prompts(cfg, b, plen, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab_size, (b, plen)), jnp.int32)


def test_double_generate_matches_fresh_engines(setup):
    """Two back-to-back generate() calls == two fresh engines.

    The second prompt is SHORTER than the first: before the fix the reused
    donated cache still held the first call's KV beyond the new prompt
    length, and decoding attended over it.
    """
    cfg, mesh, params = setup
    scfg = ServeConfig(max_seq=32, batch_size=2)
    p_long = _prompts(cfg, 2, 12, seed=1)
    p_short = _prompts(cfg, 2, 4, seed=2)

    engine = Engine(cfg, scfg, mesh, params)
    with mesh:
        out1 = engine.generate(p_long, 6)
        out2 = engine.generate(p_short, 6)

    fresh1 = Engine(cfg, scfg, mesh, params)
    fresh2 = Engine(cfg, scfg, mesh, params)
    with mesh:
        ref1 = fresh1.generate(p_long, 6)
        ref2 = fresh2.generate(p_short, 6)

    np.testing.assert_array_equal(np.asarray(out1), np.asarray(ref1))
    np.testing.assert_array_equal(np.asarray(out2), np.asarray(ref2))


def test_sampled_generate_deterministic_per_seed(setup):
    """Temperature sampling: same seed -> same stream (and the first token
    uses a split key, not the parent), different seed -> different stream."""
    cfg, mesh, params = setup
    scfg = ServeConfig(max_seq=32, batch_size=2, temperature=1.0)
    p = _prompts(cfg, 2, 8, seed=3)
    engine = Engine(cfg, scfg, mesh, params)
    with mesh:
        a = engine.generate(p, 8, seed=0)
        b = engine.generate(p, 8, seed=0)
        c = engine.generate(p, 8, seed=1)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))
