"""Tests for the repro.ops subsystem (DESIGN.md §5).

Covers the keyspace bijection (NaN / -0.0 / extreme ints), NaN-safe
sort/argsort, the splitter-based partial sorts (incl. k >= n, k = 0,
all-equal keys, multi-level inputs), segmented sort, unique / run_length /
group_by (all three engines), and the plan cache.
"""
import os

import numpy as np
import jax.numpy as jnp
import pytest

from repro import ops
from repro.core.ips4o import SortConfig
from repro.ops import keyspace

# small config exercises the 1- and 2-level paths at test-friendly sizes
_small_cfg = SortConfig(base_case=1024, kmax=32, tile=256, max_sample=256, slack=4)


def _rand(n, seed=0):
    return np.random.default_rng(seed).standard_normal(n).astype(np.float32)


# ---------------------------------------------------------------- keyspace
@pytest.mark.parametrize(
    "dtype", [np.float32, np.int32, np.uint32, np.int16, np.uint8, jnp.bfloat16]
)
def test_keyspace_roundtrip_and_order(dtype):
    rng = np.random.default_rng(1)
    if dtype is jnp.bfloat16:
        x = jnp.asarray(rng.standard_normal(4096).astype(np.float32)).astype(dtype)
    elif np.issubdtype(dtype, np.floating):
        x = jnp.asarray(rng.standard_normal(4096).astype(dtype))
    else:
        info = np.iinfo(dtype)
        x = jnp.asarray(
            rng.integers(info.min, info.max, 4096, endpoint=True).astype(dtype)
        )
    u = keyspace.encode(x)
    assert u.dtype == keyspace.ordered_uint_dtype(x.dtype)
    back = keyspace.decode(u, x.dtype)
    np.testing.assert_array_equal(
        np.asarray(back.astype(jnp.float32) if dtype is jnp.bfloat16 else back),
        np.asarray(x.astype(jnp.float32) if dtype is jnp.bfloat16 else x),
    )
    # order preserved: sorting codes == sorting values
    xs = np.asarray(x.astype(jnp.float32) if dtype is jnp.bfloat16 else x)
    order = np.argsort(np.asarray(u), kind="stable")
    np.testing.assert_array_equal(xs[order], np.sort(xs))


def test_keyspace_nan_and_signed_zero():
    x = jnp.asarray([np.nan, -0.0, 0.0, -np.inf, np.inf, 1.5, -1.5, -np.nan],
                    jnp.float32)
    u = np.asarray(keyspace.encode(x))
    # total order: -inf < -1.5 < -0.0 < +0.0 < 1.5 < +inf < NaN == NaN
    assert u[3] < u[6] < u[1] < u[2] < u[5] < u[4] < u[0]
    assert u[0] == u[7], "all NaNs canonicalize to one code"
    back = np.asarray(keyspace.decode(keyspace.encode(x), x.dtype))
    assert np.isnan(back[0]) and np.isnan(back[7])
    assert np.signbit(back[1]) and not np.signbit(back[2])  # -0.0 / +0.0 exact


def test_keyspace_extreme_ints():
    x = jnp.asarray([np.iinfo(np.int32).min, -1, 0, 1, np.iinfo(np.int32).max],
                    jnp.int32)
    u = np.asarray(keyspace.encode(x))
    assert np.all(np.diff(u.astype(np.uint64)) > 0)
    np.testing.assert_array_equal(np.asarray(keyspace.decode(keyspace.encode(x), x.dtype)),
                                  np.asarray(x))


# ---------------------------------------------------------------- sort/argsort
def test_sort_nan_safe():
    x = _rand(20_000, 3)
    x[::101] = np.nan
    x[::97] = -0.0
    out = np.asarray(ops.sort(jnp.asarray(x), cfg=_small_cfg))
    np.testing.assert_array_equal(out, np.sort(x))  # numpy also sorts NaNs last
    assert np.isnan(out[-1])


def test_sort_with_payload():
    x = _rand(9_000, 4)
    v = np.arange(9_000, dtype=np.int32)
    ks, vs = ops.sort(jnp.asarray(x), jnp.asarray(v), cfg=_small_cfg)
    ks, vs = np.asarray(ks), np.asarray(vs)
    np.testing.assert_array_equal(ks, np.sort(x))
    np.testing.assert_array_equal(x[vs], ks)


@pytest.mark.parametrize("dtype", [np.int8, np.int16, np.uint8, np.uint16])
def test_sort_narrow_int_dtypes(dtype):
    # narrow dtypes ride the same distributions as wide ones; Exponential
    # used to clamp int8/int16 to a constant info.max array (scale bug in
    # data.distributions._exponential) — pin non-degeneracy AND parity
    from repro.data.distributions import make_input

    x = make_input("Exponential", 5000, dtype, seed=9)
    assert len(np.unique(x)) > 3, "Exponential degenerated to ~constant"
    assert x.max() <= np.iinfo(dtype).max
    out = np.asarray(ops.sort(jnp.asarray(x), cfg=_small_cfg))
    np.testing.assert_array_equal(out, np.sort(x))
    for dist in ("Uniform", "TwoDup", "Ones"):
        y = make_input(dist, 4096, dtype, seed=9)
        np.testing.assert_array_equal(
            np.asarray(ops.sort(jnp.asarray(y), cfg=_small_cfg)), np.sort(y)
        )


@pytest.mark.parametrize("n", [0, 1, 2, 255, 4096])
def test_argsort_sizes(n):
    x = _rand(n, n)
    order = np.asarray(ops.argsort(jnp.asarray(x), cfg=_small_cfg))
    assert order.shape == (n,)
    if n:
        assert len(np.unique(order)) == n
        np.testing.assert_array_equal(x[order], np.sort(x))


# ---------------------------------------------------------------- topk/bottomk
@pytest.mark.parametrize("n,k", [(100_000, 7), (100_000, 512), (6_000, 100)])
def test_bottomk_topk(n, k):
    x = _rand(n, k)
    v, i = ops.bottomk(jnp.asarray(x), k, cfg=_small_cfg)
    v, i = np.asarray(v), np.asarray(i)
    np.testing.assert_array_equal(v, np.sort(x)[:k])
    np.testing.assert_array_equal(x[i], v)
    v2, i2 = ops.topk(jnp.asarray(x), k, cfg=_small_cfg)
    v2, i2 = np.asarray(v2), np.asarray(i2)
    np.testing.assert_array_equal(v2, np.sort(x)[::-1][:k])
    np.testing.assert_array_equal(x[i2], v2)


def test_topk_k_geq_n():
    x = _rand(300, 9)
    v, i = ops.topk(jnp.asarray(x), 1000, cfg=_small_cfg)
    assert v.shape == (300,)
    np.testing.assert_array_equal(np.asarray(v), np.sort(x)[::-1])
    assert len(np.unique(np.asarray(i))) == 300


def test_topk_k_zero_and_empty():
    x = _rand(64, 2)
    v, i = ops.topk(jnp.asarray(x), 0)
    assert v.shape == (0,) and i.shape == (0,)
    v, i = ops.bottomk(jnp.asarray(x[:0]), 5)
    assert v.shape == (0,) and i.shape == (0,)


def test_topk_all_equal_keys():
    x = np.full(50_000, 3.25, np.float32)
    v, i = ops.bottomk(jnp.asarray(x), 17, cfg=_small_cfg)
    np.testing.assert_array_equal(np.asarray(v), x[:17])
    assert len(np.unique(np.asarray(i))) == 17


def test_topk_small_n_base_case_path():
    # n <= base_case: degenerates to the plain stable base case
    x = _rand(100, 5)
    v, i = ops.bottomk(jnp.asarray(x), 3, cfg=_small_cfg)
    np.testing.assert_array_equal(np.asarray(v), np.sort(x)[:3])


def test_topk_with_nans():
    # NaN is the maximum of the keyspace total order (like lax.top_k):
    # topk surfaces NaNs first, bottomk ranks them last.
    x = _rand(30_000, 11)
    x[:50] = np.nan
    v, _ = ops.topk(jnp.asarray(x), 60, cfg=_small_cfg)
    v = np.asarray(v)
    assert np.all(np.isnan(v[:50]))
    np.testing.assert_array_equal(v[50:], np.sort(x[50:])[::-1][:10])
    bv, _ = ops.bottomk(jnp.asarray(x), 10, cfg=_small_cfg)
    assert not np.any(np.isnan(np.asarray(bv)))


def test_topk_int_extremes():
    # int32 max encodes to the pad-sentinel code; must still be selected
    x = np.asarray(np.random.default_rng(0).integers(-100, 100, 20_000), np.int32)
    x[:5] = np.iinfo(np.int32).max
    v, _ = ops.topk(jnp.asarray(x), 8, cfg=_small_cfg)
    np.testing.assert_array_equal(np.asarray(v), np.sort(x)[::-1][:8])


# ---------------------------------------------------------------- segmented
@pytest.mark.parametrize("n,nseg", [(3_000, 4), (40_000, 9), (2_000, 1)])
def test_segmented_sort(n, nseg):
    rng = np.random.default_rng(nseg)
    cuts = np.sort(rng.integers(0, n, nseg - 1)) if nseg > 1 else np.empty(0, np.int64)
    offs = np.concatenate([[0], cuts, [n]]).astype(np.int32)
    x = rng.standard_normal(n).astype(np.float32)
    out = np.asarray(
        ops.segmented_sort(jnp.asarray(x), jnp.asarray(offs), nseg, cfg=_small_cfg)
    )
    for a, b in zip(offs[:-1], offs[1:]):
        np.testing.assert_array_equal(out[a:b], np.sort(x[a:b]))


def test_segmented_sort_payload_and_empty_segments():
    n, nseg = 10_000, 6
    offs = np.asarray([0, 0, 2_500, 2_500, 9_000, 9_000, n], np.int32)  # empties
    rng = np.random.default_rng(7)
    x = rng.standard_normal(n).astype(np.float32)
    v = np.arange(n, dtype=np.int32)
    ks, vs = ops.segmented_sort(
        jnp.asarray(x), jnp.asarray(offs), nseg, jnp.asarray(v), cfg=_small_cfg
    )
    ks, vs = np.asarray(ks), np.asarray(vs)
    np.testing.assert_array_equal(x[vs], ks)
    for a, b in zip(offs[:-1], offs[1:]):
        np.testing.assert_array_equal(ks[a:b], np.sort(x[a:b]))
        assert set(vs[a:b]) == set(range(a, b))  # payload stays in-segment


def test_segmented_sort_skewed_segment_fallback():
    # one huge all-distinct segment forces buckets past W/2 at tiny k ->
    # the (segment, key) stable fallback must kick in and stay per-segment
    n = 8_192
    offs = np.asarray([0, 100, n], np.int32)
    x = np.random.default_rng(13).permutation(n).astype(np.float32)
    out = np.asarray(
        ops.segmented_sort(
            jnp.asarray(x), jnp.asarray(offs), 2, k=2,
            cfg=SortConfig(base_case=512, kmax=4, tile=256, max_sample=64),
        )
    )
    for a, b in zip(offs[:-1], offs[1:]):
        np.testing.assert_array_equal(out[a:b], np.sort(x[a:b]))


# ---------------------------------------------------------------- grouping
def test_unique_against_numpy():
    x = np.random.default_rng(5).integers(0, 37, 25_000).astype(np.int32)
    uv, uc, un = ops.unique(jnp.asarray(x), cfg=_small_cfg)
    un = int(un)
    ref_v, ref_c = np.unique(x, return_counts=True)
    assert un == len(ref_v)
    np.testing.assert_array_equal(np.asarray(uv)[:un], ref_v)
    np.testing.assert_array_equal(np.asarray(uc)[:un], ref_c)


def test_unique_all_equal_and_empty():
    x = np.full(5_000, 2.5, np.float32)
    uv, uc, un = ops.unique(jnp.asarray(x), cfg=_small_cfg)
    assert int(un) == 1 and float(np.asarray(uv)[0]) == 2.5
    assert int(np.asarray(uc)[0]) == 5_000
    _, _, un0 = ops.unique(jnp.asarray(x[:0]))
    assert int(un0) == 0


def test_run_length():
    x = np.asarray([5, 5, 1, 1, 1, 9, 5, 5], np.float32)
    rv, rc, rn = ops.run_length(jnp.asarray(x))
    rn = int(rn)
    np.testing.assert_array_equal(np.asarray(rv)[:rn], [5, 1, 9, 5])
    np.testing.assert_array_equal(np.asarray(rc)[:rn], [2, 3, 1, 2])


def test_run_length_nan_runs():
    x = np.asarray([np.nan, np.nan, 1.0, np.nan], np.float32)
    rv, rc, rn = ops.run_length(jnp.asarray(x))
    assert int(rn) == 3  # NaN == NaN under keyspace equality
    np.testing.assert_array_equal(np.asarray(rc)[:3], [2, 1, 1])


@pytest.mark.parametrize("method", ["partition", "pallas"])
def test_group_by_int_engines(method):
    E, n = 13, 26 * 1000
    ids = np.random.default_rng(11).integers(0, E, n).astype(np.int32)
    vals = np.arange(n, dtype=np.int32)
    g = ops.group_by(jnp.asarray(ids), jnp.asarray(vals), num_groups=E, method=method)
    np.testing.assert_array_equal(np.asarray(g.counts), np.bincount(ids, minlength=E))
    gk, gv = np.asarray(g.keys), np.asarray(g.values)
    assert np.all(np.diff(gk) >= 0)
    np.testing.assert_array_equal(ids[gv], gk)  # payload association
    # stability: within a group, source order preserved
    for e in range(E):
        grp = gv[gk == e]
        assert np.all(np.diff(grp) > 0)


def test_group_by_sort_engine_generic_keys():
    x = np.random.default_rng(17).choice(
        np.asarray([0.5, -3.0, np.nan, 7.25], np.float32), 20_000
    )
    g = ops.group_by(jnp.asarray(x), cfg=_small_cfg)
    num = int(g.num_groups)
    assert num == 4
    gk = np.asarray(g.keys)
    np.testing.assert_array_equal(gk, np.sort(x))
    gids = np.asarray(g.group_ids)
    assert gids[0] == 0 and gids[-1] == num - 1
    counts = np.asarray(g.counts)[:num]
    assert counts.sum() == 20_000
    # perm recovers the original positions
    np.testing.assert_array_equal(x[np.asarray(g.perm)], gk)


# ---------------------------------------------------------------- plan cache
def test_plan_cache_roundtrip(tmp_path):
    path = str(tmp_path / "plans.json")
    pc = ops.PlanCache(path=path)
    f = pc.get_sorter(2_048, jnp.float32, "sort", tune=True)
    x = jnp.asarray(_rand(2_048, 1))
    np.testing.assert_array_equal(np.asarray(f(x)), np.sort(np.asarray(x)))
    assert os.path.exists(path)
    # a fresh cache instance loads the persisted plan without re-tuning
    pc2 = ops.PlanCache(path=path)
    key = list(pc2._plans)[0]
    assert "config" in pc2._plans[key] and "us" in pc2._plans[key]
    cfg = pc2.config_for("sort", 2_048, jnp.float32)
    assert isinstance(cfg, SortConfig)
    # compiled callables are memoized per (op, n, dtype, k)
    assert pc.get_sorter(2_048, jnp.float32, "sort") is f


def test_plan_cache_topk_requires_k(tmp_path):
    pc = ops.PlanCache(path=str(tmp_path / "p.json"))
    with pytest.raises(ValueError, match="requires k"):
        pc.get_sorter(1_000, jnp.float32, "topk")
    f = pc.get_sorter(4_096, jnp.float32, "bottomk", k=5)
    x = jnp.asarray(_rand(4_096, 2))
    v, i = f(x)
    np.testing.assert_array_equal(np.asarray(v), np.sort(np.asarray(x))[:5])


def test_get_sorter_module_level():
    f = ops.get_sorter(1_024, jnp.int32, op="argsort")
    x = jnp.asarray(np.random.default_rng(3).integers(0, 50, 1_024), jnp.int32)
    order = np.asarray(f(x))
    np.testing.assert_array_equal(np.asarray(x)[order], np.sort(np.asarray(x)))
