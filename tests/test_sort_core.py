"""Correctness of the core IPS4o sort vs the stable oracle.

Covers: all nine paper distributions, several sizes (1- and 2-level paths),
dtypes, payload association, equality buckets, and the robustness fallback.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.ips4o import SortConfig, ips4o_sort, plan_levels
from repro.core.ref import ref_sort
from repro.core.s3sort import s3_sort
from repro.data.distributions import DISTRIBUTIONS, make_input

SIZES = [0, 1, 2, 17, 255, 4096, 10_000, 100_000]
DISTS = sorted(DISTRIBUTIONS)


@pytest.mark.parametrize("dist", DISTS)
@pytest.mark.parametrize("n", [4096, 100_000])
def test_distributions(dist, n):
    x = make_input(dist, n, np.float32, seed=3)
    out = np.asarray(ips4o_sort(jnp.asarray(x)))
    np.testing.assert_array_equal(out, np.sort(x))


@pytest.mark.parametrize("n", SIZES)
def test_sizes_uniform(n):
    x = make_input("Uniform", n, np.float32, seed=n)
    out = np.asarray(ips4o_sort(jnp.asarray(x)))
    np.testing.assert_array_equal(out, np.sort(x))


@pytest.mark.parametrize(
    "dtype", [np.float32, np.float64, np.int32, np.uint32, np.int64, jnp.bfloat16]
)
def test_dtypes(dtype):
    n = 20_000
    if dtype is jnp.bfloat16:
        x = jnp.asarray(make_input("Uniform", n, np.float32, seed=7)).astype(dtype)
        out = np.asarray(ips4o_sort(x).astype(jnp.float32))
        np.testing.assert_array_equal(out, np.sort(np.asarray(x.astype(jnp.float32))))
        return
    x = np.asarray(jnp.asarray(make_input("Uniform", n, dtype, seed=7)))  # honor x64-off cast
    out = np.asarray(ips4o_sort(jnp.asarray(x)))
    np.testing.assert_array_equal(out, np.sort(x))


def test_extreme_values_int():
    # dtype-max keys collide with the padding sentinel: must still sort and
    # keep payload association (sentinel handling uses a dedicated bucket).
    n = 9000
    rng = np.random.default_rng(0)
    x = rng.integers(0, 10, n).astype(np.int32)
    x[:100] = np.iinfo(np.int32).max
    x[100:200] = np.iinfo(np.int32).min
    v = np.arange(n, dtype=np.int32)
    ks, vs = ips4o_sort(jnp.asarray(x), jnp.asarray(v))
    ks, vs = np.asarray(ks), np.asarray(vs)
    np.testing.assert_array_equal(ks, np.sort(x))
    np.testing.assert_array_equal(x[vs], ks)


@pytest.mark.parametrize("n", [4096, 150_000])
def test_payload_association(n):
    x = make_input("TwoDup", n, np.float32, seed=5)
    v = np.arange(n, dtype=np.int32)
    ks, vs = ips4o_sort(jnp.asarray(x), jnp.asarray(v))
    ks, vs = np.asarray(ks), np.asarray(vs)
    np.testing.assert_array_equal(ks, np.sort(x))
    np.testing.assert_array_equal(x[vs], ks)
    assert len(set(vs.tolist())) == n  # a permutation


def test_payload_pytree():
    n = 30_000
    x = make_input("Uniform", n, np.float32, seed=9)
    vals = {
        "idx": jnp.arange(n, dtype=jnp.int32),
        "mat": jnp.asarray(np.random.default_rng(1).random((n, 3), np.float32)),
    }
    ks, vs = ips4o_sort(jnp.asarray(x), vals)
    order = np.argsort(x, kind="stable")
    np.testing.assert_array_equal(np.asarray(ks), x[order])
    np.testing.assert_array_equal(x[np.asarray(vs["idx"])], np.asarray(ks))
    np.testing.assert_array_equal(
        np.asarray(vs["mat"]), np.asarray(vals["mat"])[np.asarray(vs["idx"])]
    )


def test_fallback_disabled_still_ok_uniform():
    n = 100_000
    x = make_input("Uniform", n, np.float32, seed=11)
    cfg = SortConfig(fallback=False)
    out = np.asarray(ips4o_sort(jnp.asarray(x), cfg=cfg))
    np.testing.assert_array_equal(out, np.sort(x))


def test_fallback_rescues_adversarial():
    # Adversarial: nearly-all-duplicates of *two* values plus noise; with
    # tiny k and no oversampling headroom some regular bucket may exceed W/2;
    # the lax.cond fallback must still give a correct result.
    n = 65_536
    rng = np.random.default_rng(13)
    x = np.where(rng.random(n) < 0.99, 1.0, rng.random(n)).astype(np.float32)
    cfg = SortConfig(base_case=2048, kmax=8, slack=1, max_sample=64)
    out = np.asarray(ips4o_sort(jnp.asarray(x), cfg=cfg))
    np.testing.assert_array_equal(out, np.sort(x))


def test_plan_levels():
    cfg = SortConfig()
    assert plan_levels(4096, cfg) == []
    assert plan_levels(8192, cfg) == []
    one = plan_levels(2**17, cfg)
    assert len(one) == 1
    two = plan_levels(2**22, cfg)
    assert len(two) == 2
    with pytest.raises(ValueError):
        plan_levels(2**40, cfg)


def test_jit_and_donation():
    n = 50_000
    x = make_input("Exponential", n, np.float32, seed=21)
    f = jax.jit(lambda a: ips4o_sort(a), donate_argnums=0)
    out = np.asarray(f(jnp.asarray(x)))
    np.testing.assert_array_equal(out, np.sort(x))


@pytest.mark.parametrize("dist", ["Uniform", "RootDup", "Ones"])
def test_s3sort_baseline(dist):
    n = 80_000
    x = make_input(dist, n, np.float32, seed=23)
    out = np.asarray(s3_sort(jnp.asarray(x)))
    np.testing.assert_array_equal(out, np.sort(x))


def test_s3sort_payload():
    n = 40_000
    x = make_input("TwoDup", n, np.float32, seed=29)
    v = np.arange(n, dtype=np.int32)
    ks, vs = s3_sort(jnp.asarray(x), jnp.asarray(v))
    np.testing.assert_array_equal(np.asarray(ks), np.sort(x))
    np.testing.assert_array_equal(x[np.asarray(vs)], np.asarray(ks))


def test_ref_sort_stability():
    x = jnp.asarray([3, 1, 3, 1], jnp.int32)
    v = jnp.arange(4, dtype=jnp.int32)
    ks, vs = ref_sort(x, v)
    np.testing.assert_array_equal(np.asarray(vs), [1, 3, 0, 2])
