"""Rendering contract for the perf dashboard (benchmarks/report.py).

The dashboard shares row-matching and tracked-metric rules with the perf
gate (tests/test_perf_gate.py covers those); this file pins the rendering
itself — above all that degenerate inputs (an empty trajectory, an empty
bench family, a crashed run's non-numeric metric cell) render an explicit
message instead of crashing or silently emitting nothing.
"""
from benchmarks.report import attribution, render

_ROW = {"name": "sort", "n": 1 << 20, "s_per_call": 1.0}


def test_empty_trajectory_renders_explicit_message():
    for payload in ({}, {"benches": {}}, {"benches": None}):
        md = render(payload)
        assert "empty trajectory" in md, payload
        assert md.startswith("# Benchmark report")


def test_empty_bench_family_says_no_rows():
    md = render({"benches": {"sort_ops": []}})
    assert "## sort_ops" in md and "(no rows)" in md


def test_non_numeric_tracked_cell_renders_without_delta():
    base = {"benches": {"b": [dict(_ROW)]}}
    fresh = {"benches": {"b": [{**_ROW, "s_per_call": "crashed"}]}}
    md = render(base, fresh)  # must not raise on float("crashed")
    assert "crashed" in md
    assert "%" not in md.split("crashed")[1].split("|")[0]  # no delta suffix


def test_matched_row_shows_tracked_delta():
    base = {"benches": {"b": [dict(_ROW)]}}
    fresh = {"benches": {"b": [{**_ROW, "s_per_call": 2.0}]}}
    md = render(base, fresh)
    assert "(+100%)" in md


def test_fresh_only_row_is_marked_new():
    base = {"benches": {"b": [dict(_ROW)]}}
    fresh = {"benches": {"b": [dict(_ROW), {**_ROW, "n": 1 << 10}]}}
    md = render(base, fresh)
    assert "*new*" in md and "1 fresh-only" in md


def test_attribution_missing_or_spanless_trace(tmp_path):
    assert attribution(str(tmp_path / "absent.jsonl")) == ""
    p = tmp_path / "empty.jsonl"
    p.write_text("")
    assert attribution(str(p)) == ""
    p.write_text('{"type": "metric", "name": "x"}\n')
    assert attribution(str(p)) == ""


def test_attribution_aggregates_spans(tmp_path):
    p = tmp_path / "t.jsonl"
    p.write_text(
        '{"type": "span", "name": "dist.sort", "dur_us": 10.0}\n'
        '{"type": "span", "name": "dist.sort", "dur_us": 30.0}\n'
        '{"type": "span", "name": "phase:classify", "dur_us": 5.0}\n'
    )
    md = attribution(str(p))
    lines = [ln for ln in md.splitlines() if ln.startswith("| ")]
    # phase:* rows sort first despite lower total
    assert "phase:classify" in lines[1]
    assert "| dist.sort | 2 | 10.0 | 40.0 |" in md
