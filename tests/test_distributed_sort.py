"""Distributed sort tests — run in a subprocess with a forced host-device
count so the main test process keeps a single device (per the dry-run rule).
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P, NamedSharding
    from repro.core.distributed import distributed_sort
    from repro.core.ips4o import SortConfig
    from repro.data.distributions import make_input

    assert jax.device_count() == 8
    cfg = SortConfig(base_case=2048, kmax=32, tile=512, max_sample=2048)

    def run(mesh, axis, dist, n, slack=2.5):
        x = make_input(dist, n, np.float32, seed=42)
        spec = P(axis)
        xs = jax.device_put(jnp.asarray(x), NamedSharding(mesh, spec))
        out, counts, ovf = jax.jit(
            lambda a: distributed_sort(a, mesh, axis, slack=slack, cfg=cfg)
        )(xs)
        out, counts, ovf = map(np.asarray, (out, counts, ovf))
        assert not ovf.any(), f"overflow {dist}"
        d = counts.shape[0]
        cap = out.shape[0] // d
        parts = [out[i * cap : i * cap + counts[i]] for i in range(d)]
        got = np.concatenate(parts)
        np.testing.assert_array_equal(got, np.sort(x)), dist
        print("OK", dist, n, axis)

    mesh = jax.make_mesh((8,), ("data",))
    for dist in ["Uniform", "RootDup", "Ones", "AlmostSorted"]:
        run(mesh, "data", dist, 1 << 16)
    run(mesh, "data", "Exponential", 1 << 18, slack=3.0)

    # multi-pod style 2-axis distribution
    mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
    run(mesh2, ("pod", "data"), "Uniform", 1 << 16)

    # payload rows travel with their keys (the Pair/100Bytes case)
    n = 1 << 16
    x = make_input("Uniform", n, np.float32, seed=11)
    vals = np.arange(n, dtype=np.int32)[:, None]
    xs = jax.device_put(jnp.asarray(x), NamedSharding(mesh, P("data")))
    vs = jax.device_put(jnp.asarray(vals), NamedSharding(mesh, P("data", None)))
    out, ov, counts, ovf = jax.jit(
        lambda a, v: distributed_sort(a, mesh, "data", values=v,
                                      slack=2.5, cfg=cfg)
    )(xs, vs)
    out, ov, counts, ovf = map(np.asarray, (out, ov, counts, ovf))
    assert not ovf.any()
    d = counts.shape[0]
    cap = out.shape[0] // d
    keys = np.concatenate([out[i*cap:i*cap+counts[i]] for i in range(d)])
    idxs = np.concatenate([ov[i*cap:i*cap+counts[i], 0] for i in range(d)])
    np.testing.assert_array_equal(keys, np.sort(x))
    np.testing.assert_allclose(x[idxs], keys)   # rows followed their keys
    print("OK payload")
    print("ALL-OK")
    """
)


@pytest.mark.slow
def test_distributed_sort_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ALL-OK" in r.stdout
