"""Distributed sort tests — run in a subprocess with a forced host-device
count so the main test process keeps a single device (per the dry-run rule).
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P, NamedSharding
    from repro.dist import sort as distributed_sort
    from repro.core.ips4o import SortConfig
    from repro.data.distributions import make_input

    assert jax.device_count() == 8
    cfg = SortConfig(base_case=2048, kmax=32, tile=512, max_sample=2048)

    def run(mesh, axis, dist, n, slack=2.5):
        x = make_input(dist, n, np.float32, seed=42)
        spec = P(axis)
        xs = jax.device_put(jnp.asarray(x), NamedSharding(mesh, spec))
        out, counts, ovf = jax.jit(
            lambda a: distributed_sort(a, mesh, axis, slack=slack, cfg=cfg)
        )(xs)
        out, counts, ovf = map(np.asarray, (out, counts, ovf))
        assert not ovf.any(), f"overflow {dist}"
        d = counts.shape[0]
        cap = out.shape[0] // d
        parts = [out[i * cap : i * cap + counts[i]] for i in range(d)]
        got = np.concatenate(parts)
        np.testing.assert_array_equal(got, np.sort(x)), dist
        print("OK", dist, n, axis)

    mesh = jax.make_mesh((8,), ("data",))
    for dist in ["Uniform", "RootDup", "Ones", "AlmostSorted"]:
        run(mesh, "data", dist, 1 << 16)
    run(mesh, "data", "Exponential", 1 << 18, slack=3.0)

    # multi-pod style 2-axis distribution
    mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
    run(mesh2, ("pod", "data"), "Uniform", 1 << 16)

    # capacity overflow (d > 1): undersized slack must SET the overflow
    # flag and truncate deterministically (counts clamped to capacity,
    # every shard still sorted) — never UB-shaped output
    n = 1 << 16
    x = make_input("Uniform", n, np.float32, seed=21)
    xs = jax.device_put(jnp.asarray(x), NamedSharding(mesh, P("data")))
    f_over = jax.jit(lambda a: distributed_sort(a, mesh, "data",
                                                slack=0.05, cfg=cfg))
    out1, counts1, ovf1 = map(np.asarray, f_over(xs))
    assert ovf1.any(), "undersized capacity must flag overflow"
    d = counts1.shape[0]
    cap = out1.shape[0] // d
    assert (counts1 <= cap).all()  # truncated to capacity, not UB
    for i in range(d):
        shard = out1[i * cap : i * cap + counts1[i]]
        assert np.all(shard[:-1] <= shard[1:]), "overflow shard not sorted"
    out2, counts2, ovf2 = map(np.asarray, f_over(xs))  # deterministic
    np.testing.assert_array_equal(out1, out2)
    np.testing.assert_array_equal(counts1, counts2)
    print("OK overflow d=8")

    # payload rows travel with their keys (the Pair/100Bytes case)
    n = 1 << 16
    x = make_input("Uniform", n, np.float32, seed=11)
    vals = np.arange(n, dtype=np.int32)[:, None]
    xs = jax.device_put(jnp.asarray(x), NamedSharding(mesh, P("data")))
    vs = jax.device_put(jnp.asarray(vals), NamedSharding(mesh, P("data", None)))
    out, ov, counts, ovf = jax.jit(
        lambda a, v: distributed_sort(a, mesh, "data", values=v,
                                      slack=2.5, cfg=cfg)
    )(xs, vs)
    out, ov, counts, ovf = map(np.asarray, (out, ov, counts, ovf))
    assert not ovf.any()
    d = counts.shape[0]
    cap = out.shape[0] // d
    keys = np.concatenate([out[i*cap:i*cap+counts[i]] for i in range(d)])
    idxs = np.concatenate([ov[i*cap:i*cap+counts[i], 0] for i in range(d)])
    np.testing.assert_array_equal(keys, np.sort(x))
    np.testing.assert_allclose(x[idxs], keys)   # rows followed their keys
    print("OK payload")
    print("ALL-OK")
    """
)


def test_capacity_overflow_truncates_deterministically():
    """ISSUE 4 satellite: the capacity-overflow path of repro.dist.sort
    (in-process via the degenerate d == 1 mesh, which shares the overflow
    contract of the d > 1 exchange: flag set, deterministic truncation to
    ``capacity``, output still sorted — never UB-shaped output)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.dist import sort as distributed_sort
    from repro.core.ips4o import SortConfig
    from repro.data.distributions import make_input

    cfg = SortConfig(base_case=256, kmax=16, tile=128, max_sample=256)
    mesh = jax.make_mesh((1,), ("data",))
    n = 512
    x = make_input("Uniform", n, np.float32, seed=13)
    xs = jax.device_put(jnp.asarray(x), NamedSharding(mesh, P("data")))
    f = jax.jit(lambda a: distributed_sort(a, mesh, "data", slack=0.25, cfg=cfg))
    out, counts, ovf = map(np.asarray, f(xs))
    cap = out.shape[0]
    assert cap < n, "test must undersize capacity"
    assert ovf.all(), "undersized capacity must set the overflow flag"
    np.testing.assert_array_equal(counts, [cap])
    # deterministic truncation: the first `capacity` elements, sorted
    np.testing.assert_array_equal(out, np.sort(x[:cap]))
    out2, counts2, ovf2 = map(np.asarray, f(xs))
    np.testing.assert_array_equal(out, out2)

    # ample capacity on the same path: no flag, full sorted output
    g = jax.jit(lambda a: distributed_sort(a, mesh, "data", slack=2.0, cfg=cfg))
    out3, counts3, ovf3 = map(np.asarray, g(xs))
    assert not ovf3.any()
    np.testing.assert_array_equal(out3[: counts3[0]], np.sort(x))


@pytest.mark.slow
def test_distributed_sort_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ALL-OK" in r.stdout
