"""Property tests (hypothesis) for the MoE dispatch invariants — the
paper's distribution machinery under arbitrary routing patterns."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.models.moe import expert_capacity, sort_dispatch


@st.composite
def routing(draw):
    e = draw(st.sampled_from([2, 4, 8, 16]))
    n = draw(st.integers(4, 300))
    ids = draw(st.lists(st.integers(0, e - 1), min_size=n, max_size=n))
    cap = draw(st.integers(1, 64))
    return e, np.asarray(ids, np.int32), cap


@settings(max_examples=40, deadline=None)
@given(routing())
def test_sort_dispatch_invariants(r):
    e, ids, cap = r
    slot, kept, counts = jax.jit(
        lambda a: sort_dispatch(a, e, cap)
    )(jnp.asarray(ids))
    slot, kept, counts = map(np.asarray, (slot, kept, counts))

    # 1. counts = exact histogram of the routing ids
    np.testing.assert_array_equal(counts, np.bincount(ids, minlength=e))
    # 2. kept slots are unique and within their expert's capacity range
    ks = slot[kept]
    assert len(np.unique(ks)) == len(ks)
    ke = ids[kept]
    assert np.all(ks // cap == ke)
    assert np.all(ks % cap < cap)
    # 3. per-expert kept count = min(count, capacity); drops only overflow
    for ex in range(e):
        assert (kept & (ids == ex)).sum() == min(counts[ex], cap)
    # 4. dropped entries all point at the trash slot
    assert np.all(slot[~kept] == e * cap)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 10_000), st.sampled_from([8, 64, 128]),
       st.sampled_from([1, 2, 6, 8]))
def test_expert_capacity_bounds(n, e, k):
    cap = expert_capacity(n, e, k, 1.25)
    assert cap >= 8 and cap % 8 == 0
    assert cap * e >= n * k  # capacity_factor >= 1 covers uniform routing
