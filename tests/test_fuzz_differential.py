"""Differential fuzz harness: the engine vs numpy, on everything at once.

Each seeded case draws a point from (distribution ∪ dataset ∪ random
records) x dtype x n x engine x classifier and asserts **bit-identity**
against the host oracle (tests/oracle.py):

  * sorted keys equal ``keyspace_sorted`` (NaNs last, -0.0 before +0.0,
    signbits pinned);
  * the index payload equals the **stable** argsort — this is the test
    that pins the engine's stability guarantee (core/ips4o.py docstring);
    any future change that reorders equal keys fails here first;
  * record cases (multi-word, tie-heavy domains) equal ``np.lexsort``.

The n pool deliberately includes 0, 1, non-powers-of-two, n < tile, and
n > base_case (level passes + base case + padding paths all engaged).
Cases are deterministic functions of their seed — a failure reproduces
from the seed alone.  Tier-1 runs a bounded sweep; ``-m slow`` runs the
long one (CI ``fuzz`` job).
"""
import os

import numpy as np
import jax.numpy as jnp
import pytest

from oracle import assert_keys_equal, keyspace_sorted, lex_argsort_words, stable_argsort
from repro import ops
from repro.core.ips4o import SortConfig
from repro.data import datasets
from repro.data.distributions import DISTRIBUTIONS, make_input

# small geometry: n=4095+ engages level passes, tile=256 makes n=255 a
# sub-tile case, base_case=1024 keeps tiny n on the window-sort path
_CFG = SortConfig(base_case=1024, kmax=32, tile=256, max_sample=512)

_DTYPES = (np.float32, np.int32, np.uint32, np.int16, np.uint8)
_NS = (0, 1, 2, 17, 255, 1000, 4095, 4096, 5000, 8192)
_ENGINES = ("xla", "pallas")
_CLASSIFIERS = ("tree", "radix", "auto")
_DISTS = sorted(DISTRIBUTIONS)


def _scalar_case(seed: int):
    rng = np.random.default_rng(seed)
    return (
        _DISTS[rng.integers(len(_DISTS))],
        _DTYPES[rng.integers(len(_DTYPES))],
        int(_NS[rng.integers(len(_NS))]),
        _ENGINES[rng.integers(len(_ENGINES))],
        _CLASSIFIERS[rng.integers(len(_CLASSIFIERS))],
    )


def _check_scalar(seed: int):
    dist, dtype, n, engine, classifier = _scalar_case(seed)
    x = make_input(dist, n, dtype, seed=seed)
    idx = jnp.arange(n, dtype=jnp.int32)
    keys, perm = ops.sort(
        jnp.asarray(x), idx, cfg=_CFG, engine=engine, classifier=classifier
    )
    assert_keys_equal(keys, keyspace_sorted(x))
    np.testing.assert_array_equal(
        np.asarray(perm), stable_argsort(x),
        err_msg=f"stability broken: {dist} {np.dtype(dtype)} n={n} "
        f"{engine}/{classifier} seed={seed}",
    )


def _check_records(seed: int):
    rng = np.random.default_rng(seed)
    n = int((0, 1, 33, 257, 2048)[rng.integers(5)])
    W = int(rng.integers(2, 4))
    if rng.integers(2):
        # tiny domains: ties at every word, stability does all the work
        words = rng.integers(0, 4, (n, W)).astype(np.uint32)
    else:
        pool = np.asarray([np.nan, -0.0, 0.0, 1.5, -1.5], np.float32)
        words = rng.choice(pool, (n, W))
    engine = _ENGINES[rng.integers(2)]
    got = np.asarray(
        ops.argsort_records(jnp.asarray(words), cfg=_CFG, engine=engine)
    )
    np.testing.assert_array_equal(
        got, lex_argsort_words(words),
        err_msg=f"records: n={n} W={W} {words.dtype} {engine} seed={seed}",
    )


def _check_dataset(seed: int):
    rng = np.random.default_rng(seed)
    name = sorted(datasets.DATASETS)[rng.integers(len(datasets.DATASETS))]
    n = int((0, 1, 257)[rng.integers(3)])
    width = 8 if name in ("RnaSequences", "UrlPaths") else None
    ds = datasets.make_dataset(name, n, seed=seed, width=width)
    got = np.asarray(ops.argsort_records(jnp.asarray(ds.words), cfg=_CFG))
    np.testing.assert_array_equal(
        got, datasets.oracle_argsort(ds), err_msg=f"dataset {name} n={n} seed={seed}"
    )


# ---------------------------------------------------------------------------
# tier-1 bounded sweep
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(24))
def test_fuzz_scalar(seed):
    _check_scalar(seed)


@pytest.mark.parametrize("seed", range(6))
def test_fuzz_records(seed):
    _check_records(seed)


@pytest.mark.parametrize("seed", range(4))
def test_fuzz_datasets(seed):
    _check_dataset(seed)


# ---------------------------------------------------------------------------
# long sweep — CI fuzz job:
#   REPRO_FUZZ_LONG=1 pytest tests/test_fuzz_differential.py -m slow
# (env-gated on top of the marker so a plain tier-1 `pytest -q`, which has
# no -m filter, stays within its time budget)
# ---------------------------------------------------------------------------
_long = pytest.mark.skipif(
    not os.environ.get("REPRO_FUZZ_LONG"),
    reason="long fuzz sweep: set REPRO_FUZZ_LONG=1 (CI fuzz job)",
)


@_long
@pytest.mark.slow
@pytest.mark.parametrize("seed", range(100, 196))
def test_fuzz_scalar_long(seed):
    _check_scalar(seed)


@_long
@pytest.mark.slow
@pytest.mark.parametrize("seed", range(100, 124))
def test_fuzz_records_long(seed):
    _check_records(seed)


@_long
@pytest.mark.slow
@pytest.mark.parametrize("seed", range(100, 112))
def test_fuzz_datasets_long(seed):
    _check_dataset(seed)
