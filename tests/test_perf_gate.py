"""Unit tests for the CI perf-regression gate (benchmarks/check_regression)."""
import json

from benchmarks.check_regression import compare, is_tracked_metric, main


def _bench(us):
    return {"sort_x": [{"bench": "seq", "n": 1024, "dtype": "float32",
                        "algo": "ips4o", "us": us, "speedup": 2.0}]}


def test_tracked_metric_classification():
    assert is_tracked_metric("s_per_call")
    assert is_tracked_metric("batched_us")
    assert is_tracked_metric("part_ns_per_elem")
    assert not is_tracked_metric("speedup")
    assert not is_tracked_metric("coll_bytes_per_dev")
    assert not is_tracked_metric("n")
    # reference-implementation columns are comparisons, not product paths
    assert not is_tracked_metric("loop_us")
    assert not is_tracked_metric("single_us")


def test_within_threshold_passes():
    fails, warns = compare(_bench(100.0), _bench(120.0), 0.25, [])
    assert not fails and not warns


def test_regression_fails():
    fails, _ = compare(_bench(100.0), _bench(130.0), 0.25, [])
    assert len(fails) == 1 and "+30%" in fails[0]


def test_improvement_passes():
    fails, _ = compare(_bench(100.0), _bench(50.0), 0.25, [])
    assert not fails


def test_new_and_missing_rows_warn_only():
    base = _bench(100.0)
    fresh = {"sort_x": [dict(base["sort_x"][0], n=2048)]}
    fails, warns = compare(base, fresh, 0.25, [])
    assert not fails
    assert any("new row" in w for w in warns)
    assert any("missing from fresh" in w for w in warns)


def test_absent_bench_module_does_not_warn_missing():
    # CI runs --only a subset: baseline-only modules are not "missing"
    fails, warns = compare(_bench(100.0), {}, 0.25, [])
    assert not fails and not warns


def test_allowlist_downgrades_to_warning():
    allow = [{"bench": "sort_x", "metric": "us",
              "match": {"algo": "ips4o", "n": 1024},
              "reason": "intentional: engine default changed"}]
    fails, warns = compare(_bench(100.0), _bench(200.0), 0.25, allow)
    assert not fails
    assert any("allowlisted" in w for w in warns)
    # allowlist entries must actually match to apply
    fails, _ = compare(_bench(100.0), _bench(200.0), 0.25,
                       [{"match": {"algo": "other"}, "reason": "no"}])
    assert fails


def test_main_end_to_end(tmp_path, capsys):
    base = tmp_path / "base.json"
    fresh = tmp_path / "fresh.json"
    base.write_text(json.dumps({"schema": 1, "benches": _bench(100.0)}))
    fresh.write_text(json.dumps({"schema": 1, "benches": _bench(200.0)}))
    rc = main(["--baseline", str(base), "--fresh", str(fresh),
               "--allowlist", str(tmp_path / "none.json")])
    assert rc == 1
    assert "FAIL" in capsys.readouterr().out
    fresh.write_text(json.dumps({"schema": 1, "benches": _bench(110.0)}))
    assert main(["--baseline", str(base), "--fresh", str(fresh),
                 "--allowlist", str(tmp_path / "none.json")]) == 0
    # a missing baseline is not an error (first run on a fresh branch)
    assert main(["--baseline", str(tmp_path / "no.json"),
                 "--fresh", str(fresh)]) == 0
