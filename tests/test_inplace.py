"""Peak-memory harness for the in-place block permutation (DESIGN.md §10).

The paper's headline claim is *in-place*: the block permutation phase must
not allocate a second n-sized buffer.  XLA's CPU backend neither honors
donation nor reports aliased buffers, so the harness asserts the property
two ways that are both faithful and portable:

  * **structurally** — the lowered jaxpr of ``permute_blocks_by_dest``
    declares ``input_output_aliases`` mapping the data operand onto the
    output, i.e. on a backend that honors aliasing (TPU) the output *is*
    the input's HBM buffer;
  * **by accounting** — ``compile().memory_analysis()`` gives the compiled
    temp footprint: the kernel's scratch is O(block + nblocks) (two VMEM
    swap buffers, the visited bitmap, scalar state), NOT O(n).  With the
    output aliased onto the data argument, peak live bytes during the
    block move are ``arguments + temp`` = n·itemsize (data, reused) +
    dst + scratch  <=  1.25 · n·itemsize.

The element-granular scatter path (``level_fused`` + ``at[dest].set``) is
deliberately *not* under the 1.25·n bound: a scatter placement is
out-of-place by construction (that is why the block path exists), and
interpret-mode Pallas additionally materializes callback buffers that a
real TPU lowering never allocates.

Also here: adversarial unit tests for the swap-cycle kernel itself —
all-one-bucket (identity permutation), alternating buckets (maximal
cycles), boundary-partial blocks (the §4.3 overflow/cleanup phase), and
random permutation fuzz.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.block_permute import permute_blocks_by_dest, stable_block_dest

BLOCK = 1024  # elements per block (8 sublanes x 128 lanes for u32)


def _mem(f, *args):
    """CompiledMemoryStats for jit(f)(*args); skip if the backend hides it."""
    stats = jax.jit(f).lower(*args).compile().memory_analysis()
    if stats is None or not hasattr(stats, "temp_size_in_bytes"):
        pytest.skip("backend does not expose memory_analysis()")
    return stats


def _permute(a, dst):
    return permute_blocks_by_dest(a, dst, block_elems=BLOCK, interpret=True)


def _ref_permute(a, dst, block_elems=BLOCK):
    """numpy oracle: move block i to slot dst[i]; tail stays put."""
    a = np.asarray(a).copy()
    nblocks = a.shape[0] // block_elems
    body = a[: nblocks * block_elems].reshape(nblocks, block_elems)
    out = np.empty_like(body)
    out[np.asarray(dst)] = body
    a[: nblocks * block_elems] = out.reshape(-1)
    return a


def _rand_perm(nblocks, seed):
    return jnp.asarray(
        np.random.default_rng(seed).permutation(nblocks).astype(np.int32)
    )


# ---------------------------------------------------------------------------
# memory accounting
# ---------------------------------------------------------------------------


class TestPeakMemory:
    def test_aliasing_declared_in_jaxpr(self):
        """The data operand is input/output aliased — on an alias-honoring
        backend the permutation writes into the input's own HBM buffer."""
        n = 64 * BLOCK
        a = jnp.zeros((n,), jnp.uint32)
        dst = _rand_perm(64, 0)
        txt = str(jax.make_jaxpr(_permute)(a, dst))
        assert "input_output_aliases" in txt
        # operand 1 (the data ref; operand 0 is dst) aliases output 0
        assert "(1, 0)" in txt

    def test_scratch_is_block_sized_not_n_sized(self):
        """Compiled temp footprint is O(block + nblocks), far under n."""
        n = 64 * BLOCK
        a = jnp.zeros((n,), jnp.uint32)
        dst = _rand_perm(64, 1)
        stats = _mem(_permute, a, dst)
        n_bytes = n * 4
        # 2 swap buffers + visited bitmap + state + interpret-mode slack
        assert stats.temp_size_in_bytes <= 0.25 * n_bytes, (
            f"temp {stats.temp_size_in_bytes} B exceeds 25% of data "
            f"({n_bytes} B) — scratch is no longer block-sized"
        )

    def test_level_move_live_bytes_under_1_25n(self):
        """Peak live bytes during the block-permutation level move.

        With the output aliased onto the data argument (asserted above),
        live = arguments (data + dst) + temp.  The paper's in-place bound:
        strictly under 1.25 * n * itemsize.
        """
        n = 64 * BLOCK
        a = jnp.zeros((n,), jnp.uint32)
        dst = _rand_perm(64, 2)
        stats = _mem(_permute, a, dst)
        n_bytes = n * 4
        live = stats.argument_size_in_bytes + stats.temp_size_in_bytes
        assert live <= 1.25 * n_bytes, (
            f"live {live} B > 1.25 * {n_bytes} B — block move is no "
            f"longer in-place"
        )

    def test_scratch_does_not_scale_with_n(self):
        """Quadrupling n grows temp only by the visited bitmap (4 B/block),
        not by any per-element buffer."""
        small_blocks, big_blocks = 32, 128
        stats = {}
        for nb in (small_blocks, big_blocks):
            a = jnp.zeros((nb * BLOCK,), jnp.uint32)
            stats[nb] = _mem(_permute, a, _rand_perm(nb, 3)).temp_size_in_bytes
        growth = stats[big_blocks] - stats[small_blocks]
        # visited bitmap + dst staging: tens of bytes per extra block
        assert growth <= 64 * (big_blocks - small_blocks), (
            f"temp grew {growth} B for {big_blocks - small_blocks} extra "
            f"blocks — an O(n) buffer crept into the kernel"
        )


# ---------------------------------------------------------------------------
# adversarial swap-cycle layouts
# ---------------------------------------------------------------------------


class TestBlockPermuteAdversarial:
    def _roundtrip(self, dst, nblocks, n_extra=0, seed=0):
        n = nblocks * BLOCK + n_extra
        a = jnp.asarray(
            np.random.default_rng(seed).integers(0, 1 << 31, n, dtype=np.uint32)
        )
        got = np.asarray(_permute(a, dst))
        np.testing.assert_array_equal(got, _ref_permute(a, dst))

    def test_all_one_bucket_identity(self):
        """Every block already placed: dst = identity — the scan must visit
        each slot once, write it back, and terminate (no infinite cycle)."""
        nblocks = 16
        bb = jnp.zeros((nblocks,), jnp.int32)  # all blocks in bucket 0
        dst = stable_block_dest(bb)
        np.testing.assert_array_equal(np.asarray(dst), np.arange(nblocks))
        self._roundtrip(dst, nblocks, seed=10)

    def test_alternating_buckets_long_cycles(self):
        """Buckets 0,1,0,1,...: the stable dest interleaves halves — the
        permutation decomposes into long swap cycles."""
        nblocks = 16
        bb = jnp.asarray(np.arange(nblocks) % 2, dtype=jnp.int32)
        dst = stable_block_dest(bb)
        # stable grouping: evens (bucket 0) keep order in the first half
        expect = np.empty(nblocks, np.int64)
        expect[0::2] = np.arange(nblocks // 2)
        expect[1::2] = nblocks // 2 + np.arange(nblocks // 2)
        np.testing.assert_array_equal(np.asarray(dst), expect)
        self._roundtrip(dst, nblocks, seed=11)

    def test_boundary_partial_block_cleanup(self):
        """n not a multiple of block_elems: the trailing partial block is
        the overflow block — full blocks permute, the tail is re-attached
        byte-identical (cleanup phase, paper §4.3)."""
        nblocks = 8
        for extra in (1, 127, 128, BLOCK - 1):
            self._roundtrip(_rand_perm(nblocks, 12), nblocks,
                            n_extra=extra, seed=extra)

    def test_single_full_cycle(self):
        """dst[i] = (i+1) mod N: one cycle through every block."""
        nblocks = 12
        dst = jnp.asarray((np.arange(nblocks) + 1) % nblocks, dtype=jnp.int32)
        self._roundtrip(dst, nblocks, seed=13)

    def test_random_permutation_fuzz(self):
        for seed in range(5):
            self._roundtrip(_rand_perm(24, 100 + seed), 24, seed=seed)

    def test_single_block_noop(self):
        a = jnp.arange(BLOCK, dtype=jnp.uint32)
        got = _permute(a, jnp.zeros((1,), jnp.int32))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(a))

    def test_stable_block_dest_matches_argsort(self):
        bb = jnp.asarray([3, 1, 3, 0, 1, 1, 2, 0], dtype=jnp.int32)
        dst = np.asarray(stable_block_dest(bb))
        order = np.argsort(np.asarray(bb), kind="stable")
        inv = np.empty_like(order)
        inv[order] = np.arange(order.size)
        np.testing.assert_array_equal(dst, inv)
