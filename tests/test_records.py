"""Multi-word record sorting suite (DESIGN.md §11).

Three layers, cheapest first:

  * **encoding properties** (host-only numpy, no device sort):
    ``encode_words`` / ``decode_words`` round-trip strings (empty,
    non-ASCII bytes, prefix pairs) and mixed-dtype columns; word order
    *is* record order — checked against Python's own ``sorted`` over
    bytes / tuples, including the keyspace refinements (-0.0 < +0.0);
  * **tie-break stability**: duplicate full records keep input order, the
    payload permutation is bit-identical to ``np.lexsort``;
  * **the acceptance matrix**: all four dataset families x
    {xla, pallas} x {tree, radix, auto} at n=4096, word-for-word equal to
    the independent numpy oracle (``datasets.oracle_argsort`` — byte
    strings / raw-column lexsort, no keyspace machinery).

String datasets are width-clipped to 8 bytes (W=2) and composite
families are W=3, so matrix cells share traces per (W, engine,
classifier) and the matrix compiles a handful of programs, not 24.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from oracle import lex_argsort_words
from repro import ops
from repro.core.ips4o import SortConfig
from repro.data import datasets
from repro.ops import keyspace

# small geometry so level passes engage at n=4096
_CFG = SortConfig(base_case=1024, kmax=32, tile=256, max_sample=512)
_N = 4096
_WIDTH = 8  # byte budget for string families: W=2, heavier prefix ties

ENGINES = ("xla", "pallas")
CLASSIFIERS = ("tree", "radix", "auto")


# ---------------------------------------------------------------------------
# encode_words / decode_words: round-trip and order preservation (host-only)
# ---------------------------------------------------------------------------
def test_strings_roundtrip_and_order():
    recs = [
        b"",
        b"a",
        b"ab",
        b"abc",            # proper prefix chain
        "naïve".encode(),  # non-ASCII utf-8
        b"abc\xffx",       # high bytes
        bytes(range(1, 21)),
        b"abc",            # exact duplicate
    ]
    words, spec = keyspace.encode_words(recs)
    assert spec.kind == "bytes" and words.dtype == np.uint32
    assert words.shape == (len(recs), spec.words)
    assert keyspace.decode_words(words, spec) == recs
    # row-lexicographic word order == bytes order, ties included
    got = np.lexsort(tuple(reversed([words[:, j] for j in range(spec.words)])))
    want = sorted(range(len(recs)), key=lambda i: (recs[i], i))
    assert got.tolist() == want


def test_strings_width_pad_validate_and_nul():
    words, spec = keyspace.encode_words([b"ab"], width=9)
    assert spec.row_bytes == 9 and spec.words == 3 and words.shape == (1, 3)
    assert keyspace.decode_words(words, spec) == [b"ab"]
    with pytest.raises(ValueError):
        keyspace.encode_words([b"abcd"], width=3)  # record exceeds width
    with pytest.raises(ValueError):
        keyspace.encode_words([b"a\x00b"])  # NUL is the pad code point
    # empty input and all-empty records still produce a (n, 1) matrix
    w0, s0 = keyspace.encode_words([])
    assert w0.shape == (0, 1) and s0.words == 1
    w1, s1 = keyspace.encode_words([b"", b""])
    assert w1.shape == (2, 1) and keyspace.decode_words(w1, s1) == [b"", b""]


def test_columns_roundtrip_mixed_dtypes():
    rng = np.random.default_rng(0)
    cols = (
        rng.standard_normal(64).astype(np.float32),
        rng.integers(-300, 300, 64).astype(np.int16),
        rng.integers(0, 256, 64).astype(np.uint8),
        rng.integers(-(2**31), 2**31 - 1, 64).astype(np.int32),
    )
    words, spec = keyspace.encode_words(cols)
    assert spec.kind == "columns" and spec.row_bytes == 4 + 2 + 1 + 4
    assert spec.words == 3 and words.shape == (64, 3)
    back = keyspace.decode_words(words, spec)
    for a, b in zip(back, cols):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(a, b)


def test_columns_order_matches_tuple_order():
    # small domains force ties at every level; int16 crosses zero so the
    # sign-flip encoding is what keeps word order == value order
    rng = np.random.default_rng(1)
    cols = (
        rng.integers(-3, 3, 200).astype(np.int16),
        rng.integers(0, 2, 200).astype(np.uint8),
        rng.integers(-2, 2, 200).astype(np.int32),
    )
    words, spec = keyspace.encode_words(cols)
    got = np.lexsort(tuple(reversed([words[:, j] for j in range(spec.words)])))
    tuples = list(zip(*[c.tolist() for c in cols]))
    want = sorted(range(200), key=lambda i: (tuples[i], i))
    assert got.tolist() == want


def test_columns_negzero_and_nan_refinement():
    x = np.asarray([0.0, -0.0, np.nan, -np.inf, np.inf, 1.0], np.float32)
    words, spec = keyspace.encode_words((x,))
    order = np.argsort(words[:, 0], kind="stable")
    # -inf < -0.0 < +0.0 < 1.0 < +inf < NaN
    assert order.tolist() == [3, 1, 0, 5, 4, 2]
    (back,) = keyspace.decode_words(words, spec)
    assert np.signbit(back[1]) and not np.signbit(back[0])
    assert np.isnan(back[2])


def test_columns_rejects_bad_input():
    with pytest.raises(ValueError):
        keyspace.encode_words((np.zeros(3), np.zeros(4)))
    with pytest.raises(ValueError):
        keyspace.encode_words((np.zeros((2, 2)), np.zeros((2, 2))))
    with pytest.raises(TypeError):
        keyspace.encode_words((np.zeros(3, np.complex64),))


# ---------------------------------------------------------------------------
# tie-break stability on device
# ---------------------------------------------------------------------------
def test_sort_records_stable_payload_on_duplicates():
    # 8 distinct records replicated 512x: every word ties everywhere, so
    # the permutation is pure stability; lexsort is the stable oracle
    rng = np.random.default_rng(2)
    base = rng.integers(0, 3, (8, 3)).astype(np.uint32)
    words = base[rng.integers(0, 8, _N)]
    got = np.asarray(ops.argsort_records(jnp.asarray(words), cfg=_CFG))
    np.testing.assert_array_equal(got, lex_argsort_words(words))
    out, vals = ops.sort_records(
        jnp.asarray(words), jnp.arange(_N, dtype=jnp.int32), cfg=_CFG
    )
    np.testing.assert_array_equal(np.asarray(vals), lex_argsort_words(words))
    np.testing.assert_array_equal(np.asarray(out), words[lex_argsort_words(words)])


def test_sort_records_float_words_nan_negzero():
    rng = np.random.default_rng(3)
    pool = np.asarray([np.nan, -0.0, 0.0, -1.5, 1.5, np.inf, -np.inf], np.float32)
    words = rng.choice(pool, (_N, 2))
    got = np.asarray(ops.argsort_records(jnp.asarray(words), cfg=_CFG))
    np.testing.assert_array_equal(got, lex_argsort_words(words))


def test_records_tiny_and_single_word():
    assert ops.argsort_records(jnp.zeros((0, 2), jnp.uint32)).shape == (0,)
    assert ops.argsort_records(jnp.zeros((1, 3), jnp.uint32)).tolist() == [0]
    w = jnp.asarray([[5], [1], [3]], jnp.uint32)  # W=1: no tie-break levels
    assert ops.argsort_records(w, cfg=_CFG).tolist() == [1, 2, 0]
    with pytest.raises(ValueError):
        ops.argsort_records(jnp.zeros((4,), jnp.uint32))
    with pytest.raises(ValueError):
        ops.argsort_records(jnp.zeros((4, 0), jnp.uint32))


# ---------------------------------------------------------------------------
# the acceptance matrix: datasets x engines x classifiers
# ---------------------------------------------------------------------------
def _dataset(name):
    width = _WIDTH if name in ("RnaSequences", "UrlPaths") else None
    return datasets.make_dataset(name, _N, seed=11, width=width)


@pytest.mark.parametrize("classifier", CLASSIFIERS)
@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("name", sorted(datasets.DATASETS))
def test_dataset_matrix(name, engine, classifier):
    ds = _dataset(name)
    got = np.asarray(
        ops.argsort_records(
            jnp.asarray(ds.words), cfg=_CFG, engine=engine, classifier=classifier
        )
    )
    np.testing.assert_array_equal(got, datasets.oracle_argsort(ds))


@pytest.mark.parametrize("name", sorted(datasets.DATASETS))
def test_dataset_sort_records_bit_match(name):
    ds = _dataset(name)
    out = np.asarray(ops.sort_records(jnp.asarray(ds.words), cfg=_CFG))
    np.testing.assert_array_equal(out, ds.words[datasets.oracle_argsort(ds)])
