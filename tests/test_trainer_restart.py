"""Fault tolerance: checkpoint/restart must reproduce the uninterrupted run.

Trains a reduced config 6 steps straight, then the same thing as
3 steps -> "crash" -> restore -> 3 more steps, and compares final params
bitwise (the data pipeline is deterministic in (seed, step), restore
fast-forwards the stream, and the step is deterministic on CPU).
"""
import numpy as np
import jax
import pytest

from repro.configs.registry import get_reduced
from repro.data.pipeline import SyntheticLM
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import TrainConfig, Trainer


@pytest.mark.slow
def test_restart_bitwise_identical(tmp_path):
    cfg = get_reduced("yi-9b")
    tcfg = TrainConfig(microbatch=2, warmup_steps=2, total_steps=6,
                       adamw=AdamWConfig(lr=1e-3))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    data = lambda: iter(SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=4, seed=7))

    def leaves(state):
        return [np.asarray(x) for x in jax.tree.leaves(state["params"])]

    # uninterrupted
    t0 = Trainer(cfg, tcfg, mesh, ckpt_dir=None, seed=0)
    t0.init_state()
    t0.run(data(), 6, ckpt_every=100, log_every=100, log=lambda *_: None)
    ref = leaves(t0.state)

    # interrupted at step 3
    ck = str(tmp_path / "ck")
    t1 = Trainer(cfg, tcfg, mesh, ckpt_dir=ck, seed=0)
    t1.init_state()
    t1.run(data(), 3, ckpt_every=3, log_every=100, log=lambda *_: None)
    del t1  # "crash"

    t2 = Trainer(cfg, tcfg, mesh, ckpt_dir=ck, seed=0)
    t2.init_state()
    assert t2.maybe_restore(), "no checkpoint found"
    assert t2.step_num == 3
    it = data()
    for _ in range(t2.step_num):  # deterministic fast-forward
        next(it)
    t2.run(it, 3, ckpt_every=100, log_every=100, log=lambda *_: None)

    for a, b in zip(ref, leaves(t2.state)):
        np.testing.assert_array_equal(a, b)
