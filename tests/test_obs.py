"""repro.obs test suite (DESIGN.md §12).

The contract under test, in order of importance:

  * **disabled is free**: with obs off (the default), entry points trace
    to bit-identical jaxprs (zero added ops, no effects), ``trace()``
    returns one shared allocation-free null span, and the compiled hot
    path is untouched — the same executable runs before and after an
    enable/disable round-trip;
  * **enabled is structured**: eager sorts record properly nested
    sample/classify/partition/base-case spans under the op root, in-jit
    functional stats (base-case counts, bucket imbalance) arrive through
    unordered debug callbacks, and the host-side counters (plan cache,
    launch specs, stream spills, scheduler admissions) tick at their
    call sites;
  * **exports are valid**: the JSONL lines are typed records, the Chrome
    trace-event file is schema-correct (Perfetto-loadable), and
    ``summary()`` renders.

jax caveat encoded here: ``jax.make_jaxpr`` (and jit) cache traces by
function identity, so every trace after an ``obs.enabled`` toggle uses a
FRESH lambda — re-tracing the same function object would return the
stale cached jaxpr (see ``obs.enabled``'s docstring).
"""
import json
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import obs, ops
from repro.core.ips4o import SortConfig

# small geometry so a level pass + base case engage at test sizes
_CFG = SortConfig(base_case=1024, tile=512, max_sample=1024)
_N = 4096


@pytest.fixture(autouse=True)
def _obs_clean():
    obs.enabled(False)
    obs.reset()
    yield
    obs.enabled(False)
    obs.reset()


def _keys(n=_N, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal(n), jnp.float32
    )


# -- disabled: zero cost ----------------------------------------------------


def test_disabled_adds_zero_traced_ops():
    """The jaxpr-identity proof: obs off adds nothing to traced code, and
    an enable/disable round-trip returns to the identical jaxpr."""
    x = _keys()
    base = jax.make_jaxpr(lambda a: ops.sort(a, cfg=_CFG))(x)
    assert "debug_callback" not in str(base)
    assert not base.effects
    obs.enabled(True)
    inst = jax.make_jaxpr(lambda a: ops.sort(a, cfg=_CFG))(x)
    assert "debug_callback" in str(inst)
    obs.enabled(False)
    again = jax.make_jaxpr(lambda a: ops.sort(a, cfg=_CFG))(x)
    assert str(again) == str(base)
    assert not again.effects


def test_disabled_null_span_is_shared_and_recorder_untouched():
    s1 = obs.trace("a")
    s2 = obs.trace("b", attr=1)
    assert s1 is s2  # one shared null instance: no per-call allocation
    with obs.trace("c"):
        pass
    assert obs.recorder().spans == []
    assert obs.recorder().counters == {}


def test_disabled_span_overhead_budget():
    t0 = time.perf_counter()
    for _ in range(10_000):
        with obs.trace("x", a=1):
            pass
    dt = time.perf_counter() - t0
    # generous CI budget: < 5us per disabled span (measured ~0.1us)
    assert dt < 0.05, f"disabled trace() too slow: {dt * 100:.1f}us/span"


def test_disabled_toggle_keeps_compiled_fn_fast():
    """An enabled->disabled round-trip must not slow the already-compiled
    hot path: the executable is the same object (no retrace), so the
    min-of-k wall clock stays within 1%."""
    x = _keys(1 << 16)
    f = jax.jit(lambda a: ops.sort(a, cfg=_CFG))
    jax.block_until_ready(f(x))

    def t_min(k=7):
        best = float("inf")
        for _ in range(k):
            t0 = time.perf_counter()
            jax.block_until_ready(f(x))
            best = min(best, time.perf_counter() - t0)
        return best

    for _ in range(3):  # re-measure on a noisy-neighbour miss
        t0 = t_min()
        obs.enabled(True)
        obs.enabled(False)
        t1 = t_min()
        if t1 <= t0 * 1.01:
            return
    assert t1 <= t0 * 1.01, f"disabled-obs overhead {t1 / t0 - 1:.1%} > 1%"


# -- enabled: structure and metrics ----------------------------------------


def test_enabled_eager_sort_spans_nest():
    obs.enabled(True)
    x = _keys()
    out = ops.sort(x, cfg=_CFG)
    jax.effects_barrier()
    np.testing.assert_array_equal(np.asarray(out), np.sort(np.asarray(x)))
    spans = obs.recorder().spans
    names = {s["name"] for s in spans}
    assert {"ops.sort", "ips4o_sort", "level_pass", "sample", "classify",
            "partition", "base_case"} <= names
    by_id = {s["id"]: s for s in spans}
    root = next(s for s in spans if s["name"] == "ops.sort")
    assert root["parent"] is None and root["depth"] == 0
    for child, parent in [("ips4o_sort", "ops.sort"),
                          ("level_pass", "ips4o_sort"),
                          ("sample", "level_pass"),
                          ("classify", "level_pass"),
                          ("partition", "level_pass"),
                          ("base_case", "ips4o_sort")]:
        s = next(s for s in spans if s["name"] == child)
        assert by_id[s["parent"]]["name"] == parent, (child, parent)
        assert s["dur_ns"] >= 0


def test_enabled_jit_runtime_metrics():
    """In-jit functional stats travel through unordered debug callbacks:
    base-case count and bucket-imbalance histogram survive jit."""
    obs.enabled(True)
    jax.clear_caches()  # jits traced while disabled carry no obs hooks
    try:
        x = _keys()
        out = jax.jit(lambda a: ops.sort(a, cfg=_CFG))(x)
        jax.block_until_ready(out)
        jax.effects_barrier()
        np.testing.assert_array_equal(np.asarray(out), np.sort(np.asarray(x)))
        assert obs.counter_value("sort.base_case") >= 1
        imb = obs.hist_values("sort.bucket_imbalance")
        assert imb, "bucket imbalance histogram empty"
        assert all(v >= 1.0 for v in imb)  # max/mean is >= 1 by construction
    finally:
        jax.clear_caches()


def test_plan_cache_and_launch_spec_counters(tmp_path):
    from repro.launch.roofline import launch_spec
    from repro.ops.plan import PlanCache

    obs.enabled(True)
    cache = PlanCache(path=str(tmp_path / "plans.json"))
    f = cache.get_sorter(_N, jnp.float32)
    g = cache.get_sorter(_N, jnp.float32)
    assert f is g
    assert obs.counter_value("plan_cache.miss", family="sort") >= 1
    assert obs.counter_value("plan_cache.compiled_miss") == 1
    assert obs.counter_value("plan_cache.compiled_hit") == 1
    spec = launch_spec("classify", 4, 128)
    assert spec.rows > 0
    assert obs.counter_value("launch.spec", kind="classify") == 1
    # rows=0 (XLA fallback) is recorded too, distinguishably
    launch_spec("classify", 4, 128, n=1000)
    assert obs.counter_value("launch.spec", kind="classify", rows="0") == 1


def test_stream_metrics():
    from repro.stream import external_sort

    obs.enabled(True)
    data = np.random.default_rng(1).integers(0, 1 << 20, 4096).astype(np.int32)
    out = external_sort(data, chunk_size=1024)
    np.testing.assert_array_equal(out, np.sort(data))
    # 4 runs -> 2 tournament rounds; each merged pair spills to host
    assert obs.counter_value("stream.tournament_rounds") == 2
    assert obs.counter_value("stream.spill_bytes") > 0
    rounds = [s for s in obs.recorder().spans if s["name"] == "stream.merge_round"]
    assert len(rounds) == 2
    root = next(s for s in obs.recorder().spans
                if s["name"] == "stream.external_sort")
    by_id = {s["id"]: s for s in obs.recorder().spans}
    assert all(by_id[r["parent"]]["name"] == "stream.external_sort"
               for r in rounds)
    assert root["attrs"]["chunks"] == 4


def test_scheduler_metrics():
    from repro.serve.scheduler import Request, Scheduler

    obs.enabled(True)
    s = Scheduler(batch_size=2)
    for i in range(4):
        s.submit(Request(uid=i, prompt_len=1, max_new=10 - i))
    batch = s.next_batch()
    assert [r.uid for r in batch] == [3, 2]  # shortest remaining first
    assert obs.counter_value("serve.admitted") == 2
    assert any(sp["name"] == "serve.next_batch"
               for sp in obs.recorder().spans)


def test_timed_min_records_even_while_disabled():
    rec = obs.Recorder()
    calls = []
    t = obs.timed_min("phase:x", lambda: calls.append(1),
                      iters=3, warmup=1, recorder=rec, n=_N)
    assert t >= 0.0
    spans = [s for s in rec.spans if s["name"] == "phase:x"]
    assert len(spans) == 3
    assert len(calls) == 4  # 1 warmup + 3 timed
    assert {s["attrs"]["iter"] for s in spans} == {0, 1, 2}
    assert obs.recorder().spans == []  # the global recorder stays clean


# -- exporters --------------------------------------------------------------


def test_exporters_and_summary(tmp_path):
    obs.enabled(True)
    x = _keys()
    ops.sort(x, cfg=_CFG)  # eager: callbacks fire synchronously
    jax.effects_barrier()

    jl = tmp_path / "t.jsonl"
    obs.export_jsonl(str(jl))
    lines = [json.loads(ln) for ln in jl.read_text().splitlines() if ln]
    kinds = {ln["type"] for ln in lines}
    assert {"span", "counter", "histogram"} <= kinds
    for ln in lines:
        if ln["type"] == "span":
            assert isinstance(ln["ts_us"], float)
            assert isinstance(ln["dur_us"], float) and ln["dur_us"] >= 0
            assert isinstance(ln["attrs"], dict)

    ct = tmp_path / "t.trace.json"
    obs.export_chrome_trace(str(ct))
    trace = json.loads(ct.read_text())
    assert trace["displayTimeUnit"] == "ms"
    evs = trace["traceEvents"]
    assert any(e["ph"] == "X" for e in evs)
    for e in evs:
        assert e["ph"] in ("M", "X", "i", "C")
        assert "name" in e and "pid" in e
        if e["ph"] == "X":
            assert e["dur"] >= 0 and isinstance(e["ts"], float)
    # span names survive into the chrome trace
    assert {"ops.sort", "level_pass"} <= {
        e["name"] for e in evs if e["ph"] == "X"
    }

    s = obs.summary()
    assert "ops.sort" in s and "spans" in s
