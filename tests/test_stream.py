"""repro.stream test suite (ISSUE 4): out-of-core sort, merge-path merge,
streaming ops, plan-cache stream keys, and the rewired callers.

The merge acceptance bar: ``external_sort`` over >= 4 chunks bit-identical
to a sort of the full concatenation for all nine paper distributions x
{f32, i32} x both merge engines; merge stability (payload rows, duplicate
keys straddling run boundaries, NaN / -0.0, ragged and empty runs, k=1)
property-tested against ``jnp.sort`` / ``jnp.argsort(stable=True)`` of
the concatenation.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from oracle import stable_oracle as _stable_oracle
from repro import ops, stream
from repro.data.distributions import DISTRIBUTIONS, make_input
from repro.kernels.merge_path import merge_path_partition, merge_path_perm
from repro.kernels.ref import merge_path_perm_ref
from repro.ops.plan import PlanCache, StreamPlan

ENGINES = ("xla", "pallas")


# ---------------------------------------------------------------------------
# external_sort: the ISSUE acceptance sweep
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
@pytest.mark.parametrize("dist", sorted(DISTRIBUTIONS))
def test_external_sort_distributions(dist, dtype, engine):
    x = make_input(dist, 4096, dtype, seed=5)
    got = stream.external_sort(x, chunk_size=1024, engine=engine)  # 4 chunks
    assert got.dtype == x.dtype
    np.testing.assert_array_equal(got, np.sort(x))


def test_external_sort_ragged_and_generator():
    x = make_input("TwoDup", 3000, np.int32, seed=2)  # ragged tail chunk
    np.testing.assert_array_equal(
        stream.external_sort(x, chunk_size=1024), np.sort(x)
    )
    chunks = [x[:1024], x[1024:2048], x[2048:]]  # generator-fed source
    np.testing.assert_array_equal(
        stream.external_sort(iter(chunks), chunk_size=1024), np.sort(x)
    )


def test_external_argsort_is_sorting_permutation():
    x = make_input("RootDup", 4000, np.int32, seed=3)
    idx = stream.external_argsort(x, chunk_size=1000)
    assert sorted(idx.tolist()) == list(range(4000))
    np.testing.assert_array_equal(x[idx], np.sort(x))
    # distinct keys: bit-identical to the stable argsort
    y = np.random.default_rng(0).permutation(4000).astype(np.int32)
    np.testing.assert_array_equal(
        stream.external_argsort(y, chunk_size=1000), np.argsort(y, kind="stable")
    )


# ---------------------------------------------------------------------------
# merge: stability, payloads, engine parity
# ---------------------------------------------------------------------------
def _stable_runs(x: jnp.ndarray, bounds):
    """Split x at bounds; per-run stable sort with global source indices —
    the setup under which a stable merge must reproduce the global stable
    argsort exactly.

    Run order (and the oracle, ``oracle.stable_oracle``) lives in the
    *keyspace* total order: ``jnp.sort`` in this jax version leaves
    -0.0/+0.0 grouped but unordered, while the keyspace (and therefore
    the merge) orders -0.0 strictly before +0.0.
    """
    enc = ops.keyspace.encode(x)
    runs, idxs = [], []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        order = jnp.argsort(enc[lo:hi], stable=True)
        runs.append(x[lo:hi][order])
        idxs.append(order.astype(jnp.int32) + lo)
    return runs, idxs


@pytest.mark.parametrize("engine", ENGINES)
def test_merge_stability_duplicates_across_boundaries(engine):
    # duplicate-heavy keys so every run boundary straddles equal keys
    x = jnp.asarray(np.random.default_rng(7).integers(0, 5, 700).astype(np.int32))
    runs, idxs = _stable_runs(x, [0, 200, 450, 700])
    keys, src = stream.merge(runs, values=idxs, engine=engine, tile=64)
    ok, operm = _stable_oracle(x)
    np.testing.assert_array_equal(np.asarray(keys), np.asarray(ok))
    np.testing.assert_array_equal(np.asarray(src), np.asarray(operm))


@pytest.mark.parametrize("engine", ENGINES)
def test_merge_nan_negzero_payload(engine):
    pool = np.asarray(
        [np.nan, -0.0, 0.0, -np.inf, np.inf, 1.5, -1.5, 1.5], np.float32
    )
    x = jnp.asarray(np.random.default_rng(3).choice(pool, 300))
    runs, idxs = _stable_runs(x, [0, 80, 150, 300])
    keys, src = stream.merge(runs, values=idxs, engine=engine, tile=32)
    oracle, operm = _stable_oracle(x)
    np.testing.assert_array_equal(np.asarray(keys), np.asarray(oracle))
    # assert_array_equal treats -0.0 == 0.0; pin the sign bits too
    np.testing.assert_array_equal(
        np.signbit(np.asarray(keys)), np.signbit(np.asarray(oracle))
    )
    np.testing.assert_array_equal(np.asarray(src), np.asarray(operm))


@pytest.mark.parametrize("engine", ENGINES)
def test_merge_ragged_empty_and_k1(engine):
    a = jnp.sort(jnp.asarray([3.0, 1.0, 2.0], jnp.float32))
    empty = jnp.zeros((0,), jnp.float32)
    out = stream.merge([empty, a, empty, jnp.asarray([1.5], jnp.float32), empty],
                       engine=engine, tile=8)
    np.testing.assert_array_equal(
        np.asarray(out), [1.0, 1.5, 2.0, 3.0]
    )
    np.testing.assert_array_equal(np.asarray(stream.merge([a], engine=engine)),
                                  np.asarray(a))  # k=1 passthrough
    np.testing.assert_array_equal(  # payload rows (2-D leaves) ride along
        np.asarray(stream.merge(
            [a, a],
            values=[jnp.zeros((3, 2), jnp.int32), jnp.ones((3, 2), jnp.int32)],
        )[1]).sum(), 6)


def test_merge_rejects_bad_input():
    with pytest.raises(ValueError):
        stream.merge([])
    with pytest.raises(ValueError):
        stream.merge([jnp.zeros((2, 2))])
    with pytest.raises(ValueError):
        stream.merge([jnp.zeros(2, jnp.float32), jnp.zeros(2, jnp.int32)])
    with pytest.raises(ValueError):
        stream.merge([jnp.zeros(2)], values=[])
    with pytest.raises(ValueError):
        stream.merge_perm(jnp.zeros(2), jnp.zeros(2), engine="cuda")


# deterministic randomized sweep over the same edge surface the hypothesis
# suite (tests/test_stream_properties.py) explores — this one always runs,
# even where hypothesis is not installed
_POOL = np.asarray(
    [np.nan, -0.0, 0.0, -np.inf, np.inf, 1.0, -1.0, 2.5, 2.5, -2.5], np.float32
)


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("seed", range(6))
def test_merge_randomized_edge_sweep(engine, seed):
    rng = np.random.default_rng(seed)
    k = int(rng.integers(1, 6))
    lens = [int(rng.integers(0, 26)) for _ in range(k)]
    runs_np = [rng.choice(_POOL, ln) for ln in lens]
    x = jnp.asarray(np.concatenate(runs_np) if sum(lens) else np.zeros(0, np.float32))
    if x.shape[0] == 0:
        return
    bounds = np.cumsum([0] + lens).tolist()
    runs, idxs = _stable_runs(x, bounds)
    tile = int(rng.choice([8, 64]))
    keys, src = stream.merge(runs, values=idxs, engine=engine, tile=tile)
    oracle, operm = _stable_oracle(x)
    np.testing.assert_array_equal(np.asarray(keys), np.asarray(oracle))
    np.testing.assert_array_equal(
        np.signbit(np.asarray(keys)), np.signbit(np.asarray(oracle))
    )
    np.testing.assert_array_equal(np.asarray(src), np.asarray(operm))


# ---------------------------------------------------------------------------
# the merge-path kernel itself
# ---------------------------------------------------------------------------
def test_merge_path_kernel_vs_ref():
    rng = np.random.default_rng(11)
    for _ in range(10):
        na, nb = int(rng.integers(1, 400)), int(rng.integers(1, 400))
        a = jnp.asarray(np.sort(rng.integers(0, 30, na).astype(np.uint32)))
        b = jnp.asarray(np.sort(rng.integers(0, 30, nb).astype(np.uint32)))
        for tile in (16, 128):
            np.testing.assert_array_equal(
                np.asarray(merge_path_perm(a, b, tile=tile, interpret=True)),
                np.asarray(merge_path_perm_ref(a, b)),
            )


def test_merge_path_partition_properties():
    rng = np.random.default_rng(4)
    a = jnp.asarray(np.sort(rng.integers(0, 10, 130).astype(np.uint32)))
    b = jnp.asarray(np.sort(rng.integers(0, 10, 70).astype(np.uint32)))
    d = jnp.arange(0, 201, 16, dtype=jnp.int32)
    part = np.asarray(merge_path_partition(a, b, d))
    # i(d) counts A-elements among the first d outputs of the stable merge
    perm = np.asarray(merge_path_perm_ref(a, b))
    oracle = [int(np.sum(perm[:dd] < 130)) for dd in np.asarray(d)]
    np.testing.assert_array_equal(part, oracle)


# ---------------------------------------------------------------------------
# streaming ops
# ---------------------------------------------------------------------------
def test_streaming_topk_both_directions():
    x = make_input("Exponential", 5000, np.float32, seed=9)
    v, i = stream.streaming_topk(x, 7, chunk_size=1500)
    np.testing.assert_array_equal(v, np.sort(x)[::-1][:7])
    np.testing.assert_array_equal(x[i], v)
    v2, i2 = stream.streaming_topk(x, 7, chunk_size=1500, largest=False)
    np.testing.assert_array_equal(v2, np.sort(x)[:7])
    np.testing.assert_array_equal(x[i2], v2)


def test_streaming_topk_k_exceeds_stream():
    x = np.asarray([3.0, 1.0, 2.0], np.float32)
    v, i = stream.streaming_topk(x, 10, chunk_size=2)
    np.testing.assert_array_equal(v, [3.0, 2.0, 1.0])
    np.testing.assert_array_equal(x[i], v)


def test_streaming_group_by_matches_unique():
    x = make_input("EightDup", 6000, np.int32, seed=6)
    vals, counts = stream.streaming_group_by(x, chunk_size=1000)
    uv, uc = np.unique(x, return_counts=True)
    np.testing.assert_array_equal(vals, uv)
    np.testing.assert_array_equal(counts, uc)
    assert counts.sum() == 6000


def test_streaming_group_by_nan_classes():
    x = np.asarray([1.0, np.nan, 1.0, np.nan, -0.0, 0.0], np.float32)
    vals, counts = stream.streaming_group_by(x, chunk_size=2)
    # keyspace classes: -0.0 < 0.0 < 1.0 < NaN (one class)
    assert np.isnan(vals[-1]) and counts[-1] == 2
    np.testing.assert_array_equal(counts, [1, 1, 2, 2])
    np.testing.assert_array_equal(np.signbit(vals[:2]), [True, False])


# ---------------------------------------------------------------------------
# plan cache: the stream: key family
# ---------------------------------------------------------------------------
def test_stream_plan_tune_roundtrip(tmp_path):
    pc = PlanCache(path=str(tmp_path / "plans.json"))
    plan = pc.stream_plan(512, 4, jnp.int32, tune=True)
    assert isinstance(plan, StreamPlan)
    from repro.ops.plan import _stream_tiles

    assert plan.engine in ENGINES and plan.merge_tile in _stream_tiles()
    # persisted under the stream: family, reloadable by a fresh cache
    pc2 = PlanCache(path=pc.path)
    assert pc2.stream_plan(512, 4, jnp.int32) == plan
    key = PlanCache._stream_key(512, 4, jnp.int32)
    assert key.startswith("stream:chunk=512:fanin=4")
    assert key in pc2._plans and "us" in pc2._plans[key]
    # explicit engine overrides the planned engine, keeps the tile
    forced = pc2.stream_plan(512, 4, jnp.int32, engine="pallas")
    assert forced.engine == "pallas" and forced.merge_tile == plan.merge_tile
    # untuned key: backend heuristic (xla in this CPU container)
    assert pc2.stream_plan(512, 8, jnp.int32).engine == "xla"


def test_stream_plan_tolerates_foreign_entry(tmp_path):
    import json

    path = tmp_path / "plans.json"
    key = PlanCache._stream_key(256, 2, jnp.float32)
    path.write_text(json.dumps({key: {"config": {"merge_tile": "big"}}}))
    plan = PlanCache(path=str(path)).stream_plan(256, 2, jnp.float32)
    assert plan == StreamPlan(256, 2)  # defaults, never a crash


# ---------------------------------------------------------------------------
# rewired callers
# ---------------------------------------------------------------------------
def test_pack_by_length_out_of_core_matches_in_core():
    from repro.data.pipeline import pack_by_length

    rng = np.random.default_rng(0)
    lengths = rng.integers(1, 512, 4000).astype(np.int32)
    row_id, offset, num_rows = pack_by_length(lengths, 512, chunk_size=1000)
    row_id0, offset0, num_rows0 = pack_by_length(lengths, 512)
    # both paths pack the same sorted length sequence -> same row structure
    assert num_rows == num_rows0
    fill = np.zeros(num_rows, np.int64)
    for d in range(4000):
        assert 0 <= offset[d] and offset[d] + min(lengths[d], 512) <= 512
        fill[row_id[d]] += min(lengths[d], 512)
    assert (fill <= 512).all() and fill.sum() == np.minimum(lengths, 512).sum()


def test_scheduler_merged_backlog_admission():
    from repro.serve.scheduler import Request, Scheduler, admit_many

    s = Scheduler(batch_size=4)
    for uid, rem in [(10, 5), (11, 2), (12, 9), (13, 2)]:
        s.submit(Request(uid, 1, rem))
    s.attach_backlog([Request(0, 1, 7), Request(1, 1, 2), Request(2, 1, 4)])
    got = [(r.uid, r.remaining) for r in s.next_batch()]
    # shortest-remaining-first across BOTH sources; backlog wins ties (older)
    assert got == [(1, 2), (11, 2), (13, 2), (2, 4)]
    assert [r.uid for r in s.backlog] == [0]
    assert [r.uid for r in s.queue] == [10, 12]
    got2 = [(r.uid, r.remaining) for r in s.next_batch()]
    assert got2 == [(10, 5), (0, 7), (12, 9)]
    assert not s.backlog and not s.queue
    assert s.next_batch() == []

    # attach_backlog sorts an unsorted spill deterministically (FIFO ties)
    s2 = Scheduler(batch_size=2)
    s2.attach_backlog([Request(7, 1, 9), Request(8, 1, 3), Request(9, 1, 9)])
    assert [r.uid for r in s2.backlog] == [8, 7, 9]
    assert [r.uid for r in s2.next_batch()] == [8, 7]

    # admit_many routes backlog-carrying schedulers through the merged view
    s3 = Scheduler(batch_size=2)
    [s3.submit(Request(u, 1, r)) for u, r in [(1, 3), (2, 1)]]
    s4 = Scheduler(batch_size=2)
    s4.submit(Request(3, 1, 5))
    s4.attach_backlog([Request(4, 1, 5)])
    res = admit_many([s3, s4])
    assert [r.uid for r in res[0]] == [2, 1]
    assert [r.uid for r in res[1]] == [4, 3]  # backlog wins the tie on 5


def test_scheduler_backlog_repeated_attach_stays_sorted():
    from repro.serve.scheduler import Request, Scheduler

    s = Scheduler(batch_size=3)
    s.attach_backlog([Request(0, 1, 9)])
    s.attach_backlog([Request(1, 1, 1), Request(2, 1, 9)])  # second attach
    assert [r.remaining for r in s.backlog] == [1, 9, 9]
    assert [r.uid for r in s.backlog] == [1, 0, 2]  # earlier attach wins ties
    s.submit(Request(3, 1, 5))
    assert [r.uid for r in s.next_batch()] == [1, 3, 0]


def test_scheduler_backlog_int32_overflow_falls_back():
    from repro.serve.scheduler import Request, Scheduler

    s = Scheduler(batch_size=2)
    s.submit(Request(10, 1, 2**31 + 5))  # remaining overflows int32
    s.submit(Request(11, 1, 3))
    s.attach_backlog([Request(0, 1, 4)])
    assert [r.uid for r in s.next_batch()] == [11, 0]
    assert [r.uid for r in s.next_batch()] == [10]


# ---------------------------------------------------------------------------
# run formation
# ---------------------------------------------------------------------------
def test_form_runs_order_and_shapes():
    x = make_input("Uniform", 2500, np.float32, seed=8)
    runs = stream.form_runs(x, 1000)
    assert [r.shape[0] for r in runs] == [1000, 1000, 500]
    for lo, run in zip([0, 1000, 2000], runs):
        np.testing.assert_array_equal(np.asarray(run), np.sort(x[lo : lo + 1000]))
    pairs = stream.form_argsort_runs(x, 1000)
    for (keys, idx), lo in zip(pairs, [0, 1000, 2000]):
        np.testing.assert_array_equal(np.asarray(keys), x[np.asarray(idx)])
        assert int(idx.min()) >= lo


def test_iter_chunks_validation():
    with pytest.raises(ValueError):
        list(stream.iter_chunks(np.zeros(4), 0))
    with pytest.raises(ValueError):
        list(stream.iter_chunks(np.zeros((2, 2)), 1))
    with pytest.raises(ValueError):
        list(stream.iter_chunks(iter([np.zeros((2, 2))]), 1))
