"""Classifier-parity suite: every engine of the ``repro.classify`` seam
(DESIGN.md §9) must produce a keyspace-order stable sort bit-identical to
the tree baseline, on every paper distribution, on both partition engines.

Covers: sorted-output parity on all nine distributions x {f32, i32, u64}
x {tree, radix, learned} x {xla, pallas}; the skew cases (zipf, all-equal,
one-hot) where the learned engine must trip its imbalance fallback rather
than degrade; the radix extractor unit contract (shift math, sentinel
equality bit, monotonicity, unsigned-only); the fused radix kernel vs its
XLA oracle; the learned model's monotonicity and imbalance score; the
roofline-derived kernel tile rows; classifier threading through every ops
entry point (incl. the segmented exclusion); the ``clf:`` plan-cache race
/ hint / "auto" resolution; and stale pre-classifier plan migration.
"""
import json
from dataclasses import replace

import numpy as np
import jax.numpy as jnp
import pytest

from repro import ops
from repro.classify import (
    IMBALANCE_THRESHOLD,
    classifier_for,
    distribution_moments,
    eval_cdf_buckets,
    fit_cdf_knots,
    learned_bucket_ids,
    radix_bucket_ids,
    radix_shift,
    resolve_classifier,
    sample_imbalance,
)
from repro.core.ips4o import SortConfig, plan_levels
from repro.core.sampling import sentinel_for
from repro.data.distributions import DISTRIBUTIONS, make_input
from repro.launch.roofline import classify_tile_rows

_cfg = SortConfig(base_case=1024, kmax=32, tile=256, max_sample=256, slack=4)
_N = 5000
_CLFS = ("tree", "radix", "learned")


def _enc_sorted(x, cfg, classifier, engine):
    """Keyspace codes of the sorted output — the bit-exact comparison space
    (decode order equals keyspace order, but -0.0/+0.0 and NaN classes are
    only distinguishable pre-decode)."""
    out = ops.sort(jnp.asarray(x), cfg=cfg, classifier=classifier, engine=engine)
    return np.asarray(ops.keyspace.encode(out))


# ------------------------------------------------------------------ parity
@pytest.mark.parametrize("dist", sorted(DISTRIBUTIONS))
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_classifier_parity_distributions(dist, dtype):
    x = make_input(dist, _N, dtype, seed=7)
    want = np.sort(np.asarray(ops.keyspace.encode(jnp.asarray(x))), kind="stable")
    for clf in _CLFS:
        for engine in ("xla", "pallas"):
            got = _enc_sorted(x, _cfg, clf, engine)
            np.testing.assert_array_equal(
                got, want, err_msg=f"clf={clf} engine={engine}"
            )


_U64_CHILD = """
import numpy as np
import jax.numpy as jnp
from repro import ops
from repro.core.ips4o import SortConfig
from repro.data.distributions import DISTRIBUTIONS, make_input

cfg = SortConfig(base_case=1024, kmax=32, tile=256, max_sample=256, slack=4)
for dist in sorted(DISTRIBUTIONS):
    x = make_input(dist, 5000, np.uint64, seed=7)
    want = np.sort(x, kind="stable")
    for clf in ("tree", "radix", "learned"):
        for engine in ("xla", "pallas"):
            out = ops.sort(jnp.asarray(x), cfg=cfg, classifier=clf, engine=engine)
            np.testing.assert_array_equal(
                np.asarray(out), want, err_msg=f"{dist} clf={clf} engine={engine}"
            )
print("u64 parity OK")
"""


def test_classifier_parity_u64_subprocess():
    """All nine distributions x {tree, radix, learned} x {xla, pallas} on
    u64 keys.  Runs in a child process with x64 enabled from startup:
    flipping ``enable_x64`` mid-process destabilizes this jaxlib (compiled
    artifacts from both modes coexisting in one CPU client can segfault a
    later unrelated compile), so the widest dtype gets its own process."""
    import os
    import subprocess
    import sys

    env = dict(os.environ, JAX_ENABLE_X64="1", JAX_PLATFORMS="cpu")
    env["PYTHONPATH"] = (
        os.path.join(os.path.dirname(__file__), "..", "src")
        + os.pathsep
        + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, "-c", _U64_CHILD],
        env=env,
        capture_output=True,
        text=True,
        timeout=1200,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "u64 parity OK" in proc.stdout


@pytest.mark.parametrize("clf", _CLFS)
def test_classifier_two_level(clf):
    """kmax=8 forces the segmented second level: radix must shift past the
    level-1 bits, learned must map back to the per-segment tree."""
    cfg = SortConfig(base_case=512, kmax=8, tile=256, max_sample=256, slack=4)
    x = make_input("Uniform", 8000, np.int32, seed=3)
    assert len(plan_levels(8192, cfg)) == 2  # 8000 pads to 8192 -> [8, 8]
    for engine in ("xla", "pallas"):
        out = np.asarray(
            ops.sort(jnp.asarray(x), cfg=cfg, classifier=clf, engine=engine)
        )
        np.testing.assert_array_equal(out, np.sort(x))


def test_classifier_parity_batched():
    x = np.stack(
        [make_input(d, 4096, np.float32, seed=5)
         for d in ("Uniform", "TwoDup", "Sorted")]
    )
    want = np.sort(x, axis=1)
    for clf in _CLFS:
        for engine in ("xla", "pallas"):
            out = np.asarray(
                ops.batched_sort(
                    jnp.asarray(x), cfg=_cfg, classifier=clf, engine=engine
                )
            )
            np.testing.assert_array_equal(
                out, want, err_msg=f"clf={clf} engine={engine}"
            )


def test_classifier_with_payload():
    x = make_input("TwoDup", _N, np.float32, seed=11)
    v = jnp.arange(_N, dtype=jnp.int32)
    for clf in _CLFS:
        k_out, v_out = ops.sort(jnp.asarray(x), v, cfg=_cfg, classifier=clf)
        np.testing.assert_array_equal(x[np.asarray(v_out)], np.asarray(k_out))


# ------------------------------------------------------- skew / fallback
def _skew_inputs():
    rng = np.random.default_rng(0)
    zipf = np.minimum(rng.zipf(1.5, _N), 1 << 20).astype(np.int32)
    all_equal = np.full(_N, 42, np.int32)
    one_hot = np.zeros(_N, np.int32)
    one_hot[rng.integers(0, _N)] = 1
    return {"zipf": zipf, "all_equal": all_equal, "one_hot": one_hot}


@pytest.mark.parametrize("name", ["zipf", "all_equal", "one_hot"])
def test_learned_skew_falls_back_not_degrades(name):
    """On heavy skew the learned engine must reroute through the tree (its
    sample-measured imbalance trips the threshold) and still sort exactly
    — never pay the full-sort robustness fallback for a bad fit."""
    x = _skew_inputs()[name]
    out = np.asarray(ops.sort(jnp.asarray(x), cfg=_cfg, classifier="learned"))
    np.testing.assert_array_equal(out, np.sort(x))
    # the fallback itself: fit on a sample of this input, check the trigger
    enc = ops.keyspace.encode(jnp.asarray(x))
    k = 32
    sample = jnp.sort(enc[:256])
    knots = fit_cdf_knots(sample)
    imb = float(sample_imbalance(sample, knots, k))
    if name == "zipf":
        # zipf keeps some spread: the model may cope; only assert the
        # guard's contract — imbalance below threshold means balanced
        b, fell = learned_bucket_ids(enc, sample, jnp.sort(enc[:256])[8::8][:31], k)
        if not bool(fell):
            counts = np.bincount(np.asarray(b) // 2, minlength=k)
            assert counts.max() * k / enc.shape[0] <= IMBALANCE_THRESHOLD * 2
    else:
        assert imb > IMBALANCE_THRESHOLD  # degenerate fits must trip it


def test_learned_fallback_flag_all_equal():
    keys = jnp.full((1024,), 7, jnp.uint32)
    sample = jnp.sort(keys[:64])
    spl = jnp.full((31,), 7, jnp.uint32)
    b, fell = learned_bucket_ids(keys, sample, spl, 32)
    assert bool(fell)
    # fallback = the tree's ids, bit for bit
    from repro.classify import classify

    np.testing.assert_array_equal(
        np.asarray(b), np.asarray(classify(keys, spl, 32))
    )


# ------------------------------------------------------------ radix unit
def test_radix_shift_math():
    assert radix_shift(jnp.uint32, 128) == 32 - 7
    assert radix_shift(jnp.uint32, 128, consumed_bits=7) == 32 - 14
    assert radix_shift(jnp.uint8, 128, consumed_bits=7) == 0  # clamped
    with pytest.raises(ValueError):
        radix_shift(jnp.int32, 128)
    with pytest.raises(ValueError):
        radix_shift(jnp.float32, 128)


def test_radix_bucket_ids_contract():
    rng = np.random.default_rng(1)
    keys = jnp.asarray(rng.integers(0, 2**32, 4096, dtype=np.uint32))
    k = 32
    b = np.asarray(radix_bucket_ids(keys, k))
    assert b.min() >= 0 and b.max() < 2 * k
    # monotone in the key, and exactly the top-bits bucket
    order = np.argsort(np.asarray(keys), kind="stable")
    assert (np.diff(b[order]) >= 0).all()
    np.testing.assert_array_equal(b // 2, np.asarray(keys) >> (32 - 5))
    # sentinel gets the equality bit (odd id), others stay even
    sent = sentinel_for(keys.dtype)
    bs = np.asarray(radix_bucket_ids(jnp.asarray([sent, sent - 1]), k))
    assert bs[0] == 2 * k - 1 and bs[1] % 2 == 0


def test_radix_kernel_vs_oracle():
    from repro.kernels.classify import radix_histogram, radix_histogram_batched

    rng = np.random.default_rng(2)
    n, k = 4096, 32
    keys = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
    for consumed in (0, 5):
        b, hist = radix_histogram(keys, k=k, consumed_bits=consumed, rows=2)
        want = np.asarray(radix_bucket_ids(keys, k, consumed))
        np.testing.assert_array_equal(np.asarray(b), want)
        np.testing.assert_array_equal(
            np.asarray(hist).sum(axis=0), np.bincount(want, minlength=2 * k)
        )
    kb = jnp.asarray(rng.integers(0, 2**32, (3, n), dtype=np.uint32))
    bb, hb = radix_histogram_batched(kb, k=k, rows=2)
    wantb = np.asarray(radix_bucket_ids(kb, k))
    np.testing.assert_array_equal(np.asarray(bb), wantb)
    np.testing.assert_array_equal(
        np.asarray(hb).sum(axis=1),
        np.stack([np.bincount(r, minlength=2 * k) for r in wantb]),
    )


def test_dist_radix_dest_unit():
    from repro.dist.exchange import _radix_dest

    rng = np.random.default_rng(3)
    keys = jnp.asarray(rng.integers(0, 2**32, 1024, dtype=np.uint32))
    valid = jnp.arange(1024, dtype=jnp.int32) < 1000
    dest, counts = _radix_dest(keys, valid, 8)
    d = np.asarray(dest)
    np.testing.assert_array_equal(
        d[:1000], (np.asarray(keys) >> 29)[:1000]
    )
    assert (d[1000:] == 8).all()  # pads -> trash bucket
    np.testing.assert_array_equal(
        np.asarray(counts), np.bincount(d[:1000], minlength=8)
    )


# ---------------------------------------------------------- learned unit
def test_learned_model_monotone():
    rng = np.random.default_rng(4)
    sample = jnp.sort(jnp.asarray(rng.integers(0, 2**32, 256, dtype=np.uint32)))
    knots = fit_cdf_knots(sample)
    keys = jnp.sort(jnp.asarray(rng.integers(0, 2**32, 8192, dtype=np.uint32)))
    j = np.asarray(eval_cdf_buckets(keys, knots, 64))
    assert (np.diff(j) >= 0).all()
    assert j.min() >= 0 and j.max() < 64


def test_sample_imbalance_scores():
    rng = np.random.default_rng(5)
    uniform = jnp.sort(jnp.asarray(rng.integers(0, 2**32, 512, dtype=np.uint32)))
    assert float(sample_imbalance(uniform, fit_cdf_knots(uniform), 32)) < 2.0
    degenerate = jnp.full((512,), 7, jnp.uint32)
    assert (
        float(sample_imbalance(degenerate, fit_cdf_knots(degenerate), 32))
        > IMBALANCE_THRESHOLD
    )


# ------------------------------------------------------------- tile rows
def test_classify_tile_rows_properties():
    rows = classify_tile_rows(4, 128)
    assert rows[0] == 32  # reproduces the previously hard-coded tile
    assert list(rows) == sorted(rows, reverse=True)
    assert all(r & (r - 1) == 0 for r in rows) and rows[-1] == 1
    # smaller rows-budget per element -> no larger leading tile
    assert classify_tile_rows(8, 256)[0] <= classify_tile_rows(4, 32)[0]
    assert classify_tile_rows(4, 128, vmem_bytes=1 << 30)[0] == 128  # capped


def test_default_rows_divisibility():
    from repro.kernels.classify import default_rows

    r = default_rows(32 * 128, 4, 128)
    assert r and (32 * 128) % (r * 128) == 0
    assert default_rows(100, 4, 128) == 0  # not 128-aligned: no kernel


def test_classify_rows_override_threads_through():
    x = make_input("Uniform", 4096, np.float32, seed=8)
    cfg = replace(_cfg, classify_rows=2, engine="pallas")
    out = np.asarray(ops.sort(jnp.asarray(x), cfg=cfg))
    np.testing.assert_array_equal(out, np.sort(x))


# -------------------------------------------------------- ops threading
def test_classifier_threads_through_ops():
    x = jnp.asarray(make_input("Exponential", _N, np.float32, seed=5))
    want_bottom = np.sort(np.asarray(x))[:37]
    for clf in _CLFS:
        vals, _ = ops.bottomk(x, 37, cfg=_cfg, classifier=clf)
        np.testing.assert_array_equal(np.asarray(vals), want_bottom)
        vals, _ = ops.topk(x, 23, cfg=_cfg, classifier=clf)
        np.testing.assert_array_equal(
            np.asarray(vals), np.sort(np.asarray(x))[::-1][:23]
        )
        idx = ops.argsort(x, cfg=_cfg, classifier=clf)
        assert (np.diff(np.asarray(x)[np.asarray(idx)]) >= 0).all()


def test_segmented_sort_maps_radix_to_tree():
    """User segments are not bit-aligned: segmented_sort must accept the
    kwarg for API symmetry but classify with the per-segment tree."""
    x = jnp.asarray(make_input("Uniform", _N, np.float32, seed=6))
    off = jnp.asarray([0, 1500, 1500, _N], jnp.int32)
    want = np.asarray(ops.segmented_sort(x, off, 3, cfg=_cfg))
    for clf in ("radix", "learned", "auto"):
        got = np.asarray(ops.segmented_sort(x, off, 3, cfg=_cfg, classifier=clf))
        np.testing.assert_array_equal(got, want)


def test_batched_rank_k_classifier():
    x = jnp.asarray(
        np.stack([make_input("Uniform", 4096, np.float32, seed=s) for s in (1, 2)])
    )
    want = np.sort(np.asarray(x), axis=1)[:, :17]
    for clf in _CLFS:
        vals, _ = ops.batched_bottomk(x, 17, cfg=_cfg, classifier=clf)
        np.testing.assert_array_equal(np.asarray(vals), want)


# ------------------------------------------------------------ the router
def test_resolve_classifier_contract():
    for clf in _CLFS:
        assert resolve_classifier(clf) == clf
    assert resolve_classifier("auto") == "tree"  # nothing raced
    with pytest.raises(ValueError, match="classifier"):
        resolve_classifier("neural")


def test_distribution_moments_labels():
    rng = np.random.default_rng(9)
    assert distribution_moments(rng.integers(0, 2**31, 8192)) == "uniform"
    assert distribution_moments(rng.integers(0, 5, 8192)) == "dup"
    assert distribution_moments(np.sort(rng.standard_normal(8192))) == "sorted"
    # distinct values (not "dup") but lopsided in the value range: the
    # exponential's long tail stretches the bins while the mass stays low
    assert distribution_moments(rng.exponential(1.0, 8192)) == "skew"
    assert distribution_moments(np.asarray([], np.int32)) == "uniform"


def test_classifier_race_persists_and_routes(tmp_path, monkeypatch):
    from repro.ops import plan as plan_mod

    pc = ops.PlanCache(path=str(tmp_path / "plans.json"))
    n = 4096
    winner = pc.classifier_plan(n, jnp.uint32, dist="uniform", tune=True)
    assert winner in _CLFS
    entry = pc._plans[pc._clf_key(n, jnp.uint32, "uniform")]
    assert entry["winner"] == winner
    assert set(entry["us_per_classifier"]) == set(_CLFS)
    # persisted across processes
    pc2 = ops.PlanCache(path=pc.path)
    assert pc2.classifier_plan(n, jnp.uint32, dist="uniform") == winner
    # single raced label -> consensus hint; a conflicting label kills it
    assert pc2.classifier_hint(n, jnp.uint32) == winner
    other = "tree" if winner != "tree" else "radix"
    pc2._plans[pc2._clf_key(n, jnp.uint32, "dup")] = {"winner": other}
    assert pc2.classifier_hint(n, jnp.uint32) is None
    assert pc2.classifier_hint(n, jnp.uint32, dist="uniform") == winner
    # "auto" resolution consults the default cache
    monkeypatch.setattr(plan_mod, "default_cache", pc)
    assert resolve_classifier("auto", n, jnp.uint32) == winner
    assert resolve_classifier("auto", n + 1, jnp.uint32) == "tree"


def test_classifier_for_eager_routing(tmp_path):
    pc = ops.PlanCache(path=str(tmp_path / "plans.json"))
    x = jnp.asarray(
        np.random.default_rng(1).integers(0, 2**31, 4096, dtype=np.int32)
    )
    clf = classifier_for(x, cache=pc, tune=True)
    assert clf in _CLFS
    assert pc.classifier_plan(4096, jnp.int32, dist="uniform") == clf


def test_auto_classifier_sort_end_to_end(tmp_path, monkeypatch):
    """classifier="auto" must route through a raced winner and still sort."""
    from repro.ops import plan as plan_mod

    pc = ops.PlanCache(path=str(tmp_path / "plans.json"))
    pc._plans[pc._clf_key(_N, jnp.float32, "uniform")] = {"winner": "radix"}
    monkeypatch.setattr(plan_mod, "default_cache", pc)
    x = make_input("Uniform", _N, np.float32, seed=12)
    out = np.asarray(ops.sort(jnp.asarray(x), cfg=_cfg, classifier="auto"))
    np.testing.assert_array_equal(out, np.sort(x))


# ------------------------------------------------------------ plan cache
def test_plan_cache_stale_pre_classifier_plan_loads(tmp_path):
    """Plans persisted before the classifier dimension existed must load
    with classifier="tree" defaulted — migrated, not discarded."""
    path = str(tmp_path / "plans.json")
    stale = {
        "sort:n=4096:dtype=float32": {
            "config": {"base_case": 1024, "kmax": 32, "tile": 256,
                       "max_sample": 256, "slack": 4, "engine": "pallas"},
            "engine": "pallas",
            "us": 2.0,
        },
    }
    with open(path, "w") as fh:
        json.dump(stale, fh)
    pc = ops.PlanCache(path=path)
    cfg = pc.config_for("sort", 4096, jnp.float32)
    assert cfg.classifier == "tree" and cfg.classify_rows == 0
    assert cfg.engine == "pallas" and cfg.base_case == 1024  # tuned fields kept
    assert pc.classifier_hint(4096, jnp.float32) is None  # no claim either way
    # a tuned plan that DID bake a classifier feeds the hint
    pc._plans["sort:n=2048:dtype=float32"] = {
        "config": {"classifier": "radix"}, "engine": "xla", "us": 1.0,
    }
    assert pc.classifier_hint(2048, jnp.float32) == "radix"
