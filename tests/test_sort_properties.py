"""Property-based tests (hypothesis) for the system's sorting invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.classify import classify
from repro.core.ips4o import SortConfig, ips4o_sort
from repro.core.partition import stable_partition
from repro.core.ref import ref_partition

_small_cfg = SortConfig(base_case=512, kmax=8, tile=256, max_sample=256)


@st.composite
def key_arrays(draw, max_n=3000):
    n = draw(st.integers(0, max_n))
    kind = draw(st.sampled_from(["float", "int", "dup", "const", "sortedish"]))
    rng = np.random.default_rng(draw(st.integers(0, 2**31)))
    if kind == "float":
        return rng.standard_normal(n).astype(np.float32)
    if kind == "int":
        return rng.integers(-(2**31), 2**31 - 1, n).astype(np.int32).astype(np.float32)
    if kind == "dup":
        return rng.integers(0, max(1, n // 50 + 1), n).astype(np.float32)
    if kind == "const":
        lo, hi = float(np.float32(-1e30)), float(np.float32(1e30))
        return np.full(n, draw(st.floats(lo, hi, width=32)), np.float32)
    x = rng.standard_normal(n).astype(np.float32)
    x.sort()
    return x


@given(key_arrays())
@settings(max_examples=40, deadline=None)
def test_sorted_and_permutation(x):
    out = np.asarray(ips4o_sort(jnp.asarray(x), cfg=_small_cfg))
    assert out.shape == x.shape
    if len(out) > 1:
        assert np.all(out[:-1] <= out[1:]), "output not sorted"
    np.testing.assert_array_equal(np.sort(out), np.sort(x))  # multiset equal


@given(key_arrays(max_n=1500))
@settings(max_examples=25, deadline=None)
def test_idempotent(x):
    a = np.asarray(ips4o_sort(jnp.asarray(x), cfg=_small_cfg))
    b = np.asarray(ips4o_sort(jnp.asarray(a), cfg=_small_cfg))
    np.testing.assert_array_equal(a, b)


@given(key_arrays(max_n=1500))
@settings(max_examples=25, deadline=None)
def test_payload_is_inverse_permutation(x):
    v = np.arange(len(x), dtype=np.int32)
    ks, vs = ips4o_sort(jnp.asarray(x), jnp.asarray(v), cfg=_small_cfg)
    ks, vs = np.asarray(ks), np.asarray(vs)
    np.testing.assert_array_equal(x[vs], ks)
    assert len(np.unique(vs)) == len(x)


@given(
    st.integers(1, 64).map(lambda k: 1 << (k % 7 + 1)),  # k in {2..128} pow2
    st.integers(0, 2**31),
    st.integers(2, 2000),
)
@settings(max_examples=30, deadline=None)
def test_classifier_agrees_with_searchsorted(k, seed, n):
    rng = np.random.default_rng(seed)
    keys = rng.standard_normal(n).astype(np.float32)
    spl = np.sort(rng.standard_normal(k - 1).astype(np.float32))
    got = np.asarray(classify(jnp.asarray(keys), jnp.asarray(spl), k))
    j = np.searchsorted(spl, keys, side="left")  # bucket = |{s < e}|
    eq = np.zeros(n, np.int32)
    in_range = j < k - 1
    eq[in_range] = (keys[in_range] == spl[j[in_range]]).astype(np.int32)
    np.testing.assert_array_equal(got, 2 * j + eq)


@given(st.integers(0, 2**31), st.integers(1, 16), st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_stable_partition_matches_ref(seed, nbf, tiles):
    nb, tile = nbf, 128
    n = tile * tiles
    rng = np.random.default_rng(seed)
    bucket = jnp.asarray(rng.integers(0, nb, n).astype(np.int32))
    arrays = {"a": jnp.arange(n, dtype=jnp.int32)}
    got, off_g = stable_partition(bucket, arrays, nb, tile)
    exp, off_e = ref_partition(bucket, arrays, nb)
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(exp["a"]))
    np.testing.assert_array_equal(np.asarray(off_g), np.asarray(off_e))
