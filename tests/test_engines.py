"""Engine-parity suite: the "pallas" partition engine must be bit-exact
interchangeable with the "xla" engine (DESIGN.md §4.8).

Covers: sorted-output and bucket-offset parity on all nine paper input
distributions x {f32, i32} (interpret-mode kernels), the two-level
composite path, the counting-rank kernel vs its oracle, the block-move
pytree consistency, engine threading through the ops entry points, and
the PlanCache engine-dimension round-trip (incl. stale pre-engine plans).
"""
import json
from dataclasses import replace

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import ops
from repro.core.ips4o import (
    SortConfig,
    pad_with_sentinel,
    partition_passes,
    plan_levels,
    resolve_engine,
)
from repro.core.partition import partition_blocks, stable_partition
from repro.data.distributions import DISTRIBUTIONS, make_input
from repro.kernels.dispatch_rank import partition_ranks
from repro.kernels.ref import partition_ranks_ref

# one-level path with pads (n=5000 -> n_pad=6144, k=32)
_cfg = SortConfig(base_case=1024, kmax=32, tile=256, max_sample=256, slack=4)
_N = 5000


def _offsets(x, cfg):
    """Bucket offsets + partitioned keys after the level passes."""
    arrays = pad_with_sentinel({"k": ops.keyspace.encode(jnp.asarray(x))},
                               max(cfg.base_case, cfg.tile))
    levels = plan_levels(arrays["k"].shape[0], cfg)
    assert levels, "test sizes must exercise at least one level pass"
    out, off, nb, pad_bucket = partition_passes(arrays, len(x), cfg, levels)
    return np.asarray(out["k"]), np.asarray(off)


@pytest.mark.parametrize("dist", sorted(DISTRIBUTIONS))
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_engine_parity_distributions(dist, dtype):
    x = make_input(dist, _N, dtype, seed=7)
    out_x = np.asarray(ops.sort(jnp.asarray(x), cfg=_cfg, engine="xla"))
    out_p = np.asarray(ops.sort(jnp.asarray(x), cfg=_cfg, engine="pallas"))
    np.testing.assert_array_equal(out_x, out_p)
    np.testing.assert_array_equal(out_x, np.sort(x))
    # the partition passes themselves must agree too: identical bucket
    # offsets AND identical (stable) intermediate placement
    keys_x, off_x = _offsets(x, replace(_cfg, engine="xla"))
    keys_p, off_p = _offsets(x, replace(_cfg, engine="pallas"))
    np.testing.assert_array_equal(off_x, off_p)
    np.testing.assert_array_equal(keys_x, keys_p)


@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_engine_parity_two_level(dtype):
    """n large enough for the segmented second level (composite partition
    through the counting kernel)."""
    x = make_input("Uniform", 20000, dtype, seed=3)
    cfg = _cfg
    assert len(plan_levels(20480, cfg)) == 2
    out_x = np.asarray(ops.sort(jnp.asarray(x), cfg=cfg, engine="xla"))
    out_p = np.asarray(ops.sort(jnp.asarray(x), cfg=cfg, engine="pallas"))
    np.testing.assert_array_equal(out_x, out_p)
    keys_x, off_x = _offsets(x, replace(cfg, engine="xla"))
    keys_p, off_p = _offsets(x, replace(cfg, engine="pallas"))
    np.testing.assert_array_equal(off_x, off_p)
    np.testing.assert_array_equal(keys_x, keys_p)


def test_engine_parity_with_payload():
    """Payload association must survive the scatter-based move."""
    x = make_input("TwoDup", _N, np.float32, seed=11)
    v = jnp.arange(_N, dtype=jnp.int32)
    kx, vx = ops.sort(jnp.asarray(x), v, cfg=_cfg, engine="xla")
    kp, vp = ops.sort(jnp.asarray(x), v, cfg=_cfg, engine="pallas")
    np.testing.assert_array_equal(np.asarray(kx), np.asarray(kp))
    np.testing.assert_array_equal(np.asarray(vx), np.asarray(vp))
    np.testing.assert_array_equal(x[np.asarray(vp)], np.asarray(kp))


def test_engine_threads_through_ops():
    x = jnp.asarray(make_input("Exponential", _N, np.float32, seed=5))
    for engine in ("xla", "pallas"):
        vals, idx = ops.bottomk(x, 37, cfg=_cfg, engine=engine)
        np.testing.assert_array_equal(np.asarray(vals),
                                      np.sort(np.asarray(x))[:37])
    off = jnp.asarray([0, 1500, 1500, _N], jnp.int32)
    sx = ops.segmented_sort(x, off, 3, cfg=_cfg, engine="xla")
    sp = ops.segmented_sort(x, off, 3, cfg=_cfg, engine="pallas")
    np.testing.assert_array_equal(np.asarray(sx), np.asarray(sp))


def test_stable_partition_engines_bit_identical():
    rng = np.random.default_rng(0)
    nb, n = 13, 4096
    b = jnp.asarray(rng.integers(0, nb, n), jnp.int32)
    arrays = {"k": jnp.asarray(rng.standard_normal(n), jnp.float32),
              "v": jnp.arange(n, dtype=jnp.int32)}
    ax, ox = stable_partition(b, arrays, nb, 512, engine="xla")
    ap, op_ = stable_partition(b, arrays, nb, 512, engine="pallas")
    np.testing.assert_array_equal(np.asarray(ox), np.asarray(op_))
    for leaf in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(ax[leaf]), np.asarray(ap[leaf]))
    with pytest.raises(ValueError, match="engine"):
        stable_partition(b, arrays, nb, 512, engine="cuda")


@pytest.mark.parametrize("nb,n", [(3, 1024), (65, 4096), (257, 2048)])
def test_partition_ranks_kernel_vs_ref(nb, n):
    """The counting kernel (incl. the odd nb of a level pass and non-aligned
    n) must match the one-hot oracle exactly."""
    rng = np.random.default_rng(nb)
    b = jnp.asarray(rng.integers(0, nb, n), jnp.int32)
    totals = jnp.bincount(b, length=nb)
    start = (jnp.cumsum(totals) - totals).astype(jnp.int32)
    got = partition_ranks(b, start, nb=nb)
    exp = partition_ranks_ref(b, start, nb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))
    # with true prefix starts, dest is a permutation
    assert len(set(np.asarray(got).tolist())) == n


def test_partition_blocks_consistent_across_leaves():
    """The in-place block kernel must apply ONE permutation to every leaf."""
    rng = np.random.default_rng(4)
    nb, nblocks, be = 5, 24, 128
    bb = jnp.asarray(rng.integers(0, nb, nblocks), jnp.int32)
    k = jnp.asarray(rng.standard_normal(nblocks * be), jnp.float32)
    v = jnp.arange(nblocks * be, dtype=jnp.int32)
    out, d = partition_blocks({"k": k, "v": v}, bb, nb, be)
    d = np.asarray(d)
    ko, vo = np.asarray(out["k"]), np.asarray(out["v"])
    # payload association exact (same permutation hit both leaves) ...
    np.testing.assert_array_equal(np.asarray(k)[vo], ko)
    # ... and each output block is intact and grouped under its bucket
    got_bucket = np.asarray(bb)[vo[::be] // be]
    np.testing.assert_array_equal(np.repeat(np.arange(nb), np.diff(d)), got_bucket)
    # a 2-D leaf forces the WHOLE pytree onto the stable-gather path, which
    # must still move every leaf by one permutation (here: the stable one)
    v2 = jnp.stack([v, v], axis=1)
    out2, d2 = partition_blocks({"k": k, "v2": v2}, bb, nb, be)
    vo2 = np.asarray(out2["v2"])[:, 0]
    np.testing.assert_array_equal(np.asarray(k)[vo2], np.asarray(out2["k"]))
    stable_block_order = np.argsort(np.asarray(bb), kind="stable")
    np.testing.assert_array_equal(vo2[::be] // be, stable_block_order)
    np.testing.assert_array_equal(np.asarray(d2), d)


def test_auto_resolves_against_caller_n_and_dtype(tmp_path, monkeypatch):
    """"auto" must consult the plan cache with the caller's ORIGINAL
    (n, dtype) — deeper layers only see the keyspace-encoded dtype and the
    padded n, which would never match a tuned plan."""
    from repro.ops import plan as plan_mod
    from repro.ops.sort import with_engine

    pc = ops.PlanCache(path=str(tmp_path / "plans.json"))
    key = pc._key("sort", _N, jnp.float32, None)  # caller-facing key
    pc._plans[key] = {"config": {"engine": "pallas"}, "engine": "pallas", "us": 1.0}
    monkeypatch.setattr(plan_mod, "default_cache", pc)

    x = jnp.zeros((_N,), jnp.float32)
    resolved = with_engine(SortConfig(engine="auto"), None, x)
    assert resolved.engine == "pallas"
    # override still wins over cfg
    assert with_engine(SortConfig(engine="auto"), "xla", x).engine == "xla"
    # and the sort itself runs end-to-end on the resolved engine
    y = make_input("Uniform", _N, np.float32, seed=1)
    out = ops.sort(jnp.asarray(y), cfg=_cfg, engine="auto")
    np.testing.assert_array_equal(np.asarray(out), np.sort(y))


def test_pallas_partition_survives_unaligned_n():
    """When the padded n is not 128-aligned the fused classify kernel cannot
    run, but an explicit "pallas" engine must still use the counting-rank
    partition (bincount offsets path) — and stay bit-identical to xla."""
    cfg = SortConfig(base_case=500, kmax=32, tile=250, max_sample=256, slack=4)
    x = make_input("Uniform", 2500, np.float32, seed=9)
    out_p = np.asarray(ops.sort(jnp.asarray(x), cfg=cfg, engine="pallas"))
    out_x = np.asarray(ops.sort(jnp.asarray(x), cfg=cfg, engine="xla"))
    np.testing.assert_array_equal(out_p, out_x)
    np.testing.assert_array_equal(out_p, np.sort(x))


def test_resolve_engine():
    assert resolve_engine(SortConfig(engine="xla"), 1024) == "xla"
    assert resolve_engine(SortConfig(engine="pallas"), 1024) == "pallas"
    # off-TPU, auto with no persisted plan falls back to xla
    auto = resolve_engine(SortConfig(engine="auto"), 1 << 30, jnp.float32)
    assert auto == ("pallas" if jax.default_backend() == "tpu" else "xla")
    with pytest.raises(ValueError, match="engine"):
        resolve_engine(SortConfig(engine="vulkan"), 1024)


# ---------------------------------------------------------------- plan cache
def test_plan_cache_engine_roundtrip(tmp_path):
    path = str(tmp_path / "plans.json")
    pc = ops.PlanCache(path=path)
    key = pc._key("sort", 8192, jnp.float32, None)
    pc._plans[key] = {
        "config": {"base_case": 1024, "kmax": 32, "tile": 256,
                   "max_sample": 256, "slack": 4, "engine": "pallas"},
        "engine": "pallas",
        "us": 1.0,
    }
    pc._save()
    pc2 = ops.PlanCache(path=path)
    cfg = pc2.config_for("sort", 8192, jnp.float32)
    assert cfg.engine == "pallas" and cfg.base_case == 1024
    assert pc2.engine_hint(8192, jnp.float32) == "pallas"
    # the persisted engine drives "auto" resolution when it is the default
    # cache; a plain lookup through a scratch cache must not explode
    assert pc2.engine_hint(4096, jnp.float32) is None
    f = pc2.get_sorter(8192, jnp.float32, "sort")
    x = jnp.asarray(np.random.default_rng(0).standard_normal(8192), jnp.float32)
    np.testing.assert_array_equal(np.asarray(f(x)), np.sort(np.asarray(x)))


def test_plan_cache_stale_pre_engine_plan_loads(tmp_path):
    """Plans persisted before the engine dimension existed still load."""
    path = str(tmp_path / "plans.json")
    stale = {
        "sort:n=4096:dtype=float32": {
            "config": {"base_case": 8192, "kmax": 128, "tile": 4096,
                       "max_sample": 8192, "slack": 8},  # no "engine" key
            "us": 2.0,
        },
        "sort:n=2048:dtype=float32": {
            "config": {"window": 9999},  # foreign schema -> defaults
            "us": 3.0,
        },
    }
    with open(path, "w") as fh:
        json.dump(stale, fh)
    pc = ops.PlanCache(path=path)
    cfg = pc.config_for("sort", 4096, jnp.float32)
    assert cfg.engine == "xla" and cfg.base_case == 8192
    assert pc.engine_hint(4096, jnp.float32) is None  # stale plan: no claim
    assert pc.config_for("sort", 2048, jnp.float32) == SortConfig()


def test_plan_cache_tune_records_engine(tmp_path):
    pc = ops.PlanCache(path=str(tmp_path / "p.json"))
    pc.get_sorter(2048, jnp.float32, "sort", tune=True)
    plan = pc._plans[pc._key("sort", 2048, jnp.float32, None)]
    assert plan["engine"] in ("xla", "pallas")
    assert plan["config"]["engine"] == plan["engine"]
