"""launch/hlo_cost.py — the trip-count-aware HLO cost model.

The roofline table's integrity rests on this module, so it gets its own
oracle tests: an unrolled loop and the equivalent lax.scan must cost the
same, matching XLA's own numbers on the unrolled module.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze_hlo

N, STEPS = 128, 10


def _xla_flops(compiled) -> float:
    """Compiled.cost_analysis() returns a dict in new jax, [dict] in older."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return float(ca["flops"])


def _scan_fn(x):
    def body(c, _):
        return (c @ c) * 2.0, None
    y, _ = jax.lax.scan(body, x, None, length=STEPS)
    return y.sum()


def _unrolled_fn(x):
    for _ in range(STEPS):
        x = (x @ x) * 2.0
    return x.sum()


@pytest.fixture(scope="module")
def compiled_pair():
    x = jax.ShapeDtypeStruct((N, N), jnp.float32)
    scan = jax.jit(_scan_fn).lower(x).compile()
    unrolled = jax.jit(_unrolled_fn).lower(x).compile()
    return scan, unrolled


def test_trip_count_correction(compiled_pair):
    scan, unrolled = compiled_pair
    hs = analyze_hlo(scan.as_text())
    hu = analyze_hlo(unrolled.as_text())
    # XLA's raw cost_analysis counts the scan body once — the whole reason
    # this module exists.  Our analyzer must NOT.
    raw = _xla_flops(scan)
    assert raw < hs.flops / 2, "scan body no longer undercounted? re-check"
    assert hs.flops == pytest.approx(hu.flops, rel=0.02)
    assert STEPS in hs.trips.values()


def test_matches_xla_on_unrolled(compiled_pair):
    _, unrolled = compiled_pair
    hu = analyze_hlo(unrolled.as_text())
    xla = _xla_flops(unrolled)
    assert hu.flops == pytest.approx(xla, rel=0.02)
    # dot convention: 2*M*N*K
    assert hu.flops >= STEPS * 2 * N**3


def test_dot_flops_exact():
    a = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 16), jnp.float32)
    c = jax.jit(lambda a, b: a @ b).lower(a, b).compile()
    hc = analyze_hlo(c.as_text())
    assert hc.flops == pytest.approx(2 * 64 * 32 * 16, rel=0.01)
    assert hc.bytes_min >= (64 * 32 + 32 * 16 + 64 * 16) * 4


def test_collectives_multiplied_by_trips():
    """psum inside a scan must be charged once per trip."""
    if len(jax.devices()) != 1:
        pytest.skip("single-device container test")
    # No multi-device mesh here: validate on the scan DUS/bytes side instead.
    x = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)

    def f(stack):
        def body(c, i):
            return c + stack[i], None
        out, _ = jax.lax.scan(body, jnp.zeros((128, 128)), jnp.arange(8))
        return out

    c = jax.jit(f).lower(x).compile()
    hc = analyze_hlo(c.as_text())
    # the dynamic-slice of one (128,128) slab per trip must be charged as
    # the slice, not the whole stack
    slab = 128 * 128 * 4
    assert hc.bytes_min <= 8 * slab * 6, f"stack slicing overcounted: {hc}"


def test_fusion_bytes_use_aware():
    """A fusion reading one slab of a big stacked buffer must not be
    charged the full stack."""
    def f(stack, i):
        return stack[i] * 2.0 + 1.0

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 256, 256), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
    ).compile()
    hc = analyze_hlo(c.as_text())
    full = 64 * 256 * 256 * 4
    assert hc.bytes < full, f"charged the whole stack: {hc.bytes} >= {full}"
