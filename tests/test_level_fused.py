"""Bit-parity suite for the fused single-pass level kernel (DESIGN.md §10).

The fused kernel (``kernels.level_fused``) replaces the classify ->
histogram-glue -> counting-rank three-pass chain with ONE grid sweep.
The contract is *bit-identity*: destinations and bucket offsets must
equal the stable counting placement the "xla" engine computes, for every
classifier mode and every wrapper layer.  Covered here:

  * direct kernel parity vs a numpy stable-rank oracle (tree + radix
    classifiers, in-kernel pad routing, batched grid, ``rank_hist`` on
    precomputed ids with self-padding);
  * stack parity over all nine paper distributions x {f32, i32} x
    {single-level, two-level, batched, batched-two-level/segmented} —
    engine "pallas" vs engine "xla" through ``partition_passes`` /
    ``batched_partition_passes``, keys AND offsets bit-equal;
  * u64 keys in a subprocess (x64 must be enabled from interpreter
    startup — see tests/test_classify.py for why);
  * unit tests for the unified :class:`KernelLaunchSpec` every sort
    kernel now launches through.
"""
import os
import subprocess
import sys
from dataclasses import replace

import numpy as np
import jax.numpy as jnp
import pytest

from oracle import stable_dest
from repro import ops
from repro.classify import classify, radix_bucket_ids
from repro.core import sampling
from repro.core.ips4o import (
    SortConfig,
    _classify_rows,
    batched_pad_with_sentinel,
    batched_partition_passes,
    pad_with_sentinel,
    partition_passes,
    plan_levels,
)
from repro.data.distributions import DISTRIBUTIONS, make_input
from repro.kernels.level_fused import (
    fused_rows,
    level_fused,
    level_fused_batched,
    rank_hist,
    rank_hist_batched,
)
from repro.launch.roofline import (
    _CLASSIFY_VMEM_FRACTION,
    HW,
    _bytes_per_row,
    launch_spec,
    spec_candidates,
)

_cfg = SortConfig(base_case=1024, kmax=32, tile=256, max_sample=256, slack=4)


# ---------------------------------------------------------------------------
# oracles
# ---------------------------------------------------------------------------


# global stable counting placement (the scatter inverse of a stable
# argsort) — shared across suites in tests/oracle.py
_stable_dest = stable_dest


def _oracle_ids(keys, spl, k, n_real, clf, consumed=0):
    if clf == "radix":
        b = np.asarray(radix_bucket_ids(keys, k, consumed))
    else:
        b = np.asarray(classify(keys, spl, k))
    b = b.copy()
    b[n_real:] = 2 * k  # pad bucket
    return b


def _keys_for(dist, n, dtype, seed=7):
    """Sentinel-free encoded keyspace keys, 128-aligned length."""
    return ops.keyspace.encode(jnp.asarray(make_input(dist, n, dtype, seed=seed)))


def _splitters(keys, k, n_real, seed=0):
    samp = jnp.sort(keys[:n_real][: min(256, n_real)])
    return sampling.select_splitters(samp, k)


# ---------------------------------------------------------------------------
# direct kernel parity
# ---------------------------------------------------------------------------


class TestFusedKernelDirect:
    N, N_REAL, K = 6144, 6000, 32

    @pytest.mark.parametrize("dist", sorted(DISTRIBUTIONS))
    def test_tree_parity(self, dist):
        keys = _keys_for(dist, self.N, np.float32)
        spl = _splitters(keys, self.K, self.N_REAL)
        dest, off = level_fused(
            keys, spl, k=self.K, n_real=self.N_REAL, interpret=True
        )
        ids = _oracle_ids(keys, spl, self.K, self.N_REAL, "tree")
        want_dest, want_off = _stable_dest(ids, 2 * self.K + 1)
        np.testing.assert_array_equal(np.asarray(dest), want_dest)
        np.testing.assert_array_equal(np.asarray(off), want_off)

    @pytest.mark.parametrize("consumed", [0, 5])
    def test_radix_parity(self, consumed):
        keys = _keys_for("Uniform", self.N, np.int32)
        dest, off = level_fused(
            keys, None, k=self.K, n_real=self.N_REAL, classifier="radix",
            consumed_bits=consumed, interpret=True,
        )
        ids = _oracle_ids(keys, None, self.K, self.N_REAL, "radix", consumed)
        want_dest, want_off = _stable_dest(ids, 2 * self.K + 1)
        np.testing.assert_array_equal(np.asarray(dest), want_dest)
        np.testing.assert_array_equal(np.asarray(off), want_off)

    def test_no_pads(self):
        keys = _keys_for("TwoDup", self.N, np.int32)
        spl = _splitters(keys, self.K, self.N)
        dest, off = level_fused(keys, spl, k=self.K, interpret=True)
        ids = _oracle_ids(keys, spl, self.K, self.N, "tree")
        want_dest, want_off = _stable_dest(ids, 2 * self.K + 1)
        np.testing.assert_array_equal(np.asarray(dest), want_dest)
        np.testing.assert_array_equal(np.asarray(off), want_off)
        assert int(off[-2]) == self.N  # empty pad bucket

    def test_batched_parity(self):
        B, k = 3, 16
        rows_keys, spls = [], []
        for b in range(B):
            kb = _keys_for("Exponential", self.N, np.float32, seed=b)
            rows_keys.append(kb)
            spls.append(_splitters(kb, k, self.N_REAL, seed=b))
        keys = jnp.stack(rows_keys)
        spl = jnp.stack(spls)
        dest, off = level_fused_batched(
            keys, spl, k=k, n_real=self.N_REAL, interpret=True
        )
        for b in range(B):
            ids = _oracle_ids(rows_keys[b], spls[b], k, self.N_REAL, "tree")
            want_dest, want_off = _stable_dest(ids, 2 * k + 1)
            np.testing.assert_array_equal(np.asarray(dest[b]), want_dest)
            np.testing.assert_array_equal(np.asarray(off[b]), want_off)

    def test_rank_hist_self_pads(self):
        """Precomputed-ids variant: n not tile-aligned; the kernel pads
        with the all-zero one-hot trash id and trims the result."""
        nb = 65
        n = 5000  # not a multiple of any rows*128 tile
        ids = np.random.default_rng(0).integers(0, nb, n).astype(np.int32)
        dest, off = rank_hist(jnp.asarray(ids), nb=nb, interpret=True)
        want_dest, want_off = _stable_dest(ids, nb)
        np.testing.assert_array_equal(np.asarray(dest), want_dest)
        np.testing.assert_array_equal(np.asarray(off), want_off)

    def test_rank_hist_batched(self):
        nb, B, n = 33, 4, 2500
        ids = np.random.default_rng(1).integers(0, nb, (B, n)).astype(np.int32)
        dest, off = rank_hist_batched(jnp.asarray(ids), nb=nb, interpret=True)
        for b in range(B):
            want_dest, want_off = _stable_dest(ids[b], nb)
            np.testing.assert_array_equal(np.asarray(dest[b]), want_dest)
            np.testing.assert_array_equal(np.asarray(off[b]), want_off)


# ---------------------------------------------------------------------------
# stack parity: engine "pallas" (fused) vs engine "xla", all wrapper layers
# ---------------------------------------------------------------------------


def _passes_1d(x, cfg):
    arrays = pad_with_sentinel(
        {"k": ops.keyspace.encode(jnp.asarray(x))}, max(cfg.base_case, cfg.tile)
    )
    levels = plan_levels(arrays["k"].shape[0], cfg)
    out, off, nb, _ = partition_passes(arrays, len(x), cfg, levels)
    return np.asarray(out["k"]), np.asarray(off), levels, arrays["k"].shape[0]


def _passes_batched(x, cfg):
    arrays = batched_pad_with_sentinel(
        {"k": ops.keyspace.encode(jnp.asarray(x))}, max(cfg.base_case, cfg.tile)
    )
    levels = plan_levels(arrays["k"].shape[1], cfg)
    out, off, nb, _ = batched_partition_passes(arrays, x.shape[-1], cfg, levels)
    return np.asarray(out["k"]), np.asarray(off), levels, arrays["k"].shape[1]


_MODES = {
    # mode -> (n per row, batch B or None, expected number of levels)
    "single": (5000, None, 1),
    "two_level": (20000, None, 2),
    "batched": (3000, 3, 1),
    "segmented_batched": (12000, 2, 2),
}


@pytest.mark.parametrize("dist", sorted(DISTRIBUTIONS))
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
@pytest.mark.parametrize("mode", sorted(_MODES))
def test_stack_parity(dist, dtype, mode):
    n, B, want_levels = _MODES[mode]
    if B is None:
        x = make_input(dist, n, dtype, seed=7)
        run = _passes_1d
    else:
        x = np.stack(
            [make_input(dist, n, dtype, seed=7 + b) for b in range(B)]
        )
        run = _passes_batched
    keys_x, off_x, levels, n_pad = run(x, replace(_cfg, engine="xla"))
    keys_p, off_p, _, _ = run(x, replace(_cfg, engine="pallas"))
    assert len(levels) == want_levels
    # the pallas run must actually take the fused path at level 1
    assert _classify_rows(n_pad, _cfg, np.dtype(dtype), levels[0]) > 0
    np.testing.assert_array_equal(off_x, off_p)
    np.testing.assert_array_equal(keys_x, keys_p)


_U64_CHILD = """
import numpy as np
import jax.numpy as jnp
from repro import ops
from repro.core import sampling
from repro.data.distributions import DISTRIBUTIONS, make_input
from repro.kernels.level_fused import level_fused

N, N_REAL, K = 6144, 6000, 32
for dist in sorted(DISTRIBUTIONS):
    keys = ops.keyspace.encode(jnp.asarray(make_input(dist, N, np.uint64, seed=7)))
    assert keys.dtype == jnp.uint64
    samp = jnp.sort(keys[:256])
    spl = sampling.select_splitters(samp, K)
    for clf in ("tree", "radix"):
        dest, off = level_fused(
            keys, None if clf == "radix" else spl, k=K, n_real=N_REAL,
            classifier=clf, interpret=True,
        )
        if clf == "radix":
            from repro.classify import radix_bucket_ids
            ids = np.array(radix_bucket_ids(keys, K, 0))
        else:
            from repro.classify import classify
            ids = np.array(classify(keys, spl, K))
        ids[N_REAL:] = 2 * K
        order = np.argsort(ids, kind="stable")
        want = np.empty(N, np.int32); want[order] = np.arange(N)
        np.testing.assert_array_equal(np.asarray(dest), want, err_msg=dist + clf)
        woff = np.concatenate([[0], np.cumsum(np.bincount(ids, minlength=2*K+1))])
        np.testing.assert_array_equal(np.asarray(off), woff)
print("u64 fused parity OK")
"""


def test_fused_parity_u64_subprocess():
    """u64 keys exercise the widest keyspace; x64 must be on from startup
    (see tests/test_classify.py), so the sweep runs in a child process."""
    env = dict(os.environ, JAX_ENABLE_X64="1", JAX_PLATFORMS="cpu")
    env["PYTHONPATH"] = (
        os.path.join(os.path.dirname(__file__), "..", "src")
        + os.pathsep
        + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, "-c", _U64_CHILD],
        env=env,
        capture_output=True,
        text=True,
        timeout=1200,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "u64 fused parity OK" in proc.stdout


# ---------------------------------------------------------------------------
# the unified KernelLaunchSpec
# ---------------------------------------------------------------------------


class TestKernelLaunchSpec:
    def test_candidates_descending_powers_of_two(self):
        for kind, k in (("classify", 64), ("rank", 129), ("level_fused", 64),
                        ("merge", None), ("permute", None)):
            cands = spec_candidates(kind, 4, k)
            assert cands[-1] == 1
            assert all(a == 2 * b for a, b in zip(cands, cands[1:]))

    def test_leading_candidate_fits_vmem_budget(self):
        budget = HW["vmem_bytes"] // _CLASSIFY_VMEM_FRACTION
        for kind, k in (("classify", 128), ("level_fused", 128), ("rank", 257)):
            lead = spec_candidates(kind, 4, k)[0]
            assert lead * _bytes_per_row(kind, 4, k) <= budget

    def test_wider_keys_never_grow_the_tile(self):
        assert (spec_candidates("level_fused", 8, 128)[0]
                <= spec_candidates("level_fused", 4, 128)[0])
        assert (spec_candidates("classify", 4, 256)[0]
                <= spec_candidates("classify", 4, 32)[0])

    def test_n_filter(self):
        assert launch_spec("level_fused", 4, 32, n=1000).rows == 0
        spec = launch_spec("level_fused", 4, 32, n=6144)
        assert spec.rows > 0 and 6144 % spec.tile == 0

    def test_rows_pin(self):
        assert launch_spec("rank", 4, 65, rows=8).rows == 8
        # a pinned tile that does not divide n is rejected, not truncated
        assert launch_spec("rank", 4, 65, rows=8, n=1000).rows == 0

    def test_fused_rows_is_the_spec_projection(self):
        assert fused_rows(6144, 4, 32) == launch_spec(
            "level_fused", 4, 32, n=6144
        ).rows

    def test_merge_and_permute_kinds(self):
        assert launch_spec("merge", 4).tile == 1024
        assert spec_candidates("permute", 4)[0] <= 64
