"""Per-architecture smoke tests: REDUCED config, one forward + one train
step + prefill->decode consistency on CPU; asserts shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_reduced
from repro.models.transformer import (
    forward, init_decode_cache, init_model, train_loss,
)

B, S = 2, 32


def _batch(cfg, key):
    if cfg.takes_embeds:
        inputs = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32).astype(
            jnp.bfloat16
        )
    else:
        inputs = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0, cfg.vocab_size)
    return {"inputs": inputs, "labels": labels}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_finite(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    batch = _batch(cfg, jax.random.fold_in(key, 7))
    logits, cache, aux = jax.jit(
        lambda p, x: forward(p, cfg, x)
    )(params, batch["inputs"])
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert cache is None
    if cfg.family == "moe":
        assert aux is not None and np.isfinite(float(aux["lb_loss"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(1)
    params = init_model(key, cfg)
    batch = _batch(cfg, jax.random.fold_in(key, 3))

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p_: train_loss(p_, cfg, b), has_aux=True
        )(p)
        p2 = jax.tree.map(lambda a, g: a - 1e-3 * g.astype(a.dtype), p, grads)
        return loss, p2

    loss, params2 = step(params, batch)
    assert np.isfinite(float(loss))
    # loss is a plausible CE magnitude for random init
    assert 0.0 < float(loss) < 3.0 * np.log(cfg.vocab_size)
    # params actually moved
    moved = jax.tree.leaves(
        jax.tree.map(lambda a, b_: bool(jnp.any(a != b_)), params, params2)
    )
    assert any(moved)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_full_forward(arch):
    """Decoding token-by-token after a prefill must match the full forward
    logits (the serving-correctness invariant)."""
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(2)
    params = init_model(key, cfg)
    max_seq = S + 4
    batch = _batch(cfg, jax.random.fold_in(key, 9))
    x = batch["inputs"]

    full_logits, _, _ = jax.jit(lambda p, v: forward(p, cfg, v))(params, x)

    cache = init_decode_cache(cfg, B, max_seq)
    pre = x[:, : S - 2] if not cfg.takes_embeds else x[:, : S - 2, :]
    logits_p, cache, _ = jax.jit(
        lambda p, v, c: forward(p, cfg, v, cache=c, update_cache=True)
    )(params, pre, cache)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, -1], np.float32),
        np.asarray(full_logits[:, S - 3], np.float32),
        rtol=0.15, atol=0.15,
    )

    decode = jax.jit(
        lambda p, v, c, pos: forward(p, cfg, v, positions=pos, cache=c,
                                     update_cache=True)
    )
    for i in range(S - 2, S):
        tok = x[:, i : i + 1] if not cfg.takes_embeds else x[:, i : i + 1, :]
        pos = jnp.full((B, 1), i, jnp.int32)
        logits_d, cache, _ = decode(params, tok, cache, pos)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0], np.float32),
            np.asarray(full_logits[:, i], np.float32),
            rtol=0.15, atol=0.15,
        )
