"""Elastic rescale: a checkpoint written on one mesh must restore onto a
DIFFERENT mesh (new device count / topology) with identical values.

Each mesh runs in a subprocess (jax pins the host device count at first
init): save on (data=2, model=2), restore on (data=4, model=1) and on a
single device, comparing values bitwise.
"""
import os
import subprocess
import sys

import pytest

_SAVE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.manager import CheckpointManager
mesh = jax.make_mesh((2, 2), ("data", "model"))
state = {
    "w": jax.device_put(
        jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
        NamedSharding(mesh, P("data", "model"))),
    "b16": jax.device_put(
        (jnp.arange(16, dtype=jnp.float32) / 7).astype(jnp.bfloat16),
        NamedSharding(mesh, P("data"))),
}
CheckpointManager(%r).save(3, state)
print("SAVED")
"""

_RESTORE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.manager import CheckpointManager
mesh = jax.make_mesh(%r, %r)
like = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32),
        "b16": jax.ShapeDtypeStruct((16,), jnp.bfloat16)}
sh = {"w": NamedSharding(mesh, P(%r)), "b16": NamedSharding(mesh, P())}
ck = CheckpointManager(%r)
assert ck.latest_step() == 3
out = ck.restore(3, like, sh)
w = np.asarray(out["w"]); b = np.asarray(out["b16"], np.float32)
assert w.shape == (8, 8) and np.array_equal(w.ravel(), np.arange(64, dtype=np.float32))
assert np.allclose(b, (np.arange(16) / 7).astype(np.float32), atol=1e-2)
print("RESTORED", out["w"].sharding)
"""


def _run(code):
    env = {**os.environ, "PYTHONPATH": os.pathsep.join(sys.path)}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


@pytest.mark.parametrize("ndev,shape,axes,wspec", [
    (4, (4, 1), ("data", "model"), "data"),
    (1, (1,), ("data",), None),
])
def test_elastic_restore(tmp_path, ndev, shape, axes, wspec):
    ck = str(tmp_path / "ck")
    out = _run(_SAVE % ck)
    assert "SAVED" in out
    out = _run(_RESTORE % (ndev, shape, axes, wspec, ck))
    assert "RESTORED" in out
