"""repro.dist test suite (DESIGN.md §8).

Two tiers:

  * single-device tests run everywhere (tier-1): the degenerate d == 1
    contract, the level schedule / re-split selection rules (host math),
    the ``dist:`` plan-family round-trips, and the rewired callers'
    fallbacks;
  * multi-device tests require 8 devices and are skipped otherwise — the
    CI ``distributed`` job runs this file under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``, exercising the
    multi-level (2-axis) bit-identity acceptance matrix, payload routing,
    the distributed rank-k, adversarial skew (all-equal / zipf / one-hot
    shard) at the default capacity factor, and the re-split retry
    converging where the round-0 sample estimate fails.
"""
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from oracle import keyspace_sorted
from repro import dist
from repro.core.ips4o import SortConfig
from repro.data.distributions import DISTRIBUTIONS, make_input
from repro.dist.levels import plan_schedule
from repro.ops.plan import DistPlan, PlanCache

# small geometry so level passes engage at test sizes
_CFG = SortConfig(base_case=2048, kmax=32, tile=512, max_sample=2048)
_N = 1 << 16

needs_8 = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 (virtual) devices — CI mesh job"
)


# single-shard keyspace-order stable sort (the acceptance oracle: NaNs
# last, -0.0 strictly before +0.0) — shared across suites in tests/oracle.py
_keyspace_sorted = keyspace_sorted


def _valid_concat(out: np.ndarray, counts: np.ndarray) -> np.ndarray:
    d = counts.shape[0]
    cap = out.shape[0] // d
    return np.concatenate([out[i * cap : i * cap + counts[i]] for i in range(d)])


def _run_sort(mesh, axes, x, **kw):
    spec = P(axes if isinstance(axes, str) else tuple(axes))
    xs = jax.device_put(jnp.asarray(x), NamedSharding(mesh, spec))
    out, counts, ovf = jax.jit(
        lambda a: dist.sort(a, mesh, axes, cfg=_CFG, **kw)
    )(xs)
    return map(np.asarray, (out, counts, ovf))


# -- host-side unit tests (always run) --------------------------------------


def test_plan_schedule_two_axes():
    sched = plan_schedule({"pod": 2, "data": 4}, ("pod", "data"), 8192, slack=2.0)
    assert [lv.axis for lv in sched] == ["pod", "data"]
    assert [lv.groups for lv in sched] == [2, 4]          # per-axis fan-in
    assert sched[0].domain == ("pod", "data")             # level-0 spans all
    assert sched[1].domain == ("data",)                   # level-1 is pod-local
    # expectation-based capacities: padded size stays ~slack * n_local at
    # every level, not slack**levels
    assert sched[0].n_out == sched[1].n_in
    for lv in sched:
        assert lv.capacity % 128 == 0
        assert lv.n_out <= 2.5 * 8192


def test_plan_schedule_matches_seed_formula():
    # single level, divisible shard: identical capacity to the seed formula
    (lv,) = plan_schedule({"data": 8}, "data", 8192, slack=2.5)
    assert lv.capacity == max(128, -(-int(8192 // 8 * 2.5) // 128) * 128)


def test_splitters_from_histogram_balances_skew():
    from repro.core.sampling import splitters_from_histogram

    # 4 candidates, 70% of the mass just below candidate 30: every target
    # rank (25/50/75) lands inside that run, so the splitter repeats and
    # the equality-bucket striping spreads the run across all groups
    cands = jnp.asarray([10, 20, 30, 40], jnp.int32)
    cum = jnp.asarray([0, 10, 80, 90], jnp.int32)  # #keys < cand
    spl = splitters_from_histogram(cands, cum, 4, jnp.asarray(100, jnp.int32))
    assert spl.tolist() == [30, 30, 30]
    # balanced mass picks distinct, equidistant candidates
    spl = splitters_from_histogram(
        cands, jnp.asarray([0, 25, 50, 75], jnp.int32), 4,
        jnp.asarray(100, jnp.int32),
    )
    assert spl.tolist() == [20, 30, 40]


def test_dist_plan_defaults_and_roundtrip(tmp_path):
    pc = PlanCache(path=str(tmp_path / "plans.json"))
    p = pc.dist_plan(8192, 8, jnp.float32)
    assert isinstance(p, DistPlan) and p.slack == 2.0 and p.oversample >= 32
    tuned = pc.dist_plan(8192, 8, jnp.float32, tune=True)
    assert tuned.slack in (1.5, 2.0, 2.5, 3.0)
    # persisted: a fresh cache loads the same plan without tuning
    pc2 = PlanCache(path=str(tmp_path / "plans.json"))
    again = pc2.dist_plan(8192, 8, jnp.float32)
    assert again == tuned
    # engine override keeps the tuned capacity knobs
    forced = pc2.dist_plan(8192, 8, jnp.float32, engine="pallas")
    assert forced.engine == "pallas" and forced.slack == tuned.slack


def test_dist_plan_foreign_entry_tolerated(tmp_path):
    path = tmp_path / "plans.json"
    key = "dist:n_local=4096:d=4:dtype=int32"
    path.write_text(json.dumps({key: {"config": {"slack": "huge"}}}))
    pc = PlanCache(path=str(path))
    p = pc.dist_plan(4096, 4, jnp.int32)  # falls back to defaults, no crash
    assert p.slack == 2.0


def test_d1_sort_matches_ops_sort():
    mesh = jax.make_mesh((1,), ("data",))
    x = make_input("Uniform", 512, np.float32, seed=13)
    out, counts, ovf = _run_sort(mesh, "data", x, slack=2.0)
    assert not ovf.any()
    np.testing.assert_array_equal(out[: counts[0]], _keyspace_sorted(x))


def test_d1_truncation_contract():
    # undersized capacity on the degenerate mesh: flag + deterministic
    # truncation (first `capacity` elements, sorted) — the seed contract
    mesh = jax.make_mesh((1,), ("data",))
    x = make_input("Uniform", 512, np.float32, seed=13)
    out, counts, ovf = _run_sort(mesh, "data", x, slack=0.25)
    assert out.shape[0] == 128 and ovf.all() and counts.tolist() == [128]
    np.testing.assert_array_equal(out, np.sort(x[:128]))
    out2, counts2, _ = _run_sort(mesh, "data", x, slack=0.25)
    np.testing.assert_array_equal(out, out2)


def test_d1_rank_k_matches_ops():
    mesh = jax.make_mesh((1,), ("data",))
    x = make_input("Exponential", 512, np.float32, seed=3)
    xs = jax.device_put(jnp.asarray(x), NamedSharding(mesh, P("data")))
    v, i = dist.bottomk(xs, 7, mesh, "data", cfg=_CFG)
    np.testing.assert_array_equal(np.asarray(v), np.sort(x)[:7])
    np.testing.assert_allclose(x[np.asarray(i)], np.asarray(v))
    v, i = dist.topk(xs, 7, mesh, "data", cfg=_CFG)
    np.testing.assert_array_equal(np.asarray(v), np.sort(x)[::-1][:7])


def test_order_axes_slow_axis_first():
    """Default bandwidth model (outermost axis slowest): the slow axis
    schedules first so it drops out of every deeper splitter-collective
    domain; explicit bandwidths invert the choice (DESIGN.md §13.4)."""
    order = dist.order_axes({"pod": 2, "data": 4}, ("data", "pod"), 8192)
    assert order == ("pod", "data")
    order = dist.order_axes(
        {"pod": 2, "data": 4}, ("data", "pod"), 8192,
        bandwidths={"pod": 4.0, "data": 1.0},
    )
    assert order == ("data", "pod")
    # single axis / uniform bandwidths: the caller's order is kept (ties
    # never displace it)
    assert dist.order_axes({"data": 8}, "data", 8192) == ("data",)
    order = dist.order_axes(
        {"pod": 2, "data": 2}, ("data", "pod"), 8192,
        bandwidths={"pod": 1.0, "data": 1.0},
    )
    assert order == ("data", "pod")


def test_schedule_cost_ranks_orders():
    from repro.dist.levels import axis_bandwidths

    sizes = {"pod": 2, "data": 4}
    bw = axis_bandwidths(sizes)
    slow_first = plan_schedule(sizes, ("pod", "data"), 8192)
    fast_first = plan_schedule(sizes, ("data", "pod"), 8192)
    assert dist.schedule_cost(slow_first, bw) < dist.schedule_cost(fast_first, bw)
    # the a2a wire term alone is order-invariant under expectation-based
    # capacities (capacity depends only on the level's own fan-in) — the
    # splitter/control term is what ordering moves
    a = sum((lv.groups - 1) * lv.capacity for lv in slow_first)
    b = sum((lv.groups - 1) * lv.capacity for lv in fast_first)
    assert a == b


def test_dist_plan_axis_order_roundtrip(tmp_path):
    pc = PlanCache(path=str(tmp_path / "plans.json"))
    assert pc.dist_plan(8192, 8, jnp.float32).axis_order == ()
    pc.record_dist_axis_order(8192, 8, jnp.float32, ("pod", "data"))
    assert pc.dist_plan(8192, 8, jnp.float32).axis_order == ("pod", "data")
    # persisted across cache instances, and a capacity re-tune keeps it
    pc2 = PlanCache(path=str(tmp_path / "plans.json"))
    assert pc2.dist_plan(8192, 8, jnp.float32).axis_order == ("pod", "data")
    tuned = pc2.dist_plan(8192, 8, jnp.float32, tune=True)
    assert tuned.axis_order == ("pod", "data")


def test_d1_overlap_degenerate():
    # d == 1 with overlap on: the half-shard protocol must degrade to the
    # same output as the synchronous exchange (uint32 view: sentinel tails
    # decode to NaN for float keys)
    mesh = jax.make_mesh((1,), ("data",))
    x = make_input("Uniform", 512, np.float32, seed=13)
    o_s, c_s, _ = _run_sort(mesh, "data", x)
    o_o, c_o, ovf = _run_sort(mesh, "data", x, overlap=True)
    assert not ovf.any()
    np.testing.assert_array_equal(c_s, c_o)
    np.testing.assert_array_equal(o_s.view(np.uint32), o_o.view(np.uint32))


def test_order_rejects_unknown_mode():
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.arange(256, dtype=jnp.float32)
    with pytest.raises(ValueError, match="order"):
        dist.sort(x, mesh, "data", cfg=_CFG, order="fastest")


def test_pack_by_length_mesh_degenerate_falls_back():
    from repro.data.pipeline import pack_by_length

    lengths = np.random.default_rng(1).integers(1, 512, 777).astype(np.int32)
    mesh = jax.make_mesh((1,), ("data",))
    r1 = pack_by_length(lengths, 1024)
    r2 = pack_by_length(lengths, 1024, mesh=mesh)
    assert r1[2] == r2[2]
    np.testing.assert_array_equal(r1[0], r2[0])


# -- multi-device tests (CI `distributed` job) ------------------------------


@needs_8
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
@pytest.mark.parametrize("dist_name", sorted(DISTRIBUTIONS))
def test_multilevel_bit_identity(dist_name, dtype):
    """Acceptance: the 2-axis multi-level sort (and the 1-axis sort) is
    bit-identical to the single-shard keyspace-order stable sort on all
    nine paper distributions x {f32, i32} at d = 8 simulated devices."""
    x = make_input(dist_name, _N, dtype, seed=42)
    want = _keyspace_sorted(x).view(np.uint32)
    mesh = jax.make_mesh((8,), ("data",))
    out, counts, ovf = _run_sort(mesh, "data", x)
    assert not ovf.any(), f"overflow (1-axis) on {dist_name}"
    np.testing.assert_array_equal(_valid_concat(out, counts).view(np.uint32), want)
    mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
    out, counts, ovf = _run_sort(mesh2, ("pod", "data"), x)
    assert not ovf.any(), f"overflow (2-axis) on {dist_name}"
    np.testing.assert_array_equal(_valid_concat(out, counts).view(np.uint32), want)


@needs_8
def test_payload_rides_two_axis():
    mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
    x = make_input("Uniform", _N, np.float32, seed=11)
    vals = np.arange(_N, dtype=np.int32)[:, None]
    xs = jax.device_put(jnp.asarray(x), NamedSharding(mesh2, P(("pod", "data"))))
    vs = jax.device_put(
        jnp.asarray(vals), NamedSharding(mesh2, P(("pod", "data"), None))
    )
    out, ov, counts, ovf = jax.jit(
        lambda a, v: dist.sort(a, mesh2, ("pod", "data"), values=v, cfg=_CFG)
    )(xs, vs)
    out, ov, counts, ovf = map(np.asarray, (out, ov, counts, ovf))
    assert not ovf.any()
    keys = _valid_concat(out, counts)
    d = counts.shape[0]
    cap = out.shape[0] // d
    idxs = np.concatenate([ov[i * cap : i * cap + counts[i], 0] for i in range(d)])
    np.testing.assert_array_equal(keys, np.sort(x))
    np.testing.assert_allclose(x[idxs], keys)  # rows followed their keys


@needs_8
def test_argsort_global_order():
    mesh = jax.make_mesh((8,), ("data",))
    x = make_input("TwoDup", _N, np.int32, seed=5)
    xs = jax.device_put(jnp.asarray(x), NamedSharding(mesh, P("data")))
    order, counts, ovf = jax.jit(lambda a: dist.argsort(a, mesh, "data", cfg=_CFG))(xs)
    order, counts = np.asarray(order), np.asarray(counts)
    assert not np.asarray(ovf).any()
    gidx = _valid_concat(order, counts)
    assert sorted(gidx.tolist()) == list(range(_N))  # a permutation
    np.testing.assert_array_equal(x[gidx], np.sort(x))


@needs_8
def test_rank_k_distributed():
    mesh = jax.make_mesh((8,), ("data",))
    x = make_input("Exponential", _N, np.float32, seed=17)
    xs = jax.device_put(jnp.asarray(x), NamedSharding(mesh, P("data")))
    v, i = dist.bottomk(xs, 100, mesh, "data", cfg=_CFG)
    v, i = np.asarray(v), np.asarray(i)
    np.testing.assert_array_equal(v, np.sort(x)[:100])
    np.testing.assert_allclose(x[i], v)
    v, _ = dist.topk(xs, 100, mesh, "data", cfg=_CFG)
    np.testing.assert_array_equal(np.asarray(v), np.sort(x)[::-1][:100])


@needs_8
def test_group_by_per_shard_runs():
    mesh = jax.make_mesh((8,), ("data",))
    x = make_input("RootDup", _N, np.int32, seed=3)
    xs = jax.device_put(jnp.asarray(x), NamedSharding(mesh, P("data")))
    ks, starts, counts, ovf = jax.jit(
        lambda a: dist.group_by(a, mesh, "data", cfg=_CFG)
    )(xs)
    ks, starts, counts = np.asarray(ks), np.asarray(starts), np.asarray(counts)
    assert not np.asarray(ovf).any()
    cap = ks.shape[0] // 8
    total_starts = 0
    for s in range(8):
        seg_k = ks[s * cap : s * cap + counts[s]]
        seg_s = starts[s * cap : s * cap + counts[s]]
        want = np.ones(len(seg_k), bool)
        want[1:] = seg_k[1:] != seg_k[:-1]
        np.testing.assert_array_equal(seg_s, want)
        assert not starts[s * cap + counts[s] : (s + 1) * cap].any()
        total_starts += int(seg_s.sum())
    uniq = len(np.unique(x))
    assert uniq <= total_starts <= uniq + 7  # runs split only at boundaries


# -- adversarial skew: overflow must stay False at the default capacity -----


def _skew_inputs():
    rng = np.random.default_rng(7)
    one_hot = np.zeros(_N, np.float32)
    one_hot[: _N // 8] = rng.standard_normal(_N // 8)  # all mass on shard 0
    return {
        "all_equal": np.ones(_N, np.float32),
        "zipf": np.minimum(rng.zipf(1.3, _N), 1 << 30).astype(np.float32),
        "one_hot_shard": one_hot,
    }


@needs_8
@pytest.mark.parametrize("name", sorted(_skew_inputs()))
def test_skew_no_overflow_at_default_capacity(name):
    """All-equal / zipf / one-hot-shard placements through BOTH mesh
    shapes: the equality-bucket striping + balanced pre-exchange +
    re-split retry keep the overflow flag False at the default slack."""
    x = _skew_inputs()[name]
    want = _keyspace_sorted(x).view(np.uint32)
    for mesh, axes in [
        (jax.make_mesh((8,), ("data",)), "data"),
        (jax.make_mesh((2, 4), ("pod", "data")), ("pod", "data")),
    ]:
        out, counts, ovf = _run_sort(mesh, axes, x)
        assert not ovf.any(), f"overflow on {name}"
        np.testing.assert_array_equal(
            _valid_concat(out, counts).view(np.uint32), want
        )


@needs_8
def test_resplit_retry_converges():
    """Where the round-0 sample estimate genuinely overflows (tight
    capacity, tiny oversample), the observed-histogram re-split converges
    within the bounded retries — and with retries disabled the same
    configuration flags overflow (the last-resort path, still sorted)."""
    x = make_input("Exponential", _N, np.float32, seed=42)
    mesh = jax.make_mesh((8,), ("data",))
    _, _, ovf0 = _run_sort(mesh, "data", x, slack=1.25, oversample=8, retries=0)
    assert ovf0.any(), "config must overflow without the re-split retry"
    out, counts, ovf2 = _run_sort(mesh, "data", x, slack=1.25, oversample=8, retries=2)
    assert not ovf2.any(), "re-split retry failed to converge"
    np.testing.assert_array_equal(_valid_concat(out, counts), np.sort(x))
    # the last-resort output is deterministic and per-shard sorted
    out0, counts0, _ = _run_sort(mesh, "data", x, slack=1.25, oversample=8, retries=0)
    out0b, counts0b, _ = _run_sort(mesh, "data", x, slack=1.25, oversample=8, retries=0)
    np.testing.assert_array_equal(out0, out0b)
    np.testing.assert_array_equal(counts0, counts0b)
    cap = out0.shape[0] // 8
    for i in range(8):
        shard = out0[i * cap : i * cap + counts0[i]]
        assert np.all(shard[:-1] <= shard[1:])


@needs_8
def test_resplit_retry_obs_metrics():
    """The same converging-retry configuration, with ``repro.obs`` on: the
    exchange records >= 2 active re-split rounds (round 0 overflowed, a
    retry fixed it) and per-level collective volume; the retries=0 config
    records a ``dist.exchange_overflow`` event whose per-round fill shows
    capacity genuinely exceeded."""
    from repro import obs

    x = make_input("Exponential", _N, np.float32, seed=42)
    mesh = jax.make_mesh((8,), ("data",))
    obs.enabled(True)
    obs.reset()
    jax.clear_caches()  # jits traced while disabled carry no obs hooks
    try:
        _, _, ovf2 = _run_sort(
            mesh, "data", x, slack=1.25, oversample=8, retries=2
        )
        jax.effects_barrier()
        assert not ovf2.any()
        rounds = obs.hist_values("dist.resplit_rounds")
        assert rounds and max(rounds) >= 2, rounds
        vol = obs.hist_values("dist.collective_bytes")
        assert vol and all(v > 0 for v in vol), vol
        assert not obs.events("dist.exchange_overflow")

        obs.reset()
        _, _, ovf0 = _run_sort(
            mesh, "data", x, slack=1.25, oversample=8, retries=0
        )
        jax.effects_barrier()
        assert ovf0.any()
        evs = obs.events("dist.exchange_overflow")
        assert evs, "overflow must record an event"
        fill = evs[0]["attrs"]["round_fill"]
        assert max(np.atleast_1d(fill)) > 1.0, fill
    finally:
        obs.enabled(False)
        obs.reset()
        jax.clear_caches()


# -- overlap-scheduled exchange at d = 8 (DESIGN.md §13) --------------------


@needs_8
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
@pytest.mark.parametrize("dist_name", sorted(DISTRIBUTIONS))
def test_overlap_bit_identical_to_sync(dist_name, dtype):
    """Acceptance: the overlap schedule staggers only each half-shard's
    partition/pack/all_to_all behind a SHARED truncation budget, so its
    output is bit-identical to the synchronous exchange — all nine paper
    distributions x {f32, i32}, multi-level (2-axis) mesh (uint32 view:
    float sentinel tails decode to NaN)."""
    x = make_input(dist_name, _N, dtype, seed=42)
    mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
    o_s, c_s, v_s = _run_sort(mesh2, ("pod", "data"), x)
    o_o, c_o, v_o = _run_sort(mesh2, ("pod", "data"), x, overlap=True)
    np.testing.assert_array_equal(c_s, c_o)
    np.testing.assert_array_equal(v_s, v_o)
    np.testing.assert_array_equal(o_s.view(np.uint32), o_o.view(np.uint32))


@needs_8
def test_overlap_one_axis_payload_and_retry():
    """1-axis overlap: payload rows ride the half-shard frames bit-exactly,
    and the re-split retry (a full-shard decision by construction)
    composes with the overlap schedule."""
    mesh = jax.make_mesh((8,), ("data",))
    x = make_input("TwoDup", _N, np.int32, seed=5)
    vals = np.arange(_N, dtype=np.int32)
    xs = jax.device_put(jnp.asarray(x), NamedSharding(mesh, P("data")))
    vs = jax.device_put(jnp.asarray(vals), NamedSharding(mesh, P("data")))
    want = jax.jit(
        lambda a, v: dist.sort(a, mesh, "data", values=v, cfg=_CFG)
    )(xs, vs)
    got = jax.jit(
        lambda a, v: dist.sort(a, mesh, "data", values=v, cfg=_CFG, overlap=True)
    )(xs, vs)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(np.asarray(w), np.asarray(g))
    # the converging-retry config, overlapped: still converges, still
    # bit-identical to its synchronous twin
    xe = make_input("Exponential", _N, np.float32, seed=42)
    o_s, c_s, v_s = _run_sort(mesh, "data", xe, slack=1.25, oversample=8)
    o_o, c_o, v_o = _run_sort(
        mesh, "data", xe, slack=1.25, oversample=8, overlap=True
    )
    assert not v_s.any() and not v_o.any()
    np.testing.assert_array_equal(c_s, c_o)
    np.testing.assert_array_equal(o_s.view(np.uint32), o_o.view(np.uint32))


@needs_8
def test_auto_order_sorts_and_records(tmp_path, monkeypatch):
    """``order="auto"`` on a mis-declared axis tuple (fast axis first):
    the cost model reorders to slow-first, the sort is still globally
    correct, and the choice lands in the ``dist:`` plan's ``axis_order``
    for the next call to reuse without re-costing."""
    import repro.ops.plan as plan_mod

    pc = plan_mod.PlanCache(path=str(tmp_path / "plans.json"))
    monkeypatch.setattr(plan_mod, "default_cache", pc)
    mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
    x = make_input("Uniform", _N, np.float32, seed=42)
    want = _keyspace_sorted(x).view(np.uint32)
    out, counts, ovf = _run_sort(mesh2, ("data", "pod"), x, order="auto")
    assert not ovf.any()
    np.testing.assert_array_equal(_valid_concat(out, counts).view(np.uint32), want)
    p = pc.dist_plan(_N // 8, 8, jnp.float32)
    assert tuple(p.axis_order) == ("pod", "data")
    # second call: the persisted order wins (same result, no re-record)
    out2, _, ovf2 = _run_sort(mesh2, ("data", "pod"), x, order="auto")
    assert not ovf2.any()
    np.testing.assert_array_equal(out.view(np.uint32), out2.view(np.uint32))


# -- rewired callers at d = 8 ----------------------------------------------


@needs_8
@pytest.mark.parametrize("n_requests", [20, 50])
def test_scheduler_admits_across_mesh_axis(n_requests):
    # n_requests=20 pins the small-queue shape: n_pad=32 shards to 4 per
    # device, indivisible by d=8 — legal for rank-k (no pre-exchange)
    from repro.serve.scheduler import Request, Scheduler

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    lens = [int(v) for v in rng.integers(1, 20, n_requests)]

    def mk():
        s = Scheduler(batch_size=8)
        for u, m in enumerate(lens):
            s.submit(Request(uid=u, prompt_len=10, max_new=m))
        return s

    s_local, s_dist = mk(), mk()
    for _ in range(3):
        got = [r.uid for r in s_dist.next_batch(mesh=mesh, axes="data")]
        want = [r.uid for r in s_local.next_batch()]
        assert got == want  # identical admission order, FIFO ties included


@needs_8
def test_pack_by_length_sharded():
    from repro.data.pipeline import pack_by_length

    mesh = jax.make_mesh((8,), ("data",))
    lengths = np.random.default_rng(1).integers(1, 512, 3000).astype(np.int32)
    r_local = pack_by_length(lengths, 1024)
    r_dist = pack_by_length(lengths, 1024, mesh=mesh)
    assert r_local[2] == r_dist[2]  # same row count (pack consumes lengths)
    assert r_dist[0].max() < r_dist[2] and (r_dist[1] >= 0).all()
