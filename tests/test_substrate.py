"""Unit tests: optimizer, schedules, compression, checkpointing, data
pipeline, scheduler — the non-model substrate layers."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import SyntheticLM, pack_by_length
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import (
    compress_grads, decompress_grads, init_error_feedback,
)
from repro.optim.schedule import linear_warmup_cosine
from repro.serve.scheduler import Request, Scheduler


# ---------------------------------------------------------------- optimizer
@pytest.mark.parametrize("m_dtype,v_dtype", [
    ("float32", "float32"), ("bfloat16", "float32"), ("int8", "int8"),
])
def test_adamw_decreases_quadratic(m_dtype, v_dtype):
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, m_dtype=m_dtype, v_dtype=v_dtype)
    target = jnp.asarray([[1.0, -2.0], [3.0, 0.5]])
    params = {"w": jnp.zeros((2, 2))}
    state = adamw_init(params, cfg)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    l0 = loss(params)
    for _ in range(60):
        grads = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(loss(params)) < float(l0) * 0.05


def test_adamw_grad_clip_reported():
    cfg = AdamWConfig(lr=1e-3, grad_clip=1.0)
    params = {"w": jnp.ones((4,))}
    state = adamw_init(params, cfg)
    grads = {"w": jnp.full((4,), 100.0)}
    _, _, metrics = adamw_update(params, grads, state, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0, rel=1e-3)


def test_schedule_warmup_and_decay():
    s = lambda t: float(linear_warmup_cosine(jnp.asarray(t), 10, 100))
    assert s(0) == 0.0
    assert s(10) == pytest.approx(1.0, abs=1e-5)
    assert s(100) == pytest.approx(0.1, abs=1e-2)
    assert s(5) == pytest.approx(0.5, abs=0.05)


# ---------------------------------------------------------------- compression
def test_compression_error_feedback_converges():
    g = {"w": jnp.asarray([1.0, -0.5, 0.25, 1e-4])}
    err = init_error_feedback(g)
    total_true = jnp.zeros(4)
    total_q = jnp.zeros(4)
    for _ in range(50):
        comp, err = compress_grads(g, err)
        deq = decompress_grads(comp, g)
        total_true = total_true + g["w"]
        total_q = total_q + deq["w"]
    # error feedback: accumulated quantized sum tracks the true sum
    np.testing.assert_allclose(np.asarray(total_q), np.asarray(total_true),
                               rtol=0.02, atol=0.02)


def test_compression_is_int8():
    g = {"w": jnp.linspace(-3, 3, 100)}
    comp, _ = compress_grads(g, init_error_feedback(g))
    assert comp["w"]["q"].dtype == jnp.int8


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "opt": {"step": jnp.asarray(7, jnp.int32)}}
    for s in (1, 2, 3):
        mgr.save(s, state)
    assert mgr.latest_step() == 3
    assert not os.path.exists(os.path.join(str(tmp_path), "step_0000000001"))
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
    out = mgr.restore(3, like)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    assert int(out["opt"]["step"]) == 7


def test_checkpoint_async_and_crash_tmp_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.ones((4,))}
    mgr.save(5, state, blocking=False)
    mgr.wait()
    # simulate a crash mid-save: stray .tmp dir must be ignored + GC'd
    os.makedirs(os.path.join(str(tmp_path), "step_0000000009.tmp"))
    assert mgr.latest_step() == 5
    mgr2 = CheckpointManager(str(tmp_path))
    assert not any(d.endswith(".tmp") for d in os.listdir(str(tmp_path)))
    assert mgr2.latest_step() == 5


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.ones((4,))})
    with pytest.raises(ValueError, match="checkpoint"):
        mgr.restore(1, {"w": jax.ShapeDtypeStruct((5,), jnp.float32)})


# ---------------------------------------------------------------- data
def test_synthetic_data_deterministic_resume():
    src = SyntheticLM(vocab_size=100, seq_len=16, global_batch=4, seed=3)
    b1 = src.batch(41)
    b2 = src.batch(41)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    assert b1["inputs"].shape == (4, 16)
    assert not np.array_equal(src.batch(42)["inputs"], b1["inputs"])


def test_pack_by_length_valid():
    rng = np.random.default_rng(0)
    lengths = rng.integers(1, 100, 64)
    row_id, offset, rows = pack_by_length(lengths, 128)
    used = {}
    for doc in range(64):
        ln = min(int(lengths[doc]), 128)
        span = (int(row_id[doc]), int(offset[doc]), int(offset[doc]) + ln)
        assert span[2] <= 128
        for other in used.get(span[0], []):
            assert span[2] <= other[0] or span[1] >= other[1], "overlap"
        used.setdefault(span[0], []).append((span[1], span[2]))
    # sorted packing should be reasonably tight
    assert rows <= int(np.ceil(lengths.sum() / 128)) * 2


# ---------------------------------------------------------------- scheduler
def test_scheduler_shortest_remaining_first():
    s = Scheduler(batch_size=3)
    for uid, rem in enumerate([50, 5, 20, 1, 99]):
        s.submit(Request(uid=uid, prompt_len=8, max_new=rem))
    batch = s.next_batch()
    assert [r.uid for r in batch] == [3, 1, 2]
    assert len(s.queue) == 2


def test_scheduler_fifo_on_remaining_ties():
    """Equal ``remaining`` must admit in submission (FIFO) order — the
    composite (remaining, arrival-index) key makes tie-breaking
    deterministic instead of riding the unstable window sort."""
    s = Scheduler(batch_size=2)
    for uid in range(6):
        s.submit(Request(uid=uid, prompt_len=8, max_new=7))
    assert [r.uid for r in s.next_batch()] == [0, 1]
    assert [r.uid for r in s.next_batch()] == [2, 3]
    assert [r.uid for r in s.next_batch()] == [4, 5]
    # shorter-remaining still beats arrival order
    s.submit(Request(uid=10, prompt_len=8, max_new=9))
    s.submit(Request(uid=11, prompt_len=8, max_new=3))
    s.submit(Request(uid=12, prompt_len=8, max_new=9))
    assert [r.uid for r in s.next_batch()] == [11, 10]


def test_scheduler_mixed_queue_lengths_deterministic():
    """Same queue content -> same admission, across the pow2-padded shapes
    (and re-running an identical queue state twice is identical)."""
    def run(n):
        s = Scheduler(batch_size=3)
        for uid in range(n):
            s.submit(Request(uid=uid, prompt_len=4, max_new=5 + (uid % 2)))
        return [r.uid for r in s.next_batch()]

    for n in (3, 4, 5, 7, 9):
        first, second = run(n), run(n)
        assert first == second
        expect = sorted(range(n), key=lambda u: (5 + (u % 2), u))[: min(3, n)]
        assert first == expect
