"""The paper's technique in its framework role: sort-based MoE dispatch.

Runs the deepseek-moe-16b family (reduced config) and shows the IPS4o
partition machinery routing tokens to experts:

  * expert-major token grouping through ``repro.ops.group_by`` — the
    subsystem view of dispatch — with the stable-partition and fused
    Pallas (``kernels.dispatch_rank``) engines agreeing,
  * per-LAYER routing in ONE call: a whole step's routing ids (L, n*k)
    dispatched by one batched ``sort_dispatch`` / one ``batched_argsort``
    instead of L python-loop dispatches (DESIGN.md §6),
  * per-expert token counts from the tile-histogram pass,
  * capacity clamping (the overflow-block analogue) and drop fraction,
  * gradient flow through the dispatch (train a few steps, loss drops),
  * equivalence vs the dense one-hot reference dispatch.

  PYTHONPATH=src python examples/moe_routing.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_reduced
from repro.data.pipeline import SyntheticLM
from repro.models.moe import expert_capacity, sort_dispatch
from repro.models.transformer import init_model, train_loss
from repro.ops import batched_argsort, group_by
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

# --- 1. dispatch mechanics on raw routing ids ------------------------------
E, k, n = 8, 2, 4096
rng = np.random.default_rng(0)
flat_e = jnp.asarray(rng.integers(0, E, n * k).astype(np.int32))
cap = expert_capacity(n, E, k, 1.25)
slot, kept, counts = jax.jit(lambda a: sort_dispatch(a, E, cap))(flat_e)
print(f"experts={E} top_k={k} tokens={n} capacity={cap}")
print(f"per-expert counts: {np.asarray(counts)}")
print(f"dropped: {1 - float(kept.sum()) / (n * k):.4%}")
assert len(np.unique(np.asarray(slot)[np.asarray(kept)])) == int(kept.sum())

# --- 1b. the same grouping as a repro.ops library call ---------------------
# group_by IS the dispatch problem: group (token, k) entries expert-major.
tok_idx = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
g = group_by(flat_e, tok_idx, num_groups=E)                 # stable partition
gp = group_by(flat_e, tok_idx, num_groups=E, method="pallas")  # fused kernel
np.testing.assert_array_equal(np.asarray(g.counts), np.asarray(counts))
np.testing.assert_array_equal(np.asarray(g.keys), np.asarray(gp.keys))
np.testing.assert_array_equal(np.asarray(g.perm), np.asarray(gp.perm))
assert np.all(np.diff(np.asarray(g.keys)) >= 0)  # expert-major grouping
print(f"ops.group_by == pallas dispatch-rank grouping  "
      f"(max per-expert load {int(np.asarray(g.counts).max())})")

# --- 1c. per-layer routing in ONE call -------------------------------------
# A transformer step routes every MoE layer; batching the dispatch over the
# layer axis runs all L stable partitions in one trace (DESIGN.md §6).
L = 6
flat_e_layers = jnp.asarray(rng.integers(0, E, (L, n * k)).astype(np.int32))
slot_b, kept_b, counts_b = jax.jit(
    lambda a: sort_dispatch(a, E, cap)
)(flat_e_layers)
for layer in range(L):
    s1, k1, c1 = sort_dispatch(flat_e_layers[layer], E, cap)
    np.testing.assert_array_equal(np.asarray(slot_b[layer]), np.asarray(s1))
    np.testing.assert_array_equal(np.asarray(kept_b[layer]), np.asarray(k1))
    np.testing.assert_array_equal(np.asarray(counts_b[layer]), np.asarray(c1))
# the expert-major order itself, for all layers in one batched argsort
order_b = batched_argsort(flat_e_layers)
grouped = np.take_along_axis(np.asarray(flat_e_layers), np.asarray(order_b), axis=1)
assert np.all(np.diff(grouped, axis=1) >= 0)
print(f"1c. {L} layers routed in one batched call "
      f"(per-layer == unbatched, bit-exact)")

# --- 2. the same machinery inside the full model ---------------------------
cfg = get_reduced("deepseek-moe-16b")
params = init_model(jax.random.PRNGKey(0), cfg)
opt = adamw_init(params, AdamWConfig())
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4)

@jax.jit
def step(params, opt, batch):
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: train_loss(p, cfg, batch, lb_coef=0.01), has_aux=True
    )(params)
    params, opt, _ = adamw_update(params, grads, opt, AdamWConfig(lr=1e-3), 1.0)
    return params, opt, loss, metrics

losses = []
for i, batch in zip(range(20), iter(data)):
    batch = jax.tree.map(jnp.asarray, batch)
    # learnable task (copy): next-token = current token
    batch["labels"] = batch["inputs"]
    params, opt, loss, metrics = step(params, opt, batch)
    losses.append(float(loss))
    if i % 5 == 0:
        extra = {k_: round(float(v), 4) for k_, v in metrics.items()}
        print(f"step {i}: loss={losses[-1]:.4f} {extra}")

assert losses[-1] < losses[0], f"loss did not drop: {losses[0]} -> {losses[-1]}"
print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} through the sort-based "
      "dispatch (gradients flow) — OK")
