"""Quickstart: the IPS4o sorting library in seven snippets.

  PYTHONPATH=src python examples/quickstart.py
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ips4o import ips4o_sort, make_sorter

# 1. Sort keys -------------------------------------------------------------
x = jnp.asarray(np.random.default_rng(0).random(1 << 17, dtype=np.float32))
y = ips4o_sort(x)
assert bool(jnp.all(y[:-1] <= y[1:]))
print(f"1. sorted {x.shape[0]} f32 keys: head={np.asarray(y[:4])}")

# 2. Key + payload (any pytree with matching leading dim) -------------------
payload = {"idx": jnp.arange(x.shape[0]), "vec": jnp.zeros((x.shape[0], 3))}
yk, yv = ips4o_sort(x, payload)
assert bool(jnp.all(jnp.take(x, yv["idx"]) == yk))
print("2. payload rows follow their keys (checked)")

# 3. In-place: donate the input buffer (the paper's headline property) ------
sorter = make_sorter(x.shape[0], x.dtype, donate=True)
y = sorter(jnp.array(x))  # donated copy: XLA reuses its HBM allocation
print("3. donated sorter compiled; input buffer reused by XLA")

# 4. Duplicate-heavy input -> equality buckets (§4.4) ----------------------
dup = jnp.asarray((np.arange(1 << 17) % 317).astype(np.float32))
yd = ips4o_sort(dup)
assert bool(jnp.all(yd[:-1] <= yd[1:]))
print("4. RootDup-style input sorted via equality buckets")

# 5. Distributed sort under shard_map (single device here; the same code
#    runs on the (data,) axis of the production mesh) ----------------------
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import dist

mesh = jax.make_mesh((len(jax.devices()),), ("data",))
ds = jax.jit(functools.partial(dist.sort, mesh=mesh))
xs = jax.device_put(x, NamedSharding(mesh, P("data")))
out, counts, overflow = ds(xs)
assert not bool(jnp.any(overflow))
print(f"5. distributed sort: {int(counts.sum())} elements globally ordered "
      f"across {mesh.shape['data']} shard(s)")

# 6. Batched: (B, n) rows sorted in ONE trace (no vmap, no python loop) ----
from repro.ops import batched_sort, batched_topk

xb = jnp.asarray(np.random.default_rng(1).random((8, 1 << 14), np.float32))
yb = batched_sort(xb)                          # every row, one compiled call
assert bool(jnp.all(yb[:, :-1] <= yb[:, 1:]))
vals, idx = batched_topk(xb, 4)                # per-row top-k, same call shape
assert bool(jnp.all(vals[:, 0] == xb.max(axis=1)))
print(f"6. batched: {xb.shape[0]} rows x {xb.shape[1]} keys sorted in one "
      "trace; per-row top-4 via batched_topk")

# 7. Streaming / out-of-core (DESIGN.md §7): datasets larger than one device
#    allocation — IPS4o run formation + a stable merge-path k-way merge ------
from repro.stream import external_sort, merge, streaming_topk

host = np.random.default_rng(2).standard_normal(1 << 16).astype(np.float32)
ys = external_sort(host, chunk_size=1 << 14)   # 4 chunks, never all on device
assert (ys[:-1] <= ys[1:]).all()
runs = [jnp.sort(jnp.asarray(host[: 1 << 13])),  # device-resident k-way merge
        jnp.sort(jnp.asarray(host[1 << 13 : 1 << 14]))]
m = merge(runs)                                # stable; engine="pallas" for the
assert bool(jnp.all(m[:-1] <= m[1:]))          # merge-path kernel
tv, ti = streaming_topk(host, 8, chunk_size=1 << 14)  # bounded candidate buffer
assert tv[0] == host.max()
print(f"7. streaming: {host.shape[0]} host-resident keys external-sorted in "
      "chunks; k-way merge + streaming top-8 (indices into the stream)")
print("quickstart OK")
