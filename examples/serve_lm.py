"""Batched serving example: prefill + KV-cache decode with the Engine.

  PYTHONPATH=src python examples/serve_lm.py [--arch yi-9b] [--new 24]

Demonstrates:
  * jitted prefill and decode steps with donated (in-place) KV cache;
  * the scheduler ordering requests by remaining length (the sorting
    engine's serving role) to minimize padding waste;
  * greedy generation determinism: the same prompt twice -> same tokens.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_reduced
from repro.models.transformer import init_model
from repro.serve.engine import Engine, ServeConfig
from repro.serve.scheduler import Request, Scheduler


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=24)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    mesh = jax.make_mesh((len(jax.devices()), 1), ("data", "model"))
    params = init_model(jax.random.PRNGKey(0), cfg)

    # scheduler: admit a ragged queue, batch by sorted remaining length
    rng = np.random.default_rng(0)
    sched = Scheduler(batch_size=args.batch)
    lens = {}
    for i in range(args.batch * 2):
        plen = int(rng.integers(4, args.prompt_len + 1))
        lens[i] = plen
        sched.submit(Request(uid=i, prompt_len=plen,
                             max_new=int(rng.integers(8, args.new + 1))))
    wave = sched.next_batch()
    print(f"scheduler picked {len(wave)} of {args.batch * 2} requests "
          f"(remaining {[r.remaining for r in wave]} — sorted, min pad waste)")

    scfg = ServeConfig(max_seq=args.prompt_len + args.new + 8,
                       batch_size=args.batch)
    engine = Engine(cfg, scfg, mesh, params)

    prompts = np.zeros((args.batch, args.prompt_len), np.int32)
    for r_i, r in enumerate(wave[: args.batch]):
        plen = lens[r.uid]
        prompts[r_i, -plen:] = rng.integers(0, cfg.vocab_size, plen)
    prompts = jnp.asarray(prompts)

    with mesh:
        out1 = engine.generate(prompts, args.new)
    print(f"generated {out1.shape} tokens; first row: {np.asarray(out1[0,:8])}...")

    # determinism check (greedy): the SAME engine back-to-back — generate()
    # reinitializes the donated KV cache, so a second call can't attend
    # over the first call's stale keys/values
    with mesh:
        out2 = engine.generate(prompts, args.new)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    # ... and across fresh engine instances
    engine2 = Engine(cfg, scfg, mesh, params)
    with mesh:
        out3 = engine2.generate(prompts, args.new)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out3))
    print("greedy decode deterministic across calls and engine instances — OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
