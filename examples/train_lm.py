"""End-to-end training driver (deliverable (b)): train a reduced-config LM
for a few hundred steps on the synthetic pipeline, with checkpoint/restart.

  PYTHONPATH=src python examples/train_lm.py                 # yi-9b reduced, 120 steps
  PYTHONPATH=src python examples/train_lm.py --arch deepseek-moe-16b --steps 60

This is a thin preset over the production launcher
(``python -m repro.launch.train``), which the multi-pod configs also use.
Kill it mid-run and re-launch with the same --ckpt-dir to see restart.
"""
import argparse
import sys
import tempfile

from repro.launch.train import main as train_main


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt:
        rc = train_main([
            "--arch", args.arch, "--reduced",
            "--steps", str(args.steps),
            "--batch", str(args.batch),
            "--seq", str(args.seq),
            "--microbatch", str(max(args.batch // 2, 1)),
            "--ckpt-dir", ckpt,
            "--ckpt-every", str(max(args.steps // 2, 1)),
        ])
    return rc


if __name__ == "__main__":
    sys.exit(main())
