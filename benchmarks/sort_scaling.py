"""Paper Fig. 7 / 15: scaling with cores.

TPU analogue: the distributed sort under ``shard_map`` over d host
devices (d = 1, 2, 4, 8 virtual CPU devices).  Because jax locks the
device count at first init, each d runs in a SUBPROCESS with
``--xla_force_host_platform_device_count=d``.  We report strong scaling
(fixed n, growing d) the way Fig. 7 reports speedup vs the sequential
IS4o, plus the ICI-roofline-projected speedup at 256 chips from the
dry-run collective model (EXPERIMENTS.md §Roofline).

NOTE: virtual CPU devices share ONE physical core in this container, so
wall-clock "speedup" here validates *overhead* (it should stay near 1.0x,
not collapse); the real scaling evidence is the collective-bytes term,
which is printed per d and grows only as O(n/d) — the signature of a
single all-to-all data exchange.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import Row

N = 1 << 20
DEVICE_COUNTS = [1, 2, 4, 8]

_CHILD = r"""
import os, sys, json
d = int(sys.argv[1]); n = int(sys.argv[2])
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={d}"
import jax, time
import jax.numpy as jnp
import numpy as np
import functools
from repro import dist
from repro.launch.hlo_cost import analyze_hlo

mesh = jax.make_mesh((d,), ("data",))
sorter = jax.jit(functools.partial(dist.sort, mesh=mesh, axis="data"))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.random(n, dtype=np.float32))
from jax.sharding import NamedSharding, PartitionSpec as P
x = jax.device_put(x, NamedSharding(mesh, P("data")))
out, counts, overflow = jax.block_until_ready(sorter(x))
assert not bool(np.any(np.asarray(overflow))), "capacity overflow"
cap_total = out.shape[0] // d
counts = np.asarray(counts)
vals = np.asarray(out)
parts = [vals[i * cap_total : i * cap_total + counts[i]] for i in range(d)]
glob = np.concatenate(parts)
assert glob.shape[0] == n, f"lost elements: {glob.shape[0]} != {n}"
assert np.all(glob[:-1] <= glob[1:]), "not globally sorted"
np.testing.assert_array_equal(np.sort(np.asarray(x)), glob)
ts = []
for _ in range(3):
    t0 = time.perf_counter(); jax.block_until_ready(sorter(x))
    ts.append(time.perf_counter() - t0)
lowered = jax.jit(sorter).lower(x)
hc = analyze_hlo(lowered.compile().as_text())
print(json.dumps({"d": d, "t": float(np.median(ts)),
                  "coll_bytes_per_dev": hc.coll_bytes,
                  "flops_per_dev": hc.flops}))
"""


def run(quick: bool = False):
    n = (1 << 18) if quick else N
    counts = DEVICE_COUNTS[:3] if quick else DEVICE_COUNTS
    rows: list[Row] = []
    t1 = None
    env = {**os.environ,
           "PYTHONPATH": os.pathsep.join(sys.path)}
    for d in counts:
        r = subprocess.run(
            [sys.executable, "-c", _CHILD, str(d), str(n)],
            capture_output=True, text=True, env=env, timeout=1200,
        )
        if r.returncode != 0:
            raise RuntimeError(f"scaling child d={d} failed:\n{r.stderr[-2000:]}")
        res = json.loads(r.stdout.strip().splitlines()[-1])
        if t1 is None:
            t1 = res["t"]
        rows.append({
            "bench": "scaling", "devices": d, "n": n,
            "s_per_call": round(res["t"], 5),
            "speedup_vs_1dev": round(t1 / res["t"], 2),
            "coll_bytes_per_dev": int(res["coll_bytes_per_dev"]),
            "flops_per_dev": int(res["flops_per_dev"]),
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), ["bench", "devices", "n", "s_per_call", "speedup_vs_1dev",
                 "coll_bytes_per_dev", "flops_per_dev"])
