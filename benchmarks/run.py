"""Benchmark driver: one module per paper table/figure.

  sort_sequential    Fig. 6 / 16-19   sequential sizes x algos
  sort_distributions Fig. 8 / 9-11    nine input distributions
  sort_datatypes     Fig. 12-14       Pair / Quartet / 100Bytes payloads
  sort_scaling       Fig. 7 / 15      shard_map scaling (subprocess per d)
  io_volume          §4.5 / App. B    in-place vs out-of-place I/O volume
  moe_dispatch       framework role   sort-based vs one-hot MoE dispatch
  sort_ops           DESIGN.md §5     repro.ops: topk vs full sort, group_by
  sort_batched       DESIGN.md §6     batched (B, n) sort vs loop-over-rows
  sort_external      DESIGN.md §7     external_sort vs single-shot + merge
  sort_distributed   DESIGN.md §8     multi-level mesh sort, volume per level
  sort_classifier    DESIGN.md §9     classifier engines: tree/radix/learned/auto
  sort_records       DESIGN.md §11    workload zoo: string / composite records

``python -m benchmarks.run [--quick] [--only NAME[,NAME...]]`` prints one
CSV block per table plus a Table-1-style summary, and writes every row to
a machine-readable ``BENCH_sort.json`` (``--json PATH`` overrides) so
each PR's perf trajectory is diffable; ``--list`` prints the registered
suites and exits.
"""
from __future__ import annotations

import argparse
import sys
import time

MODULES = [
    "sort_sequential",
    "sort_distributions",
    "sort_datatypes",
    "sort_scaling",
    "io_volume",
    "moe_dispatch",
    "sort_ops",
    "sort_batched",
    "sort_external",
    "sort_distributed",
    "sort_classifier",
    "sort_records",
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmark modules")
    ap.add_argument("--json", default="BENCH_sort.json",
                    help="machine-readable output path ('' disables)")
    ap.add_argument("--trace", default=None, metavar="PREFIX",
                    help="export a repro.obs trace of one instrumented "
                         "quick-shape sort: PREFIX.jsonl + PREFIX.trace.json "
                         "(Perfetto), plus an obs_trace phase-attribution row")
    ap.add_argument("--list", action="store_true",
                    help="print the registered benchmark suites and exit")
    args = ap.parse_args(argv)

    if args.list:
        for name in MODULES:
            print(name)
        return 0

    import importlib

    from benchmarks.common import emit, emit_json

    failures = 0
    all_rows = {}
    only = None
    if args.only:
        only = {s.strip() for s in args.only.split(",") if s.strip()}
        unknown = only - set(MODULES)
        if unknown:  # fail loudly: a typo must not silently drop a bench
            ap.error(f"--only: unknown module(s) {sorted(unknown)}; "
                     f"choose from {MODULES}")
    for name in MODULES:
        if only and name not in only:
            continue
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.perf_counter()
        print(f"\n== {name} ==", flush=True)
        try:
            rows = mod.run(quick=args.quick)
        except Exception as e:  # pragma: no cover
            import traceback
            traceback.print_exc()
            print(f"FAILED {name}: {type(e).__name__}: {e}")
            failures += 1
            continue
        all_rows[name] = rows
        if rows:
            emit(rows, list(rows[0].keys()))
        print(f"-- {name} done in {time.perf_counter() - t0:.1f}s", flush=True)

    if args.trace:
        from benchmarks.common import export_obs_trace

        print("\n== obs_trace ==", flush=True)
        try:
            rows = export_obs_trace(args.trace)
            all_rows["obs_trace"] = rows
            emit(rows, list(rows[0].keys()))
        except Exception as e:  # pragma: no cover
            import traceback
            traceback.print_exc()
            print(f"FAILED obs_trace: {type(e).__name__}: {e}")
            failures += 1

    if args.json and all_rows:
        emit_json(all_rows, args.json)

    # Table-1-style summary: our speedups vs library sort
    dist = all_rows.get("sort_distributions")
    if dist:
        sp = [r["speedup_vs_jnp"] for r in dist]
        print("\n== summary (Table 1 analogue) ==")
        print(f"is4o vs jnp.sort speedup: min={min(sp):.2f} "
              f"median={sorted(sp)[len(sp)//2]:.2f} max={max(sp):.2f}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
