"""Paper technique in its framework role: sort-based MoE token dispatch.

Compares the IPS4o-machinery dispatch (classify -> per-tile histogram ->
prefix-sum -> rank -> scatter, ``models/moe.sort_dispatch``) against the
standard dense one-hot dispatch (einsum with a (n, E, cap) one-hot tensor,
the Mesh-TensorFlow/Switch formulation).  The sort-based path does
O(n*(k + log n)) work vs O(n*E*cap) for the one-hot; on duplicate-heavy
routing (hot experts) the equality-bucket analogue (capacity clamp) keeps
it balanced.  Wall-clock on CPU + flops from the compiled artifact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.moe import expert_capacity, sort_dispatch

from benchmarks.common import Row, bench


def _onehot_dispatch(flat_e, num_experts, cap):
    """Dense baseline: position-in-expert via cumsum over one-hot."""
    m = flat_e.shape[0]
    oh = jax.nn.one_hot(flat_e, num_experts, dtype=jnp.int32)   # (m, E)
    rank = jnp.cumsum(oh, axis=0) * oh - 1                       # (m, E)
    r = jnp.max(rank, axis=1)
    kept = r < cap
    slot = jnp.where(kept, flat_e * cap + r, num_experts * cap)
    return slot, kept, jnp.sum(oh, axis=0)


def run(quick: bool = False):
    rows: list[Row] = []
    n = (1 << 14) if quick else (1 << 16)
    for E, k, skew in [(64, 6, "uniform"), (64, 6, "hot"), (128, 8, "uniform")]:
        rng = np.random.default_rng(1)
        if skew == "uniform":
            e = rng.integers(0, E, n * k).astype(np.int32)
        else:  # zipf-ish hot experts — the duplicate-keys regime of §4.4
            z = rng.zipf(1.5, n * k) % E
            e = z.astype(np.int32)
        cap = expert_capacity(n, E, k, 1.25)
        flat = jnp.asarray(e)

        f_sort = jax.jit(lambda a: sort_dispatch(a, E, cap))
        f_oh = jax.jit(lambda a: _onehot_dispatch(a, E, cap))

        s_slot, s_kept, s_counts = jax.tree.map(np.asarray, f_sort(flat))
        o_slot, o_kept, o_counts = jax.tree.map(np.asarray, f_oh(flat))
        np.testing.assert_array_equal(s_counts, o_counts)
        # both must produce collision-free slots for kept entries
        for slot, kept in [(s_slot, s_kept), (o_slot, o_kept)]:
            kept_slots = slot[kept]
            assert len(np.unique(kept_slots)) == len(kept_slots)
        assert int(s_kept.sum()) == int(o_kept.sum())

        t_sort = bench(lambda: f_sort(flat))
        t_oh = bench(lambda: f_oh(flat))
        rows.append({
            "bench": "moe_dispatch", "experts": E, "top_k": k, "skew": skew,
            "n_tokens": n, "capacity": cap,
            "sort_us": round(t_sort * 1e6, 1),
            "onehot_us": round(t_oh * 1e6, 1),
            "speedup": round(t_oh / t_sort, 2),
            "dropped_frac": round(1 - float(s_kept.sum()) / (n * k), 4),
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), ["bench", "experts", "top_k", "skew", "n_tokens", "capacity",
                 "sort_us", "onehot_us", "speedup", "dropped_frac"])
