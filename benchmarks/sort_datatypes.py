"""Paper Fig. 12-14: element types with payloads (Pair / Quartet / 100Bytes).

Key + payload sorts: the paper's Pair = 8B key + 8B payload, Quartet =
24B key + 8B (we model the lexicographic 3-word key with a u64 primary
key + 2-word payload — same bytes moved), 100Bytes = 10B key + 90B
payload (u64 key + 12 u64 words ~ 104B).  The paper's observation that
moving elements twice hurts large payloads is visible as ns/elem growth
with payload width.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ips4o import SortConfig, ips4o_sort

from benchmarks.common import Row, bench

N = 1 << 20

TYPES = {            # payload words of 8 bytes alongside a u64 key
    "Key8": 0,       # bare 64-bit element (paper: double)
    "Pair": 1,       # 8B key + 8B payload
    "Quartet": 3,    # 32B element
    "100Bytes": 12,  # ~104B element
}


def run(quick: bool = False):
    n = (1 << 18) if quick else N
    rows: list[Row] = []
    rng = np.random.default_rng(3)
    keys = jnp.asarray(rng.integers(0, 2**63 - 1, n, dtype=np.uint64))
    for name, words in TYPES.items():
        if words:
            payload = jnp.asarray(
                rng.integers(0, 2**63 - 1, (n, words), dtype=np.uint64)
            )
            f = jax.jit(lambda k, v: ips4o_sort(k, v, cfg=SortConfig()))
            ok, ov = f(keys, payload)
            # payload rows must follow their key
            order = np.argsort(np.asarray(keys), kind="stable")
            np.testing.assert_array_equal(np.asarray(ok), np.asarray(keys)[order])
            t = bench(lambda: f(keys, payload))
        else:
            f = jax.jit(lambda k: ips4o_sort(k, cfg=SortConfig()))
            t = bench(lambda: f(keys))
        rows.append({
            "bench": "datatypes", "type": name,
            "elem_bytes": 8 * (1 + words), "n": n,
            "ns_per_elem": round(t / n * 1e9, 2),
            "MB_per_s": round(8 * (1 + words) * n / t / 1e6, 1),
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), ["bench", "type", "elem_bytes", "n", "ns_per_elem", "MB_per_s"])
