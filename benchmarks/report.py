"""Render BENCH json into a markdown perf dashboard.

``python -m benchmarks.report`` turns the committed ``BENCH_sort.json``
baseline (and, when given ``--fresh``, a just-produced run) into one
markdown document: a table per bench module, with tracked wall-clock
metrics annotated by their committed-vs-fresh delta.  CI renders it next
to the perf gate and uploads it as an artifact, so a PR's perf story is
readable without parsing JSON.

Matching and "tracked metric" rules are imported from
``benchmarks.check_regression`` — the dashboard and the gate can never
disagree about which rows correspond or which columns matter.

With ``--trace BENCH_trace.jsonl`` (the JSONL half of ``benchmarks.run
--trace``), the report also renders a per-phase attribution table from
the recorded spans — where one instrumented sort spent its time, by span
name, with ``phase:*`` staged timings listed first.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional

from benchmarks.check_regression import is_tracked_metric, row_identity

_FLOAT_FIELDS_SI = ("hlo_flops", "hlo_bytes")


def _fmt(field: str, v: Any) -> str:
    if v is None or v == "":
        return ""
    if field.endswith("_bytes") or field in _FLOAT_FIELDS_SI:
        try:
            x = float(v)
        except (TypeError, ValueError):
            return str(v)
        for unit in ("", "K", "M", "G", "T"):
            if abs(x) < 1024:
                return f"{x:.1f}{unit}" if unit else f"{x:.0f}"
            x /= 1024
        return f"{x:.1f}P"
    if isinstance(v, float):
        return f"{v:g}"
    return str(v)


def _delta(base: Optional[float], fresh: float) -> str:
    if not base:
        return ""
    d = fresh / base - 1.0
    return f" ({d:+.0%})"


def render(
    baseline: Dict[str, Any], fresh: Optional[Dict[str, Any]] = None
) -> str:
    """Markdown for a baseline payload, deltas vs ``fresh`` when given.

    Each bench becomes a table whose columns are the union of its rows'
    fields (baseline order first).  When a fresh run contains a matching
    row (same identity under the gate's ``row_identity``), tracked
    metrics show the fresh value with the relative delta vs the
    committed baseline; fresh-only and baseline-only rows are counted in
    the per-bench caption.
    """
    benches: Dict[str, List[Dict]] = baseline.get("benches") or {}
    fresh_benches: Dict[str, List[Dict]] = (fresh or {}).get("benches") or {}
    fresh_rows = {
        row_identity(b, r): r for b, rows in fresh_benches.items() for r in rows
    }
    lines = ["# Benchmark report", ""]
    meta = [f"baseline backend: `{baseline.get('backend', '?')}`",
            f"generated: {baseline.get('generated_at', '?')}"]
    if fresh:
        meta.append(f"fresh run: {fresh.get('generated_at', '?')} "
                    f"(`{fresh.get('backend', '?')}`)")
    lines += ["; ".join(meta), ""]
    if not benches and not fresh_benches:
        # an empty trajectory (fresh checkout, aborted run, hand-pruned
        # json) is a valid dashboard — say so instead of rendering nothing
        lines += ["*(empty trajectory: no benches recorded — run "
                  "`python -m benchmarks.run` to populate)*", ""]
        return "\n".join(lines) + "\n"
    for bench in sorted(set(benches) | set(fresh_benches)):
        rows = benches.get(bench, [])
        extra = [
            r for b, rs in fresh_benches.items() if b == bench for r in rs
            if row_identity(b, r) not in {row_identity(bench, x) for x in rows}
        ]
        lines.append(f"## {bench}")
        if not rows and not extra:
            lines += ["(no rows)", ""]
            continue
        fields: List[str] = []
        for r in rows + extra:
            for k in r:
                if k not in fields:
                    fields.append(k)
        matched = 0
        body = []
        for r in rows:
            fr = fresh_rows.get(row_identity(bench, r))
            matched += fr is not None
            cells = []
            for f in fields:
                v = r.get(f)
                if fr is not None and is_tracked_metric(f) and f in fr:
                    base_v = v if isinstance(v, (int, float)) else None
                    try:
                        fresh_v = float(fr[f])
                    except (TypeError, ValueError):
                        # non-numeric tracked cell (a crashed run wrote a
                        # marker string): show it verbatim, no delta
                        cells.append(_fmt(f, fr[f]))
                    else:
                        cells.append(_fmt(f, fr[f]) + _delta(base_v, fresh_v))
                else:
                    cells.append(_fmt(f, v))
            body.append("| " + " | ".join(cells) + " |")
        for r in extra:  # fresh-only rows (new bench cells, baseline-first)
            body.append(
                "| " + " | ".join(_fmt(f, r.get(f)) for f in fields) + " | *new*"
            )
        cap = f"{len(rows)} baseline row(s)"
        if fresh:
            cap += f", {matched} matched fresh, {len(extra)} fresh-only"
        lines += [
            cap, "",
            "| " + " | ".join(fields) + " |",
            "|" + "---|" * len(fields),
            *body, "",
        ]
    return "\n".join(lines) + "\n"


def attribution(trace_path: str) -> str:
    """Markdown per-phase attribution table from an obs JSONL trace.

    Aggregates the trace's span lines by name (count / min / total);
    ``phase:*`` spans — the staged-subtraction timers — sort first, the
    remaining structural spans after, both by descending total time.
    Returns "" when the file is missing or holds no spans.
    """
    agg: Dict[str, List[float]] = {}
    try:
        with open(trace_path) as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                rec = json.loads(line)
                if rec.get("type") != "span":
                    continue
                name, dur = rec["name"], float(rec.get("dur_us", 0.0))
                cur = agg.setdefault(name, [0, 0.0, float("inf")])
                cur[0] += 1
                cur[1] += dur
                cur[2] = min(cur[2], dur)
    except FileNotFoundError:
        print(f"no obs trace at {trace_path}; skipping attribution table")
        return ""
    if not agg:
        return ""
    order = sorted(
        agg.items(),
        key=lambda kv: (not kv[0].startswith("phase:"), -kv[1][1]),
    )
    lines = [
        "## Per-phase attribution (obs trace)", "",
        f"from `{trace_path}` — `phase:*` rows are min-of-k staged timers, "
        "the rest are structural spans (trace-time inside jit)", "",
        "| span | count | min_us | total_us |",
        "|---|---|---|---|",
    ]
    for name, (cnt, total, mn) in order:
        lines.append(f"| {name} | {cnt} | {mn:.1f} | {total:.1f} |")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="BENCH_sort.json")
    ap.add_argument("--fresh", default=None,
                    help="optional fresh-run json to diff against the baseline")
    ap.add_argument("--trace", default=None,
                    help="optional obs JSONL trace for the attribution table")
    ap.add_argument("--out", default="BENCH_report.md")
    args = ap.parse_args(argv)

    with open(args.baseline) as fh:
        baseline = json.load(fh)
    fresh = None
    if args.fresh:
        try:
            with open(args.fresh) as fh:
                fresh = json.load(fh)
        except FileNotFoundError:
            print(f"no fresh run at {args.fresh}; rendering baseline only")
    md = render(baseline, fresh)
    if args.trace:
        md += "\n" + attribution(args.trace)
    with open(args.out, "w") as fh:
        fh.write(md)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
