"""Record-sorting bench: the workload zoo through the multi-word path.

One row per (dataset family x classifier): wall clock and throughput of
``ops.argsort_records`` over the MSD tie-break schedule (DESIGN.md §11),
with a ``jnp.lexsort`` reference column and the static observability
columns from :func:`benchmarks.common.compiled_cost` — memory watermark
(XLA's compiled memory_analysis) and analytic HLO flops/bytes — so the
perf trajectory of the record path is visible in byte/flop terms, not
just machine-relative wall clocks.

Output is parity-asserted against the independent numpy oracle
(``datasets.oracle_argsort``) before anything is timed.  String families
are width-clipped so the word count stays at W=2 (the tie-heavy regime);
composite families are W=3 by construction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import ops
from repro.core.ips4o import SortConfig
from repro.data import datasets

from benchmarks.common import Row, bench, compiled_cost

_CFG = SortConfig()
_WIDTH = 8  # byte clip for string families: W=2
CLASSIFIERS = ["radix", "auto"]
CLASSIFIERS_FULL = ["tree", "radix", "auto"]


def _make(name: str, n: int) -> datasets.Dataset:
    width = _WIDTH if name in ("RnaSequences", "UrlPaths") else None
    return datasets.make_dataset(name, n, seed=0, width=width)


def run(quick: bool = False):
    n = 1 << 14 if quick else 1 << 16
    classifiers = CLASSIFIERS if quick else CLASSIFIERS_FULL
    rows: list[Row] = []
    for name in sorted(datasets.DATASETS):
        ds = _make(name, n)
        words = jnp.asarray(ds.words)
        want = datasets.oracle_argsort(ds)
        lex_cols = tuple(
            reversed([ops.keyspace.encode(words[:, j]) for j in range(ds.spec.words)])
        )
        lex_fn = jax.jit(lambda *c: jnp.lexsort(c))
        lex_s = bench(lambda: lex_fn(*lex_cols))
        for clf in classifiers:
            fn = lambda w: ops.argsort_records(w, cfg=_CFG, classifier=clf)
            got = np.asarray(fn(words))
            np.testing.assert_array_equal(got, want)  # parity before timing
            call, cost = compiled_cost(fn, words)
            s = bench(call)
            row: Row = {
                "dataset": name,
                "n": n,
                "W": ds.spec.words,
                "classifier": clf,
                "s_per_call": round(s, 6),
                "meps": round(n / s / 1e6, 1),
                "lexsort_us": round(lex_s * 1e6, 1),
            }
            row.update(cost)
            rows.append(row)
    return rows
