"""Paper Fig. 6 / 16-19: sequential sort, Uniform input, across sizes.

IS4o (ours, in-place via donation) vs s3-sort (out-of-place samplesort,
the paper's non-in-place baseline) vs jnp.sort (XLA's library sort — the
std::sort role).  ns/element, f32 and u32 keys.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ips4o import SortConfig, ips4o_sort
from repro.core.s3sort import s3_sort

from benchmarks.common import Row, bench, check_sorted

SIZES = [1 << 16, 1 << 18, 1 << 20, 1 << 22]
DTYPES = [jnp.float32, jnp.uint32]


def run(quick: bool = False):
    sizes = SIZES[:2] if quick else SIZES
    rows: list[Row] = []
    for dtype in DTYPES:
        for n in sizes:
            rng = np.random.default_rng(42)
            if dtype == jnp.float32:
                x = jnp.asarray(rng.random(n, dtype=np.float32))
            else:
                x = jnp.asarray(
                    rng.integers(0, 2**32 - 1, n, dtype=np.uint32)
                )
            algos = {
                "is4o": jax.jit(lambda a: ips4o_sort(a, cfg=SortConfig())),
                "s3sort": jax.jit(lambda a: s3_sort(a, cfg=SortConfig())),
                "jnp.sort": jax.jit(jnp.sort),
            }
            for name, f in algos.items():
                check_sorted(f(x), x)
                t = bench(lambda f=f: f(x))
                rows.append({
                    "bench": "sequential", "algo": name,
                    "dtype": jnp.dtype(dtype).name, "n": n,
                    "ns_per_elem": round(t / n * 1e9, 2),
                    "s_per_call": round(t, 5),
                })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), ["bench", "algo", "dtype", "n", "ns_per_elem", "s_per_call"])
