"""Paper Fig. 6 / 16-19: sequential sort, Uniform input, across sizes.

IS4o (ours, in-place via donation) vs s3-sort (out-of-place samplesort,
the paper's non-in-place baseline) vs jnp.sort (XLA's library sort — the
std::sort role).  ns/element, f32 and u32 keys.

IS4o runs once per partition engine ("xla" | "pallas"); each engine row
also carries a partition-pass-only timing (``part_ns_per_elem``) — the
classify+distribute phase is where the engines differ, the base case is
shared.  A final ``plan`` row per (dtype, smallest n) reports which engine
the PlanCache autotune sweep selects on this machine.  Off-TPU the Pallas
kernels run in interpret mode, so their rows are restricted to
n <= _PALLAS_MAX (larger sizes would only time the interpreter) — the
skipped rows are announced, not silent.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ips4o import (
    SortConfig, ips4o_sort, pad_with_sentinel, partition_passes, plan_levels,
)
from repro.core.s3sort import s3_sort
from repro.ops.plan import PlanCache

from benchmarks.common import Row, bench, check_sorted

SIZES = [1 << 16, 1 << 18, 1 << 20, 1 << 22]
DTYPES = [jnp.float32, jnp.uint32]
_PALLAS_MAX = 1 << 18  # off-TPU interpret-mode ceiling for pallas rows
_KERNEL_N = 1 << 20    # per-kernel rows: the DESIGN.md §10 comparison size


def _partition_only(x: jax.Array, cfg: SortConfig):
    """Just the level passes (classify + distribute) — the engine seam."""
    arrays = pad_with_sentinel({"k": x}, max(cfg.base_case, cfg.tile))
    levels = plan_levels(arrays["k"].shape[0], cfg)
    if not levels:
        return arrays["k"], None
    out, off, _, _ = partition_passes(arrays, x.shape[0], cfg, levels)
    return out["k"], off


def _engines(n: int) -> list:
    if jax.default_backend() == "tpu" or n <= _PALLAS_MAX:
        return ["xla", "pallas"]
    print(f"# n={n}: pallas rows skipped (interpret mode past {_PALLAS_MAX})")
    return ["xla"]


def _kernel_rows(quick: bool) -> list:
    """Per-kernel microbenchmarks (DESIGN.md §10), uniform u32.

    ``level_fused`` is timed against the *three-pass* composition it
    replaced (classify kernel -> histogram glue -> counting-rank kernel —
    no longer a production path, composed here from the surviving pieces)
    at the same n; ``block_permute`` is the swap-cycle in-place block
    move.  Both engines run in interpret mode off-TPU, so the fused vs
    three-pass ratio compares like with like.
    """
    from repro.kernels.block_permute import permute_blocks_by_dest, stable_block_dest
    from repro.kernels.classify import classify_histogram
    from repro.kernels.dispatch_rank import partition_ranks
    from repro.kernels.level_fused import level_fused

    rows: list[Row] = []
    k = 64
    n = (1 << 18) if quick else _KERNEL_N
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.integers(0, 2**32 - 1, n, dtype=np.uint32))
    spl = jnp.sort(jnp.asarray(
        rng.integers(0, 2**32 - 1, k - 1, dtype=np.uint32)
    ))

    fused = jax.jit(partial(level_fused, k=k, interpret=True))

    @jax.jit
    def three_pass(keys, spl):
        # the pre-§10 production tiles: classify at the old roofline rows,
        # counting-rank at its former hard-coded rows=8 default
        b, hist = classify_histogram(keys, spl, k=k, rows=32, interpret=True)
        totals = hist.sum(axis=0)
        off = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(totals).astype(jnp.int32)]
        )
        dest = partition_ranks(b, off[:-1], nb=2 * k, rows=8, interpret=True)
        return dest, off

    # identical placements (the fused kernel's whole contract)
    d_f, o_f = fused(x, spl)
    d_t, o_t = three_pass(x, spl)
    np.testing.assert_array_equal(np.asarray(d_f), np.asarray(d_t))
    # fused offsets carry one extra boundary (the empty pad bucket)
    np.testing.assert_array_equal(np.asarray(o_f[: o_t.shape[0]]), np.asarray(o_t))

    t_fused = bench(lambda: fused(x, spl), agg="min")
    t_three = bench(lambda: three_pass(x, spl), agg="min")
    for algo, t in (("level_fused", t_fused), ("three_pass", t_three)):
        rows.append({
            "bench": "kernel", "algo": algo, "engine": "pallas",
            "dtype": "uint32", "n": n,
            "s_per_call": round(t, 5),
            "meps": round(n / t / 1e6, 2),
        })
    print(f"-- fused level pass vs three-pass: {t_three / t_fused:.2f}x "
          f"(bar: >= 2x) at n={n}")

    block = 1024
    nblocks = n // block
    bb = jnp.asarray(rng.integers(0, 2 * k, nblocks, dtype=np.int32))
    dst = stable_block_dest(bb)
    mover = jax.jit(partial(permute_blocks_by_dest, block_elems=block,
                            interpret=True))
    t_perm = bench(lambda: mover(x, dst), agg="min")
    rows.append({
        "bench": "kernel", "algo": "block_permute", "engine": "pallas",
        "dtype": "uint32", "n": n,
        "s_per_call": round(t_perm, 5),
        "meps": round(n / t_perm / 1e6, 2),
    })
    return rows


def run(quick: bool = False):
    sizes = SIZES[:2] if quick else SIZES
    rows: list[Row] = []
    plan_cache = PlanCache(path=None)  # the real per-machine cache
    for dtype in DTYPES:
        for n in sizes:
            rng = np.random.default_rng(42)
            if dtype == jnp.float32:
                x = jnp.asarray(rng.random(n, dtype=np.float32))
            else:
                x = jnp.asarray(
                    rng.integers(0, 2**32 - 1, n, dtype=np.uint32)
                )
            algos = {}
            for engine in _engines(n):
                cfg = SortConfig(engine=engine)
                algos[("is4o", engine)] = (
                    jax.jit(partial(ips4o_sort, cfg=cfg)),
                    jax.jit(partial(_partition_only, cfg=cfg)),
                )
            algos[("s3sort", "-")] = (
                jax.jit(lambda a: s3_sort(a, cfg=SortConfig())), None)
            algos[("jnp.sort", "-")] = (jax.jit(jnp.sort), None)

            for (name, engine), (f, fpart) in algos.items():
                check_sorted(f(x), x)
                t = bench(lambda f=f: f(x))
                row = {
                    "bench": "sequential", "algo": name, "engine": engine,
                    "dtype": jnp.dtype(dtype).name, "n": n,
                    "ns_per_elem": round(t / n * 1e9, 2),
                    "s_per_call": round(t, 5),
                }
                if fpart is not None:
                    tp = bench(lambda fpart=fpart: fpart(x))
                    row["part_ns_per_elem"] = round(tp / n * 1e9, 2)
                rows.append(row)

        # which engine does the tuned plan pick at the smallest size?
        n0 = sizes[0]
        chosen = plan_cache.config_for("sort", n0, dtype, tune=True)
        rows.append({
            "bench": "sequential", "algo": "plan", "engine": chosen.engine,
            "dtype": jnp.dtype(dtype).name, "n": n0,
        })
    rows.extend(_kernel_rows(quick))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), ["bench", "algo", "engine", "dtype", "n", "ns_per_elem",
                 "s_per_call", "part_ns_per_elem", "meps"])
