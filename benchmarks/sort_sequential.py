"""Paper Fig. 6 / 16-19: sequential sort, Uniform input, across sizes.

IS4o (ours, in-place via donation) vs s3-sort (out-of-place samplesort,
the paper's non-in-place baseline) vs jnp.sort (XLA's library sort — the
std::sort role).  ns/element, f32 and u32 keys.

IS4o runs once per partition engine ("xla" | "pallas"); each engine row
also carries a partition-pass-only timing (``part_ns_per_elem``) — the
classify+distribute phase is where the engines differ, the base case is
shared.  A final ``plan`` row per (dtype, smallest n) reports which engine
the PlanCache autotune sweep selects on this machine.  Off-TPU the Pallas
kernels run in interpret mode, so their rows are restricted to
n <= _PALLAS_MAX (larger sizes would only time the interpreter) — the
skipped rows are announced, not silent.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ips4o import (
    SortConfig, ips4o_sort, pad_with_sentinel, partition_passes, plan_levels,
)
from repro.core.s3sort import s3_sort
from repro.ops.plan import PlanCache

from benchmarks.common import Row, bench, check_sorted

SIZES = [1 << 16, 1 << 18, 1 << 20, 1 << 22]
DTYPES = [jnp.float32, jnp.uint32]
_PALLAS_MAX = 1 << 18  # off-TPU interpret-mode ceiling for pallas rows


def _partition_only(x: jax.Array, cfg: SortConfig):
    """Just the level passes (classify + distribute) — the engine seam."""
    arrays = pad_with_sentinel({"k": x}, max(cfg.base_case, cfg.tile))
    levels = plan_levels(arrays["k"].shape[0], cfg)
    if not levels:
        return arrays["k"], None
    out, off, _, _ = partition_passes(arrays, x.shape[0], cfg, levels)
    return out["k"], off


def _engines(n: int) -> list:
    if jax.default_backend() == "tpu" or n <= _PALLAS_MAX:
        return ["xla", "pallas"]
    print(f"# n={n}: pallas rows skipped (interpret mode past {_PALLAS_MAX})")
    return ["xla"]


def run(quick: bool = False):
    sizes = SIZES[:2] if quick else SIZES
    rows: list[Row] = []
    plan_cache = PlanCache(path=None)  # the real per-machine cache
    for dtype in DTYPES:
        for n in sizes:
            rng = np.random.default_rng(42)
            if dtype == jnp.float32:
                x = jnp.asarray(rng.random(n, dtype=np.float32))
            else:
                x = jnp.asarray(
                    rng.integers(0, 2**32 - 1, n, dtype=np.uint32)
                )
            algos = {}
            for engine in _engines(n):
                cfg = SortConfig(engine=engine)
                algos[("is4o", engine)] = (
                    jax.jit(partial(ips4o_sort, cfg=cfg)),
                    jax.jit(partial(_partition_only, cfg=cfg)),
                )
            algos[("s3sort", "-")] = (
                jax.jit(lambda a: s3_sort(a, cfg=SortConfig())), None)
            algos[("jnp.sort", "-")] = (jax.jit(jnp.sort), None)

            for (name, engine), (f, fpart) in algos.items():
                check_sorted(f(x), x)
                t = bench(lambda f=f: f(x))
                row = {
                    "bench": "sequential", "algo": name, "engine": engine,
                    "dtype": jnp.dtype(dtype).name, "n": n,
                    "ns_per_elem": round(t / n * 1e9, 2),
                    "s_per_call": round(t, 5),
                }
                if fpart is not None:
                    tp = bench(lambda fpart=fpart: fpart(x))
                    row["part_ns_per_elem"] = round(tp / n * 1e9, 2)
                rows.append(row)

        # which engine does the tuned plan pick at the smallest size?
        n0 = sizes[0]
        chosen = plan_cache.config_for("sort", n0, dtype, tune=True)
        rows.append({
            "bench": "sequential", "algo": "plan", "engine": chosen.engine,
            "dtype": jnp.dtype(dtype).name, "n": n0,
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), ["bench", "algo", "engine", "dtype", "n", "ns_per_elem",
                 "s_per_call", "part_ns_per_elem"])
