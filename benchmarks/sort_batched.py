"""Batched sort engine vs python-loop-over-rows (DESIGN.md §6).

The claim to evidence: real callers carry a batch dimension, and
``ops.batched_sort``'s single-trace pipeline beats B dispatches of the
1-D sort.  Two regimes, matching the rewired callers:

  * **scheduler regime** — many small int32 rows (pow2-padded admission
    queues, ``serve.scheduler.admit_many``): per-row work is comparable
    to the per-call dispatch cost, so looping wastes most of the step and
    batching wins big.  This is where the >= 3x acceptance bar (ISSUE 3)
    is measured, at B >= 32.
  * **bulk regime** — fewer large f32 rows (per-layer routing ids,
    per-shard length argsorts): the sort work itself dominates and the
    batched win settles toward the dispatch-amortization floor; reported
    for honesty, not for the bar.

Timings use min-of-N (``common.bench(agg="min")``): the loop side
accumulates B sequential dispatches per observation, so medians carry
scheduler noise that the minimum does not.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ips4o import SortConfig
from repro.ops import batched_sort, sort

from benchmarks.common import Row, bench

# cfg matched to the row length, as the plan cache would pick: small
# windows for queue-sized rows, paper defaults for bulk rows
_SMALL = SortConfig(base_case=256, tile=256, max_sample=256, kmax=64)
_BULK = SortConfig()


def _sweep(quick: bool):
    small = [(32, 256), (64, 256), (64, 512), (128, 256)]
    bulk = [(32, 4096)] if quick else [(32, 4096), (32, 16384)]
    if not quick:
        small += [(128, 512), (256, 256)]
    return [(B, n, "scheduler", _SMALL, jnp.int32) for B, n in small] + [
        (B, n, "bulk", _BULK, jnp.float32) for B, n in bulk
    ]


def run(quick: bool = False):
    rows: list[Row] = []
    rng = np.random.default_rng(0)
    for B, n, regime, cfg, dtype in _sweep(quick):
        if dtype == jnp.int32:
            x = jnp.asarray(rng.integers(0, 1 << 30, (B, n)).astype(np.int32))
        else:
            x = jnp.asarray(rng.standard_normal((B, n)).astype(np.float32))
        f_batched = jax.jit(lambda a, cfg=cfg: batched_sort(a, cfg=cfg))
        f_row = jax.jit(lambda a, cfg=cfg: sort(a, cfg=cfg))

        out = np.asarray(f_batched(x))
        np.testing.assert_array_equal(out, np.sort(np.asarray(x), axis=1))
        np.testing.assert_array_equal(  # per-row bit-parity with the 1-D op
            out[0], np.asarray(f_row(x[0]))
        )

        t_batched = bench(lambda: f_batched(x), iters=9, agg="min")
        t_loop = bench(
            lambda: [f_row(x[i]) for i in range(B)], iters=9, agg="min"
        )
        rows.append({
            "bench": "batched_vs_loop",
            "regime": regime,
            "B": B,
            "n": n,
            "batched_us": round(t_batched * 1e6, 1),
            "loop_us": round(t_loop * 1e6, 1),
            "speedup": round(t_loop / t_batched, 2),
            "batched_meps": round(B * n / t_batched / 1e6, 1),
        })
    best = max(r["speedup"] for r in rows if r["B"] >= 32)
    print(f"-- best speedup at B>=32: {best:.2f}x (bar: >= 3x)")
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run(quick=True), ["bench", "regime", "B", "n", "batched_us",
                           "loop_us", "speedup", "batched_meps"])
