"""External (out-of-core) sort vs single-shot, plus merge throughput
(DESIGN.md §7).

Two claims to evidence:

  * **overhead ceiling** — at an n where the data still fits on device,
    ``stream.external_sort`` (chunked run formation + merge tournament
    with host spill between rounds, host-to-host end to end) must stay
    within 2x of the single-shot plan-cached device sort measured over
    the same host-to-host boundary (ISSUE 4 acceptance bar).  That ratio
    is the price of streaming; above device memory the single-shot path
    simply does not exist.
  * **merge throughput** — the k-way merge primitive itself (device-
    resident, jitted), both engines, at several fan-ins: Meps rows so the
    merge-path kernel's trajectory is trackable per PR.

One shared row schema (run.py prints one header per module): the
external rows leave the merge columns blank and vice versa, matching the
``sort_ops`` convention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.ops import plan
from repro.stream import external_sort, merge

from benchmarks.common import Row, bench

HEADER = ["bench", "n", "chunks", "fanin", "engine",
          "external_us", "single_us", "ratio", "merge_us", "meps"]


def _row(**kw) -> Row:
    r = {h: "" for h in HEADER}
    r.update(kw)
    return r


def _external_rows(quick: bool) -> list:
    rows: list[Row] = []
    rng = np.random.default_rng(0)
    sweeps = [(1 << 17, 4)] if quick else [(1 << 16, 4), (1 << 17, 4), (1 << 17, 8)]
    for n, chunks in sweeps:
        chunk = n // chunks
        x = rng.standard_normal(n).astype(np.float32)

        single = plan.default_cache.get_sorter(n, jnp.float32, "sort")

        def single_shot():
            # same host-to-host boundary as the external path
            return np.asarray(single(jax.device_put(jnp.asarray(x))))

        np.testing.assert_array_equal(external_sort(x, chunk_size=chunk), np.sort(x))
        np.testing.assert_array_equal(single_shot(), np.sort(x))

        t_ext = bench(lambda: external_sort(x, chunk_size=chunk), iters=5, agg="min")
        t_one = bench(single_shot, iters=5, agg="min")
        rows.append(_row(
            bench="external_vs_single",
            n=n,
            chunks=chunks,
            external_us=round(t_ext * 1e6, 1),
            single_us=round(t_one * 1e6, 1),
            ratio=round(t_ext / t_one, 2),
            meps=round(n / t_ext / 1e6, 2),
        ))
    worst = max(r["ratio"] for r in rows)
    print(f"-- external_sort overhead ceiling: {worst:.2f}x (bar: <= 2x on-device)")
    return rows


def _merge_rows(quick: bool) -> list:
    rows: list[Row] = []
    rng = np.random.default_rng(1)
    run_len = 1 << 14
    fanins = [2, 8] if quick else [2, 4, 8, 16]
    for k in fanins:
        runs = [
            jnp.asarray(np.sort(rng.standard_normal(run_len).astype(np.float32)))
            for _ in range(k)
        ]
        n = k * run_len
        for engine in ("xla", "pallas"):
            f = jax.jit(lambda *rs, e=engine: merge(list(rs), engine=e))
            out = np.asarray(f(*runs))
            np.testing.assert_array_equal(
                out, np.sort(np.concatenate([np.asarray(r) for r in runs]))
            )
            t = bench(lambda: f(*runs), iters=5, agg="min")
            rows.append(_row(
                bench="merge_throughput",
                n=n,
                fanin=k,
                engine=engine,
                merge_us=round(t * 1e6, 1),
                meps=round(n / t / 1e6, 2),
            ))
    return rows


def run(quick: bool = False):
    return _external_rows(quick) + _merge_rows(quick)


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run(quick=True), HEADER)
