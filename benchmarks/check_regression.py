"""CI perf-regression gate: fresh quick-bench run vs the committed baseline.

``python -m benchmarks.check_regression --fresh BENCH_fresh.json`` compares
every time-like metric of the fresh run against the committed
``BENCH_sort.json`` baseline and exits non-zero when any tracked metric
slowed down by more than the threshold (default 25%) — so the perf
trajectory the bench history establishes cannot silently regress.

Matching and tracking rules:

  * rows are keyed per bench module by their *identity fields* — every
    field that is neither a tracked (time-like) metric nor a derived one
    (speedup / ratio / Meps / byte counts), e.g. (bench, algo, n, dtype,
    engine);
  * tracked metrics are lower-is-better wall-clock fields:
    ``s_per_call``, ``*_us``, ``us``, ``*ns_per_elem``, ``t`` — except
    reference-implementation columns (``loop_us``, ``single_us``), whose
    variance is a comparison moving, not a product path regressing, and
    the ``phase_*`` attribution columns of the obs-trace bench (staged
    subtractions, reference-only);
  * rows present in only one file are reported but never fail the gate
    (CI runs ``--quick --only <subset>``; new benches land baseline-first);
  * intentional regressions go in the allowlist
    (``benchmarks/regression_allowlist.json``): a list of entries with a
    ``reason`` and ``match`` dict of identity fields (subset match; an
    optional ``metric`` restricts to one metric) — matched failures
    downgrade to warnings.

Wall clocks are machine-relative; the gate compares runs from the same CI
runner class against a baseline refreshed whenever a PR intentionally
moves a number (regenerate via ``python -m benchmarks.run --quick --only
sort_sequential,sort_batched,sort_external,sort_distributed,sort_classifier``).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, Iterable, List, Tuple

_TRACKED_EXACT = {"s_per_call", "us", "t"}
_TRACKED_SUFFIX = ("_us", "ns_per_elem")
# reference-implementation timings (the comparison column of a bench, e.g.
# loop-over-rows, the single-shot sort, or jnp.lexsort): their variance is
# not a product regression — the engine column of the same row is what the
# gate tracks
_REFERENCE_METRICS = {"loop_us", "single_us", "lexsort_us"}
# per-phase attribution columns (the obs-trace staged-subtraction table):
# differences of isolated sub-step timings, informative but far too jittery
# to gate — and not identity either (they vary run to run)
_REFERENCE_PREFIXES = ("phase_",)
# derived / environment fields: not metrics, not identity (the _bytes /
# _flops families are the static observability columns of compiled_cost)
_IGNORED_EXACT = {"speedup", "ratio", "meps", "speedup_vs_1dev"} | _REFERENCE_METRICS
_IGNORED_SUFFIX = (
    "_meps", "_bytes", "_bytes_per_dev", "_per_dev", "_ratio", "_flops"
)


def is_tracked_metric(field: str) -> bool:
    if field in _REFERENCE_METRICS or field.startswith(_REFERENCE_PREFIXES):
        return False
    return field in _TRACKED_EXACT or field.endswith(_TRACKED_SUFFIX)


def _is_identity(field: str) -> bool:
    if is_tracked_metric(field) or field in _IGNORED_EXACT:
        return False
    if field.startswith(_REFERENCE_PREFIXES):
        return False
    return not field.endswith(_IGNORED_SUFFIX)


def row_identity(bench: str, row: Dict[str, Any]) -> Tuple:
    return (bench,) + tuple(
        sorted((k, str(v)) for k, v in row.items() if _is_identity(k))
    )


def _metrics(row: Dict[str, Any]) -> Dict[str, float]:
    out = {}
    for k, v in row.items():
        if is_tracked_metric(k) and isinstance(v, (int, float)) and v > 0:
            out[k] = float(v)
    return out


def _allowed(entry_list: List[Dict], bench: str, row: Dict, metric: str) -> bool:
    for entry in entry_list:
        match = entry.get("match", {})
        if entry.get("bench") not in (None, bench):
            continue
        if entry.get("metric") not in (None, metric):
            continue
        if all(str(row.get(k)) == str(v) for k, v in match.items()):
            return True
    return False


def compare(
    baseline: Dict[str, List[Dict]],
    fresh: Dict[str, List[Dict]],
    threshold: float,
    allowlist: List[Dict],
) -> Tuple[List[str], List[str]]:
    """Returns (failures, warnings) — human-readable lines."""
    failures: List[str] = []
    warnings: List[str] = []
    base_rows = {
        row_identity(b, r): r for b, rows in baseline.items() for r in rows
    }
    fresh_rows = {
        row_identity(b, r): (b, r) for b, rows in fresh.items() for r in rows
    }
    for ident, (bench, row) in fresh_rows.items():
        base = base_rows.get(ident)
        if base is None:
            warnings.append(f"new row (no baseline): {ident}")
            continue
        base_m = _metrics(base)
        for metric, val in _metrics(row).items():
            ref = base_m.get(metric)
            if ref is None:
                continue
            slowdown = val / ref - 1.0
            if slowdown > threshold:
                line = (
                    f"{bench}: {metric} {ref:g} -> {val:g} "
                    f"(+{slowdown:.0%} > {threshold:.0%}) at "
                    + ", ".join(f"{k}={v}" for k, v in ident[1:])
                )
                if _allowed(allowlist, bench, row, metric):
                    warnings.append("allowlisted: " + line)
                else:
                    failures.append(line)
    for ident in base_rows:
        if ident not in fresh_rows and ident[0] in fresh:
            warnings.append(f"baseline row missing from fresh run: {ident}")
    return failures, warnings


def main(argv: Iterable[str] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="BENCH_sort.json")
    ap.add_argument("--fresh", default="BENCH_fresh.json")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max tolerated slowdown fraction (0.25 = +25%%)")
    ap.add_argument("--allowlist", default="benchmarks/regression_allowlist.json")
    args = ap.parse_args(argv)

    try:
        with open(args.baseline) as fh:
            baseline = json.load(fh)
    except FileNotFoundError:
        print(f"no baseline at {args.baseline}: nothing to gate")
        return 0
    with open(args.fresh) as fh:
        fresh = json.load(fh)
    allowlist: List[Dict] = []
    try:
        with open(args.allowlist) as fh:
            allowlist = json.load(fh)
    except FileNotFoundError:
        pass

    failures, warnings = compare(
        baseline.get("benches", {}), fresh.get("benches", {}),
        args.threshold, allowlist,
    )
    for w in warnings:
        print("WARN", w)
    for f in failures:
        print("FAIL", f)
    if failures:
        print(f"\nperf gate: {len(failures)} regression(s) beyond "
              f"{args.threshold:.0%} — add an allowlist entry with a reason "
              f"if intentional ({args.allowlist})")
        return 1
    print(f"perf gate: OK ({len(warnings)} warnings)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
