"""Paper §4.5 + Appendix B: I/O-volume accounting (IS4o 48n vs s3-sort 86n).

The paper's key quantitative claim for the in-place design is that IS4o
moves ~48n bytes through the memory hierarchy per 8-byte element at one
level of recursion, while out-of-place s3-sort moves >86n (oracle array,
copy-back, allocation/write-allocate misses).

TPU analogue measured here from the compiled artifact (no execution):
  * bytes-accessed per element (trip-count-corrected, launch/hlo_cost)
    of our donated in-place pipeline vs the out-of-place s3-sort pipeline;
  * peak HBM footprint: in-place must be ~n*s + O(metadata) (donation
    reuses the input buffer), out-of-place ~2n*s.  This is the paper's
    OOM-column experiment, statically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ips4o import SortConfig, ips4o_sort
from repro.core.s3sort import s3_sort

from benchmarks.common import Row


def _stats(fn, x, donate: bool):
    f = jax.jit(fn, donate_argnums=(0,) if donate else ())
    lowered = f.lower(x)
    compiled = lowered.compile()
    from repro.launch.hlo_cost import analyze_hlo
    hc = analyze_hlo(compiled.as_text())
    mem = compiled.memory_analysis()
    temp = getattr(mem, "temp_size_in_bytes", 0) if mem else 0
    args = getattr(mem, "argument_size_in_bytes", 0) if mem else 0
    alias = getattr(mem, "alias_size_in_bytes", 0) if mem else 0
    return hc, temp, args, alias


def run(quick: bool = False):
    n = 1 << 18 if quick else 1 << 20
    rows: list[Row] = []
    x = jnp.asarray(np.random.default_rng(0).random(n, dtype=np.float32))
    elem = x.dtype.itemsize
    for name, fn, donate in [
        ("is4o_inplace", lambda a: ips4o_sort(a, cfg=SortConfig()), True),
        ("s3sort_oop", lambda a: s3_sort(a, cfg=SortConfig()), False),
    ]:
        hc, temp, args, alias = _stats(fn, x, donate)
        rows.append({
            "bench": "io_volume", "algo": name, "n": n,
            "bytes_per_elem": round(hc.bytes / n, 1),
            "hard_bytes_per_elem": round(hc.bytes_min / n, 1),
            "peak_temp_bytes": int(temp),
            "peak_over_input": round((temp + args) / (n * elem), 2),
            "aliased_bytes": int(alias),
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), ["bench", "algo", "n", "bytes_per_elem",
                 "hard_bytes_per_elem", "peak_temp_bytes",
                 "peak_over_input", "aliased_bytes"])
