"""Shared benchmark plumbing: stable timing on one CPU device + CSV rows.

Wall-clock numbers here are CPU-backend (this container has no TPU); they
are *relative* evidence (algorithm vs algorithm on identical hardware),
matching the paper's methodology of same-machine comparisons.  The TPU
roofline story lives in EXPERIMENTS.md §Roofline, derived from the
compiled dry-run instead of wall clocks.
"""
from __future__ import annotations

import json
import time
from typing import Any, Callable, Dict, Iterable, List

import jax
import numpy as np

__all__ = ["bench", "Row", "emit", "emit_json", "check_sorted"]

Row = Dict[str, Any]


def bench(
    fn: Callable[[], Any], *, warmup: int = 2, iters: int = 5, agg: str = "median"
) -> float:
    """Seconds/call of a nullary jitted callable (median by default).

    ``agg="min"`` is the noise-robust choice for dispatch-bound
    microbenchmarks on shared machines: the minimum is the cleanest
    observation of the actual cost, where a median still carries
    scheduler hiccups.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(min(ts) if agg == "min" else np.median(ts))


def check_sorted(out_keys, in_keys) -> None:
    out = np.asarray(out_keys)
    assert np.all(out[:-1] <= out[1:]), "output not sorted"
    np.testing.assert_array_equal(np.sort(np.asarray(in_keys)), out)


def emit(rows: Iterable[Row], header: List[str]) -> None:
    print(",".join(header))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in header))


def emit_json(all_rows: Dict[str, List[Row]], path: str) -> None:
    """Write every bench's rows to one machine-readable JSON file, so the
    perf trajectory is trackable per PR (CI archives the artifact)."""
    payload = {
        "schema": 1,
        "backend": jax.default_backend(),
        "generated_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "benches": all_rows,
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
    print(f"wrote {path}")
