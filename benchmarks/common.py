"""Shared benchmark plumbing: stable timing on one CPU device + CSV rows.

Wall-clock numbers here are CPU-backend (this container has no TPU); they
are *relative* evidence (algorithm vs algorithm on identical hardware),
matching the paper's methodology of same-machine comparisons.  The TPU
roofline story lives in EXPERIMENTS.md §Roofline, derived from the
compiled dry-run instead of wall clocks.
"""
from __future__ import annotations

import json
import time
from typing import Any, Callable, Dict, Iterable, List

import jax
import numpy as np

__all__ = ["bench", "Row", "emit", "emit_json", "check_sorted", "compiled_cost",
           "export_obs_trace"]

Row = Dict[str, Any]


def compiled_cost(fn: Callable[..., Any], *args: Any):
    """AOT-compile ``fn(*args)`` and capture its static cost profile.

    Returns ``(nullary, row)``: a nullary callable running the compiled
    executable (feed it to :func:`bench`) and a Row of observability
    columns — the XLA memory watermark (``mem_temp_bytes`` /
    ``mem_arg_bytes`` / ``mem_out_bytes`` / ``mem_peak_bytes``, from
    ``compiled.memory_analysis()``) and the analytic HLO cost
    (``hlo_flops`` / ``hlo_bytes``, via the same
    ``repro.launch.hlo_cost.analyze_hlo`` the roofline dry-run uses).
    Every column is gate-neutral (byte/flop suffixes are neither identity
    nor tracked metrics in check_regression); fields a backend doesn't
    report are simply absent.
    """
    compiled = jax.jit(fn).lower(*args).compile()
    row: Row = {}
    try:
        ma = compiled.memory_analysis()
    except Exception:
        ma = None
    if ma is not None:
        peak = 0
        for attr, col in (
            ("temp_size_in_bytes", "mem_temp_bytes"),
            ("argument_size_in_bytes", "mem_arg_bytes"),
            ("output_size_in_bytes", "mem_out_bytes"),
        ):
            v = getattr(ma, attr, None)
            if isinstance(v, (int, float)):
                row[col] = int(v)
                peak += int(v)
        if row:
            row["mem_peak_bytes"] = peak
    try:
        from repro.launch.hlo_cost import analyze_hlo

        cost = analyze_hlo(compiled.as_text())
        row["hlo_flops"] = float(cost.flops)
        row["hlo_bytes"] = float(cost.bytes)
    except Exception:
        pass
    return (lambda: compiled(*args)), row


def bench(
    fn: Callable[[], Any], *, warmup: int = 2, iters: int = 5, agg: str = "median"
) -> float:
    """Seconds/call of a nullary jitted callable (median by default).

    ``agg="min"`` is the noise-robust choice for dispatch-bound
    microbenchmarks on shared machines: the minimum is the cleanest
    observation of the actual cost, where a median still carries
    scheduler hiccups.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(min(ts) if agg == "min" else np.median(ts))


def check_sorted(out_keys, in_keys) -> None:
    out = np.asarray(out_keys)
    assert np.all(out[:-1] <= out[1:]), "output not sorted"
    np.testing.assert_array_equal(np.sort(np.asarray(in_keys)), out)


def emit(rows: Iterable[Row], header: List[str]) -> None:
    print(",".join(header))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in header))


def export_obs_trace(prefix: str, n: int = 1 << 18) -> List[Row]:
    """Run instrumented quick-shape sorts with ``repro.obs`` enabled and
    export the trace: ``<prefix>.jsonl`` (spans + metrics), and
    ``<prefix>.trace.json`` (Chrome trace-event JSON — load it at
    https://ui.perfetto.dev).

    Exercises every metric family the ISSUE names: plan-cache hit/miss +
    compiled hit/miss (a fresh :class:`~repro.ops.plan.PlanCache` queried
    twice), kernel launch-spec choices (one Pallas-engine sort at a
    128-aligned size), the in-jit functional stats (bucket imbalance,
    base-case counts), and a staged-subtraction per-phase attribution row
    (``phase_*_us`` columns — untracked reference metrics in the perf
    gate: each is a difference of isolated timings, honest about overlap
    but too jittery to gate).
    """
    import os
    import tempfile
    from functools import partial

    import jax.numpy as jnp

    from repro import obs, ops
    from repro.core import sampling
    from repro.core.ips4o import SortConfig, plan_levels
    from repro.ops import keyspace
    from repro.ops.plan import PlanCache

    from benchmarks.sort_classifier import _classify_only, _partition_only

    was = obs.enabled()
    obs.enabled(True)
    obs.reset()
    jax.clear_caches()  # jits traced while disabled carry no obs hooks
    try:
        x = jnp.asarray(
            np.random.default_rng(0).standard_normal(n), jnp.float32
        )
        # plan-cache traffic: miss + compiled-miss at the full shape, then
        # an autotuned small shape (sweep + persisted plan) looked up twice
        # -> hit, plus a compiled-hit on the re-request
        cache = PlanCache(path=os.path.join(tempfile.mkdtemp(), "plans.json"))
        f = cache.get_sorter(n, jnp.float32)
        with obs.trace("ops.sort:jit", n=n):
            obs.block(f(x))
        cache.get_sorter(n, jnp.float32)
        m = 1 << 12
        cache.get_sorter(m, jnp.float32, tune=True)
        cache.config_for("sort", m, jnp.float32)

        # one Pallas-engine level pass at a 128-aligned size: the fused
        # kernel resolves its tile through launch_spec -> launch.spec counts
        small = SortConfig(base_case=1024, tile=512, max_sample=1024,
                           engine="pallas")
        g = jax.jit(partial(ops.sort, cfg=small))
        with obs.trace("ops.sort:pallas", n=1 << 13):
            obs.block(g(x[: 1 << 13]))

        # staged-subtraction phase attribution at the full shape
        cfg = SortConfig(engine="xla")
        k = plan_levels(n, cfg)[0]
        rng = jax.random.PRNGKey(0)
        f_enc = jax.jit(keyspace.encode)
        enc = jax.block_until_ready(f_enc(x))

        def _sample_only(e, r):
            m1 = min(max(sampling.oversampling_factor(n) * k, k),
                     cfg.max_sample, n)
            pos = jax.random.randint(r, (m1,), 0, n)
            return sampling.select_splitters(
                jnp.sort(jnp.take(e, pos, axis=0)), k)

        f_sample = jax.jit(_sample_only)
        f_clf = jax.jit(partial(_classify_only, k=k, cfg=cfg, clf="tree"))
        f_part = jax.jit(partial(_partition_only, cfg=cfg))
        f_full = jax.jit(partial(ops.sort, cfg=cfg))

        tenc = obs.timed_min("phase:encode", lambda: f_enc(x), n=n)
        ts = obs.timed_min("phase:sample", lambda: f_sample(enc, rng), n=n)
        tc = obs.timed_min("phase:classify+sample",
                           lambda: f_clf(enc, rng), n=n)
        tp = obs.timed_min("phase:levels", lambda: f_part(enc), n=n)
        tf = obs.timed_min("phase:total", lambda: f_full(x), n=n)
        row: Row = {
            "bench": "obs_trace", "n": n, "dtype": "float32",
            "phase_encode_us": round(tenc * 1e6, 1),
            "phase_sample_us": round(ts * 1e6, 1),
            "phase_classify_us": round(max(tc - ts, 0.0) * 1e6, 1),
            "phase_partition_us": round(max(tp - tc, 0.0) * 1e6, 1),
            "phase_base_case_us": round(max(tf - tp - 2 * tenc, 0.0) * 1e6, 1),
            "phase_total_us": round(tf * 1e6, 1),
        }
        jax.effects_barrier()  # flush pending in-jit metric callbacks
        obs.export_jsonl(prefix + ".jsonl")
        obs.export_chrome_trace(prefix + ".trace.json")
        print(obs.summary())
        return [row]
    finally:
        obs.enabled(was)
        obs.reset()
        jax.clear_caches()


def emit_json(all_rows: Dict[str, List[Row]], path: str) -> None:
    """Write every bench's rows to one machine-readable JSON file, so the
    perf trajectory is trackable per PR (CI archives the artifact)."""
    payload = {
        "schema": 1,
        "backend": jax.default_backend(),
        "generated_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "benches": all_rows,
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
    print(f"wrote {path}")
