"""Shared benchmark plumbing: stable timing on one CPU device + CSV rows.

Wall-clock numbers here are CPU-backend (this container has no TPU); they
are *relative* evidence (algorithm vs algorithm on identical hardware),
matching the paper's methodology of same-machine comparisons.  The TPU
roofline story lives in EXPERIMENTS.md §Roofline, derived from the
compiled dry-run instead of wall clocks.
"""
from __future__ import annotations

import json
import time
from typing import Any, Callable, Dict, Iterable, List

import jax
import numpy as np

__all__ = ["bench", "Row", "emit", "emit_json", "check_sorted", "compiled_cost"]

Row = Dict[str, Any]


def compiled_cost(fn: Callable[..., Any], *args: Any):
    """AOT-compile ``fn(*args)`` and capture its static cost profile.

    Returns ``(nullary, row)``: a nullary callable running the compiled
    executable (feed it to :func:`bench`) and a Row of observability
    columns — the XLA memory watermark (``mem_temp_bytes`` /
    ``mem_arg_bytes`` / ``mem_out_bytes`` / ``mem_peak_bytes``, from
    ``compiled.memory_analysis()``) and the analytic HLO cost
    (``hlo_flops`` / ``hlo_bytes``, via the same
    ``repro.launch.hlo_cost.analyze_hlo`` the roofline dry-run uses).
    Every column is gate-neutral (byte/flop suffixes are neither identity
    nor tracked metrics in check_regression); fields a backend doesn't
    report are simply absent.
    """
    compiled = jax.jit(fn).lower(*args).compile()
    row: Row = {}
    try:
        ma = compiled.memory_analysis()
    except Exception:
        ma = None
    if ma is not None:
        peak = 0
        for attr, col in (
            ("temp_size_in_bytes", "mem_temp_bytes"),
            ("argument_size_in_bytes", "mem_arg_bytes"),
            ("output_size_in_bytes", "mem_out_bytes"),
        ):
            v = getattr(ma, attr, None)
            if isinstance(v, (int, float)):
                row[col] = int(v)
                peak += int(v)
        if row:
            row["mem_peak_bytes"] = peak
    try:
        from repro.launch.hlo_cost import analyze_hlo

        cost = analyze_hlo(compiled.as_text())
        row["hlo_flops"] = float(cost.flops)
        row["hlo_bytes"] = float(cost.bytes)
    except Exception:
        pass
    return (lambda: compiled(*args)), row


def bench(
    fn: Callable[[], Any], *, warmup: int = 2, iters: int = 5, agg: str = "median"
) -> float:
    """Seconds/call of a nullary jitted callable (median by default).

    ``agg="min"`` is the noise-robust choice for dispatch-bound
    microbenchmarks on shared machines: the minimum is the cleanest
    observation of the actual cost, where a median still carries
    scheduler hiccups.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(min(ts) if agg == "min" else np.median(ts))


def check_sorted(out_keys, in_keys) -> None:
    out = np.asarray(out_keys)
    assert np.all(out[:-1] <= out[1:]), "output not sorted"
    np.testing.assert_array_equal(np.sort(np.asarray(in_keys)), out)


def emit(rows: Iterable[Row], header: List[str]) -> None:
    print(",".join(header))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in header))


def emit_json(all_rows: Dict[str, List[Row]], path: str) -> None:
    """Write every bench's rows to one machine-readable JSON file, so the
    perf trajectory is trackable per PR (CI archives the artifact)."""
    payload = {
        "schema": 1,
        "backend": jax.default_backend(),
        "generated_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "benches": all_rows,
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
    print(f"wrote {path}")
