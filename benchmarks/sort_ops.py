"""repro.ops benchmarks: partial sort vs full sort, group_by vs sort+scan.

Two claims to evidence (DESIGN.md §5):

  * ``ops.bottomk``/``topk`` beat a full ``ips4o_sort`` for k << n because
    the base case runs only over the rank-covering prefix — the rows report
    the window counts of both plans next to the wall clocks, so the "fewer
    base-case windows sorted" mechanism is visible, not just the speedup;
  * ``ops.group_by`` (one stable partition, no sampling) beats the generic
    sort+boundary-scan formulation for int-keyed grouping (the MoE regime),
    and stays flat on duplicate-heavy keys where the equality buckets do
    the work.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ips4o import SortConfig, ips4o_sort, plan_levels
from repro.ops import bottomk, group_by
from repro.ops.topk import _prefix_limit

from benchmarks.common import Row, bench


def _window_count(span: int, W: int) -> int:
    """Windows the two overlapped base-case passes sort over a span."""
    if span <= 0:
        return 0
    return span // W + max(0, (span - W) // W)


def _sort_scan_groups(keys: jax.Array, num_groups: int, cfg: SortConfig):
    """Baseline: full sort + boundary cumsum scan (what group_by replaces)."""
    idx = jnp.arange(keys.shape[0], dtype=jnp.int32)
    ks, perm = ips4o_sort(keys, idx, cfg=cfg)
    counts = jnp.zeros((num_groups,), jnp.int32).at[ks].add(1, mode="promise_in_bounds")
    return ks, perm, counts


def run(quick: bool = False):
    rows: list[Row] = []
    cfg = SortConfig()
    W = cfg.base_case
    n = (1 << 14) if quick else (1 << 17)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))

    # ---- topk vs full sort -------------------------------------------------
    f_full = jax.jit(lambda a: ips4o_sort(a, cfg=cfg))
    t_full = bench(lambda: f_full(x))
    unit = max(W, cfg.tile)
    n_pad = -(-n // unit) * unit
    full_windows = _window_count(n_pad, W)
    for k in (16, 256, 4096):
        if k >= n:
            continue
        f_topk = jax.jit(lambda a, k=k: bottomk(a, k, cfg=cfg))
        v, i = jax.tree.map(np.asarray, f_topk(x))
        np.testing.assert_allclose(v, np.sort(np.asarray(x))[:k])
        np.testing.assert_array_equal(np.asarray(x)[i], v)
        t_topk = bench(lambda: f_topk(x))
        P = _prefix_limit(k, W, n_pad)
        rows.append({
            "bench": "topk_vs_full", "n": n, "k": k,
            "levels": len(plan_levels(n_pad, cfg)),
            "windows_full": full_windows,
            "windows_topk": _window_count(P, W),
            "full_us": round(t_full * 1e6, 1),
            "topk_us": round(t_topk * 1e6, 1),
            "speedup": round(t_full / t_topk, 2),
        })

    # ---- group_by vs sort+scan --------------------------------------------
    m = (1 << 14) if quick else (1 << 16)
    for E, skew in [(64, "uniform"), (64, "hot")]:
        if skew == "uniform":
            ids = rng.integers(0, E, m).astype(np.int32)
        else:  # zipf-ish hot groups — the duplicate-keys regime of §4.4
            ids = (rng.zipf(1.5, m) % E).astype(np.int32)
        keys = jnp.asarray(ids)
        f_gb = jax.jit(lambda a: group_by(a, num_groups=E))
        f_ss = jax.jit(lambda a: _sort_scan_groups(a, E, cfg))
        g = f_gb(keys)
        ks, perm, counts = f_ss(keys)
        np.testing.assert_array_equal(np.asarray(g.counts), np.asarray(counts))
        np.testing.assert_array_equal(np.asarray(g.keys), np.asarray(ks))
        t_gb = bench(lambda: f_gb(keys))
        t_ss = bench(lambda: f_ss(keys))
        rows.append({
            "bench": "group_by_vs_sortscan", "n": m, "k": E, "levels": skew,
            "windows_full": "", "windows_topk": "",
            "full_us": round(t_ss * 1e6, 1),
            "topk_us": round(t_gb * 1e6, 1),
            "speedup": round(t_ss / t_gb, 2),
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), ["bench", "n", "k", "levels", "windows_full", "windows_topk",
                 "full_us", "topk_us", "speedup"])
