"""DESIGN.md §8: multi-level distributed sort on a simulated host mesh.

For d in {2, 4, 8} virtual CPU devices (subprocess each, like
``sort_scaling``), runs the ``repro.dist`` engine on a single-axis mesh
(one exchange level) and — where d factors — a two-axis mesh (2, d/2)
(two levels), reporting wall clock and the **collective volume per
level**: bytes entering each level's ``all_to_all`` per device, the
quantity the multi-level schedule is designed to keep per-axis-sized
(splitter sets of ``groups - 1``, fan-in ``groups`` instead of d).

NOTE: virtual devices share one physical core, so wall clock validates
overhead only; the volume-per-level accounting (static, from the level
schedule) is the scaling evidence, matching the Fugaku observation that
per-axis collective fan-in is what survives at scale.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import Row

N = 1 << 18
DEVICE_COUNTS = [2, 4, 8]

_CHILD = r"""
import os, sys, json
d = int(sys.argv[1]); n = int(sys.argv[2]); axes_kind = sys.argv[3]
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={d}"
import jax, time
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import dist
from repro.dist.levels import plan_schedule

if axes_kind == "two" and d >= 4:
    mesh = jax.make_mesh((2, d // 2), ("pod", "data"))
    axes = ("pod", "data")
else:
    mesh = jax.make_mesh((d,), ("data",))
    axes = "data"

rng = np.random.default_rng(0)
x = jnp.asarray(rng.random(n, dtype=np.float32))
x = jax.device_put(x, NamedSharding(mesh, P(axes if isinstance(axes, str) else tuple(axes))))
f = jax.jit(lambda a: dist.sort(a, mesh, axes))
out, counts, overflow = jax.block_until_ready(f(x))
assert not bool(np.any(np.asarray(overflow))), "capacity overflow"
counts = np.asarray(counts)
vals = np.asarray(out)
cap = vals.shape[0] // counts.shape[0]
glob = np.concatenate([vals[i*cap:i*cap+counts[i]] for i in range(counts.shape[0])])
np.testing.assert_array_equal(np.sort(np.asarray(x)), glob)
ts = []
for _ in range(3):
    t0 = time.perf_counter(); jax.block_until_ready(f(x))
    ts.append(time.perf_counter() - t0)

# static collective-volume accounting from the level schedule: each level
# moves groups * capacity key slots (+ the count vector) per device
sched = plan_schedule(dict(mesh.shape), axes, n // d, slack=2.0)
itemsize = 4
vol_per_level = [lvl.groups * lvl.capacity * itemsize for lvl in sched]
print(json.dumps({
    "d": d, "t": float(np.median(ts)), "levels": len(sched),
    "splitters_per_level": [lvl.groups - 1 for lvl in sched],
    "vol_per_level": vol_per_level,
    "exchange_bytes_per_dev": int(sum(vol_per_level)),
}))
"""


def run(quick: bool = False):
    n = (1 << 16) if quick else N
    counts = DEVICE_COUNTS[:2] if quick else DEVICE_COUNTS
    rows: list[Row] = []
    env = {**os.environ, "PYTHONPATH": os.pathsep.join(sys.path)}
    for d in counts:
        kinds = ["one"] + (["two"] if d >= 4 else [])
        for kind in kinds:
            r = subprocess.run(
                [sys.executable, "-c", _CHILD, str(d), str(n), kind],
                capture_output=True, text=True, env=env, timeout=1200,
            )
            if r.returncode != 0:
                raise RuntimeError(
                    f"dist child d={d} {kind} failed:\n{r.stderr[-2000:]}"
                )
            res = json.loads(r.stdout.strip().splitlines()[-1])
            rows.append({
                "bench": "dist_multilevel",
                "devices": d,
                "mesh": "1-axis" if kind == "one" else "2-axis",
                "n": n,
                "levels": res["levels"],
                "splitters_per_level": "/".join(
                    str(s) for s in res["splitters_per_level"]
                ),
                "s_per_call": round(res["t"], 5),
                "exchange_bytes_per_dev": res["exchange_bytes_per_dev"],
                "vol_per_level_bytes": "/".join(
                    str(v) for v in res["vol_per_level"]
                ),
            })
    return rows


HEADER = [
    "bench", "devices", "mesh", "n", "levels", "splitters_per_level",
    "s_per_call", "exchange_bytes_per_dev", "vol_per_level_bytes",
]
