"""DESIGN.md §8: multi-level distributed sort on a simulated host mesh.

For d in {2, 4, 8} virtual CPU devices (subprocess each, like
``sort_scaling``), runs the ``repro.dist`` engine on a single-axis mesh
(one exchange level) and — where d factors — a two-axis mesh (2, d/2)
(two levels), reporting wall clock and the **collective volume per
level**: bytes entering each level's ``all_to_all`` per device, the
quantity the multi-level schedule is designed to keep per-axis-sized
(splitter sets of ``groups - 1``, fan-in ``groups`` instead of d).

Each row also times the overlap-scheduled exchange (DESIGN.md §13) next
to the synchronous one — ``s_per_call`` vs ``overlap_us`` are the
off/on wall clocks, ``overlap_ratio`` their quotient — after asserting
the two outputs are bit-identical, and reports ``order_cost_ratio``:
the static topology cost (``dist.schedule_cost``) of the declared axis
order over the cost-model optimum (1.0 = already optimal).

NOTE: virtual devices share one physical core, so wall clock validates
overhead only (overlap cannot *win* here — there is no second core to
overlap onto; ``overlap_ratio`` ~ 1 is the expected healthy reading);
the volume-per-level accounting (static, from the level schedule) is
the scaling evidence, matching the Fugaku observation that per-axis
collective fan-in is what survives at scale.

``python -m benchmarks.sort_distributed --overlap-trace PATH`` runs one
d=8 two-axis overlapped sort with ``repro.obs`` enabled and exports the
JSONL trace — the per-level ``dist.overlap_efficiency`` /
``dist.collective_bytes`` evidence the CI mesh job uploads.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from benchmarks.common import Row

N = 1 << 18
DEVICE_COUNTS = [2, 4, 8]

_CHILD = r"""
import os, sys, json
d = int(sys.argv[1]); n = int(sys.argv[2]); axes_kind = sys.argv[3]
trace = sys.argv[4] if len(sys.argv) > 4 else ""
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={d}"
import jax, time
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import dist, obs
from repro.dist.levels import axis_bandwidths, order_axes, plan_schedule, schedule_cost

if trace:
    obs.enabled(True)  # before any jit traces, so the hooks are staged

if axes_kind == "two" and d >= 4:
    mesh = jax.make_mesh((2, d // 2), ("pod", "data"))
    axes = ("pod", "data")
else:
    mesh = jax.make_mesh((d,), ("data",))
    axes = "data"

rng = np.random.default_rng(0)
x = jnp.asarray(rng.random(n, dtype=np.float32))
x = jax.device_put(x, NamedSharding(mesh, P(axes if isinstance(axes, str) else tuple(axes))))
f = jax.jit(lambda a: dist.sort(a, mesh, axes))
f_ovl = jax.jit(lambda a: dist.sort(a, mesh, axes, overlap=True))
out, counts, overflow = jax.block_until_ready(f(x))
assert not bool(np.any(np.asarray(overflow))), "capacity overflow"
counts = np.asarray(counts)
vals = np.asarray(out)
cap = vals.shape[0] // counts.shape[0]
glob = np.concatenate([vals[i*cap:i*cap+counts[i]] for i in range(counts.shape[0])])
np.testing.assert_array_equal(np.sort(np.asarray(x)), glob)
# the overlap schedule must be bit-identical before its clock means anything
# (uint32 view: float sentinel tails decode to NaN)
out_o, counts_o, ovf_o = jax.block_until_ready(f_ovl(x))
assert not bool(np.any(np.asarray(ovf_o)))
np.testing.assert_array_equal(np.asarray(counts_o), counts)
np.testing.assert_array_equal(np.asarray(out_o).view(np.uint32), vals.view(np.uint32))
def med(fn):
    ts = []
    for _ in range(3):
        t0 = time.perf_counter(); jax.block_until_ready(fn(x))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))
t_sync, t_ovl = med(f), med(f_ovl)

if trace:
    jax.effects_barrier()
    obs.export_jsonl(trace)

# static collective-volume accounting from the level schedule: each level
# moves groups * capacity key slots (+ the count vector) per device
sched = plan_schedule(dict(mesh.shape), axes, n // d, slack=2.0)
itemsize = 4
vol_per_level = [lvl.groups * lvl.capacity * itemsize for lvl in sched]
# static topology cost of the declared order vs the cost-model optimum
bw = axis_bandwidths(dict(mesh.shape))
best = order_axes(dict(mesh.shape), axes, n // d)
best_cost = schedule_cost(plan_schedule(dict(mesh.shape), best, n // d, slack=2.0), bw)
print(json.dumps({
    "d": d, "t": t_sync, "t_overlap": t_ovl, "levels": len(sched),
    "splitters_per_level": [lvl.groups - 1 for lvl in sched],
    "vol_per_level": vol_per_level,
    "exchange_bytes_per_dev": int(sum(vol_per_level)),
    "order_cost_ratio": schedule_cost(sched, bw) / best_cost,
}))
"""


def run(quick: bool = False):
    n = (1 << 16) if quick else N
    counts = DEVICE_COUNTS[:2] if quick else DEVICE_COUNTS
    rows: list[Row] = []
    for d in counts:
        kinds = ["one"] + (["two"] if d >= 4 else [])
        for kind in kinds:
            res = _child(d, n, kind)
            rows.append({
                "bench": "dist_multilevel",
                "devices": d,
                "mesh": "1-axis" if kind == "one" else "2-axis",
                "n": n,
                "levels": res["levels"],
                "splitters_per_level": "/".join(
                    str(s) for s in res["splitters_per_level"]
                ),
                "s_per_call": round(res["t"], 5),
                "overlap_us": round(res["t_overlap"] * 1e6, 1),
                "overlap_ratio": round(res["t_overlap"] / res["t"], 3),
                "order_cost_ratio": round(res["order_cost_ratio"], 3),
                "exchange_bytes_per_dev": res["exchange_bytes_per_dev"],
                "vol_per_level_bytes": "/".join(
                    str(v) for v in res["vol_per_level"]
                ),
            })
    return rows


def _child(d: int, n: int, kind: str, trace: str = "") -> dict:
    env = {**os.environ, "PYTHONPATH": os.pathsep.join(sys.path)}
    r = subprocess.run(
        [sys.executable, "-c", _CHILD, str(d), str(n), kind, trace],
        capture_output=True, text=True, env=env, timeout=1200,
    )
    if r.returncode != 0:
        raise RuntimeError(
            f"dist child d={d} {kind} failed:\n{r.stderr[-2000:]}"
        )
    return json.loads(r.stdout.strip().splitlines()[-1])


HEADER = [
    "bench", "devices", "mesh", "n", "levels", "splitters_per_level",
    "s_per_call", "overlap_us", "overlap_ratio", "order_cost_ratio",
    "exchange_bytes_per_dev", "vol_per_level_bytes",
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true")
    ap.add_argument(
        "--overlap-trace", default=None, metavar="PATH",
        help="run one d=8 two-axis overlapped sort with obs enabled and "
             "export the per-level overlap-efficiency JSONL trace to PATH",
    )
    args = ap.parse_args(argv)
    if args.overlap_trace:
        path = os.path.abspath(args.overlap_trace)
        res = _child(8, 1 << 16, "two", trace=path)
        spans = sum(1 for line in open(path) if line.strip())
        print(f"wrote {path} ({spans} records; overlap sort "
              f"{res['t_overlap'] * 1e3:.1f} ms vs sync {res['t'] * 1e3:.1f} ms)")
        return 0
    for row in run(quick=args.quick):
        print(json.dumps(row))
    return 0


if __name__ == "__main__":
    sys.exit(main())
