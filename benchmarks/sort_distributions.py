"""Paper Fig. 8 / 9-11 (robustness): all nine input distributions.

Shows the equality-bucket machinery (§4.4) turning duplicate-heavy inputs
(RootDup/TwoDup/EightDup/Ones) into easy instances, as in the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ips4o import SortConfig, ips4o_sort
from repro.data.distributions import DISTRIBUTIONS, make_input

from benchmarks.common import Row, bench, check_sorted

N = 1 << 20


def run(quick: bool = False):
    n = (1 << 18) if quick else N
    rows: list[Row] = []
    sorter = jax.jit(lambda a: ips4o_sort(a, cfg=SortConfig()))
    lib = jax.jit(jnp.sort)
    for dist in DISTRIBUTIONS:
        x = jnp.asarray(make_input(dist, n, np.float32, seed=7))
        check_sorted(sorter(x), x)
        t_ours = bench(lambda: sorter(x))
        t_lib = bench(lambda: lib(x))
        rows.append({
            "bench": "distributions", "distribution": dist, "n": n,
            "is4o_ns_per_elem": round(t_ours / n * 1e9, 2),
            "jnp_sort_ns_per_elem": round(t_lib / n * 1e9, 2),
            "speedup_vs_jnp": round(t_lib / t_ours, 2),
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), ["bench", "distribution", "n", "is4o_ns_per_elem",
                 "jnp_sort_ns_per_elem", "speedup_vs_jnp"])
