"""DESIGN.md §9: classifier engines (tree vs radix vs learned vs auto).

One row per (classifier, distribution, dtype, n): full-sort wall clock
plus the two phase timings where the engines actually differ —

  pass_ns_per_elem      the level passes only (classify + partition); the
                        base case is classifier-agnostic and dominates the
                        full sort at these sizes, so the full-sort column
                        alone would hide the seam;
  classify_ns_per_elem  the bucket-id computation alone (sampling +
                        splitter selection + descent for the tree, one
                        shift + mask for radix, sample + CDF fit + eval
                        for learned) — the paper's (and IPS2Ra's) claim
                        lives here.

Radix rows carry ``speedup`` = tree classify / radix classify for the
same cell.  The ``auto`` row reports the plan-cache race winner for the
cell's (n, dtype, distribution label) and times the routed sort — the
"auto never loses to the best fixed engine by >10%" check is a direct
column comparison.  CPU-backend numbers, XLA partition engine (interpret-
mode Pallas would time the interpreter, not the classifier).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.classify import learned_bucket_ids, radix_bucket_ids
from repro.classify.tree import classify
from repro.core import sampling
from repro.core.ips4o import (
    SortConfig, ips4o_sort, pad_with_sentinel, partition_passes, plan_levels,
)
from repro.data.distributions import make_input
from repro.ops import keyspace
from repro.ops.plan import PlanCache

from benchmarks.common import Row, bench, check_sorted

DISTS = ["Uniform", "TwoDup", "Sorted", "Exponential"]
SIZES = [1 << 16, 1 << 20]
CLASSIFIERS = ["tree", "radix", "learned"]


def _partition_only(x: jax.Array, cfg: SortConfig):
    """Level passes only — classify + stable partition, no base case."""
    arrays = pad_with_sentinel({"k": x}, max(cfg.base_case, cfg.tile))
    levels = plan_levels(arrays["k"].shape[0], cfg)
    if not levels:
        return arrays["k"], None
    out, off, _, _ = partition_passes(arrays, x.shape[0], cfg, levels)
    return out["k"], off


def _classify_only(enc: jax.Array, rng, *, k: int, cfg: SortConfig, clf: str):
    """Bucket ids alone, including each engine's per-call setup (the tree
    and learned engines pay their sampling here; radix pays nothing)."""
    n = enc.shape[0]
    if clf == "radix":
        return radix_bucket_ids(enc, k)
    m1 = min(max(sampling.oversampling_factor(n) * k, k), cfg.max_sample, n)
    pos = jax.random.randint(rng, (m1,), 0, n)
    sample = jnp.sort(jnp.take(enc, pos, axis=0))
    spl = sampling.select_splitters(sample, k)
    if clf == "learned":
        return learned_bucket_ids(enc, sample, spl, k)[0]
    return classify(enc, spl, k)


def _draw(dist: str, n: int, dtype) -> jax.Array:
    npdt = np.dtype(jnp.dtype(dtype).name)
    return jnp.asarray(make_input(dist, n, npdt, seed=42))


def _cells(quick: bool):
    sizes = SIZES[:1] if quick else SIZES
    dtypes = [jnp.uint32] if quick else [jnp.uint32, jnp.float32]
    for dtype in dtypes:
        for n in sizes:
            for dist in DISTS:
                yield dist, dtype, n


def _bench_cell(dist: str, dtype, n: int, plan_cache: PlanCache) -> list:
    x = _draw(dist, n, dtype)
    enc = keyspace.encode(x)
    k = plan_levels(n, SortConfig())[0]
    rng = jax.random.PRNGKey(0)
    rows: list[Row] = []
    times = {}
    for clf in CLASSIFIERS:
        cfg = SortConfig(engine="xla", classifier=clf)
        f = jax.jit(partial(ips4o_sort, cfg=cfg))
        fpart = jax.jit(partial(_partition_only, cfg=cfg))
        fclf = jax.jit(partial(_classify_only, k=k, cfg=cfg, clf=clf))
        check_sorted(f(enc), enc)
        t = bench(lambda f=f: f(enc), agg="min")
        # the isolated sub-step timers are the noisiest columns of the
        # suite (tens of us absolute): min-of-9 via the obs tracer instead
        # of min-of-5 tightens run-to-run variance, and with obs enabled
        # the k attempts land in the trace as phase:* spans
        tp = obs.timed_min("phase:pass", lambda fpart=fpart: fpart(enc),
                           clf=clf, dist=dist, n=n)
        tc = obs.timed_min("phase:classify", lambda fclf=fclf: fclf(enc, rng),
                           clf=clf, dist=dist, n=n)
        times[clf] = t
        row = {
            "bench": "classifier", "clf": clf, "dist": dist,
            "dtype": jnp.dtype(dtype).name, "n": n,
            "s_per_call": round(t, 5),
            "ns_per_elem": round(t / n * 1e9, 2),
            "pass_ns_per_elem": round(tp / n * 1e9, 2),
            "classify_ns_per_elem": round(tc / n * 1e9, 3),
        }
        rows.append(row)
    # the ≥1.3x criterion column: same-cell classify-phase ratio
    tree_c, radix_c = rows[0]["classify_ns_per_elem"], rows[1]["classify_ns_per_elem"]
    rows[1]["speedup"] = round(tree_c / max(radix_c, 1e-9), 2)

    # auto: race on the cell's own input (the eager data-aware path) —
    # keyed per benchmark distribution, so cells whose coarse
    # distribution_moments labels collide still each race their own data
    winner = plan_cache.classifier_plan(n, dtype, dist=dist, tune=True, x=enc)
    # with a cached plan the routed sort IS the winner engine's jitted sort,
    # so its cost is the fixed row's measurement — re-timing the identical
    # computation in a fresh closure would only add CPU-container jitter to
    # the speedup column, which is meant to isolate routing quality
    t = times[winner or "tree"]
    rows.append({
        "bench": "classifier", "clf": f"auto->{winner}", "dist": dist,
        "dtype": jnp.dtype(dtype).name, "n": n,
        "s_per_call": round(t, 5),
        "ns_per_elem": round(t / n * 1e9, 2),
        "speedup": round(min(times.values()) / t, 2),  # vs best fixed
    })
    return rows


def run(quick: bool = False):
    rows: list[Row] = []
    # races run on a fresh per-run cache: a stale winner persisted under
    # different machine load would make the auto rows misreport the router
    import os
    import tempfile

    plan_cache = PlanCache(
        path=os.path.join(tempfile.mkdtemp(), "clf_plans.json")
    )
    for dist, dtype, n in _cells(quick):
        rows.extend(_bench_cell(dist, dtype, n, plan_cache))
    if not quick:
        # u64: the widest keyspace, where the radix extractor's constant
        # cost gap over the 2·log2(k)-deep tree descent is largest.  Runs
        # in a child process with x64 enabled from startup — flipping
        # enable_x64 mid-process destabilizes this jaxlib after a long
        # compile history (see tests/test_classify.py's u64 parity test)
        rows.extend(_u64_rows())
    return rows


def _u64_rows() -> list:
    import json as _json
    import os
    import subprocess
    import sys

    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.sort_classifier"],
        env=dict(os.environ, JAX_ENABLE_X64="1", SORT_CLASSIFIER_U64="1"),
        capture_output=True,
        text=True,
        timeout=1200,
    )
    if proc.returncode != 0:
        print(f"# u64 cell failed in subprocess:\n{proc.stderr[-2000:]}")
        return []
    return _json.loads(proc.stdout.splitlines()[-1])


if __name__ == "__main__":
    import os

    if os.environ.get("SORT_CLASSIFIER_U64"):
        # child mode (x64 on from startup): one u64 cell, rows as JSON
        import json as _json
        import tempfile

        pc = PlanCache(path=os.path.join(tempfile.mkdtemp(), "clf_plans.json"))
        print(_json.dumps(_bench_cell("Uniform", jnp.uint64, SIZES[0], pc)))
    else:
        from benchmarks.common import emit
        emit(run(), ["bench", "clf", "dist", "dtype", "n", "s_per_call",
                     "ns_per_elem", "pass_ns_per_elem", "classify_ns_per_elem",
                     "speedup"])
